//! E5 — Fig. 4 + Tables 3–7: downstream comparison of GaLore vs baseline
//! checkpoints across the five task categories.
//!
//! Trains both optimizers on identical data, then runs the synthetic
//! five-category suite on both final parameter sets. Reproduced claim:
//! near-parity averages, with no category collapsing under GaLore.

use galore2::config::TrainConfig;
use galore2::coordinator;

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let steps: u64 = if quick { 150 } else { 400 };
    let questions = if quick { 30 } else { 80 };
    let preset = "llama-micro";

    println!("== E5 / Tables 3–7: downstream suite, {preset}, {steps} steps ==\n");
    let base = TrainConfig {
        preset: preset.into(),
        out_dir: std::env::temp_dir().join("galore2_bench"),
        steps,
        eval_every: 0,
        log_every: steps,
        corpus_tokens: 400_000,
        val_tokens: 40_000,
        seed: 7,
        ..TrainConfig::default()
    };
    let galore = coordinator::train(TrainConfig {
        run_name: "bench-ds-galore".into(),
        optimizer: "galore".into(),
        lr: 0.02,
        galore_rank: 32,
        galore_update_freq: (steps / 4).max(25),
        ..base.clone()
    })?;
    let baseline = coordinator::train(TrainConfig {
        run_name: "bench-ds-adam8bit".into(),
        optimizer: "adam8bit".into(),
        lr: 0.01,
        ..base
    })?;

    println!("\n-- scoring GaLore checkpoint --");
    let g = coordinator::eval_params(&galore.cfg, galore.params(), questions)?;
    println!("\n-- scoring Adam8bit checkpoint --");
    let b = coordinator::eval_params(&baseline.cfg, baseline.params(), questions)?;

    println!("\n{:<24} {:>8} {:>9} {:>7}   paper (Tables 3–7)", "category", "galore", "baseline", "chance");
    let paper = [
        ("language_understanding", 0.37, 0.37),
        ("commonsense", 0.40, 0.41),
        ("paraphrase", 0.67, 0.64),
        ("truthfulness", 0.30, 0.30),
        ("academic_exams", 0.24, 0.24),
    ];
    let mut g_avg = 0.0;
    let mut b_avg = 0.0;
    for ((gr, br), (pname, pg, pb)) in g.iter().zip(&b).zip(paper) {
        assert_eq!(gr.category.name(), pname);
        println!(
            "{:<24} {:>8.3} {:>9.3} {:>7.3}   {:.2} vs {:.2}",
            gr.category.name(),
            gr.accuracy,
            br.accuracy,
            gr.chance,
            pg,
            pb
        );
        g_avg += gr.accuracy;
        b_avg += br.accuracy;
    }
    g_avg /= g.len() as f64;
    b_avg /= b.len() as f64;
    println!("{:<24} {:>8.3} {:>9.3}", "AVERAGE", g_avg, b_avg);
    println!(
        "\nparity check: |galore − baseline| average gap = {:.3} → {}",
        (g_avg - b_avg).abs(),
        if (g_avg - b_avg).abs() < 0.08 {
            "✓ near-parity (the paper's headline downstream finding)"
        } else {
            "✗ gap larger than expected on this budget"
        }
    );
    Ok(())
}
