//! E2/E8/E9 — Table 1, the §1 single-GPU claims, and the §3 memory
//! equations.
//!
//! Three sections:
//!   1. Table 1 rows from the analytic memory model (Llama3-8B, FSDP x2);
//!   2. §1 claims (7B Adam ≥58 GB; GaLore+8bit fits 24 GB);
//!   3. live FSDP cluster byte counters (llama-nano/micro) cross-checked
//!      against the model's optimizer-state terms, plus DDP-vs-FSDP.

use galore2::config::{ParallelMode, TrainConfig};
use galore2::memory::{
    estimate, optimizer_state_bytes, MemoryCfg, OptimKind, Parallelism, Precision,
};
use galore2::model::LlamaCfg;
use galore2::train::Trainer;
use galore2::util::human_bytes;

fn main() -> anyhow::Result<()> {
    // ---- 1. Table 1 ----------------------------------------------------
    println!("== E2 / Table 1: per-GPU memory, Llama3-8B, FSDP x2, bs=1 ==\n");
    let cfg8b = LlamaCfg::preset("llama3-8b").unwrap();
    let rank = cfg8b.default_rank();
    println!(
        "{:<10} {:>5} {:<16} {:>12} {:>10}",
        "model", "seq", "method", "model GiB", "paper GB"
    );
    for (seq, optim, per_layer, paper) in [
        (4096usize, OptimKind::GaLore { rank }, true, "77.45"),
        (4096, OptimKind::AdamW, false, "/ (OOM)"),
        (2048, OptimKind::GaLore { rank }, true, "72.84"),
        (2048, OptimKind::AdamW, false, "77.64"),
    ] {
        let est = estimate(
            &cfg8b,
            &MemoryCfg {
                optim,
                parallelism: Parallelism::Fsdp { world: 2 },
                precision: Precision::mixed_bf16(),
                seq,
                batch: 1,
                per_layer_update: per_layer,
                activation_factor: 0.3,
            },
        );
        let name = if matches!(optim, OptimKind::AdamW) {
            "AdamW + FSDP"
        } else {
            "GaLore + FSDP"
        };
        println!(
            "{:<10} {:>5} {:<16} {:>12.2} {:>10}",
            "Llama3 8B",
            seq,
            name,
            est.total_gib(),
            paper
        );
    }

    // ---- 2. §1 claims ----------------------------------------------------
    println!("\n== E8 / §1 claims: Llama 7B, single GPU, bs=1 ==\n");
    let cfg7b = LlamaCfg::preset("llama-7b").unwrap();
    let adam = estimate(
        &cfg7b,
        &MemoryCfg {
            optim: OptimKind::AdamW,
            parallelism: Parallelism::Single,
            precision: Precision::full_fp32(),
            seq: 1024,
            batch: 1,
            per_layer_update: false,
            activation_factor: 0.15,
        },
    );
    let galore8 = estimate(
        &cfg7b,
        &MemoryCfg {
            optim: OptimKind::GaLore8bit { rank: 1024 },
            parallelism: Parallelism::Single,
            precision: Precision {
                param_bytes: 2,
                grad_bytes: 2,
                master_fp32: false,
            },
            seq: 256,
            batch: 1,
            per_layer_update: true,
            activation_factor: 0.15,
        },
    );
    println!("fp32 Adam:      {:>7.1} GiB   paper: \"at least 58 GB\"  {}", adam.total_gib(),
        if adam.total_gib() > 58.0 { "✓" } else { "✗" });
    println!("GaLore + 8bit:  {:>7.1} GiB   paper: fits 24 GB (RTX 4090) {}", galore8.total_gib(),
        if galore8.total_gib() < 24.0 { "✓" } else { "✗" });

    // ---- 3. §3 equations + live counters ---------------------------------
    println!("\n== E9 / §3 equations: optimizer state for one 4096x11008 layer ==\n");
    let (m, n, r) = (4096usize, 11008usize, 1024usize);
    println!(
        "AdamW  2mn·4      = {}",
        human_bytes(optimizer_state_bytes(OptimKind::AdamW, m, n))
    );
    println!(
        "GaLore (mr+2nr)·4 = {}",
        human_bytes(optimizer_state_bytes(OptimKind::GaLore { rank: r }, m, n))
    );
    println!(
        // Q-GaLore charges the STORED projector: mr int8 codes + one f32
        // absmax scale per 256-element block (matches Projector::nbytes).
        "QGaLore mr·1+2nr·4= {}",
        human_bytes(optimizer_state_bytes(OptimKind::QGaLore { rank: r }, m, n))
    );
    println!(
        "LoRA   3(m+n)r·4  = {}",
        human_bytes(optimizer_state_bytes(OptimKind::Lora { rank: r }, m, n))
    );

    println!("\n== live FSDP/DDP counters (llama-micro, world 4, 10 steps) ==\n");
    for (mode, optimizer) in [
        (ParallelMode::Fsdp, "adamw"),
        (ParallelMode::Fsdp, "adam8bit"),
        (ParallelMode::Fsdp, "galore"),
        // Quantized projector: the optim column reports the stored
        // (codes + scales) size via state_bytes/Projector::nbytes.
        (ParallelMode::Fsdp, "qgalore"),
        (ParallelMode::Ddp, "galore"),
    ] {
        let cfg = TrainConfig {
            preset: "llama-micro".into(),
            run_name: format!("bench-t1-{mode:?}-{optimizer}").to_lowercase(),
            out_dir: std::env::temp_dir().join("galore2_bench"),
            optimizer: optimizer.into(),
            parallel: mode,
            world: 4,
            steps: 10,
            lr: 0.01,
            galore_rank: 32,
            galore_update_freq: 5,
            eval_every: 0,
            corpus_tokens: 30_000,
            val_tokens: 5_000,
            ..TrainConfig::default()
        };
        let mut trainer = Trainer::new(cfg)?;
        for t in 0..10 {
            trainer.train_step(t)?;
        }
        let rep = &trainer.memory_reports().unwrap()[0];
        println!(
            "{:<4} {:<9} rank0: params {:>10}  optim {:>10}  transient ≤ {:>10}",
            trainer.engine().name(),
            optimizer,
            human_bytes(rep.param_shard_bytes as u64),
            human_bytes(rep.optimizer_bytes as u64),
            human_bytes(rep.peak_transient_bytes as u64),
        );
    }
    println!(
        "\nordering check (live): galore optim < adam8bit optim < adamw optim;\n\
         the DDP galore row pays full-replica params + replicated moments"
    );
    Ok(())
}
