//! E4 — Figure 3: GaLore vs 8-bit Adam validation loss over the token
//! budget (the 500B-token run, scaled to this testbed).
//!
//! Both optimizers train the same model on the same data with the paper's
//! schedule (10% warmup + cosine→10%, uniform GaLore hyperparameters,
//! T scaled to keep #subspace-updates/run in the paper's regime). The
//! reproduced claim is the SHAPE: curves track each other closely and end
//! at comparable validation loss/perplexity.

use galore2::config::TrainConfig;
use galore2::metrics::ascii_chart;
use galore2::train::Trainer;

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let preset = "llama-micro";
    let steps: u64 = if quick { 150 } else { 500 };

    println!("== E4 / Figure 3: GaLore vs Adam8bit, {preset}, {steps} steps ==\n");
    let base = TrainConfig {
        preset: preset.into(),
        out_dir: std::env::temp_dir().join("galore2_bench"),
        steps,
        eval_every: (steps / 25).max(1),
        eval_batches: 8,
        log_every: steps,
        corpus_tokens: 500_000,
        val_tokens: 50_000,
        seed: 7,
        ..TrainConfig::default()
    };

    let mut curves = Vec::new();
    for (name, optimizer, lr) in [("galore", "galore", 0.02f32), ("adam8bit", "adam8bit", 0.01)] {
        let cfg = TrainConfig {
            run_name: format!("bench-fig3-{name}"),
            optimizer: optimizer.into(),
            lr,
            galore_rank: 32,
            galore_update_freq: (steps / 5).max(25),
            galore_alpha: 0.25,
            ..base.clone()
        };
        let mut trainer = Trainer::new(cfg)?;
        let outcome = trainer.run()?;
        let pts: Vec<(u64, f64)> = trainer
            .metrics
            .of_tag("val")
            .map(|p| (p.tokens, p.loss))
            .collect();
        println!(
            "{name:<9} final val loss {:.4} (ppl {:.2}) in {:.0}s over {} tokens",
            outcome.final_val_loss,
            outcome.final_val_loss.exp(),
            outcome.wall_secs,
            outcome.tokens
        );
        curves.push((name, pts, outcome.final_val_loss));
    }

    println!("\nvalidation loss vs tokens:");
    let series: Vec<(&str, Vec<(u64, f64)>)> = curves
        .iter()
        .map(|(n, p, _)| (*n, p.clone()))
        .collect();
    println!("{}", ascii_chart(&series, 72, 16));

    let gap = curves[0].2 - curves[1].2;
    println!(
        "final gap (galore − adam8bit): {gap:+.4} nats  → {}",
        if gap.abs() < 0.1 {
            "✓ comparable final loss (the paper's §5 conclusion)"
        } else if gap < 0.0 {
            "GaLore ahead on this budget"
        } else {
            "baseline ahead on this budget (paper sees this in the first \
             150B-token phase too)"
        }
    );
    Ok(())
}
