//! E1 — Figure 1: comparison of projection methods across Llama models.
//!
//! Trains GaLore end-to-end (through the fwd_bwd artifact) once per
//! (preset × projection kind) with identical data/seed/schedule and prints
//! the validation-loss table. Expected shape (the paper's finding):
//! rand_svd ≈ svd; q8 close; q4 noticeably worse; random clearly worse.

use galore2::config::TrainConfig;
use galore2::train::Trainer;

const KINDS: [&str; 5] = ["svd", "rand_svd", "q8", "q4", "random"];

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let presets: &[&str] = if quick {
        &["llama-nano"]
    } else {
        &["llama-nano", "llama-micro"]
    };
    // Budget/refresh scaling: the paper runs T = 500 of ~476K steps —
    // refreshes are RARE relative to the run, so subspace quality matters.
    // A short budget with one mid-run refresh reproduces that regime;
    // long budgets with frequent refreshes let even a random subspace
    // catch up (we verified this — see EXPERIMENTS.md E1 note).
    let steps: u64 = if quick { 80 } else { 140 };

    println!("== E1 / Figure 1: projection types x model sizes ({steps} steps) ==\n");
    println!("{:<12} {:>9} {:>9} {:>9} {:>9} {:>9}", "preset", "svd", "rand_svd", "q8", "q4", "random");
    for preset in presets {
        let hidden = galore2::model::LlamaCfg::preset(preset).unwrap().hidden;
        let mut losses = Vec::new();
        for kind in KINDS {
            let cfg = TrainConfig {
                preset: preset.to_string(),
                run_name: format!("bench-fig1-{preset}-{kind}"),
                out_dir: std::env::temp_dir().join("galore2_bench"),
                optimizer: "galore".into(),
                lr: 0.02,
                steps,
                galore_rank: hidden / 8,
                galore_update_freq: steps / 2, // one refresh mid-run
                galore_alpha: 0.25,
                galore_projection: kind.into(),
                eval_every: 0,
                eval_batches: 8,
                log_every: steps,
                corpus_tokens: 300_000,
                val_tokens: 30_000,
                seed: 7,
                ..TrainConfig::default()
            };
            let mut trainer = Trainer::new(cfg)?;
            let outcome = trainer.run()?;
            losses.push(outcome.final_val_loss);
        }
        println!(
            "{:<12} {:>9.4} {:>9.4} {:>9.4} {:>9.4} {:>9.4}",
            preset, losses[0], losses[1], losses[2], losses[3], losses[4]
        );
        let ok_rand_svd = (losses[1] - losses[0]).abs() < 0.1;
        let ok_random = losses[4] > losses[0] + 0.05;
        println!(
            "             rand_svd≈svd: {}   random degrades: {}",
            if ok_rand_svd { "✓" } else { "✗" },
            if ok_random { "✓" } else { "✗" }
        );
    }
    println!("\npaper (Fig. 1): randomized SVD fully matches the GaLore baseline;");
    println!("random and extremely-quantized projections degrade significantly.");
    Ok(())
}
