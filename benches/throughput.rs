//! E10 — end-to-end and component throughput (§Perf).
//!
//! Sections:
//!   1. optimizer step time per engine: AdamW / Adam8bit / GaLore-native /
//!      GaLore-pjrt on a llama-micro-shaped layer set;
//!   2. GEMM plan sweep for the native projection kernels (feeds the
//!      MatmulPlan defaults);
//!   3. parallel GEMM scaling on the paper's 1024-rank projection +
//!      reprojection shapes (1, 2, 4 threads vs serial) — summarized into
//!      BENCH_throughput.json for EXPERIMENTS.md §Perf;
//!   3b. persistent pool vs scoped spawning: region dispatch cost and the
//!      llama-micro projection pair that sat below the OLD 4e6 serial
//!      cutover — the evidence behind the re-tuned `PAR_MIN_FLOPS`
//!      (`pool_vs_scoped` in BENCH_throughput.json, grepped by CI);
//!   4. collectives throughput (all-reduce / reduce-scatter / all-gather);
//!   4b. cluster step over threads vs process transport (FSDP world 2) —
//!      the gap is the per-step socket overhead (EXPERIMENTS.md §Transport);
//!   4c. overlapped vs serial collectives: the same FSDP step at worlds
//!      2/4 over both transports with the per-layer reduce pipeline on
//!      vs off (`overlap_vs_serial` in BENCH_throughput.json, grepped by
//!      CI) — the gap is hidden communication time (§Perf);
//!   4d. shm vs sockets: the process-transport step at worlds 2/4 with
//!      the shared-memory data plane on vs off, galore + adamw, overlap
//!      on and off (`shm_vs_sockets` in BENCH_throughput.json, grepped
//!      by CI) — the gap is payload copy + framing cost
//!      (EXPERIMENTS.md §Transport);
//!   5. full train-step wall time per optimizer (artifact execution +
//!      optimizer, one untimed warmup step so one-time pool/thread startup
//!      stays out of the per-step figures) — the headline table in
//!      EXPERIMENTS.md §Perf.

use galore2::bench::Bench;
use galore2::config::TrainConfig;
use galore2::dist::{Comm, FsdpCluster, TransportKind};
use galore2::optim::{
    Adam8bit, AdamCfg, AdamW, GaLore, GaLoreCfg, Optimizer, ProjectionKind,
};
use galore2::parallel;
use galore2::tensor::{matmul_at_b_with_plan, matmul_with_plan, Matrix, MatmulPlan};
use galore2::testing::fixtures;
use galore2::train::Trainer;
use galore2::util::json::Json;
use galore2::util::rng::Pcg64;

fn layer_set() -> Vec<(Matrix, Matrix)> {
    // llama-micro's distinct 2-d shapes (param, grad).
    let mut rng = Pcg64::new(1, 0);
    [(128usize, 128usize), (128, 352), (352, 128), (512, 128)]
        .iter()
        .map(|&(m, n)| {
            (
                Matrix::randn(m, n, 0.02, &mut rng),
                Matrix::randn(m, n, 0.01, &mut rng),
            )
        })
        .collect()
}

fn bench_optimizer(b: &mut Bench, name: &str, opt: &mut dyn Optimizer) {
    let mut layers = layer_set();
    let grads: Vec<Matrix> = layers.iter().map(|(_, g)| g.clone()).collect();
    let mut t = 0u64;
    b.run(&format!("optstep_{name}"), || {
        opt.begin_step(t);
        for (idx, ((p, _), g)) in layers.iter_mut().zip(&grads).enumerate() {
            opt.step_param(idx, p, g, 1e-3);
        }
        t += 1;
    });
}

fn mean_of(b: &Bench, name: &str) -> Option<f64> {
    b.results().iter().find(|r| r.name == name).map(|r| r.mean_ns)
}

/// Write every recorded result (all sections run so far) plus the headline
/// projection+reprojection speedup to BENCH_throughput.json.
fn write_report(b: &Bench, speedup_4t: Option<f64>, hidden: usize, rank: usize) -> anyhow::Result<()> {
    let mut report = Json::obj();
    report.set(
        "results",
        Json::arr(b.results().iter().map(|r| r.to_json()).collect()),
    );
    if let Some(speedup) = speedup_4t {
        report
            .set("projpair_speedup_4t", Json::num(speedup))
            .set(
                "projpair_shapes",
                Json::str(format!("{hidden}x{rank} / {hidden}x{hidden}")),
            );
    }
    // §3b summary: pool-vs-scoped dispatch cost and the sub-old-cutover
    // micro projection pair. CI greps BENCH_throughput.json for this key.
    let mut pool = Json::obj();
    for (key, bench) in [
        ("dispatch_pool_ns", "pool_dispatch_noop_t4"),
        ("dispatch_scoped_ns", "scoped_dispatch_noop_t4"),
        ("micro_t1_ns", "gemm_projpair_micro128r32_t1"),
        ("micro_pool_t4_ns", "gemm_projpair_micro128r32_pool_t4"),
        ("micro_scoped_t4_ns", "gemm_projpair_micro128r32_scoped_t4"),
    ] {
        if let Some(mean) = mean_of(b, bench) {
            pool.set(key, Json::num(mean));
        }
    }
    if let (Some(t1), Some(t4)) = (
        mean_of(b, "gemm_projpair_micro128r32_t1"),
        mean_of(b, "gemm_projpair_micro128r32_pool_t4"),
    ) {
        pool.set("micro_pool_speedup_4t", Json::num(t1 / t4));
    }
    report.set("pool_vs_scoped", pool);
    // §4c summary: per-step wall time with the comm pipeline on vs off.
    // Trajectories are bitwise identical either way, so speedup > 1 is
    // pure hidden communication. CI greps for this key.
    let mut overlap = Json::obj();
    for world in [2usize, 4] {
        for transport in ["threads", "process"] {
            let serial = mean_of(b, &format!("clusterstep_fsdp{world}_{transport}_serial"));
            let over = mean_of(b, &format!("clusterstep_fsdp{world}_{transport}_overlap"));
            if let (Some(s), Some(o)) = (serial, over) {
                let mut row = Json::obj();
                row.set("serial_ns", Json::num(s))
                    .set("overlap_ns", Json::num(o))
                    .set("speedup", Json::num(s / o));
                overlap.set(&format!("fsdp{world}_{transport}"), row);
            }
        }
    }
    report.set("overlap_vs_serial", overlap);
    // §4d summary: per-step wall time over the process transport with the
    // shm slot-table data plane vs socket frames. Trajectories are bitwise
    // identical either way (tests/transport.rs), so speedup > 1 is pure
    // payload copy + framing cost. CI greps for this key.
    let mut shm = Json::obj();
    for world in [2usize, 4] {
        for opt in ["galore", "adamw"] {
            for sched in ["serial", "overlap"] {
                let sockets =
                    mean_of(b, &format!("shmstep_fsdp{world}_{opt}_{sched}_sockets"));
                let shm_ns = mean_of(b, &format!("shmstep_fsdp{world}_{opt}_{sched}_shm"));
                if let (Some(s), Some(m)) = (sockets, shm_ns) {
                    let mut row = Json::obj();
                    row.set("sockets_ns", Json::num(s))
                        .set("shm_ns", Json::num(m))
                        .set("speedup", Json::num(s / m));
                    shm.set(&format!("fsdp{world}_{opt}_{sched}"), row);
                }
            }
        }
    }
    report.set("shm_vs_sockets", shm);
    std::fs::write("BENCH_throughput.json", report.to_pretty())?;
    println!("machine-readable report -> BENCH_throughput.json");
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let mut b = Bench::new();
    // `cargo bench --bench throughput -- --quick` (CI smoke) or BENCH_QUICK=1.
    // `quick_from_env` treats `BENCH_QUICK=0`/empty as off — the old
    // `env::var(..).is_ok()` gate silently shortened benches on those.
    let quick =
        galore2::bench::quick_from_env() || std::env::args().any(|a| a == "--quick");

    println!("== 1. optimizer step time (4 micro-shaped layers) ==");
    bench_optimizer(&mut b, "adamw", &mut AdamW::new(AdamCfg::default()));
    bench_optimizer(&mut b, "adam8bit", &mut Adam8bit::new(AdamCfg::default()));
    let gcfg = GaLoreCfg {
        rank: 32,
        update_freq: 100,
        alpha: 0.25,
        projection: ProjectionKind::RandSvd,
        ..GaLoreCfg::default()
    };
    bench_optimizer(
        &mut b,
        "galore_native",
        &mut GaLore::new(gcfg, AdamCfg::default(), 3),
    );
    // pjrt engine (needs micro kernel artifacts)
    let artifacts = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if artifacts.join("manifest_llama-micro.json").exists() {
        let manifest =
            galore2::runtime::Manifest::load(artifacts.join("manifest_llama-micro.json"))?;
        if !manifest.kernels.is_empty() {
            let rt = std::sync::Arc::new(galore2::runtime::Runtime::cpu()?);
            let mut pjrt = galore2::train::PjrtGaLore::new(
                gcfg,
                AdamCfg::default(),
                rt,
                artifacts.clone(),
                manifest,
                3,
            );
            bench_optimizer(&mut b, "galore_pjrt", &mut pjrt);
        }
    }

    println!("\n== 2. GEMM plan sweep (projection shape 128x352 · 352x32) ==");
    let mut rng = Pcg64::new(2, 0);
    let a = Matrix::randn(128, 352, 1.0, &mut rng);
    let c = Matrix::randn(352, 128, 1.0, &mut rng);
    let flops = 2.0 * 128.0 * 352.0 * 128.0;
    for (mc, kc, nc) in [(32, 64, 64), (64, 256, 256), (64, 128, 512), (128, 512, 512)] {
        b.run_with_throughput(
            &format!("gemm_mc{mc}_kc{kc}_nc{nc}"),
            Some((flops, "flop")),
            || {
                matmul_with_plan(
                    &a,
                    &c,
                    MatmulPlan {
                        mc,
                        kc,
                        nc,
                        threads: 1, // block-size sweep measures the serial kernel
                    },
                )
            },
        );
    }

    println!("\n== 3. parallel GEMM scaling (1024-class projection shapes) ==");
    // The paper's quarter-rank setting at hidden 1024: P is 1024x256.
    //   projection    R = Pᵀ·G   (1024x256)ᵀ · (1024x1024) -> 256x1024
    //   reprojection  G̃ = P·N    (1024x256)  · (256x1024)  -> 1024x1024
    let (hidden, rank) = (1024usize, 256usize);
    let mut rng2 = Pcg64::new(3, 0);
    let p = Matrix::randn(hidden, rank, 1.0, &mut rng2);
    let g = Matrix::randn(hidden, hidden, 1.0, &mut rng2);
    let nlow = Matrix::randn(rank, hidden, 1.0, &mut rng2);
    let pair_flops = 2.0 * (hidden * rank * hidden) as f64 * 2.0; // proj + reproj
    let thread_counts = [1usize, 2, 4];
    for &threads in &thread_counts {
        b.run_with_throughput(
            &format!("gemm_projpair_{hidden}r{rank}_t{threads}"),
            Some((pair_flops, "flop")),
            || {
                let plan = MatmulPlan::with_threads(threads);
                let r = matmul_at_b_with_plan(&p, &g, plan); // projection
                let back = matmul_with_plan(&p, &nlow, plan); // reprojection
                (r, back)
            },
        );
    }

    // Headline figure for the acceptance criterion, computed once and
    // printed immediately (write_report reuses it in both exit paths).
    let speedup_4t = match (
        mean_of(&b, &format!("gemm_projpair_{hidden}r{rank}_t1")),
        mean_of(&b, &format!("gemm_projpair_{hidden}r{rank}_t4")),
    ) {
        (Some(t1), Some(t4)) => Some(t1 / t4),
        _ => None,
    };
    if let Some(speedup) = speedup_4t {
        println!(
            "\nprojection+reprojection speedup @4 threads: {speedup:.2}x \
             (acceptance target >= 2x)"
        );
    }

    println!("\n== 3b. persistent pool vs scoped spawning ==");
    // (a) Pure region dispatch cost: 4 one-byte chunks, trivial body. The
    // pool row measures queue-push + condvar wake + join; the scoped row
    // measures 4 OS-thread spawns + joins. Their gap is the overhead the
    // `PAR_MIN_FLOPS` cutover has to amortize.
    let mut noop = vec![0u8; 4];
    b.run("pool_dispatch_noop_t4", || {
        parallel::par_chunks_mut(&mut noop, 1, 4, |_, c| c[0] = c[0].wrapping_add(1));
        noop[0]
    });
    parallel::set_pool_enabled(false);
    b.run("scoped_dispatch_noop_t4", || {
        parallel::par_chunks_mut(&mut noop, 1, 4, |_, c| c[0] = c[0].wrapping_add(1));
        noop[0]
    });
    parallel::set_pool_enabled(true);
    // (b) The llama-micro projection pair (128x352 layer, rank 32):
    // ~2.9 MFLOP per GEMM — below the OLD 4e6 cutover, so the scoped era
    // ran it serial. Under the pool it parallelizes and must win; the
    // scoped row shows why the old threshold was right for scoped spawn.
    let (mh, mw, mr) = (128usize, 352usize, 32usize);
    let mut rng3 = Pcg64::new(4, 0);
    let mp = Matrix::randn(mh, mr, 1.0, &mut rng3);
    let mg = Matrix::randn(mh, mw, 1.0, &mut rng3);
    let mn = Matrix::randn(mr, mw, 1.0, &mut rng3);
    let micro_flops = 2.0 * (mh * mr * mw) as f64 * 2.0; // proj + reproj
    for (name, threads, pooled) in [
        ("gemm_projpair_micro128r32_t1", 1usize, true),
        ("gemm_projpair_micro128r32_pool_t4", 4, true),
        ("gemm_projpair_micro128r32_scoped_t4", 4, false),
    ] {
        parallel::set_pool_enabled(pooled);
        b.run_with_throughput(name, Some((micro_flops, "flop")), || {
            let plan = MatmulPlan::with_threads(threads);
            let r = matmul_at_b_with_plan(&mp, &mg, plan); // projection
            let back = matmul_with_plan(&mp, &mn, plan); // reprojection
            (r, back)
        });
    }
    parallel::set_pool_enabled(true);
    if let (Some(t1), Some(t4)) = (
        mean_of(&b, "gemm_projpair_micro128r32_t1"),
        mean_of(&b, "gemm_projpair_micro128r32_pool_t4"),
    ) {
        println!(
            "\nmicro projection pair (sub-old-cutover) pool speedup @4 threads: {:.2}x",
            t1 / t4
        );
    }

    println!("\n== 4. collectives (world 4, 1 MiB payloads) ==");
    let elems = 256 * 1024usize;
    for op in ["all_reduce", "reduce_scatter", "all_gather"] {
        let bytes = (elems * 4) as f64;
        b.run_with_throughput(&format!("collective_{op}"), Some((bytes, "B")), || {
            let comms = Comm::create_world(4);
            std::thread::scope(|s| {
                let handles: Vec<_> = comms
                    .into_iter()
                    .map(|c| {
                        s.spawn(move || {
                            let data = vec![1.0f32; elems];
                            match op {
                                "all_reduce" => {
                                    c.all_reduce_sum(data).len()
                                }
                                "reduce_scatter" => {
                                    let off: Vec<usize> =
                                        (0..=4).map(|i| i * elems / 4).collect();
                                    c.reduce_scatter_sum(data, &off).len()
                                }
                                _ => c.all_gather(data).len(),
                            }
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).sum::<usize>()
            })
        });
    }

    println!("\n== 4b. cluster step: threads vs process transport (FSDP world 2) ==");
    // The process transport self-execs the galore2 binary; benches (like
    // integration tests) get its path from cargo (thread-safe override,
    // not set_var).
    galore2::dist::set_worker_binary(env!("CARGO_BIN_EXE_galore2"));
    let cluster_shapes: &[(usize, usize)] = &[(256, 384), (384, 256), (64, 64)];
    for transport in [TransportKind::Threads, TransportKind::Process] {
        let mut cluster = FsdpCluster::with_transport(
            2,
            fixtures::metas_for(cluster_shapes),
            galore2::dist::OptimizerSpec::AdamW(AdamCfg::default()),
            7,
            transport,
        )
        .expect("spawning bench cluster");
        cluster.init_params(&fixtures::randn_set(cluster_shapes, 0.1, 3, 0));
        let mut t = 0u64;
        // One priming step, then take the per-step data-plane volume from
        // the cluster's StepTraffic report — not a hand-maintained
        // elems*4 loop that would drift from the real protocol. Threads
        // move no data-plane bytes, so their row has no throughput.
        cluster.step(t, vec![fixtures::rank_grads(cluster_shapes, t, 0, 0.05); 2], 1e-3);
        t += 1;
        let moved = cluster.last_step_traffic().and_then(|tr| {
            let total = tr.socket_bytes + tr.shm_bytes;
            (total > 0).then_some((total as f64, "B"))
        });
        b.run_with_throughput(
            &format!("clusterstep_fsdp2_{}", transport.name()),
            moved,
            || {
                let grads = fixtures::rank_grads(cluster_shapes, t, 0, 0.05);
                cluster.step(t, vec![grads; 2], 1e-3);
                t += 1;
            },
        );
    }
    // The gap between the two rows IS the socket overhead per step
    // (serialize grads + relayed collectives) — paste per-host figures
    // into EXPERIMENTS.md §Transport.

    println!("\n== 4c. overlapped vs serial collectives (FSDP worlds 2/4) ==");
    // Same step, two schedules: serial runs every per-layer reduce inline
    // on the worker; overlapped issues layer k+1's reduce to the rank's
    // comm thread while layer k feeds the optimizer (dist/pipeline.rs).
    // Bitwise-identical trajectories (tests/determinism.rs), so the gap
    // between the rows is pure hidden communication time. The knob must
    // be set BEFORE the cluster spawns — workers capture it at
    // construction (process children via the GALORE2_OVERLAP env).
    for world in [2usize, 4] {
        for transport in [TransportKind::Threads, TransportKind::Process] {
            for (mode, overlap) in [("serial", false), ("overlap", true)] {
                galore2::dist::set_overlap_enabled(overlap);
                let mut cluster = FsdpCluster::with_transport(
                    world,
                    fixtures::metas_for(cluster_shapes),
                    galore2::dist::OptimizerSpec::AdamW(AdamCfg::default()),
                    7,
                    transport,
                )
                .expect("spawning overlap bench cluster");
                cluster.init_params(&fixtures::randn_set(cluster_shapes, 0.1, 3, 0));
                let mut t = 0u64;
                b.run(
                    &format!("clusterstep_fsdp{world}_{}_{mode}", transport.name()),
                    || {
                        let grads = fixtures::rank_grads(cluster_shapes, t, 0, 0.05);
                        cluster.step(t, vec![grads; world], 1e-3);
                        t += 1;
                    },
                );
            }
        }
    }
    galore2::dist::set_overlap_enabled(true);

    println!("\n== 4d. shm vs sockets (process transport, FSDP worlds 2/4) ==");
    // Same step, two data planes: sockets serialize every gradient
    // element through the relay (two copies per element per collective);
    // shm deposits payloads in the slot table and puts only fixed-size
    // control frames on the socket. The reduction order is identical —
    // tests/transport.rs pins shm-on bitwise against sockets, threads,
    // and single — so the gap between the rows is pure payload copy +
    // framing cost. Both knobs must be set BEFORE the cluster spawns;
    // process children capture them from GALORE2_OVERLAP / GALORE2_SHM
    // at exec.
    for world in [2usize, 4] {
        for (opt_name, spec) in [
            (
                "galore",
                galore2::dist::OptimizerSpec::GaLore {
                    galore: gcfg,
                    adam: AdamCfg::default(),
                },
            ),
            (
                "adamw",
                galore2::dist::OptimizerSpec::AdamW(AdamCfg::default()),
            ),
        ] {
            for (sched, overlap) in [("serial", false), ("overlap", true)] {
                for (plane, shm_on) in [("sockets", false), ("shm", true)] {
                    galore2::dist::set_overlap_enabled(overlap);
                    galore2::dist::set_shm_enabled(shm_on);
                    let mut cluster = FsdpCluster::with_transport(
                        world,
                        fixtures::metas_for(cluster_shapes),
                        spec.clone(),
                        7,
                        TransportKind::Process,
                    )
                    .expect("spawning shm bench cluster");
                    cluster.init_params(&fixtures::randn_set(cluster_shapes, 0.1, 3, 0));
                    let mut t = 0u64;
                    // Prime one step; the throughput denominator is the
                    // measured per-step StepTraffic volume (socket + shm).
                    cluster.step(
                        t,
                        vec![fixtures::rank_grads(cluster_shapes, t, 0, 0.05); world],
                        1e-3,
                    );
                    t += 1;
                    let moved = cluster.last_step_traffic().and_then(|tr| {
                        let total = tr.socket_bytes + tr.shm_bytes;
                        (total > 0).then_some((total as f64, "B"))
                    });
                    b.run_with_throughput(
                        &format!("shmstep_fsdp{world}_{opt_name}_{sched}_{plane}"),
                        moved,
                        || {
                            let grads = fixtures::rank_grads(cluster_shapes, t, 0, 0.05);
                            cluster.step(t, vec![grads; world], 1e-3);
                            t += 1;
                        },
                    );
                }
            }
        }
    }
    galore2::dist::set_shm_enabled(true);
    galore2::dist::set_overlap_enabled(true);

    println!("\n== 5. full train step (llama-nano, artifact + optimizer) ==");
    if !artifacts.join("manifest_llama-nano.json").exists() {
        println!("skipped: artifacts missing — run `make artifacts PRESET=llama-nano`");
        b.summarize_vs_baseline();
        write_report(&b, speedup_4t, hidden, rank)?;
        return Ok(());
    }
    let steps = if quick { 10 } else { 30 };
    for optimizer in ["adamw", "adam8bit", "galore"] {
        let cfg = TrainConfig {
            preset: "llama-nano".into(),
            run_name: format!("bench-tp-{optimizer}"),
            out_dir: std::env::temp_dir().join("galore2_bench"),
            optimizer: optimizer.into(),
            lr: 0.01,
            // +1 budgets the untimed warmup step below.
            steps: steps + 1,
            galore_rank: 16,
            galore_update_freq: 10,
            eval_every: 0,
            corpus_tokens: 50_000,
            val_tokens: 5_000,
            ..TrainConfig::default()
        };
        let mut trainer = Trainer::new(cfg)?;
        let flops = trainer.llama.step_flops();
        // One untimed warmup step: the first step pays one-time costs
        // (pool worker spawn, corpus/cache touch) that would otherwise be
        // folded into every per-step figure and skew pool-vs-scoped
        // comparisons. `steps` timed steps follow.
        trainer.train_step(0)?;
        let timer = galore2::util::Timer::start();
        for t in 1..=steps {
            trainer.train_step(t)?;
        }
        let per_step = timer.elapsed_secs() / steps as f64;
        println!(
            "trainstep_{optimizer:<9} {:>9.2} ms/step  {:>8.3} GFLOP/s  ({} tokens/step)",
            per_step * 1e3,
            flops / per_step / 1e9,
            trainer.llama.batch * trainer.llama.seq
        );
    }
    b.summarize_vs_baseline();
    write_report(&b, speedup_4t, hidden, rank)?;
    Ok(())
}
