//! E6 — §4.1.2: "fast randomized SVD can be 15X faster than the original
//! SVD operation with no loss in accuracy."
//!
//! Benchmarks full SVD vs randomized SVD across gradient-shaped matrices
//! (n = 4m, rank = m/4 — the paper's quarter-rank setting) and reports the
//! speedup factor and the relative reconstruction accuracy gap.

use galore2::bench::Bench;
use galore2::linalg::{randomized_svd, rank_r_error, svd, RandSvdOpts};
use galore2::tensor::Matrix;
use galore2::util::rng::Pcg64;

fn main() {
    let mut b = Bench::new();
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let sizes: &[usize] = if quick { &[64, 128] } else { &[64, 128, 256, 512] };

    println!("== E6: full vs randomized SVD (n = 4m, rank = m/4) ==\n");
    let mut table = Vec::new();
    for &m in sizes {
        let n = 4 * m;
        let rank = (m / 4).max(1);
        let mut rng = Pcg64::new(1, m as u64);
        let g = Matrix::randn(m, n, 1.0, &mut rng);

        let full = b
            .run(&format!("svd_full_{m}x{n}"), || svd(&g))
            .map(|r| r.mean_secs());
        let mut rng2 = Pcg64::new(2, m as u64);
        let rand = b
            .run(&format!("svd_rand_{m}x{n}_r{rank}"), || {
                randomized_svd(&g, rank, RandSvdOpts::default(), &mut rng2)
            })
            .map(|r| r.mean_secs());

        // Accuracy: both truncated to `rank`, error vs optimal rank-r error.
        let best = rank_r_error(&g, rank) as f64;
        let full_err = {
            let s = svd(&g).truncate(rank);
            g.sub(&s.reconstruct()).frobenius_norm() as f64
        };
        let rand_err = {
            let mut rng3 = Pcg64::new(3, m as u64);
            let s = randomized_svd(&g, rank, RandSvdOpts::default(), &mut rng3);
            g.sub(&s.reconstruct()).frobenius_norm() as f64
        };
        if let (Some(f), Some(r)) = (full, rand) {
            table.push((m, n, rank, f, r, full_err / best, rand_err / best));
        }
    }

    println!("\n{:>5} {:>6} {:>5} {:>12} {:>12} {:>9} {:>14} {:>14}",
        "m", "n", "rank", "full (s)", "rand (s)", "speedup", "full err/opt", "rand err/opt");
    for (m, n, r, tf, tr, ef, er) in &table {
        println!(
            "{m:>5} {n:>6} {r:>5} {tf:>12.4} {tr:>12.4} {:>8.1}x {ef:>14.4} {er:>14.4}",
            tf / tr
        );
    }
    if let Some((_, _, _, tf, tr, _, er)) = table.last() {
        println!(
            "\npaper: ~15x at 7B scale, no accuracy loss. here (largest size): \
             {:.1}x speedup, rand err within {:.1}% of optimal.",
            tf / tr,
            (er - 1.0) * 100.0
        );
        println!(
            "(the speedup grows with m — full SVD is O(m^2 n), the sketch is \
             O(mnr) — so the 7B-scale gap is larger than this testbed's)"
        );
    }
}
