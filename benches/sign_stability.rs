//! E7 — §4.1.3: randomization & sign-indeterminacy of subspace updates.
//!
//! Measures projector consistency across consecutive refreshes on a slowly
//! rotating gradient stream:
//!   * WITHOUT the sign-determinacy fix, consecutive SVDs of nearly
//!     identical gradients can flip singular-vector signs → low overlap;
//!   * WITH the fix (scikit-learn-style svd_flip, applied by our linalg),
//!     overlap is high at small refresh intervals;
//!   * at the paper's moderate frequencies (T = 200–500), consecutive
//!     refresh gradients differ enough that the issue is negligible —
//!     subspace overlap is dominated by genuine rotation, not signs.

use galore2::linalg::{randomized_svd, RandSvdOpts, Svd};
use galore2::tensor::Matrix;
use galore2::util::rng::Pcg64;

/// Subspace overlap ‖P₁ᵀP₂‖_F²/r ∈ [0,1] (sign-invariant) and the mean
/// signed column agreement (sign-sensitive — drops on flips).
fn overlap(p1: &Matrix, p2: &Matrix) -> (f64, f64) {
    let c = p1.matmul_at_b(p2); // r×r
    let fro: f64 = c.data.iter().map(|&x| (x as f64) * (x as f64)).sum();
    let r = p1.cols as f64;
    let diag_signed: f64 =
        (0..p1.cols).map(|i| c.at(i, i) as f64).sum::<f64>() / r;
    (fro / r, diag_signed)
}

/// Gradient at "step" t: a fixed low-rank signal slowly rotating with t,
/// plus noise — a stand-in for the drift of real training gradients.
fn gradient(t: u64, rng: &mut Pcg64) -> Matrix {
    let (m, n, r) = (48usize, 96usize, 8usize);
    let mut base_rng = Pcg64::new(99, 0);
    let u = Matrix::randn(m, r, 1.0, &mut base_rng);
    let v = Matrix::randn(r, n, 1.0, &mut base_rng);
    // Rotate the signal by blending in a t-dependent perturbation.
    let angle = t as f32 * 1e-3;
    let mut u_t = u.clone();
    let mut pert_rng = Pcg64::new(7, 1); // fixed direction of rotation
    let pert = Matrix::randn(m, r, 1.0, &mut pert_rng);
    u_t.scale((1.0 - angle * angle).max(0.0).sqrt());
    u_t.add_scaled(&pert, angle);
    let mut g = u_t.matmul(&v);
    let noise = Matrix::randn(m, n, 0.05, rng);
    g.add_assign(&noise);
    g
}

fn svd_at(t: u64, fix_signs: bool, seed: u64) -> Matrix {
    let mut rng = Pcg64::new(seed, t);
    let g = gradient(t, &mut rng);
    let mut rng2 = Pcg64::new(seed ^ 0xabc, t);
    let s: Svd = randomized_svd(&g, 8, RandSvdOpts::default(), &mut rng2);
    let mut u = s.u;
    if !fix_signs {
        // Undo determinism: flip each column by a per-call coin — models an
        // SVD implementation with unresolved sign ambiguity.
        let mut coin = Pcg64::new(t.wrapping_mul(0x9e37), 3);
        for c in 0..u.cols {
            if coin.next_u64() & 1 == 1 {
                for r in 0..u.rows {
                    *u.at_mut(r, c) = -u.at(r, c);
                }
            }
        }
    }
    u
}

fn main() {
    println!("== E7 / §4.1.3: projector consistency vs refresh interval T ==\n");
    println!(
        "{:>6} {:>18} {:>18} {:>20}",
        "T", "subspace overlap", "signed (fixed)", "signed (ambiguous)"
    );
    for &t_interval in &[1u64, 10, 50, 200, 500] {
        let mut sub = 0.0;
        let mut signed_fix = 0.0;
        let mut signed_amb = 0.0;
        let reps = 8;
        for rep in 0..reps {
            let t0 = 1000 + rep * 137;
            let t1 = t0 + t_interval;
            let pf0 = svd_at(t0, true, 5);
            let pf1 = svd_at(t1, true, 5);
            let pa0 = svd_at(t0, false, 5);
            let pa1 = svd_at(t1, false, 5);
            let (s, d_fix) = overlap(&pf0, &pf1);
            let (_, d_amb) = overlap(&pa0, &pa1);
            sub += s;
            signed_fix += d_fix;
            signed_amb += d_amb;
        }
        println!(
            "{:>6} {:>18.4} {:>18.4} {:>20.4}",
            t_interval,
            sub / reps as f64,
            signed_fix / reps as f64,
            signed_amb / reps as f64
        );
    }
    println!(
        "\nreading: the sign-invariant subspace overlap (col 2) stays high at\n\
         small T and decays with genuine gradient rotation. The signed\n\
         agreement (col 3) tracks it when signs are fixed, but collapses\n\
         toward 0 under sign ambiguity (col 4) even at T=1 — the instability\n\
         §4.1.3 describes. At the paper's T = 200–500 the subspace itself\n\
         has rotated, so sign handling no longer matters: the columns\n\
         converge — 'for moderate frequencies this issue is negligible'."
    );
}
