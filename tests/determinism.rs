//! Integration: thread-count invariance of the parallel substrate.
//!
//! The contract (util/rng.rs): distributed + multi-threaded runs are
//! bit-reproducible regardless of thread scheduling. These tests pin it
//! end-to-end — parallel GEMM kernels, the randomized-SVD refresh, and a
//! full FSDP training run must produce identical bits at 1, 2 and 4
//! worker threads.

use galore2::dist::{
    set_overlap_enabled, set_worker_binary, DdpCluster, FsdpCluster, OptimizerSpec, TransportKind,
};
use galore2::linalg::{randomized_svd, RandSvdOpts};
use galore2::optim::{AdamCfg, GaLoreCfg};
use galore2::parallel;
use galore2::tensor::{
    matmul_a_bt_with_plan, matmul_at_b_with_plan, matmul_with_plan, Matrix, MatmulPlan,
};
use galore2::testing::fixtures;
use galore2::util::rng::Pcg64;
use std::sync::Mutex;

/// Serializes tests that mutate the process-wide thread default. (The
/// kernels are thread-count invariant, so a race would not change results —
/// holding the lock just keeps failure attribution clean.)
static THREADS_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    THREADS_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[test]
fn gemm_kernels_bitwise_identical_across_thread_counts() {
    let _g = lock();
    // Sizes above the parallel cutover (2·m·k·n ≥ PAR_MIN_FLOPS = 3e5),
    // so every multi-thread row below runs through the persistent pool.
    let mut rng = Pcg64::new(21, 0);
    let a = Matrix::randn(320, 256, 1.0, &mut rng);
    let b = Matrix::randn(256, 288, 1.0, &mut rng);
    let serial = matmul_with_plan(&a, &b, MatmulPlan::serial());
    let p = Matrix::randn(256, 192, 1.0, &mut rng); // projection layout (k×m)
    let g = Matrix::randn(256, 300, 1.0, &mut rng);
    let serial_atb = matmul_at_b_with_plan(&p, &g, MatmulPlan::serial());
    let x = Matrix::randn(260, 240, 1.0, &mut rng);
    let y = Matrix::randn(250, 240, 1.0, &mut rng);
    let serial_abt = matmul_a_bt_with_plan(&x, &y, MatmulPlan::serial());
    for threads in [1usize, 2, 4] {
        let plan = MatmulPlan::with_threads(threads);
        assert_eq!(
            matmul_with_plan(&a, &b, plan).data,
            serial.data,
            "matmul differs at {threads} threads"
        );
        assert_eq!(
            matmul_at_b_with_plan(&p, &g, plan).data,
            serial_atb.data,
            "matmul_at_b differs at {threads} threads"
        );
        assert_eq!(
            matmul_a_bt_with_plan(&x, &y, plan).data,
            serial_abt.data,
            "matmul_a_bt differs at {threads} threads"
        );
    }
}

#[test]
fn sub_cutover_projection_gemms_parallelize_bitwise_through_pool() {
    let _g = lock();
    // llama-micro's 128x352 layer at rank 32: ~2.9 MFLOP per GEMM — the
    // class the old scoped-spawn cutover (4e6) kept serial. With the
    // persistent pool the cutover is 3e5, so these now parallelize; the
    // bits must not notice, and the pool must actually engage.
    let mut rng = Pcg64::new(23, 0);
    let p = Matrix::randn(128, 32, 1.0, &mut rng);
    let g = Matrix::randn(128, 352, 1.0, &mut rng);
    let n = Matrix::randn(32, 352, 1.0, &mut rng);
    let proj1 = matmul_at_b_with_plan(&p, &g, MatmulPlan::serial()); // R = PᵀG
    let back1 = matmul_with_plan(&p, &n, MatmulPlan::serial()); // G̃ = P·N
    for threads in [2usize, 4] {
        let plan = MatmulPlan::with_threads(threads);
        assert_eq!(
            matmul_at_b_with_plan(&p, &g, plan).data,
            proj1.data,
            "micro projection differs at {threads} threads"
        );
        assert_eq!(
            matmul_with_plan(&p, &n, plan).data,
            back1.data,
            "micro reprojection differs at {threads} threads"
        );
    }
    assert!(
        parallel::pool_size() >= 1,
        "sub-old-cutover projection GEMMs must engage the persistent pool"
    );
}

#[test]
fn pool_workers_are_reused_across_sequential_engines() {
    let _g = lock();
    // Two identical FSDP runs back to back: the second must reuse the
    // parked workers the first spawned, not grow the pool — and reuse
    // must not perturb a single bit.
    parallel::shutdown_pool();
    assert_eq!(parallel::pool_size(), 0, "shutdown must leave no workers");
    let first = run_fsdp_galore(4);
    let after_first = parallel::pool_size();
    assert!(after_first >= 1, "pooled FSDP run must spawn workers");
    let second = run_fsdp_galore(4);
    let after_second = parallel::pool_size();
    // World 2 splitting a 4-thread budget needs at most 1 extra worker
    // per rank; demand-driven growth must never exceed that.
    assert!(
        after_second <= 2,
        "pool grew past the world-2 budget: {after_second} workers"
    );
    assert!(after_second >= after_first, "pool shrank without shutdown");
    for (idx, (x, y)) in first.iter().zip(&second).enumerate() {
        assert_eq!(x.data, y.data, "param {idx}: pool reuse perturbed bits");
    }
}

#[test]
fn thread_share_splits_pool_budget_under_fsdp_process_transport() {
    let _g = lock();
    // Process-transport children inherit the coordinator's 4-thread
    // budget via GALORE2_THREADS at spawn (resolved once into their
    // OnceLock) and split it by world (`set_thread_share(2)`), so each
    // child runs width-2 kernels through its own persistent pool. The
    // result must match a serial thread-transport run bit for bit.
    set_worker_binary(env!("CARGO_BIN_EXE_galore2"));
    let serial = run_fsdp_galore(1);
    let pooled_process = run_fsdp_galore_over(4, TransportKind::Process);
    for (idx, (x, y)) in serial.iter().zip(&pooled_process).enumerate() {
        assert_eq!(
            x.data, y.data,
            "param {idx}: pooled process run diverged from serial threads run"
        );
    }
}

#[test]
fn randomized_svd_bitwise_identical_across_thread_counts() {
    let _g = lock();
    let a = {
        let mut rng = Pcg64::new(22, 0);
        // Low-rank-plus-noise, large enough that the sketch products run
        // through the threaded kernels.
        let u = Matrix::randn(300, 24, 1.0, &mut rng);
        let v = Matrix::randn(24, 500, 1.0, &mut rng);
        u.matmul(&v)
    };
    let run = |threads: usize| {
        parallel::set_default_threads(threads);
        let out = randomized_svd(&a, 64, RandSvdOpts::default(), &mut Pcg64::new(7, 3));
        parallel::set_default_threads(0);
        out
    };
    let t1 = run(1);
    for threads in [2usize, 4] {
        let tn = run(threads);
        assert_eq!(t1.u.data, tn.u.data, "U differs at {threads} threads");
        assert_eq!(t1.s, tn.s, "S differs at {threads} threads");
        assert_eq!(t1.vt.data, tn.vt.data, "Vᵀ differs at {threads} threads");
    }
}

/// Sizes above the parallel GEMM cutover, so the pool actually engages.
fn cluster_shapes() -> Vec<(usize, usize)> {
    vec![(256, 384), (384, 256), (64, 64), (1, 128)]
}

/// Full FSDP GaLore run at a given worker-pool thread count (model/grad
/// builders shared with the other suites via `testing::fixtures`).
fn run_fsdp_galore(pool_threads: usize) -> Vec<Matrix> {
    run_fsdp_galore_over(pool_threads, TransportKind::Threads)
}

fn run_fsdp_galore_over(pool_threads: usize, transport: TransportKind) -> Vec<Matrix> {
    parallel::set_default_threads(pool_threads);
    let world = 2;
    let shapes = cluster_shapes();
    let spec = OptimizerSpec::GaLore {
        galore: GaLoreCfg {
            rank: 64,
            update_freq: 2,
            alpha: 1.0,
            ..GaLoreCfg::default()
        },
        adam: AdamCfg::default(),
    };
    let mut cluster =
        FsdpCluster::with_transport(world, fixtures::metas_for(&shapes), spec, 33, transport)
            .unwrap_or_else(|e| panic!("spawning fsdp cluster over {}: {e}", transport.name()));
    let init = fixtures::randn_set(&shapes, 0.1, 2, 0);
    cluster.init_params(&init);
    for t in 0..4 {
        let per_rank: Vec<Vec<Matrix>> = (0..world)
            .map(|r| fixtures::rank_grads(&shapes, t, r, 0.05))
            .collect();
        cluster.step(t, per_rank, 0.02);
    }
    let out = cluster.gather_params();
    parallel::set_default_threads(0);
    out
}

#[test]
fn fsdp_training_bitwise_identical_across_thread_counts() {
    let _g = lock();
    // Covers the whole §4.3 path at 1/2/4 pool threads: tree-reduced
    // gradients, leader randomized SVD, P broadcast, sharded low-rank Adam.
    let t1 = run_fsdp_galore(1);
    let t2 = run_fsdp_galore(2);
    let t4 = run_fsdp_galore(4);
    for (idx, ((a, b), c)) in t1.iter().zip(&t2).zip(&t4).enumerate() {
        assert_eq!(a.data, b.data, "param {idx}: 1 vs 2 pool threads differ");
        assert_eq!(a.data, c.data, "param {idx}: 1 vs 4 pool threads differ");
        assert!(a.data.iter().all(|x| x.is_finite()), "param {idx} non-finite");
    }
}

#[test]
fn fsdp_run_is_reproducible_across_repeats() {
    let _g = lock();
    // Same config, same seed, auto thread count: byte-identical params.
    let a = run_fsdp_galore(0);
    let b = run_fsdp_galore(0);
    for (idx, (x, y)) in a.iter().zip(&b).enumerate() {
        assert_eq!(x.data, y.data, "param {idx}: repeat run diverged");
    }
}

/// One tiny cluster run for the overlap matrix below: `mode` is "fsdp" or
/// "ddp"; 5 steps at update_freq 2 cross SVD refreshes at t = 0, 2, 4, so
/// the pipeline's refresh gating (all-reduce → broadcast FIFO order) is
/// exercised, not just the steady-state reduce-scatter path.
fn run_tiny_cluster(
    mode: &str,
    world: usize,
    spec: &OptimizerSpec,
    transport: TransportKind,
    overlap: bool,
) -> Vec<Matrix> {
    set_overlap_enabled(overlap);
    let shapes = vec![(12usize, 24usize), (24, 12), (16, 16), (1, 16)];
    let init = fixtures::randn_set(&shapes, 0.1, 5, 0);
    let steps = 5u64;
    let out = match mode {
        "fsdp" => {
            let mut cluster = FsdpCluster::with_transport(
                world,
                fixtures::metas_for(&shapes),
                spec.clone(),
                77,
                transport,
            )
            .unwrap_or_else(|e| panic!("spawning fsdp over {}: {e}", transport.name()));
            cluster.init_params(&init);
            for t in 0..steps {
                let per_rank: Vec<Vec<Matrix>> = (0..world)
                    .map(|r| fixtures::rank_grads(&shapes, t, r, 0.05))
                    .collect();
                cluster.step(t, per_rank, 0.02);
            }
            cluster.gather_params()
        }
        _ => {
            let mut cluster = DdpCluster::with_transport(
                world,
                fixtures::metas_for(&shapes),
                spec.clone(),
                77,
                transport,
            )
            .unwrap_or_else(|e| panic!("spawning ddp over {}: {e}", transport.name()));
            cluster.init_params(&init);
            for t in 0..steps {
                let per_rank: Vec<Vec<Matrix>> = (0..world)
                    .map(|r| fixtures::rank_grads(&shapes, t, r, 0.05))
                    .collect();
                cluster.step(t, per_rank, 0.02);
            }
            // gather_params additionally asserts replica equality.
            cluster.gather_params()
        }
    };
    set_overlap_enabled(true);
    out
}

#[test]
fn overlap_on_off_bitwise_identical_across_modes() {
    let _g = lock();
    // The comm pipeline (dist/pipeline.rs) must be bitwise INVISIBLE:
    // overlapping moves only WHEN a collective runs relative to compute,
    // never the fixed-tree reduction order within it. Pin overlap-on ==
    // overlap-off over the full matrix: FSDP at worlds 2/4 + DDP at
    // world 2, × galore (SVD-refresh-crossing) / qgalore / adamw, × both
    // transports (worker threads and worker processes — the process path
    // also covers the GALORE2_OVERLAP env relay to children).
    set_worker_binary(env!("CARGO_BIN_EXE_galore2"));
    let galore = GaLoreCfg {
        rank: 4,
        update_freq: 2,
        alpha: 1.0,
        ..GaLoreCfg::default()
    };
    let specs: Vec<(&str, OptimizerSpec)> = vec![
        (
            "galore",
            OptimizerSpec::GaLore {
                galore,
                adam: AdamCfg::default(),
            },
        ),
        (
            "qgalore",
            OptimizerSpec::QGaLore {
                galore,
                adam: AdamCfg::default(),
                similarity_threshold: 1.0,
            },
        ),
        ("adamw", OptimizerSpec::AdamW(AdamCfg::default())),
    ];
    for transport in [TransportKind::Threads, TransportKind::Process] {
        for (spec_name, spec) in &specs {
            for (mode, world) in [("fsdp", 2usize), ("fsdp", 4), ("ddp", 2)] {
                let on = run_tiny_cluster(mode, world, spec, transport, true);
                let off = run_tiny_cluster(mode, world, spec, transport, false);
                for (idx, (x, y)) in on.iter().zip(&off).enumerate() {
                    assert_eq!(
                        x.data,
                        y.data,
                        "param {idx}: overlap changed bits ({mode} world {world}, \
                         {spec_name}, {} transport)",
                        transport.name()
                    );
                }
            }
        }
    }
}

#[test]
fn fsdp_process_transport_bitwise_equals_threads() {
    let _g = lock();
    // The same run with ranks as Unix-socket worker PROCESSES instead of
    // threads — at SVD-refresh-heavy settings (update_freq 2), so the
    // leader's randomized SVD, the projector broadcast wire, and the
    // sharded low-rank Adam all cross the socket fabric. Bits must not
    // notice (the f32 wire ships exact little-endian bit patterns).
    set_worker_binary(env!("CARGO_BIN_EXE_galore2"));
    let threads = run_fsdp_galore(0);
    let process = run_fsdp_galore_over(0, TransportKind::Process);
    for (idx, (x, y)) in threads.iter().zip(&process).enumerate() {
        assert_eq!(x.data, y.data, "param {idx}: transports diverged");
    }
}
