//! Cross-world parity: the elastic-resume contract.
//!
//! A v3+ checkpoint stores optimizer state in the canonical, world-agnostic
//! form (`checkpoint::canonical`). These tests pin the contract end to
//! end at the engine level, with no compiled artifacts needed:
//!
//! * a checkpoint written under FSDP world=2 resumes under FSDP world=4,
//!   world=1, DDP, and single-process with a **bitwise identical**
//!   trajectory — for galore, qgalore, and adamw;
//! * DDP checkpoints resume under FSDP and single-process the same way;
//! * the canonical export bytes are identical no matter which mode/world
//!   produced them, and gather∘scatter is the identity on them — including
//!   non-power-of-two worlds (3, 5) and worlds that leave ranks with
//!   empty shards;
//! * legacy (v2) world-locked state and corrupt blobs fail loudly, never
//!   silently resetting moments; loading a v2 checkpoint at its original
//!   world and re-saving migrates it to the current (canonical) version.
//!
//! Identical per-rank microbatch gradients make trajectories bitwise
//! comparable across worlds 1/2/4 (the tree-reduced average of w equal
//! values is exact for power-of-two w — see dist/fsdp.rs tests).
//!
//! Since checkpoint v5, state blobs carry the exact STORED representation
//! (codes + block scales): Q-GaLore checkpoints resume bit-exactly from
//! ANY step — including mid refresh-cycle — and adam8bit joins the
//! elastic matrix wherever shard boundaries land on 256-element
//! quantization blocks, with an explicit `--resume-requantize`
//! (`ImportOpts::requantize`) opt-in for everything inexact (misaligned
//! adam8bit, adafactor's factored cross-statistics). Committed v3/v4
//! fixture files pin the legacy gates against rot.

use galore2::checkpoint::canonical::{CanonicalOptState, CanonicalTensor, OptPayload};
use galore2::checkpoint::{Checkpoint, LEGACY_VERSION, VERSION};
use galore2::dist::{set_worker_binary, FsdpCluster, TransportKind};
use galore2::optim::{AdamCfg, GaLoreCfg, OptimizerSpec, ProjectionKind};
use galore2::quant::Quantized8;
use galore2::tensor::Matrix;
use galore2::testing::fixtures;
use galore2::train::{DdpEngine, FsdpEngine, ImportOpts, SingleEngine, TrainEngine};

/// Wide, tall, square, and bias-like (unprojected) parameters.
const SHAPES: &[(usize, usize)] = &[(8, 16), (16, 8), (6, 6), (1, 12)];
/// Shapes whose world-1/2/4 shard boundaries all land on 256-element
/// quantization blocks: block-quantized (adam8bit) state gathers and
/// re-slices EXACTLY across this matrix.
const ALIGNED_SHAPES: &[(usize, usize)] = &[(512, 2), (2, 1024)];
const LR: f32 = 0.03;
const SEED: u64 = 21;

fn grads(shapes: &[(usize, usize)], t: u64) -> Vec<Matrix> {
    // Stream of rank 0 for EVERY rank: identical microbatches keep runs
    // comparable across world sizes.
    fixtures::rank_grads(shapes, t, 0, 0.1)
}

fn init(shapes: &[(usize, usize)]) -> Vec<Matrix> {
    fixtures::randn_set(shapes, 0.5, 7, 0)
}

/// Build an engine: ("single", _) | ("fsdp", w) | ("ddp", w).
fn build(
    mode: &str,
    world: usize,
    shapes: &[(usize, usize)],
    spec: &OptimizerSpec,
    seed: u64,
) -> Box<dyn TrainEngine> {
    let metas = fixtures::metas_for(shapes);
    match mode {
        "single" => Box::new(SingleEngine::new(spec, seed, None, init(shapes)).unwrap()),
        "fsdp" => {
            Box::new(FsdpEngine::new(world, metas, spec.clone(), seed, &init(shapes)).unwrap())
        }
        "ddp" => Box::new(DdpEngine::new(world, metas, spec.clone(), seed, &init(shapes)).unwrap()),
        other => panic!("unknown mode {other}"),
    }
}

fn drive(e: &mut dyn TrainEngine, shapes: &[(usize, usize)], t0: u64, t1: u64) {
    let w = e.world();
    for t in t0..t1 {
        e.step(t, vec![grads(shapes, t); w], LR);
    }
}

fn assert_params_eq(got: &[Matrix], want: &[Matrix], label: &str) {
    assert_eq!(got.len(), want.len(), "{label}: param count");
    for (idx, (a, b)) in got.iter().zip(want).enumerate() {
        assert_eq!(a.data, b.data, "{label}: param {idx} diverged");
    }
}

fn galore_spec() -> OptimizerSpec {
    OptimizerSpec::GaLore {
        galore: GaLoreCfg {
            rank: 4,
            update_freq: 3,
            alpha: 1.0,
            projection: ProjectionKind::RandSvd,
            ..GaLoreCfg::default()
        },
        adam: AdamCfg::default(),
    }
}

fn qgalore_spec() -> OptimizerSpec {
    OptimizerSpec::QGaLore {
        galore: GaLoreCfg {
            rank: 4,
            update_freq: 3,
            alpha: 1.0,
            projection: ProjectionKind::Quant8,
            ..GaLoreCfg::default()
        },
        adam: AdamCfg::default(),
        // Cosine similarity never exceeds 2.0: the lazy gate takes every
        // scheduled refresh, keeping single/DDP (gated) trajectories equal
        // to FSDP (coordinator-driven, gate inert).
        similarity_threshold: 2.0,
    }
}

fn galore_q8_spec() -> OptimizerSpec {
    // A *GaLore* spec with a quantized projector: reports the "qgalore"
    // display name but serializes the raw GaLore layout on every build
    // path — the codec conversion at the canonical boundary
    // (OptimizerSpec::state_codec) is what keeps it resumable anywhere.
    OptimizerSpec::GaLore {
        galore: GaLoreCfg {
            rank: 4,
            update_freq: 3,
            alpha: 1.0,
            projection: ProjectionKind::Quant8,
            ..GaLoreCfg::default()
        },
        adam: AdamCfg::default(),
    }
}

fn adamw_spec() -> OptimizerSpec {
    OptimizerSpec::AdamW(AdamCfg::default())
}

fn adam8bit_spec() -> OptimizerSpec {
    OptimizerSpec::Adam8bit(AdamCfg::default())
}

fn adafactor_spec() -> OptimizerSpec {
    OptimizerSpec::Adafactor { eps: 1e-30 }
}

/// The headline contract: train under FSDP world=2, checkpoint at
/// `boundary`, resume under every other mode/world, and the continued
/// trajectory is bitwise identical to the uninterrupted run.
fn elastic_from_fsdp2(spec: OptimizerSpec, boundary: u64, total: u64) {
    // Uninterrupted single-process reference — for these specs the
    // FSDP/DDP trajectories are bitwise equal to it by construction.
    let mut reference = build("single", 1, SHAPES, &spec, SEED);
    drive(reference.as_mut(), SHAPES, 0, total);

    // Source run: FSDP world=2, checkpoint at `boundary`, then continue —
    // pinning that the export itself doesn't perturb the trajectory and
    // that the sharded run matches the single-process reference.
    let mut src = build("fsdp", 2, SHAPES, &spec, SEED);
    drive(src.as_mut(), SHAPES, 0, boundary);
    let blob = src.export_state();
    let snapshot = src.params().to_vec();
    drive(src.as_mut(), SHAPES, boundary, total);
    assert_params_eq(src.params(), reference.params(), "uninterrupted fsdp(2)");

    for (mode, world) in [("fsdp", 4), ("fsdp", 1), ("ddp", 2), ("ddp", 4), ("single", 1)] {
        // Seed 999: everything the resumed run knows must come from the
        // checkpoint, not from construction-time state.
        let mut target = build(mode, world, SHAPES, &spec, 999);
        target.init_params(&snapshot);
        target
            .import_state(&blob)
            .unwrap_or_else(|e| panic!("{mode}({world}) import: {e}"));
        drive(target.as_mut(), SHAPES, boundary, total);
        assert_params_eq(
            target.params(),
            reference.params(),
            &format!("resumed {mode}({world})"),
        );
    }
}

#[test]
fn galore_fsdp2_checkpoint_resumes_anywhere() {
    // Boundary mid refresh-cycle (freq 3, boundary 7): the projector and
    // low-rank moments cross the checkpoint, and the next refresh (t=9)
    // draws from the restored sketch stream.
    elastic_from_fsdp2(galore_spec(), 7, 12);
}

#[test]
fn adamw_fsdp2_checkpoint_resumes_anywhere() {
    elastic_from_fsdp2(adamw_spec(), 5, 10);
}

#[test]
fn qgalore_fsdp2_checkpoint_resumes_anywhere() {
    // Boundary ON a refresh step (6 % 3 == 0) — the historically safe
    // alignment; kept alongside the mid-cycle test below.
    elastic_from_fsdp2(qgalore_spec(), 6, 12);
}

#[test]
fn qgalore_resume_crosses_non_refresh_boundary() {
    // Boundary MID refresh-cycle (7 % 3 != 0; last refresh t=6, next
    // t=9): the checkpoint must carry the quantized projector's exact
    // stored representation (codes + block scales) for the continuation
    // to stay bitwise. Before checkpoint v5 the projector was serialized
    // dequantized and only refresh-aligned checkpoints resumed
    // bit-exactly (re-quantizing a dequantized P can wobble a block's
    // absmax scale by 1 ulp); this pins that the alignment requirement is
    // gone.
    elastic_from_fsdp2(qgalore_spec(), 7, 12);
}

#[test]
fn quantized_galore_alias_checkpoint_resumes_anywhere() {
    // The other spec that answers to the "qgalore" name: plain GaLore
    // with a quantized projector (raw state layout everywhere). Its
    // checkpoints must convert through the same canonical framing — and,
    // with the stored-representation blobs, resume bitwise from a
    // NON-refresh-aligned boundary too.
    elastic_from_fsdp2(galore_q8_spec(), 7, 12);
}

#[test]
fn ddp_checkpoint_resumes_under_fsdp_and_single() {
    // The reverse direction: replicated-state checkpoints re-slice onto
    // sharded workers.
    let spec = galore_spec();
    let mut reference = build("single", 1, SHAPES, &spec, SEED);
    drive(reference.as_mut(), SHAPES, 0, 12);

    let mut src = build("ddp", 2, SHAPES, &spec, SEED);
    drive(src.as_mut(), SHAPES, 0, 7);
    let blob = src.export_state();
    let snapshot = src.params().to_vec();

    for (mode, world) in [("fsdp", 4), ("fsdp", 1), ("single", 1)] {
        let mut target = build(mode, world, SHAPES, &spec, 999);
        target.init_params(&snapshot);
        target
            .import_state(&blob)
            .unwrap_or_else(|e| panic!("{mode}({world}) import: {e}"));
        drive(target.as_mut(), SHAPES, 7, 12);
        assert_params_eq(
            target.params(),
            reference.params(),
            &format!("ddp→{mode}({world})"),
        );
    }
}

#[test]
fn canonical_export_bytes_identical_across_modes_and_worlds() {
    // The canonical form really is canonical: the same trajectory exports
    // the same BYTES from every mode and world — single, FSDP at 1/2/4,
    // and DDP — for both the projected (galore) and full-rank (adamw)
    // optimizers, and for the quantized-GaLore alias (raw layout under a
    // "qgalore" name — every mode wraps it into the same framed
    // canonical form). True Q-GaLore is excluded: its single/DDP blob
    // carries lazy-gate probe history that FSDP's inert gate never
    // accumulates.
    for spec in [galore_spec(), adamw_spec(), galore_q8_spec()] {
        let mut engines: Vec<(String, Box<dyn TrainEngine>)> = vec![
            ("single".into(), build("single", 1, SHAPES, &spec, SEED)),
            ("fsdp(1)".into(), build("fsdp", 1, SHAPES, &spec, SEED)),
            ("fsdp(2)".into(), build("fsdp", 2, SHAPES, &spec, SEED)),
            ("fsdp(4)".into(), build("fsdp", 4, SHAPES, &spec, SEED)),
            ("ddp(2)".into(), build("ddp", 2, SHAPES, &spec, SEED)),
        ];
        for (_, e) in engines.iter_mut() {
            drive(e.as_mut(), SHAPES, 0, 7);
        }
        let base = engines[0].1.export_state();
        assert!(
            CanonicalOptState::sniff(&base),
            "engine export must be canonical"
        );
        for (label, e) in &engines[1..] {
            let bytes = e.export_state();
            assert_eq!(
                bytes.len(),
                base.len(),
                "{}: {label} canonical size differs from single",
                spec.name()
            );
            assert_eq!(
                bytes,
                base,
                "{}: {label} canonical bytes differ from single",
                spec.name()
            );
        }
    }
}

#[test]
fn import_export_is_identity_at_any_world() {
    // Scatter∘gather over live clusters: importing canonical state into a
    // world-w engine and immediately re-exporting reproduces the exact
    // canonical bytes — for odd worlds too (3, 5), where shard widths are
    // uneven and the (1, 12) bias leaves ranks with tiny/empty slices.
    for spec in [galore_spec(), adamw_spec()] {
        let mut src = build("fsdp", 2, SHAPES, &spec, SEED);
        drive(src.as_mut(), SHAPES, 0, 7);
        let blob = src.export_state();
        let snapshot = src.params().to_vec();
        for world in [1usize, 2, 3, 4, 5] {
            let mut target = build("fsdp", world, SHAPES, &spec, 999);
            target.init_params(&snapshot);
            target
                .import_state(&blob)
                .unwrap_or_else(|e| panic!("world {world} import: {e}"));
            assert_eq!(
                target.export_state(),
                blob,
                "{} world {world}: import→export not identity",
                spec.name()
            );
        }
    }
}

#[test]
fn odd_world_resume_is_deterministic_and_finite() {
    // Worlds 3 and 5 average by non-powers-of-two, so they are not
    // bitwise-comparable to the single reference — but resuming there
    // must be deterministic (two resumes agree exactly) and healthy.
    let spec = galore_spec();
    let mut src = build("fsdp", 2, SHAPES, &spec, SEED);
    drive(src.as_mut(), SHAPES, 0, 6);
    let blob = src.export_state();
    let snapshot = src.params().to_vec();
    for world in [3usize, 5] {
        let run = |seed: u64| {
            let mut eng = build("fsdp", world, SHAPES, &spec, seed);
            eng.init_params(&snapshot);
            eng.import_state(&blob).unwrap();
            drive(eng.as_mut(), SHAPES, 6, 12);
            eng.params().to_vec()
        };
        let a = run(999);
        let b = run(4242);
        assert_params_eq(&a, &b, &format!("world {world} repeat resume"));
        for (idx, p) in a.iter().enumerate() {
            assert!(
                p.data.iter().all(|x| x.is_finite()),
                "world {world} param {idx} non-finite"
            );
        }
    }
}

#[test]
fn empty_shards_survive_checkpoint_and_resume() {
    // Layers narrower than the world: at world=4 the (2, 3) and (1, 3)
    // params leave rank 0 with ZERO columns. Train, checkpoint, resume
    // narrower and wider — trajectories must still match the
    // single-process reference bitwise.
    let shapes: &[(usize, usize)] = &[(2, 3), (1, 3), (3, 2), (4, 8)];
    let spec = adamw_spec();
    let mut reference = build("single", 1, shapes, &spec, SEED);
    drive(reference.as_mut(), shapes, 0, 8);

    let mut src = build("fsdp", 4, shapes, &spec, SEED);
    drive(src.as_mut(), shapes, 0, 4);
    let blob = src.export_state();
    let snapshot = src.params().to_vec();
    for (mode, world) in [("fsdp", 2), ("fsdp", 4), ("single", 1)] {
        let mut target = build(mode, world, shapes, &spec, 999);
        target.init_params(&snapshot);
        target.import_state(&blob).unwrap();
        drive(target.as_mut(), shapes, 4, 8);
        assert_params_eq(
            target.params(),
            reference.params(),
            &format!("empty-shard {mode}({world})"),
        );
    }
}

#[test]
fn adam8bit_block_aligned_fsdp2_checkpoint_resumes_anywhere() {
    // ALIGNED_SHAPES put every world-1/2/4 shard boundary on a
    // 256-element quantization block, so each rank's block-quantized
    // moments ARE a contiguous run of the full tensor's blocks: the
    // canonical gather is byte-identical to a single-process export and
    // the elastic matrix FSDP(2)→{FSDP(4), FSDP(1), DDP(2), Single} is
    // bitwise — no re-quantization anywhere.
    let spec = adam8bit_spec();
    let shapes = ALIGNED_SHAPES;
    let mut reference = build("single", 1, shapes, &spec, SEED);
    drive(reference.as_mut(), shapes, 0, 10);

    let mut src = build("fsdp", 2, shapes, &spec, SEED);
    drive(src.as_mut(), shapes, 0, 5);
    let blob = src.export_state();
    let snapshot = src.params().to_vec();

    // The aligned gather really is canonical: same bytes as a
    // single-process export of the same trajectory.
    let mut single_src = build("single", 1, shapes, &spec, SEED);
    drive(single_src.as_mut(), shapes, 0, 5);
    assert_eq!(
        blob,
        single_src.export_state(),
        "aligned adam8bit gather must match the single-process canonical bytes"
    );

    drive(src.as_mut(), shapes, 5, 10);
    assert_params_eq(src.params(), reference.params(), "uninterrupted fsdp(2) adam8bit");

    for (mode, world) in [("fsdp", 4), ("fsdp", 1), ("ddp", 2), ("single", 1)] {
        let mut target = build(mode, world, shapes, &spec, 999);
        target.init_params(&snapshot);
        target
            .import_state(&blob)
            .unwrap_or_else(|e| panic!("{mode}({world}) import: {e}"));
        drive(target.as_mut(), shapes, 5, 10);
        assert_params_eq(
            target.params(),
            reference.params(),
            &format!("resumed {mode}({world}) adam8bit"),
        );
    }
}

#[test]
fn adam8bit_misaligned_state_requires_loud_requantize_opt_in() {
    // SHAPES' small tensors cannot land shard boundaries on quantization
    // blocks, so FSDP(2) adam8bit state stays world-locked per-rank:
    // same-world resume is bitwise, every other target fails loudly
    // WITHOUT `--resume-requantize` and continues deterministically (and
    // finitely) WITH it.
    let spec = adam8bit_spec();
    let mut reference = build("fsdp", 2, SHAPES, &spec, SEED);
    drive(reference.as_mut(), SHAPES, 0, 10);

    let mut src = build("fsdp", 2, SHAPES, &spec, SEED);
    drive(src.as_mut(), SHAPES, 0, 5);
    let blob = src.export_state();
    let snapshot = src.params().to_vec();

    let mut same = build("fsdp", 2, SHAPES, &spec, 999);
    same.init_params(&snapshot);
    same.import_state(&blob).unwrap();
    drive(same.as_mut(), SHAPES, 5, 10);
    assert_params_eq(same.params(), reference.params(), "same-world adam8bit resume");

    for (mode, world) in [("fsdp", 4), ("fsdp", 1), ("ddp", 2), ("single", 1)] {
        let mut target = build(mode, world, SHAPES, &spec, 999);
        target.init_params(&snapshot);
        let err = target.import_state(&blob).unwrap_err();
        assert!(
            err.contains("--resume-requantize"),
            "{mode}({world}): error must name the opt-in flag: {err}"
        );
        let run = |seed: u64| {
            let mut eng = build(mode, world, SHAPES, &spec, seed);
            eng.init_params(&snapshot);
            eng.import_state_with(&blob, ImportOpts::requantize())
                .unwrap_or_else(|e| panic!("{mode}({world}) requantize import: {e}"));
            drive(eng.as_mut(), SHAPES, 5, 10);
            eng.params().to_vec()
        };
        let a = run(999);
        let b = run(4242);
        assert_params_eq(&a, &b, &format!("{mode}({world}) repeat requantize resume"));
        for (idx, p) in a.iter().enumerate() {
            assert!(
                p.data.iter().all(|x| x.is_finite()),
                "{mode}({world}) param {idx} non-finite after requantized resume"
            );
        }
        // The requantized import restores real moments (the trajectory is
        // approximate, not reset): continuing must actually move the
        // parameters away from the checkpoint snapshot.
        for (idx, (p, s)) in a.iter().zip(&snapshot).enumerate() {
            assert_ne!(
                p.data, s.data,
                "{mode}({world}) param {idx} did not train after requantized resume"
            );
        }
    }
}

#[test]
fn adafactor_same_world_and_replicated_resumes_are_bitwise() {
    // Adafactor's factored accumulators are rank-local statistics, so the
    // exact cross-world story is narrower: same-world FSDP resume and the
    // replicated family (single ↔ DDP ↔ FSDP(1)) are bitwise.
    let spec = adafactor_spec();
    // FSDP(2) → FSDP(2): per-rank frames pass through identically.
    let mut reference = build("fsdp", 2, SHAPES, &spec, SEED);
    drive(reference.as_mut(), SHAPES, 0, 10);
    let mut src = build("fsdp", 2, SHAPES, &spec, SEED);
    drive(src.as_mut(), SHAPES, 0, 5);
    let blob = src.export_state();
    let snapshot = src.params().to_vec();
    let mut same = build("fsdp", 2, SHAPES, &spec, 999);
    same.init_params(&snapshot);
    same.import_state(&blob).unwrap();
    drive(same.as_mut(), SHAPES, 5, 10);
    assert_params_eq(same.params(), reference.params(), "same-world adafactor resume");

    // Single source → DDP(2) and FSDP(1): full-tensor state passes
    // through exactly, trajectories match the uninterrupted single run.
    let mut single_ref = build("single", 1, SHAPES, &spec, SEED);
    drive(single_ref.as_mut(), SHAPES, 0, 10);
    let mut single_src = build("single", 1, SHAPES, &spec, SEED);
    drive(single_src.as_mut(), SHAPES, 0, 5);
    let sblob = single_src.export_state();
    let ssnapshot = single_src.params().to_vec();
    for (mode, world) in [("ddp", 2), ("fsdp", 1), ("single", 1)] {
        let mut target = build(mode, world, SHAPES, &spec, 999);
        target.init_params(&ssnapshot);
        target
            .import_state(&sblob)
            .unwrap_or_else(|e| panic!("{mode}({world}) import: {e}"));
        drive(target.as_mut(), SHAPES, 5, 10);
        assert_params_eq(
            target.params(),
            single_ref.params(),
            &format!("single→{mode}({world}) adafactor"),
        );
    }
}

#[test]
fn adafactor_cross_world_requires_loud_opt_in() {
    // The factored cross-statistic cannot be re-sliced exactly: crossing
    // worlds (either direction) fails loudly without the opt-in and runs
    // deterministically with it.
    let spec = adafactor_spec();

    // Direction 1: single-process (full-tensor) state → FSDP(2).
    let mut single_src = build("single", 1, SHAPES, &spec, SEED);
    drive(single_src.as_mut(), SHAPES, 0, 5);
    let sblob = single_src.export_state();
    let ssnapshot = single_src.params().to_vec();
    let mut sharded = build("fsdp", 2, SHAPES, &spec, 999);
    sharded.init_params(&ssnapshot);
    let err = sharded.import_state(&sblob).unwrap_err();
    assert!(
        err.contains("--resume-requantize"),
        "single→fsdp(2): error must name the opt-in flag: {err}"
    );

    // Direction 2: FSDP(2) per-rank state → {FSDP(4), single}.
    let mut src = build("fsdp", 2, SHAPES, &spec, SEED);
    drive(src.as_mut(), SHAPES, 0, 5);
    let blob = src.export_state();
    let snapshot = src.params().to_vec();
    for (mode, world) in [("fsdp", 4), ("single", 1), ("fsdp", 2)] {
        // fsdp(2) rides along as the exact control: the opt-in must not
        // change the exact same-world path.
        let run = |seed: u64, opts: ImportOpts| {
            let mut eng = build(mode, world, SHAPES, &spec, seed);
            eng.init_params(&snapshot);
            eng.import_state_with(&blob, opts)
                .unwrap_or_else(|e| panic!("{mode}({world}) import: {e}"));
            drive(eng.as_mut(), SHAPES, 5, 10);
            eng.params().to_vec()
        };
        if !(mode == "fsdp" && world == 2) {
            let mut target = build(mode, world, SHAPES, &spec, 999);
            target.init_params(&snapshot);
            let err = target.import_state(&blob).unwrap_err();
            assert!(
                err.contains("--resume-requantize"),
                "{mode}({world}): error must name the opt-in flag: {err}"
            );
        }
        let a = run(999, ImportOpts::requantize());
        let b = run(4242, ImportOpts::requantize());
        assert_params_eq(&a, &b, &format!("{mode}({world}) repeat adafactor resume"));
        for (idx, p) in a.iter().enumerate() {
            assert!(
                p.data.iter().all(|x| x.is_finite()),
                "{mode}({world}) param {idx} non-finite after merged resume"
            );
        }
    }
}

#[test]
fn corrupt_quantized_payloads_fail_loudly() {
    // Structurally inconsistent quantized canonical state — lying block
    // counts, scale-count mismatches, truncation anywhere — must ERROR on
    // import, never panic or silently misparse. (Unit-level guards live
    // in quant/ and checkpoint/canonical.rs; this pins the engine
    // surface.)
    let spec = adam8bit_spec();
    let mut src = build("fsdp", 2, ALIGNED_SHAPES, &spec, SEED);
    drive(src.as_mut(), ALIGNED_SHAPES, 0, 3);
    let blob = src.export_state();
    let snapshot = src.params().to_vec();
    for cut in [9usize, 24, blob.len() / 3, blob.len() / 2, blob.len() - 1] {
        let mut target = build("fsdp", 2, ALIGNED_SHAPES, &spec, 999);
        target.init_params(&snapshot);
        assert!(
            target.import_state(&blob[..cut]).is_err(),
            "truncation at {cut}/{} bytes imported silently",
            blob.len()
        );
    }
    // A hand-built payload whose scale count disagrees with its element
    // count: the shared block parser's cross-check must reject it.
    let lying = CanonicalOptState {
        name: "adam8bit".into(),
        payload: OptPayload::Quantized {
            t: 2,
            states: vec![(
                0,
                vec![
                    CanonicalTensor::Q8(Quantized8 {
                        codes: vec![0; 1024],
                        scales: vec![1.0], // should be 4 blocks
                        len: 1024,
                    }),
                    CanonicalTensor::Q8(Quantized8::quantize(&vec![0.1; 1024])),
                ],
            )],
        },
    }
    .encode();
    let mut target = build("fsdp", 2, ALIGNED_SHAPES, &spec, 999);
    target.init_params(&snapshot);
    let err = target.import_state(&lying).unwrap_err();
    assert!(
        err.contains("scales") || err.contains("blocks") || err.contains("elements"),
        "unhelpful corrupt-payload error: {err}"
    );
}

#[test]
fn committed_legacy_fixtures_migrate_to_v5() {
    // COMMITTED v3/v4 checkpoint files (tests/fixtures/, generated by
    // make_fixtures.py against the pre-v5 layouts) pin the legacy gates:
    // if the version gate, the canonical sniffing, or the pre-v5
    // optimizer blob layouts rot, these loads fail — no silent skip
    // (GALORE2_DENY_SKIP irrelevant: the files are in-tree).
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures");

    // v3: adamw canonical state, no token counter. Resumes under FSDP(2),
    // DDP(2) and single with IDENTICAL continuations.
    let v3 = Checkpoint::load(dir.join("ckpt_v3_adamw.ckpt")).unwrap();
    assert_eq!(v3.step, 4);
    assert_eq!(v3.tokens_seen, None, "v3 carries no token counter");
    assert!(CanonicalOptState::sniff(&v3.opt_state));
    let spec = adamw_spec();
    let mut runs: Vec<(String, Vec<Matrix>)> = Vec::new();
    for (mode, world) in [("fsdp", 2), ("ddp", 2), ("single", 1)] {
        let mut e = build(mode, world, SHAPES, &spec, 999);
        e.init_params(&v3.params);
        e.import_state(&v3.opt_state)
            .unwrap_or_else(|err| panic!("v3 {mode}({world}) import: {err}"));
        drive(e.as_mut(), SHAPES, v3.step, v3.step + 4);
        runs.push((format!("v3 {mode}({world})"), e.params().to_vec()));
    }
    let base = runs[0].1.clone();
    for (label, params) in &runs[1..] {
        assert_params_eq(params, &base, label);
    }

    // v4: galore canonical state in the LEGACY (dequantized-projector)
    // blob layout + exact token counter. Load → resume → re-save
    // migrates to v5; the migrated file carries canonical state and
    // re-slices to a different world, all bitwise on one trajectory.
    let v4 = Checkpoint::load(dir.join("ckpt_v4_galore.ckpt")).unwrap();
    assert_eq!(v4.step, 6);
    assert_eq!(v4.tokens_seen, Some(12_288), "v4 carries the token counter");
    let spec = galore_spec();
    let mut single = build("single", 1, SHAPES, &spec, 999);
    single.init_params(&v4.params);
    single.import_state(&v4.opt_state).unwrap();
    drive(single.as_mut(), SHAPES, 6, 12);

    let mut migrator = build("fsdp", 2, SHAPES, &spec, 999);
    migrator.init_params(&v4.params);
    migrator.import_state(&v4.opt_state).unwrap();
    let out = std::env::temp_dir().join(format!(
        "galore2_fixture_migrated_{}.ckpt",
        std::process::id()
    ));
    Checkpoint {
        step: v4.step,
        tokens_seen: v4.tokens_seen,
        names: v4.names.clone(),
        params: migrator.params().to_vec(),
        opt_state: migrator.export_state(),
    }
    .save(&out)
    .unwrap();
    let bytes = std::fs::read(&out).unwrap();
    assert_eq!(
        u32::from_le_bytes(bytes[8..12].try_into().unwrap()),
        VERSION,
        "re-save must write the current (v5) version"
    );
    let migrated = Checkpoint::load(&out).unwrap();
    assert!(CanonicalOptState::sniff(&migrated.opt_state));
    assert_eq!(migrated.tokens_seen, Some(12_288));

    drive(migrator.as_mut(), SHAPES, 6, 12);
    assert_params_eq(
        migrator.params(),
        single.params(),
        "v4 fixture: fsdp(2) vs single continuation",
    );
    let mut wide = build("fsdp", 4, SHAPES, &spec, 999);
    wide.init_params(&migrated.params);
    wide.import_state(&migrated.opt_state).unwrap();
    drive(wide.as_mut(), SHAPES, 6, 12);
    assert_params_eq(
        wide.params(),
        single.params(),
        "migrated v5 file resumes elastically at world 4",
    );
    std::fs::remove_file(out).ok();
}

#[test]
fn truncated_canonical_state_fails_loudly() {
    // Chopping the canonical blob anywhere — mid-header, mid-frame, off
    // by one — must produce an error, never a silent partial import.
    let spec = galore_spec();
    let mut src = build("fsdp", 2, SHAPES, &spec, SEED);
    drive(src.as_mut(), SHAPES, 0, 4);
    let blob = src.export_state();
    let snapshot = src.params().to_vec();
    for cut in [8usize, 9, 20, blob.len() / 2, blob.len() - 1] {
        let mut target = build("fsdp", 2, SHAPES, &spec, 999);
        target.init_params(&snapshot);
        assert!(
            target.import_state(&blob[..cut]).is_err(),
            "truncation at {cut}/{} bytes imported silently",
            blob.len()
        );
    }
    // Wrong-optimizer state is rejected by name, not misparsed.
    let mut adamw_engine = build("fsdp", 2, SHAPES, &adamw_spec(), 999);
    adamw_engine.init_params(&snapshot);
    let err = adamw_engine.import_state(&blob).unwrap_err();
    assert!(
        err.contains("galore") && err.contains("adamw"),
        "unhelpful optimizer-mismatch error: {err}"
    );
}

#[test]
fn legacy_v2_state_is_world_locked_with_actionable_error() {
    // v2 checkpoints carried raw FSDP per-rank frames. Same world still
    // resumes bitwise; any other world must fail loudly with a migration
    // hint — NEVER silently reset moments.
    let spec = galore_spec();
    let metas = fixtures::metas_for(SHAPES);
    let mut cluster = FsdpCluster::new(2, metas, spec.clone(), SEED);
    cluster.init_params(&init(SHAPES));
    for t in 0..4u64 {
        cluster.step(t, vec![grads(SHAPES, t); 2], LR);
    }
    let legacy = cluster.export_optimizers();
    let snapshot = cluster.gather_params();
    assert!(
        !CanonicalOptState::sniff(&legacy),
        "legacy framed blob must not carry the canonical header"
    );

    // Same world: the legacy path still restores every rank.
    let mut same = build("fsdp", 2, SHAPES, &spec, 999);
    same.init_params(&snapshot);
    same.import_state(&legacy).unwrap();
    let mut reference = build("single", 1, SHAPES, &spec, SEED);
    drive(reference.as_mut(), SHAPES, 0, 8);
    drive(same.as_mut(), SHAPES, 4, 8);
    assert_params_eq(same.params(), reference.params(), "legacy same-world resume");

    // Different world: loud, actionable failure.
    let mut other = build("fsdp", 4, SHAPES, &spec, 999);
    other.init_params(&snapshot);
    let err = other.import_state(&legacy).unwrap_err();
    assert!(
        err.contains("world=2") && err.contains("--world 2"),
        "unhelpful legacy world-mismatch error: {err}"
    );
}

#[test]
fn v2_checkpoint_migrates_to_canonical_and_unlocks_elastic_resume() {
    // Load a legacy (v2) checkpoint at its original world, re-save — the
    // new file carries canonical (v3+) state and resumes at any world.
    let dir = std::env::temp_dir().join(format!("galore2_resharding_{}", std::process::id()));
    let v2_path = dir.join("legacy_v2.ckpt");
    let migrated_path = dir.join("migrated.ckpt");
    let spec = galore_spec();
    let names: Vec<String> = fixtures::metas_for(SHAPES)
        .iter()
        .map(|m| m.name.clone())
        .collect();

    // Source run writes a v2 checkpoint at step 6 (legacy framed state).
    let mut cluster = FsdpCluster::new(2, fixtures::metas_for(SHAPES), spec.clone(), SEED);
    cluster.init_params(&init(SHAPES));
    for t in 0..6u64 {
        cluster.step(t, vec![grads(SHAPES, t); 2], LR);
    }
    Checkpoint {
        step: 6,
        tokens_seen: None,
        names: names.clone(),
        params: cluster.gather_params(),
        opt_state: cluster.export_optimizers(),
    }
    .save_with_version(&v2_path, LEGACY_VERSION)
    .unwrap();

    // Migrate: load v2, resume at the ORIGINAL world, save → current version.
    let v2 = Checkpoint::load(&v2_path).unwrap();
    let mut migrator = build("fsdp", 2, SHAPES, &spec, 999);
    migrator.init_params(&v2.params);
    migrator.import_state(&v2.opt_state).unwrap();
    Checkpoint {
        step: v2.step,
        tokens_seen: None,
        names,
        params: migrator.params().to_vec(),
        opt_state: migrator.export_state(),
    }
    .save(&migrated_path)
    .unwrap();

    // The migrated file is canonical and resumes at a DIFFERENT world,
    // bitwise on the uninterrupted single-process trajectory.
    let migrated = Checkpoint::load(&migrated_path).unwrap();
    assert!(
        CanonicalOptState::sniff(&migrated.opt_state),
        "migrated checkpoint must carry canonical state"
    );
    let mut reference = build("single", 1, SHAPES, &spec, SEED);
    drive(reference.as_mut(), SHAPES, 0, 12);
    let mut elastic = build("fsdp", 4, SHAPES, &spec, 999);
    elastic.init_params(&migrated.params);
    elastic.import_state(&migrated.opt_state).unwrap();
    drive(elastic.as_mut(), SHAPES, migrated.step, 12);
    assert_params_eq(
        elastic.params(),
        reference.params(),
        "migrated elastic resume",
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn process_transport_checkpoint_resumes_elastically_across_transports() {
    // The canonical form is transport-independent by construction: a
    // checkpoint produced by Unix-socket worker PROCESSES (FSDP world=2)
    // exports the exact bytes a threaded source would, and resumes under
    // threaded FSDP(4), a process-transport DDP(2), and single-process —
    // all bitwise on the uninterrupted single-process trajectory.
    set_worker_binary(env!("CARGO_BIN_EXE_galore2"));
    let spec = galore_spec();
    let mut reference = build("single", 1, SHAPES, &spec, SEED);
    drive(reference.as_mut(), SHAPES, 0, 12);

    let metas = fixtures::metas_for(SHAPES);
    let mut src: Box<dyn TrainEngine> = Box::new(
        FsdpEngine::with_transport(
            2,
            metas.clone(),
            spec.clone(),
            SEED,
            &init(SHAPES),
            TransportKind::Process,
        )
        .unwrap(),
    );
    drive(src.as_mut(), SHAPES, 0, 7);
    let blob = src.export_state();
    let snapshot = src.params().to_vec();

    // Same boundary, threaded source: byte-identical canonical export.
    let mut threaded = build("fsdp", 2, SHAPES, &spec, SEED);
    drive(threaded.as_mut(), SHAPES, 0, 7);
    assert_eq!(
        blob,
        threaded.export_state(),
        "canonical bytes must not depend on the transport"
    );

    let targets: Vec<(&str, Box<dyn TrainEngine>)> = vec![
        ("threads fsdp(4)", build("fsdp", 4, SHAPES, &spec, 999)),
        ("threads single", build("single", 1, SHAPES, &spec, 999)),
        (
            "process ddp(2)",
            Box::new(
                DdpEngine::with_transport(
                    2,
                    metas,
                    spec.clone(),
                    999,
                    &init(SHAPES),
                    TransportKind::Process,
                )
                .unwrap(),
            ),
        ),
    ];
    for (label, mut target) in targets {
        target.init_params(&snapshot);
        target
            .import_state(&blob)
            .unwrap_or_else(|e| panic!("{label} import: {e}"));
        drive(target.as_mut(), SHAPES, 7, 12);
        assert_params_eq(target.params(), reference.params(), label);
    }
}

#[test]
fn process_transport_adam8bit_canonical_bytes_match_threads() {
    // The quantized canonical form is transport-independent too: worker
    // PROCESSES export the exact bytes worker threads do (block-aligned
    // geometry → the typed Quantized flavor), and the blob resumes under
    // threaded single-process bitwise.
    set_worker_binary(env!("CARGO_BIN_EXE_galore2"));
    let spec = adam8bit_spec();
    let shapes = ALIGNED_SHAPES;
    let metas = fixtures::metas_for(shapes);
    let mut proc: Box<dyn TrainEngine> = Box::new(
        FsdpEngine::with_transport(
            2,
            metas,
            spec.clone(),
            SEED,
            &init(shapes),
            TransportKind::Process,
        )
        .unwrap(),
    );
    drive(proc.as_mut(), shapes, 0, 4);
    let blob = proc.export_state();
    let snapshot = proc.params().to_vec();

    let mut threaded = build("fsdp", 2, shapes, &spec, SEED);
    drive(threaded.as_mut(), shapes, 0, 4);
    assert_eq!(
        blob,
        threaded.export_state(),
        "adam8bit canonical bytes must not depend on the transport"
    );

    let mut reference = build("single", 1, shapes, &spec, SEED);
    drive(reference.as_mut(), shapes, 0, 8);
    let mut target = build("single", 1, shapes, &spec, 999);
    target.init_params(&snapshot);
    target.import_state(&blob).unwrap();
    drive(target.as_mut(), shapes, 4, 8);
    assert_params_eq(
        target.params(),
        reference.params(),
        "process-transport adam8bit → single",
    );
}
