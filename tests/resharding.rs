//! Cross-world parity: the elastic-resume contract.
//!
//! A v3+ checkpoint stores optimizer state in the canonical, world-agnostic
//! form (`checkpoint::canonical`). These tests pin the contract end to
//! end at the engine level, with no compiled artifacts needed:
//!
//! * a checkpoint written under FSDP world=2 resumes under FSDP world=4,
//!   world=1, DDP, and single-process with a **bitwise identical**
//!   trajectory — for galore, qgalore, and adamw;
//! * DDP checkpoints resume under FSDP and single-process the same way;
//! * the canonical export bytes are identical no matter which mode/world
//!   produced them, and gather∘scatter is the identity on them — including
//!   non-power-of-two worlds (3, 5) and worlds that leave ranks with
//!   empty shards;
//! * legacy (v2) world-locked state and corrupt blobs fail loudly, never
//!   silently resetting moments; loading a v2 checkpoint at its original
//!   world and re-saving migrates it to the current (canonical) version.
//!
//! Identical per-rank microbatch gradients make trajectories bitwise
//! comparable across worlds 1/2/4 (the tree-reduced average of w equal
//! values is exact for power-of-two w — see dist/fsdp.rs tests).
//! Q-GaLore's checkpoint boundary sits ON a refresh step: quantized
//! projectors are re-derived from the restored sketch stream at the first
//! refresh after resume, sidestepping the 1-ulp absmax wobble that
//! re-quantizing a dequantized P can introduce (EXPERIMENTS.md §Resume).

use galore2::checkpoint::canonical::CanonicalOptState;
use galore2::checkpoint::{Checkpoint, LEGACY_VERSION};
use galore2::dist::{set_worker_binary, FsdpCluster, TransportKind};
use galore2::optim::{AdamCfg, GaLoreCfg, OptimizerSpec, ProjectionKind};
use galore2::tensor::Matrix;
use galore2::testing::fixtures;
use galore2::train::{DdpEngine, FsdpEngine, SingleEngine, TrainEngine};

/// Wide, tall, square, and bias-like (unprojected) parameters.
const SHAPES: &[(usize, usize)] = &[(8, 16), (16, 8), (6, 6), (1, 12)];
const LR: f32 = 0.03;
const SEED: u64 = 21;

fn grads(shapes: &[(usize, usize)], t: u64) -> Vec<Matrix> {
    // Stream of rank 0 for EVERY rank: identical microbatches keep runs
    // comparable across world sizes.
    fixtures::rank_grads(shapes, t, 0, 0.1)
}

fn init(shapes: &[(usize, usize)]) -> Vec<Matrix> {
    fixtures::randn_set(shapes, 0.5, 7, 0)
}

/// Build an engine: ("single", _) | ("fsdp", w) | ("ddp", w).
fn build(
    mode: &str,
    world: usize,
    shapes: &[(usize, usize)],
    spec: &OptimizerSpec,
    seed: u64,
) -> Box<dyn TrainEngine> {
    let metas = fixtures::metas_for(shapes);
    match mode {
        "single" => Box::new(SingleEngine::new(spec, seed, None, init(shapes)).unwrap()),
        "fsdp" => {
            Box::new(FsdpEngine::new(world, metas, spec.clone(), seed, &init(shapes)).unwrap())
        }
        "ddp" => Box::new(DdpEngine::new(world, metas, spec.clone(), seed, &init(shapes)).unwrap()),
        other => panic!("unknown mode {other}"),
    }
}

fn drive(e: &mut dyn TrainEngine, shapes: &[(usize, usize)], t0: u64, t1: u64) {
    let w = e.world();
    for t in t0..t1 {
        e.step(t, vec![grads(shapes, t); w], LR);
    }
}

fn assert_params_eq(got: &[Matrix], want: &[Matrix], label: &str) {
    assert_eq!(got.len(), want.len(), "{label}: param count");
    for (idx, (a, b)) in got.iter().zip(want).enumerate() {
        assert_eq!(a.data, b.data, "{label}: param {idx} diverged");
    }
}

fn galore_spec() -> OptimizerSpec {
    OptimizerSpec::GaLore {
        galore: GaLoreCfg {
            rank: 4,
            update_freq: 3,
            alpha: 1.0,
            projection: ProjectionKind::RandSvd,
            ..GaLoreCfg::default()
        },
        adam: AdamCfg::default(),
    }
}

fn qgalore_spec() -> OptimizerSpec {
    OptimizerSpec::QGaLore {
        galore: GaLoreCfg {
            rank: 4,
            update_freq: 3,
            alpha: 1.0,
            projection: ProjectionKind::Quant8,
            ..GaLoreCfg::default()
        },
        adam: AdamCfg::default(),
        // Cosine similarity never exceeds 2.0: the lazy gate takes every
        // scheduled refresh, keeping single/DDP (gated) trajectories equal
        // to FSDP (coordinator-driven, gate inert).
        similarity_threshold: 2.0,
    }
}

fn galore_q8_spec() -> OptimizerSpec {
    // A *GaLore* spec with a quantized projector: reports the "qgalore"
    // display name but serializes the raw GaLore layout on every build
    // path — the codec conversion at the canonical boundary
    // (OptimizerSpec::state_codec) is what keeps it resumable anywhere.
    OptimizerSpec::GaLore {
        galore: GaLoreCfg {
            rank: 4,
            update_freq: 3,
            alpha: 1.0,
            projection: ProjectionKind::Quant8,
            ..GaLoreCfg::default()
        },
        adam: AdamCfg::default(),
    }
}

fn adamw_spec() -> OptimizerSpec {
    OptimizerSpec::AdamW(AdamCfg::default())
}

/// The headline contract: train under FSDP world=2, checkpoint at
/// `boundary`, resume under every other mode/world, and the continued
/// trajectory is bitwise identical to the uninterrupted run.
fn elastic_from_fsdp2(spec: OptimizerSpec, boundary: u64, total: u64) {
    // Uninterrupted single-process reference — for these specs the
    // FSDP/DDP trajectories are bitwise equal to it by construction.
    let mut reference = build("single", 1, SHAPES, &spec, SEED);
    drive(reference.as_mut(), SHAPES, 0, total);

    // Source run: FSDP world=2, checkpoint at `boundary`, then continue —
    // pinning that the export itself doesn't perturb the trajectory and
    // that the sharded run matches the single-process reference.
    let mut src = build("fsdp", 2, SHAPES, &spec, SEED);
    drive(src.as_mut(), SHAPES, 0, boundary);
    let blob = src.export_state();
    let snapshot = src.params().to_vec();
    drive(src.as_mut(), SHAPES, boundary, total);
    assert_params_eq(src.params(), reference.params(), "uninterrupted fsdp(2)");

    for (mode, world) in [("fsdp", 4), ("fsdp", 1), ("ddp", 2), ("ddp", 4), ("single", 1)] {
        // Seed 999: everything the resumed run knows must come from the
        // checkpoint, not from construction-time state.
        let mut target = build(mode, world, SHAPES, &spec, 999);
        target.init_params(&snapshot);
        target
            .import_state(&blob)
            .unwrap_or_else(|e| panic!("{mode}({world}) import: {e}"));
        drive(target.as_mut(), SHAPES, boundary, total);
        assert_params_eq(
            target.params(),
            reference.params(),
            &format!("resumed {mode}({world})"),
        );
    }
}

#[test]
fn galore_fsdp2_checkpoint_resumes_anywhere() {
    // Boundary mid refresh-cycle (freq 3, boundary 7): the projector and
    // low-rank moments cross the checkpoint, and the next refresh (t=9)
    // draws from the restored sketch stream.
    elastic_from_fsdp2(galore_spec(), 7, 12);
}

#[test]
fn adamw_fsdp2_checkpoint_resumes_anywhere() {
    elastic_from_fsdp2(adamw_spec(), 5, 10);
}

#[test]
fn qgalore_fsdp2_checkpoint_resumes_anywhere() {
    // Boundary ON a refresh step (6 % 3 == 0): the quantized projector is
    // re-derived from the restored stream before first use (see module
    // docs for why quantized P transport pins this alignment).
    elastic_from_fsdp2(qgalore_spec(), 6, 12);
}

#[test]
fn quantized_galore_alias_checkpoint_resumes_anywhere() {
    // The other spec that answers to the "qgalore" name: plain GaLore
    // with a quantized projector (raw state layout everywhere). Its
    // checkpoints must convert through the same canonical framing.
    elastic_from_fsdp2(galore_q8_spec(), 6, 12);
}

#[test]
fn ddp_checkpoint_resumes_under_fsdp_and_single() {
    // The reverse direction: replicated-state checkpoints re-slice onto
    // sharded workers.
    let spec = galore_spec();
    let mut reference = build("single", 1, SHAPES, &spec, SEED);
    drive(reference.as_mut(), SHAPES, 0, 12);

    let mut src = build("ddp", 2, SHAPES, &spec, SEED);
    drive(src.as_mut(), SHAPES, 0, 7);
    let blob = src.export_state();
    let snapshot = src.params().to_vec();

    for (mode, world) in [("fsdp", 4), ("fsdp", 1), ("single", 1)] {
        let mut target = build(mode, world, SHAPES, &spec, 999);
        target.init_params(&snapshot);
        target
            .import_state(&blob)
            .unwrap_or_else(|e| panic!("{mode}({world}) import: {e}"));
        drive(target.as_mut(), SHAPES, 7, 12);
        assert_params_eq(
            target.params(),
            reference.params(),
            &format!("ddp→{mode}({world})"),
        );
    }
}

#[test]
fn canonical_export_bytes_identical_across_modes_and_worlds() {
    // The canonical form really is canonical: the same trajectory exports
    // the same BYTES from every mode and world — single, FSDP at 1/2/4,
    // and DDP — for both the projected (galore) and full-rank (adamw)
    // optimizers, and for the quantized-GaLore alias (raw layout under a
    // "qgalore" name — every mode wraps it into the same framed
    // canonical form). True Q-GaLore is excluded: its single/DDP blob
    // carries lazy-gate probe history that FSDP's inert gate never
    // accumulates.
    for spec in [galore_spec(), adamw_spec(), galore_q8_spec()] {
        let mut engines: Vec<(String, Box<dyn TrainEngine>)> = vec![
            ("single".into(), build("single", 1, SHAPES, &spec, SEED)),
            ("fsdp(1)".into(), build("fsdp", 1, SHAPES, &spec, SEED)),
            ("fsdp(2)".into(), build("fsdp", 2, SHAPES, &spec, SEED)),
            ("fsdp(4)".into(), build("fsdp", 4, SHAPES, &spec, SEED)),
            ("ddp(2)".into(), build("ddp", 2, SHAPES, &spec, SEED)),
        ];
        for (_, e) in engines.iter_mut() {
            drive(e.as_mut(), SHAPES, 0, 7);
        }
        let base = engines[0].1.export_state();
        assert!(
            CanonicalOptState::sniff(&base),
            "engine export must be canonical"
        );
        for (label, e) in &engines[1..] {
            let bytes = e.export_state();
            assert_eq!(
                bytes.len(),
                base.len(),
                "{}: {label} canonical size differs from single",
                spec.name()
            );
            assert_eq!(
                bytes,
                base,
                "{}: {label} canonical bytes differ from single",
                spec.name()
            );
        }
    }
}

#[test]
fn import_export_is_identity_at_any_world() {
    // Scatter∘gather over live clusters: importing canonical state into a
    // world-w engine and immediately re-exporting reproduces the exact
    // canonical bytes — for odd worlds too (3, 5), where shard widths are
    // uneven and the (1, 12) bias leaves ranks with tiny/empty slices.
    for spec in [galore_spec(), adamw_spec()] {
        let mut src = build("fsdp", 2, SHAPES, &spec, SEED);
        drive(src.as_mut(), SHAPES, 0, 7);
        let blob = src.export_state();
        let snapshot = src.params().to_vec();
        for world in [1usize, 2, 3, 4, 5] {
            let mut target = build("fsdp", world, SHAPES, &spec, 999);
            target.init_params(&snapshot);
            target
                .import_state(&blob)
                .unwrap_or_else(|e| panic!("world {world} import: {e}"));
            assert_eq!(
                target.export_state(),
                blob,
                "{} world {world}: import→export not identity",
                spec.name()
            );
        }
    }
}

#[test]
fn odd_world_resume_is_deterministic_and_finite() {
    // Worlds 3 and 5 average by non-powers-of-two, so they are not
    // bitwise-comparable to the single reference — but resuming there
    // must be deterministic (two resumes agree exactly) and healthy.
    let spec = galore_spec();
    let mut src = build("fsdp", 2, SHAPES, &spec, SEED);
    drive(src.as_mut(), SHAPES, 0, 6);
    let blob = src.export_state();
    let snapshot = src.params().to_vec();
    for world in [3usize, 5] {
        let run = |seed: u64| {
            let mut eng = build("fsdp", world, SHAPES, &spec, seed);
            eng.init_params(&snapshot);
            eng.import_state(&blob).unwrap();
            drive(eng.as_mut(), SHAPES, 6, 12);
            eng.params().to_vec()
        };
        let a = run(999);
        let b = run(4242);
        assert_params_eq(&a, &b, &format!("world {world} repeat resume"));
        for (idx, p) in a.iter().enumerate() {
            assert!(
                p.data.iter().all(|x| x.is_finite()),
                "world {world} param {idx} non-finite"
            );
        }
    }
}

#[test]
fn empty_shards_survive_checkpoint_and_resume() {
    // Layers narrower than the world: at world=4 the (2, 3) and (1, 3)
    // params leave rank 0 with ZERO columns. Train, checkpoint, resume
    // narrower and wider — trajectories must still match the
    // single-process reference bitwise.
    let shapes: &[(usize, usize)] = &[(2, 3), (1, 3), (3, 2), (4, 8)];
    let spec = adamw_spec();
    let mut reference = build("single", 1, shapes, &spec, SEED);
    drive(reference.as_mut(), shapes, 0, 8);

    let mut src = build("fsdp", 4, shapes, &spec, SEED);
    drive(src.as_mut(), shapes, 0, 4);
    let blob = src.export_state();
    let snapshot = src.params().to_vec();
    for (mode, world) in [("fsdp", 2), ("fsdp", 4), ("single", 1)] {
        let mut target = build(mode, world, shapes, &spec, 999);
        target.init_params(&snapshot);
        target.import_state(&blob).unwrap();
        drive(target.as_mut(), shapes, 4, 8);
        assert_params_eq(
            target.params(),
            reference.params(),
            &format!("empty-shard {mode}({world})"),
        );
    }
}

#[test]
fn truncated_canonical_state_fails_loudly() {
    // Chopping the canonical blob anywhere — mid-header, mid-frame, off
    // by one — must produce an error, never a silent partial import.
    let spec = galore_spec();
    let mut src = build("fsdp", 2, SHAPES, &spec, SEED);
    drive(src.as_mut(), SHAPES, 0, 4);
    let blob = src.export_state();
    let snapshot = src.params().to_vec();
    for cut in [8usize, 9, 20, blob.len() / 2, blob.len() - 1] {
        let mut target = build("fsdp", 2, SHAPES, &spec, 999);
        target.init_params(&snapshot);
        assert!(
            target.import_state(&blob[..cut]).is_err(),
            "truncation at {cut}/{} bytes imported silently",
            blob.len()
        );
    }
    // Wrong-optimizer state is rejected by name, not misparsed.
    let mut adamw_engine = build("fsdp", 2, SHAPES, &adamw_spec(), 999);
    adamw_engine.init_params(&snapshot);
    let err = adamw_engine.import_state(&blob).unwrap_err();
    assert!(
        err.contains("galore") && err.contains("adamw"),
        "unhelpful optimizer-mismatch error: {err}"
    );
}

#[test]
fn legacy_v2_state_is_world_locked_with_actionable_error() {
    // v2 checkpoints carried raw FSDP per-rank frames. Same world still
    // resumes bitwise; any other world must fail loudly with a migration
    // hint — NEVER silently reset moments.
    let spec = galore_spec();
    let metas = fixtures::metas_for(SHAPES);
    let mut cluster = FsdpCluster::new(2, metas, spec.clone(), SEED);
    cluster.init_params(&init(SHAPES));
    for t in 0..4u64 {
        cluster.step(t, vec![grads(SHAPES, t); 2], LR);
    }
    let legacy = cluster.export_optimizers();
    let snapshot = cluster.gather_params();
    assert!(
        !CanonicalOptState::sniff(&legacy),
        "legacy framed blob must not carry the canonical header"
    );

    // Same world: the legacy path still restores every rank.
    let mut same = build("fsdp", 2, SHAPES, &spec, 999);
    same.init_params(&snapshot);
    same.import_state(&legacy).unwrap();
    let mut reference = build("single", 1, SHAPES, &spec, SEED);
    drive(reference.as_mut(), SHAPES, 0, 8);
    drive(same.as_mut(), SHAPES, 4, 8);
    assert_params_eq(same.params(), reference.params(), "legacy same-world resume");

    // Different world: loud, actionable failure.
    let mut other = build("fsdp", 4, SHAPES, &spec, 999);
    other.init_params(&snapshot);
    let err = other.import_state(&legacy).unwrap_err();
    assert!(
        err.contains("world=2") && err.contains("--world 2"),
        "unhelpful legacy world-mismatch error: {err}"
    );
}

#[test]
fn v2_checkpoint_migrates_to_canonical_and_unlocks_elastic_resume() {
    // Load a legacy (v2) checkpoint at its original world, re-save — the
    // new file carries canonical (v3+) state and resumes at any world.
    let dir = std::env::temp_dir().join(format!("galore2_resharding_{}", std::process::id()));
    let v2_path = dir.join("legacy_v2.ckpt");
    let migrated_path = dir.join("migrated.ckpt");
    let spec = galore_spec();
    let names: Vec<String> = fixtures::metas_for(SHAPES)
        .iter()
        .map(|m| m.name.clone())
        .collect();

    // Source run writes a v2 checkpoint at step 6 (legacy framed state).
    let mut cluster = FsdpCluster::new(2, fixtures::metas_for(SHAPES), spec.clone(), SEED);
    cluster.init_params(&init(SHAPES));
    for t in 0..6u64 {
        cluster.step(t, vec![grads(SHAPES, t); 2], LR);
    }
    Checkpoint {
        step: 6,
        tokens_seen: None,
        names: names.clone(),
        params: cluster.gather_params(),
        opt_state: cluster.export_optimizers(),
    }
    .save_with_version(&v2_path, LEGACY_VERSION)
    .unwrap();

    // Migrate: load v2, resume at the ORIGINAL world, save → current version.
    let v2 = Checkpoint::load(&v2_path).unwrap();
    let mut migrator = build("fsdp", 2, SHAPES, &spec, 999);
    migrator.init_params(&v2.params);
    migrator.import_state(&v2.opt_state).unwrap();
    Checkpoint {
        step: v2.step,
        tokens_seen: None,
        names,
        params: migrator.params().to_vec(),
        opt_state: migrator.export_state(),
    }
    .save(&migrated_path)
    .unwrap();

    // The migrated file is canonical and resumes at a DIFFERENT world,
    // bitwise on the uninterrupted single-process trajectory.
    let migrated = Checkpoint::load(&migrated_path).unwrap();
    assert!(
        CanonicalOptState::sniff(&migrated.opt_state),
        "migrated checkpoint must carry canonical state"
    );
    let mut reference = build("single", 1, SHAPES, &spec, SEED);
    drive(reference.as_mut(), SHAPES, 0, 12);
    let mut elastic = build("fsdp", 4, SHAPES, &spec, 999);
    elastic.init_params(&migrated.params);
    elastic.import_state(&migrated.opt_state).unwrap();
    drive(elastic.as_mut(), SHAPES, migrated.step, 12);
    assert_params_eq(
        elastic.params(),
        reference.params(),
        "migrated elastic resume",
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn process_transport_checkpoint_resumes_elastically_across_transports() {
    // The canonical form is transport-independent by construction: a
    // checkpoint produced by Unix-socket worker PROCESSES (FSDP world=2)
    // exports the exact bytes a threaded source would, and resumes under
    // threaded FSDP(4), a process-transport DDP(2), and single-process —
    // all bitwise on the uninterrupted single-process trajectory.
    set_worker_binary(env!("CARGO_BIN_EXE_galore2"));
    let spec = galore_spec();
    let mut reference = build("single", 1, SHAPES, &spec, SEED);
    drive(reference.as_mut(), SHAPES, 0, 12);

    let metas = fixtures::metas_for(SHAPES);
    let mut src: Box<dyn TrainEngine> = Box::new(
        FsdpEngine::with_transport(
            2,
            metas.clone(),
            spec.clone(),
            SEED,
            &init(SHAPES),
            TransportKind::Process,
        )
        .unwrap(),
    );
    drive(src.as_mut(), SHAPES, 0, 7);
    let blob = src.export_state();
    let snapshot = src.params().to_vec();

    // Same boundary, threaded source: byte-identical canonical export.
    let mut threaded = build("fsdp", 2, SHAPES, &spec, SEED);
    drive(threaded.as_mut(), SHAPES, 0, 7);
    assert_eq!(
        blob,
        threaded.export_state(),
        "canonical bytes must not depend on the transport"
    );

    let targets: Vec<(&str, Box<dyn TrainEngine>)> = vec![
        ("threads fsdp(4)", build("fsdp", 4, SHAPES, &spec, 999)),
        ("threads single", build("single", 1, SHAPES, &spec, 999)),
        (
            "process ddp(2)",
            Box::new(
                DdpEngine::with_transport(
                    2,
                    metas,
                    spec.clone(),
                    999,
                    &init(SHAPES),
                    TransportKind::Process,
                )
                .unwrap(),
            ),
        ),
    ];
    for (label, mut target) in targets {
        target.init_params(&snapshot);
        target
            .import_state(&blob)
            .unwrap_or_else(|e| panic!("{label} import: {e}"));
        drive(target.as_mut(), SHAPES, 7, 12);
        assert_params_eq(target.params(), reference.params(), label);
    }
}
