#!/usr/bin/env python3
"""Regenerate the committed legacy checkpoint fixtures.

These files pin the LEGACY format gates: `ckpt_v3_adamw.ckpt` is a
version-3 checkpoint (canonical adamw state, no token counter) and
`ckpt_v4_galore.ckpt` is a version-4 checkpoint (canonical galore state in
the PRE-v5 blob layout: dequantized f32 projector behind explicit dims,
leading step counter instead of the STATE_MAGIC2 gate). They are loaded by
`tests/resharding.rs::committed_legacy_fixtures_migrate_to_v5`, which
resumes them, cross-checks the continuation across modes bitwise, and
asserts the re-saved file migrates to the current (v5) format.

The byte layouts mirror rust/src/checkpoint/{mod,canonical}.rs and the
pre-v5 optimizer blob layouts. The parameter/moment VALUES are synthetic
(deterministic, well-formed) — the migration test compares resumed runs
against each other, not against a recorded trajectory, so only structure
and determinism matter. Regenerate with `python3 tests/fixtures/make_fixtures.py`
only if the legacy layouts themselves need re-deriving; do NOT regenerate
to track new state formats — the whole point is that these bytes stay old.
"""
import struct
from pathlib import Path

HERE = Path(__file__).parent

# The resharding test suite's standard shapes: wide, tall, square, bias.
SHAPES = [(8, 16), (16, 8), (6, 6), (1, 12)]

PCG_MULT = 0x2360ED051FC65DA44385DF649FCCF645
MASK128 = (1 << 128) - 1


def pcg64_state(seed: int, stream: int) -> bytes:
    """State bytes of Pcg64::new(seed, stream) (util/rng.rs write_state)."""
    inc = ((stream << 1) | 1) & MASK128
    state = 0
    state = (state * PCG_MULT + inc) & MASK128  # next_u64
    state = (state + seed) & MASK128
    state = (state * PCG_MULT + inc) & MASK128  # next_u64
    return state.to_bytes(16, "little") + inc.to_bytes(16, "little")


def u64(x: int) -> bytes:
    return struct.pack("<Q", x)


def f32s(xs) -> bytes:
    return u64(len(xs)) + b"".join(struct.pack("<f", x) for x in xs)


def param_values(idx: int, n: int):
    return [((idx * 131 + k * 7) % 97) * 0.01 - 0.45 for k in range(n)]


def moment_m(idx: int, n: int):
    return [0.001 * ((idx * 11 + k) % 13 + 1) for k in range(n)]


def moment_v(idx: int, n: int):
    return [0.0001 * ((idx * 5 + k) % 7 + 1) for k in range(n)]


def canonical(name: bytes, blob: bytes) -> bytes:
    out = b"GAL2OPT\x01" + u64(len(name)) + name
    out += u64(0)  # FLAVOR_FULL
    out += u64(len(blob)) + blob
    return out


def checkpoint(version: int, step: int, tokens, opt_state: bytes) -> bytes:
    out = b"GAL2CKPT" + struct.pack("<I", version) + u64(step)
    if version >= 4:
        out += bytes([1 if tokens is not None else 0]) + u64(tokens or 0)
    out += u64(len(SHAPES))
    for idx, (rows, cols) in enumerate(SHAPES):
        name = f"p{idx}".encode()
        out += u64(len(name)) + name + u64(rows) + u64(cols)
        out += b"".join(
            struct.pack("<f", x) for x in param_values(idx, rows * cols)
        )
    out += u64(len(opt_state)) + opt_state
    return out


def adamw_blob(t: int) -> bytes:
    # Pre-v5 == current adamw layout: [t][n] per state [idx][f32s m][f32s v].
    out = u64(t) + u64(len(SHAPES))
    for idx, (rows, cols) in enumerate(SHAPES):
        n = rows * cols
        out += u64(idx) + f32s(moment_m(idx, n)) + f32s(moment_v(idx, n))
    return out


def galore_v1_blob(t: int, rank: int) -> bytes:
    # Pre-v5 galore layout: [t][refreshes][rng 32B][n] then per state
    # [idx][tag]; low-rank: [last_refresh][side][p_rows][p_cols][f32s p]
    # [f32s m][f32s v]; full: [f32s m][f32s v]. The projector is the
    # DEQUANTIZED v1 representation (what this fixture exists to pin).
    out = u64(t) + u64(9)  # t, refreshes (informational)
    out += pcg64_state(21, 0x6A10)  # the resharding suite's SEED
    out += u64(len(SHAPES))
    for idx, (rows, cols) in enumerate(SHAPES):
        out += u64(idx)
        if min(rows, cols) < 2 or rank > min(rows, cols):
            n = rows * cols
            out += u64(0) + f32s(moment_m(idx, n)) + f32s(moment_v(idx, n))
            continue
        out += u64(1)
        out += u64(3)  # last_refresh (t=3 with update_freq 3)
        side = 0 if rows <= cols else 1  # Left for wide, Right for tall
        out += u64(side)
        d = rows if side == 0 else cols
        out += u64(d) + u64(rank)
        out += f32s(param_values(idx + 40, d * rank))
        lm, ln = (rank, cols) if side == 0 else (rows, rank)
        out += f32s(moment_m(idx, lm * ln)) + f32s(moment_v(idx, lm * ln))
    return out


def main():
    v3 = checkpoint(3, 4, None, canonical(b"adamw", adamw_blob(3)))
    (HERE / "ckpt_v3_adamw.ckpt").write_bytes(v3)
    v4 = checkpoint(4, 6, 12_288, canonical(b"galore", galore_v1_blob(5, 4)))
    (HERE / "ckpt_v4_galore.ckpt").write_bytes(v4)
    print(f"ckpt_v3_adamw.ckpt: {len(v3)} bytes")
    print(f"ckpt_v4_galore.ckpt: {len(v4)} bytes")


if __name__ == "__main__":
    main()
