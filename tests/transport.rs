//! Cross-transport parity: Unix-socket worker PROCESSES are bitwise equal
//! to in-process worker THREADS — and to single-process runs — plus the
//! failure modes that make the process transport operable.
//!
//! The claims pinned here:
//!
//! * FSDP at worlds 1/2/4 and DDP at world 2, for galore and adamw, over
//!   `TransportKind::Process` produce parameters bitwise identical to
//!   `TransportKind::Threads` and to `SingleEngine` (identical per-rank
//!   microbatches make power-of-two-world averages exact — same
//!   construction as tests/resharding.rs);
//! * the shared-memory data plane (`--shm`, default on) is bitwise
//!   identical to the socket plane, and with it on gradient collectives
//!   put exactly ZERO payload bytes on the comm sockets;
//! * per-rank telemetry (memory reports, traffic counters) and the
//!   optimizer-state frame protocol round-trip through the sockets;
//! * a worker that crashes during setup is a spawn **error**; one that
//!   crashes mid-step is a prompt coordinator **panic** — never a hang —
//!   and the rendezvous socket is cleaned up either way;
//! * a missing worker binary fails with an actionable message.
//!
//! The suite serializes on a mutex: the crash-injection hooks
//! (`set_test_crash_hooks`, injected into worker environments at spawn)
//! and the worker-binary override are process-global. CI runs this suite
//! with `GALORE2_DENY_SKIP=1`; no test here needs compiled artifacts, and
//! the fixtures' skip guard keeps it that way if one ever does.

use galore2::dist::{
    set_shm_enabled, set_test_crash_hooks, set_worker_binary, DdpCluster, FsdpCluster,
    OptimizerSpec, TransportKind, WORKER_BIN_ENV,
};
use galore2::optim::{AdamCfg, GaLoreCfg, ProjectionKind};
use galore2::tensor::Matrix;
use galore2::testing::fixtures;
use galore2::train::{DdpEngine, FsdpEngine, SingleEngine, TrainEngine};
use std::sync::Mutex;

static ENV_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Point the process transport at the real galore2 binary — the test
/// harness binary this code runs in has no `worker` subcommand. Uses the
/// thread-safe programmatic override, NOT `std::env::set_var` (setenv
/// while sibling tests getenv is a data race).
fn use_real_worker_bin() {
    set_worker_binary(env!("CARGO_BIN_EXE_galore2"));
}

/// Wide, tall, square, and bias-like (unprojected) parameters.
const SHAPES: &[(usize, usize)] = &[(8, 16), (16, 8), (6, 6), (1, 12)];
const LR: f32 = 0.03;
const SEED: u64 = 21;
const STEPS: u64 = 7;

fn grads(t: u64) -> Vec<Matrix> {
    // Rank 0's stream for EVERY rank: identical microbatches keep runs
    // comparable across world sizes (power-of-two averages are exact).
    fixtures::rank_grads(SHAPES, t, 0, 0.1)
}

fn init() -> Vec<Matrix> {
    fixtures::randn_set(SHAPES, 0.5, 7, 0)
}

fn galore_spec() -> OptimizerSpec {
    OptimizerSpec::GaLore {
        galore: GaLoreCfg {
            rank: 4,
            update_freq: 3,
            alpha: 1.0,
            projection: ProjectionKind::RandSvd,
            ..GaLoreCfg::default()
        },
        adam: AdamCfg::default(),
    }
}

fn adamw_spec() -> OptimizerSpec {
    OptimizerSpec::AdamW(AdamCfg::default())
}

fn fsdp(world: usize, spec: &OptimizerSpec, transport: TransportKind) -> Box<dyn TrainEngine> {
    Box::new(
        FsdpEngine::with_transport(
            world,
            fixtures::metas_for(SHAPES),
            spec.clone(),
            SEED,
            &init(),
            transport,
        )
        .unwrap_or_else(|e| panic!("fsdp({world}) over {}: {e}", transport.name())),
    )
}

fn ddp(world: usize, spec: &OptimizerSpec, transport: TransportKind) -> Box<dyn TrainEngine> {
    Box::new(
        DdpEngine::with_transport(
            world,
            fixtures::metas_for(SHAPES),
            spec.clone(),
            SEED,
            &init(),
            transport,
        )
        .unwrap_or_else(|e| panic!("ddp({world}) over {}: {e}", transport.name())),
    )
}

fn run(mut engine: Box<dyn TrainEngine>) -> Vec<Matrix> {
    let world = engine.world();
    for t in 0..STEPS {
        engine.step(t, vec![grads(t); world], LR);
    }
    engine.params().to_vec()
}

fn assert_params_eq(got: &[Matrix], want: &[Matrix], label: &str) {
    assert_eq!(got.len(), want.len(), "{label}: param count");
    for (idx, (a, b)) in got.iter().zip(want).enumerate() {
        assert_eq!(a.data, b.data, "{label}: param {idx} diverged");
    }
}

#[test]
fn fsdp_process_bitwise_equals_threads_and_single() {
    let _g = lock();
    use_real_worker_bin();
    for spec in [galore_spec(), adamw_spec()] {
        let single = run(Box::new(SingleEngine::new(&spec, SEED, None, init()).unwrap()));
        for world in [1usize, 2, 4] {
            let threads = run(fsdp(world, &spec, TransportKind::Threads));
            let process = run(fsdp(world, &spec, TransportKind::Process));
            assert_params_eq(
                &process,
                &threads,
                &format!("{} fsdp({world}) process vs threads", spec.name()),
            );
            assert_params_eq(
                &process,
                &single,
                &format!("{} fsdp({world}) process vs single", spec.name()),
            );
        }
    }
}

#[test]
fn ddp_process_bitwise_equals_threads_and_single() {
    let _g = lock();
    use_real_worker_bin();
    for spec in [galore_spec(), adamw_spec()] {
        let single = run(Box::new(SingleEngine::new(&spec, SEED, None, init()).unwrap()));
        let threads = run(ddp(2, &spec, TransportKind::Threads));
        let process = run(ddp(2, &spec, TransportKind::Process));
        assert_params_eq(
            &process,
            &threads,
            &format!("{} ddp(2) process vs threads", spec.name()),
        );
        // DDP gathers through the replica-equality assertion, so this also
        // proves socket replicas stay in lockstep.
        assert_params_eq(
            &process,
            &single,
            &format!("{} ddp(2) process vs single", spec.name()),
        );
    }
}

/// The tentpole parity pin: with the shared-memory data plane ON (the
/// default) the process transport stays bitwise identical to the socket
/// plane — and, through the sibling suites above, to threads and single —
/// for FSDP at worlds 1/2/4 and DDP at world 2, galore and adamw. STEPS=7
/// with update_freq=3 crosses two subspace refreshes, so the leader
/// broadcast rides both planes too.
#[test]
fn shm_plane_bitwise_equals_socket_plane() {
    let _g = lock();
    use_real_worker_bin();
    for spec in [galore_spec(), adamw_spec()] {
        for world in [1usize, 2, 4] {
            set_shm_enabled(true);
            let on = run(fsdp(world, &spec, TransportKind::Process));
            set_shm_enabled(false);
            let off = run(fsdp(world, &spec, TransportKind::Process));
            set_shm_enabled(true);
            assert_params_eq(
                &on,
                &off,
                &format!("{} fsdp({world}) shm vs sockets", spec.name()),
            );
        }
        set_shm_enabled(true);
        let on = run(ddp(2, &spec, TransportKind::Process));
        set_shm_enabled(false);
        let off = run(ddp(2, &spec, TransportKind::Process));
        set_shm_enabled(true);
        assert_params_eq(&on, &off, &format!("{} ddp(2) shm vs sockets", spec.name()));
    }
}

/// The zero-copy pin: with shm on, gradient collectives put EXACTLY zero
/// payload bytes on the comm sockets (the per-rank counters are measured
/// inside the worker processes, which each own one transport); with shm
/// off, the same run moves every payload byte over the sockets and none
/// through the slot table.
#[test]
fn shm_plane_puts_zero_payload_bytes_on_the_socket() {
    let _g = lock();
    use_real_worker_bin();
    let world = 2;
    let mut drive = |shm: bool| {
        set_shm_enabled(shm);
        let mut cluster = FsdpCluster::with_transport(
            world,
            fixtures::metas_for(SHAPES),
            galore_spec(),
            SEED,
            TransportKind::Process,
        )
        .unwrap();
        cluster.init_params(&init());
        for t in 0..4 {
            cluster.step(t, vec![grads(t); world], LR);
        }
        let reports = cluster.memory_reports();
        let traffic = cluster
            .last_step_traffic()
            .expect("distributed steps must report traffic");
        (reports, traffic)
    };

    let (reports, traffic) = drive(true);
    for r in &reports {
        assert_eq!(
            r.socket_bytes, 0,
            "rank {}: shm-on collectives must move ZERO payload bytes over the socket",
            r.rank
        );
        assert!(
            r.shm_bytes > 0,
            "rank {}: shm-on payloads must flow through the slot table",
            r.rank
        );
    }
    assert_eq!(traffic.socket_bytes, 0, "per-step socket payload, shm on");
    assert!(traffic.shm_bytes > 0, "per-step shm payload, shm on");

    let (reports, traffic) = drive(false);
    set_shm_enabled(true);
    for r in &reports {
        assert!(
            r.socket_bytes > 0,
            "rank {}: shm-off payloads ride the sockets",
            r.rank
        );
        assert_eq!(
            r.shm_bytes, 0,
            "rank {}: shm-off runs must not touch the slot table",
            r.rank
        );
    }
    assert!(traffic.socket_bytes > 0, "per-step socket payload, shm off");
    assert_eq!(traffic.shm_bytes, 0, "per-step shm payload, shm off");
}

#[test]
fn process_cluster_telemetry_and_state_frames_roundtrip() {
    let _g = lock();
    use_real_worker_bin();
    let world = 2;
    let mut cluster = FsdpCluster::with_transport(
        world,
        fixtures::metas_for(SHAPES),
        galore_spec(),
        SEED,
        TransportKind::Process,
    )
    .unwrap();
    assert_eq!(cluster.transport(), TransportKind::Process);
    cluster.init_params(&init());
    for t in 0..4 {
        cluster.step(t, vec![grads(t); world], LR);
    }
    // Telemetry computed IN the worker processes crosses back intact.
    let reports = cluster.memory_reports();
    assert_eq!(reports.len(), world);
    let mut threaded = FsdpCluster::with_transport(
        world,
        fixtures::metas_for(SHAPES),
        galore_spec(),
        SEED,
        TransportKind::Threads,
    )
    .unwrap();
    threaded.init_params(&init());
    for t in 0..4 {
        threaded.step(t, vec![grads(t); world], LR);
    }
    for (rep, want) in reports.iter().zip(threaded.memory_reports()) {
        assert_eq!(rep.rank, want.rank);
        assert_eq!(rep.param_shard_bytes, want.param_shard_bytes);
        assert_eq!(rep.optimizer_bytes, want.optimizer_bytes);
        assert_eq!(
            rep.traffic_elems, want.traffic_elems,
            "rank {}: traffic cost model must not depend on the transport",
            rep.rank
        );
    }
    // The optimizer-state frame protocol round-trips over the sockets and
    // matches the threaded cluster byte for byte.
    let frames = cluster.export_frames();
    assert_eq!(frames, threaded.export_frames(), "state frames differ");
    cluster.import_frames(frames).unwrap();
    assert_params_eq(
        &cluster.gather_params(),
        &threaded.gather_params(),
        "post-roundtrip gather",
    );
}

#[test]
fn rendezvous_socket_is_unlinked() {
    let _g = lock();
    use_real_worker_bin();
    let cluster = DdpCluster::with_transport(
        2,
        fixtures::metas_for(SHAPES),
        adamw_spec(),
        SEED,
        TransportKind::Process,
    )
    .unwrap();
    let path = cluster
        .socket_path()
        .expect("process cluster records its socket path")
        .to_path_buf();
    assert!(
        !path.exists(),
        "rendezvous socket {} must be unlinked once the world is connected",
        path.display()
    );
    // The shm slot table is unlinked with it: workers keep the file alive
    // through their open fds (memfd-like semantics), so no name persists.
    let table = path.with_file_name("slots.shm");
    assert!(
        !table.exists(),
        "shm slot table {} must be unlinked once the world is connected",
        table.display()
    );
    drop(cluster);
    assert!(!path.exists(), "socket file resurrected by Drop");
}

#[test]
fn worker_crash_during_setup_is_an_error_not_a_hang() {
    let _g = lock();
    use_real_worker_bin();
    // Persistent setup crash (u32::MAX credits): the rank dies on EVERY
    // respawn attempt, so the retry budget must run out and the final
    // error must still name the culprit.
    set_test_crash_hooks(Some((1, u32::MAX)), None);
    let result = FsdpEngine::with_transport(
        2,
        fixtures::metas_for(SHAPES),
        galore_spec(),
        SEED,
        &init(),
        TransportKind::Process,
    );
    set_test_crash_hooks(None, None);
    let err = result.err().expect("a worker dying in setup must fail the spawn");
    assert!(
        err.contains("rank 1"),
        "error must name the dead rank: {err}"
    );
}

#[test]
fn worker_crash_mid_step_panics_promptly_without_hanging() {
    let _g = lock();
    use_real_worker_bin();
    set_test_crash_hooks(None, Some((0, 0)));
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let mut cluster = FsdpCluster::with_transport(
            2,
            fixtures::metas_for(SHAPES),
            adamw_spec(),
            SEED,
            TransportKind::Process,
        )
        .unwrap();
        cluster.init_params(&init());
        // Rank 0 exits on this command; rank 1 is left inside a
        // collective. The relay must unblock it and the coordinator must
        // panic (caught here) instead of waiting forever.
        cluster.step(0, vec![grads(0); 2], LR);
    }));
    set_test_crash_hooks(None, None);
    assert!(
        result.is_err(),
        "a worker process dying mid-step must surface as a coordinator error"
    );
}

#[test]
fn missing_worker_binary_fails_with_actionable_error() {
    let _g = lock();
    set_worker_binary("/nonexistent/galore2-not-here");
    let result = DdpCluster::with_transport(
        2,
        fixtures::metas_for(SHAPES),
        adamw_spec(),
        SEED,
        TransportKind::Process,
    );
    use_real_worker_bin();
    let err = result.err().expect("missing worker binary must fail the spawn");
    assert!(
        err.contains(WORKER_BIN_ENV),
        "error must mention the override knob: {err}"
    );
}
