//! Fault-tolerant elastic training: kill a worker rank mid-run and the
//! supervised loop must snapshot → re-shard → continue, ending **bitwise
//! identical** to an uninterrupted run launched at the post-recovery
//! world from the same snapshot step.
//!
//! The claims pinned here:
//!
//! * an injected rank crash at step N — both transports, FSDP and DDP,
//!   galore + adamw + qgalore — recovers automatically under
//!   `--on-failure respawn|shrink`, with final parameters AND canonical
//!   optimizer bytes equal to a clean reference: original world to the
//!   snapshot step, canonical export/import into the post-recovery
//!   world, then the remaining steps;
//! * the exact `tokens_seen` counter survives the rollback (the
//!   recovered run re-counts replayed tokens, so the total is what an
//!   uninterrupted run would report);
//! * `--on-failure abort` still fails promptly with the dead rank named
//!   — no hang — on both transports, as do an exhausted recovery budget
//!   and a crash before the first snapshot;
//! * a transient spawn-time crash is retried within `[dist]
//!   spawn_retries`; a persistent one fails naming the rank and the
//!   attempt count;
//! * repeated kill→recover cycles leak no worker threads (thread
//!   transport) and no rendezvous socket directories (process
//!   transport), with the persistent compute pool shut down on both
//!   sides of the measurement so cluster threads are counted exactly;
//! * `parallel::shutdown_pool` joins every pool worker (OS thread count
//!   returns to baseline) and the pool restarts lazily afterwards.
//!
//! Fixtures mirror tests/transport.rs: every rank feeds rank 0's
//! gradient stream, so shard averages are exact and runs stay
//! comparable across world sizes. The suite serializes on a mutex
//! because the crash hooks and worker-binary override are
//! process-global. CI runs it with `GALORE2_DENY_SKIP=1`; nothing here
//! needs compiled artifacts.

use galore2::dist::{
    set_test_crash_hooks, set_test_shm_fail, set_worker_binary, OptimizerSpec, TransportKind,
};
use galore2::optim::{AdamCfg, GaLoreCfg, ProjectionKind};
use galore2::tensor::Matrix;
use galore2::testing::fixtures;
use galore2::train::{
    DdpEngine, FsdpEngine, ImportOpts, OnFailure, RecoveryPolicy, StepEvent, Supervised,
    Supervisor, TrainEngine,
};
use std::sync::Mutex;

static ENV_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn use_real_worker_bin() {
    set_worker_binary(env!("CARGO_BIN_EXE_galore2"));
}

/// Wide, tall, square, and bias-like (unprojected) parameters.
const SHAPES: &[(usize, usize)] = &[(8, 16), (16, 8), (6, 6), (1, 12)];
const LR: f32 = 0.03;
const SEED: u64 = 21;
const STEPS: u64 = 9;
const SNAP_EVERY: u64 = 4;
const TOKENS_PER_STEP: u64 = 64;

fn grads(t: u64) -> Vec<Matrix> {
    fixtures::rank_grads(SHAPES, t, 0, 0.1)
}

fn init() -> Vec<Matrix> {
    fixtures::randn_set(SHAPES, 0.5, 7, 0)
}

fn galore_spec() -> OptimizerSpec {
    OptimizerSpec::GaLore {
        galore: GaLoreCfg {
            rank: 4,
            update_freq: 3,
            alpha: 1.0,
            projection: ProjectionKind::RandSvd,
            ..GaLoreCfg::default()
        },
        adam: AdamCfg::default(),
    }
}

fn adamw_spec() -> OptimizerSpec {
    OptimizerSpec::AdamW(AdamCfg::default())
}

fn qgalore_spec() -> OptimizerSpec {
    OptimizerSpec::QGaLore {
        galore: GaLoreCfg {
            rank: 4,
            update_freq: 3,
            alpha: 1.0,
            projection: ProjectionKind::RandSvd,
            ..GaLoreCfg::default()
        },
        adam: AdamCfg::default(),
        similarity_threshold: 0.9,
    }
}

#[derive(Clone, Copy)]
enum Mode {
    Fsdp,
    Ddp,
}

impl Mode {
    fn name(self) -> &'static str {
        match self {
            Mode::Fsdp => "fsdp",
            Mode::Ddp => "ddp",
        }
    }
}

fn build(
    mode: Mode,
    world: usize,
    spec: &OptimizerSpec,
    transport: TransportKind,
) -> Result<Box<dyn TrainEngine>, String> {
    Ok(match mode {
        Mode::Fsdp => Box::new(FsdpEngine::with_transport(
            world,
            fixtures::metas_for(SHAPES),
            spec.clone(),
            SEED,
            &init(),
            transport,
        )?) as Box<dyn TrainEngine>,
        Mode::Ddp => Box::new(DdpEngine::with_transport(
            world,
            fixtures::metas_for(SHAPES),
            spec.clone(),
            SEED,
            &init(),
            transport,
        )?),
    })
}

fn factory(
    mode: Mode,
    spec: &OptimizerSpec,
    transport: TransportKind,
) -> galore2::train::EngineFactory {
    let spec = spec.clone();
    Box::new(move |world| build(mode, world, &spec, transport))
}

struct RunOutcome {
    params: Vec<Matrix>,
    opt_state: Vec<u8>,
    world: usize,
    recoveries: usize,
}

/// Drive a supervised run to `STEPS` with rank `crash.0` scheduled to die
/// at step `crash.1` (the plan is consumed by the FIRST world spawned, so
/// the rebuilt world comes up clean). Mimics the trainer's loop:
/// snapshot at the top of the step, count tokens, rewind on recovery.
fn supervised_run(
    mode: Mode,
    spec: &OptimizerSpec,
    transport: TransportKind,
    world: usize,
    on_failure: OnFailure,
    crash: (usize, u64),
) -> Result<RunOutcome, String> {
    set_test_crash_hooks(None, Some(crash));
    let engine = build(mode, world, spec, transport);
    // The spawn above consumed the step plan; clear the hooks so a
    // failure in `build` can't leak the plan into later tests either.
    set_test_crash_hooks(None, None);
    let mut sup = Supervisor::new(
        engine?,
        factory(mode, spec, transport),
        RecoveryPolicy {
            on_failure,
            snapshot_every: SNAP_EVERY,
            max_recoveries: 3,
        },
        ImportOpts::default(),
    );
    let mut tokens: u64 = 0;
    let mut t: u64 = 0;
    while t < STEPS {
        sup.maybe_snapshot(t, tokens);
        tokens += TOKENS_PER_STEP;
        let w = sup.engine().world();
        match sup.step(t, vec![grads(t); w], LR)? {
            Supervised::Stepped => t += 1,
            Supervised::Recovered {
                resume_step,
                tokens_seen,
                new_world,
                events,
            } => {
                assert!(
                    matches!(events.first(), Some(StepEvent::WorkerLost { .. })),
                    "recovery must lead with WorkerLost"
                );
                assert!(
                    matches!(events.last(), Some(StepEvent::RecoveryComplete { .. })),
                    "recovery must end with RecoveryComplete"
                );
                assert_eq!(new_world, sup.engine().world(), "reported world mismatch");
                t = resume_step;
                tokens = tokens_seen;
            }
        }
    }
    assert_eq!(
        tokens,
        STEPS * TOKENS_PER_STEP,
        "token counter must survive the rollback exactly"
    );
    Ok(RunOutcome {
        params: sup.engine().params().to_vec(),
        opt_state: sup.engine().export_state(),
        world: sup.engine().world(),
        recoveries: sup.recoveries(),
    })
}

/// The uninterrupted reference a recovered run must match bitwise: run
/// the ORIGINAL world to the snapshot step, export canonical state, then
/// import into a fresh engine at the POST-recovery world and finish the
/// schedule there. Always over threads — canonical bytes are
/// transport-independent (pinned in tests/transport.rs), so this also
/// cross-checks the process-transport recoveries against threads.
fn reference_run(
    mode: Mode,
    spec: &OptimizerSpec,
    start_world: usize,
    end_world: usize,
    crash_step: u64,
) -> (Vec<Matrix>, Vec<u8>) {
    // Snapshots land at the top of every SNAP_EVERY-th step, so a crash
    // at `crash_step` restores the largest cadence multiple <= it.
    let snap_step = crash_step - crash_step % SNAP_EVERY;
    let mut first = build(mode, start_world, spec, TransportKind::Threads).unwrap();
    for t in 0..snap_step {
        first.step(t, vec![grads(t); start_world], LR);
    }
    let params = first.params().to_vec();
    let state = first.export_state();
    drop(first);
    let mut second = build(mode, end_world, spec, TransportKind::Threads).unwrap();
    second.init_params(&params);
    second
        .import_state_with(&state, ImportOpts::default())
        .unwrap();
    for t in snap_step..STEPS {
        second.step(t, vec![grads(t); end_world], LR);
    }
    (second.params().to_vec(), second.export_state())
}

fn assert_bitwise(got: &RunOutcome, want: &(Vec<Matrix>, Vec<u8>), label: &str) {
    assert_eq!(got.params.len(), want.0.len(), "{label}: param count");
    for (idx, (a, b)) in got.params.iter().zip(&want.0).enumerate() {
        assert_eq!(a.data, b.data, "{label}: param {idx} diverged");
    }
    assert_eq!(
        got.opt_state, want.1,
        "{label}: canonical optimizer bytes diverged"
    );
}

/// One recover-and-compare case: crash `rank` at `step`, expect exactly
/// one recovery landing on `end_world`, bitwise equal to the reference.
fn check_recovery(
    mode: Mode,
    spec: &OptimizerSpec,
    transport: TransportKind,
    start_world: usize,
    on_failure: OnFailure,
    crash: (usize, u64),
) {
    let end_world = match on_failure {
        OnFailure::Respawn => start_world,
        OnFailure::Shrink => (start_world - 1).max(1),
        OnFailure::Abort => unreachable!("recovery cases never use abort"),
    };
    let label = format!(
        "{} {} world {start_world}→{end_world} ({}, rank {} dies at step {})",
        spec.name(),
        mode.name(),
        on_failure.name(),
        crash.0,
        crash.1
    );
    let out = supervised_run(mode, spec, transport, start_world, on_failure, crash)
        .unwrap_or_else(|e| panic!("{label}: supervised run failed: {e}"));
    assert_eq!(out.recoveries, 1, "{label}: expected exactly one recovery");
    assert_eq!(out.world, end_world, "{label}: wrong post-recovery world");
    let want = reference_run(mode, spec, start_world, end_world, crash.1);
    assert_bitwise(&out, &want, &label);
}

#[test]
fn threads_fsdp_galore_respawn_recovers_bitwise() {
    let _g = lock();
    check_recovery(
        Mode::Fsdp,
        &galore_spec(),
        TransportKind::Threads,
        2,
        OnFailure::Respawn,
        (1, 5),
    );
}

#[test]
fn threads_fsdp_adamw_shrink_recovers_bitwise() {
    let _g = lock();
    // World 3 → 2: exercises a non-power-of-two source world and a real
    // re-shard (different shard boundaries on both sides).
    check_recovery(
        Mode::Fsdp,
        &adamw_spec(),
        TransportKind::Threads,
        3,
        OnFailure::Shrink,
        (2, 6),
    );
}

#[test]
fn threads_fsdp_qgalore_shrink_recovers_bitwise() {
    let _g = lock();
    // Crash at step 3 with cadence 4: the only restore point is the
    // step-0 snapshot, so the WHOLE run replays on the shrunken world.
    // Q-GaLore's quantized-projector state rides the elastic galore
    // codec, so the re-shard stays exact (adam8bit's world-locked shards
    // would not — that combination is rejected at import, not here).
    check_recovery(
        Mode::Fsdp,
        &qgalore_spec(),
        TransportKind::Threads,
        2,
        OnFailure::Shrink,
        (0, 3),
    );
}

#[test]
fn threads_ddp_galore_shrink_recovers_bitwise() {
    let _g = lock();
    check_recovery(
        Mode::Ddp,
        &galore_spec(),
        TransportKind::Threads,
        2,
        OnFailure::Shrink,
        (1, 5),
    );
}

#[test]
fn process_fsdp_galore_respawn_recovers_bitwise() {
    let _g = lock();
    use_real_worker_bin();
    let dirs_before = worker_tmp_dirs();
    let fds_before = open_fds();
    check_recovery(
        Mode::Fsdp,
        &galore_spec(),
        TransportKind::Process,
        2,
        OnFailure::Respawn,
        (1, 5),
    );
    assert_eq!(
        worker_tmp_dirs(),
        dirs_before,
        "kill→recover must not leak rendezvous socket directories"
    );
    // Each cluster the recovery built and tore down opened sockets plus
    // (shm default on) a slot-table fd per side; all of them must be
    // closed again once both the dead and the rebuilt cluster are gone.
    // Small slack for harness churn (e.g. a lazily opened urandom fd) —
    // a leaked slot table or stream would add several fds per cycle.
    let fds_after = open_fds();
    assert!(
        fds_after <= fds_before + 2,
        "fds leaked across kill→recover (slot table or stream not closed): \
         {fds_before} → {fds_after}"
    );
}

#[test]
fn process_fsdp_adamw_shrink_recovers_bitwise() {
    let _g = lock();
    use_real_worker_bin();
    // Rank 0 (the relay's first socket) dies before the first cadence
    // boundary: restore from the step-0 snapshot onto a single rank.
    check_recovery(
        Mode::Fsdp,
        &adamw_spec(),
        TransportKind::Process,
        2,
        OnFailure::Shrink,
        (0, 2),
    );
}

#[test]
fn process_ddp_adamw_respawn_recovers_bitwise() {
    let _g = lock();
    use_real_worker_bin();
    // Crash at step 4, right AFTER the step-4 snapshot was captured: the
    // rollback distance is zero steps, the smallest possible replay.
    check_recovery(
        Mode::Ddp,
        &adamw_spec(),
        TransportKind::Process,
        2,
        OnFailure::Respawn,
        (1, 4),
    );
}

#[test]
fn abort_fails_promptly_naming_rank_threads() {
    let _g = lock();
    let err = supervised_run(
        Mode::Fsdp,
        &adamw_spec(),
        TransportKind::Threads,
        2,
        OnFailure::Abort,
        (1, 2),
    )
    .err()
    .expect("abort policy must fail the run");
    assert!(err.contains("rank 1"), "error must name the dead rank: {err}");
    assert!(
        err.contains("--on-failure abort"),
        "error must point at the policy knob: {err}"
    );
}

#[test]
fn abort_fails_promptly_naming_rank_process() {
    let _g = lock();
    use_real_worker_bin();
    let err = supervised_run(
        Mode::Ddp,
        &adamw_spec(),
        TransportKind::Process,
        2,
        OnFailure::Abort,
        (1, 2),
    )
    .err()
    .expect("abort policy must fail the run");
    assert!(err.contains("rank 1"), "error must name the dead rank: {err}");
}

#[test]
fn exhausted_budget_and_missing_snapshot_fail_with_rank_named() {
    let _g = lock();
    let spec = adamw_spec();
    // Budget of zero: the very first (otherwise survivable) loss fails.
    set_test_crash_hooks(None, Some((0, 1)));
    let engine = build(Mode::Fsdp, 2, &spec, TransportKind::Threads);
    set_test_crash_hooks(None, None);
    let mut sup = Supervisor::new(
        engine.unwrap(),
        factory(Mode::Fsdp, &spec, TransportKind::Threads),
        RecoveryPolicy {
            on_failure: OnFailure::Respawn,
            snapshot_every: 1,
            max_recoveries: 0,
        },
        ImportOpts::default(),
    );
    sup.maybe_snapshot(0, 0);
    assert!(matches!(
        sup.step(0, vec![grads(0); 2], LR),
        Ok(Supervised::Stepped)
    ));
    sup.maybe_snapshot(1, TOKENS_PER_STEP);
    let err = sup
        .step(1, vec![grads(1); 2], LR)
        .err()
        .expect("budget of 0 must turn the loss into a failure");
    assert!(err.contains("rank 0"), "error must name the dead rank: {err}");
    assert!(
        err.contains("recovery budget exhausted"),
        "error must say WHY recovery was refused: {err}"
    );
    drop(sup);
    // A crash before any snapshot exists is equally unrecoverable.
    set_test_crash_hooks(None, Some((1, 0)));
    let engine = build(Mode::Fsdp, 2, &spec, TransportKind::Threads);
    set_test_crash_hooks(None, None);
    let mut sup = Supervisor::new(
        engine.unwrap(),
        factory(Mode::Fsdp, &spec, TransportKind::Threads),
        RecoveryPolicy {
            on_failure: OnFailure::Respawn,
            snapshot_every: SNAP_EVERY,
            max_recoveries: 3,
        },
        ImportOpts::default(),
    );
    // Deliberately no maybe_snapshot.
    let err = sup
        .step(0, vec![grads(0); 2], LR)
        .err()
        .expect("a loss before the first snapshot must fail");
    assert!(err.contains("rank 1"), "error must name the dead rank: {err}");
    assert!(
        err.contains("no snapshot captured yet"),
        "error must say WHY recovery was refused: {err}"
    );
}

#[test]
fn transient_spawn_crash_is_retried_within_budget() {
    let _g = lock();
    use_real_worker_bin();
    // ONE setup-crash credit: rank 1's first process dies during setup,
    // its respawn comes up clean, and the cluster must still reach a
    // bitwise-correct result (default [dist] spawn_retries = 2).
    set_test_crash_hooks(Some((1, 1)), None);
    let result = build(Mode::Fsdp, 2, &galore_spec(), TransportKind::Process);
    set_test_crash_hooks(None, None);
    let mut engine = result.expect("one transient setup crash must be retried, not fatal");
    for t in 0..3 {
        engine.step(t, vec![grads(t); 2], LR);
    }
    let mut want = build(Mode::Fsdp, 2, &galore_spec(), TransportKind::Threads).unwrap();
    for t in 0..3 {
        want.step(t, vec![grads(t); 2], LR);
    }
    for (idx, (a, b)) in engine.params().iter().zip(want.params()).enumerate() {
        assert_eq!(a.data, b.data, "param {idx} diverged after a retried spawn");
    }
}

#[test]
fn persistent_spawn_crash_names_rank_and_attempts() {
    let _g = lock();
    use_real_worker_bin();
    set_test_crash_hooks(Some((1, u32::MAX)), None);
    let result = build(Mode::Fsdp, 2, &galore_spec(), TransportKind::Process);
    set_test_crash_hooks(None, None);
    let err = result
        .err()
        .expect("a rank that dies on every spawn attempt must fail the build");
    assert!(err.contains("rank 1"), "error must name the dead rank: {err}");
    assert!(
        err.contains("attempts") && err.contains("spawn_retries"),
        "error must report the attempt count and the retry knob: {err}"
    );
}

#[test]
fn shm_handshake_failure_during_setup_errors_naming_rank_never_hangs() {
    let _g = lock();
    use_real_worker_bin();
    // The shm data plane adds a step to worker setup: map the slot table
    // the setup frame declared. Rank 1's open fails on EVERY spawn
    // attempt (persistent credits), so each respawn burns a retry until
    // the coordinator gives up. The failure lands BEFORE the rank's
    // Ready, i.e. inside the handshake — the coordinator must surface a
    // named error through the spawn-retry path, never hang a collective
    // waiting for a rank that will not come up.
    set_test_shm_fail(Some((1, u32::MAX)));
    let result = build(Mode::Fsdp, 2, &galore_spec(), TransportKind::Process);
    set_test_shm_fail(None);
    let err = result
        .err()
        .expect("a rank whose shm handshake always fails must fail the build");
    assert!(err.contains("rank 1"), "error must name the failing rank: {err}");

    // One credit: the first spawn of rank 1 fails its shm handshake, the
    // respawn maps the (still-linked) slot table cleanly, and the
    // cluster trains bitwise-identically to the thread transport.
    set_test_shm_fail(Some((1, 1)));
    let result = build(Mode::Fsdp, 2, &galore_spec(), TransportKind::Process);
    set_test_shm_fail(None);
    let mut engine = result.expect("one transient shm-handshake failure must be retried");
    for t in 0..3 {
        engine.step(t, vec![grads(t); 2], LR);
    }
    let mut want = build(Mode::Fsdp, 2, &galore_spec(), TransportKind::Threads).unwrap();
    for t in 0..3 {
        want.step(t, vec![grads(t); 2], LR);
    }
    for (idx, (a, b)) in engine.params().iter().zip(want.params()).enumerate() {
        assert_eq!(a.data, b.data, "param {idx} diverged after a retried shm handshake");
    }
}

fn thread_count() -> usize {
    std::fs::read_dir("/proc/self/task")
        .map(|d| d.count())
        .unwrap_or(0)
}

/// Open file descriptors of this process (entries in `/proc/self/fd`).
/// The `read_dir` handle itself is open during both sides of a bracket,
/// so before/after deltas are comparable.
fn open_fds() -> usize {
    std::fs::read_dir("/proc/self/fd")
        .map(|d| d.count())
        .unwrap_or(0)
}

fn worker_tmp_dirs() -> usize {
    let prefix = format!("g2w-{}-", std::process::id());
    std::fs::read_dir(std::env::temp_dir())
        .map(|d| {
            d.filter_map(|e| e.ok())
                .filter(|e| e.file_name().to_string_lossy().starts_with(&prefix))
                .count()
        })
        .unwrap_or(0)
}

#[test]
fn repeated_kill_recover_cycles_leak_no_threads() {
    let _g = lock();
    let spec = adamw_spec();
    // Park no compute workers on either side of the measurement: the
    // persistent pool is process-global and grows on demand, so joining
    // it here pins the count to CLUSTER threads only — a leaked worker
    // can't hide behind pool growth, and parked pool workers from other
    // tests can't inflate the baseline.
    galore2::parallel::shutdown_pool();
    let baseline = thread_count();
    for cycle in 0..3 {
        let out = supervised_run(
            Mode::Fsdp,
            &spec,
            TransportKind::Threads,
            2,
            OnFailure::Respawn,
            (1, 5),
        )
        .unwrap_or_else(|e| panic!("cycle {cycle}: {e}"));
        assert_eq!(out.recoveries, 1, "cycle {cycle}");
    }
    galore2::parallel::shutdown_pool();
    assert_eq!(
        galore2::parallel::pool_size(),
        0,
        "pool shutdown must join every compute worker"
    );
    // Each leaked panicked worker would add `world` threads per cycle;
    // allow a little slack for the test harness's own thread churn.
    let after = thread_count();
    assert!(
        after <= baseline + 2,
        "worker threads leaked across kill→recover cycles: {baseline} → {after}"
    );
}

#[test]
fn pipelined_kill_mid_step_recovers_with_no_leaked_comm_threads() {
    let _g = lock();
    // Overlap is ON by default: every rank owns a dedicated comm thread
    // (dist/pipeline.rs) with collectives in flight while the worker
    // computes. Killing a rank mid-pipelined step must (a) recover
    // promptly — the survivors' issued collectives all complete or
    // poison, never hang — and (b) join every comm thread of both the
    // dead and the rebuilt cluster: comm threads park on a condvar, so a
    // leaked one would survive to process exit and show in
    // /proc/self/task. GaLore at update_freq 3 puts refreshes at t=3/6,
    // so the kill at t=5 lands mid-steady-state pipeline and the replay
    // re-crosses a refresh (broadcast FIFO gating) on the rebuilt world.
    galore2::dist::set_overlap_enabled(true);
    galore2::parallel::shutdown_pool();
    let baseline = thread_count();
    for _cycle in 0..2 {
        check_recovery(
            Mode::Fsdp,
            &galore_spec(),
            TransportKind::Threads,
            2,
            OnFailure::Respawn,
            (1, 5),
        );
    }
    galore2::parallel::shutdown_pool();
    let after = thread_count();
    assert!(
        after <= baseline + 2,
        "comm threads leaked across pipelined kill→recover cycles: {baseline} → {after}"
    );
}

#[test]
fn pool_shutdown_joins_all_workers_and_pool_restarts() {
    let _g = lock();
    // Force the pool up with a wide parallel region, shut it down, and
    // require the OS thread count to return to the pre-pool level — then
    // prove the pool restarts lazily and still computes correctly.
    galore2::parallel::shutdown_pool();
    let baseline = thread_count();
    let work = |data: &mut Vec<u64>| {
        galore2::parallel::par_chunks_mut(data, 64, 4, |i, chunk| {
            for (j, x) in chunk.iter_mut().enumerate() {
                *x = (i * 1_000 + j) as u64;
            }
        });
    };
    let mut data = vec![0u64; 4096];
    work(&mut data);
    assert!(
        galore2::parallel::pool_size() >= 1,
        "wide region must spawn pool workers"
    );
    assert!(thread_count() > baseline, "pool workers must be real OS threads");
    galore2::parallel::shutdown_pool();
    assert_eq!(galore2::parallel::pool_size(), 0);
    // Same slack as the kill→recover leak test: the harness's own test
    // threads come and go; what may NOT remain is the pool's workers.
    let after_shutdown = thread_count();
    assert!(
        after_shutdown <= baseline + 2,
        "shutdown must JOIN pool workers, not abandon them: {baseline} → {after_shutdown}"
    );
    // Lazy restart: the same call works again and spawns fresh workers.
    let mut again = vec![0u64; 4096];
    work(&mut again);
    assert_eq!(data, again, "pool restart must not change results");
    assert!(
        galore2::parallel::pool_size() >= 1,
        "pool must restart on demand after shutdown"
    );
    galore2::parallel::shutdown_pool();
}
