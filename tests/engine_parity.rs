//! Integration: the native-Rust and PJRT-Pallas GaLore engines are
//! numerically interchangeable on the real model workload, execution
//! modes (Single / FSDP / DDP) agree at world=1, every `OptimizerSpec`
//! variant builds the same optimizer on every path, and the
//! property-level invariants hold across the optimizer stack.

use galore2::config::{Engine, ParallelMode, TrainConfig};
use galore2::optim::{BuildTarget, OptimizerSpec};
use galore2::testing::{fixtures, prop};
use galore2::train::Trainer;

fn ready() -> bool {
    fixtures::artifacts_ready()
}

fn cfg(engine: Engine, run: &str) -> TrainConfig {
    TrainConfig {
        engine,
        galore_update_freq: 10,
        corpus_tokens: 50_000,
        val_tokens: 8_000,
        ..fixtures::tiny_train_cfg("galore", run, 15)
    }
}

#[test]
fn native_and_pjrt_engines_agree_on_model_training() {
    if !ready() {
        eprintln!("skipping: run make artifacts");
        return;
    }
    let mut native = Trainer::new(cfg(Engine::Native, "eng_native")).unwrap();
    let mut pjrt = Trainer::new(cfg(Engine::Pjrt, "eng_pjrt")).unwrap();
    for t in 0..15 {
        let ln = native.train_step(t).unwrap();
        let lp = pjrt.train_step(t).unwrap();
        assert!(
            (ln - lp).abs() < 5e-3,
            "step {t}: native loss {ln} vs pjrt loss {lp}"
        );
    }
    // Parameters should match closely (same seeds ⇒ same rand-SVD sketches;
    // kernel vs native Adam math agrees to fp32 round-off).
    let mut worst = 0f32;
    for (a, b) in native.params().iter().zip(pjrt.params()) {
        worst = worst.max(prop::max_abs_diff(&a.data, &b.data));
    }
    assert!(worst < 5e-3, "param drift between engines: {worst}");
}

fn cfg_mode(optimizer: &str, run: &str, parallel: ParallelMode) -> TrainConfig {
    TrainConfig {
        optimizer: optimizer.into(),
        run_name: format!("{run}_{optimizer}_{}", std::process::id()),
        parallel,
        world: 1,
        lr: 0.01,
        ..cfg(Engine::Native, run)
    }
}

#[test]
fn single_fsdp_ddp_world1_trajectories_match() {
    // §4.3's claim at the API level: the same OptimizerSpec recipe runs
    // unchanged on every TrainEngine, and at world=1 the trajectories are
    // identical — for the full GaLore path (leader SVD + broadcast under
    // FSDP, local refresh under Single/DDP, same rand-SVD stream) and the
    // AdamW baseline.
    if !ready() {
        eprintln!("skipping: run make artifacts");
        return;
    }
    for optimizer in ["adamw", "galore"] {
        let mut single =
            Trainer::new(cfg_mode(optimizer, "tri_single", ParallelMode::Single)).unwrap();
        let mut fsdp =
            Trainer::new(cfg_mode(optimizer, "tri_fsdp", ParallelMode::Fsdp)).unwrap();
        let mut ddp =
            Trainer::new(cfg_mode(optimizer, "tri_ddp", ParallelMode::Ddp)).unwrap();
        for t in 0..12 {
            let ls = single.train_step(t).unwrap();
            let lf = fsdp.train_step(t).unwrap();
            let ld = ddp.train_step(t).unwrap();
            assert!(
                (ls - lf).abs() < 1e-4,
                "{optimizer} step {t}: single {ls} vs fsdp(1) {lf}"
            );
            assert!(
                (ls - ld).abs() < 1e-4,
                "{optimizer} step {t}: single {ls} vs ddp(1) {ld}"
            );
        }
        for (idx, (a, b)) in single.params().iter().zip(fsdp.params()).enumerate() {
            let diff = prop::max_abs_diff(&a.data, &b.data);
            assert!(diff < 1e-5, "{optimizer} param {idx}: fsdp drift {diff}");
        }
        for (idx, (a, b)) in single.params().iter().zip(ddp.params()).enumerate() {
            let diff = prop::max_abs_diff(&a.data, &b.data);
            assert!(diff < 1e-5, "{optimizer} param {idx}: ddp drift {diff}");
        }
    }
}

#[test]
fn spec_roundtrip_same_name_on_every_build_path() {
    // No artifacts needed: every optimizer string maps to ONE spec, and
    // that spec builds an optimizer reporting the same name on the
    // single-process, FSDP-worker and DDP-worker paths.
    for optimizer in ["adamw", "adam8bit", "adafactor", "sgdm", "galore", "qgalore"] {
        let c = TrainConfig {
            optimizer: optimizer.into(),
            ..TrainConfig::default()
        };
        let spec = c.optimizer_spec(64).unwrap();
        let single = spec
            .build(1, BuildTarget::Single { pjrt: None })
            .expect("single build");
        let fsdp = spec
            .build(
                1,
                BuildTarget::Worker {
                    external_subspace: true,
                },
            )
            .expect("fsdp build");
        let ddp = spec
            .build(
                1,
                BuildTarget::Worker {
                    external_subspace: false,
                },
            )
            .expect("ddp build");
        assert_eq!(single.name(), spec.name(), "{optimizer}: single path");
        assert_eq!(fsdp.name(), spec.name(), "{optimizer}: fsdp path");
        assert_eq!(ddp.name(), spec.name(), "{optimizer}: ddp path");
    }
    // The PJRT variant is single-process only and says so on every other
    // path (rather than silently building something else).
    let c = TrainConfig {
        engine: Engine::Pjrt,
        ..TrainConfig::default()
    };
    let spec = c.optimizer_spec(64).unwrap();
    assert!(matches!(spec, OptimizerSpec::PjrtGaLore { .. }));
    assert!(spec
        .build(
            1,
            BuildTarget::Worker {
                external_subspace: true
            }
        )
        .is_err());
}

#[test]
fn prop_projection_roundtrip_energy_never_increases() {
    // ‖P Pᵀ G‖ ≤ ‖G‖ for any orthonormal P (projection is non-expansive) —
    // checked over random shapes and all projection kinds.
    use galore2::optim::{ProjectionKind, Projector};
    use galore2::tensor::Matrix;
    use galore2::util::rng::Pcg64;
    prop::check("projection non-expansive", 40, |g| {
        let m = g.usize_in(2, 24);
        let n = g.usize_in(2, 24);
        let r = g.usize_in(1, m.min(n));
        let grad = Matrix::from_vec(m, n, g.matrix(m, n));
        let kind = *g.choose(&[
            ProjectionKind::FullSvd,
            ProjectionKind::RandSvd,
            ProjectionKind::Random,
        ]);
        let mut rng = Pcg64::new(11, 5);
        let mut p = Projector::from_gradient(&grad, r, kind, &mut rng);
        let low = p.project(&grad);
        let back = p.project_back(&low);
        let ratio = back.frobenius_norm() / grad.frobenius_norm().max(1e-9);
        if ratio > 1.01 {
            return Err(format!(
                "projection expanded energy: ratio {ratio} ({kind:?}, {m}x{n} r{r})"
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_galore_step_is_bounded() {
    // Adam-normalized GaLore updates are bounded by lr·α per element in
    // the projected basis ⇒ ‖ΔW‖∞ ≤ lr·α·‖P‖₁-ish; we check the practical
    // bound ‖ΔW‖∞ ≤ lr·α·√r · c for random gradients.
    use galore2::optim::{AdamCfg, GaLore, GaLoreCfg, Optimizer};
    use galore2::tensor::Matrix;
    prop::check("galore update bounded", 25, |g| {
        let m = g.usize_in(4, 20);
        let n = g.usize_in(4, 20);
        let r = g.usize_in(1, m.min(n) - 1);
        let lr = 0.01f32;
        let alpha = g.f32_in(0.05, 1.0);
        let cfg = GaLoreCfg {
            rank: r,
            update_freq: 1000,
            alpha,
            ..GaLoreCfg::default()
        };
        let mut opt = GaLore::new(cfg, AdamCfg::default(), 9);
        let mut w = Matrix::zeros(m, n);
        let grad = Matrix::from_vec(m, n, g.matrix(m, n));
        opt.begin_step(0);
        opt.step_param(0, &mut w, &grad, lr);
        let bound = lr * alpha * (r as f32).sqrt() * 1.3 + 1e-5;
        if w.max_abs() > bound {
            return Err(format!(
                "update {} exceeds bound {bound} (m{m} n{n} r{r} α{alpha})",
                w.max_abs()
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_quantized_projector_degrades_gracefully() {
    // q8 projection of the gradient stays within a few percent of fp32;
    // q4 within ~15% — the quantitative backdrop of Fig. 1.
    use galore2::optim::{ProjectionKind, Projector};
    use galore2::tensor::Matrix;
    use galore2::util::rng::Pcg64;
    prop::check("quantized projector error bands", 20, |g| {
        let m = g.usize_in(8, 24);
        let n = g.usize_in(8, 32);
        let r = g.usize_in(2, m.min(n) / 2);
        let grad = Matrix::from_vec(m, n, g.matrix(m, n));
        let mut rng = Pcg64::new(13, 1);
        let mut fp = Projector::from_gradient(&grad, r, ProjectionKind::RandSvd, &mut rng);
        let base = fp.project(&grad);
        for (kind, tol) in [(ProjectionKind::Quant8, 0.05), (ProjectionKind::Quant4, 0.30)] {
            let mut q = Projector::from_gradient(&grad, r, kind, &mut Pcg64::new(13, 1));
            let got = q.project(&grad);
            let rel = got.sub(&base).frobenius_norm() / base.frobenius_norm().max(1e-9);
            if rel > tol {
                return Err(format!("{kind:?} rel err {rel} > {tol}"));
            }
        }
        Ok(())
    });
}
