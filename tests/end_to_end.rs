//! Integration: the full stack (artifacts → runtime → trainer → optimizer
//! → eval → checkpoint) composes and learns.
//!
//! All tests skip gracefully when `make artifacts` hasn't been run.

use galore2::config::{ParallelMode, TrainConfig};
use galore2::dist::{set_worker_binary, TransportKind};
use galore2::testing::fixtures;
use galore2::train::Trainer;

fn ready() -> bool {
    fixtures::artifacts_ready()
}

fn cfg(optimizer: &str, run: &str, steps: u64) -> TrainConfig {
    fixtures::tiny_train_cfg(optimizer, run, steps)
}

#[test]
fn galore_learns_the_corpus() {
    if !ready() {
        eprintln!("skipping: run make artifacts");
        return;
    }
    let mut trainer = Trainer::new(cfg("galore", "e2e_galore", 250)).unwrap();
    let outcome = trainer.run().unwrap();
    // ln(vocab)=5.55 start; conditional-entropy floor ≈ 1.6–1.8.
    assert!(
        outcome.final_val_loss < 2.5,
        "GaLore failed to learn: val loss {}",
        outcome.final_val_loss
    );
}

#[test]
fn galore_tracks_adam8bit_final_loss() {
    // The Fig. 3 conclusion at integration-test scale: comparable val loss.
    if !ready() {
        return;
    }
    let mut galore = Trainer::new(cfg("galore", "e2e_cmp_g", 250)).unwrap();
    let g = galore.run().unwrap();
    let mut base = Trainer::new({
        let mut c = cfg("adam8bit", "e2e_cmp_b", 250);
        c.lr = 0.01;
        c
    })
    .unwrap();
    let b = base.run().unwrap();
    assert!(
        (g.final_val_loss - b.final_val_loss).abs() < 0.5,
        "galore {} vs adam8bit {} diverge",
        g.final_val_loss,
        b.final_val_loss
    );
}

#[test]
fn fsdp_two_ranks_matches_single_rank_adamw() {
    // FSDP(world=1) must equal Single exactly up to optimizer impl; with
    // world=2 and identical microbatches the averaged gradient differs, so
    // we check world=1 parity (strict) — the sharded-engine path vs the
    // in-process path.
    if !ready() {
        return;
    }
    let mut single = Trainer::new({
        let mut c = cfg("adamw", "e2e_par_single", 25);
        c.lr = 0.01;
        c
    })
    .unwrap();
    let mut fsdp = Trainer::new({
        let mut c = cfg("adamw", "e2e_par_fsdp", 25);
        c.lr = 0.01;
        c.parallel = ParallelMode::Fsdp;
        c.world = 1;
        c
    })
    .unwrap();
    for t in 0..25 {
        let ls = single.train_step(t).unwrap();
        let lf = fsdp.train_step(t).unwrap();
        assert!(
            (ls - lf).abs() < 1e-4,
            "step {t}: single {ls} vs fsdp(1) {lf}"
        );
    }
    for (a, b) in single.params().iter().zip(fsdp.params()) {
        let diff = a
            .data
            .iter()
            .zip(&b.data)
            .map(|(x, y)| (x - y).abs())
            .fold(0f32, f32::max);
        assert!(diff < 1e-5, "param drift {diff}");
    }
}

#[test]
fn fsdp_galore_world2_learns() {
    if !ready() {
        return;
    }
    let mut trainer = Trainer::new({
        let mut c = cfg("galore", "e2e_fsdp2", 120);
        c.parallel = ParallelMode::Fsdp;
        c.world = 2;
        c
    })
    .unwrap();
    let outcome = trainer.run().unwrap();
    assert!(
        outcome.final_val_loss < 3.5,
        "FSDP GaLore failed to learn: {}",
        outcome.final_val_loss
    );
    // Memory telemetry present and sane.
    let reports = trainer.memory_reports().unwrap();
    assert_eq!(reports.len(), 2);
    assert!(reports[0].optimizer_bytes > 0);
}

#[test]
fn ddp_galore_world2_learns() {
    // `--parallel ddp` is a first-class trainer mode: full run, learning,
    // and replicated-state telemetry (every rank reports FULL moments).
    if !ready() {
        return;
    }
    let mut trainer = Trainer::new({
        let mut c = cfg("galore", "e2e_ddp2", 120);
        c.parallel = ParallelMode::Ddp;
        c.world = 2;
        c
    })
    .unwrap();
    let outcome = trainer.run().unwrap();
    assert!(
        outcome.final_val_loss < 3.5,
        "DDP GaLore failed to learn: {}",
        outcome.final_val_loss
    );
    let reports = trainer.memory_reports().unwrap();
    assert_eq!(reports.len(), 2);
    // Replicated params: every rank holds the full model.
    let full: usize = trainer.params().iter().map(|p| p.numel() * 4).sum();
    assert_eq!(reports[0].param_shard_bytes, full);
    assert_eq!(reports[1].param_shard_bytes, full);
}

#[test]
fn checkpoint_resume_reproduces_trajectory() {
    if !ready() {
        return;
    }
    // Train 30 steps, checkpoint at 20, resume a fresh trainer, compare
    // losses at steps 20..30 step-for-step.
    let mut a = Trainer::new(cfg("galore", "e2e_ckpt_a", 40)).unwrap();
    let mut losses_a = Vec::new();
    for t in 0..20 {
        a.train_step(t).unwrap();
    }
    a.save_checkpoint(20).unwrap();
    for t in 20..30 {
        losses_a.push(a.train_step(t).unwrap());
    }
    let mut b = Trainer::new(cfg("galore", "e2e_ckpt_a", 40)).unwrap();
    let resumed = b.resume(&a.checkpoint_path(20)).unwrap();
    assert_eq!(resumed, 20);
    let mut losses_b = Vec::new();
    for t in 20..30 {
        losses_b.push(b.train_step(t).unwrap());
    }
    for (i, (x, y)) in losses_a.iter().zip(&losses_b).enumerate() {
        assert!(
            (x - y).abs() < 1e-4,
            "resume diverged at step {}: {x} vs {y}",
            20 + i
        );
    }
}

#[test]
fn fsdp_checkpoint_resume_reproduces_trajectory() {
    // The FSDP resume fix: restoring must re-scatter loaded params into
    // the cluster's shards AND restore every rank's shard-local moments
    // (TrainEngine::import_state) — not train from stale shards with
    // fresh moments.
    if !ready() {
        return;
    }
    let fsdp_cfg = |run: &str| {
        let mut c = cfg("galore", run, 40);
        c.parallel = ParallelMode::Fsdp;
        c.world = 2;
        // Refresh at t=25 lands INSIDE the compared window (20..30): the
        // checkpoint carries each worker's SVD-stream position, so the
        // resumed leader must draw the same sketch there.
        c.galore_update_freq = 25;
        c
    };
    let mut a = Trainer::new(fsdp_cfg("e2e_fsdp_ckpt")).unwrap();
    for t in 0..20 {
        a.train_step(t).unwrap();
    }
    a.save_checkpoint(20).unwrap();
    let mut losses_a = Vec::new();
    for t in 20..30 {
        losses_a.push(a.train_step(t).unwrap());
    }
    let mut b = Trainer::new(fsdp_cfg("e2e_fsdp_ckpt")).unwrap();
    assert_eq!(b.resume(&a.checkpoint_path(20)).unwrap(), 20);
    let mut losses_b = Vec::new();
    for t in 20..30 {
        losses_b.push(b.train_step(t).unwrap());
    }
    for (i, (x, y)) in losses_a.iter().zip(&losses_b).enumerate() {
        assert!(
            (x - y).abs() < 1e-4,
            "FSDP resume diverged at step {}: {x} vs {y}",
            20 + i
        );
    }
    for (a_p, b_p) in a.params().iter().zip(b.params()) {
        let diff = a_p
            .data
            .iter()
            .zip(&b_p.data)
            .map(|(x, y)| (x - y).abs())
            .fold(0f32, f32::max);
        assert!(diff < 1e-5, "FSDP resume param drift {diff}");
    }
}

#[test]
fn process_transport_full_training_matches_threads_bitwise() {
    // The acceptance claim at trainer level: a real FSDP GaLore training
    // run (fwd/bwd artifacts, data loader, LR schedule, subspace
    // refreshes) over `--transport process` reproduces the threaded run's
    // loss trace bit for bit, and ends on bitwise-identical parameters.
    if !ready() {
        return;
    }
    set_worker_binary(env!("CARGO_BIN_EXE_galore2"));
    let mk = |transport: TransportKind, run: &str| {
        let mut c = cfg("galore", run, 12);
        c.parallel = ParallelMode::Fsdp;
        c.world = 2;
        c.galore_update_freq = 5; // refresh inside the window
        c.transport = transport;
        c
    };
    let mut threads = Trainer::new(mk(TransportKind::Threads, "e2e_tr_threads")).unwrap();
    let mut process = Trainer::new(mk(TransportKind::Process, "e2e_tr_process")).unwrap();
    for t in 0..12 {
        let lt = threads.train_step(t).unwrap();
        let lp = process.train_step(t).unwrap();
        assert_eq!(
            lt.to_bits(),
            lp.to_bits(),
            "loss trace diverged across transports at step {t}: {lt} vs {lp}"
        );
    }
    for (idx, (a, b)) in threads.params().iter().zip(process.params()).enumerate() {
        assert_eq!(a.data, b.data, "param {idx} diverged across transports");
    }
}

#[test]
fn v4_checkpoint_restores_exact_token_counter_across_worlds() {
    // ROADMAP PR 3 follow-up: `tokens_seen` is a v4 checkpoint field. An
    // ELASTIC resume (different world ⇒ different tokens-per-step) must
    // report the SOURCE run's exact counter, not a rescaling.
    if !ready() {
        return;
    }
    let mut a = Trainer::new(cfg("adamw", "e2e_tok", 20)).unwrap();
    for t in 0..10 {
        a.train_step(t).unwrap();
    }
    let saved_tokens = a.tokens_seen;
    assert!(saved_tokens > 0);
    a.save_checkpoint(10).unwrap();
    let mut b = Trainer::new({
        let mut c = cfg("adamw", "e2e_tok", 20);
        c.parallel = ParallelMode::Ddp;
        c.world = 2;
        c
    })
    .unwrap();
    assert_eq!(b.resume(&a.checkpoint_path(10)).unwrap(), 10);
    assert_eq!(
        b.tokens_seen, saved_tokens,
        "elastic resume must carry the exact token counter (v4 field)"
    );
    // The same-world reconstruction fallback stays exact for pre-v4-style
    // resumes; here the counter comes straight from the file either way.
    let mut c = Trainer::new(cfg("adamw", "e2e_tok", 20)).unwrap();
    c.resume(&a.checkpoint_path(10)).unwrap();
    assert_eq!(c.tokens_seen, saved_tokens);
}

#[test]
fn downstream_improves_with_training() {
    // Trained model beats the untrained one on the cloze categories —
    // the eval harness actually measures learning.
    if !ready() {
        return;
    }
    use galore2::coordinator::eval_params;
    let untrained_cfg = cfg("galore", "e2e_ds", 1);
    let llama = galore2::model::LlamaCfg::preset("llama-nano").unwrap();
    let untrained = galore2::model::init_params(&llama, 42);
    let u = eval_params(&untrained_cfg, &untrained, 60).unwrap();

    let mut trainer = Trainer::new(cfg("adam8bit", "e2e_ds_t", 300)).unwrap();
    trainer.run().unwrap();
    let t = eval_params(&trainer.cfg, trainer.params(), 60).unwrap();

    let u_avg: f64 = u.iter().map(|r| r.accuracy).sum::<f64>() / u.len() as f64;
    let t_avg: f64 = t.iter().map(|r| r.accuracy).sum::<f64>() / t.len() as f64;
    assert!(
        t_avg > u_avg + 0.1,
        "training did not lift downstream acc: {u_avg:.3} -> {t_avg:.3}"
    );
}
