//! Project invariants, CI-gated:
//!
//! 1. **Self-scan** — `galore2::analysis::lint_root` over this repo's own
//!    `rust/src` reports zero findings: every byte-layout site is either in
//!    a sanctioned parser module or carries a justified
//!    `// lint: allow(<rule>): <reason>`.
//! 2. **Rule fixtures** — each lint rule fires on a seeded violation and
//!    allow-comment hygiene is itself enforced, so a regression in the
//!    lint engine can't silently green the gate.
//! 3. **CLI contract** — `galore2 lint` exits non-zero naming file:line
//!    and rule on a dirty tree, zero on the merged tree.
//! 4. **Corrupt-input properties** — every parser behind the single-parser
//!    invariant (wire cmd/reply/setup frames, quantized stored tensors,
//!    transport framing, checkpoint files) returns `Err` on truncation and
//!    length-field corruption, never panics on single-byte mutations, and
//!    never lets a corrupt length field drive a huge allocation (enforced
//!    by a wrapping global allocator that records the largest single
//!    allocation request).

use galore2::analysis::{lint_root, lint_source, ALLOW_HYGIENE};
use galore2::checkpoint::Checkpoint;
use galore2::tensor::Matrix;
use galore2::testing::{fuzz, prop};
use std::alloc::{GlobalAlloc, Layout, System};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};

// ------------------------------------------------------------ alloc guard

/// Pass-through allocator that records the largest single allocation
/// request made by this test binary. Parsers fed corrupt length fields
/// must error out *before* allocating, so nothing in this suite has any
/// business requesting more than [`ALLOC_CAP`] bytes at once.
struct CapAlloc;

static LARGEST_ALLOC: AtomicUsize = AtomicUsize::new(0);

/// 16 MiB: orders of magnitude above anything these tests legitimately
/// allocate (source files, tiny matrices, sample frames), orders of
/// magnitude below what a trusted 0xFF…FF length prefix would request.
const ALLOC_CAP: usize = 1 << 24;

unsafe impl GlobalAlloc for CapAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        LARGEST_ALLOC.fetch_max(layout.size(), Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        LARGEST_ALLOC.fetch_max(new_size, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOCATOR: CapAlloc = CapAlloc;

fn assert_allocations_bounded(context: &str) {
    let largest = LARGEST_ALLOC.load(Ordering::Relaxed);
    assert!(
        largest <= ALLOC_CAP,
        "{context}: some allocation requested {largest} bytes (cap {ALLOC_CAP}) — \
         a parser trusted a corrupt length field"
    );
}

fn repo_root() -> &'static Path {
    Path::new(env!("CARGO_MANIFEST_DIR"))
}

// -------------------------------------------------------------- self-scan

#[test]
fn lint_self_scan_is_clean() {
    let report = lint_root(repo_root()).expect("lint scan must read rust/src");
    assert!(
        report.files_scanned > 20,
        "scan only saw {} files — wrong root?",
        report.files_scanned
    );
    assert!(
        report.clean(),
        "the tree must lint clean; findings:\n{}",
        report.render_text()
    );
}

// ----------------------------------------------------------- rule fixtures

#[test]
fn each_rule_fires_on_a_seeded_violation() {
    let cases: &[(&str, &str, &str)] = &[
        (
            "single-parser",
            "dist/bad.rs",
            "fn f(b: [u8; 8]) -> u64 { u64::from_le_bytes(b) }",
        ),
        (
            "checked-alloc",
            "quant/bad.rs",
            "fn d(r: &mut Reader) -> Vec<u8> {\n    let n = r.u64().unwrap_or(0) as usize;\n    Vec::with_capacity(n)\n}",
        ),
        (
            "no-panic-dist",
            "dist/bad.rs",
            "fn serve(x: Option<u64>) -> u64 { x.unwrap() }",
        ),
        (
            "determinism",
            "optim/bad.rs",
            "use std::collections::HashMap;",
        ),
        (
            "determinism",
            "parallel/bad.rs",
            "fn t() -> Option<usize> { std::env::var(\"T\").ok()?.parse().ok() }",
        ),
        (
            "lock-across-collective",
            "train/bad.rs",
            "fn f(m: &M, c: &C) {\n    let g = m.lock();\n    c.barrier();\n    drop(g);\n}",
        ),
        // The comm-pipeline serve loop (dist/pipeline.rs) is a SERVE_FN
        // region: a worker death must flow through the FailureCell path
        // as a named error, so a bare unwrap there is a finding.
        (
            "no-panic-dist",
            "dist/pipeline.rs",
            "fn serve(comm: Comm, q: &Q) { let r = q.pop().unwrap(); comm.run(r); }",
        ),
        // Holding the pipeline's queue lock across the collective itself
        // would serialize ranks against each other (and deadlock under a
        // poisoned peer) — the real serve loop pops under the lock, then
        // drops the guard BEFORE running the collective.
        (
            "lock-across-collective",
            "dist/pipeline.rs",
            "fn f(s: &S, t: &mut T) {\n    let st = s.m.lock();\n    t.exchange(v, None, &mut r);\n    drop(st);\n}",
        ),
        // The shm module's raw-le_bytes allowlist covers ONLY the
        // `mod header` codec region: the same token outside it fires.
        (
            "single-parser",
            "dist/shm.rs",
            "mod header { fn g(b: [u8; 8]) -> u64 { u64::from_le_bytes(b) } }\nfn f(x: u64) -> [u8; 8] { x.to_le_bytes() }",
        ),
        // dist/shm.rs is a parser module: an unbounded parse+alloc (a
        // declared slot-table length trusted without a checked bound
        // before mapping) is a finding.
        (
            "checked-alloc",
            "dist/shm.rs",
            "fn open(r: &mut Reader) -> Vec<u8> {\n    let n = r.u64().unwrap_or(0) as usize;\n    Vec::with_capacity(n)\n}",
        ),
    ];
    for (rule, file, src) in cases {
        let findings = lint_source(file, src);
        assert!(
            findings.iter().any(|f| f.rule == *rule),
            "rule {rule} did not fire on its fixture; got: {:?}",
            findings
                .iter()
                .map(|f| (f.rule, f.line))
                .collect::<Vec<_>>()
        );
    }
}

#[test]
fn justified_allow_suppresses_and_bad_allows_are_findings() {
    // A justified allow silences exactly its rule.
    let allowed = "// lint: allow(single-parser): fixture — fixed-width tag, caller length-checks\n\
                   fn f(b: [u8; 8]) -> u64 { u64::from_le_bytes(b) }";
    assert!(
        lint_source("dist/bad.rs", allowed).is_empty(),
        "a justified allow must suppress its finding"
    );
    // A dangling allow (no code after it) is itself a finding.
    let dangling = "// lint: allow(single-parser): nothing follows this comment\n";
    assert!(
        lint_source("dist/bad.rs", dangling)
            .iter()
            .any(|f| f.rule == ALLOW_HYGIENE),
        "dangling allow must be an allow-hygiene finding"
    );
    // An allow naming an unknown rule never suppresses anything.
    let unknown = "// lint: allow(definitely-not-a-rule): why\n\
                   fn f(b: [u8; 8]) -> u64 { u64::from_le_bytes(b) }";
    let findings = lint_source("dist/bad.rs", unknown);
    assert!(findings.iter().any(|f| f.rule == ALLOW_HYGIENE));
    assert!(findings.iter().any(|f| f.rule == "single-parser"));
    // An empty reason is rejected: allows must say *why*.
    let unreasoned = "// lint: allow(single-parser):\n\
                      fn f(b: [u8; 8]) -> u64 { u64::from_le_bytes(b) }";
    assert!(lint_source("dist/bad.rs", unreasoned)
        .iter()
        .any(|f| f.rule == ALLOW_HYGIENE));
}

// ------------------------------------------------------------ CLI contract

fn write_fixture_tree(root: &Path) {
    let src = root.join("rust").join("src");
    std::fs::create_dir_all(src.join("dist")).unwrap();
    std::fs::create_dir_all(src.join("quant")).unwrap();
    // One file seeding four of the five rules…
    std::fs::write(
        src.join("dist").join("bad.rs"),
        "use std::collections::HashMap;\n\
         \n\
         fn serve(x: Option<u64>) -> u64 {\n\
         \x20   let v = x.unwrap();\n\
         \x20   u64::from_le_bytes([0u8; 8]) + v\n\
         }\n\
         \n\
         fn sync(m: &std::sync::Mutex<u64>, c: &Comm) {\n\
         \x20   let g = m.lock();\n\
         \x20   c.barrier();\n\
         \x20   drop(g);\n\
         }\n",
    )
    .unwrap();
    // …and one seeding the fifth (checked-alloc is parser-module scoped).
    std::fs::write(
        src.join("quant").join("bad.rs"),
        "fn d(r: &mut Reader) -> Vec<u8> {\n\
         \x20   let n = r.u64().unwrap_or(0) as usize;\n\
         \x20   Vec::with_capacity(n)\n\
         }\n",
    )
    .unwrap();
}

#[test]
fn lint_cli_fails_on_seeded_violations_and_passes_on_real_tree() {
    let dir = std::env::temp_dir().join(format!("galore2_lint_fixture_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    write_fixture_tree(&dir);

    let out = std::process::Command::new(env!("CARGO_BIN_EXE_galore2"))
        .args(["lint", "--root"])
        .arg(&dir)
        .output()
        .expect("running galore2 lint");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        !out.status.success(),
        "lint must exit non-zero on a dirty tree; stdout:\n{stdout}"
    );
    // Findings name file:line and rule for every seeded rule.
    for rule in galore2::analysis::RULES {
        assert!(
            stdout.contains(&format!("[{rule}]")),
            "seeded {rule} violation missing from output:\n{stdout}"
        );
    }
    assert!(
        stdout.contains("rust/src/dist/bad.rs:4:"),
        "findings must carry file:line; stdout:\n{stdout}"
    );
    assert!(stdout.contains("rust/src/quant/bad.rs:"), "{stdout}");

    // JSON mode renders the same findings machine-readably.
    let json_out = std::process::Command::new(env!("CARGO_BIN_EXE_galore2"))
        .args(["lint", "--json", "--root"])
        .arg(&dir)
        .output()
        .expect("running galore2 lint --json");
    let json = String::from_utf8_lossy(&json_out.stdout);
    assert!(!json_out.status.success());
    assert!(json.contains("\"clean\": false"), "{json}");
    assert!(json.contains("\"rule\": \"no-panic-dist\""), "{json}");

    let _ = std::fs::remove_dir_all(&dir);

    // The merged tree itself must pass through the same CLI path.
    let clean = std::process::Command::new(env!("CARGO_BIN_EXE_galore2"))
        .args(["lint", "--root"])
        .arg(repo_root())
        .output()
        .expect("running galore2 lint on the repo");
    let clean_stdout = String::from_utf8_lossy(&clean.stdout);
    assert!(
        clean.status.success(),
        "repo tree must lint clean; stdout:\n{clean_stdout}"
    );
    assert!(clean_stdout.contains("0 finding(s)"), "{clean_stdout}");
}

// --------------------------------------------- corrupt-input property tests

type Decoder = fn(&[u8]) -> Result<(), String>;

fn parser_samples() -> Vec<(&'static str, Vec<u8>, Decoder)> {
    vec![
        ("cmd", fuzz::sample_cmd_frame(), fuzz::decode_cmd_frame as Decoder),
        ("reply", fuzz::sample_reply_frame(), fuzz::decode_reply_frame as Decoder),
        ("report", fuzz::sample_report_frame(), fuzz::decode_reply_frame as Decoder),
        ("setup", fuzz::sample_setup_frame(), fuzz::decode_setup_frame as Decoder),
        ("stored-tensor", fuzz::sample_stored_tensor(), fuzz::decode_stored_tensor as Decoder),
    ]
}

#[test]
fn every_strict_prefix_of_every_frame_errors() {
    for (name, frame, decode) in parser_samples() {
        assert!(decode(&frame).is_ok(), "{name} sample must be valid");
        for cut in 0..frame.len() {
            assert!(
                decode(&frame[..cut]).is_err(),
                "{name} truncated to {cut}/{} bytes decoded silently",
                frame.len()
            );
        }
    }
    assert_allocations_bounded("prefix truncation");
}

#[test]
fn corrupt_length_fields_error_without_huge_allocations() {
    // Transport framing: an all-ones length prefix trips the frame cap.
    let framed = fuzz::frame(b"payload");
    let mut torn = framed.clone();
    for b in torn.iter_mut().take(8) {
        *b = 0xFF;
    }
    let err = fuzz::read_frame_bytes(&torn).unwrap_err();
    assert!(err.contains("cap"), "unhelpful torn-frame error: {err}");
    // A plausible-but-lying length prefix (claims more than arrives) is a
    // torn frame, not a hang and not a trusted allocation.
    let mut lying = framed.clone();
    lying[0] = 0xEE; // claims ~238 bytes; only 7 follow
    let err = fuzz::read_frame_bytes(&lying).unwrap_err();
    assert!(err.contains("torn frame"), "{err}");
    assert_eq!(fuzz::read_frame_bytes(&framed).unwrap(), 7);

    // Inner length/count fields: overwrite every u64-sized window with
    // 0xFF and require no panic and no huge allocation (windows that only
    // touch payload values — f32 data, free-form counters — may stay
    // decodable).
    for (_, frame, decode) in parser_samples() {
        for start in 0..frame.len().saturating_sub(8) {
            let mut corrupt = frame.clone();
            for b in corrupt[start..start + 8].iter_mut() {
                *b = 0xFF;
            }
            let _ = decode(&corrupt);
        }
    }
    // The canonical corruption — an all-ones count/length field — must be
    // *rejected*, loudly. Offsets: cmd's grads count sits after
    // [tag u8][t u64][lr f32]; reply's matrix count after [tag u8]; setup
    // leads with its meta count; a stored tensor's rows follow its tag.
    let must_fail: &[(&str, usize)] =
        &[("cmd", 13), ("reply", 1), ("setup", 0), ("stored-tensor", 1)];
    let samples = parser_samples();
    for (name, offset) in must_fail {
        let (_, frame, decode) = samples
            .iter()
            .find(|(n, _, _)| n == name)
            .expect("sample present");
        let mut corrupt = frame.clone();
        for b in corrupt[*offset..offset + 8].iter_mut() {
            *b = 0xFF;
        }
        assert!(
            decode(&corrupt).is_err(),
            "{name} with an all-ones count at offset {offset} decoded silently"
        );
    }
    assert_allocations_bounded("length-field corruption");
}

#[test]
fn random_single_byte_mutations_never_panic() {
    let samples = parser_samples();
    prop::check("single-byte frame mutations never panic", 400, |g| {
        let sample = g.choose(&samples);
        let mut bytes = sample.1.clone();
        let pos = g.usize_in(0, bytes.len() - 1);
        bytes[pos] ^= (1 + g.usize_in(0, 254)) as u8;
        // The result may legitimately be Ok (payload-byte flips) — the
        // property is "no panic, no huge allocation".
        let _ = (sample.2)(&bytes);
        Ok(())
    });
    assert_allocations_bounded("random mutation");
}

// -------------------------------------------------- checkpoint corruption

fn sample_checkpoint_bytes(dir: &Path) -> (PathBuf, Vec<u8>) {
    let ck = Checkpoint {
        step: 7,
        tokens_seen: Some(1234),
        names: vec!["blocks.0.wq".into(), "embed".into()],
        params: vec![
            Matrix::from_vec(2, 3, vec![1.0, -2.0, 3.5, 0.0, -0.0, 42.0]),
            Matrix::from_vec(1, 4, vec![0.25; 4]),
        ],
        opt_state: vec![9u8; 24],
    };
    let path = dir.join("sample.ckpt");
    ck.save(&path).expect("writing sample checkpoint");
    let bytes = std::fs::read(&path).expect("reading sample checkpoint back");
    (path, bytes)
}

#[test]
fn corrupt_checkpoints_error_never_panic() {
    let dir = std::env::temp_dir().join(format!("galore2_invariants_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let (path, bytes) = sample_checkpoint_bytes(&dir);
    assert!(Checkpoint::load(&path).is_ok(), "sample must load");

    // Every strict prefix must fail loudly.
    let cut_path = dir.join("cut.ckpt");
    for cut in 0..bytes.len() {
        std::fs::write(&cut_path, &bytes[..cut]).unwrap();
        assert!(
            Checkpoint::load(&cut_path).is_err(),
            "checkpoint truncated to {cut}/{} bytes loaded silently",
            bytes.len()
        );
    }

    // All-ones overwrites of the header's gate/count/length fields must be
    // rejected before any allocation trusts them. Offsets per the format
    // doc at the top of checkpoint/mod.rs (v5 layout, 8-byte magic):
    //   8 → version, 29 → n_params, 37 → first name_len.
    for field_off in [8usize, 29, 37] {
        let mut corrupt = bytes.clone();
        for b in corrupt[field_off..field_off + 8].iter_mut() {
            *b = 0xFF;
        }
        std::fs::write(&cut_path, &corrupt).unwrap();
        assert!(
            Checkpoint::load(&cut_path).is_err(),
            "checkpoint with 0xFF…FF at offset {field_off} loaded silently"
        );
    }

    // Random single-byte mutations: Err or Ok, never a panic or a huge
    // allocation. (Mutating f32 payload or the step counter can stay Ok.)
    let mut_path = dir.join("mut.ckpt");
    prop::check("checkpoint byte mutations never panic", 120, |g| {
        let mut corrupt = bytes.clone();
        let pos = g.usize_in(0, corrupt.len() - 1);
        corrupt[pos] ^= (1 + g.usize_in(0, 254)) as u8;
        std::fs::write(&mut_path, &corrupt).map_err(|e| e.to_string())?;
        let _ = Checkpoint::load(&mut_path);
        Ok(())
    });

    let _ = std::fs::remove_dir_all(&dir);
    assert_allocations_bounded("checkpoint corruption");
}

/// The committed pre-refactor fixtures pin that routing the checkpoint
/// codec through `optim::ser` changed no bytes on the read side.
#[test]
fn committed_legacy_fixtures_still_load() {
    for (name, version) in [("ckpt_v3_adamw.ckpt", 3u32), ("ckpt_v4_galore.ckpt", 4)] {
        let path = repo_root().join("tests").join("fixtures").join(name);
        let ck = Checkpoint::load(&path)
            .unwrap_or_else(|e| panic!("committed fixture {name} must load: {e}"));
        assert!(!ck.params.is_empty(), "{name} has no params");
        if version < 4 {
            assert_eq!(ck.tokens_seen, None, "v3 files predate tokens_seen");
        }
    }
}
