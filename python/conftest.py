import os
import sys

# Make `from compile import ...` work whether pytest runs from python/ or
# the repo root (the Makefile's final-log command uses the root).
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
