"""L2 correctness: model shapes, gradient sanity, pallas/ref parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as model_lib
from compile.model import PRESETS, ModelCfg

CFG = PRESETS["llama-nano"]


def tiny_batch(cfg: ModelCfg, key=0):
    k = jax.random.PRNGKey(key)
    toks = jax.random.randint(k, (cfg.batch, cfg.seq), 0, cfg.vocab, jnp.int32)
    tgts = jnp.roll(toks, -1, axis=1)
    return toks, tgts


def test_param_specs_shapes_and_count():
    specs = model_lib.param_specs(CFG)
    names = [n for n, _ in specs]
    assert names[0] == "embed.weight"
    assert names[-1] == "lm_head.weight"
    assert len([n for n in names if "attn.wq" in n]) == CFG.layers
    # 2 + 9 per layer
    assert len(specs) == 2 + 9 * CFG.layers + 1  # +1 final_norm
    total = model_lib.n_params(CFG)
    manual = sum(int(np.prod(s)) for _, s in specs)
    assert total == manual


def test_7b_param_count_matches_table2():
    # Table 2: hidden 4096, intermediate 11008, 32 heads, 32 layers → ~6.7B.
    cfg = PRESETS["llama-7b"]
    assert cfg.hidden == 4096
    assert cfg.intermediate == 11008
    assert cfg.heads == 32 and cfg.layers == 32
    n = model_lib.n_params(cfg)
    assert 6.4e9 < n < 7.1e9, n


def test_forward_shapes_and_finiteness():
    params = model_lib.init_params(CFG, jax.random.PRNGKey(1))
    toks, _ = tiny_batch(CFG)
    logits = model_lib.forward(params, toks, CFG, use_pallas=False)
    assert logits.shape == (CFG.batch, CFG.seq, CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_initial_loss_near_uniform():
    params = model_lib.init_params(CFG, jax.random.PRNGKey(2))
    toks, tgts = tiny_batch(CFG)
    loss = model_lib.loss_fn(params, toks, tgts, CFG, use_pallas=False)
    expect = np.log(CFG.vocab)
    assert abs(float(loss) - expect) < 0.5, (float(loss), expect)


def test_pallas_and_ref_model_agree():
    params = model_lib.init_params(CFG, jax.random.PRNGKey(3))
    toks, tgts = tiny_batch(CFG)
    l_ref = model_lib.loss_fn(params, toks, tgts, CFG, use_pallas=False)
    l_pal = model_lib.loss_fn(params, toks, tgts, CFG, use_pallas=True)
    np.testing.assert_allclose(float(l_ref), float(l_pal), rtol=1e-5)


def test_fwd_bwd_outputs_loss_plus_grads():
    params = model_lib.init_params(CFG, jax.random.PRNGKey(4))
    toks, tgts = tiny_batch(CFG)
    fwd_bwd = model_lib.make_fwd_bwd(CFG, use_pallas=False)
    out = fwd_bwd(*params, toks, tgts)
    assert len(out) == 1 + len(params)
    loss, grads = out[0], out[1:]
    assert loss.shape == ()
    for g, p in zip(grads, params):
        assert g.shape == p.shape
        assert bool(jnp.all(jnp.isfinite(g)))
    # Gradient should be non-trivial on every 2-d parameter.
    for (name, _), g in zip(model_lib.param_specs(CFG), grads):
        if g.ndim == 2:
            assert float(jnp.abs(g).max()) > 0, name


def test_gradients_match_pallas_vs_ref():
    params = model_lib.init_params(CFG, jax.random.PRNGKey(5))
    toks, tgts = tiny_batch(CFG)
    g_ref = jax.grad(lambda ps: model_lib.loss_fn(ps, toks, tgts, CFG, False))(params)
    g_pal = jax.grad(lambda ps: model_lib.loss_fn(ps, toks, tgts, CFG, True))(params)
    for a, b in zip(g_ref, g_pal):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-5)


def test_few_adam_steps_reduce_loss():
    params = model_lib.init_params(CFG, jax.random.PRNGKey(6))
    toks, tgts = tiny_batch(CFG)
    loss_grad = jax.jit(
        jax.value_and_grad(
            lambda ps: model_lib.loss_fn(ps, toks, tgts, CFG, use_pallas=False)
        )
    )
    l0, _ = loss_grad(params)
    lr = 1e-2
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    for t in range(20):
        loss, grads = loss_grad(params)
        m = [0.9 * mi + 0.1 * gi for mi, gi in zip(m, grads)]
        v = [0.999 * vi + 0.001 * gi * gi for vi, gi in zip(v, grads)]
        bc1 = 1 - 0.9 ** (t + 1)
        bc2 = 1 - 0.999 ** (t + 1)
        params = [
            p - lr * (mi / bc1) / (jnp.sqrt(vi / bc2) + 1e-8)
            for p, mi, vi in zip(params, m, v)
        ]
    l1, _ = loss_grad(params)
    assert float(l1) < float(l0) - 0.5, (float(l0), float(l1))


def test_causality():
    # Changing a future token must not affect earlier logits.
    params = model_lib.init_params(CFG, jax.random.PRNGKey(7))
    toks, _ = tiny_batch(CFG)
    logits_a = model_lib.forward(params, toks, CFG, use_pallas=False)
    toks_b = toks.at[:, -1].set((toks[:, -1] + 1) % CFG.vocab)
    logits_b = model_lib.forward(params, toks_b, CFG, use_pallas=False)
    np.testing.assert_allclose(
        logits_a[:, :-1, :], logits_b[:, :-1, :], rtol=1e-5, atol=1e-5
    )


@pytest.mark.parametrize("preset", ["llama-nano", "llama-micro"])
def test_presets_construct(preset):
    cfg = PRESETS[preset]
    assert cfg.hidden % cfg.heads == 0
    specs = model_lib.param_specs(cfg)
    assert all(all(d > 0 for d in s) for _, s in specs)
