"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes (including non-multiples of the block size, rank-1
edges) and value scales; assert_allclose against ref.py is THE correctness
signal for everything the Rust runtime later executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.galore_project import galore_project, galore_project_right
from compile.kernels.galore_update import galore_adam_update
from compile.kernels.rmsnorm import rmsnorm

SETTINGS = dict(max_examples=25, deadline=None)


def rand(key, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------- projection


@settings(**SETTINGS)
@given(
    m=st.integers(1, 300),
    n=st.integers(1, 300),
    r=st.integers(1, 64),
    scale=st.sampled_from([1e-3, 1.0, 1e3]),
)
def test_project_matches_ref(m, n, r, scale):
    p = rand(0, (m, r), scale)
    g = rand(1, (m, n), scale)
    got = galore_project(p, g)
    want = ref.galore_project_ref(p, g)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5 * scale * scale * m)


@settings(**SETTINGS)
@given(m=st.integers(1, 300), n=st.integers(1, 300), r=st.integers(1, 64))
def test_project_right_matches_ref(m, n, r):
    g = rand(2, (m, n))
    p = rand(3, (n, r))
    got = galore_project_right(g, p)
    want = ref.galore_project_right_ref(g, p)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5 * n)


@pytest.mark.parametrize("block", [8, 32, 128, 256])
def test_project_block_size_invariance(block):
    p = rand(4, (100, 24))
    g = rand(5, (100, 130))
    base = ref.galore_project_ref(p, g)
    got = galore_project(p, g, block_m=block, block_n=block, block_r=block)
    np.testing.assert_allclose(got, base, rtol=2e-5, atol=1e-3)


def test_project_exact_block_multiples():
    # Shapes exactly on block boundaries exercise the no-padding path.
    p = rand(6, (256, 128))
    g = rand(7, (256, 384))
    np.testing.assert_allclose(
        galore_project(p, g), ref.galore_project_ref(p, g), rtol=2e-5, atol=2e-3
    )


# ------------------------------------------------------------- fused update


@settings(**SETTINGS)
@given(
    dim=st.integers(1, 200),
    n=st.integers(1, 200),
    r=st.integers(1, 32),
    step=st.integers(0, 10_000),
)
def test_update_matches_ref(dim, n, r, step):
    p = rand(8, (dim, r))
    rr = rand(9, (r, n))
    m = rand(10, (r, n), 0.1)
    v = jnp.abs(rand(11, (r, n), 0.01))
    got = galore_adam_update(p, rr, m, v, float(step))
    want = ref.galore_adam_update_ref(p, rr, m, v, float(step))
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=3e-5, atol=3e-4)


def test_update_zero_state_first_step_is_sign_like():
    # t=0, zero moments: N = g/|g| elementwise (eps aside) ⇒ delta = α·P·sign.
    p = jnp.eye(8, dtype=jnp.float32)
    r = jnp.array([[2.0] * 6] * 8, jnp.float32)
    m = jnp.zeros((8, 6), jnp.float32)
    v = jnp.zeros((8, 6), jnp.float32)
    _, _, delta = galore_adam_update(p, r, m, v, 0.0, alpha=0.5)
    np.testing.assert_allclose(delta, 0.5 * np.ones((8, 6)), rtol=1e-4)


def test_update_moments_recurrence():
    p = rand(12, (16, 4))
    r = rand(13, (4, 32))
    m0 = rand(14, (4, 32))
    v0 = jnp.abs(rand(15, (4, 32)))
    m1, v1, _ = galore_adam_update(p, r, m0, v0, 5.0, beta1=0.9, beta2=0.999)
    np.testing.assert_allclose(m1, 0.9 * m0 + 0.1 * r, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(v1, 0.999 * v0 + 0.001 * r * r, rtol=1e-5, atol=1e-7)


def test_update_alpha_scaling():
    p = rand(16, (12, 3))
    r = rand(17, (3, 20))
    m = jnp.zeros((3, 20))
    v = jnp.zeros((3, 20))
    _, _, d1 = galore_adam_update(p, r, m, v, 0.0, alpha=1.0)
    _, _, d2 = galore_adam_update(p, r, m, v, 0.0, alpha=0.125)
    np.testing.assert_allclose(d1 * 0.125, d2, rtol=1e-6)


# ---------------------------------------------------------------- rmsnorm


@settings(**SETTINGS)
@given(
    rows=st.integers(1, 300),
    hidden=st.sampled_from([8, 64, 127, 256]),
    scale=st.sampled_from([1e-2, 1.0, 1e2]),
)
def test_rmsnorm_matches_ref(rows, hidden, scale):
    x = rand(18, (rows, hidden), scale)
    w = 1.0 + 0.1 * rand(19, (hidden,))
    np.testing.assert_allclose(
        rmsnorm(x, w), ref.rmsnorm_ref(x, w), rtol=1e-4, atol=1e-5 * scale
    )


def test_rmsnorm_unit_rows():
    # Rows with RMS 1 pass through scaled by w only.
    x = jnp.ones((4, 16), jnp.float32)
    w = 2.0 * jnp.ones((16,), jnp.float32)
    np.testing.assert_allclose(rmsnorm(x, w), 2.0 * np.ones((4, 16)), rtol=1e-4)


def test_rmsnorm_gradients_match_ref():
    x = rand(20, (33, 48))
    w = 1.0 + 0.1 * rand(21, (48,))
    cot = rand(22, (33, 48))
    gx_k, gw_k = jax.grad(lambda x, w: jnp.sum(rmsnorm(x, w) * cot), (0, 1))(x, w)
    gx_r, gw_r = jax.grad(
        lambda x, w: jnp.sum(ref.rmsnorm_ref(x, w) * cot), (0, 1)
    )(x, w)
    np.testing.assert_allclose(gx_k, gx_r, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(gw_k, gw_r, rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(rows=st.integers(1, 200), block=st.sampled_from([16, 64, 128]))
def test_rmsnorm_block_rows_invariance(rows, block):
    x = rand(23, (rows, 32))
    w = jnp.ones((32,), jnp.float32)
    np.testing.assert_allclose(
        rmsnorm(x, w, 1e-5, block), ref.rmsnorm_ref(x, w), rtol=1e-4, atol=1e-6
    )


# ----------------------------------------------------- algebraic invariants


def test_projection_roundtrip_on_low_rank_gradient():
    # G of rank ≤ r, P = top-r left singular vectors ⇒ P·(PᵀG) == G.
    a = rand(24, (64, 8))
    b = rand(25, (8, 96))
    g = a @ b
    u, _, _ = jnp.linalg.svd(g, full_matrices=False)
    p = u[:, :8]
    r = galore_project(p, g)
    rec = p @ r
    np.testing.assert_allclose(rec, g, rtol=1e-3, atol=1e-3)


def test_update_then_apply_descends_quadratic():
    # End-to-end kernel loop: minimize ½‖W−T‖² in a rank-r subspace.
    key = jax.random.PRNGKey(42)
    t_lowrank = (
        jax.random.normal(key, (32, 4)) @ jax.random.normal(key, (4, 48))
    ).astype(jnp.float32)
    w = jnp.zeros((32, 48), jnp.float32)
    m = jnp.zeros((4, 48), jnp.float32)
    v = jnp.zeros((4, 48), jnp.float32)
    u, _, _ = jnp.linalg.svd(t_lowrank, full_matrices=False)
    p = u[:, :4]
    # Adam's normalized update moves ~lr per element per step; target
    # entries are O(2), so 200 steps at lr=0.2 reach the basin comfortably.
    for step in range(200):
        g = w - t_lowrank
        r = galore_project(p, g)
        m, v, delta = galore_adam_update(p, r, m, v, float(step), alpha=1.0)
        w = w - 0.2 * delta
    rel = float(jnp.linalg.norm(w - t_lowrank) / jnp.linalg.norm(t_lowrank))
    assert rel < 0.05, rel
