"""AOT pipeline: lowered HLO text is well-formed and parameterized right."""

import json
import os
import subprocess
import sys

import pytest

from compile import aot, model as model_lib
from compile.model import PRESETS


def entry_param_count(text: str) -> int:
    """Parameters of the ENTRY computation only (nested computations —
    fusions, reducers — declare their own)."""
    entry = text[text.index("ENTRY "):]
    body = entry[: entry.index("\n}")]
    return body.count(" parameter(")


def test_hlo_text_lowering_nano():
    cfg = PRESETS["llama-nano"]
    text = aot.lower_model(cfg, use_pallas=False)
    assert text.startswith("HloModule")
    # One parameter per model weight + tokens + targets.
    n_inputs = len(model_lib.param_specs(cfg)) + 2
    assert entry_param_count(text) == n_inputs, entry_param_count(text)
    # Output tuple: loss + one grad per param.
    assert "ROOT" in text


def test_forward_lowering_nano():
    cfg = PRESETS["llama-nano"]
    text = aot.lower_forward(cfg, use_pallas=False)
    assert text.startswith("HloModule")
    n_inputs = len(model_lib.param_specs(cfg)) + 1
    assert entry_param_count(text) == n_inputs


def test_galore_kernel_shapes_cover_2d_params():
    cfg = PRESETS["llama-nano"]
    shapes = aot.galore_kernel_shapes(cfg, rank=16)
    # every eligible 2-d param (rows, cols) must map to (min, max, 16)
    for name, shape in model_lib.param_specs(cfg):
        if len(shape) == 2 and min(shape) > 16:
            assert (min(shape), max(shape), 16) in shapes, (name, shape)
    # and the convention is always min-first
    assert all(d <= n for d, n, _ in shapes)


def test_update_kernel_lowering():
    text = aot.lower_galore_update(64, 48, 8, alpha=0.25)
    assert text.startswith("HloModule")
    assert entry_param_count(text) == 5  # p, r, m, v, step


@pytest.mark.slow
def test_cli_end_to_end(tmp_path):
    env = dict(os.environ)
    out = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--preset", "llama-nano",
         "--out-dir", str(tmp_path), "--no-pallas"],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env, capture_output=True, text=True, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    manifest = json.loads((tmp_path / "manifest_llama-nano.json").read_text())
    assert manifest["preset"] == "llama-nano"
    assert manifest["n_params"] == model_lib.n_params(PRESETS["llama-nano"])
    assert (tmp_path / manifest["artifacts"]["fwd_bwd"]).exists()
    assert (tmp_path / manifest["artifacts"]["forward"]).exists()
