"""AOT pipeline: lower L2/L1 jax functions to HLO-text artifacts + manifest.

HLO *text* is the interchange format (not serialized HloModuleProto): jax
≥ 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

Artifacts (under --out-dir, default ../artifacts):
  model_<preset>.hlo.txt          fwd_bwd: (params..., tokens, targets) -> (loss, grads...)
  forward_<preset>.hlo.txt        forward: (params..., tokens) -> (logits,)
  galore_update_<d>x<n>x<r>.hlo.txt   fused Pallas update kernel, per layer shape
  manifest_<preset>.json          parameter names/shapes, io spec, kernel index

Usage:
  python -m compile.aot --preset llama-nano [--out-dir ../artifacts]
         [--no-pallas] [--kernels] [--alpha 0.25]
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model as model_lib
from compile.kernels.galore_update import galore_adam_update


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_model(cfg, use_pallas: bool):
    specs = model_lib.param_specs(cfg)
    args = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in specs]
    tok = jax.ShapeDtypeStruct((cfg.batch, cfg.seq), jnp.int32)
    fwd_bwd = model_lib.make_fwd_bwd(cfg, use_pallas)
    lowered = jax.jit(fwd_bwd).lower(*args, tok, tok)
    return to_hlo_text(lowered)


def lower_forward(cfg, use_pallas: bool):
    specs = model_lib.param_specs(cfg)
    args = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in specs]
    tok = jax.ShapeDtypeStruct((cfg.batch, cfg.seq), jnp.int32)
    fwd = model_lib.make_forward(cfg, use_pallas)
    lowered = jax.jit(fwd).lower(*args, tok)
    return to_hlo_text(lowered)


def galore_kernel_shapes(cfg, rank: int):
    """Distinct (dim, n, rank) shapes of the fused update kernel across the
    model's GaLore-eligible (2-d) parameters. Convention matches Alg. 1's
    min-side projection: dim = min(rows, cols) (the projected side, P is
    (dim, r)), n = max(rows, cols). Tall parameters are handled by the Rust
    engine transposing G in/out — identical math, one kernel per shape."""
    shapes = set()
    for name, shape in model_lib.param_specs(cfg):
        if len(shape) == 2 and min(shape) > rank:
            shapes.add((min(shape), max(shape), rank))
    return sorted(shapes)


def lower_galore_update(dim: int, n: int, rank: int, alpha: float):
    p = jax.ShapeDtypeStruct((dim, rank), jnp.float32)
    r = jax.ShapeDtypeStruct((rank, n), jnp.float32)
    m = jax.ShapeDtypeStruct((rank, n), jnp.float32)
    v = jax.ShapeDtypeStruct((rank, n), jnp.float32)
    step = jax.ShapeDtypeStruct((), jnp.float32)

    def fn(p, r, m, v, step):
        return galore_adam_update(p, r, m, v, step, alpha=alpha)

    lowered = jax.jit(fn).lower(p, r, m, v, step)
    return to_hlo_text(lowered)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="llama-nano")
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    ap.add_argument("--no-pallas", action="store_true",
                    help="use jnp reference ops instead of Pallas kernels "
                         "inside the model (identical numerics)")
    ap.add_argument("--kernels", action="store_true",
                    help="also lower standalone GaLore update kernels for "
                         "each eligible layer shape")
    ap.add_argument("--rank", type=int, default=0,
                    help="GaLore rank for kernel lowering (default h/4)")
    ap.add_argument("--alpha", type=float, default=1.0,
                    help="scale baked into the update kernel; the Rust "
                         "engine applies the configured GaLore alpha on top, "
                         "so 1.0 keeps the artifact alpha-agnostic")
    args = ap.parse_args()

    cfg = model_lib.PRESETS[args.preset]
    os.makedirs(args.out_dir, exist_ok=True)
    use_pallas = not args.no_pallas

    manifest = {
        "preset": cfg.name,
        "hidden": cfg.hidden,
        "intermediate": cfg.intermediate,
        "heads": cfg.heads,
        "layers": cfg.layers,
        "vocab": cfg.vocab,
        "seq": cfg.seq,
        "batch": cfg.batch,
        "n_params": model_lib.n_params(cfg),
        "params": [
            {"name": n, "shape": list(s)} for n, s in model_lib.param_specs(cfg)
        ],
        "use_pallas": use_pallas,
        "artifacts": {},
        "kernels": [],
    }

    path = os.path.join(args.out_dir, f"model_{cfg.name}.hlo.txt")
    text = lower_model(cfg, use_pallas)
    with open(path, "w") as f:
        f.write(text)
    manifest["artifacts"]["fwd_bwd"] = os.path.basename(path)
    print(f"wrote {path} ({len(text)} chars)")

    path = os.path.join(args.out_dir, f"forward_{cfg.name}.hlo.txt")
    text = lower_forward(cfg, use_pallas)
    with open(path, "w") as f:
        f.write(text)
    manifest["artifacts"]["forward"] = os.path.basename(path)
    print(f"wrote {path} ({len(text)} chars)")

    if args.kernels:
        rank = args.rank or max(1, cfg.hidden // 4)
        for dim, n, r in galore_kernel_shapes(cfg, rank):
            kpath = os.path.join(
                args.out_dir, f"galore_update_{dim}x{n}x{r}.hlo.txt"
            )
            text = lower_galore_update(dim, n, r, args.alpha)
            with open(kpath, "w") as f:
                f.write(text)
            manifest["kernels"].append(
                {"dim": dim, "n": n, "rank": r, "alpha": args.alpha,
                 "file": os.path.basename(kpath)}
            )
            print(f"wrote {kpath} ({len(text)} chars)")

    mpath = os.path.join(args.out_dir, f"manifest_{cfg.name}.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
