"""Pallas kernel: fused RMSNorm (used twice per transformer block).

A single VMEM pass per row-block: square-reduce, rsqrt, scale — vs the
unfused jnp version's three HBM round-trips. Grid is 1-D over row blocks;
the hidden dimension stays resident in VMEM (hidden ≤ 4096 ⇒ ≤ 16 KiB/row
in f32, comfortably within the ~16 MiB VMEM budget at our block sizes).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 128


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...]
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = x * (1.0 / jnp.sqrt(var + eps)) * w_ref[...]


def _rmsnorm_bwd_kernel(x_ref, w_ref, g_ref, dx_ref, dw_ref, *, eps: float):
    """Hand-derived VJP, one row-block per grid step.

    y_i = w_i · x_i · inv, inv = rsqrt(mean(x²)+eps):
      dx_j = inv·w_j·g_j − (inv³·x_j/H)·Σ_i g_i w_i x_i
      dw_i = Σ_rows g_i · x_i · inv            (accumulated across blocks)
    """
    i = pl.program_id(0)
    x = x_ref[...]
    w = w_ref[...]
    g = g_ref[...]
    hidden = x.shape[-1]
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    inv = 1.0 / jnp.sqrt(var + eps)
    gwx = jnp.sum(g * w * x, axis=-1, keepdims=True)
    dx_ref[...] = inv * w * g - (inv ** 3) * x * gwx / hidden

    @pl.when(i == 0)
    def _init():
        dw_ref[...] = jnp.zeros_like(dw_ref)

    dw_ref[...] += jnp.sum(g * x * inv, axis=0)


def _rmsnorm_raw(x, weight, eps: float, block_rows: int):
    rows, hidden = x.shape
    assert weight.shape == (hidden,)
    br = min(block_rows, rows)
    grid = (pl.cdiv(rows, br),)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, hidden), lambda i: (i, 0)),
            pl.BlockSpec((hidden,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, hidden), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, hidden), jnp.float32),
        interpret=True,
    )(x, weight)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def rmsnorm(x, weight, eps: float = 1e-5,
            block_rows: int = DEFAULT_BLOCK_ROWS):
    """RMSNorm over the last axis of a 2-d input (rows, hidden).

    Differentiable: forward and backward are both Pallas kernels, so the
    fused norm lowers into the fwd_bwd artifact end to end.
    """
    return _rmsnorm_raw(x, weight, eps, block_rows)


def _rmsnorm_fwd(x, weight, eps, block_rows):
    return _rmsnorm_raw(x, weight, eps, block_rows), (x, weight)


def _rmsnorm_bwd(eps, block_rows, residuals, g):
    x, weight = residuals
    rows, hidden = x.shape
    br = min(block_rows, rows)
    grid = (pl.cdiv(rows, br),)
    dx, dw = pl.pallas_call(
        functools.partial(_rmsnorm_bwd_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, hidden), lambda i: (i, 0)),
            pl.BlockSpec((hidden,), lambda i: (0,)),
            pl.BlockSpec((br, hidden), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((br, hidden), lambda i: (i, 0)),
            pl.BlockSpec((hidden,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, hidden), jnp.float32),
            jax.ShapeDtypeStruct((hidden,), jnp.float32),
        ],
        interpret=True,
    )(x, weight, g)
    return dx, dw


rmsnorm.defvjp(_rmsnorm_fwd, _rmsnorm_bwd)
