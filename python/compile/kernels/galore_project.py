"""Pallas kernel: GaLore gradient projection R = Pᵀ G (§3).

Hardware adaptation (DESIGN.md §2): the paper runs this as a cuBLAS GEMM on
H100 tensor cores. On TPU the same contraction targets the MXU systolic
array; the BlockSpec schedule below streams (bm × bn) tiles of G and
(bm × br) tiles of P through VMEM while accumulating the (br × bn) output
tile across the m-dimension grid axis — the HBM↔VMEM pipeline a CUDA kernel
would express with threadblocks + shared memory.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO with identical numerics
(see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-shaped tiles. 128 matches the systolic array edge; smaller shapes are
# handled by clamping to the actual dimension (grid of 1).
DEFAULT_BLOCK = 128


def _project_kernel(p_ref, g_ref, out_ref, *, m_total: int, bm: int):
    """One (br × bn) output tile; grid axis 2 walks m-blocks (accumulate).

    The m axis is the contraction: its final partial tile is padded by the
    runtime (with NaN in interpret mode), so pad rows are masked to zero
    before the dot — on real TPU the same mask makes the pad lanes inert.
    """
    mb = pl.program_id(2)

    @pl.when(mb == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    valid = m_total - mb * bm  # rows of this tile that are in-bounds
    rows = jax.lax.broadcasted_iota(jnp.int32, p_ref.shape, 0)
    p = jnp.where(rows < valid, p_ref[...], 0.0)
    rows_g = jax.lax.broadcasted_iota(jnp.int32, g_ref.shape, 0)
    g = jnp.where(rows_g < valid, g_ref[...], 0.0)
    # fp32 accumulate on the MXU: (br, bm) x (bm, bn).
    out_ref[...] += jnp.dot(p.T, g, preferred_element_type=jnp.float32).astype(
        out_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_r"))
def galore_project(p, g, block_m: int = DEFAULT_BLOCK,
                   block_n: int = DEFAULT_BLOCK, block_r: int = DEFAULT_BLOCK):
    """R = Pᵀ G with P: (m, r), G: (m, n) → (r, n)."""
    m, r = p.shape
    m2, n = g.shape
    assert m == m2, f"shape mismatch: P {p.shape} vs G {g.shape}"
    bm, bn, br = min(block_m, m), min(block_n, n), min(block_r, r)
    grid = (pl.cdiv(r, br), pl.cdiv(n, bn), pl.cdiv(m, bm))
    return pl.pallas_call(
        functools.partial(_project_kernel, m_total=m, bm=bm),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, br), lambda i, j, k: (k, i)),  # P tile
            pl.BlockSpec((bm, bn), lambda i, j, k: (k, j)),  # G tile
        ],
        out_specs=pl.BlockSpec((br, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, n), jnp.float32),
        interpret=True,
    )(p, g)


def _project_right_kernel(g_ref, p_ref, out_ref, *, k_total: int, bk: int):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    valid = k_total - kb * bk  # contraction-axis mask (see _project_kernel)
    cols_g = jax.lax.broadcasted_iota(jnp.int32, g_ref.shape, 1)
    g = jnp.where(cols_g < valid, g_ref[...], 0.0)
    rows_p = jax.lax.broadcasted_iota(jnp.int32, p_ref.shape, 0)
    p = jnp.where(rows_p < valid, p_ref[...], 0.0)
    out_ref[...] += jnp.dot(g, p, preferred_element_type=jnp.float32).astype(
        out_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_r"))
def galore_project_right(g, p, block_m: int = DEFAULT_BLOCK,
                         block_n: int = DEFAULT_BLOCK,
                         block_r: int = DEFAULT_BLOCK):
    """R = G P with G: (m, n), P: (n, r) → (m, r) (tall-parameter side)."""
    m, n = g.shape
    n2, r = p.shape
    assert n == n2, f"shape mismatch: G {g.shape} vs P {p.shape}"
    bm, bn, br = min(block_m, m), min(block_n, n), min(block_r, r)
    grid = (pl.cdiv(m, bm), pl.cdiv(r, br), pl.cdiv(n, bn))
    return pl.pallas_call(
        functools.partial(_project_right_kernel, k_total=n, bk=bn),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, k)),  # G tile
            pl.BlockSpec((bn, br), lambda i, j, k: (k, j)),  # P tile
        ],
        out_specs=pl.BlockSpec((bm, br), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, r), jnp.float32),
        interpret=True,
    )(g, p)
