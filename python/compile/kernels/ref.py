"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here; pytest
asserts allclose between kernel and oracle across shape/dtype sweeps. The
oracles are also used directly by model.py when ``use_pallas=False`` (for
fast lowering of large presets — identical numerics, no interpret-mode
overhead).
"""

import jax.numpy as jnp


def rmsnorm_ref(x, weight, eps: float = 1e-5):
    """RMSNorm over the last axis: x * w / rms(x)."""
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * (1.0 / jnp.sqrt(var + eps)) * weight


def galore_project_ref(p, g):
    """R = Pᵀ G.  P: (m, r), G: (m, n) → R: (r, n). §3 projection."""
    return p.T @ g


def galore_project_right_ref(g, p):
    """R = G P.  G: (m, n), P: (n, r) → R: (m, r). Tall-parameter side."""
    return g @ p


def galore_adam_update_ref(p, r, m, v, step, beta1=0.9, beta2=0.999,
                           eps=1e-8, alpha=0.25):
    """Fused low-rank Adam update + back-projection (§3, Alg. 1 body).

    Inputs:  P (m, rank) projector, R (rank, n) projected gradient,
             M, V (rank, n) moments, step (0-based, scalar f32).
    Returns: (new_m, new_v, delta) where delta = alpha * P @ N is the
             full-space update direction (caller applies W -= lr * delta).
    """
    new_m = beta1 * m + (1.0 - beta1) * r
    new_v = beta2 * v + (1.0 - beta2) * jnp.square(r)
    bc1 = 1.0 - beta1 ** (step + 1.0)
    bc2 = 1.0 - beta2 ** (step + 1.0)
    n_hat = (new_m / bc1) / (jnp.sqrt(new_v / bc2) + eps)
    delta = alpha * (p @ n_hat)
    return new_m, new_v, delta
