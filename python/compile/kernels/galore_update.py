"""Pallas kernel: fused low-rank Adam moment update + back-projection.

This is the per-step GaLore hot-spot after projection (§3, Alg. 1 body):

    M' = β₁M + (1−β₁)R          (rank × n, elementwise — VPU)
    V' = β₂V + (1−β₂)R²
    N  = (M'/bc₁) / (√(V'/bc₂) + ε)
    ΔW = α · P N                 (m × n, contraction — MXU)

Fusing the moment update with the reprojection means R, M, V stream through
VMEM exactly once per step and N never round-trips to HBM — the same
fusion FSDP's per-layer hook achieves at the framework level (Fig. 2).

Grid: 1-D over column blocks of n. Each step loads (rank × bn) tiles of
R/M/V plus the whole P (m × rank — small, r ≪ m), computes the moment tile,
and emits the (m × bn) tile of ΔW. VMEM footprint per step:
rank·bn·3 + m·rank + m·bn floats.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 128


def _update_kernel(step_ref, p_ref, r_ref, m_ref, v_ref,
                   new_m_ref, new_v_ref, delta_ref, *,
                   beta1: float, beta2: float, eps: float, alpha: float):
    step = step_ref[0]
    r = r_ref[...]
    new_m = beta1 * m_ref[...] + (1.0 - beta1) * r
    new_v = beta2 * v_ref[...] + (1.0 - beta2) * r * r
    bc1 = 1.0 - beta1 ** (step + 1.0)
    bc2 = 1.0 - beta2 ** (step + 1.0)
    n_hat = (new_m / bc1) / (jnp.sqrt(new_v / bc2) + eps)
    new_m_ref[...] = new_m
    new_v_ref[...] = new_v
    delta_ref[...] = alpha * jnp.dot(
        p_ref[...], n_hat, preferred_element_type=jnp.float32
    )


@functools.partial(
    jax.jit,
    static_argnames=("beta1", "beta2", "eps", "alpha", "block_n"),
)
def galore_adam_update(p, r, m, v, step, beta1: float = 0.9,
                       beta2: float = 0.999, eps: float = 1e-8,
                       alpha: float = 0.25, block_n: int = DEFAULT_BLOCK_N):
    """Fused GaLore/Adam update.

    Args:
      p: (dim, rank) projector (orthonormal columns).
      r: (rank, n) projected gradient.
      m, v: (rank, n) Adam moments.
      step: scalar f32, 0-based step (bias correction).
    Returns:
      (new_m, new_v, delta) with delta = α·P·N of shape (dim, n).
    """
    dim, rank = p.shape
    rank2, n = r.shape
    assert rank == rank2 and m.shape == r.shape and v.shape == r.shape
    bn = min(block_n, n)
    grid = (pl.cdiv(n, bn),)
    step_arr = jnp.asarray(step, dtype=jnp.float32).reshape(1)
    return pl.pallas_call(
        functools.partial(
            _update_kernel, beta1=beta1, beta2=beta2, eps=eps, alpha=alpha
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda j: (0,)),           # step scalar
            pl.BlockSpec((dim, rank), lambda j: (0, 0)),  # P (whole)
            pl.BlockSpec((rank, bn), lambda j: (0, j)),   # R tile
            pl.BlockSpec((rank, bn), lambda j: (0, j)),   # M tile
            pl.BlockSpec((rank, bn), lambda j: (0, j)),   # V tile
        ],
        out_specs=[
            pl.BlockSpec((rank, bn), lambda j: (0, j)),
            pl.BlockSpec((rank, bn), lambda j: (0, j)),
            pl.BlockSpec((dim, bn), lambda j: (0, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rank, n), jnp.float32),
            jax.ShapeDtypeStruct((rank, n), jnp.float32),
            jax.ShapeDtypeStruct((dim, n), jnp.float32),
        ],
        interpret=True,
    )(step_arr, p, r, m, v)
