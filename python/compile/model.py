"""L2: Llama-family transformer in JAX (build-time only).

Architecture follows Table 2 / the Llama reference: RMSNorm (pre-norm),
rotary position embeddings, causal multi-head attention, SwiGLU MLP,
untied LM head. The fused RMSNorm Pallas kernel from L1 lowers into the
same HLO as the rest of the model (``use_pallas=True``).

The lowered artifact is ``fwd_bwd``: (params..., tokens, targets) →
(loss, grads...) — the Rust coordinator owns parameters, optimizer and the
training loop; this graph is the only compute it delegates to XLA.
"""

import dataclasses
import functools
import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.rmsnorm import rmsnorm as rmsnorm_pallas


@dataclasses.dataclass(frozen=True)
class ModelCfg:
    name: str
    hidden: int
    intermediate: int
    heads: int
    layers: int
    vocab: int
    seq: int
    batch: int

    @property
    def head_dim(self) -> int:
        assert self.hidden % self.heads == 0
        return self.hidden // self.heads


# Presets (DESIGN.md §6). llama-7b matches Table 2; llama3-8b matches the
# Table 1 memory rows. Large presets exist for shape math / the memory
# model — only nano..100m are meant to execute on CPU.
PRESETS: Dict[str, ModelCfg] = {
    cfg.name: cfg
    for cfg in [
        ModelCfg("llama-nano", 64, 176, 4, 2, 256, 64, 4),
        ModelCfg("llama-micro", 128, 352, 4, 4, 512, 64, 4),
        ModelCfg("llama-mini", 256, 688, 8, 6, 2048, 128, 4),
        ModelCfg("llama-100m", 640, 1712, 10, 10, 8192, 256, 4),
        ModelCfg("llama-1b", 2048, 5504, 16, 24, 32000, 1024, 1),
        ModelCfg("llama-7b", 4096, 11008, 32, 32, 32000, 1024, 1),
        ModelCfg("llama3-8b", 4096, 14336, 32, 32, 128256, 2048, 1),
    ]
}


def param_specs(cfg: ModelCfg) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) list — the ABI between aot.py and the Rust
    coordinator (mirrored in rust/src/model/llama.rs; checked by the
    manifest test)."""
    specs: List[Tuple[str, Tuple[int, ...]]] = [
        ("embed.weight", (cfg.vocab, cfg.hidden)),
    ]
    for i in range(cfg.layers):
        p = f"layers.{i}."
        specs += [
            (p + "attn_norm.weight", (cfg.hidden,)),
            (p + "attn.wq", (cfg.hidden, cfg.hidden)),
            (p + "attn.wk", (cfg.hidden, cfg.hidden)),
            (p + "attn.wv", (cfg.hidden, cfg.hidden)),
            (p + "attn.wo", (cfg.hidden, cfg.hidden)),
            (p + "mlp_norm.weight", (cfg.hidden,)),
            (p + "mlp.w_gate", (cfg.hidden, cfg.intermediate)),
            (p + "mlp.w_up", (cfg.hidden, cfg.intermediate)),
            (p + "mlp.w_down", (cfg.intermediate, cfg.hidden)),
        ]
    specs += [
        ("final_norm.weight", (cfg.hidden,)),
        ("lm_head.weight", (cfg.hidden, cfg.vocab)),
    ]
    return specs


def n_params(cfg: ModelCfg) -> int:
    return sum(math.prod(s) for _, s in param_specs(cfg))


def init_params(cfg: ModelCfg, key) -> List[jnp.ndarray]:
    """Scaled-normal init (0.02 for embeddings/projections, 1 for norms),
    matching the Llama reference."""
    out = []
    for name, shape in param_specs(cfg):
        key, sub = jax.random.split(key)
        if name.endswith("norm.weight"):
            out.append(jnp.ones(shape, jnp.float32))
        elif "w_down" in name or "attn.wo" in name:
            # residual-branch outputs get the depth-scaled init
            std = 0.02 / math.sqrt(2 * cfg.layers)
            out.append(std * jax.random.normal(sub, shape, jnp.float32))
        else:
            out.append(0.02 * jax.random.normal(sub, shape, jnp.float32))
    return out


def _rope_tables(seq: int, head_dim: int):
    half = head_dim // 2
    freqs = 1.0 / (10000.0 ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    t = jnp.arange(seq, dtype=jnp.float32)
    angles = jnp.outer(t, freqs)  # (seq, half)
    return jnp.cos(angles), jnp.sin(angles)


def _apply_rope(x, cos, sin):
    """x: (batch, heads, seq, head_dim). Rotate pairs (even, odd)."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[None, None, :, :]
    s = sin[None, None, :, :]
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)


def _norm(x2d, weight, use_pallas: bool):
    if use_pallas:
        return rmsnorm_pallas(x2d, weight)
    return ref.rmsnorm_ref(x2d, weight)


def forward(params: List[jnp.ndarray], tokens, cfg: ModelCfg,
            use_pallas: bool = True):
    """tokens: (batch, seq) int32 → logits (batch, seq, vocab)."""
    specs = param_specs(cfg)
    named = dict(zip([n for n, _ in specs], params))
    b, s = tokens.shape
    h = named["embed.weight"][tokens]  # (b, s, hidden)
    cos, sin = _rope_tables(s, cfg.head_dim)
    causal = jnp.tril(jnp.ones((s, s), jnp.bool_))

    for i in range(cfg.layers):
        p = f"layers.{i}."
        # --- attention block ---
        x = _norm(h.reshape(b * s, cfg.hidden), named[p + "attn_norm.weight"],
                  use_pallas).reshape(b, s, cfg.hidden)
        q = (x @ named[p + "attn.wq"]).reshape(b, s, cfg.heads, cfg.head_dim)
        k = (x @ named[p + "attn.wk"]).reshape(b, s, cfg.heads, cfg.head_dim)
        v = (x @ named[p + "attn.wv"]).reshape(b, s, cfg.heads, cfg.head_dim)
        q = _apply_rope(q.transpose(0, 2, 1, 3), cos, sin)
        k = _apply_rope(k.transpose(0, 2, 1, 3), cos, sin)
        v = v.transpose(0, 2, 1, 3)
        scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / math.sqrt(cfg.head_dim)
        scores = jnp.where(causal[None, None, :, :], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        attn = jnp.einsum("bhqk,bhkd->bhqd", probs, v)
        attn = attn.transpose(0, 2, 1, 3).reshape(b, s, cfg.hidden)
        h = h + attn @ named[p + "attn.wo"]
        # --- MLP block (SwiGLU) ---
        x = _norm(h.reshape(b * s, cfg.hidden), named[p + "mlp_norm.weight"],
                  use_pallas).reshape(b, s, cfg.hidden)
        gate = jax.nn.silu(x @ named[p + "mlp.w_gate"])
        up = x @ named[p + "mlp.w_up"]
        h = h + (gate * up) @ named[p + "mlp.w_down"]

    x = _norm(h.reshape(b * s, cfg.hidden), named["final_norm.weight"],
              use_pallas).reshape(b, s, cfg.hidden)
    return x @ named["lm_head.weight"]


def loss_fn(params: List[jnp.ndarray], tokens, targets, cfg: ModelCfg,
            use_pallas: bool = True):
    """Mean cross-entropy next-token loss. targets: (batch, seq) int32."""
    logits = forward(params, tokens, cfg, use_pallas)
    logp = jax.nn.log_softmax(logits, axis=-1)
    picked = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return -jnp.mean(picked)


def make_fwd_bwd(cfg: ModelCfg, use_pallas: bool = True):
    """(params..., tokens, targets) → (loss, grad_0, ..., grad_{P-1})."""
    n = len(param_specs(cfg))

    def fwd_bwd(*args):
        params = list(args[:n])
        tokens, targets = args[n], args[n + 1]
        loss, grads = jax.value_and_grad(
            lambda ps: loss_fn(ps, tokens, targets, cfg, use_pallas)
        )(params)
        return (loss, *grads)

    return fwd_bwd


def make_forward(cfg: ModelCfg, use_pallas: bool = True):
    """(params..., tokens) → (logits,) — the eval/serving graph."""
    n = len(param_specs(cfg))

    def fwd(*args):
        params = list(args[:n])
        tokens = args[n]
        return (forward(params, tokens, cfg, use_pallas),)

    return fwd
