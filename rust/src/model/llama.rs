//! Llama architecture shape math (Table 2 + DESIGN.md §6 presets).

/// Rust-side parameter spec (mirrors python/compile/model.py).
#[derive(Clone, Debug, PartialEq)]
pub struct ParamSpecR {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamSpecR {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn matrix_shape(&self) -> (usize, usize) {
        match self.shape.len() {
            1 => (1, self.shape[0]),
            2 => (self.shape[0], self.shape[1]),
            _ => panic!("unsupported rank"),
        }
    }

    pub fn is_2d(&self) -> bool {
        self.shape.len() == 2
    }
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LlamaCfg {
    pub name: &'static str,
    pub hidden: usize,
    pub intermediate: usize,
    pub heads: usize,
    pub layers: usize,
    pub vocab: usize,
    pub seq: usize,
    pub batch: usize,
}

/// Must stay in sync with python/compile/model.py PRESETS.
pub const PRESETS: &[LlamaCfg] = &[
    LlamaCfg { name: "llama-nano",  hidden: 64,   intermediate: 176,   heads: 4,  layers: 2,  vocab: 256,    seq: 64,   batch: 4 },
    LlamaCfg { name: "llama-micro", hidden: 128,  intermediate: 352,   heads: 4,  layers: 4,  vocab: 512,    seq: 64,   batch: 4 },
    LlamaCfg { name: "llama-mini",  hidden: 256,  intermediate: 688,   heads: 8,  layers: 6,  vocab: 2048,   seq: 128,  batch: 4 },
    LlamaCfg { name: "llama-100m",  hidden: 640,  intermediate: 1712,  heads: 10, layers: 10, vocab: 8192,   seq: 256,  batch: 4 },
    LlamaCfg { name: "llama-1b",    hidden: 2048, intermediate: 5504,  heads: 16, layers: 24, vocab: 32000,  seq: 1024, batch: 1 },
    LlamaCfg { name: "llama-7b",    hidden: 4096, intermediate: 11008, heads: 32, layers: 32, vocab: 32000,  seq: 1024, batch: 1 },
    LlamaCfg { name: "llama3-8b",   hidden: 4096, intermediate: 14336, heads: 32, layers: 32, vocab: 128256, seq: 2048, batch: 1 },
];

impl LlamaCfg {
    pub fn preset(name: &str) -> Option<LlamaCfg> {
        PRESETS.iter().find(|c| c.name == name).copied()
    }

    pub fn preset_names() -> Vec<&'static str> {
        PRESETS.iter().map(|c| c.name).collect()
    }

    pub fn head_dim(&self) -> usize {
        self.hidden / self.heads
    }

    /// Ordered parameter list — the artifact ABI.
    pub fn param_specs(&self) -> Vec<ParamSpecR> {
        let mut specs = vec![ParamSpecR {
            name: "embed.weight".into(),
            shape: vec![self.vocab, self.hidden],
        }];
        for i in 0..self.layers {
            let p = format!("layers.{i}.");
            let mut push = |suffix: &str, shape: Vec<usize>| {
                specs.push(ParamSpecR {
                    name: format!("{p}{suffix}"),
                    shape,
                })
            };
            push("attn_norm.weight", vec![self.hidden]);
            push("attn.wq", vec![self.hidden, self.hidden]);
            push("attn.wk", vec![self.hidden, self.hidden]);
            push("attn.wv", vec![self.hidden, self.hidden]);
            push("attn.wo", vec![self.hidden, self.hidden]);
            push("mlp_norm.weight", vec![self.hidden]);
            push("mlp.w_gate", vec![self.hidden, self.intermediate]);
            push("mlp.w_up", vec![self.hidden, self.intermediate]);
            push("mlp.w_down", vec![self.intermediate, self.hidden]);
        }
        specs.push(ParamSpecR {
            name: "final_norm.weight".into(),
            shape: vec![self.hidden],
        });
        specs.push(ParamSpecR {
            name: "lm_head.weight".into(),
            shape: vec![self.hidden, self.vocab],
        });
        specs
    }

    pub fn n_params(&self) -> usize {
        self.param_specs().iter().map(|s| s.numel()).sum()
    }

    /// Per-step FLOPs estimate (fwd+bwd ≈ 6·N·tokens — the standard
    /// transformer approximation used for throughput reporting).
    pub fn step_flops(&self) -> f64 {
        6.0 * self.n_params() as f64 * (self.batch * self.seq) as f64
    }

    /// Default GaLore rank: quarter of hidden (the paper's "quarter of full
    /// rank" setting; §4.3 evaluation and rank 1024 for hidden 4096 in §5).
    pub fn default_rank(&self) -> usize {
        (self.hidden / 4).max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preset_lookup() {
        assert!(LlamaCfg::preset("llama-7b").is_some());
        assert!(LlamaCfg::preset("nope").is_none());
        assert_eq!(LlamaCfg::preset_names().len(), PRESETS.len());
    }

    #[test]
    fn table2_shapes() {
        let c = LlamaCfg::preset("llama-7b").unwrap();
        assert_eq!(
            (c.hidden, c.intermediate, c.heads, c.layers),
            (4096, 11008, 32, 32)
        );
        assert_eq!(c.head_dim(), 128);
        let n = c.n_params();
        assert!(
            (6.4e9..7.1e9).contains(&(n as f64)),
            "7B param count off: {n}"
        );
    }

    #[test]
    fn llama3_8b_param_count() {
        let c = LlamaCfg::preset("llama3-8b").unwrap();
        let n = c.n_params() as f64;
        // Untied head + large vocab: ~8.5B with MHA (the real model uses
        // GQA; our MHA variant runs slightly heavier attention).
        assert!((7.5e9..9.2e9).contains(&n), "{n}");
    }

    #[test]
    fn spec_count_formula() {
        for cfg in PRESETS {
            let specs = cfg.param_specs();
            assert_eq!(specs.len(), 1 + 9 * cfg.layers + 2);
            // rank-1 params: 2 per layer + final norm
            let n1 = specs.iter().filter(|s| s.shape.len() == 1).count();
            assert_eq!(n1, 2 * cfg.layers + 1);
        }
    }

    #[test]
    fn default_rank_is_quarter_hidden() {
        let c = LlamaCfg::preset("llama-7b").unwrap();
        assert_eq!(c.default_rank(), 1024); // §5: rank 1024
    }

    #[test]
    fn nano_params_small_enough_for_tests() {
        let c = LlamaCfg::preset("llama-nano").unwrap();
        assert!(c.n_params() < 200_000, "{}", c.n_params());
    }
}
