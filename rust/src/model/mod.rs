//! Model definitions: the Rust-side mirror of python/compile/model.py.
//!
//! The parameter layout here is the ABI between the coordinator and the
//! AOT artifacts — `LlamaCfg::param_specs` must match python's
//! `param_specs` exactly (checked against the manifest in tests).

mod llama;

pub use llama::{LlamaCfg, ParamSpecR};

use crate::tensor::Matrix;
use crate::util::rng::Pcg64;

/// Initialize parameters as matrices (1-d params become 1×n), matching the
/// init distribution in python's `init_params` (values differ — rust PCG vs
/// jax threefry — but scale/shape semantics are identical).
pub fn init_params(cfg: &LlamaCfg, seed: u64) -> Vec<Matrix> {
    let mut rng = Pcg64::new(seed, 0x11a);
    cfg.param_specs()
        .iter()
        .map(|spec| {
            let (r, c) = spec.matrix_shape();
            if spec.name.ends_with("norm.weight") {
                Matrix::from_vec(r, c, vec![1.0; r * c])
            } else if spec.name.contains("w_down") || spec.name.contains("attn.wo") {
                let std = 0.02 / (2.0 * cfg.layers as f32).sqrt();
                Matrix::randn(r, c, std, &mut rng)
            } else {
                Matrix::randn(r, c, 0.02, &mut rng)
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_shapes_match_specs() {
        let cfg = LlamaCfg::preset("llama-nano").unwrap();
        let params = init_params(&cfg, 1);
        let specs = cfg.param_specs();
        assert_eq!(params.len(), specs.len());
        for (p, s) in params.iter().zip(&specs) {
            assert_eq!(p.shape(), s.matrix_shape(), "{}", s.name);
        }
    }

    #[test]
    fn norms_start_at_one_weights_small() {
        let cfg = LlamaCfg::preset("llama-nano").unwrap();
        let params = init_params(&cfg, 2);
        for (p, s) in params.iter().zip(cfg.param_specs()) {
            if s.name.ends_with("norm.weight") {
                assert!(p.data.iter().all(|&x| x == 1.0), "{}", s.name);
            } else {
                assert!(p.max_abs() < 0.25, "{} too large: {}", s.name, p.max_abs());
                assert!(p.max_abs() > 0.0);
            }
        }
    }

    #[test]
    fn init_deterministic_by_seed() {
        let cfg = LlamaCfg::preset("llama-nano").unwrap();
        let a = init_params(&cfg, 7);
        let b = init_params(&cfg, 7);
        let c = init_params(&cfg, 8);
        // compare a 2-d weight (index 0 = embed); norms are constant 1s.
        assert_eq!(a[0].data, b[0].data);
        assert_ne!(a[0].data, c[0].data);
    }
}
