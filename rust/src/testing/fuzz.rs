//! Corrupt-input entry points for the parser property tests.
//!
//! The wire/quant decoders are deliberately `pub(crate)` — the single-parser
//! invariant (`galore2 lint`) keeps their byte layouts private to the crate.
//! The integration suite (`tests/invariants.rs`) still needs to throw
//! corrupted bytes at exactly those decoders, so this module re-exports them
//! behind result-only wrappers: callers learn *whether* a frame parsed, never
//! the layout. Each sample frame here is a valid encoding the fuzz tests
//! mutate byte-by-byte.

use crate::dist::cluster::{Cmd, Reply};
use crate::dist::{wire, MemoryReport, OptimizerSpec, ParamMeta};
use crate::optim::ser::Reader;
use crate::optim::AdamCfg;
use crate::quant::{LinearQ8, StoredTensor};
use crate::tensor::Matrix;

/// Decode a cluster command frame; `Ok(())` iff it parses.
pub fn decode_cmd_frame(bytes: &[u8]) -> Result<(), String> {
    wire::decode_cmd(bytes).map(|_| ())
}

/// Decode a cluster reply frame; `Ok(())` iff it parses.
pub fn decode_reply_frame(bytes: &[u8]) -> Result<(), String> {
    wire::decode_reply(bytes).map(|_| ())
}

/// Decode a worker setup frame; `Ok(())` iff it parses.
pub fn decode_setup_frame(bytes: &[u8]) -> Result<(), String> {
    wire::decode_setup(bytes).map(|_| ())
}

/// Decode a stored-tensor payload (quantized projector codec).
pub fn decode_stored_tensor(bytes: &[u8]) -> Result<(), String> {
    let mut r = Reader::new(bytes);
    StoredTensor::decode(&mut r).map(|_| ())
}

/// Run the transport framer (`[len u64][payload]`) over an in-memory byte
/// stream; `Ok` carries the payload length so tests can sanity-check it.
pub fn read_frame_bytes(bytes: &[u8]) -> Result<usize, String> {
    let mut cursor = std::io::Cursor::new(bytes);
    wire::read_frame(&mut cursor)
        .map(|payload| payload.len())
        .map_err(|e| e.to_string())
}

/// Wrap a payload in the transport framing (length prefix + bytes).
pub fn frame(payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::new();
    wire::write_frame(&mut out, payload).expect("Vec write cannot fail");
    out
}

/// A valid `Cmd::Step` frame with matrix payloads — the richest command.
pub fn sample_cmd_frame() -> Vec<u8> {
    wire::encode_cmd(&Cmd::Step {
        t: 42,
        lr: 0.125,
        grads: vec![
            Matrix::from_vec(2, 3, vec![1.0, -2.0, 3.5, 0.0, -0.0, f32::NAN]),
            Matrix::from_vec(1, 4, vec![4.0; 4]),
        ],
    })
}

/// A valid `Reply::Params` frame — nested count + matrix payloads, the
/// reply variant with the most length fields to corrupt.
pub fn sample_reply_frame() -> Vec<u8> {
    wire::encode_reply(&Reply::Params(vec![Matrix::from_vec(
        3,
        2,
        vec![1.0, 2.0, -3.0, 0.5, -0.5, 9.0],
    )]))
}

/// A valid `Reply::Report` frame (all-integer payload — any byte pattern
/// decodes, so it only participates in the no-panic properties).
pub fn sample_report_frame() -> Vec<u8> {
    wire::encode_reply(&Reply::Report(MemoryReport {
        rank: 3,
        param_shard_bytes: 1024,
        optimizer_bytes: 2048,
        peak_transient_bytes: 4096,
        traffic_elems: 123_456,
        socket_bytes: 777,
        shm_bytes: 8_888,
    }))
}

/// A valid setup frame (param metas + optimizer spec + seed).
pub fn sample_setup_frame() -> Vec<u8> {
    wire::encode_setup(
        &[
            ParamMeta {
                name: "blocks.0.wq".into(),
                rows: 8,
                cols: 4,
            },
            ParamMeta {
                name: "embed".into(),
                rows: 1,
                cols: 16,
            },
        ],
        &OptimizerSpec::AdamW(AdamCfg::default()),
        0xdead_beef,
        Some(&wire::ShmSetup {
            path: "/tmp/g2w-0-0/slots.shm".into(),
            slot_elems: 192,
        }),
    )
    .expect("AdamW spec is always encodable")
}

/// A valid quantized stored-tensor payload (Q8 blocks + scales).
pub fn sample_stored_tensor() -> Vec<u8> {
    let xs: Vec<f32> = (0..96).map(|i| (i as f32 - 48.0) * 0.25).collect();
    let stored = StoredTensor::Q8 {
        rows: 6,
        cols: 16,
        q: LinearQ8::quantize(&xs),
    };
    let mut out = Vec::new();
    stored.encode(&mut out);
    out
}
