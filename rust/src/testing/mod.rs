//! Test support: property harness + shared integration fixtures.
//!
//! Property-based testing helper (proptest substitute for the offline build).
//!
//! Usage:
//! ```ignore
//! use galore2::testing::prop;
//! prop::check("matmul associates with identity", 100, |g| {
//!     let n = g.usize_in(1, 8);
//!     // ... build case from g, return Ok(()) or Err(description)
//!     Ok(())
//! });
//! ```
//!
//! On failure the harness re-runs the failing case seed and panics with the
//! seed so the case can be replayed deterministically with
//! `PROP_SEED=<seed> cargo test <name>`.

pub mod fixtures;
pub mod fuzz;

pub mod prop {
    use crate::util::rng::Pcg64;

    /// Case generator handed to property closures.
    pub struct Gen {
        rng: Pcg64,
        /// Log of drawn values, printed on failure for diagnosis.
        pub trace: Vec<String>,
    }

    impl Gen {
        pub fn new(seed: u64) -> Gen {
            Gen {
                rng: Pcg64::new(seed, 0xfeed),
                trace: Vec::new(),
            }
        }

        pub fn rng(&mut self) -> &mut Pcg64 {
            &mut self.rng
        }

        pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
            assert!(lo <= hi);
            let v = lo + self.rng.next_below((hi - lo + 1) as u64) as usize;
            self.trace.push(format!("usize[{lo},{hi}]={v}"));
            v
        }

        pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
            let v = lo + (hi - lo) * self.rng.next_f32();
            self.trace.push(format!("f32[{lo},{hi}]={v}"));
            v
        }

        pub fn bool(&mut self) -> bool {
            let v = self.rng.next_u64() & 1 == 1;
            self.trace.push(format!("bool={v}"));
            v
        }

        /// A vector of finite f32s, magnitudes spanning several decades so
        /// numeric edge cases get exercised.
        pub fn vec_f32(&mut self, len: usize) -> Vec<f32> {
            let mut v = vec![0f32; len];
            for x in v.iter_mut() {
                let mag = 10f32.powf(self.rng.next_f32() * 6.0 - 3.0); // 1e-3 .. 1e3
                let sign = if self.rng.next_u64() & 1 == 1 { 1.0 } else { -1.0 };
                *x = sign * mag * self.rng.next_f32();
            }
            self.trace.push(format!("vec_f32(len={len})"));
            v
        }

        /// Normal matrix entries (well-conditioned with high probability).
        pub fn matrix(&mut self, rows: usize, cols: usize) -> Vec<f32> {
            let mut v = vec![0f32; rows * cols];
            self.rng.fill_normal(&mut v, 1.0);
            self.trace.push(format!("matrix({rows}x{cols})"));
            v
        }

        pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
            let i = self.rng.next_below(items.len() as u64) as usize;
            self.trace.push(format!("choose#{i}"));
            &items[i]
        }
    }

    /// Run `cases` random cases of `property`. Panics with the failing seed
    /// and the generator trace on the first failure.
    pub fn check<F>(name: &str, cases: u64, mut property: F)
    where
        F: FnMut(&mut Gen) -> Result<(), String>,
    {
        // Replay mode: a single pinned seed.
        if let Ok(seed_str) = std::env::var("PROP_SEED") {
            let seed: u64 = seed_str.parse().expect("PROP_SEED must be u64");
            let mut g = Gen::new(seed);
            if let Err(msg) = property(&mut g) {
                panic!("property `{name}` failed (replay seed {seed}): {msg}\ntrace: {:?}", g.trace);
            }
            return;
        }
        // Deterministic per-property seed stream: hash of the name.
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for b in name.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
        for case in 0..cases {
            let seed = h.wrapping_add(case.wrapping_mul(0x9e37_79b9_7f4a_7c15));
            let mut g = Gen::new(seed);
            if let Err(msg) = property(&mut g) {
                panic!(
                    "property `{name}` failed on case {case}/{cases}: {msg}\n\
                     replay with: PROP_SEED={seed}\ntrace: {:?}",
                    g.trace
                );
            }
        }
    }

    /// Assert two slices are elementwise close (abs OR rel tolerance).
    pub fn assert_close(a: &[f32], b: &[f32], atol: f32, rtol: f32) -> Result<(), String> {
        if a.len() != b.len() {
            return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
        }
        for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
            let diff = (x - y).abs();
            let tol = atol + rtol * x.abs().max(y.abs());
            if !(diff <= tol) {
                return Err(format!(
                    "mismatch at [{i}]: {x} vs {y} (diff {diff:.3e} > tol {tol:.3e})"
                ));
            }
        }
        Ok(())
    }

    /// Max absolute difference between slices.
    pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
        a.iter()
            .zip(b)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::prop;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        prop::check("trivially true", 50, |g| {
            let _ = g.usize_in(0, 10);
            count += 1;
            Ok(())
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "replay with: PROP_SEED=")]
    fn failing_property_reports_seed() {
        prop::check("always fails", 10, |g| {
            let n = g.usize_in(1, 5);
            Err(format!("boom n={n}"))
        });
    }

    #[test]
    fn assert_close_catches_mismatch() {
        assert!(prop::assert_close(&[1.0, 2.0], &[1.0, 2.0001], 1e-6, 1e-3).is_ok());
        assert!(prop::assert_close(&[1.0], &[1.1], 1e-6, 1e-3).is_err());
        assert!(prop::assert_close(&[1.0], &[1.0, 2.0], 1.0, 1.0).is_err());
    }

    #[test]
    fn deterministic_given_name() {
        let mut first: Vec<usize> = Vec::new();
        prop::check("det", 5, |g| {
            first.push(g.usize_in(0, 1000));
            Ok(())
        });
        let mut second: Vec<usize> = Vec::new();
        prop::check("det", 5, |g| {
            second.push(g.usize_in(0, 1000));
            Ok(())
        });
        assert_eq!(first, second);
    }
}
