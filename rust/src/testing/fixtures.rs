//! Shared integration-test fixtures.
//!
//! The tiny-model/trainer builders used to be duplicated (with drifting
//! parameters) across `tests/determinism.rs`, `tests/engine_parity.rs`
//! and `tests/end_to_end.rs`; they live here once so every suite —
//! including `tests/resharding.rs` — trains the same fixture models.
//!
//! Skip policy: suites that need the compiled fwd/bwd artifacts guard on
//! [`artifacts_ready`] and return early when `make artifacts` hasn't run.
//! CI jobs that must not lose coverage silently set `GALORE2_DENY_SKIP=1`,
//! which turns that graceful skip into a hard failure.

use crate::config::TrainConfig;
use crate::dist::ParamMeta;
use crate::tensor::Matrix;
use crate::util::rng::Pcg64;
use std::path::PathBuf;

/// The repo's artifact directory (`make artifacts` output).
pub fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Whether the llama-nano artifacts exist. Under `GALORE2_DENY_SKIP=1`
/// (set by CI for suites that may not skip) missing artifacts PANIC
/// instead of letting the caller return early, so a skipped test can
/// never masquerade as a green job.
pub fn artifacts_ready() -> bool {
    let ready = artifacts_dir().join("manifest_llama-nano.json").exists();
    if !ready && std::env::var_os("GALORE2_DENY_SKIP").is_some() {
        panic!(
            "GALORE2_DENY_SKIP is set but the llama-nano artifacts are missing — \
             a test was about to skip silently; run `make artifacts PRESET=llama-nano`"
        );
    }
    ready
}

/// The shared tiny-trainer config (llama-nano, deterministic corpus, no
/// periodic eval). Suites override individual fields via struct-update
/// syntax where they need a different cadence.
pub fn tiny_train_cfg(optimizer: &str, run: &str, steps: u64) -> TrainConfig {
    TrainConfig {
        preset: "llama-nano".into(),
        artifacts_dir: artifacts_dir(),
        out_dir: std::env::temp_dir().join("galore2_it"),
        run_name: format!("{run}_{}", std::process::id()),
        optimizer: optimizer.into(),
        lr: 0.02,
        steps,
        galore_rank: 16,
        galore_update_freq: 40,
        galore_alpha: 0.25,
        eval_every: 0,
        eval_batches: 4,
        log_every: 100,
        corpus_tokens: 120_000,
        val_tokens: 12_000,
        seed: 42,
        ..TrainConfig::default()
    }
}

/// Parameter metadata ("p0", "p1", …) for a list of shapes.
pub fn metas_for(shapes: &[(usize, usize)]) -> Vec<ParamMeta> {
    shapes
        .iter()
        .enumerate()
        .map(|(i, &(r, c))| ParamMeta {
            name: format!("p{i}"),
            rows: r,
            cols: c,
        })
        .collect()
}

/// A deterministic gaussian parameter/gradient set for a list of shapes.
pub fn randn_set(shapes: &[(usize, usize)], std: f32, seed: u64, stream: u64) -> Vec<Matrix> {
    let mut rng = Pcg64::new(seed, stream);
    shapes
        .iter()
        .map(|&(r, c)| Matrix::randn(r, c, std, &mut rng))
        .collect()
}

/// A deterministic per-(step, rank) microbatch gradient set — the standard
/// stand-in for the fwd/bwd pass in engine-level cluster tests. Passing
/// the same `rank` to every worker yields identical per-rank gradients,
/// which makes trajectories bitwise comparable across world sizes 1/2/4
/// (the averaged gradient is then exactly the single-rank gradient).
pub fn rank_grads(shapes: &[(usize, usize)], t: u64, rank: usize, std: f32) -> Vec<Matrix> {
    randn_set(shapes, std, 1000 + t, rank as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixtures_are_deterministic() {
        let shapes = [(3usize, 4usize), (4, 3)];
        assert_eq!(metas_for(&shapes).len(), 2);
        assert_eq!(metas_for(&shapes)[1].rows, 4);
        let a = randn_set(&shapes, 0.5, 7, 0);
        let b = randn_set(&shapes, 0.5, 7, 0);
        assert_eq!(a[0].data, b[0].data);
        let g0 = rank_grads(&shapes, 3, 0, 0.1);
        let g1 = rank_grads(&shapes, 3, 1, 0.1);
        assert_eq!(g0.len(), 2);
        assert_ne!(g0[0].data, g1[0].data, "ranks must get distinct streams");
    }

    #[test]
    fn tiny_cfg_points_at_repo_artifacts() {
        let c = tiny_train_cfg("galore", "fixture", 5);
        assert_eq!(c.preset, "llama-nano");
        assert_eq!(c.steps, 5);
        assert!(c.artifacts_dir.ends_with("artifacts"));
        assert!(c.run_name.starts_with("fixture_"));
    }
}
