//! galore2 — launcher CLI.
//!
//! Subcommands:
//!   train    train a model (config file + flag overrides)
//!   eval     run the downstream suite on a checkpoint
//!   memory   print the analytic per-GPU memory table (Table 1 / §1)
//!   svd      time full vs randomized SVD (§4.1.2's 15× claim)
//!   lint     project-invariant static analysis over rust/src (CI gate)
//!   presets  list model presets
//!   worker   (internal) one process-transport rank — the coordinator
//!            self-execs this binary per rank under `--transport process`
//!
//! Examples:
//!   galore2 train --config configs/nano-galore.toml --steps 100
//!   galore2 train --preset llama-nano --optimizer adam8bit --steps 50
//!   galore2 memory --preset llama3-8b --seq 2048 --world 2
//!   galore2 eval --config configs/nano-galore.toml --checkpoint runs/x.ckpt

use anyhow::{bail, Context, Result};
use galore2::checkpoint::Checkpoint;
use galore2::config::TrainConfig;
use galore2::coordinator;
use galore2::linalg::{randomized_svd, svd, RandSvdOpts};
use galore2::model::LlamaCfg;
use galore2::tensor::Matrix;
use galore2::util::cli::Args;
use galore2::util::rng::Pcg64;
use galore2::util::Timer;

fn main() -> Result<()> {
    let args = Args::from_env().map_err(|e| anyhow::anyhow!(e))?;
    let sub = args.subcommand.clone().unwrap_or_else(|| "help".into());
    match sub.as_str() {
        "train" => cmd_train(&args),
        "eval" => cmd_eval(&args),
        "memory" => cmd_memory(&args),
        "svd" => cmd_svd(&args),
        "lint" => cmd_lint(&args),
        "worker" => cmd_worker(&args),
        "presets" => {
            for name in LlamaCfg::preset_names() {
                let c = LlamaCfg::preset(name).unwrap();
                println!(
                    "{:<12} hidden={:<5} interm={:<6} heads={:<3} layers={:<3} vocab={:<7} ≈{} params",
                    name,
                    c.hidden,
                    c.intermediate,
                    c.heads,
                    c.layers,
                    c.vocab,
                    galore2::util::human_count(c.n_params() as u64)
                );
            }
            Ok(())
        }
        _ => {
            println!("{HELP}");
            Ok(())
        }
    }?;
    let unused = args.unused();
    if !unused.is_empty() {
        eprintln!("warning: unrecognized flags: {unused:?}");
    }
    Ok(())
}

const HELP: &str = "galore2 — GaLore 2 pre-training framework
USAGE: galore2 <train|eval|memory|svd|lint|presets> [flags]
  train   --config FILE | --preset P --optimizer O --steps N --lr X
          --weight-decay W --rank R --update-freq T --alpha A
          --projection KIND --moments keep|reset|project
          --parallel single|fsdp|ddp --world N --threads N
          --transport threads|process (worker fabric for fsdp/ddp)
          --overlap true|false (pipeline per-layer reduces behind
            optimizer compute; false = serial bitwise reference)
          --shm true|false (process-transport data plane: shared slot
            table with zero socket payload bytes; false = socket frames)
          --engine native|pjrt --eval-batches N
          --on-failure abort|respawn|shrink (worker death mid-run:
            fail fast, rebuild at same world, or continue on world-1)
          --snapshot-every N (in-memory restore-point cadence)
          --max-recoveries N --spawn-retries N
          --resume CKPT (elastic: any source mode/world/transport)
          [--resume-requantize] (opt into lossy adam8bit/adafactor
            re-slicing when the new world is not block-aligned)
          [--save-final] [--eval-downstream]
  eval    --config FILE --checkpoint CKPT [--questions N]
  memory  --preset P [--seq N] [--world N]
  svd     [--m N] [--n N] [--rank R] [--iters K]
  lint    [--json] [--root DIR] (scan rust/src for invariant
          violations: single-parser, checked-alloc, no-panic-dist,
          determinism, lock-across-collective; exit 1 on findings)
  presets
  worker  (internal) --mode fsdp|ddp --rank N --world N --endpoint PATH";

fn load_cfg(args: &Args) -> Result<TrainConfig> {
    let mut cfg = if let Some(path) = args.get("config") {
        TrainConfig::from_toml(path)?
    } else {
        TrainConfig::default()
    };
    cfg.apply_cli(args)?;
    // Cross-field checks (e.g. --transport process needs --parallel
    // fsdp|ddp) — fail at the flag level, before any real work.
    cfg.validate()?;
    Ok(cfg)
}

fn cmd_train(args: &Args) -> Result<()> {
    let save_final = args.has("save-final");
    let eval_downstream = args.has("eval-downstream");
    let questions = args.usize_or("questions", 40);
    let cfg = load_cfg(args)?;
    let trainer = coordinator::train(cfg)?;
    if save_final {
        let path = trainer.save_checkpoint(trainer.cfg.steps)?;
        println!("checkpoint → {}", path.display());
    }
    if eval_downstream {
        coordinator::eval_params(&trainer.cfg, trainer.params(), questions)?;
    }
    Ok(())
}

/// One process-transport rank. Spawned by the coordinator (never by
/// hand) as `galore2 worker --mode fsdp --rank 0 --world 2 --endpoint
/// /tmp/g2w-<pid>-<n>/w.sock`; lives exactly as long as its cluster.
fn cmd_worker(args: &Args) -> Result<()> {
    let mode = args
        .get("mode")
        .context("--mode required for worker")?
        .to_string();
    let rank: usize = args
        .get("rank")
        .context("--rank required for worker")?
        .parse()
        .context("--rank must be a number")?;
    let world: usize = args
        .get("world")
        .context("--world required for worker")?
        .parse()
        .context("--world must be a number")?;
    let endpoint = args
        .get("endpoint")
        .context("--endpoint required for worker")?
        .to_string();
    galore2::dist::run_worker(&mode, rank, world, &endpoint).map_err(|e| anyhow::anyhow!(e))
}

/// Project-invariant static analysis over the crate's own sources.
/// Exits non-zero when the tree has unexplained findings — run as a
/// blocking CI step next to clippy/fmt.
fn cmd_lint(args: &Args) -> Result<()> {
    let root = std::path::PathBuf::from(args.str_or("root", "."));
    let report = galore2::analysis::lint_root(&root)
        .with_context(|| format!("lint scan failed under {}", root.display()))?;
    if args.has("json") {
        print!("{}", report.render_json());
    } else {
        print!("{}", report.render_text());
    }
    if !report.clean() {
        bail!("lint: {} finding(s) — see output above", report.findings.len());
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let ckpt_path = args
        .get("checkpoint")
        .context("--checkpoint required for eval")?
        .to_string();
    let cfg = load_cfg(args)?;
    let ckpt = Checkpoint::load(&ckpt_path)?;
    println!(
        "loaded checkpoint step={} ({} params)",
        ckpt.step,
        ckpt.params.len()
    );
    let n = args.usize_or("questions", 40);
    coordinator::eval_params(&cfg, &ckpt.params, n)?;
    Ok(())
}

fn cmd_memory(args: &Args) -> Result<()> {
    let preset = args.str_or("preset", "llama3-8b");
    let seq = args.usize_or("seq", 2048);
    let world = args.usize_or("world", 2);
    coordinator::memory_report(&preset, seq, world)?;
    Ok(())
}

/// §4.1.2: time full SVD vs randomized SVD on a gradient-sized matrix.
fn cmd_svd(args: &Args) -> Result<()> {
    let m = args.usize_or("m", 512);
    let n = args.usize_or("n", 2048);
    let rank = args.usize_or("rank", m / 4);
    let iters = args.usize_or("iters", 3);
    if rank == 0 || rank > m.min(n) {
        bail!("rank must be in 1..=min(m,n)");
    }
    let mut rng = Pcg64::new(7, 0);
    let g = Matrix::randn(m, n, 1.0, &mut rng);
    let timer = Timer::start();
    for _ in 0..iters {
        let _ = svd(&g);
    }
    let full_s = timer.elapsed_secs() / iters as f64;
    let timer = Timer::start();
    for _ in 0..iters {
        let _ = randomized_svd(&g, rank, RandSvdOpts::default(), &mut rng);
    }
    let rand_s = timer.elapsed_secs() / iters as f64;
    println!(
        "{m}x{n} rank {rank}: full SVD {:.3}s, randomized {:.3}s → {:.1}x speedup",
        full_s,
        rand_s,
        full_s / rand_s
    );
    Ok(())
}
