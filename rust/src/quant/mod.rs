//! Block-wise quantization substrate.
//!
//! Two consumers:
//!   * the 8-bit Adam baseline (Dettmers et al. 2022) — the optimizer the
//!     paper's 500B-token run compares against — quantizes moment tensors
//!     block-wise with a *dynamic* (non-uniform) code;
//!   * Q-GaLore (§4.2) stores the projection matrix in 8- or 4-bit linear
//!     codes.
//!
//! Both use absmax block scaling: each block of 256 values is normalized by
//! its max magnitude and indexed into a code table.

/// Block size shared by all quantizers (bitsandbytes uses 256).
pub const BLOCK: usize = 256;

/// The dynamic 8-bit code of Dettmers et al.: a sign bit, 3 exponent-ish
/// bits and remaining precision bits, covering ~7 decades. We generate it
/// as the sorted set of ±(lin/2^e) values, matching the reference layout
/// closely enough for optimizer-state use.
fn dynamic_code() -> &'static [f32; 256] {
    use once_cell::sync::OnceCell;
    static CODE: OnceCell<[f32; 256]> = OnceCell::new();
    CODE.get_or_init(|| {
        let mut vals: Vec<f32> = Vec::with_capacity(256);
        // 7 exponent levels × 16 mantissa steps × 2 signs = 224, plus a
        // linear fill near 1.0 and exact zero. Sorted and deduped to 256.
        for e in 0..7 {
            let scale = 10f32.powi(-(e as i32));
            for m in 1..=16 {
                let v = scale * (m as f32) / 16.0;
                vals.push(v);
                vals.push(-v);
            }
        }
        for m in 1..=16 {
            vals.push(0.9 + 0.1 * (m as f32) / 16.0);
            vals.push(-(0.9 + 0.1 * (m as f32) / 16.0));
        }
        vals.push(0.0);
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        vals.dedup();
        // Pad/trim to exactly 256 by inserting midpoints of largest gaps.
        while vals.len() < 256 {
            let mut worst = 0;
            let mut gap = 0f32;
            for i in 0..vals.len() - 1 {
                let g = vals[i + 1] - vals[i];
                if g > gap {
                    gap = g;
                    worst = i;
                }
            }
            vals.insert(worst + 1, vals[worst] + gap / 2.0);
        }
        vals.truncate(256);
        let mut arr = [0f32; 256];
        arr.copy_from_slice(&vals);
        arr
    })
}

/// Binary-search the nearest code index for `x` in a sorted code table.
fn nearest_code(code: &[f32], x: f32) -> u8 {
    let mut lo = 0usize;
    let mut hi = code.len() - 1;
    while lo < hi {
        let mid = (lo + hi) / 2;
        if code[mid] < x {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    // lo is the first index with code >= x; compare with neighbor.
    if lo > 0 && (x - code[lo - 1]).abs() <= (code[lo] - x).abs() {
        (lo - 1) as u8
    } else {
        lo as u8
    }
}

/// A block-wise quantized f32 vector (8-bit dynamic code).
#[derive(Clone, Debug, Default)]
pub struct Quantized8 {
    pub codes: Vec<u8>,
    pub scales: Vec<f32>, // one absmax per block
    pub len: usize,
}

impl Quantized8 {
    pub fn quantize(xs: &[f32]) -> Quantized8 {
        let code = dynamic_code();
        let mut codes = Vec::with_capacity(xs.len());
        let mut scales = Vec::with_capacity(xs.len().div_ceil(BLOCK));
        for block in xs.chunks(BLOCK) {
            let absmax = block.iter().fold(0f32, |m, &x| m.max(x.abs()));
            let scale = if absmax > 0.0 { absmax } else { 1.0 };
            scales.push(scale);
            for &x in block {
                codes.push(nearest_code(code, x / scale));
            }
        }
        Quantized8 {
            codes,
            scales,
            len: xs.len(),
        }
    }

    pub fn dequantize(&self) -> Vec<f32> {
        let code = dynamic_code();
        let mut out = Vec::with_capacity(self.len);
        for (bi, block) in self.codes.chunks(BLOCK).enumerate() {
            let scale = self.scales[bi];
            for &c in block {
                out.push(code[c as usize] * scale);
            }
        }
        out
    }

    /// Dequantize a single element.
    pub fn get(&self, i: usize) -> f32 {
        dynamic_code()[self.codes[i] as usize] * self.scales[i / BLOCK]
    }

    /// Storage bytes (codes + scales), the number the memory model charges.
    pub fn nbytes(&self) -> usize {
        self.codes.len() + self.scales.len() * 4
    }
}

/// Linear (uniform) signed 8-bit block quantizer — Q-GaLore's projector
/// format (projection matrices are near-Gaussian, where a uniform code is
/// fine and decode is a single multiply).
#[derive(Clone, Debug, Default)]
pub struct LinearQ8 {
    pub codes: Vec<i8>,
    pub scales: Vec<f32>,
    pub len: usize,
}

impl LinearQ8 {
    pub fn quantize(xs: &[f32]) -> LinearQ8 {
        let mut codes = Vec::with_capacity(xs.len());
        let mut scales = Vec::with_capacity(xs.len().div_ceil(BLOCK));
        for block in xs.chunks(BLOCK) {
            let absmax = block.iter().fold(0f32, |m, &x| m.max(x.abs()));
            let scale = if absmax > 0.0 { absmax / 127.0 } else { 1.0 };
            scales.push(scale);
            for &x in block {
                codes.push((x / scale).round().clamp(-127.0, 127.0) as i8);
            }
        }
        LinearQ8 {
            codes,
            scales,
            len: xs.len(),
        }
    }

    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.len);
        for (bi, block) in self.codes.chunks(BLOCK).enumerate() {
            let scale = self.scales[bi];
            for &c in block {
                out.push(c as f32 * scale);
            }
        }
        out
    }

    pub fn nbytes(&self) -> usize {
        self.codes.len() + self.scales.len() * 4
    }
}

/// Linear signed 4-bit block quantizer (two codes per byte) — Q-GaLore's
/// most aggressive projector format; Figure 1's "q4" series.
#[derive(Clone, Debug, Default)]
pub struct LinearQ4 {
    pub packed: Vec<u8>,
    pub scales: Vec<f32>,
    pub len: usize,
}

impl LinearQ4 {
    pub fn quantize(xs: &[f32]) -> LinearQ4 {
        let mut nibbles: Vec<u8> = Vec::with_capacity(xs.len());
        let mut scales = Vec::with_capacity(xs.len().div_ceil(BLOCK));
        for block in xs.chunks(BLOCK) {
            let absmax = block.iter().fold(0f32, |m, &x| m.max(x.abs()));
            let scale = if absmax > 0.0 { absmax / 7.0 } else { 1.0 };
            scales.push(scale);
            for &x in block {
                let q = (x / scale).round().clamp(-7.0, 7.0) as i8;
                nibbles.push((q + 8) as u8); // bias to 1..15 (0 unused)
            }
        }
        let mut packed = Vec::with_capacity(nibbles.len().div_ceil(2));
        for pair in nibbles.chunks(2) {
            let lo = pair[0];
            let hi = if pair.len() > 1 { pair[1] } else { 8 };
            packed.push(lo | (hi << 4));
        }
        LinearQ4 {
            packed,
            scales,
            len: xs.len(),
        }
    }

    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.len);
        for i in 0..self.len {
            let byte = self.packed[i / 2];
            let nib = if i % 2 == 0 { byte & 0xf } else { byte >> 4 };
            let q = nib as i8 - 8;
            out.push(q as f32 * self.scales[i / BLOCK]);
        }
        out
    }

    pub fn nbytes(&self) -> usize {
        self.packed.len() + self.scales.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop;

    #[test]
    fn dynamic_code_table_well_formed() {
        let code = dynamic_code();
        assert_eq!(code.len(), 256);
        for w in code.windows(2) {
            assert!(w[1] > w[0], "not strictly increasing");
        }
        assert!(code.contains(&0.0));
        assert!((code[255] - 1.0).abs() < 1e-6);
        assert!((code[0] + 1.0).abs() < 1e-6);
    }

    #[test]
    fn q8_roundtrip_error_bounded() {
        prop::check("q8 roundtrip bounded", 30, |g| {
            let n = g.usize_in(1, 1000);
            let xs = g.vec_f32(n);
            let q = Quantized8::quantize(&xs);
            let back = q.dequantize();
            for (bi, block) in xs.chunks(BLOCK).enumerate() {
                let absmax = block.iter().fold(0f32, |m, &x| m.max(x.abs()));
                for (i, &x) in block.iter().enumerate() {
                    let y = back[bi * BLOCK + i];
                    // dynamic code is dense near 0; worst-case gap ~0.06·absmax
                    if (x - y).abs() > 0.07 * absmax + 1e-7 {
                        return Err(format!("block {bi} elem {i}: {x} vs {y} absmax {absmax}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn q8_small_values_high_precision() {
        // Near zero the dynamic code gives much better than 1/255 resolution.
        let xs: Vec<f32> = vec![1.0, 0.001, -0.0005, 0.00001, 0.0];
        let q = Quantized8::quantize(&xs);
        let back = q.dequantize();
        assert!((back[1] - 0.001).abs() < 0.0005, "{back:?}");
        assert_eq!(back[4], 0.0);
    }

    #[test]
    fn linear_q8_roundtrip() {
        prop::check("linear q8 bounded", 30, |g| {
            let n = g.usize_in(1, 600);
            let xs = g.vec_f32(n);
            let q = LinearQ8::quantize(&xs);
            let back = q.dequantize();
            for (bi, block) in xs.chunks(BLOCK).enumerate() {
                let absmax = block.iter().fold(0f32, |m, &x| m.max(x.abs()));
                let tol = absmax / 127.0 * 0.5 + 1e-7;
                for (i, &x) in block.iter().enumerate() {
                    if (x - back[bi * BLOCK + i]).abs() > tol {
                        return Err(format!("exceeds half-step: {x}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn linear_q4_roundtrip() {
        prop::check("linear q4 bounded", 30, |g| {
            let n = g.usize_in(1, 600);
            let xs = g.vec_f32(n);
            let q = LinearQ4::quantize(&xs);
            let back = q.dequantize();
            assert_eq!(back.len(), n);
            for (bi, block) in xs.chunks(BLOCK).enumerate() {
                let absmax = block.iter().fold(0f32, |m, &x| m.max(x.abs()));
                let tol = absmax / 7.0 * 0.5 + 1e-7;
                for (i, &x) in block.iter().enumerate() {
                    if (x - back[bi * BLOCK + i]).abs() > tol {
                        return Err(format!("exceeds half-step: {x} tol {tol}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn storage_sizes() {
        let xs = vec![0.5f32; 1000];
        assert_eq!(Quantized8::quantize(&xs).nbytes(), 1000 + 4 * 4);
        assert_eq!(LinearQ8::quantize(&xs).nbytes(), 1000 + 4 * 4);
        assert_eq!(LinearQ4::quantize(&xs).nbytes(), 500 + 4 * 4);
    }

    #[test]
    fn zero_vector_roundtrips() {
        let xs = vec![0f32; 300];
        assert_eq!(Quantized8::quantize(&xs).dequantize(), xs);
        assert_eq!(LinearQ8::quantize(&xs).dequantize(), xs);
        assert_eq!(LinearQ4::quantize(&xs).dequantize(), xs);
    }

    #[test]
    fn get_matches_dequantize() {
        let mut g = crate::util::rng::Pcg64::new(1, 0);
        let mut xs = vec![0f32; 700];
        g.fill_normal(&mut xs, 2.0);
        let q = Quantized8::quantize(&xs);
        let all = q.dequantize();
        for i in [0, 1, 255, 256, 257, 699] {
            assert_eq!(q.get(i), all[i]);
        }
    }
}
