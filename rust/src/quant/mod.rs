//! Block-wise quantization substrate.
//!
//! Two consumers:
//!   * the 8-bit Adam baseline (Dettmers et al. 2022) — the optimizer the
//!     paper's 500B-token run compares against — quantizes moment tensors
//!     block-wise with a *dynamic* (non-uniform) code;
//!   * Q-GaLore (§4.2) stores the projection matrix in 8- or 4-bit linear
//!     codes.
//!
//! Both use absmax block scaling: each block of 256 values is normalized by
//! its max magnitude and indexed into a code table.

/// Block size shared by all quantizers (bitsandbytes uses 256).
pub const BLOCK: usize = 256;

/// The dynamic 8-bit code of Dettmers et al.: a sign bit, 3 exponent-ish
/// bits and remaining precision bits, covering ~7 decades. We generate it
/// as the sorted set of ±(lin/2^e) values, matching the reference layout
/// closely enough for optimizer-state use.
fn dynamic_code() -> &'static [f32; 256] {
    use once_cell::sync::OnceCell;
    static CODE: OnceCell<[f32; 256]> = OnceCell::new();
    CODE.get_or_init(|| {
        let mut vals: Vec<f32> = Vec::with_capacity(256);
        // 7 exponent levels × 16 mantissa steps × 2 signs = 224, plus a
        // linear fill near 1.0 and exact zero. Sorted and deduped to 256.
        for e in 0..7 {
            let scale = 10f32.powi(-(e as i32));
            for m in 1..=16 {
                let v = scale * (m as f32) / 16.0;
                vals.push(v);
                vals.push(-v);
            }
        }
        for m in 1..=16 {
            vals.push(0.9 + 0.1 * (m as f32) / 16.0);
            vals.push(-(0.9 + 0.1 * (m as f32) / 16.0));
        }
        vals.push(0.0);
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        vals.dedup();
        // Pad/trim to exactly 256 by inserting midpoints of largest gaps.
        while vals.len() < 256 {
            let mut worst = 0;
            let mut gap = 0f32;
            for i in 0..vals.len() - 1 {
                let g = vals[i + 1] - vals[i];
                if g > gap {
                    gap = g;
                    worst = i;
                }
            }
            vals.insert(worst + 1, vals[worst] + gap / 2.0);
        }
        vals.truncate(256);
        let mut arr = [0f32; 256];
        arr.copy_from_slice(&vals);
        arr
    })
}

/// Binary-search the nearest code index for `x` in a sorted code table.
fn nearest_code(code: &[f32], x: f32) -> u8 {
    let mut lo = 0usize;
    let mut hi = code.len() - 1;
    while lo < hi {
        let mid = (lo + hi) / 2;
        if code[mid] < x {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    // lo is the first index with code >= x; compare with neighbor.
    if lo > 0 && (x - code[lo - 1]).abs() <= (code[lo] - x).abs() {
        (lo - 1) as u8
    } else {
        lo as u8
    }
}

/// A block-wise quantized f32 vector (8-bit dynamic code).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Quantized8 {
    pub codes: Vec<u8>,
    pub scales: Vec<f32>, // one absmax per block
    pub len: usize,
}

impl Quantized8 {
    pub fn quantize(xs: &[f32]) -> Quantized8 {
        let code = dynamic_code();
        let mut codes = Vec::with_capacity(xs.len());
        let mut scales = Vec::with_capacity(xs.len().div_ceil(BLOCK));
        for block in xs.chunks(BLOCK) {
            let absmax = block.iter().fold(0f32, |m, &x| m.max(x.abs()));
            let scale = if absmax > 0.0 { absmax } else { 1.0 };
            scales.push(scale);
            for &x in block {
                codes.push(nearest_code(code, x / scale));
            }
        }
        Quantized8 {
            codes,
            scales,
            len: xs.len(),
        }
    }

    pub fn dequantize(&self) -> Vec<f32> {
        let code = dynamic_code();
        let mut out = Vec::with_capacity(self.len);
        for (bi, block) in self.codes.chunks(BLOCK).enumerate() {
            let scale = self.scales[bi];
            for &c in block {
                out.push(code[c as usize] * scale);
            }
        }
        out
    }

    /// Dequantize a single element.
    pub fn get(&self, i: usize) -> f32 {
        dynamic_code()[self.codes[i] as usize] * self.scales[i / BLOCK]
    }

    /// Storage bytes (codes + scales), the number the memory model charges.
    pub fn nbytes(&self) -> usize {
        self.codes.len() + self.scales.len() * 4
    }
}

/// Linear (uniform) signed 8-bit block quantizer — Q-GaLore's projector
/// format (projection matrices are near-Gaussian, where a uniform code is
/// fine and decode is a single multiply).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LinearQ8 {
    pub codes: Vec<i8>,
    pub scales: Vec<f32>,
    pub len: usize,
}

impl LinearQ8 {
    pub fn quantize(xs: &[f32]) -> LinearQ8 {
        let mut codes = Vec::with_capacity(xs.len());
        let mut scales = Vec::with_capacity(xs.len().div_ceil(BLOCK));
        for block in xs.chunks(BLOCK) {
            let absmax = block.iter().fold(0f32, |m, &x| m.max(x.abs()));
            let scale = if absmax > 0.0 { absmax / 127.0 } else { 1.0 };
            scales.push(scale);
            for &x in block {
                codes.push((x / scale).round().clamp(-127.0, 127.0) as i8);
            }
        }
        LinearQ8 {
            codes,
            scales,
            len: xs.len(),
        }
    }

    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.len);
        for (bi, block) in self.codes.chunks(BLOCK).enumerate() {
            let scale = self.scales[bi];
            for &c in block {
                out.push(c as f32 * scale);
            }
        }
        out
    }

    pub fn nbytes(&self) -> usize {
        self.codes.len() + self.scales.len() * 4
    }
}

/// Linear signed 4-bit block quantizer (two codes per byte) — Q-GaLore's
/// most aggressive projector format; Figure 1's "q4" series.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LinearQ4 {
    pub packed: Vec<u8>,
    pub scales: Vec<f32>,
    pub len: usize,
}

impl LinearQ4 {
    pub fn quantize(xs: &[f32]) -> LinearQ4 {
        let mut nibbles: Vec<u8> = Vec::with_capacity(xs.len());
        let mut scales = Vec::with_capacity(xs.len().div_ceil(BLOCK));
        for block in xs.chunks(BLOCK) {
            let absmax = block.iter().fold(0f32, |m, &x| m.max(x.abs()));
            let scale = if absmax > 0.0 { absmax / 7.0 } else { 1.0 };
            scales.push(scale);
            for &x in block {
                let q = (x / scale).round().clamp(-7.0, 7.0) as i8;
                nibbles.push((q + 8) as u8); // bias to 1..15 (0 unused)
            }
        }
        let mut packed = Vec::with_capacity(nibbles.len().div_ceil(2));
        for pair in nibbles.chunks(2) {
            let lo = pair[0];
            let hi = if pair.len() > 1 { pair[1] } else { 8 };
            packed.push(lo | (hi << 4));
        }
        LinearQ4 {
            packed,
            scales,
            len: xs.len(),
        }
    }

    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.len);
        for i in 0..self.len {
            let byte = self.packed[i / 2];
            let nib = if i % 2 == 0 { byte & 0xf } else { byte >> 4 };
            let q = nib as i8 - 8;
            out.push(q as f32 * self.scales[i / BLOCK]);
        }
        out
    }

    pub fn nbytes(&self) -> usize {
        self.packed.len() + self.scales.len() * 4
    }
}

// ---------------------------------------------------------------------------
// Stored-representation codec
// ---------------------------------------------------------------------------
//
// The single serialize/deserialize surface for block-quantized data. Every
// persisted or transported stored representation — Adam8bit moments,
// Q-GaLore projectors, canonical checkpoint payloads, the FSDP subspace
// broadcast — goes through `encode_blocks`/`decode_blocks`: one layout, one
// hardened parser. Codes travel as their exact bytes and scales as exact
// f32 bit patterns, so encode∘decode is the identity on the stored
// representation. (A dequantize→requantize round trip is NOT: it can
// wobble a block's absmax scale by 1 ulp, which is exactly the drift the
// elastic-resume and FSDP-replication contracts forbid.)

use crate::optim::ser::{push_f32s, push_u64, Reader};

/// Layout: `[len u64][ncodes u64][code bytes][scales: len-framed f32s]`.
fn encode_blocks(out: &mut Vec<u8>, len: usize, codes: &[u8], scales: &[f32]) {
    push_u64(out, len as u64);
    push_u64(out, codes.len() as u64);
    out.extend_from_slice(codes);
    push_f32s(out, scales);
}

/// The one parser for the block layout. `codes_for_len` maps element count
/// to stored code bytes (1 byte/elem for the 8-bit codes, packed nibble
/// pairs for 4-bit). Checked: corrupt counts error before any allocation
/// (`Reader` range checks), and the cross-invariants — code bytes and
/// scale count both derived from `len` — are enforced so a bit-flipped
/// header can never decode into a structurally inconsistent tensor.
fn decode_blocks(
    r: &mut Reader,
    codes_for_len: fn(usize) -> usize,
) -> Result<(usize, Vec<u8>, Vec<f32>), String> {
    let len = r.u64()? as usize;
    let ncodes = r.u64()? as usize;
    if ncodes != codes_for_len(len) {
        return Err(format!(
            "quantized blocks: {ncodes} code bytes for {len} elements"
        ));
    }
    let codes = r.bytes(ncodes)?.to_vec();
    let scales = r.f32s()?;
    if scales.len() != len.div_ceil(BLOCK) {
        return Err(format!(
            "quantized blocks: {} scales for {len} elements (block size {BLOCK})",
            scales.len()
        ));
    }
    Ok((len, codes, scales))
}

fn one_code_byte_per_elem(len: usize) -> usize {
    len
}

fn packed_nibble_bytes(len: usize) -> usize {
    len.div_ceil(2)
}

impl Quantized8 {
    /// Serialize the exact stored representation (codes + block scales).
    pub(crate) fn encode(&self, out: &mut Vec<u8>) {
        encode_blocks(out, self.len, &self.codes, &self.scales);
    }

    /// Inverse of [`Quantized8::encode`]; errors (never panics) on
    /// truncated or inconsistent input.
    pub(crate) fn decode(r: &mut Reader) -> Result<Quantized8, String> {
        let (len, codes, scales) = decode_blocks(r, one_code_byte_per_elem)?;
        Ok(Quantized8 { codes, scales, len })
    }
}

impl LinearQ8 {
    pub(crate) fn encode(&self, out: &mut Vec<u8>) {
        let bytes: Vec<u8> = self.codes.iter().map(|&c| c as u8).collect();
        encode_blocks(out, self.len, &bytes, &self.scales);
    }

    pub(crate) fn decode(r: &mut Reader) -> Result<LinearQ8, String> {
        let (len, bytes, scales) = decode_blocks(r, one_code_byte_per_elem)?;
        Ok(LinearQ8 {
            codes: bytes.iter().map(|&b| b as i8).collect(),
            scales,
            len,
        })
    }
}

impl LinearQ4 {
    pub(crate) fn encode(&self, out: &mut Vec<u8>) {
        encode_blocks(out, self.len, &self.packed, &self.scales);
    }

    pub(crate) fn decode(r: &mut Reader) -> Result<LinearQ4, String> {
        let (len, packed, scales) = decode_blocks(r, packed_nibble_bytes)?;
        Ok(LinearQ4 {
            packed,
            scales,
            len,
        })
    }
}

/// The exact stored representation of a (possibly quantized) 2-d tensor —
/// what [`crate::optim::Projector`] persists, broadcasts, and restores.
/// Tagged with the storage kind so a decoder reconstructs the *identical*
/// codes + scales, never a re-quantization of dequantized values.
#[derive(Clone, Debug, PartialEq)]
pub enum StoredTensor {
    F32 {
        rows: usize,
        cols: usize,
        data: Vec<f32>,
    },
    /// Linear 8-bit blocks (Q-GaLore's default projector storage).
    Q8 {
        rows: usize,
        cols: usize,
        q: LinearQ8,
    },
    /// Linear 4-bit blocks (Q-GaLore-int4).
    Q4 {
        rows: usize,
        cols: usize,
        q: LinearQ4,
    },
}

const STORED_F32: u8 = 0;
const STORED_Q8: u8 = 1;
const STORED_Q4: u8 = 2;

impl StoredTensor {
    pub fn rows(&self) -> usize {
        match self {
            StoredTensor::F32 { rows, .. }
            | StoredTensor::Q8 { rows, .. }
            | StoredTensor::Q4 { rows, .. } => *rows,
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            StoredTensor::F32 { cols, .. }
            | StoredTensor::Q8 { cols, .. }
            | StoredTensor::Q4 { cols, .. } => *cols,
        }
    }

    /// Dequantized row-major values (f32 passes through untouched).
    pub fn materialize(&self) -> Vec<f32> {
        match self {
            StoredTensor::F32 { data, .. } => data.clone(),
            StoredTensor::Q8 { q, .. } => q.dequantize(),
            StoredTensor::Q4 { q, .. } => q.dequantize(),
        }
    }

    /// Layout: `[tag u8][rows u64][cols u64][payload]` with the payload in
    /// the shared block codec (f32 data as a len-framed f32 vector).
    pub(crate) fn encode(&self, out: &mut Vec<u8>) {
        match self {
            StoredTensor::F32 { rows, cols, data } => {
                out.push(STORED_F32);
                push_u64(out, *rows as u64);
                push_u64(out, *cols as u64);
                push_f32s(out, data);
            }
            StoredTensor::Q8 { rows, cols, q } => {
                out.push(STORED_Q8);
                push_u64(out, *rows as u64);
                push_u64(out, *cols as u64);
                q.encode(out);
            }
            StoredTensor::Q4 { rows, cols, q } => {
                out.push(STORED_Q4);
                push_u64(out, *rows as u64);
                push_u64(out, *cols as u64);
                q.encode(out);
            }
        }
    }

    /// Decode the LEGACY (pre-`STATE_MAGIC2`) projector layout —
    /// `[rows u64][cols u64][len-framed f32 data]`, what v1 galore state
    /// blobs carry. One parser for it crate-wide (the canonical layer and
    /// the optimizer's own gated import both route here).
    pub(crate) fn decode_legacy_f32(r: &mut Reader) -> Result<StoredTensor, String> {
        let rows = r.u64()? as usize;
        let cols = r.u64()? as usize;
        let data = r.f32s()?;
        if data.len() != rows.checked_mul(cols).ok_or("truncated state")? {
            return Err(format!(
                "projector has {} elements for shape {rows}x{cols}",
                data.len()
            ));
        }
        Ok(StoredTensor::F32 { rows, cols, data })
    }

    pub(crate) fn decode(r: &mut Reader) -> Result<StoredTensor, String> {
        let tag = r.bytes(1)?[0];
        let rows = r.u64()? as usize;
        let cols = r.u64()? as usize;
        let numel = rows
            .checked_mul(cols)
            .ok_or_else(|| format!("stored tensor shape {rows}x{cols} overflows"))?;
        let check = |len: usize| {
            if len == numel {
                Ok(())
            } else {
                Err(format!(
                    "stored tensor holds {len} elements for shape {rows}x{cols}"
                ))
            }
        };
        Ok(match tag {
            STORED_F32 => {
                let data = r.f32s()?;
                check(data.len())?;
                StoredTensor::F32 { rows, cols, data }
            }
            STORED_Q8 => {
                let q = LinearQ8::decode(r)?;
                check(q.len)?;
                StoredTensor::Q8 { rows, cols, q }
            }
            STORED_Q4 => {
                let q = LinearQ4::decode(r)?;
                check(q.len)?;
                StoredTensor::Q4 { rows, cols, q }
            }
            other => return Err(format!("unknown stored-tensor tag {other}")),
        })
    }
}

// ---------------------------------------------------------------------------
// Byte payloads over f32 collectives
// ---------------------------------------------------------------------------

/// Pack an arbitrary byte payload into f32 words for transport over the
/// f32 collectives (`Comm::broadcast`). Three bytes ride per word as an
/// exact small integer (< 2^24, always finite — no NaN bit patterns that a
/// fabric could quiet), prefixed by a two-word length. Exact inverse:
/// [`words_to_bytes`].
pub(crate) fn bytes_to_words(bytes: &[u8]) -> Vec<f32> {
    let mut words = Vec::with_capacity(2 + bytes.len().div_ceil(3));
    words.push((bytes.len() & 0xff_ffff) as f32);
    words.push((bytes.len() >> 24) as f32);
    for chunk in bytes.chunks(3) {
        let mut v = 0u32;
        for (i, &b) in chunk.iter().enumerate() {
            v |= (b as u32) << (8 * i);
        }
        words.push(v as f32);
    }
    words
}

/// Inverse of [`bytes_to_words`]; errors on malformed word streams.
pub(crate) fn words_to_bytes(words: &[f32]) -> Result<Vec<u8>, String> {
    let word = |i: usize| -> Result<usize, String> {
        let w = *words
            .get(i)
            .ok_or_else(|| "byte payload truncated".to_string())?;
        if w < 0.0 || w.fract() != 0.0 || w >= (1u32 << 24) as f32 {
            return Err(format!("byte payload word {i} is not a packed integer ({w})"));
        }
        Ok(w as usize)
    };
    let len = word(0)? | (word(1)? << 24);
    if words.len() != 2 + len.div_ceil(3) {
        return Err(format!(
            "byte payload declares {len} bytes but has {} words",
            words.len()
        ));
    }
    let mut bytes = Vec::with_capacity(len);
    for i in 0..len.div_ceil(3) {
        let v = word(2 + i)? as u32;
        for j in 0..3 {
            if bytes.len() < len {
                bytes.push((v >> (8 * j)) as u8);
            }
        }
    }
    Ok(bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop;

    #[test]
    fn dynamic_code_table_well_formed() {
        let code = dynamic_code();
        assert_eq!(code.len(), 256);
        for w in code.windows(2) {
            assert!(w[1] > w[0], "not strictly increasing");
        }
        assert!(code.contains(&0.0));
        assert!((code[255] - 1.0).abs() < 1e-6);
        assert!((code[0] + 1.0).abs() < 1e-6);
    }

    #[test]
    fn q8_roundtrip_error_bounded() {
        prop::check("q8 roundtrip bounded", 30, |g| {
            let n = g.usize_in(1, 1000);
            let xs = g.vec_f32(n);
            let q = Quantized8::quantize(&xs);
            let back = q.dequantize();
            for (bi, block) in xs.chunks(BLOCK).enumerate() {
                let absmax = block.iter().fold(0f32, |m, &x| m.max(x.abs()));
                for (i, &x) in block.iter().enumerate() {
                    let y = back[bi * BLOCK + i];
                    // dynamic code is dense near 0; worst-case gap ~0.06·absmax
                    if (x - y).abs() > 0.07 * absmax + 1e-7 {
                        return Err(format!("block {bi} elem {i}: {x} vs {y} absmax {absmax}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn q8_small_values_high_precision() {
        // Near zero the dynamic code gives much better than 1/255 resolution.
        let xs: Vec<f32> = vec![1.0, 0.001, -0.0005, 0.00001, 0.0];
        let q = Quantized8::quantize(&xs);
        let back = q.dequantize();
        assert!((back[1] - 0.001).abs() < 0.0005, "{back:?}");
        assert_eq!(back[4], 0.0);
    }

    #[test]
    fn linear_q8_roundtrip() {
        prop::check("linear q8 bounded", 30, |g| {
            let n = g.usize_in(1, 600);
            let xs = g.vec_f32(n);
            let q = LinearQ8::quantize(&xs);
            let back = q.dequantize();
            for (bi, block) in xs.chunks(BLOCK).enumerate() {
                let absmax = block.iter().fold(0f32, |m, &x| m.max(x.abs()));
                let tol = absmax / 127.0 * 0.5 + 1e-7;
                for (i, &x) in block.iter().enumerate() {
                    if (x - back[bi * BLOCK + i]).abs() > tol {
                        return Err(format!("exceeds half-step: {x}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn linear_q4_roundtrip() {
        prop::check("linear q4 bounded", 30, |g| {
            let n = g.usize_in(1, 600);
            let xs = g.vec_f32(n);
            let q = LinearQ4::quantize(&xs);
            let back = q.dequantize();
            assert_eq!(back.len(), n);
            for (bi, block) in xs.chunks(BLOCK).enumerate() {
                let absmax = block.iter().fold(0f32, |m, &x| m.max(x.abs()));
                let tol = absmax / 7.0 * 0.5 + 1e-7;
                for (i, &x) in block.iter().enumerate() {
                    if (x - back[bi * BLOCK + i]).abs() > tol {
                        return Err(format!("exceeds half-step: {x} tol {tol}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn storage_sizes() {
        let xs = vec![0.5f32; 1000];
        assert_eq!(Quantized8::quantize(&xs).nbytes(), 1000 + 4 * 4);
        assert_eq!(LinearQ8::quantize(&xs).nbytes(), 1000 + 4 * 4);
        assert_eq!(LinearQ4::quantize(&xs).nbytes(), 500 + 4 * 4);
    }

    #[test]
    fn zero_vector_roundtrips() {
        let xs = vec![0f32; 300];
        assert_eq!(Quantized8::quantize(&xs).dequantize(), xs);
        assert_eq!(LinearQ8::quantize(&xs).dequantize(), xs);
        assert_eq!(LinearQ4::quantize(&xs).dequantize(), xs);
    }

    #[test]
    fn get_matches_dequantize() {
        let mut g = crate::util::rng::Pcg64::new(1, 0);
        let mut xs = vec![0f32; 700];
        g.fill_normal(&mut xs, 2.0);
        let q = Quantized8::quantize(&xs);
        let all = q.dequantize();
        for i in [0, 1, 255, 256, 257, 699] {
            assert_eq!(q.get(i), all[i]);
        }
    }

    #[test]
    fn codec_roundtrips_exact_stored_representation() {
        // encode∘decode is the identity on codes + scales for every
        // quantizer — including lengths that leave a partial tail block
        // and the empty tensor.
        let mut rng = crate::util::rng::Pcg64::new(8, 0);
        for n in [0usize, 1, 255, 256, 257, 700] {
            let mut xs = vec![0f32; n];
            rng.fill_normal(&mut xs, 1.5);
            let q8 = Quantized8::quantize(&xs);
            let l8 = LinearQ8::quantize(&xs);
            let l4 = LinearQ4::quantize(&xs);
            let mut buf = Vec::new();
            q8.encode(&mut buf);
            l8.encode(&mut buf);
            l4.encode(&mut buf);
            let mut r = Reader::new(&buf);
            assert_eq!(Quantized8::decode(&mut r).unwrap(), q8, "n={n}");
            assert_eq!(LinearQ8::decode(&mut r).unwrap(), l8, "n={n}");
            assert_eq!(LinearQ4::decode(&mut r).unwrap(), l4, "n={n}");
            assert!(r.done(), "n={n}: trailing bytes");
        }
    }

    #[test]
    fn codec_rejects_truncation_and_inconsistent_headers() {
        let q = Quantized8::quantize(&vec![0.5f32; 300]);
        let mut buf = Vec::new();
        q.encode(&mut buf);
        for cut in [0, 7, 8, 16, buf.len() / 2, buf.len() - 1] {
            let mut r = Reader::new(&buf[..cut]);
            assert!(
                Quantized8::decode(&mut r).is_err(),
                "truncation at {cut} decoded silently"
            );
        }
        // Corrupt element count: the code-byte cross-check must fire.
        let mut bad = buf.clone();
        bad[0] ^= 0x01;
        assert!(Quantized8::decode(&mut Reader::new(&bad)).is_err());
        // Insane element count must error before allocating.
        let mut insane = Vec::new();
        crate::optim::ser::push_u64(&mut insane, u64::MAX);
        crate::optim::ser::push_u64(&mut insane, u64::MAX);
        assert!(Quantized8::decode(&mut Reader::new(&insane)).is_err());
        // Scale-count mismatch: append one extra scale word to the framed
        // scales vector by rebuilding the blob with a lying scale count.
        let mut lying = Vec::new();
        encode_blocks(&mut lying, 300, &q.codes, &q.scales[..1]);
        assert!(Quantized8::decode(&mut Reader::new(&lying)).is_err());
    }

    #[test]
    fn stored_tensor_roundtrips_all_kinds() {
        let mut rng = crate::util::rng::Pcg64::new(9, 0);
        let mut data = vec![0f32; 12 * 7];
        rng.fill_normal(&mut data, 1.0);
        let cases = vec![
            StoredTensor::F32 {
                rows: 12,
                cols: 7,
                data: data.clone(),
            },
            StoredTensor::Q8 {
                rows: 12,
                cols: 7,
                q: LinearQ8::quantize(&data),
            },
            StoredTensor::Q4 {
                rows: 12,
                cols: 7,
                q: LinearQ4::quantize(&data),
            },
        ];
        for st in &cases {
            let mut buf = Vec::new();
            st.encode(&mut buf);
            let mut r = Reader::new(&buf);
            let back = StoredTensor::decode(&mut r).unwrap();
            assert_eq!(&back, st);
            assert!(r.done());
            assert_eq!(back.rows(), 12);
            assert_eq!(back.cols(), 7);
            assert_eq!(back.materialize().len(), 12 * 7);
        }
        // Shape/payload mismatch is rejected.
        let mut buf = Vec::new();
        StoredTensor::F32 {
            rows: 3,
            cols: 3,
            data: vec![0.0; 9],
        }
        .encode(&mut buf);
        buf[1] ^= 0x01; // rows 3 -> 2
        assert!(StoredTensor::decode(&mut Reader::new(&buf)).is_err());
    }

    #[test]
    fn byte_word_packing_is_exact_inverse() {
        for n in [0usize, 1, 2, 3, 4, 100, 257] {
            let bytes: Vec<u8> = (0..n).map(|i| (i * 37 % 256) as u8).collect();
            let words = bytes_to_words(&bytes);
            assert!(words.iter().all(|w| w.is_finite() && w.fract() == 0.0));
            assert_eq!(words_to_bytes(&words).unwrap(), bytes, "n={n}");
        }
        assert!(words_to_bytes(&[]).is_err());
        assert!(words_to_bytes(&[3.0, 0.0]).is_err(), "missing payload words");
        assert!(words_to_bytes(&[1.5, 0.0, 0.0]).is_err(), "non-integer word");
    }
}
