//! # GaLore 2 — Gradient Low-Rank Projection at scale
//!
//! A Rust + JAX + Pallas reproduction of *GaLore 2: Large-Scale LLM
//! Pre-Training by Gradient Low-Rank Projection* (Su et al., 2025).
//!
//! Architecture (see DESIGN.md):
//! * **L3 (this crate)** — training coordinator: FSDP-style sharded runtime,
//!   the GaLore optimizer family, fast randomized SVD subspace updates,
//!   data pipeline, memory model, downstream eval harness, CLI launcher.
//! * **L2 (python/compile/model.py)** — JAX Llama fwd/bwd, AOT-lowered to
//!   HLO text artifacts, never imported at runtime.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the GaLore
//!   hot-spot (projection + fused low-rank Adam update), lowered into the
//!   same artifacts and also loadable as standalone executables.

pub mod analysis;
pub mod bench;
pub mod checkpoint;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod dist;
pub mod eval;
pub mod linalg;
pub mod memory;
pub mod metrics;
pub mod model;
pub mod optim;
pub mod parallel;
pub mod quant;
pub mod runtime;
pub mod tensor;
pub mod testing;
pub mod train;
pub mod util;
