//! Packed sequence dataloader with train/validation split.

use super::corpus::Corpus;
use crate::util::rng::Pcg64;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Split {
    Train,
    Validation,
}

/// One batch: tokens and next-token targets, both (batch, seq) row-major.
#[derive(Clone, Debug)]
pub struct Batch {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub batch: usize,
    pub seq: usize,
}

/// Streams packed (batch, seq) windows from a token pool.
///
/// The pool is materialized once per split from disjoint corpus streams
/// ("validation ... no overlap with the training data", §5); batches are
/// random windows (train) or a deterministic sweep (validation).
pub struct DataLoader {
    train: Vec<u32>,
    val: Vec<u32>,
    pub batch: usize,
    pub seq: usize,
    seed: u64,
    rng: Pcg64,
    val_cursor: usize,
}

impl DataLoader {
    pub fn new(
        corpus: &Corpus,
        train_tokens: usize,
        val_tokens: usize,
        batch: usize,
        seq: usize,
        seed: u64,
    ) -> DataLoader {
        assert!(train_tokens > seq + 1 && val_tokens > seq + 1);
        DataLoader {
            train: corpus.sample(train_tokens, 0),
            val: corpus.sample(val_tokens, 1),
            batch,
            seq,
            seed,
            rng: Pcg64::new(seed, 0xda7a),
            val_cursor: 0,
        }
    }

    pub fn train_tokens(&self) -> usize {
        self.train.len()
    }

    /// Tokens consumed per training batch.
    pub fn tokens_per_batch(&self) -> usize {
        self.batch * self.seq
    }

    fn window(pool: &[u32], start: usize, seq: usize) -> (Vec<i32>, Vec<i32>) {
        let toks = pool[start..start + seq].iter().map(|&t| t as i32).collect();
        let tgts = pool[start + 1..start + seq + 1]
            .iter()
            .map(|&t| t as i32)
            .collect();
        (toks, tgts)
    }

    /// Random training batch (stateful stream; prefer [`train_batch_at`]
    /// inside training loops — it is a pure function of the step, which is
    /// what makes checkpoint-resume reproduce trajectories exactly).
    pub fn next_train(&mut self) -> Batch {
        let mut tokens = Vec::with_capacity(self.batch * self.seq);
        let mut targets = Vec::with_capacity(self.batch * self.seq);
        for _ in 0..self.batch {
            let start =
                self.rng.next_below((self.train.len() - self.seq - 1) as u64) as usize;
            let (t, g) = Self::window(&self.train, start, self.seq);
            tokens.extend(t);
            targets.extend(g);
        }
        Batch {
            tokens,
            targets,
            batch: self.batch,
            seq: self.seq,
        }
    }

    /// Training batch for step `step`, rank `rank` — pure function of
    /// (loader seed, step, rank), so resumed runs replay the same data.
    pub fn train_batch_at(&self, step: u64, rank: u64) -> Batch {
        let mut rng = Pcg64::new(
            self.seed ^ step.wrapping_mul(0x9e37_79b9_7f4a_7c15),
            0xda7a ^ rank,
        );
        let mut tokens = Vec::with_capacity(self.batch * self.seq);
        let mut targets = Vec::with_capacity(self.batch * self.seq);
        for _ in 0..self.batch {
            let start =
                rng.next_below((self.train.len() - self.seq - 1) as u64) as usize;
            let (t, g) = Self::window(&self.train, start, self.seq);
            tokens.extend(t);
            targets.extend(g);
        }
        Batch {
            tokens,
            targets,
            batch: self.batch,
            seq: self.seq,
        }
    }

    /// `n` independent microbatches for step `step` (one per rank).
    pub fn train_microbatches_at(&self, step: u64, n: usize) -> Vec<Batch> {
        (0..n).map(|r| self.train_batch_at(step, r as u64)).collect()
    }

    /// `n` independent microbatches (stateful; see [`train_microbatches_at`]).
    pub fn next_train_microbatches(&mut self, n: usize) -> Vec<Batch> {
        (0..n).map(|_| self.next_train()).collect()
    }

    /// Deterministic sweep over validation windows; wraps around.
    pub fn next_val(&mut self) -> Batch {
        let mut tokens = Vec::with_capacity(self.batch * self.seq);
        let mut targets = Vec::with_capacity(self.batch * self.seq);
        for _ in 0..self.batch {
            if self.val_cursor + self.seq + 1 >= self.val.len() {
                self.val_cursor = 0;
            }
            let (t, g) = Self::window(&self.val, self.val_cursor, self.seq);
            tokens.extend(t);
            targets.extend(g);
            self.val_cursor += self.seq;
        }
        Batch {
            tokens,
            targets,
            batch: self.batch,
            seq: self.seq,
        }
    }

    /// Number of full validation batches in one sweep.
    pub fn val_batches_per_epoch(&self) -> usize {
        (self.val.len() - 1) / (self.seq * self.batch)
    }

    /// Reset the validation sweep (call before each evaluation pass so
    /// every eval sees the same windows).
    pub fn reset_val(&mut self) {
        self.val_cursor = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::CorpusCfg;

    fn loader() -> DataLoader {
        let corpus = Corpus::new(CorpusCfg {
            vocab: 64,
            ..CorpusCfg::default()
        });
        DataLoader::new(&corpus, 5000, 1000, 2, 16, 42)
    }

    #[test]
    fn batch_shapes() {
        let mut dl = loader();
        let b = dl.next_train();
        assert_eq!(b.tokens.len(), 2 * 16);
        assert_eq!(b.targets.len(), 2 * 16);
    }

    #[test]
    fn targets_are_shifted_tokens() {
        let mut dl = loader();
        let b = dl.next_train();
        for row in 0..b.batch {
            let t = &b.tokens[row * b.seq..(row + 1) * b.seq];
            let g = &b.targets[row * b.seq..(row + 1) * b.seq];
            assert_eq!(&t[1..], &g[..b.seq - 1]);
        }
    }

    #[test]
    fn validation_sweep_deterministic() {
        let mut a = loader();
        let mut b = loader();
        for _ in 0..5 {
            assert_eq!(a.next_val().tokens, b.next_val().tokens);
        }
        // After reset the sweep repeats.
        let first = {
            a.reset_val();
            a.next_val().tokens
        };
        a.reset_val();
        assert_eq!(a.next_val().tokens, first);
    }

    #[test]
    fn train_and_val_pools_disjoint_streams() {
        let dl = loader();
        // Identical cfg but different streams — prefixes must differ.
        assert_ne!(&dl.train[..64], &dl.val[..64]);
    }

    #[test]
    fn microbatches_differ_per_rank() {
        let mut dl = loader();
        let mbs = dl.next_train_microbatches(3);
        assert_eq!(mbs.len(), 3);
        assert_ne!(mbs[0].tokens, mbs[1].tokens);
    }

    #[test]
    fn val_epoch_count() {
        let dl = loader();
        assert_eq!(dl.val_batches_per_epoch(), (1000 - 1) / (16 * 2));
    }
}
