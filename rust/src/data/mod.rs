//! Data pipeline: synthetic corpus (C4 stand-in), tokenizer, packed loader.
//!
//! The corpus is an order-2 Markov process with Zipf-distributed "topic"
//! structure (DESIGN.md §3 item 2): skewed unigram frequencies + strong
//! local transition structure give a loss landscape where a language model
//! meaningfully improves over the unigram entropy floor, and where the
//! downstream eval harness can pose tasks with known ground truth.

mod corpus;
mod loader;
mod tokenizer;

pub use corpus::{Corpus, CorpusCfg};
pub use loader::{Batch, DataLoader, Split};
pub use tokenizer::ByteTokenizer;
