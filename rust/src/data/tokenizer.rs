//! Byte-pair-free byte tokenizer with a greedy merge vocabulary.
//!
//! Used by the quickstart example to train on real text snippets: bytes are
//! base tokens (0..256); the most frequent adjacent pairs in a training
//! sample become merge tokens until the target vocab is filled — a small
//! BPE, enough to exercise the text→tokens→model path end to end.

use std::collections::HashMap;

#[derive(Clone, Debug)]
pub struct ByteTokenizer {
    /// merges[i] = (left, right) producing token 256 + i.
    merges: Vec<(u32, u32)>,
    vocab: usize,
}

impl ByteTokenizer {
    /// Byte-only tokenizer (vocab 256).
    pub fn bytes_only() -> ByteTokenizer {
        ByteTokenizer {
            merges: Vec::new(),
            vocab: 256,
        }
    }

    /// Learn merges from `text` until `vocab` tokens exist.
    pub fn train(text: &str, vocab: usize) -> ByteTokenizer {
        assert!(vocab >= 256, "vocab must cover raw bytes");
        let mut toks: Vec<u32> = text.bytes().map(u32::from).collect();
        let mut merges = Vec::new();
        while 256 + merges.len() < vocab {
            let mut counts: HashMap<(u32, u32), usize> = HashMap::new();
            for w in toks.windows(2) {
                *counts.entry((w[0], w[1])).or_default() += 1;
            }
            // Deterministic tie-break: highest count, then lowest pair.
            let best = counts
                .into_iter()
                .max_by_key(|&((a, b), c)| (c, std::cmp::Reverse((a, b))));
            let Some(((a, b), count)) = best else { break };
            if count < 2 {
                break;
            }
            let new_id = 256 + merges.len() as u32;
            merges.push((a, b));
            toks = Self::apply_merge(&toks, a, b, new_id);
        }
        ByteTokenizer {
            merges,
            vocab,
        }
    }

    fn apply_merge(toks: &[u32], a: u32, b: u32, id: u32) -> Vec<u32> {
        let mut out = Vec::with_capacity(toks.len());
        let mut i = 0;
        while i < toks.len() {
            if i + 1 < toks.len() && toks[i] == a && toks[i + 1] == b {
                out.push(id);
                i += 2;
            } else {
                out.push(toks[i]);
                i += 1;
            }
        }
        out
    }

    pub fn vocab_size(&self) -> usize {
        self.vocab
    }

    pub fn encode(&self, text: &str) -> Vec<u32> {
        let mut toks: Vec<u32> = text.bytes().map(u32::from).collect();
        for (i, &(a, b)) in self.merges.iter().enumerate() {
            toks = Self::apply_merge(&toks, a, b, 256 + i as u32);
        }
        toks
    }

    pub fn decode(&self, toks: &[u32]) -> String {
        let mut bytes = Vec::new();
        for &t in toks {
            self.expand(t, &mut bytes);
        }
        String::from_utf8_lossy(&bytes).into_owned()
    }

    fn expand(&self, tok: u32, out: &mut Vec<u8>) {
        if tok < 256 {
            out.push(tok as u8);
        } else {
            let (a, b) = self.merges[(tok - 256) as usize];
            self.expand(a, out);
            self.expand(b, out);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "the quick brown fox jumps over the lazy dog. \
                          the quick brown fox jumps again and again.";

    #[test]
    fn bytes_only_roundtrip() {
        let tk = ByteTokenizer::bytes_only();
        let toks = tk.encode(SAMPLE);
        assert_eq!(toks.len(), SAMPLE.len());
        assert_eq!(tk.decode(&toks), SAMPLE);
    }

    #[test]
    fn bpe_roundtrip_and_compression() {
        let tk = ByteTokenizer::train(SAMPLE, 300);
        let toks = tk.encode(SAMPLE);
        assert!(toks.len() < SAMPLE.len(), "no compression");
        assert_eq!(tk.decode(&toks), SAMPLE);
    }

    #[test]
    fn encode_decode_unseen_text() {
        let tk = ByteTokenizer::train(SAMPLE, 280);
        let unseen = "a totally different sentence — with unicode: héllo";
        assert_eq!(tk.decode(&tk.encode(unseen)), unseen);
    }

    #[test]
    fn tokens_below_vocab() {
        let tk = ByteTokenizer::train(SAMPLE, 270);
        assert!(tk.encode(SAMPLE).iter().all(|&t| (t as usize) < 270));
    }

    #[test]
    fn deterministic_training() {
        let a = ByteTokenizer::train(SAMPLE, 280);
        let b = ByteTokenizer::train(SAMPLE, 280);
        assert_eq!(a.encode(SAMPLE), b.encode(SAMPLE));
    }
}
