//! Synthetic pre-training corpus with known latent structure.
//!
//! Generator: an order-2 Markov chain over the model vocabulary.
//!   * Unigram marginals are Zipf(1.1) — like natural text.
//!   * Each (prev2, prev1) context deterministically selects a sparse
//!     successor distribution of `branching` tokens (Zipf-weighted), so the
//!     conditional entropy is far below the unigram entropy — a model that
//!     learns context beats one that learns frequencies, which is exactly
//!     the gradient structure pre-training exercises.
//!   * A held-out validation stream uses the SAME chain with a disjoint
//!     RNG stream ("carefully curated to ensure no overlap", §5).
//!
//! The chain parameters are derived deterministically from (seed, vocab),
//! so eval tasks can recompute ground-truth successors without storing the
//! transition table.

use crate::util::rng::Pcg64;

#[derive(Clone, Copy, Debug)]
pub struct CorpusCfg {
    pub vocab: usize,
    /// Successors per context.
    pub branching: usize,
    /// Markov order: 1 ⇒ contexts are single tokens (vocab contexts,
    /// each visited often — learnable at small token budgets); 2 ⇒
    /// vocab² contexts (memorization regime; used by the long-horizon
    /// ablation only).
    pub order: usize,
    pub seed: u64,
}

impl Default for CorpusCfg {
    fn default() -> Self {
        CorpusCfg {
            vocab: 256,
            branching: 8,
            order: 1,
            seed: 1234,
        }
    }
}

/// Deterministic synthetic corpus.
#[derive(Clone, Debug)]
pub struct Corpus {
    pub cfg: CorpusCfg,
    /// Zipf weights for successor choice (shared across contexts).
    succ_weights: Vec<f64>,
}

impl Corpus {
    pub fn new(cfg: CorpusCfg) -> Corpus {
        assert!(cfg.vocab >= 4, "vocab too small");
        let branching = cfg.branching.min(cfg.vocab);
        let succ_weights = (0..branching)
            .map(|k| 1.0 / ((k + 1) as f64).powf(1.1))
            .collect();
        Corpus {
            cfg: CorpusCfg { branching, ..cfg },
            succ_weights,
        }
    }

    /// The `k`-th candidate successor of context (a, b) — a deterministic
    /// hash of (seed, context, k) into the vocab, Zipf-tilted toward low
    /// ids so unigram marginals stay skewed. Order-1 chains ignore `a`.
    pub fn successor(&self, a: u32, b: u32, k: usize) -> u32 {
        let a = if self.cfg.order >= 2 { a } else { 0 };
        let mut h = self.cfg.seed ^ 0x9e37_79b9_7f4a_7c15;
        for v in [a as u64, b as u64, k as u64] {
            h ^= v.wrapping_add(0x9e37_79b9_7f4a_7c15);
            h = h.wrapping_mul(0xff51_afd7_ed55_8ccd);
            h ^= h >> 33;
        }
        // Square the uniform draw: density ∝ 1/(2√u) → heavier mass at low
        // ids, approximating a Zipf-ish unigram marginal.
        let u = (h >> 11) as f64 / (1u64 << 53) as f64;
        ((u * u * self.cfg.vocab as f64) as u32).min(self.cfg.vocab as u32 - 1)
    }

    /// Ground-truth most-likely successor of a context (k = 0 candidate) —
    /// the eval harness's answer key.
    pub fn best_successor(&self, a: u32, b: u32) -> u32 {
        self.successor(a, b, 0)
    }

    /// Sample a stream of `len` tokens. `stream` namespaces train (0) vs
    /// validation (1) vs eval-task (2+) data — same chain, disjoint draws.
    pub fn sample(&self, len: usize, stream: u64) -> Vec<u32> {
        let mut rng = Pcg64::new(self.cfg.seed ^ 0xc0de, stream);
        let mut out = Vec::with_capacity(len);
        let mut a = rng.next_below(self.cfg.vocab as u64) as u32;
        let mut b = rng.next_below(self.cfg.vocab as u64) as u32;
        out.push(a);
        if len > 1 {
            out.push(b);
        }
        while out.len() < len {
            let k = rng.sample_weighted(&self.succ_weights);
            let next = self.successor(a, b, k);
            out.push(next);
            a = b;
            b = next;
        }
        out
    }

    /// Empirical conditional entropy bound: entropy of the Zipf successor
    /// choice (nats) — the loss floor a perfect model reaches.
    pub fn conditional_entropy(&self) -> f64 {
        let total: f64 = self.succ_weights.iter().sum();
        -self
            .succ_weights
            .iter()
            .map(|w| {
                let p = w / total;
                p * p.ln()
            })
            .sum::<f64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let c = Corpus::new(CorpusCfg::default());
        assert_eq!(c.sample(100, 0), c.sample(100, 0));
        assert_ne!(c.sample(100, 0), c.sample(100, 1));
    }

    #[test]
    fn tokens_in_vocab() {
        let c = Corpus::new(CorpusCfg {
            vocab: 64,
            ..CorpusCfg::default()
        });
        assert!(c.sample(5000, 0).iter().all(|&t| t < 64));
    }

    #[test]
    fn transitions_follow_declared_successors() {
        let c = Corpus::new(CorpusCfg::default());
        let toks = c.sample(2000, 0);
        for w in toks.windows(3) {
            let (a, b, next) = (w[0], w[1], w[2]);
            let ok = (0..c.cfg.branching).any(|k| c.successor(a, b, k) == next);
            assert!(ok, "transition ({a},{b})->{next} not in successor set");
        }
    }

    #[test]
    fn best_successor_is_most_frequent() {
        let c = Corpus::new(CorpusCfg::default());
        let toks = c.sample(200_000, 0);
        // Pick a context that occurs often and check argmax next-token.
        use std::collections::HashMap;
        let mut ctx_counts: HashMap<(u32, u32), HashMap<u32, usize>> = HashMap::new();
        for w in toks.windows(3) {
            *ctx_counts
                .entry((w[0], w[1]))
                .or_default()
                .entry(w[2])
                .or_default() += 1;
        }
        let (&ctx, nexts) = ctx_counts
            .iter()
            .max_by_key(|(_, m)| m.values().sum::<usize>())
            .unwrap();
        let total: usize = nexts.values().sum();
        assert!(total > 50, "context too rare for the check");
        let empirical_best = *nexts.iter().max_by_key(|(_, &c)| c).unwrap().0;
        assert_eq!(empirical_best, c.best_successor(ctx.0, ctx.1));
    }

    #[test]
    fn unigram_distribution_is_skewed() {
        let c = Corpus::new(CorpusCfg::default());
        let toks = c.sample(100_000, 0);
        let mut counts = vec![0usize; c.cfg.vocab];
        for &t in &toks {
            counts[t as usize] += 1;
        }
        counts.sort_unstable_by(|a, b| b.cmp(a));
        let top10: usize = counts[..10].iter().sum();
        assert!(
            top10 as f64 > 0.2 * toks.len() as f64,
            "not skewed: top10 covers {}",
            top10 as f64 / toks.len() as f64
        );
    }

    #[test]
    fn conditional_entropy_below_unigram() {
        let c = Corpus::new(CorpusCfg::default());
        // branching 8 Zipf entropy ≈ 1.8 nats ≪ ln(256) = 5.5.
        let h = c.conditional_entropy();
        assert!(h > 0.5 && h < (c.cfg.vocab as f64).ln() / 2.0, "{h}");
    }
}
