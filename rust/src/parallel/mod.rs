//! Scoped worker pool for the compute hot path (std-only, no rayon).
//!
//! The GEMM kernels, the randomized-SVD range finder and the FSDP engine
//! all fan work out through this module. Work units are *disjoint* `&mut`
//! slices of the output buffer, so parallel execution is data-race-free by
//! construction and — because every unit computes exactly what the serial
//! kernel would — results are **bitwise identical** for any thread count
//! (the determinism contract stated in `util/rng.rs`).
//!
//! Thread-count resolution (first match wins):
//!   1. an explicit per-call request (`MatmulPlan::threads` > 0),
//!   2. a process-wide override via [`set_default_threads`]
//!      (`[parallel] threads` in the config / `--threads` on the CLI),
//!   3. the `GALORE2_THREADS` environment variable,
//!   4. `std::thread::available_parallelism()`.
//!
//! Threads are spawned with `std::thread::scope`, so borrowing inputs from
//! the caller's stack needs no `Arc`s; spawn overhead (~tens of µs) is
//! amortized by the serial-fallback size thresholds at the call sites.

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Process-wide thread-count override; 0 means "not set".
static DEFAULT_THREADS: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// How many sibling compute threads share the machine with this one.
    /// Distributed workers set this to the world size so nested kernels
    /// split the core budget instead of oversubscribing it world-fold.
    static THREAD_SHARE: Cell<usize> = const { Cell::new(1) };
}

/// Declare that the *current thread* is one of `siblings` concurrent
/// compute threads (e.g. an FSDP worker in a world of that size). Auto
/// thread resolution on this thread divides the hardware budget
/// accordingly; explicit per-call requests are unaffected.
pub fn set_thread_share(siblings: usize) {
    THREAD_SHARE.with(|c| c.set(siblings.max(1)));
}

/// Hardware parallelism (1 if the query fails).
pub fn available() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Set the process-wide default worker count. 0 restores auto-detection.
pub fn set_default_threads(n: usize) {
    DEFAULT_THREADS.store(n, Ordering::Relaxed);
}

/// The default worker count: override > `GALORE2_THREADS` > hardware,
/// divided by this thread's [`set_thread_share`] (so a world of FSDP
/// workers collectively uses one machine's worth of threads).
pub fn default_threads() -> usize {
    let base = {
        let forced = DEFAULT_THREADS.load(Ordering::Relaxed);
        if forced > 0 {
            forced
        } else {
            std::env::var("GALORE2_THREADS")
                .ok()
                .and_then(|s| s.trim().parse::<usize>().ok())
                .filter(|&n| n > 0)
                .unwrap_or_else(available)
        }
    };
    let share = THREAD_SHARE.with(|c| c.get()).max(1);
    (base / share).max(1)
}

/// Resolve a per-call request: 0 means "use the default".
pub fn resolve(requested: usize) -> usize {
    if requested > 0 {
        requested
    } else {
        default_threads()
    }
}

/// Run `f(chunk_index, chunk)` over consecutive disjoint `chunk_len`-sized
/// chunks of `data` (the last chunk may be short), using up to `threads`
/// scoped OS threads. Chunks are handed out through a shared queue so
/// uneven chunks still balance; since every chunk is an independent pure
/// function of its index, scheduling order cannot affect the result.
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk_len: usize, threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    if data.is_empty() {
        return;
    }
    assert!(chunk_len > 0, "par_chunks_mut: chunk_len must be > 0");
    let n_chunks = (data.len() + chunk_len - 1) / chunk_len;
    let workers = threads.max(1).min(n_chunks);
    if workers <= 1 {
        for (i, chunk) in data.chunks_mut(chunk_len).enumerate() {
            f(i, chunk);
        }
        return;
    }
    let queue = Mutex::new(data.chunks_mut(chunk_len).enumerate());
    let queue = &queue;
    let f = &f;
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(move || loop {
                // Hold the lock only for the hand-off, not the work.
                let next = queue.lock().unwrap().next();
                match next {
                    Some((i, chunk)) => f(i, chunk),
                    None => break,
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_every_element_exactly_once() {
        let mut data = vec![0u32; 1003]; // deliberately not a chunk multiple
        par_chunks_mut(&mut data, 64, 4, |_, chunk| {
            for x in chunk.iter_mut() {
                *x += 1;
            }
        });
        assert!(data.iter().all(|&x| x == 1));
    }

    #[test]
    fn chunk_indices_are_consecutive() {
        let mut data = vec![0usize; 300];
        par_chunks_mut(&mut data, 100, 3, |i, chunk| {
            for x in chunk.iter_mut() {
                *x = i;
            }
        });
        assert_eq!(data[0], 0);
        assert_eq!(data[150], 1);
        assert_eq!(data[299], 2);
    }

    #[test]
    fn more_threads_than_chunks_is_fine() {
        let mut data = vec![0u8; 10];
        par_chunks_mut(&mut data, 7, 16, |_, chunk| {
            for x in chunk.iter_mut() {
                *x = 9;
            }
        });
        assert!(data.iter().all(|&x| x == 9));
    }

    #[test]
    fn empty_input_is_a_noop() {
        let mut data: Vec<u8> = Vec::new();
        par_chunks_mut(&mut data, 8, 4, |_, _| panic!("must not run"));
    }

    #[test]
    fn resolution_order_and_thread_share() {
        // One test (not several) because the process-wide override is
        // shared state — concurrent test threads would race on it.
        assert_eq!(resolve(3), 3);
        set_default_threads(2);
        assert_eq!(resolve(0), 2);
        // Thread share divides the budget, but only on the thread that
        // declared it — run on a fresh OS thread so nothing leaks out.
        std::thread::spawn(|| {
            set_default_threads(8);
            set_thread_share(4);
            assert_eq!(resolve(0), 2);
            set_thread_share(100); // over-subscribed world still gets 1
            assert_eq!(resolve(0), 1);
            assert_eq!(resolve(6), 6, "explicit requests bypass the share");
        })
        .join()
        .unwrap();
        set_default_threads(0);
        assert!(resolve(0) >= 1);
    }
}
