//! Persistent worker pool for the compute hot path (std-only, no rayon).
//!
//! The GEMM kernels, the randomized-SVD range finder and the FSDP engine
//! all fan work out through this module. Work units are *disjoint* `&mut`
//! slices of the output buffer, so parallel execution is data-race-free by
//! construction and — because every unit computes exactly what the serial
//! kernel would — results are **bitwise identical** for any thread count
//! (the determinism contract stated in `util/rng.rs`).
//!
//! Thread-count resolution (first match wins):
//!   1. an explicit per-call request (`MatmulPlan::threads` > 0),
//!   2. a process-wide override via [`set_default_threads`]
//!      (`[parallel] threads` in the config / `--threads` on the CLI),
//!   3. the `GALORE2_THREADS` environment variable (read ONCE, at first
//!      resolution, into a `OnceLock` — never on the hot path),
//!   4. `std::thread::available_parallelism()`.
//!
//! Execution goes through the persistent park/unpark pool in [`pool`]:
//! long-lived workers are created lazily on first demand (and grow on
//! demand after [`set_default_threads`] raises the budget), park on a
//! condvar between parallel regions, and borrow the caller's stack through
//! a bounded-lifetime region handoff — so `par_chunks_mut` keeps its
//! scoped-borrow signature and call sites are unchanged. Dispatch costs a
//! queue push + condvar wake (single-digit µs) instead of the ~tens-of-µs
//! per-call `thread::scope` spawn the previous revision paid; the serial
//! cutover at the call sites (`PAR_MIN_FLOPS` in `tensor/matmul.rs`) is
//! re-tuned accordingly. [`set_pool_enabled`]`(false)` (config
//! `[parallel] pool = false` / CLI `--pool false`) falls back to the
//! scoped spawner, kept as [`par_chunks_mut_scoped`] both as an escape
//! hatch and as the reference implementation benches compare against.

mod pool;

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};

/// Process-wide thread-count override; 0 means "not set".
static DEFAULT_THREADS: AtomicUsize = AtomicUsize::new(0);

/// Whether `par_chunks_mut` dispatches through the persistent pool
/// (default) or the scoped per-call spawner.
static POOL_ENABLED: AtomicBool = AtomicBool::new(true);

/// `GALORE2_THREADS`, parsed exactly once per process. Re-reading the
/// environment per call put a `getenv` on every kernel invocation — and a
/// `getenv` racing a concurrent env mutation is the UB class the dist
/// layer was scrubbed of (see `dist/process.rs`: children receive the
/// value via `Command::env` at spawn, before this cell is first read).
static ENV_THREADS: OnceLock<Option<usize>> = OnceLock::new();

fn env_threads() -> Option<usize> {
    *ENV_THREADS.get_or_init(|| {
        // lint: allow(determinism): GALORE2_THREADS is resolved exactly once into a OnceLock at first use; set_default_threads is the only runtime override (hot-path getenv is what the rule bans)
        std::env::var("GALORE2_THREADS")
            .ok()
            .and_then(|s| s.trim().parse::<usize>().ok())
            .filter(|&n| n > 0)
    })
}

thread_local! {
    /// How many sibling compute threads share the machine with this one.
    /// Distributed workers set this to the world size so nested kernels
    /// split the core budget instead of oversubscribing it world-fold.
    static THREAD_SHARE: Cell<usize> = const { Cell::new(1) };
}

/// Declare that the *current thread* is one of `siblings` concurrent
/// compute threads (e.g. an FSDP worker in a world of that size). Auto
/// thread resolution on this thread divides the hardware budget
/// accordingly; explicit per-call requests are unaffected. The pool is
/// process-wide, so the division keeps a world of workers submitting
/// regions at a combined width of ~one machine's worth of threads.
pub fn set_thread_share(siblings: usize) {
    THREAD_SHARE.with(|c| c.set(siblings.max(1)));
}

/// Hardware parallelism (1 if the query fails).
pub fn available() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Set the process-wide default worker count. 0 restores auto-detection.
/// Raising the budget needs no pool restart: workers are spawned on
/// demand, so the next parallel region grows the pool to the new width.
pub fn set_default_threads(n: usize) {
    DEFAULT_THREADS.store(n, Ordering::Relaxed);
}

/// Route `par_chunks_mut` through the persistent pool (`true`, default)
/// or the scoped per-call spawner (`false`). Both produce bitwise
/// identical results; the knob exists for debugging and for benchmarking
/// the dispatch cost difference (throughput §3b).
pub fn set_pool_enabled(enabled: bool) {
    POOL_ENABLED.store(enabled, Ordering::Relaxed);
}

/// Whether the persistent pool is the active dispatch path.
pub fn pool_enabled() -> bool {
    POOL_ENABLED.load(Ordering::Relaxed)
}

/// Number of live pool workers (parked or busy). Zero until the first
/// pooled region demands one, and again after [`shutdown_pool`].
pub fn pool_size() -> usize {
    pool::size()
}

/// Join every pool worker and return the process to its no-threads state.
/// Safe to call at any time (in-flight regions finish first; concurrent
/// submitters fall back to running serially); the pool restarts lazily on
/// the next demand. Tests use this to pin exact `/proc/self/task` counts
/// across kill→recover cycles.
pub fn shutdown_pool() {
    pool::shutdown();
}

/// The default worker count: override > `GALORE2_THREADS` > hardware,
/// divided by this thread's [`set_thread_share`] (so a world of FSDP
/// workers collectively uses one machine's worth of threads).
pub fn default_threads() -> usize {
    let base = {
        let forced = DEFAULT_THREADS.load(Ordering::Relaxed);
        if forced > 0 {
            forced
        } else {
            env_threads().unwrap_or_else(available)
        }
    };
    let share = THREAD_SHARE.with(|c| c.get()).max(1);
    (base / share).max(1)
}

/// Resolve a per-call request: 0 means "use the default".
pub fn resolve(requested: usize) -> usize {
    if requested > 0 {
        requested
    } else {
        default_threads()
    }
}

/// Run `f(chunk_index, chunk)` over consecutive disjoint `chunk_len`-sized
/// chunks of `data` (the last chunk may be short), using up to `threads`
/// workers from the persistent pool (the calling thread is one of them).
/// Chunks are handed out through a shared queue so uneven chunks still
/// balance; since every chunk is an independent pure function of its
/// index, scheduling order cannot affect the result — output is bitwise
/// identical to serial for any thread count and either dispatch path.
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk_len: usize, threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    if data.is_empty() {
        return;
    }
    assert!(chunk_len > 0, "par_chunks_mut: chunk_len must be > 0");
    let n_chunks = data.len().div_ceil(chunk_len);
    let workers = threads.max(1).min(n_chunks);
    if workers <= 1 {
        for (i, chunk) in data.chunks_mut(chunk_len).enumerate() {
            f(i, chunk);
        }
        return;
    }
    if !pool_enabled() {
        par_chunks_mut_scoped(data, chunk_len, workers, f);
        return;
    }
    // Region handoff: the chunk queue and `f` stay on this stack frame;
    // the submitter and up to `workers - 1` pool workers all drain the
    // queue. `run_region` does not return until every worker that touched
    // this region is done with it, so the borrows below stay valid.
    let queue = Mutex::new(data.chunks_mut(chunk_len).enumerate());
    let f = &f;
    let drain = move || loop {
        // Hold the lock only for the hand-off, not the work.
        let next = queue.lock().unwrap_or_else(|e| e.into_inner()).next();
        match next {
            Some((i, chunk)) => f(i, chunk),
            None => break,
        }
    };
    pool::run_region(&drain, workers - 1);
}

/// The pre-pool implementation: spawn `workers` scoped OS threads for this
/// one region. Same chunk queue, same determinism guarantee; ~tens of µs
/// of per-call spawn/join cost. Kept as the `pool = false` fallback and as
/// the baseline throughput §3b measures the pool against.
pub fn par_chunks_mut_scoped<T, F>(data: &mut [T], chunk_len: usize, workers: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    if data.is_empty() {
        return;
    }
    assert!(chunk_len > 0, "par_chunks_mut: chunk_len must be > 0");
    let queue = Mutex::new(data.chunks_mut(chunk_len).enumerate());
    let queue = &queue;
    let f = &f;
    std::thread::scope(|s| {
        for _ in 0..workers.max(1) {
            s.spawn(move || loop {
                let next = queue.lock().unwrap_or_else(|e| e.into_inner()).next();
                match next {
                    Some((i, chunk)) => f(i, chunk),
                    None => break,
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_every_element_exactly_once() {
        let mut data = vec![0u32; 1003]; // deliberately not a chunk multiple
        par_chunks_mut(&mut data, 64, 4, |_, chunk| {
            for x in chunk.iter_mut() {
                *x += 1;
            }
        });
        assert!(data.iter().all(|&x| x == 1));
    }

    #[test]
    fn chunk_indices_are_consecutive() {
        let mut data = vec![0usize; 300];
        par_chunks_mut(&mut data, 100, 3, |i, chunk| {
            for x in chunk.iter_mut() {
                *x = i;
            }
        });
        assert_eq!(data[0], 0);
        assert_eq!(data[150], 1);
        assert_eq!(data[299], 2);
    }

    #[test]
    fn more_threads_than_chunks_is_fine() {
        let mut data = vec![0u8; 10];
        par_chunks_mut(&mut data, 7, 16, |_, chunk| {
            for x in chunk.iter_mut() {
                *x = 9;
            }
        });
        assert!(data.iter().all(|&x| x == 9));
    }

    #[test]
    fn empty_input_is_a_noop() {
        let mut data: Vec<u8> = Vec::new();
        par_chunks_mut(&mut data, 8, 4, |_, _| panic!("must not run"));
    }

    #[test]
    fn pool_and_scoped_paths_agree_bitwise() {
        // Same work, both dispatchers, byte-for-byte equal output. f32
        // accumulation with a chunk-dependent seed would expose any
        // reordering of per-chunk work.
        let run = |scoped: bool| -> Vec<f32> {
            let mut data = vec![0f32; 2048];
            let body = |i: usize, chunk: &mut [f32]| {
                let mut acc = (i as f32 + 1.0) * 0.37;
                for (j, x) in chunk.iter_mut().enumerate() {
                    acc = acc * 1.000_1 + (j as f32) * 0.01;
                    *x = acc;
                }
            };
            if scoped {
                par_chunks_mut_scoped(&mut data, 100, 4, body);
            } else {
                par_chunks_mut(&mut data, 100, 4, body);
            }
            data
        };
        let pooled = run(false);
        let scoped = run(true);
        assert_eq!(
            pooled.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            scoped.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn pool_workers_park_and_are_reused() {
        let mut data = vec![0u64; 4096];
        par_chunks_mut(&mut data, 32, 4, |i, chunk| {
            for x in chunk.iter_mut() {
                *x = i as u64;
            }
        });
        let after_first = pool_size();
        assert!(after_first >= 1, "pooled region must have spawned workers");
        for _ in 0..8 {
            par_chunks_mut(&mut data, 32, 4, |i, chunk| {
                for x in chunk.iter_mut() {
                    *x += i as u64;
                }
            });
        }
        // Sequential same-width regions reuse the parked workers instead
        // of growing the pool. (Other tests in this binary may run pooled
        // regions concurrently, so allow growth up to their demand too —
        // but never unbounded: cap at this binary's test-thread budget
        // times the per-region width.)
        assert!(
            pool_size() >= after_first,
            "pool must not shrink without shutdown"
        );
    }

    #[test]
    fn worker_panic_propagates_to_submitter_and_pool_survives() {
        let caught = std::panic::catch_unwind(|| {
            let mut data = vec![0u8; 1024];
            par_chunks_mut(&mut data, 8, 4, |i, _| {
                if i == 63 {
                    panic!("boom in chunk 63");
                }
            });
        });
        assert!(caught.is_err(), "a chunk panic must reach the caller");
        // The pool must still be serviceable afterwards.
        let mut data = vec![0u32; 512];
        par_chunks_mut(&mut data, 16, 4, |_, chunk| {
            for x in chunk.iter_mut() {
                *x = 7;
            }
        });
        assert!(data.iter().all(|&x| x == 7));
    }

    #[test]
    fn resolution_order_and_thread_share() {
        // One test (not several) because the process-wide override is
        // shared state — concurrent test threads would race on it.
        assert_eq!(resolve(3), 3);
        set_default_threads(2);
        assert_eq!(resolve(0), 2);
        // Thread share divides the budget, but only on the thread that
        // declared it — run on a fresh OS thread so nothing leaks out.
        std::thread::spawn(|| {
            set_default_threads(8);
            set_thread_share(4);
            assert_eq!(resolve(0), 2);
            set_thread_share(100); // over-subscribed world still gets 1
            assert_eq!(resolve(0), 1);
            assert_eq!(resolve(6), 6, "explicit requests bypass the share");
        })
        .join()
        .unwrap();
        set_default_threads(0);
        assert!(resolve(0) >= 1);
    }
}
