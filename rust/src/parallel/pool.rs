//! Persistent park/unpark worker pool behind [`super::par_chunks_mut`].
//!
//! The scoped predecessor spawned fresh OS threads for every parallel
//! region — a ~tens-of-µs tax per GEMM/SVD call that forced a high serial
//! cutover (`PAR_MIN_FLOPS`) and left GaLore's mid-sized projections
//! single-threaded. Here workers are spawned lazily on first demand, then
//! PARK on a condvar between regions; dispatching a region costs a queue
//! push plus a wake (single-digit µs, measured by `pool_dispatch_noop_t4`
//! in benches/throughput.rs §3b).
//!
//! ## Region protocol
//!
//! A *region* is one `par_chunks_mut` call. The caller's closure and the
//! chunk queue live on the caller's stack; the pool only ever sees a
//! type-erased `&'static (dyn Fn() + Sync)` pointing at them. That
//! lifetime is a lie the [`RegionGuard`] makes true: the submitter
//! enqueues a ticket with `extra` claimable worker slots, runs the task
//! itself (so a region ALWAYS completes, even if no worker is free or the
//! pool is shutting down), then — in the guard's `Drop`, so a panicking
//! task cannot skip it — removes any unclaimed slots and blocks until
//! every worker that DID claim the ticket has reported finished. Only
//! then can the borrowed frame unwind, so a claimed pointer never
//! dangles.
//!
//! ## Determinism
//!
//! The pool moves WHO executes a chunk, never WHAT a chunk computes:
//! chunks remain independent pure functions of their index, handed out
//! through the same mutex-serialized queue as the scoped version, so
//! results stay bitwise identical to serial for any thread count and any
//! scheduling (tests/determinism.rs pins this end to end).
//!
//! ## Shutdown
//!
//! [`shutdown`] parks no corpses: it flags the pool, wakes everyone, and
//! JOINS every worker (in-flight regions finish first — workers only
//! check the flag between regions). The pool restarts lazily on the next
//! region, so kill→recover cycles and test harnesses can bound
//! `/proc/self/task` exactly (tests/fault_tolerance.rs).

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock};
use std::thread::JoinHandle;

/// Type-erased region task. The `'static` is synthesized by
/// [`run_region`]; validity is guaranteed by the guard protocol above —
/// a worker may only call it between claiming a ticket and incrementing
/// `finished`, and must never touch it after.
type Task = &'static (dyn Fn() + Sync);

/// Per-region completion state, shared between the submitter and every
/// claimant (heap-allocated, so it safely outlives queue removal).
#[derive(Default)]
struct RegionSync {
    /// Workers that claimed a slot for this region. Incremented under the
    /// pool mutex (so it can no longer grow once the ticket has left the
    /// queue), read by the submitter after dequeue — hence atomic rather
    /// than folded into `m`, which claimants touch without the pool lock.
    claimed: AtomicUsize,
    m: Mutex<RegionState>,
    cv: Condvar,
}

#[derive(Default)]
struct RegionState {
    finished: usize,
    /// First worker panic, re-thrown on the submitting thread.
    panic: Option<Box<dyn std::any::Any + Send>>,
}

/// A queued region with `slots` worker seats still unclaimed.
struct Ticket {
    task: Task,
    sync: Arc<RegionSync>,
    slots: usize,
}

#[derive(Default)]
struct PoolState {
    /// Set by [`shutdown`]; workers exit between regions, submitters stop
    /// enqueuing (their regions run on the submitting thread alone).
    shutdown: bool,
    /// Workers currently executing a region task (claim → finish).
    busy: usize,
    queue: VecDeque<Ticket>,
    handles: Vec<JoinHandle<()>>,
}

#[derive(Default)]
struct Shared {
    m: Mutex<PoolState>,
    /// Parked workers wait here for queue activity or shutdown.
    work: Condvar,
}

static SHARED: OnceLock<Shared> = OnceLock::new();

fn shared() -> &'static Shared {
    SHARED.get_or_init(Shared::default)
}

/// Poison-tolerant lock: a panic inside a region task is caught and
/// re-thrown on the submitter, so observing a poisoned mutex here is
/// benign — the protected state is still consistent.
fn lock(m: &Mutex<PoolState>) -> MutexGuard<'_, PoolState> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Run `task` on the calling thread while up to `extra` pool workers run
/// it concurrently. Every participant executes the same closure (which
/// drains a shared chunk queue), so the region completes no matter how
/// many workers actually pick it up. Worker panics are re-thrown here.
pub(super) fn run_region(task: &(dyn Fn() + Sync), extra: usize) {
    if extra == 0 {
        task();
        return;
    }
    let sync = Arc::new(RegionSync::default());
    // Erase the stack lifetime. Sound because `RegionGuard` (dropped at
    // the end of this function, panic or not) removes unclaimed slots and
    // waits for all claimants before the frame can unwind.
    let task: Task = unsafe {
        std::mem::transmute::<&(dyn Fn() + Sync), &'static (dyn Fn() + Sync + 'static)>(task)
    };
    let enqueued = enqueue(task, &sync, extra);
    let _guard = RegionGuard {
        sync: &sync,
        enqueued,
    };
    task();
}

/// Queue a ticket and make sure enough workers exist to claim it; returns
/// false (nothing queued) when the pool is shutting down.
fn enqueue(task: Task, sync: &Arc<RegionSync>, slots: usize) -> bool {
    let sh = shared();
    let mut st = lock(&sh.m);
    if st.shutdown {
        return false;
    }
    st.queue.push_back(Ticket {
        task,
        sync: Arc::clone(sync),
        slots,
    });
    // Grow to current demand: every queued slot plus every busy worker
    // wants a thread. Demand — not cumulative use — bounds the pool, and
    // `set_thread_share` bounds demand at ~one machine's worth of threads
    // across a distributed world.
    let demand = st.queue.iter().map(|t| t.slots).sum::<usize>() + st.busy;
    while st.handles.len() < demand {
        let name = format!("galore2-pool-{}", st.handles.len());
        match std::thread::Builder::new().name(name).spawn(worker_loop) {
            Ok(h) => st.handles.push(h),
            // Thread exhaustion: run the region with fewer workers.
            Err(_) => break,
        }
    }
    drop(st);
    if slots == 1 {
        sh.work.notify_one();
    } else {
        sh.work.notify_all();
    }
    true
}

fn worker_loop() {
    let sh = shared();
    loop {
        let (task, sync) = {
            let mut st = lock(&sh.m);
            loop {
                if st.shutdown {
                    return;
                }
                if !st.queue.is_empty() {
                    break;
                }
                st = sh.work.wait(st).unwrap_or_else(|e| e.into_inner());
            }
            let exhausted;
            let (task, sync) = {
                // Queue invariant: every queued ticket has slots > 0.
                let t = st.queue.front_mut().expect("checked non-empty");
                t.slots -= 1;
                exhausted = t.slots == 0;
                // Under the pool lock — see `RegionSync::claimed`.
                t.sync.claimed.fetch_add(1, Ordering::SeqCst);
                (t.task, Arc::clone(&t.sync))
            };
            if exhausted {
                st.queue.pop_front();
            }
            st.busy += 1;
            (task, sync)
        };
        // Run outside every lock. A panic in the region closure must kill
        // neither this worker nor (silently) the region: capture it, hand
        // it to the submitter.
        let result = catch_unwind(AssertUnwindSafe(task));
        // `task` must not be used past this point: once `finished` is
        // published the submitter's frame may unwind.
        {
            let mut st = lock(&sh.m);
            st.busy -= 1;
        }
        let mut rs = sync.m.lock().unwrap_or_else(|e| e.into_inner());
        if let Err(payload) = result {
            rs.panic.get_or_insert(payload);
        }
        rs.finished += 1;
        sync.cv.notify_all();
    }
}

/// Closes a region: pulls unclaimed slots out of the queue, waits for
/// every claimant, then re-throws the first worker panic. Runs in `Drop`
/// so a panic in the submitter's own share of the work still blocks until
/// workers have released their borrows into the submitter's frame.
struct RegionGuard<'a> {
    sync: &'a Arc<RegionSync>,
    enqueued: bool,
}

impl Drop for RegionGuard<'_> {
    fn drop(&mut self) {
        let sh = shared();
        if self.enqueued {
            let mut st = lock(&sh.m);
            st.queue.retain(|t| !Arc::ptr_eq(&t.sync, self.sync));
        }
        // The ticket is out of the queue (or was never in it): `claimed`
        // is final. Wait for the in-flight claimants.
        let target = self.sync.claimed.load(Ordering::SeqCst);
        let mut rs = self.sync.m.lock().unwrap_or_else(|e| e.into_inner());
        while rs.finished < target {
            rs = self.sync.cv.wait(rs).unwrap_or_else(|e| e.into_inner());
        }
        let worker_panic = rs.panic.take();
        drop(rs);
        if let Some(payload) = worker_panic {
            if !std::thread::panicking() {
                std::panic::resume_unwind(payload);
            }
        }
    }
}

/// Workers currently alive (parked or busy). Grows with demand, shrinks
/// only via [`shutdown`].
pub(super) fn size() -> usize {
    lock(&shared().m).handles.len()
}

/// Stop and JOIN every pool worker. In-flight regions complete first
/// (workers re-check the flag only between regions; submitters always
/// drain their own queue). Regions submitted while the shutdown flag is
/// up simply run on their submitting thread. The pool restarts lazily on
/// the next demand after the join completes.
pub(super) fn shutdown() {
    let sh = shared();
    let handles = {
        let mut st = lock(&sh.m);
        st.shutdown = true;
        std::mem::take(&mut st.handles)
    };
    sh.work.notify_all();
    for h in handles {
        let _ = h.join();
    }
    let mut st = lock(&sh.m);
    st.shutdown = false;
    st.busy = 0;
}
