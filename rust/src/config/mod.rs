//! Configuration system: TOML files (configs/*.toml) + CLI overrides.

use crate::dist::OptimizerSpec;
use crate::optim::{AdamCfg, GaLoreCfg, MomentHandling, ProjectionKind};
use crate::util::cli::Args;
use crate::util::toml::TomlDoc;
use anyhow::{bail, Context, Result};
use std::path::PathBuf;

/// How the model's fwd/bwd and GaLore updates are executed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Engine {
    /// GaLore math in Rust (tensor/linalg substrate).
    Native,
    /// GaLore fused update via the Pallas kernel artifacts over PJRT.
    Pjrt,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ParallelMode {
    Single,
    Fsdp,
    Ddp,
}

/// The full training configuration (Megatron-style single source of truth).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub preset: String,
    pub artifacts_dir: PathBuf,
    pub out_dir: PathBuf,
    pub run_name: String,

    pub optimizer: String,
    pub lr: f32,
    pub weight_decay: f32,
    pub steps: u64,
    pub warmup_frac: f64,
    pub lr_floor_frac: f32,

    pub galore_rank: usize, // 0 = hidden/4
    pub galore_update_freq: u64,
    pub galore_alpha: f32,
    pub galore_projection: String,
    pub galore_moments: String,

    pub parallel: ParallelMode,
    pub world: usize,
    /// Worker threads for the GEMM/SVD hot path; 0 = auto
    /// (`GALORE2_THREADS` or the hardware parallelism).
    pub threads: usize,
    pub engine: Engine,

    pub seed: u64,
    pub corpus_tokens: usize,
    pub val_tokens: usize,
    pub eval_every: u64,
    pub eval_batches: usize,
    pub checkpoint_every: u64,
    pub log_every: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            preset: "llama-nano".into(),
            artifacts_dir: PathBuf::from("artifacts"),
            out_dir: PathBuf::from("runs"),
            run_name: "run".into(),
            optimizer: "galore".into(),
            lr: 0.01,
            weight_decay: 0.0,
            steps: 200,
            warmup_frac: 0.1,
            lr_floor_frac: 0.1,
            galore_rank: 0,
            galore_update_freq: 50,
            galore_alpha: 0.25,
            galore_projection: "rand_svd".into(),
            galore_moments: "keep".into(),
            parallel: ParallelMode::Single,
            world: 1,
            threads: 0,
            engine: Engine::Native,
            seed: 42,
            corpus_tokens: 200_000,
            val_tokens: 20_000,
            eval_every: 50,
            eval_batches: 8,
            checkpoint_every: 0,
            log_every: 10,
        }
    }
}

impl TrainConfig {
    pub fn from_toml(path: &str) -> Result<TrainConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        let doc = TomlDoc::parse(&text)
            .map_err(|e| anyhow::anyhow!("parsing {path}: {e}"))?;
        let mut c = TrainConfig::default();
        c.preset = doc.str_or("", "preset", &c.preset);
        c.run_name = doc.str_or("", "run_name", &c.run_name);
        c.artifacts_dir = PathBuf::from(doc.str_or(
            "",
            "artifacts_dir",
            c.artifacts_dir.to_str().unwrap(),
        ));
        c.out_dir = PathBuf::from(doc.str_or("", "out_dir", c.out_dir.to_str().unwrap()));
        c.optimizer = doc.str_or("optimizer", "name", &c.optimizer);
        c.lr = doc.f64_or("optimizer", "lr", c.lr as f64) as f32;
        c.weight_decay =
            doc.f64_or("optimizer", "weight_decay", c.weight_decay as f64) as f32;
        c.steps = doc.i64_or("train", "steps", c.steps as i64) as u64;
        c.warmup_frac = doc.f64_or("train", "warmup_frac", c.warmup_frac);
        c.lr_floor_frac =
            doc.f64_or("train", "lr_floor_frac", c.lr_floor_frac as f64) as f32;
        c.galore_rank = doc.i64_or("galore", "rank", c.galore_rank as i64) as usize;
        c.galore_update_freq =
            doc.i64_or("galore", "update_freq", c.galore_update_freq as i64) as u64;
        c.galore_alpha = doc.f64_or("galore", "alpha", c.galore_alpha as f64) as f32;
        c.galore_projection = doc.str_or("galore", "projection", &c.galore_projection);
        c.galore_moments = doc.str_or("galore", "moments", &c.galore_moments);
        c.parallel = match doc.str_or("parallel", "mode", "single").as_str() {
            "single" => ParallelMode::Single,
            "fsdp" => ParallelMode::Fsdp,
            "ddp" => ParallelMode::Ddp,
            other => bail!("unknown parallel.mode {other:?}"),
        };
        c.world = doc.i64_or("parallel", "world", c.world as i64) as usize;
        // Clamp: a negative value would wrap to a huge usize thread count.
        c.threads = doc
            .i64_or("parallel", "threads", c.threads as i64)
            .max(0) as usize;
        c.engine = match doc.str_or("train", "engine", "native").as_str() {
            "native" => Engine::Native,
            "pjrt" => Engine::Pjrt,
            other => bail!("unknown engine {other:?}"),
        };
        c.seed = doc.i64_or("train", "seed", c.seed as i64) as u64;
        c.corpus_tokens =
            doc.i64_or("data", "corpus_tokens", c.corpus_tokens as i64) as usize;
        c.val_tokens = doc.i64_or("data", "val_tokens", c.val_tokens as i64) as usize;
        c.eval_every = doc.i64_or("train", "eval_every", c.eval_every as i64) as u64;
        c.eval_batches =
            doc.i64_or("train", "eval_batches", c.eval_batches as i64) as usize;
        c.checkpoint_every =
            doc.i64_or("train", "checkpoint_every", c.checkpoint_every as i64) as u64;
        c.log_every = doc.i64_or("train", "log_every", c.log_every as i64) as u64;
        Ok(c)
    }

    /// CLI flags override file values (`--steps`, `--optimizer`, …).
    pub fn apply_cli(&mut self, args: &Args) {
        self.preset = args.str_or("preset", &self.preset);
        self.run_name = args.str_or("run-name", &self.run_name);
        if let Some(d) = args.get("artifacts-dir") {
            self.artifacts_dir = PathBuf::from(d);
        }
        if let Some(d) = args.get("out-dir") {
            self.out_dir = PathBuf::from(d);
        }
        self.optimizer = args.str_or("optimizer", &self.optimizer);
        self.lr = args.f32_or("lr", self.lr);
        self.steps = args.u64_or("steps", self.steps);
        self.galore_rank = args.usize_or("rank", self.galore_rank);
        self.galore_update_freq = args.u64_or("update-freq", self.galore_update_freq);
        self.galore_alpha = args.f32_or("alpha", self.galore_alpha);
        self.galore_projection = args.str_or("projection", &self.galore_projection);
        self.world = args.usize_or("world", self.world);
        self.threads = args.usize_or("threads", self.threads);
        if let Some(mode) = args.get("parallel") {
            self.parallel = match mode {
                "single" => ParallelMode::Single,
                "fsdp" => ParallelMode::Fsdp,
                "ddp" => ParallelMode::Ddp,
                _ => self.parallel,
            };
        }
        if let Some(engine) = args.get("engine") {
            self.engine = match engine {
                "pjrt" => Engine::Pjrt,
                _ => Engine::Native,
            };
        }
        self.seed = args.u64_or("seed", self.seed);
        self.eval_every = args.u64_or("eval-every", self.eval_every);
        self.corpus_tokens = args.usize_or("corpus-tokens", self.corpus_tokens);
        self.log_every = args.u64_or("log-every", self.log_every);
    }

    pub fn galore_cfg(&self, hidden: usize) -> Result<GaLoreCfg> {
        let rank = if self.galore_rank == 0 {
            (hidden / 4).max(1)
        } else {
            self.galore_rank
        };
        let projection = ProjectionKind::parse(&self.galore_projection)
            .with_context(|| format!("unknown projection {:?}", self.galore_projection))?;
        let moments = match self.galore_moments.as_str() {
            "keep" => MomentHandling::Keep,
            "reset" => MomentHandling::Reset,
            "project" => MomentHandling::Project,
            other => bail!("unknown moment handling {other:?}"),
        };
        Ok(GaLoreCfg {
            rank,
            update_freq: self.galore_update_freq,
            alpha: self.galore_alpha,
            projection,
            moments,
            min_dim: 2,
            external_subspace: false,
        })
    }

    pub fn adam_cfg(&self) -> AdamCfg {
        AdamCfg {
            weight_decay: self.weight_decay,
            ..AdamCfg::default()
        }
    }

    pub fn optimizer_spec(&self, hidden: usize) -> Result<OptimizerSpec> {
        Ok(match self.optimizer.as_str() {
            "adamw" => OptimizerSpec::AdamW(self.adam_cfg()),
            "adam8bit" => OptimizerSpec::Adam8bit(self.adam_cfg()),
            "adafactor" => OptimizerSpec::Adafactor { eps: 1e-30 },
            "sgdm" => OptimizerSpec::SgdM { momentum: 0.9 },
            // qgalore under FSDP keeps the quantized projector storage
            // (the memory-relevant part); the similarity-gated lazy
            // refresh stays a single-process feature for now.
            "galore" | "qgalore" => {
                let mut galore = self.galore_cfg(hidden)?;
                if self.optimizer == "qgalore" {
                    galore.projection = ProjectionKind::Quant8;
                }
                OptimizerSpec::GaLore {
                    galore,
                    adam: self.adam_cfg(),
                }
            }
            other => bail!("unknown optimizer {other:?}"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
preset = "llama-mini"
run_name = "fig3"

[train]
steps = 500
engine = "native"
seed = 7

[optimizer]
name = "galore"
lr = 0.005

[galore]
rank = 64
update_freq = 100
alpha = 0.125
projection = "rand_svd"

[parallel]
mode = "fsdp"
world = 4
threads = 2
"#;

    #[test]
    fn parses_full_config() {
        let path = std::env::temp_dir().join("galore2_cfg_test.toml");
        std::fs::write(&path, SAMPLE).unwrap();
        let c = TrainConfig::from_toml(path.to_str().unwrap()).unwrap();
        assert_eq!(c.preset, "llama-mini");
        assert_eq!(c.steps, 500);
        assert_eq!(c.galore_rank, 64);
        assert!((c.galore_alpha - 0.125).abs() < 1e-6);
        assert_eq!(c.parallel, ParallelMode::Fsdp);
        assert_eq!(c.world, 4);
        assert_eq!(c.threads, 2);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn cli_overrides_file() {
        let mut c = TrainConfig::default();
        let args = Args::parse(
            "train --steps 99 --optimizer adam8bit --rank 32 --parallel ddp"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        c.apply_cli(&args);
        assert_eq!(c.steps, 99);
        assert_eq!(c.optimizer, "adam8bit");
        assert_eq!(c.galore_rank, 32);
        assert_eq!(c.parallel, ParallelMode::Ddp);
    }

    #[test]
    fn galore_rank_auto_is_quarter_hidden() {
        let c = TrainConfig::default();
        assert_eq!(c.galore_cfg(4096).unwrap().rank, 1024);
        let spec = c.optimizer_spec(256).unwrap();
        assert_eq!(spec.name(), "galore");
    }

    #[test]
    fn rejects_unknown_optimizer() {
        let mut c = TrainConfig::default();
        c.optimizer = "turbo".into();
        assert!(c.optimizer_spec(64).is_err());
    }
}
