//! Configuration system: TOML files (configs/*.toml) + CLI overrides.
//!
//! `TrainConfig::optimizer_spec` is the single mapping from config strings
//! to [`OptimizerSpec`] — the recipe every execution mode builds its
//! optimizer from. CLI and TOML agree on accepted values: both bail on an
//! unknown `parallel.mode` / `--parallel` or `engine` / `--engine`.

use crate::dist::TransportKind;
use crate::optim::{AdamCfg, GaLoreCfg, MomentHandling, OptimizerSpec, ProjectionKind};
use crate::train::OnFailure;
use crate::util::cli::Args;
use crate::util::toml::TomlDoc;
use anyhow::{bail, Context, Result};
use std::path::PathBuf;

/// How the model's fwd/bwd and GaLore updates are executed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Engine {
    /// GaLore math in Rust (tensor/linalg substrate).
    Native,
    /// GaLore fused update via the Pallas kernel artifacts over PJRT.
    Pjrt,
}

impl Engine {
    /// Shared by TOML and CLI parsing so the two can never drift.
    pub fn parse(s: &str) -> Result<Engine> {
        Ok(match s {
            "native" => Engine::Native,
            "pjrt" => Engine::Pjrt,
            other => bail!("unknown engine {other:?} (native|pjrt)"),
        })
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ParallelMode {
    Single,
    Fsdp,
    Ddp,
}

impl ParallelMode {
    /// Shared by TOML and CLI parsing so the two can never drift.
    pub fn parse(s: &str) -> Result<ParallelMode> {
        Ok(match s {
            "single" => ParallelMode::Single,
            "fsdp" => ParallelMode::Fsdp,
            "ddp" => ParallelMode::Ddp,
            other => bail!("unknown parallel mode {other:?} (single|fsdp|ddp)"),
        })
    }
}

/// The full training configuration (Megatron-style single source of truth).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub preset: String,
    pub artifacts_dir: PathBuf,
    pub out_dir: PathBuf,
    pub run_name: String,
    /// Checkpoint to resume from before training (`[train] resume` /
    /// `--resume`). Elastic: the checkpoint may come from ANY
    /// `--parallel` mode and world size — v3+ checkpoints store the
    /// world-agnostic canonical optimizer state (see EXPERIMENTS.md
    /// §Resume).
    pub resume_from: Option<PathBuf>,
    /// Opt into LOSSY resume conversions (`[train] resume_requantize` /
    /// `--resume-requantize`): re-quantize block-quantized adam8bit
    /// moments across misaligned shard boundaries and merge/replicate
    /// adafactor's factored cross-statistics when the target
    /// mode/world cannot re-slice the checkpoint exactly. Off by default:
    /// inexact imports then fail loudly instead of approximating.
    pub resume_requantize: bool,

    pub optimizer: String,
    pub lr: f32,
    pub weight_decay: f32,
    /// Adafactor's variance-floor epsilon (`[optimizer] adafactor_eps`).
    pub adafactor_eps: f32,
    /// SGD momentum coefficient (`[optimizer] momentum`).
    pub sgdm_momentum: f32,
    pub steps: u64,
    pub warmup_frac: f64,
    pub lr_floor_frac: f32,

    pub galore_rank: usize, // 0 = hidden/4
    pub galore_update_freq: u64,
    pub galore_alpha: f32,
    pub galore_projection: String,
    pub galore_moments: String,
    /// Q-GaLore's lazy-refresh cosine threshold
    /// (`[galore] similarity_threshold`; 1.0 disables laziness).
    pub galore_similarity: f32,

    pub parallel: ParallelMode,
    pub world: usize,
    /// Worker threads for the GEMM/SVD hot path; 0 = auto
    /// (`GALORE2_THREADS` or the hardware parallelism).
    pub threads: usize,
    /// Dispatch parallel regions through the persistent park/unpark pool
    /// (`[parallel] pool` / `--pool`; default true). `false` falls back to
    /// per-call scoped spawning — same bitwise results, higher dispatch
    /// cost; kept for debugging and A/B benchmarking.
    pub pool: bool,
    /// Fabric connecting distributed ranks (`[dist] transport` /
    /// `--transport`): in-process worker threads (default) or self-exec'd
    /// worker OS processes over Unix-domain sockets. Trajectories are
    /// bitwise identical across transports (tests/transport.rs).
    pub transport: TransportKind,
    /// Overlap per-layer collectives with optimizer compute via each
    /// rank's comm thread (`[dist] overlap` / `--overlap`; default true).
    /// `false` keeps every collective inline on the worker — the serial
    /// bitwise reference. Same trajectory either way
    /// (tests/determinism.rs pins overlap-on == overlap-off).
    pub overlap: bool,
    /// Shared-memory data plane for the process transport (`[dist] shm` /
    /// `--shm`; default true): gradient payloads move through a per-cluster
    /// slot table and the comm sockets carry only 33-byte control frames.
    /// `false` keeps payloads on the sockets — the fallback path. Bitwise
    /// identical either way (tests/transport.rs pins shm-on == shm-off).
    pub shm: bool,
    pub engine: Engine,
    /// What to do when a worker rank dies mid-run (`[train] on_failure` /
    /// `--on-failure abort|respawn|shrink`). Non-abort policies rebuild
    /// the cluster and replay from the rolling in-memory snapshot (see
    /// EXPERIMENTS.md §Fault tolerance).
    pub on_failure: OnFailure,
    /// Rolling in-memory snapshot cadence in steps for fault tolerance
    /// (`[train] snapshot_every` / `--snapshot-every`; 0 is treated as 1).
    /// Independent of the on-disk `checkpoint_every` cadence.
    pub snapshot_every: u64,
    /// Worker-loss recoveries allowed before the run fails anyway
    /// (`[train] max_recoveries` / `--max-recoveries`).
    pub max_recoveries: usize,
    /// Process-transport spawn/handshake retries per rank before the
    /// launch fails (`[dist] spawn_retries` / `--spawn-retries`).
    pub spawn_retries: usize,

    pub seed: u64,
    pub corpus_tokens: usize,
    pub val_tokens: usize,
    pub eval_every: u64,
    pub eval_batches: usize,
    pub checkpoint_every: u64,
    pub log_every: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            preset: "llama-nano".into(),
            artifacts_dir: PathBuf::from("artifacts"),
            out_dir: PathBuf::from("runs"),
            run_name: "run".into(),
            resume_from: None,
            resume_requantize: false,
            optimizer: "galore".into(),
            lr: 0.01,
            weight_decay: 0.0,
            adafactor_eps: 1e-30,
            sgdm_momentum: 0.9,
            steps: 200,
            warmup_frac: 0.1,
            lr_floor_frac: 0.1,
            galore_rank: 0,
            galore_update_freq: 50,
            galore_alpha: 0.25,
            galore_projection: "rand_svd".into(),
            galore_moments: "keep".into(),
            galore_similarity: 0.9,
            parallel: ParallelMode::Single,
            world: 1,
            threads: 0,
            pool: true,
            transport: TransportKind::Threads,
            overlap: true,
            shm: true,
            engine: Engine::Native,
            on_failure: OnFailure::Abort,
            snapshot_every: 50,
            max_recoveries: 3,
            spawn_retries: 2,
            seed: 42,
            corpus_tokens: 200_000,
            val_tokens: 20_000,
            eval_every: 50,
            eval_batches: 8,
            checkpoint_every: 0,
            log_every: 10,
        }
    }
}

impl TrainConfig {
    pub fn from_toml(path: &str) -> Result<TrainConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        let doc = TomlDoc::parse(&text)
            .map_err(|e| anyhow::anyhow!("parsing {path}: {e}"))?;
        let d = TrainConfig::default();
        Ok(TrainConfig {
            preset: doc.str_or("", "preset", &d.preset),
            run_name: doc.str_or("", "run_name", &d.run_name),
            artifacts_dir: PathBuf::from(doc.str_or(
                "",
                "artifacts_dir",
                d.artifacts_dir.to_str().unwrap(),
            )),
            out_dir: PathBuf::from(doc.str_or("", "out_dir", d.out_dir.to_str().unwrap())),
            resume_from: match doc.str_or("train", "resume", "") {
                s if s.is_empty() => None,
                s => Some(PathBuf::from(s)),
            },
            resume_requantize: doc.bool_or("train", "resume_requantize", d.resume_requantize),
            optimizer: doc.str_or("optimizer", "name", &d.optimizer),
            lr: doc.f64_or("optimizer", "lr", d.lr as f64) as f32,
            weight_decay: doc.f64_or("optimizer", "weight_decay", d.weight_decay as f64)
                as f32,
            adafactor_eps: doc.f64_or("optimizer", "adafactor_eps", d.adafactor_eps as f64)
                as f32,
            sgdm_momentum: doc.f64_or("optimizer", "momentum", d.sgdm_momentum as f64)
                as f32,
            steps: doc.i64_or("train", "steps", d.steps as i64) as u64,
            warmup_frac: doc.f64_or("train", "warmup_frac", d.warmup_frac),
            lr_floor_frac: doc.f64_or("train", "lr_floor_frac", d.lr_floor_frac as f64)
                as f32,
            galore_rank: doc.i64_or("galore", "rank", d.galore_rank as i64) as usize,
            galore_update_freq: doc
                .i64_or("galore", "update_freq", d.galore_update_freq as i64)
                as u64,
            galore_alpha: doc.f64_or("galore", "alpha", d.galore_alpha as f64) as f32,
            galore_projection: doc.str_or("galore", "projection", &d.galore_projection),
            galore_moments: doc.str_or("galore", "moments", &d.galore_moments),
            galore_similarity: doc.f64_or(
                "galore",
                "similarity_threshold",
                d.galore_similarity as f64,
            ) as f32,
            parallel: ParallelMode::parse(&doc.str_or("parallel", "mode", "single"))?,
            world: doc.i64_or("parallel", "world", d.world as i64) as usize,
            // Clamp: a negative value would wrap to a huge usize thread count.
            threads: doc.i64_or("parallel", "threads", d.threads as i64).max(0) as usize,
            pool: doc.bool_or("parallel", "pool", d.pool),
            transport: TransportKind::parse(&doc.str_or("dist", "transport", "threads"))
                .map_err(|e| anyhow::anyhow!(e))?,
            overlap: doc.bool_or("dist", "overlap", d.overlap),
            shm: doc.bool_or("dist", "shm", d.shm),
            engine: Engine::parse(&doc.str_or("train", "engine", "native"))?,
            on_failure: OnFailure::parse(&doc.str_or("train", "on_failure", "abort"))
                .map_err(|e| anyhow::anyhow!(e))?,
            snapshot_every: doc
                .i64_or("train", "snapshot_every", d.snapshot_every as i64)
                .max(0) as u64,
            max_recoveries: doc
                .i64_or("train", "max_recoveries", d.max_recoveries as i64)
                .max(0) as usize,
            spawn_retries: doc
                .i64_or("dist", "spawn_retries", d.spawn_retries as i64)
                .max(0) as usize,
            seed: doc.i64_or("train", "seed", d.seed as i64) as u64,
            corpus_tokens: doc.i64_or("data", "corpus_tokens", d.corpus_tokens as i64)
                as usize,
            val_tokens: doc.i64_or("data", "val_tokens", d.val_tokens as i64) as usize,
            eval_every: doc.i64_or("train", "eval_every", d.eval_every as i64) as u64,
            eval_batches: doc.i64_or("train", "eval_batches", d.eval_batches as i64)
                as usize,
            checkpoint_every: doc
                .i64_or("train", "checkpoint_every", d.checkpoint_every as i64)
                as u64,
            log_every: doc.i64_or("train", "log_every", d.log_every as i64) as u64,
        })
    }

    /// CLI flags override file values (`--steps`, `--optimizer`, …).
    /// Unknown `--parallel` / `--engine` values are an error, exactly like
    /// their TOML counterparts.
    pub fn apply_cli(&mut self, args: &Args) -> Result<()> {
        self.preset = args.str_or("preset", &self.preset);
        self.run_name = args.str_or("run-name", &self.run_name);
        if let Some(d) = args.get("artifacts-dir") {
            self.artifacts_dir = PathBuf::from(d);
        }
        if let Some(d) = args.get("out-dir") {
            self.out_dir = PathBuf::from(d);
        }
        if let Some(p) = args.get("resume") {
            self.resume_from = Some(PathBuf::from(p));
        }
        self.resume_requantize = args.bool_or("resume-requantize", self.resume_requantize);
        self.optimizer = args.str_or("optimizer", &self.optimizer);
        self.lr = args.f32_or("lr", self.lr);
        self.weight_decay = args.f32_or("weight-decay", self.weight_decay);
        self.steps = args.u64_or("steps", self.steps);
        self.galore_rank = args.usize_or("rank", self.galore_rank);
        self.galore_update_freq = args.u64_or("update-freq", self.galore_update_freq);
        self.galore_alpha = args.f32_or("alpha", self.galore_alpha);
        self.galore_projection = args.str_or("projection", &self.galore_projection);
        self.galore_moments = args.str_or("moments", &self.galore_moments);
        self.world = args.usize_or("world", self.world);
        self.threads = args.usize_or("threads", self.threads);
        self.pool = args.bool_or("pool", self.pool);
        self.overlap = args.bool_or("overlap", self.overlap);
        self.shm = args.bool_or("shm", self.shm);
        if let Some(mode) = args.get("parallel") {
            self.parallel = ParallelMode::parse(mode)?;
        }
        if let Some(transport) = args.get("transport") {
            self.transport = TransportKind::parse(transport).map_err(|e| anyhow::anyhow!(e))?;
        }
        if let Some(engine) = args.get("engine") {
            self.engine = Engine::parse(engine)?;
        }
        if let Some(policy) = args.get("on-failure") {
            self.on_failure = OnFailure::parse(policy).map_err(|e| anyhow::anyhow!(e))?;
        }
        self.snapshot_every = args.u64_or("snapshot-every", self.snapshot_every);
        self.max_recoveries = args.usize_or("max-recoveries", self.max_recoveries);
        self.spawn_retries = args.usize_or("spawn-retries", self.spawn_retries);
        self.seed = args.u64_or("seed", self.seed);
        self.eval_every = args.u64_or("eval-every", self.eval_every);
        self.eval_batches = args.usize_or("eval-batches", self.eval_batches);
        self.corpus_tokens = args.usize_or("corpus-tokens", self.corpus_tokens);
        self.log_every = args.u64_or("log-every", self.log_every);
        Ok(())
    }

    /// Cross-field validation (individual fields are validated where they
    /// parse). Call sites: `main::load_cfg` (fail before any artifact or
    /// data work) and `Trainer::new` (guards non-CLI construction paths).
    pub fn validate(&self) -> Result<()> {
        if self.parallel == ParallelMode::Single && self.transport != TransportKind::Threads {
            bail!(
                "transport {:?} needs distributed workers; use --parallel fsdp|ddp \
                 (single-process runs have no worker fabric to select)",
                self.transport.name()
            );
        }
        if self.on_failure != OnFailure::Abort && self.parallel == ParallelMode::Single {
            bail!(
                "--on-failure {} needs distributed workers to rebuild; use \
                 --parallel fsdp|ddp (a single-process run has no cluster to recover)",
                self.on_failure.name()
            );
        }
        Ok(())
    }

    pub fn galore_cfg(&self, hidden: usize) -> Result<GaLoreCfg> {
        let rank = if self.galore_rank == 0 {
            (hidden / 4).max(1)
        } else {
            self.galore_rank
        };
        let projection = ProjectionKind::parse(&self.galore_projection)
            .with_context(|| format!("unknown projection {:?}", self.galore_projection))?;
        let moments = match self.galore_moments.as_str() {
            "keep" => MomentHandling::Keep,
            "reset" => MomentHandling::Reset,
            "project" => MomentHandling::Project,
            other => bail!("unknown moment handling {other:?}"),
        };
        Ok(GaLoreCfg {
            rank,
            update_freq: self.galore_update_freq,
            alpha: self.galore_alpha,
            projection,
            moments,
            min_dim: 2,
            external_subspace: false,
        })
    }

    pub fn adam_cfg(&self) -> AdamCfg {
        AdamCfg {
            weight_decay: self.weight_decay,
            ..AdamCfg::default()
        }
    }

    /// The single mapping from config strings to the optimizer recipe.
    /// Execution modes never interpret `cfg.optimizer` / `cfg.engine`
    /// themselves — they build whatever this spec says via
    /// [`OptimizerSpec::build`].
    pub fn optimizer_spec(&self, hidden: usize) -> Result<OptimizerSpec> {
        if self.engine == Engine::Pjrt {
            if self.optimizer != "galore" {
                bail!("engine=pjrt only applies to galore (got {})", self.optimizer);
            }
            if self.parallel != ParallelMode::Single {
                bail!("engine=pjrt is single-process only (use --parallel single)");
            }
            return Ok(OptimizerSpec::PjrtGaLore {
                galore: self.galore_cfg(hidden)?,
                adam: self.adam_cfg(),
            });
        }
        Ok(match self.optimizer.as_str() {
            "adamw" => OptimizerSpec::AdamW(self.adam_cfg()),
            "adam8bit" => OptimizerSpec::Adam8bit(self.adam_cfg()),
            "adafactor" => OptimizerSpec::Adafactor {
                eps: self.adafactor_eps,
            },
            "sgdm" => OptimizerSpec::SgdM {
                momentum: self.sgdm_momentum,
            },
            "galore" => OptimizerSpec::GaLore {
                galore: self.galore_cfg(hidden)?,
                adam: self.adam_cfg(),
            },
            "qgalore" => OptimizerSpec::QGaLore {
                // The spec normalizes a non-quantized projection kind to
                // Quant8 (Q-GaLore's invariant) while honouring an
                // explicit q4 choice.
                galore: self.galore_cfg(hidden)?,
                adam: self.adam_cfg(),
                similarity_threshold: self.galore_similarity,
            },
            other => bail!("unknown optimizer {other:?}"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
preset = "llama-mini"
run_name = "fig3"

[train]
steps = 500
engine = "native"
seed = 7

[optimizer]
name = "galore"
lr = 0.005
adafactor_eps = 1e-20
momentum = 0.8

[galore]
rank = 64
update_freq = 100
alpha = 0.125
projection = "rand_svd"
similarity_threshold = 0.7

[parallel]
mode = "fsdp"
world = 4
threads = 2
pool = false

[dist]
transport = "process"
overlap = false
shm = false
"#;

    fn write_sample(name: &str, body: &str) -> std::path::PathBuf {
        let path = std::env::temp_dir()
            .join(format!("galore2_cfg_{name}_{}.toml", std::process::id()));
        std::fs::write(&path, body).unwrap();
        path
    }

    #[test]
    fn parses_full_config() {
        let path = write_sample("full", SAMPLE);
        let c = TrainConfig::from_toml(path.to_str().unwrap()).unwrap();
        assert_eq!(c.preset, "llama-mini");
        assert_eq!(c.steps, 500);
        assert_eq!(c.galore_rank, 64);
        assert!((c.galore_alpha - 0.125).abs() < 1e-6);
        assert!((c.galore_similarity - 0.7).abs() < 1e-6);
        assert!((c.sgdm_momentum - 0.8).abs() < 1e-6);
        assert!(c.adafactor_eps > 0.0 && c.adafactor_eps < 1e-19);
        assert_eq!(c.parallel, ParallelMode::Fsdp);
        assert_eq!(c.world, 4);
        assert_eq!(c.threads, 2);
        assert!(!c.pool, "[parallel] pool = false must disable the pool");
        assert!(TrainConfig::default().pool, "pool defaults on");
        assert_eq!(c.transport, TransportKind::Process);
        assert!(!c.overlap, "[dist] overlap = false must select serial");
        assert!(TrainConfig::default().overlap, "overlap defaults on");
        assert!(!c.shm, "[dist] shm = false must select the socket plane");
        assert!(TrainConfig::default().shm, "shm defaults on");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn pool_flag_parses_from_cli() {
        let mut c = TrainConfig::default();
        assert!(c.pool);
        let args =
            Args::parse("train --pool false".split_whitespace().map(String::from)).unwrap();
        c.apply_cli(&args).unwrap();
        assert!(!c.pool, "--pool false must select the scoped fallback");
    }

    #[test]
    fn overlap_flag_parses_from_cli() {
        let mut c = TrainConfig::default();
        assert!(c.overlap);
        let args =
            Args::parse("train --overlap false".split_whitespace().map(String::from)).unwrap();
        c.apply_cli(&args).unwrap();
        assert!(!c.overlap, "--overlap false must select serial collectives");
    }

    #[test]
    fn shm_flag_parses_from_cli() {
        let mut c = TrainConfig::default();
        assert!(c.shm);
        let args = Args::parse("train --shm false".split_whitespace().map(String::from)).unwrap();
        c.apply_cli(&args).unwrap();
        assert!(!c.shm, "--shm false must select the socket data plane");
    }

    #[test]
    fn transport_defaults_to_threads_and_parses_both_ways() {
        let c = TrainConfig::default();
        assert_eq!(c.transport, TransportKind::Threads);
        let mut c = TrainConfig::default();
        let args = Args::parse(
            "train --parallel fsdp --transport process"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        c.apply_cli(&args).unwrap();
        assert_eq!(c.transport, TransportKind::Process);
        // CLI/TOML parity: both reject unknown transports.
        let mut c = TrainConfig::default();
        let bad =
            Args::parse("train --transport tcp".split_whitespace().map(String::from)).unwrap();
        assert!(c.apply_cli(&bad).is_err());
        let toml_bad = write_sample("badtransport", "[dist]\ntransport = \"tcp\"\n");
        assert!(TrainConfig::from_toml(toml_bad.to_str().unwrap()).is_err());
        std::fs::remove_file(toml_bad).ok();
    }

    #[test]
    fn validate_rejects_process_transport_without_distributed_workers() {
        let mut c = TrainConfig {
            transport: TransportKind::Process,
            ..TrainConfig::default()
        };
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("fsdp|ddp"), "unhelpful error: {err}");
        c.parallel = ParallelMode::Fsdp;
        assert!(c.validate().is_ok());
        c.parallel = ParallelMode::Ddp;
        assert!(c.validate().is_ok());
        assert!(TrainConfig::default().validate().is_ok());
    }

    #[test]
    fn fault_tolerance_knobs_parse_from_toml_and_cli() {
        let d = TrainConfig::default();
        assert_eq!(d.on_failure, OnFailure::Abort);
        assert_eq!(d.snapshot_every, 50);
        assert_eq!(d.max_recoveries, 3);
        assert_eq!(d.spawn_retries, 2);
        let path = write_sample(
            "fault",
            "[train]\non_failure = \"shrink\"\nsnapshot_every = 10\nmax_recoveries = 5\n\
             \n[parallel]\nmode = \"fsdp\"\nworld = 4\n\n[dist]\nspawn_retries = 4\n",
        );
        let c = TrainConfig::from_toml(path.to_str().unwrap()).unwrap();
        assert_eq!(c.on_failure, OnFailure::Shrink);
        assert_eq!(c.snapshot_every, 10);
        assert_eq!(c.max_recoveries, 5);
        assert_eq!(c.spawn_retries, 4);
        assert!(c.validate().is_ok());
        std::fs::remove_file(path).ok();
        let mut c = TrainConfig::default();
        let args = Args::parse(
            "train --parallel ddp --on-failure respawn --snapshot-every 25 \
             --max-recoveries 1 --spawn-retries 0"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        c.apply_cli(&args).unwrap();
        assert_eq!(c.on_failure, OnFailure::Respawn);
        assert_eq!(c.snapshot_every, 25);
        assert_eq!(c.max_recoveries, 1);
        assert_eq!(c.spawn_retries, 0);
        assert!(c.validate().is_ok());
        // CLI/TOML parity: both reject unknown policies.
        let mut c = TrainConfig::default();
        let bad = Args::parse(
            "train --on-failure retry".split_whitespace().map(String::from),
        )
        .unwrap();
        assert!(c.apply_cli(&bad).is_err());
        let toml_bad = write_sample("badfailure", "[train]\non_failure = \"retry\"\n");
        assert!(TrainConfig::from_toml(toml_bad.to_str().unwrap()).is_err());
        std::fs::remove_file(toml_bad).ok();
    }

    #[test]
    fn validate_rejects_recovery_without_distributed_workers() {
        let mut c = TrainConfig {
            on_failure: OnFailure::Respawn,
            ..TrainConfig::default()
        };
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("fsdp|ddp"), "unhelpful error: {err}");
        c.parallel = ParallelMode::Fsdp;
        assert!(c.validate().is_ok());
        c.on_failure = OnFailure::Shrink;
        c.parallel = ParallelMode::Ddp;
        assert!(c.validate().is_ok());
    }

    #[test]
    fn resume_path_parses_from_toml_and_cli() {
        let c = TrainConfig::default();
        assert!(c.resume_from.is_none());
        let path = write_sample(
            "resume",
            "[train]\nresume = \"runs/x/step_20.ckpt\"\n",
        );
        let c = TrainConfig::from_toml(path.to_str().unwrap()).unwrap();
        assert_eq!(
            c.resume_from.as_deref(),
            Some(std::path::Path::new("runs/x/step_20.ckpt"))
        );
        std::fs::remove_file(path).ok();
        let mut c = TrainConfig::default();
        let args = Args::parse(
            "train --resume runs/y/step_5.ckpt"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        c.apply_cli(&args).unwrap();
        assert_eq!(
            c.resume_from.as_deref(),
            Some(std::path::Path::new("runs/y/step_5.ckpt"))
        );
    }

    #[test]
    fn resume_requantize_parses_from_toml_and_cli() {
        // Off by default: inexact imports must be opt-in only.
        assert!(!TrainConfig::default().resume_requantize);
        let path = write_sample(
            "requant",
            "[train]\nresume = \"runs/x/step_20.ckpt\"\nresume_requantize = true\n",
        );
        let c = TrainConfig::from_toml(path.to_str().unwrap()).unwrap();
        assert!(c.resume_requantize);
        std::fs::remove_file(path).ok();
        let mut c = TrainConfig::default();
        let args = Args::parse(
            "train --resume runs/y/step_5.ckpt --resume-requantize"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        c.apply_cli(&args).unwrap();
        assert!(c.resume_requantize);
    }

    #[test]
    fn cli_overrides_file() {
        let mut c = TrainConfig::default();
        let args = Args::parse(
            "train --steps 99 --optimizer adam8bit --rank 32 --parallel ddp \
             --weight-decay 0.1 --moments reset --eval-batches 3"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        c.apply_cli(&args).unwrap();
        assert_eq!(c.steps, 99);
        assert_eq!(c.optimizer, "adam8bit");
        assert_eq!(c.galore_rank, 32);
        assert_eq!(c.parallel, ParallelMode::Ddp);
        assert!((c.weight_decay - 0.1).abs() < 1e-6);
        assert_eq!(c.galore_moments, "reset");
        assert_eq!(c.eval_batches, 3);
    }

    #[test]
    fn cli_rejects_unknown_modes_like_toml_does() {
        // CLI/TOML parity: both fail on unknown parallel/engine values.
        let mut c = TrainConfig::default();
        let bad_parallel = Args::parse(
            "train --parallel mesh".split_whitespace().map(String::from),
        )
        .unwrap();
        assert!(c.apply_cli(&bad_parallel).is_err());
        let bad_engine =
            Args::parse("train --engine cuda".split_whitespace().map(String::from))
                .unwrap();
        assert!(c.apply_cli(&bad_engine).is_err());
        let toml_bad = write_sample("badmode", "[parallel]\nmode = \"mesh\"\n");
        assert!(TrainConfig::from_toml(toml_bad.to_str().unwrap()).is_err());
        std::fs::remove_file(toml_bad).ok();
    }

    #[test]
    fn galore_rank_auto_is_quarter_hidden() {
        let c = TrainConfig::default();
        assert_eq!(c.galore_cfg(4096).unwrap().rank, 1024);
        let spec = c.optimizer_spec(256).unwrap();
        assert_eq!(spec.name(), "galore");
    }

    #[test]
    fn optimizer_spec_covers_every_name() {
        for (name, expect) in [
            ("adamw", "adamw"),
            ("adam8bit", "adam8bit"),
            ("adafactor", "adafactor"),
            ("sgdm", "sgdm"),
            ("galore", "galore"),
            ("qgalore", "qgalore"),
        ] {
            let c = TrainConfig {
                optimizer: name.into(),
                ..TrainConfig::default()
            };
            assert_eq!(c.optimizer_spec(64).unwrap().name(), expect);
        }
    }

    #[test]
    fn lifted_hyperparameters_reach_the_spec() {
        let c = TrainConfig {
            optimizer: "adafactor".into(),
            adafactor_eps: 1e-8,
            ..TrainConfig::default()
        };
        match c.optimizer_spec(64).unwrap() {
            OptimizerSpec::Adafactor { eps } => assert!((eps - 1e-8).abs() < 1e-12),
            other => panic!("wrong spec {other:?}"),
        }
        let c = TrainConfig {
            optimizer: "sgdm".into(),
            sgdm_momentum: 0.75,
            ..TrainConfig::default()
        };
        match c.optimizer_spec(64).unwrap() {
            OptimizerSpec::SgdM { momentum } => assert!((momentum - 0.75).abs() < 1e-6),
            other => panic!("wrong spec {other:?}"),
        }
        let c = TrainConfig {
            optimizer: "qgalore".into(),
            galore_similarity: 0.42,
            ..TrainConfig::default()
        };
        match c.optimizer_spec(64).unwrap() {
            OptimizerSpec::QGaLore {
                similarity_threshold,
                ..
            } => assert!((similarity_threshold - 0.42).abs() < 1e-6),
            other => panic!("wrong spec {other:?}"),
        }
    }

    #[test]
    fn pjrt_spec_requires_galore_and_single() {
        let mut c = TrainConfig {
            engine: Engine::Pjrt,
            ..TrainConfig::default()
        };
        assert_eq!(c.optimizer_spec(64).unwrap().name(), "galore-pjrt");
        c.parallel = ParallelMode::Fsdp;
        assert!(c.optimizer_spec(64).is_err());
        c.parallel = ParallelMode::Single;
        c.optimizer = "adamw".into();
        assert!(c.optimizer_spec(64).is_err());
    }

    #[test]
    fn rejects_unknown_optimizer() {
        let c = TrainConfig {
            optimizer: "turbo".into(),
            ..TrainConfig::default()
        };
        assert!(c.optimizer_spec(64).is_err());
    }
}
