//! Analytic per-GPU memory model (Table 1, §1's "58 GB", §3's equations).
//!
//! Mirrors the accounting PyTorch's memory snapshot would report for the
//! paper's training setup: parameter storage, gradients, optimizer state,
//! activations, and framework overhead — under single-GPU, DDP or FSDP,
//! for each optimizer. The FSDP engine's live byte counters validate the
//! state terms at small scale; the large-preset numbers regenerate the
//! paper's tables.

use crate::model::LlamaCfg;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OptimKind {
    AdamW,
    Adam8bit,
    /// GaLore with the given rank; inner Adam moments in fp32.
    GaLore { rank: usize },
    /// GaLore + 8-bit inner Adam (the §1 single-GPU configuration).
    GaLore8bit { rank: usize },
    /// Q-GaLore (§4.2): the projector is STORED in linear INT8 blocks
    /// (1 byte/element + one f32 absmax scale per 256-element block —
    /// `Projector::nbytes`); inner Adam moments stay fp32. The model must
    /// charge the stored size, never the dequantized f32 size, to match
    /// the live `state_bytes` counters and the paper's memory table.
    QGaLore { rank: usize },
    /// LoRA with the given adapter rank (§3's comparison equation).
    Lora { rank: usize },
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Parallelism {
    Single,
    Ddp { world: usize },
    Fsdp { world: usize },
}

/// Precision plan. The paper's runs use bf16 parameters/gradients with
/// fp32 optimizer state (mixed precision); `full_fp32` models the §1
/// single-batch accounting (fp32 everything).
#[derive(Clone, Copy, Debug)]
pub struct Precision {
    pub param_bytes: usize,
    pub grad_bytes: usize,
    pub master_fp32: bool,
}

impl Precision {
    pub fn mixed_bf16() -> Precision {
        Precision {
            param_bytes: 2,
            grad_bytes: 2,
            master_fp32: true,
        }
    }
    pub fn full_fp32() -> Precision {
        Precision {
            param_bytes: 4,
            grad_bytes: 4,
            master_fp32: false,
        }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct MemoryCfg {
    pub optim: OptimKind,
    pub parallelism: Parallelism,
    pub precision: Precision,
    pub seq: usize,
    pub batch: usize,
    /// Per-layer fused update (Fig. 2): gradients are consumed layer by
    /// layer and never stored for the whole model at once.
    pub per_layer_update: bool,
    /// Activation checkpointing factor: 1.0 = store all, ~0.15 with full
    /// recompute of attention internals (the paper's large runs).
    pub activation_factor: f64,
}

#[derive(Clone, Copy, Debug, Default)]
pub struct MemoryBreakdown {
    pub params: u64,
    pub master_weights: u64,
    pub grads: u64,
    pub optimizer: u64,
    pub activations: u64,
    pub workspace: u64,
}

impl MemoryBreakdown {
    pub fn total(&self) -> u64 {
        self.params
            + self.master_weights
            + self.grads
            + self.optimizer
            + self.activations
            + self.workspace
    }

    pub fn total_gib(&self) -> f64 {
        self.total() as f64 / (1u64 << 30) as f64
    }
}

/// Stored bytes of a d×r projector under `optim`'s storage kind: fp32 for
/// plain GaLore, INT8 codes + per-block f32 absmax scales for Q-GaLore
/// (matching `Projector::nbytes` — the quantization is the point, so the
/// model must never charge the dequantized size).
fn projector_bytes(optim: OptimKind, d: u64, r: u64) -> u64 {
    match optim {
        OptimKind::QGaLore { .. } => d * r + (d * r).div_ceil(256) * 4,
        _ => d * r * 4,
    }
}

/// Optimizer-state bytes for one m×n parameter (the §3 equations).
pub fn optimizer_state_bytes(optim: OptimKind, rows: usize, cols: usize) -> u64 {
    let (m, n) = (rows.min(cols), rows.max(cols)); // paper convention m ≤ n
    let numel = (rows * cols) as u64;
    match optim {
        OptimKind::AdamW => 2 * numel * 4,
        OptimKind::Adam8bit => 2 * numel + 2 * numel.div_ceil(256) * 4,
        OptimKind::GaLore { rank }
        | OptimKind::GaLore8bit { rank }
        | OptimKind::QGaLore { rank } => {
            if rank >= m || rows.min(cols) < 2 {
                // ineligible: full-rank inner Adam
                return optimizer_state_bytes(
                    match optim {
                        OptimKind::GaLore8bit { .. } => OptimKind::Adam8bit,
                        _ => OptimKind::AdamW,
                    },
                    rows,
                    cols,
                );
            }
            let r = rank as u64;
            // §3: projector mr (at its STORED size) + moments 2nr.
            let projector = projector_bytes(optim, m as u64, r);
            let moment_elems = 2 * (n as u64) * r;
            let moments = match optim {
                OptimKind::GaLore8bit { .. } => {
                    moment_elems + moment_elems.div_ceil(256) * 4
                }
                _ => moment_elems * 4,
            };
            projector + moments
        }
        OptimKind::Lora { rank } => {
            // §3: LoRA stores adapters A (m×r), B (n×r) + their Adam
            // moments: 3mr + 3nr reduced by weights being frozen elsewhere;
            // here we count the optimizer-relevant 2·(mr+nr) moments plus
            // adapters = 3(m+n)r total, per the paper's (mn + 3mr + 3nr)
            // with the mn charged under params.
            3 * ((m + n) as u64) * (rank as u64) * 4
        }
    }
}

/// Full per-GPU breakdown for a model preset.
pub fn estimate(cfg: &LlamaCfg, mem: &MemoryCfg) -> MemoryBreakdown {
    let n_params = cfg.n_params() as u64;
    let world = match mem.parallelism {
        Parallelism::Single => 1,
        Parallelism::Ddp { .. } => 1, // DDP replicates everything
        Parallelism::Fsdp { world } => world as u64,
    };

    let params = n_params * mem.precision.param_bytes as u64 / world;
    let master_weights = if mem.precision.master_fp32 {
        n_params * 4 / world
    } else {
        0
    };

    // Gradients: FSDP + per-layer update keeps ≤ one layer's full gradient
    // live (all-gathered) + the sharded rest; otherwise a full-model copy.
    let largest_layer: u64 = cfg
        .param_specs()
        .iter()
        .map(|s| s.numel() as u64)
        .max()
        .unwrap_or(0);
    let grads = if mem.per_layer_update {
        largest_layer * mem.precision.grad_bytes as u64
            + n_params * mem.precision.grad_bytes as u64 / world / 8
    } else {
        n_params * mem.precision.grad_bytes as u64
    };

    // Optimizer state (sharded under FSDP, replicated otherwise), with the
    // GaLore projector replicated across ranks (§4.3).
    let mut optimizer = 0u64;
    for spec in cfg.param_specs() {
        let (r, c) = spec.matrix_shape();
        let full = optimizer_state_bytes(mem.optim, r, c);
        optimizer += match (mem.optim, mem.parallelism) {
            (
                OptimKind::GaLore { rank }
                | OptimKind::GaLore8bit { rank }
                | OptimKind::QGaLore { rank },
                Parallelism::Fsdp { .. },
            ) if rank < r.min(c) && spec.is_2d() => {
                // The projector is replicated across ranks (§4.3), at its
                // stored size; only the moments shard.
                let proj = projector_bytes(mem.optim, r.min(c) as u64, rank as u64);
                proj + (full - proj) / world
            }
            _ => full / world,
        };
    }

    // Activations: standard transformer estimate (Korthikanti et al.):
    // per layer ≈ s·b·h·(34 + 5·a·s/h) bytes at bf16-ish storage, scaled
    // by the checkpointing factor.
    let (s, b, h, a, layers) = (
        mem.seq as f64,
        mem.batch as f64,
        cfg.hidden as f64,
        cfg.heads as f64,
        cfg.layers as f64,
    );
    let per_layer = s * b * h * (34.0 + 5.0 * a * s / h);
    let logits = s * b * cfg.vocab as f64 * 4.0 * 2.0; // logits + softmax grad
    let activations = (layers * per_layer * mem.activation_factor + logits) as u64;

    // Workspace: collective staging + cuBLAS/XLA scratch; calibrated
    // against PyTorch's reserved-vs-allocated gap (~6% + 1 GiB).
    let subtotal = params + master_weights + grads + optimizer + activations;
    let workspace = subtotal / 16 + (1u64 << 30);

    MemoryBreakdown {
        params,
        master_weights,
        grads,
        optimizer,
        activations,
        workspace,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gib(x: f64) -> u64 {
        (x * (1u64 << 30) as f64) as u64
    }

    #[test]
    fn galore_equation_matches_paper_exactly() {
        // §3: GaLore memory = mn (weight) + mr (projector) + 2nr (moments);
        // our optimizer term must equal mr + 2nr in f32 elements.
        let (m, n, r) = (4096usize, 11008usize, 1024usize);
        let bytes = optimizer_state_bytes(OptimKind::GaLore { rank: r }, m, n);
        assert_eq!(bytes, ((m * r + 2 * n * r) * 4) as u64);
        // and LoRA's 3mr + 3nr:
        let lora = optimizer_state_bytes(OptimKind::Lora { rank: r }, m, n);
        assert_eq!(lora, (3 * (m + n) * r * 4) as u64);
        // GaLore < LoRA at equal rank (the paper's point):
        assert!(bytes < lora);
    }

    #[test]
    fn orientation_invariant() {
        // m ≤ n convention must make the estimate symmetric in (rows, cols).
        let a = optimizer_state_bytes(OptimKind::GaLore { rank: 64 }, 1000, 300);
        let b = optimizer_state_bytes(OptimKind::GaLore { rank: 64 }, 300, 1000);
        assert_eq!(a, b);
    }

    #[test]
    fn qgalore_projector_counted_at_stored_size() {
        // The paper-facing memory table must charge quantized state at its
        // STORED size (codes + block scales), never dequantized f32.
        let (m, n, r) = (4096usize, 11008usize, 1024usize);
        let q = optimizer_state_bytes(OptimKind::QGaLore { rank: r }, m, n);
        let proj_elems = (m * r) as u64;
        let expect = proj_elems + proj_elems.div_ceil(256) * 4 + (2 * n * r * 4) as u64;
        assert_eq!(q, expect, "analytic q8 projector term drifted");
        // ~4x smaller projector than fp32 GaLore's mr·4 term.
        let f = optimizer_state_bytes(OptimKind::GaLore { rank: r }, m, n);
        assert_eq!(f - q, proj_elems * 4 - proj_elems - proj_elems.div_ceil(256) * 4);

        // Cross-check against the LIVE accounting: a real quantized
        // projector reports exactly the analytic stored size.
        use crate::optim::{ProjectionKind, Projector};
        use crate::tensor::Matrix;
        use crate::util::rng::Pcg64;
        let mut rng = Pcg64::new(3, 0);
        let g = Matrix::randn(256, 512, 1.0, &mut rng);
        let p = Projector::from_gradient(&g, 64, ProjectionKind::Quant8, &mut rng);
        let d = 256u64 * 64;
        assert_eq!(p.nbytes() as u64, d + d.div_ceil(256) * 4);

        // And the ineligible fallback stays fp32 Adam.
        let tiny = optimizer_state_bytes(OptimKind::QGaLore { rank: 64 }, 1, 128);
        assert_eq!(tiny, optimizer_state_bytes(OptimKind::AdamW, 1, 128));
    }

    #[test]
    fn fsdp_qgalore_replicates_stored_projector_only() {
        // Under FSDP the projector term must stay at its stored (int8)
        // size while the fp32 moments shard with the world.
        let cfg = LlamaCfg::preset("llama-1b").unwrap();
        let mk = |optim| {
            estimate(
                &cfg,
                &MemoryCfg {
                    optim,
                    parallelism: Parallelism::Fsdp { world: 4 },
                    precision: Precision::mixed_bf16(),
                    seq: 1024,
                    batch: 1,
                    per_layer_update: true,
                    activation_factor: 0.3,
                },
            )
        };
        let rank = 128;
        let q = mk(OptimKind::QGaLore { rank });
        let f = mk(OptimKind::GaLore { rank });
        assert!(
            q.optimizer < f.optimizer,
            "quantized projector must shrink the optimizer term: {} !< {}",
            q.optimizer,
            f.optimizer
        );
    }

    #[test]
    fn adam8bit_is_quarter_of_adamw() {
        let a = optimizer_state_bytes(OptimKind::AdamW, 512, 512);
        let b = optimizer_state_bytes(OptimKind::Adam8bit, 512, 512);
        assert!(b * 39 / 10 <= a && a <= b * 41 / 10, "{a} vs {b}");
    }

    #[test]
    fn intro_claim_7b_adam_exceeds_58gb() {
        // §1: "pre-training a Llama 7B model requires at least 58 GB of
        // memory for just a single batch" (fp32 Adam, no tricks):
        // 4(W) + 4(G) + 8(opt) = 16 bytes/param ⇒ ~100 GB at 6.7B, and
        // ≥58 GB already at bf16 weights+grads. Check the fp32 floor.
        let cfg = LlamaCfg::preset("llama-7b").unwrap();
        let mem = MemoryCfg {
            optim: OptimKind::AdamW,
            parallelism: Parallelism::Single,
            precision: Precision::full_fp32(),
            seq: 1024,
            batch: 1,
            per_layer_update: false,
            activation_factor: 0.15,
        };
        let est = estimate(&cfg, &mem);
        assert!(
            est.total() > gib(58.0),
            "7B Adam estimate {:.1} GiB below the paper's 58 GB floor",
            est.total_gib()
        );
    }

    #[test]
    fn intro_claim_galore8bit_fits_24gb() {
        // §1: GaLore (8-bit Adam, per-layer update) pre-trains 7B on a
        // 24 GB RTX 4090.
        let cfg = LlamaCfg::preset("llama-7b").unwrap();
        let mem = MemoryCfg {
            optim: OptimKind::GaLore8bit { rank: 1024 },
            parallelism: Parallelism::Single,
            precision: Precision {
                param_bytes: 2,
                grad_bytes: 2,
                master_fp32: false,
            },
            seq: 256,
            batch: 1,
            per_layer_update: true,
            activation_factor: 0.15,
        };
        let est = estimate(&cfg, &mem);
        assert!(
            est.total() < gib(24.0),
            "GaLore-8bit 7B estimate {:.1} GiB exceeds 24 GB",
            est.total_gib()
        );
    }

    #[test]
    fn fsdp_galore_beats_fsdp_adamw_at_8b() {
        // Table 1 ordering: GaLore+FSDP < AdamW+FSDP on Llama3-8B.
        let cfg = LlamaCfg::preset("llama3-8b").unwrap();
        let base = MemoryCfg {
            optim: OptimKind::AdamW,
            parallelism: Parallelism::Fsdp { world: 2 },
            precision: Precision::mixed_bf16(),
            seq: 2048,
            batch: 1,
            per_layer_update: false,
            activation_factor: 0.3,
        };
        let adamw = estimate(&cfg, &base);
        let galore = estimate(
            &cfg,
            &MemoryCfg {
                optim: OptimKind::GaLore { rank: 1024 },
                per_layer_update: true,
                ..base
            },
        );
        assert!(
            galore.total() < adamw.total(),
            "galore {:.2} GiB !< adamw {:.2} GiB",
            galore.total_gib(),
            adamw.total_gib()
        );
        // Both in the Table-1 ballpark (tens of GB).
        assert!(adamw.total_gib() > 40.0 && adamw.total_gib() < 120.0);
    }

    #[test]
    fn fsdp_scales_state_down_with_world() {
        let cfg = LlamaCfg::preset("llama-1b").unwrap();
        let mk = |world| {
            estimate(
                &cfg,
                &MemoryCfg {
                    optim: OptimKind::AdamW,
                    parallelism: Parallelism::Fsdp { world },
                    precision: Precision::mixed_bf16(),
                    seq: 1024,
                    batch: 1,
                    per_layer_update: false,
                    activation_factor: 0.3,
                },
            )
        };
        let w2 = mk(2);
        let w8 = mk(8);
        assert!(w8.optimizer * 3 < w2.optimizer);
        assert!(w8.params < w2.params);
        // Activations don't shard (same batch per GPU).
        assert_eq!(w8.activations, w2.activations);
    }

    #[test]
    fn ddp_equals_single_for_state() {
        let cfg = LlamaCfg::preset("llama-1b").unwrap();
        let mk = |parallelism| {
            estimate(
                &cfg,
                &MemoryCfg {
                    optim: OptimKind::AdamW,
                    parallelism,
                    precision: Precision::mixed_bf16(),
                    seq: 512,
                    batch: 1,
                    per_layer_update: false,
                    activation_factor: 0.3,
                },
            )
        };
        let single = mk(Parallelism::Single);
        let ddp = mk(Parallelism::Ddp { world: 8 });
        assert_eq!(single.optimizer, ddp.optimizer);
        assert_eq!(single.params, ddp.params);
    }

    #[test]
    fn longer_seq_costs_more_activations() {
        let cfg = LlamaCfg::preset("llama3-8b").unwrap();
        let mk = |seq| {
            estimate(
                &cfg,
                &MemoryCfg {
                    optim: OptimKind::GaLore { rank: 1024 },
                    parallelism: Parallelism::Fsdp { world: 2 },
                    precision: Precision::mixed_bf16(),
                    seq,
                    batch: 1,
                    per_layer_update: true,
                    activation_factor: 0.3,
                },
            )
        };
        // Table 1: GaLore 4096 (77.45) > GaLore 2048 (72.84).
        assert!(mk(4096).total() > mk(2048).total());
    }
}
