//! String/comment-aware token scanner for the invariant linter.
//!
//! Deliberately NOT a Rust parser (no `syn`, no dependency): the lint
//! rules (`analysis/rules.rs`) only need identifier/punctuation tokens
//! with line numbers, plus the text of `//` comments (where the
//! `lint: allow` escape hatch lives). What the scanner must get exactly
//! right is what it *skips* — string literals (including raw and byte
//! strings), char literals vs lifetimes, and nested block comments — so
//! a rule can never fire on the word `unwrap` inside an error message,
//! and a banned call can never hide inside what the scanner mistakes for
//! a string.

/// One scanned token. Identifiers (including keywords and numeric
/// literals — the rules treat both as plain words) carry their full
/// text; everything else is a single punctuation character.
#[derive(Clone, Debug, PartialEq)]
pub struct Token {
    pub text: String,
    pub line: u32,
    pub is_ident: bool,
}

/// A `//` comment (line or doc), with the text after the slashes.
#[derive(Clone, Debug, PartialEq)]
pub struct Comment {
    pub line: u32,
    pub text: String,
}

/// Scan result: code tokens plus the line comments (for allow parsing).
pub struct Lexed {
    pub tokens: Vec<Token>,
    pub comments: Vec<Comment>,
}

fn is_ident_char(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Scan `src` into tokens and comments. Never fails: unterminated
/// strings/comments simply consume the rest of the file (the rustc build
/// running alongside the linter reports those as what they are).
pub fn lex(src: &str) -> Lexed {
    let b: Vec<char> = src.chars().collect();
    let n = b.len();
    let mut tokens = Vec::new();
    let mut comments = Vec::new();
    let mut i = 0usize;
    let mut line = 1u32;
    while i < n {
        let c = b[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // Line comment (also covers /// and //! doc comments).
        if c == '/' && i + 1 < n && b[i + 1] == '/' {
            let start = i + 2;
            let mut j = start;
            while j < n && b[j] != '\n' {
                j += 1;
            }
            comments.push(Comment {
                line,
                text: b[start..j].iter().collect(),
            });
            i = j;
            continue;
        }
        // Block comment, nested.
        if c == '/' && i + 1 < n && b[i + 1] == '*' {
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if b[i] == '\n' {
                    line += 1;
                    i += 1;
                } else if b[i] == '/' && i + 1 < n && b[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == '*' && i + 1 < n && b[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            continue;
        }
        // String-literal family. Check the prefixed forms BEFORE generic
        // ident scanning, so `r"..."`/`br#"..."#`/`b"..."`/`b'x'` are
        // skipped as literals rather than read as idents.
        if c == '"' {
            i = skip_string(&b, i + 1, &mut line, true);
            continue;
        }
        if c == 'b' && i + 1 < n && b[i + 1] == '"' {
            i = skip_string(&b, i + 2, &mut line, true);
            continue;
        }
        if c == 'b' && i + 1 < n && b[i + 1] == '\'' {
            i = skip_char_literal(&b, i + 1);
            continue;
        }
        if c == 'r' || (c == 'b' && i + 1 < n && b[i + 1] == 'r') {
            let after_prefix = if c == 'r' { i + 1 } else { i + 2 };
            let mut j = after_prefix;
            while j < n && b[j] == '#' {
                j += 1;
            }
            if j < n && b[j] == '"' {
                let hashes = j - after_prefix;
                i = skip_raw_string(&b, j + 1, hashes, &mut line);
                continue;
            }
            // Fall through: an ordinary ident starting with r/b.
        }
        // Char literal vs lifetime.
        if c == '\'' {
            if i + 1 < n && b[i + 1] == '\\' {
                i = skip_char_literal(&b, i);
                continue;
            }
            if i + 2 < n && b[i + 2] == '\'' && b[i + 1] != '\'' {
                // 'x'
                i += 3;
                continue;
            }
            // Lifetime or loop label: consume the quote + ident chars.
            i += 1;
            while i < n && is_ident_char(b[i]) {
                i += 1;
            }
            continue;
        }
        if is_ident_char(c) {
            let start = i;
            while i < n && is_ident_char(b[i]) {
                i += 1;
            }
            tokens.push(Token {
                text: b[start..i].iter().collect(),
                line,
                is_ident: true,
            });
            continue;
        }
        tokens.push(Token {
            text: c.to_string(),
            line,
            is_ident: false,
        });
        i += 1;
    }
    Lexed { tokens, comments }
}

/// Skip past a (possibly multi-line) quoted literal starting AFTER the
/// opening quote; returns the index after the closing quote.
fn skip_string(b: &[char], mut i: usize, line: &mut u32, escapes: bool) -> usize {
    let n = b.len();
    while i < n {
        match b[i] {
            '\\' if escapes => i += 2,
            '"' => return i + 1,
            '\n' => {
                *line += 1;
                i += 1;
            }
            _ => i += 1,
        }
    }
    n
}

/// Skip a raw string body (after the opening quote): ends at `"` followed
/// by `hashes` `#` characters. No escapes inside.
fn skip_raw_string(b: &[char], mut i: usize, hashes: usize, line: &mut u32) -> usize {
    let n = b.len();
    while i < n {
        if b[i] == '\n' {
            *line += 1;
            i += 1;
            continue;
        }
        if b[i] == '"' {
            let mut k = 0usize;
            while k < hashes && i + 1 + k < n && b[i + 1 + k] == '#' {
                k += 1;
            }
            if k == hashes {
                return i + 1 + hashes;
            }
        }
        i += 1;
    }
    n
}

/// Skip a char/byte-char literal starting AT the opening quote; returns
/// the index after the closing quote. Handles `'\''`, `'\\'`, `'\u{..}'`.
fn skip_char_literal(b: &[char], mut i: usize) -> usize {
    let n = b.len();
    i += 1; // opening quote
    if i < n && b[i] == '\\' {
        i += 2; // backslash + escaped char (or the u of \u{...})
        while i < n && b[i] != '\'' {
            i += 1;
        }
        return (i + 1).min(n);
    }
    i += 1; // the literal char
    if i < n && b[i] == '\'' {
        i += 1;
    }
    i
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .tokens
            .into_iter()
            .filter(|t| t.is_ident)
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn strings_and_comments_are_invisible() {
        let src = r##"
            // from_le_bytes in a comment
            /* unwrap() in a /* nested */ block */
            let s = "from_le_bytes unwrap()";
            let r = r#"to_le_bytes "quoted" panic!"#;
            let by = b"from_le_bytes";
            call(x);
        "##;
        let ids = idents(src);
        assert!(!ids.iter().any(|t| t.contains("bytes")), "{ids:?}");
        assert!(!ids.iter().any(|t| t == "unwrap"), "{ids:?}");
        assert!(ids.contains(&"call".to_string()));
    }

    #[test]
    fn char_literals_and_lifetimes() {
        let src = "fn f<'a>(x: &'a str) { let q = '\\''; let c = 'x'; 'outer: loop { break 'outer; } g(); }";
        let ids = idents(src);
        // The lifetime/label names are consumed with their quote, not
        // emitted as idents; quoted chars never start a string.
        assert!(ids.contains(&"g".to_string()));
        assert!(!ids.contains(&"outer".to_string()));
        assert!(!ids.contains(&"a".to_string()));
    }

    #[test]
    fn line_numbers_track_multiline_constructs() {
        let src = "let a = \"line\none\";\nlet b = 1; // trailing\nunwrap();\n";
        let lexed = lex(src);
        let unwrap_tok = lexed
            .tokens
            .iter()
            .find(|t| t.text == "unwrap")
            .expect("unwrap token");
        assert_eq!(unwrap_tok.line, 4);
        let trailing = lexed
            .comments
            .iter()
            .find(|c| c.text.contains("trailing"))
            .expect("comment");
        assert_eq!(trailing.line, 3);
    }

    #[test]
    fn byte_char_and_raw_prefix_idents_do_not_misfire() {
        // `rank` starts with r, `br` could look like a raw-string prefix:
        // both must stay ordinary idents; `b'R'` is a literal.
        let ids = idents("let rank = br0; let x = b'R'; let broke = 1;");
        assert!(ids.contains(&"rank".to_string()));
        assert!(ids.contains(&"br0".to_string()));
        assert!(ids.contains(&"broke".to_string()));
    }
}
