//! Project-invariant static analysis (`galore2 lint`).
//!
//! A dependency-free, lexer-based pass over the crate's own sources that
//! enforces the conventions every bitwise-parity claim in this repo
//! rests on: one hardened byte parser, checked parser allocations,
//! non-panicking dist error paths, no wall clocks or unordered maps in
//! serialization/collective code, and no lock guard held across a
//! collective. See `rules.rs` for the catalogue and the
//! `// lint: allow(<rule>): <reason>` escape hatch, and EXPERIMENTS.md
//! §Static analysis for which parity test each rule protects.
//!
//! Wired up twice: as the `galore2 lint [--json] [--root DIR]`
//! subcommand (blocking CI step) and as the `tests/invariants.rs` tier
//! (self-scan must be clean, rule fixtures must fire).

mod lexer;
mod rules;

pub use rules::{check_file as lint_source, Finding, ALLOW_HYGIENE, RULES};

use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// Result of linting a tree.
pub struct Report {
    pub findings: Vec<Finding>,
    pub files_scanned: usize,
}

impl Report {
    pub fn clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// Human-readable rendering: one `file:line rule message` per
    /// finding (the format the acceptance criteria and CI logs key on).
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            out.push_str(&format!(
                "rust/src/{}:{}: [{}] {}\n",
                f.file, f.line, f.rule, f.message
            ));
        }
        out.push_str(&format!(
            "lint: {} finding(s) across {} file(s)\n",
            self.findings.len(),
            self.files_scanned
        ));
        out
    }

    /// Machine-readable rendering (`galore2 lint --json`).
    pub fn render_json(&self) -> String {
        let mut out = String::from("{\n  \"findings\": [");
        for (i, f) in self.findings.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "\n    {{\"file\": \"{}\", \"line\": {}, \"rule\": \"{}\", \"message\": \"{}\"}}",
                json_escape(&format!("rust/src/{}", f.file)),
                f.line,
                json_escape(f.rule),
                json_escape(&f.message)
            ));
        }
        if !self.findings.is_empty() {
            out.push_str("\n  ");
        }
        out.push_str(&format!(
            "],\n  \"files_scanned\": {},\n  \"clean\": {}\n}}\n",
            self.files_scanned,
            self.clean()
        ));
        out
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Lint every `.rs` file under `<root>/rust/src`, in sorted path order
/// (deterministic output regardless of directory-entry order).
pub fn lint_root(root: &Path) -> io::Result<Report> {
    let src_root = root.join("rust").join("src");
    let mut files = Vec::new();
    collect_rs(&src_root, &mut files)?;
    files.sort();
    let mut findings = Vec::new();
    for path in &files {
        let rel = path
            .strip_prefix(&src_root)
            .unwrap_or(path)
            .components()
            .map(|c| c.as_os_str().to_string_lossy().into_owned())
            .collect::<Vec<_>>()
            .join("/");
        let src = fs::read_to_string(path)?;
        findings.extend(lint_source(&rel, &src));
    }
    Ok(Report {
        findings,
        files_scanned: files.len(),
    })
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    if !dir.is_dir() {
        return Err(io::Error::new(
            io::ErrorKind::NotFound,
            format!("lint root has no rust/src tree: {}", dir.display()),
        ));
    }
    for entry in fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_text_names_file_line_and_rule() {
        let report = Report {
            findings: lint_source(
                "runtime/mod.rs",
                "fn f(b: [u8; 8]) -> u64 { u64::from_le_bytes(b) }",
            ),
            files_scanned: 1,
        };
        let text = report.render_text();
        assert!(
            text.contains("rust/src/runtime/mod.rs:1: [single-parser]"),
            "{text}"
        );
        assert!(!report.clean());
    }

    #[test]
    fn render_json_escapes_and_reports_clean() {
        let report = Report {
            findings: vec![],
            files_scanned: 3,
        };
        let json = report.render_json();
        assert!(json.contains("\"clean\": true"), "{json}");
        assert!(json.contains("\"files_scanned\": 3"), "{json}");
        let dirty = Report {
            findings: lint_source(
                "runtime/mod.rs",
                "fn f(b: [u8; 8]) -> u64 { u64::from_le_bytes(b) }",
            ),
            files_scanned: 1,
        };
        let json = dirty.render_json();
        assert!(json.contains("\"rule\": \"single-parser\""), "{json}");
        assert!(json.contains("\"clean\": false"), "{json}");
    }
}
