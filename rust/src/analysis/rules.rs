//! The five project-invariant rules, plus the `lint: allow` escape hatch.
//!
//! Each rule is deny-by-default and suppressable only by an inline
//! comment of the form
//!
//! ```text
//! // lint: allow(<rule>): <non-empty reason>
//! ```
//!
//! placed on the offending line or on its own line directly above the
//! offending code. A malformed directive, an unknown rule name, an empty
//! reason, or a directive that precedes no code is itself a finding
//! (rule `allow-hygiene`) — the escape hatch cannot rot silently.
//!
//! Rule catalogue (scopes are module paths relative to `rust/src/`):
//!
//! - `single-parser`: raw `from_le_bytes`/`to_le_bytes` byte-layout code
//!   is confined to `optim::ser` (the `mod ser` block of `optim/mod.rs`),
//!   `shm::header` (the `mod header` block of `dist/shm.rs` — the shm
//!   control/go frames, and ONLY them), `dist/wire.rs`, and `quant/`.
//!   Everything else goes through the hardened `ser::Reader`/push
//!   helpers, so there is exactly one place where a length field is
//!   trusted.
//! - `checked-alloc`: in parser modules (`dist/wire.rs`, `dist/shm.rs`,
//!   `quant/`, `checkpoint/`, `optim/mod.rs`), a function that parses raw
//!   bytes (uses `Reader`, `from_le_bytes`, `read_exact`, or
//!   `read_to_end`) and allocates (`with_capacity`, `vec![…]`) must carry
//!   a visible bound: `remaining`, `checked_mul`, `checked_add`, or
//!   `take` — in `dist/shm.rs` this is what bounds the mapped slot-table
//!   length against the setup-declared geometry before any IO.
//! - `no-panic-dist`: inside `dist/` worker serve loops, the process
//!   relay, collective/transport bodies, and `Drop` impls, `unwrap`,
//!   `expect`, `panic!`-family macros, and slice indexing are banned —
//!   a death must flow through `FailureCell`, never a panic that could
//!   strand a peer in `PoisonBarrier`.
//! - `determinism`: no `HashMap`/`HashSet`, `Instant`/`SystemTime` in
//!   serialization/collective modules (`dist/`, `quant/`, `checkpoint/`,
//!   `optim/`), no `std::env::set_var` anywhere in the crate, and no
//!   `env::var` reads in `parallel/` — the kernel hot path resolves
//!   `GALORE2_THREADS` exactly once into a `OnceLock` (a per-call
//!   `getenv` racing a concurrent env mutation is UB; the one-time init
//!   carries a justified allow).
//! - `lock-across-collective`: a lock-guard binding (`.lock()`,
//!   `.read()`, `.write()`) still live at a `barrier`/`all_reduce`/
//!   `exchange`-family call in the same function is deadlock bait.

use super::lexer::{lex, Lexed, Token};
use std::collections::BTreeSet;

/// The enforceable rules, in catalogue order.
pub const RULES: [&str; 5] = [
    "single-parser",
    "checked-alloc",
    "no-panic-dist",
    "determinism",
    "lock-across-collective",
];

/// Meta-rule for broken `lint: allow` directives; never suppressable.
pub const ALLOW_HYGIENE: &str = "allow-hygiene";

#[derive(Clone, Debug, PartialEq)]
pub struct Finding {
    pub file: String,
    pub line: u32,
    pub rule: &'static str,
    pub message: String,
}

/// Lint one source file (path relative to `rust/src/`, `/`-separated).
/// Returns the unsuppressed findings, sorted by line.
pub fn check_file(rel: &str, src: &str) -> Vec<Finding> {
    let lexed = lex(src);
    let toks = &lexed.tokens;
    let mut raw: Vec<Finding> = Vec::new();
    rule_single_parser(rel, toks, &mut raw);
    rule_checked_alloc(rel, toks, &mut raw);
    rule_no_panic_dist(rel, toks, &mut raw);
    rule_determinism(rel, toks, &mut raw);
    rule_lock_across_collective(rel, toks, &mut raw);

    // Nested fn regions can double-report a site; keep the first.
    let mut seen: BTreeSet<(u32, &'static str, String)> = BTreeSet::new();
    raw.retain(|f| seen.insert((f.line, f.rule, f.message.clone())));

    let (allows, mut findings) = parse_allows(rel, &lexed);
    findings.extend(
        raw.into_iter()
            .filter(|f| !allows.iter().any(|a| a.rule == f.rule && a.effective_line == Some(f.line))),
    );
    findings.sort_by_key(|f| (f.line, f.rule));
    findings
}

// ---------------------------------------------------------------------------
// token helpers

fn is_id(t: &Token, s: &str) -> bool {
    t.is_ident && t.text == s
}

fn is_p(t: &Token, s: &str) -> bool {
    !t.is_ident && t.text == s
}

/// Index just past the `}` matching the `{` at `open`.
fn match_brace(toks: &[Token], open: usize) -> usize {
    let mut depth = 0usize;
    let mut i = open;
    while i < toks.len() {
        if is_p(&toks[i], "{") {
            depth += 1;
        } else if is_p(&toks[i], "}") {
            depth -= 1;
            if depth == 0 {
                return i + 1;
            }
        }
        i += 1;
    }
    toks.len()
}

/// A contiguous token range `[start, end)` with an identifying name.
struct Region {
    name: String,
    start: usize,
    end: usize,
}

/// All `fn <name> … { … }` bodies (headers included, nested fns too).
fn fn_regions(toks: &[Token]) -> Vec<Region> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < toks.len() {
        if is_id(&toks[i], "fn") && i + 1 < toks.len() && toks[i + 1].is_ident {
            let name = toks[i + 1].text.clone();
            let mut j = i + 2;
            while j < toks.len() && !is_p(&toks[j], "{") && !is_p(&toks[j], ";") {
                j += 1;
            }
            if j < toks.len() && is_p(&toks[j], "{") {
                out.push(Region {
                    name,
                    start: i,
                    end: match_brace(toks, j),
                });
            }
            i += 2;
            continue;
        }
        i += 1;
    }
    out
}

/// Bodies of `impl … Drop … for … { … }` blocks.
fn drop_impl_regions(toks: &[Token]) -> Vec<Region> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < toks.len() {
        if is_id(&toks[i], "impl") {
            let mut j = i + 1;
            let mut saw_drop = false;
            let mut saw_for = false;
            while j < toks.len() && !is_p(&toks[j], "{") && !is_p(&toks[j], ";") {
                saw_drop |= is_id(&toks[j], "Drop");
                saw_for |= is_id(&toks[j], "for");
                j += 1;
            }
            if saw_drop && saw_for && j < toks.len() && is_p(&toks[j], "{") {
                out.push(Region {
                    name: "Drop impl".into(),
                    start: i,
                    end: match_brace(toks, j),
                });
                i = j + 1;
                continue;
            }
        }
        i += 1;
    }
    out
}

/// The token range of `mod <name> { … }`, if present.
fn mod_region(toks: &[Token], name: &str) -> Option<(usize, usize)> {
    for i in 0..toks.len() {
        if is_id(&toks[i], "mod")
            && i + 2 < toks.len()
            && is_id(&toks[i + 1], name)
            && is_p(&toks[i + 2], "{")
        {
            return Some((i, match_brace(toks, i + 2)));
        }
    }
    None
}

// ---------------------------------------------------------------------------
// rules

/// Modules whose whole files are the sanctioned byte-layout home.
fn single_parser_exempt(rel: &str) -> bool {
    rel == "dist/wire.rs" || rel.starts_with("quant/")
}

fn rule_single_parser(rel: &str, toks: &[Token], out: &mut Vec<Finding>) {
    if single_parser_exempt(rel) {
        return;
    }
    let ser = if rel == "optim/mod.rs" {
        mod_region(toks, "ser")
    } else if rel == "dist/shm.rs" {
        // The shm control/go header codec is the one sanctioned raw
        // byte-layout island in the shm module; slot payloads themselves
        // go through wire.rs's f32 codec.
        mod_region(toks, "header")
    } else {
        None
    };
    for (i, t) in toks.iter().enumerate() {
        if !(is_id(t, "from_le_bytes") || is_id(t, "to_le_bytes")) {
            continue;
        }
        if let Some((s, e)) = ser {
            if i >= s && i < e {
                continue;
            }
        }
        out.push(Finding {
            file: rel.into(),
            line: t.line,
            rule: "single-parser",
            message: format!(
                "raw `{}` outside optim::ser / dist/wire.rs / quant/ — route byte layout through the hardened codec",
                t.text
            ),
        });
    }
}

/// Parser modules where the checked-alloc rule applies.
fn checked_alloc_scope(rel: &str) -> bool {
    rel == "dist/wire.rs"
        || rel == "dist/shm.rs"
        || rel.starts_with("quant/")
        || rel.starts_with("checkpoint/")
        || rel == "optim/mod.rs"
}

const PARSE_MARKERS: [&str; 4] = ["Reader", "from_le_bytes", "read_exact", "read_to_end"];
const ALLOC_GUARDS: [&str; 4] = ["remaining", "checked_mul", "checked_add", "take"];

fn rule_checked_alloc(rel: &str, toks: &[Token], out: &mut Vec<Finding>) {
    if !checked_alloc_scope(rel) {
        return;
    }
    // `mod tests` builds fixture buffers with `vec![…]` and parses bytes
    // it just wrote itself — untrusted-length hardening is a production
    // concern, so test regions are out of scope.
    let tests = mod_region(toks, "tests");
    for r in fn_regions(toks) {
        if let Some((s, e)) = tests {
            if r.start >= s && r.end <= e {
                continue;
            }
        }
        let body = &toks[r.start..r.end];
        let has = |names: &[&str]| body.iter().any(|t| t.is_ident && names.contains(&t.text.as_str()));
        if !has(&PARSE_MARKERS) || has(&ALLOC_GUARDS) {
            continue;
        }
        for (k, t) in body.iter().enumerate() {
            let vec_macro =
                is_id(t, "vec") && k + 1 < body.len() && is_p(&body[k + 1], "!");
            if is_id(t, "with_capacity") || vec_macro {
                out.push(Finding {
                    file: rel.into(),
                    line: t.line,
                    rule: "checked-alloc",
                    message: format!(
                        "allocation in parser fn `{}` with no visible `remaining`/`checked_mul`/`take` bound — a corrupt length field controls this size",
                        r.name
                    ),
                });
            }
        }
    }
}

/// dist/ functions that are serve loops, the relay, collective/transport
/// bodies, or synchronization primitives — the no-hang contract's scope.
const SERVE_FNS: [&str; 10] = [
    "serve",
    "serve_worker",
    "relay_loop",
    "handle_cmd",
    "run_worker",
    "exchange",
    "barrier",
    "wait",
    "wait_or_die",
    "poison",
];

const PANIC_MACROS: [&str; 4] = ["panic", "unreachable", "todo", "unimplemented"];

/// Idents that legitimately precede `[` in type position (`&mut [f32]`,
/// `Box<dyn Fn…>`); indexing through them is not expressible.
const PRE_BRACKET_KEYWORDS: [&str; 6] = ["mut", "ref", "dyn", "in", "as", "return"];

fn rule_no_panic_dist(rel: &str, toks: &[Token], out: &mut Vec<Finding>) {
    if !rel.starts_with("dist/") {
        return;
    }
    let mut regions: Vec<Region> = fn_regions(toks)
        .into_iter()
        .filter(|r| SERVE_FNS.contains(&r.name.as_str()))
        .collect();
    regions.extend(drop_impl_regions(toks));
    for r in &regions {
        for i in r.start..r.end {
            let t = &toks[i];
            if is_id(t, "unwrap") || is_id(t, "expect") {
                out.push(Finding {
                    file: rel.into(),
                    line: t.line,
                    rule: "no-panic-dist",
                    message: format!(
                        "`{}()` in dist no-panic region `{}` — record the death into FailureCell and return",
                        t.text, r.name
                    ),
                });
                continue;
            }
            if t.is_ident
                && PANIC_MACROS.contains(&t.text.as_str())
                && i + 1 < r.end
                && is_p(&toks[i + 1], "!")
            {
                out.push(Finding {
                    file: rel.into(),
                    line: t.line,
                    rule: "no-panic-dist",
                    message: format!(
                        "`{}!` in dist no-panic region `{}` — deaths must flow through FailureCell",
                        t.text, r.name
                    ),
                });
                continue;
            }
            if is_p(t, "[") && i > r.start {
                let p = &toks[i - 1];
                let indexes = (p.is_ident && !PRE_BRACKET_KEYWORDS.contains(&p.text.as_str()))
                    || is_p(p, ")")
                    || is_p(p, "]");
                if indexes {
                    out.push(Finding {
                        file: rel.into(),
                        line: t.line,
                        rule: "no-panic-dist",
                        message: format!(
                            "slice indexing in dist no-panic region `{}` — use `get()` or prove the bound with an allow",
                            r.name
                        ),
                    });
                }
            }
        }
    }
}

/// Serialization/collective modules where wall clocks and unordered
/// iteration would silently break bitwise parity.
fn determinism_scope(rel: &str) -> bool {
    rel.starts_with("dist/")
        || rel.starts_with("quant/")
        || rel.starts_with("checkpoint/")
        || rel.starts_with("optim/")
}

const NONDET_TYPES: [&str; 4] = ["HashMap", "HashSet", "Instant", "SystemTime"];

fn rule_determinism(rel: &str, toks: &[Token], out: &mut Vec<Finding>) {
    for (i, t) in toks.iter().enumerate() {
        // In `parallel/`, reading the environment at all is banned: the
        // thread-budget env var is resolved ONCE into a OnceLock (that
        // init site carries a justified allow); anything else would put a
        // `getenv` back on the kernel hot path, where it races any
        // concurrent env mutation (the UB class scrubbed from dist/).
        // Matched as the token tail `env :: var` (the lexer emits `::` as
        // two `:` punct tokens).
        if rel.starts_with("parallel/")
            && is_id(t, "var")
            && i >= 3
            && is_id(&toks[i - 3], "env")
            && is_p(&toks[i - 2], ":")
            && is_p(&toks[i - 1], ":")
        {
            out.push(Finding {
                file: rel.into(),
                line: t.line,
                rule: "determinism",
                message: "`env::var` in parallel/ — the hot path must not touch the environment; resolve once via the OnceLock in parallel::env_threads".into(),
            });
            continue;
        }
        if is_id(t, "set_var") {
            out.push(Finding {
                file: rel.into(),
                line: t.line,
                rule: "determinism",
                message: "`set_var` mutates process-global env (racy, and a hidden input to spawned workers) — thread configuration explicitly".into(),
            });
            continue;
        }
        if determinism_scope(rel) && t.is_ident && NONDET_TYPES.contains(&t.text.as_str()) {
            out.push(Finding {
                file: rel.into(),
                line: t.line,
                rule: "determinism",
                message: format!(
                    "`{}` in a serialization/collective module — unordered iteration / wall-clock time breaks bitwise parity",
                    t.text
                ),
            });
        }
    }
}

const LOCK_METHODS: [&str; 3] = ["lock", "read", "write"];
const COLLECTIVES: [&str; 6] = [
    "barrier",
    "all_reduce_sum",
    "reduce_scatter_sum",
    "all_gather",
    "broadcast",
    "exchange",
];

fn rule_lock_across_collective(rel: &str, toks: &[Token], out: &mut Vec<Finding>) {
    for r in fn_regions(toks) {
        let end = r.end;
        let mut i = r.start;
        while i < end {
            if !is_id(&toks[i], "let") {
                i += 1;
                continue;
            }
            let mut j = i + 1;
            if j < end && is_id(&toks[j], "mut") {
                j += 1;
            }
            // Simple binding only: `let [mut] name =` / `let [mut] name :`.
            // Destructuring (`let Some(g)`, `let (a, b)`) is skipped — the
            // zero-arg `.lock()`-family call below wouldn't bind a guard
            // name we could track through `drop(name)` anyway.
            if !(j + 1 < end && toks[j].is_ident && (is_p(&toks[j + 1], "=") || is_p(&toks[j + 1], ":")))
            {
                i += 1;
                continue;
            }
            let name = toks[j].text.clone();
            let bind_line = toks[j].line;
            // Statement end: `;` at bracket depth 0.
            let mut depth = 0i32;
            let mut k = j + 1;
            let mut stmt_end = end;
            while k < end {
                let t = &toks[k];
                if is_p(t, "(") || is_p(t, "[") || is_p(t, "{") {
                    depth += 1;
                } else if is_p(t, ")") || is_p(t, "]") || is_p(t, "}") {
                    depth -= 1;
                } else if is_p(t, ";") && depth == 0 {
                    stmt_end = k;
                    break;
                }
                k += 1;
            }
            // Guard acquisition: a zero-arg `.lock()`/`.read()`/`.write()`
            // call in the initializer (`read(&mut buf)` has arguments and
            // does not match).
            let acquires = (j..stmt_end).any(|m| {
                m + 2 < end
                    && toks[m].is_ident
                    && LOCK_METHODS.contains(&toks[m].text.as_str())
                    && is_p(&toks[m + 1], "(")
                    && is_p(&toks[m + 2], ")")
            });
            if !acquires {
                i = stmt_end + 1;
                continue;
            }
            // Guard is live from the end of the let-statement until
            // `drop(name)` or the end of the function.
            let mut m = stmt_end;
            while m < end {
                if is_id(&toks[m], "drop")
                    && m + 3 < end
                    && is_p(&toks[m + 1], "(")
                    && is_id(&toks[m + 2], &name)
                    && is_p(&toks[m + 3], ")")
                {
                    break;
                }
                if toks[m].is_ident
                    && COLLECTIVES.contains(&toks[m].text.as_str())
                    && m + 1 < end
                    && is_p(&toks[m + 1], "(")
                    && !(m > 0 && is_id(&toks[m - 1], "fn"))
                {
                    out.push(Finding {
                        file: rel.into(),
                        line: toks[m].line,
                        rule: "lock-across-collective",
                        message: format!(
                            "`{}` called while lock guard `{}` (bound line {}) is live — drop the guard first or a poisoned peer deadlocks the collective",
                            toks[m].text, name, bind_line
                        ),
                    });
                }
                m += 1;
            }
            i = stmt_end + 1;
        }
    }
}

// ---------------------------------------------------------------------------
// allow directives

struct Allow {
    rule: &'static str,
    /// Line the allow suppresses; `None` if it precedes no code.
    effective_line: Option<u32>,
}

/// Parse every `lint:` comment. Returns the well-formed allows and the
/// hygiene findings for malformed/unknown/empty-reason/dangling ones.
fn parse_allows(rel: &str, lexed: &Lexed) -> (Vec<Allow>, Vec<Finding>) {
    let code_lines: BTreeSet<u32> = lexed.tokens.iter().map(|t| t.line).collect();
    let mut allows = Vec::new();
    let mut findings = Vec::new();
    for c in &lexed.comments {
        let text = c.text.trim();
        let Some(rest) = text.strip_prefix("lint:") else {
            continue;
        };
        let bad = |msg: String| Finding {
            file: rel.into(),
            line: c.line,
            rule: ALLOW_HYGIENE,
            message: msg,
        };
        let rest = rest.trim_start();
        let Some(rest) = rest.strip_prefix("allow(") else {
            findings.push(bad(format!(
                "malformed lint directive `{text}` — expected `lint: allow(<rule>): <reason>`"
            )));
            continue;
        };
        let Some(close) = rest.find(')') else {
            findings.push(bad("unclosed `allow(` in lint directive".into()));
            continue;
        };
        let rule_name = rest[..close].trim();
        let Some(&rule) = RULES.iter().find(|r| **r == rule_name) else {
            findings.push(bad(format!(
                "unknown rule `{rule_name}` in lint allow (known: {})",
                RULES.join(", ")
            )));
            continue;
        };
        let after = rest[close + 1..].trim_start();
        let Some(reason) = after.strip_prefix(':') else {
            findings.push(bad(format!(
                "lint allow for `{rule_name}` missing `: <reason>`"
            )));
            continue;
        };
        if reason.trim().is_empty() {
            findings.push(bad(format!(
                "lint allow for `{rule_name}` has an empty reason — say why the invariant holds here"
            )));
            continue;
        }
        let effective_line = if code_lines.contains(&c.line) {
            Some(c.line)
        } else {
            code_lines.range(c.line + 1..).next().copied()
        };
        if effective_line.is_none() {
            findings.push(bad(format!(
                "lint allow for `{rule_name}` precedes no code — dead directive"
            )));
        }
        allows.push(Allow {
            rule,
            effective_line,
        });
    }
    (allows, findings)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_of(findings: &[Finding]) -> Vec<&'static str> {
        findings.iter().map(|f| f.rule).collect()
    }

    #[test]
    fn single_parser_fires_outside_sanctioned_modules() {
        let src = "fn f(b: [u8; 8]) -> u64 { u64::from_le_bytes(b) }";
        let f = check_file("runtime/mod.rs", src);
        assert_eq!(rules_of(&f), vec!["single-parser"]);
        assert!(check_file("dist/wire.rs", src).is_empty());
        assert!(check_file("quant/mod.rs", src).is_empty());
    }

    #[test]
    fn single_parser_respects_mod_ser_region() {
        let src = "mod ser { fn g(b: [u8; 8]) -> u64 { u64::from_le_bytes(b) } }\nfn h(x: u64) -> [u8; 8] { x.to_le_bytes() }";
        let f = check_file("optim/mod.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].line, 2);
    }

    #[test]
    fn single_parser_allows_only_the_shm_header_region() {
        // Inside `mod header`: sanctioned (the 33-byte ctrl/go codec).
        // The same token anywhere else in dist/shm.rs: a finding.
        let src = "mod header { fn g(b: [u8; 8]) -> u64 { u64::from_le_bytes(b) } }\nfn h(x: u64) -> [u8; 8] { x.to_le_bytes() }";
        let f = check_file("dist/shm.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].line, 2);
        assert_eq!(f[0].rule, "single-parser");
        // Other dist modules get no such region: both lines fire.
        let f = check_file("dist/comm.rs", src);
        assert_eq!(f.len(), 2, "{f:?}");
    }

    #[test]
    fn checked_alloc_covers_the_shm_module() {
        // An unbounded parse+alloc in dist/shm.rs must fire: the mapped
        // slot-table length has to be validated against the declared
        // geometry before allocating/reading.
        let bad = "fn open(r: &mut Reader) -> Vec<u8> { let n = r.u64_raw(); Vec::with_capacity(n as usize) }";
        let good = "fn open(r: &mut Reader) -> Vec<u8> { let n = (r.u64_raw() as usize).checked_mul(4).unwrap_or(0); Vec::with_capacity(n) }";
        assert_eq!(
            rules_of(&check_file("dist/shm.rs", bad)),
            vec!["checked-alloc"]
        );
        assert!(check_file("dist/shm.rs", good).is_empty());
    }

    #[test]
    fn checked_alloc_wants_a_visible_bound() {
        let bad = "fn parse(r: &mut Reader) -> Vec<u8> { let n = r.u64_raw(); Vec::with_capacity(n as usize) }";
        let good = "fn parse(r: &mut Reader) -> Vec<u8> { let n = r.u64_raw(); if n > r.remaining() { return Vec::new(); } Vec::with_capacity(n as usize) }";
        assert_eq!(rules_of(&check_file("checkpoint/mod.rs", bad)), vec!["checked-alloc"]);
        assert!(check_file("checkpoint/mod.rs", good).is_empty());
        // Out of scope: same code elsewhere is not a parser module. The
        // `Reader` marker alone triggers nothing outside the scope list.
        assert!(check_file("runtime/mod.rs", bad).is_empty());
    }

    #[test]
    fn no_panic_dist_bans_unwrap_panic_and_indexing() {
        let src = "fn serve(x: &[f32], i: usize) { let v = x[i]; maybe().unwrap(); panic!(\"boom {v}\"); }";
        let f = check_file("dist/comm.rs", src);
        assert_eq!(
            rules_of(&f),
            vec!["no-panic-dist", "no-panic-dist", "no-panic-dist"]
        );
        // Same body under a non-serve name: out of the no-hang scope.
        let free = "fn helper(x: &[f32], i: usize) { let v = x[i]; maybe().unwrap(); panic!(\"boom {v}\"); }";
        assert!(check_file("dist/comm.rs", free).is_empty());
        // Type-position brackets don't count as indexing.
        let ty = "fn serve(bufs: &mut [Vec<f32>]) -> Vec<f32> { bufs.concat() }";
        assert!(check_file("dist/comm.rs", ty).is_empty());
    }

    #[test]
    fn no_panic_dist_covers_drop_impls() {
        let src = "impl Drop for Cluster { fn drop(&mut self) { self.h.join().unwrap(); } }";
        let f = check_file("dist/cluster.rs", src);
        assert_eq!(rules_of(&f), vec!["no-panic-dist"]);
    }

    #[test]
    fn determinism_bans_clocks_maps_and_set_var() {
        let f = check_file("dist/process.rs", "fn t() { let t0 = Instant::now(); }");
        assert_eq!(rules_of(&f), vec!["determinism"]);
        // HashMap fine outside the serialization scope, set_var banned anywhere.
        assert!(check_file("runtime/mod.rs", "fn t(m: &HashMap<u32, u32>) {}").is_empty());
        let f = check_file("runtime/mod.rs", "fn t() { std::env::set_var(\"A\", \"1\"); }");
        assert_eq!(rules_of(&f), vec!["determinism"]);
    }

    #[test]
    fn determinism_bans_env_var_on_the_parallel_hot_path() {
        let hot = "fn t() -> Option<usize> { std::env::var(\"T\").ok()?.parse().ok() }";
        let f = check_file("parallel/mod.rs", hot);
        assert_eq!(rules_of(&f), vec!["determinism"]);
        // Same read elsewhere is out of this facet's scope…
        assert!(check_file("runtime/mod.rs", hot).is_empty());
        // …and the one-time OnceLock init is exactly what the allow is for.
        let init = "// lint: allow(determinism): resolved once into a OnceLock at first use\nfn t() -> Option<usize> { std::env::var(\"T\").ok()?.parse().ok() }";
        assert!(check_file("parallel/mod.rs", init).is_empty());
        // An unrelated local named `var` must not trip the token matcher.
        assert!(check_file("parallel/mod.rs", "fn t(var: usize) -> usize { var }").is_empty());
    }

    #[test]
    fn lock_guard_live_across_collective() {
        let bad = "fn step(&self) { let g = self.state.lock(); self.comm.barrier(); }";
        let f = check_file("optim/galore.rs", bad);
        assert_eq!(rules_of(&f), vec!["lock-across-collective"]);
        let dropped = "fn step(&self) { let g = self.state.lock(); drop(g); self.comm.barrier(); }";
        assert!(check_file("optim/galore.rs", dropped).is_empty());
        // `read(&mut buf)` takes an argument: io read, not a guard.
        let io = "fn step(&self) { let n = sock.read(&mut buf); self.comm.barrier(); }";
        assert!(check_file("optim/galore.rs", io).is_empty());
    }

    #[test]
    fn allow_suppresses_exactly_its_rule_and_line() {
        let src = "// lint: allow(single-parser): fixed 8-byte tag, length-checked by caller\nfn f(b: [u8; 8]) -> u64 { u64::from_le_bytes(b) }";
        assert!(check_file("runtime/mod.rs", src).is_empty());
        // Wrong rule name in the allow: original finding survives AND the
        // directive itself is flagged.
        let wrong = "// lint: allow(no-panic-dist): wrong rule\nfn f(b: [u8; 8]) -> u64 { u64::from_le_bytes(b) }";
        let f = check_file("runtime/mod.rs", wrong);
        assert_eq!(rules_of(&f), vec!["single-parser"]);
    }

    #[test]
    fn allow_hygiene_findings() {
        let empty = "// lint: allow(single-parser):\nfn f(b: [u8; 8]) -> u64 { u64::from_le_bytes(b) }";
        let f = check_file("runtime/mod.rs", empty);
        assert_eq!(rules_of(&f), vec![ALLOW_HYGIENE, "single-parser"]);
        let unknown = "// lint: allow(no-such-rule): reason\nfn g() {}";
        let f = check_file("runtime/mod.rs", unknown);
        assert_eq!(rules_of(&f), vec![ALLOW_HYGIENE]);
        let dangling = "fn g() {}\n// lint: allow(determinism): nothing follows";
        let f = check_file("runtime/mod.rs", dangling);
        assert_eq!(rules_of(&f), vec![ALLOW_HYGIENE]);
    }

    #[test]
    fn same_line_allow_works() {
        let src = "fn f(b: [u8; 8]) -> u64 { u64::from_le_bytes(b) } // lint: allow(single-parser): fixture tag decode";
        assert!(check_file("runtime/mod.rs", src).is_empty());
    }
}
