//! DDP mode: replicated data-parallel training (the Table 1 "DDP"
//! baseline), a first-class trainer mode.
//!
//! Every rank holds a FULL parameter replica and FULL optimizer state;
//! per step each rank computes gradients on its own microbatch, the
//! gradients are tree-all-reduced (then averaged), and each rank applies
//! the identical update. Because the reduction order is fixed and the
//! optimizers are seeded identically, replicas stay **bitwise equal** —
//! [`gather_params`](Cluster::gather_params) verifies this on every
//! gather.
//!
//! Contrast with [`super::FsdpCluster`]: DDP trades w× optimizer-state
//! replication for one all-reduce per layer; FSDP shards the state and
//! pays (reduce-)scatter/gather traffic instead.
//!
//! The worker protocol (channels, spawn loop, panic-aware Drop) is the
//! generic [`Cluster`] — this file defines only what a DDP rank stores
//! plus the replica-specific surface; [`run_ddp`] remains as the
//! closure-driven harness the dist tests use.

use super::cluster::{Cluster, MemoryReport, ParamMeta, StepTiming, StepTraffic, Worker};
use super::comm::{Collective, Comm};
use super::pipeline::{monotonic_ns, overlap_enabled, CommDriver};
use super::{BuildTarget, OptimizerSpec, WorkerOpt};
use crate::tensor::Matrix;

/// A world of persistent workers (threads or processes, per
/// [`super::TransportKind`]) with replicated state.
pub type DdpCluster = Cluster<DdpWorker>;

/// One DDP rank: a full replica + optimizer + comm handle.
pub struct DdpWorker {
    world: usize,
    rank: usize,
    comm: CommDriver,
    opt: WorkerOpt,
    params: Vec<Matrix>,
    peak_transient: usize,
    /// Timing of the most recent step (worker-blocked comm vs the rest),
    /// surfaced through `Worker::last_step_timing`.
    last_timing: StepTiming,
    /// Data-plane traffic of the most recent step (per-step deltas of the
    /// process-wide transport counters), surfaced through
    /// `Worker::last_step_traffic`.
    last_traffic: StepTraffic,
}

impl Worker for DdpWorker {
    const MODE: &'static str = "ddp";

    fn new(
        rank: usize,
        world: usize,
        comm: Comm,
        _metas: Vec<ParamMeta>,
        spec: OptimizerSpec,
        seed: u64,
    ) -> DdpWorker {
        // SAME seed on every rank (unlike FSDP's per-rank hygiene XOR):
        // GaLore's local SVD refreshes draw identical streams, keeping the
        // replicas in lockstep — and making DDP(world=1) bitwise equal to
        // Single mode.
        let opt = spec
            .build(
                seed,
                BuildTarget::Worker {
                    external_subspace: false,
                },
            )
            .expect("spec validated in Cluster::new");
        DdpWorker {
            world,
            rank,
            comm: CommDriver::new(comm, overlap_enabled()),
            opt,
            params: Vec::new(),
            peak_transient: 0,
            last_timing: StepTiming::default(),
            last_traffic: StepTraffic::default(),
        }
    }

    fn install(&mut self, full: Vec<Matrix>) {
        self.params = full;
    }

    fn step(&mut self, t: u64, lr: f32, grads: Vec<Matrix>) {
        assert_eq!(grads.len(), self.params.len(), "init_params before step");
        let wall0 = monotonic_ns();
        let (sock0, shm0) = super::process::wire_traffic();
        self.opt.as_opt().begin_step(t);
        let scale = 1.0 / self.world as f32;
        // Issue-ahead + consume-in-order: layer idx+1's all-reduce is in
        // flight while layer idx's averaged gradient feeds `step_param`
        // (`dist/pipeline.rs`; fixed-tree order within each layer is
        // untouched, so the overlap is bitwise invisible). The in-flight
        // layer's buffer is charged to `peak_transient` identically in
        // serial and overlapped mode.
        let sizes: Vec<usize> = grads.iter().map(|g| g.data.len()).collect();
        let mut grads = grads.into_iter();
        if let Some(g) = grads.next() {
            self.comm.issue(Collective::AllReduceSum(g.data));
        }
        for idx in 0..sizes.len() {
            let extra = if idx + 1 < sizes.len() {
                if let Some(g) = grads.next() {
                    self.comm.issue(Collective::AllReduceSum(g.data));
                }
                sizes[idx + 1] * 4
            } else {
                0
            };
            let (r, c) = self.params[idx].shape();
            // Per-layer fused update: the reduced gradient is consumed and
            // dropped before the NEXT-next layer's all-reduce (Fig. 2, with
            // one layer of lookahead).
            self.peak_transient = self.peak_transient.max(2 * sizes[idx] * 4 + extra);
            let mut avg = self.comm.wait();
            for x in avg.iter_mut() {
                *x *= scale;
            }
            let avg = Matrix::from_vec(r, c, avg);
            self.opt.as_opt().step_param(idx, &mut self.params[idx], &avg, lr);
        }
        let comm_ns = self.comm.take_comm_ns();
        let wall = monotonic_ns() - wall0;
        self.last_timing = StepTiming {
            comm_ns,
            compute_ns: wall.saturating_sub(comm_ns),
        };
        let (sock, shm) = super::process::wire_traffic();
        self.last_traffic = StepTraffic {
            socket_bytes: sock - sock0,
            shm_bytes: shm - shm0,
            peak_transient_bytes: (self.peak_transient + super::process::shm_inflight_bytes())
                as u64,
        };
    }

    fn params(&self) -> Vec<Matrix> {
        self.params.clone()
    }

    /// DDP frame: the optimizer blob alone (replicated state carries no
    /// per-rank SVD stream — each rank's optimizer owns its own RNG).
    fn export_state(&self) -> Vec<u8> {
        self.opt.export_state()
    }

    fn import_state(&mut self, bytes: &[u8]) -> Result<(), String> {
        self.opt.as_opt().import_state(bytes)
    }

    fn report(&self) -> MemoryReport {
        let (socket_bytes, shm_bytes) = super::process::wire_traffic();
        MemoryReport {
            rank: self.rank,
            // Full replica — the w× redundancy Table 1 charges DDP for.
            param_shard_bytes: self.params.iter().map(|p| p.numel() * 4).sum(),
            optimizer_bytes: self.opt.state_bytes(),
            // Charge the in-flight shm generation like the pipeline's
            // extra gradient buffer.
            peak_transient_bytes: self.peak_transient + super::process::shm_inflight_bytes(),
            traffic_elems: self.comm.traffic_elems(),
            socket_bytes,
            shm_bytes,
        }
    }

    fn last_step_timing(&self) -> StepTiming {
        self.last_timing
    }

    fn last_step_traffic(&self) -> StepTraffic {
        self.last_traffic
    }
}

impl Cluster<DdpWorker> {
    /// Rank 0's replica WITHOUT the cross-rank equality sweep — the cheap
    /// per-step read (replicas are identical by construction; use
    /// [`gather_params`](Cluster::gather_params) where divergence should
    /// be caught).
    pub fn rank0_params(&self) -> Vec<Matrix> {
        self.rank_params(0)
    }

    /// [`rank0_params`](Cluster::rank0_params) with worker death caught
    /// and attributed, for the recovery supervisor.
    pub fn try_rank0_params(&mut self) -> Result<Vec<Matrix>, super::WorkerLoss> {
        self.try_rank_params(0)
    }

    /// Rank 0's replica — after asserting every rank's replica is bitwise
    /// identical. A divergence means a non-deterministic reduction or
    /// optimizer, which would silently corrupt any real DDP run.
    pub fn gather_params(&self) -> Vec<Matrix> {
        let mut per_rank = self.params_per_rank();
        for r in 1..per_rank.len() {
            for (idx, (a, b)) in per_rank[0].iter().zip(&per_rank[r]).enumerate() {
                assert_eq!(
                    a.data, b.data,
                    "DDP replicas diverged on param {idx} (rank 0 vs {r})"
                );
            }
        }
        per_rank.swap_remove(0)
    }

    /// Serialized optimizer state (replicas are identical, so rank 0's
    /// blob represents every rank; same format as single-process state).
    pub fn export_optimizer(&self) -> Vec<u8> {
        self.export_rank_frame(0)
    }

    /// Restore optimizer state on every rank from one blob (replicated
    /// state ⇒ the same bytes restore every replica).
    pub fn import_optimizer(&self, bytes: &[u8]) -> Result<(), String> {
        self.import_frames(vec![bytes.to_vec(); self.world()])
    }
}

/// Run `steps` of synchronous data-parallel training over a fresh
/// [`DdpCluster`] (the closure-driven test harness; real training goes
/// through `train::DdpEngine`).
///
/// `grad_fn(rank, step, params)` returns rank-local microbatch gradients in
/// parameter order (full shapes); it runs on the coordinator thread — the
/// workers do the reductions and updates. Every step gathers through the
/// replica-equality assertion. Returns the final parameters (identical on
/// every rank; verified) and per-rank memory/traffic reports.
pub fn run_ddp<F>(
    world: usize,
    init: &[Matrix],
    spec: &OptimizerSpec,
    seed: u64,
    steps: u64,
    lr: f32,
    grad_fn: F,
) -> (Vec<Matrix>, Vec<MemoryReport>)
where
    F: Fn(usize, u64, &[Matrix]) -> Vec<Matrix> + Sync,
{
    let metas: Vec<ParamMeta> = init
        .iter()
        .enumerate()
        .map(|(i, p)| ParamMeta {
            name: format!("p{i}"),
            rows: p.rows,
            cols: p.cols,
        })
        .collect();
    let mut cluster = DdpCluster::new(world, metas, spec.clone(), seed);
    cluster.init_params(init);
    let mut params = init.to_vec();
    for t in 0..steps {
        let per_rank: Vec<Vec<Matrix>> = (0..world).map(|r| grad_fn(r, t, &params)).collect();
        cluster.step(t, per_rank, lr);
        params = cluster.gather_params();
    }
    let reports = cluster.memory_reports();
    (params, reports)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{AdamCfg, GaLoreCfg};
    use crate::util::rng::Pcg64;

    fn target_and_init(m: usize, n: usize) -> (Matrix, Vec<Matrix>) {
        let mut rng = Pcg64::new(5, 0);
        (Matrix::randn(m, n, 1.0, &mut rng), vec![Matrix::zeros(m, n)])
    }

    #[test]
    fn ddp_adamw_converges_and_replicas_agree() {
        let (target, init) = target_and_init(10, 14);
        let (params, reports) = run_ddp(
            4,
            &init,
            &OptimizerSpec::AdamW(AdamCfg::default()),
            3,
            300,
            0.05,
            |rank, t, params| {
                // Quadratic with per-rank microbatch noise.
                let mut g = params[0].sub(&target);
                let noise = Matrix::randn(10, 14, 0.02, &mut Pcg64::new(t, rank as u64));
                g.add_assign(&noise);
                vec![g]
            },
        );
        let rel = params[0].sub(&target).frobenius_norm() / target.frobenius_norm();
        assert!(rel < 0.05, "DDP AdamW did not converge: rel {rel}");
        assert_eq!(reports.len(), 4);
        // Replicated state: every rank holds the FULL optimizer moments.
        for r in &reports {
            assert_eq!(r.optimizer_bytes, 2 * 10 * 14 * 4);
            assert!(r.traffic_elems > 0);
        }
    }

    #[test]
    fn ddp_galore_stays_in_lockstep() {
        // GaLore's randomized refresh is the dangerous part: identical
        // seeding must keep replica SVDs identical (gather_params asserts
        // replica equality after every step).
        let (target, init) = target_and_init(12, 20);
        let spec = OptimizerSpec::GaLore {
            galore: GaLoreCfg {
                rank: 4,
                update_freq: 10,
                alpha: 1.0,
                ..GaLoreCfg::default()
            },
            adam: AdamCfg::default(),
        };
        let (params, _) = run_ddp(3, &init, &spec, 9, 60, 0.05, |rank, t, params| {
            let mut g = params[0].sub(&target);
            let noise = Matrix::randn(12, 20, 0.01, &mut Pcg64::new(t, rank as u64));
            g.add_assign(&noise);
            vec![g]
        });
        assert!(params[0].max_abs() > 0.0, "no update applied");
    }

    #[test]
    fn ddp_world1_equals_serial_training() {
        let (target, init) = target_and_init(8, 8);
        let grad = |_: usize, _: u64, params: &[Matrix]| vec![params[0].sub(&target)];
        let (ddp, _) = run_ddp(
            1,
            &init,
            &OptimizerSpec::AdamW(AdamCfg::default()),
            1,
            20,
            0.1,
            grad,
        );
        // Serial reference.
        let mut params = init.clone();
        let mut opt = crate::optim::AdamW::new(AdamCfg::default());
        for t in 0..20 {
            let g = params[0].sub(&target);
            crate::optim::step_all(&mut opt, t, &mut params, &[g], 0.1);
        }
        assert_eq!(ddp[0].data, params[0].data, "world-1 DDP != serial");
    }

    #[test]
    fn ddp_optimizer_state_roundtrips() {
        // Export after a step, restore into a fresh cluster, evolve both:
        // trajectories must stay bitwise identical.
        let (target, init) = target_and_init(6, 9);
        let grads = |params: &[Matrix]| vec![vec![params[0].sub(&target)]; 2];
        let mut a = DdpCluster::new(
            2,
            vec![ParamMeta {
                name: "p0".into(),
                rows: 6,
                cols: 9,
            }],
            OptimizerSpec::AdamW(AdamCfg::default()),
            7,
        );
        a.init_params(&init);
        let mut pa = init.clone();
        a.step(0, grads(&pa), 0.05);
        pa = a.gather_params();
        let blob = a.export_optimizer();
        let mut b = DdpCluster::new(
            2,
            vec![ParamMeta {
                name: "p0".into(),
                rows: 6,
                cols: 9,
            }],
            OptimizerSpec::AdamW(AdamCfg::default()),
            99,
        );
        b.init_params(&pa);
        b.import_optimizer(&blob).unwrap();
        a.step(1, grads(&pa), 0.05);
        b.step(1, grads(&pa), 0.05);
        let fa = a.gather_params();
        let fb = b.gather_params();
        assert_eq!(fa[0].data, fb[0].data, "restored DDP cluster diverged");
    }
}
