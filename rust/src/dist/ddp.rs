//! Replicated data-parallel training (the Table 1 "DDP" baseline).
//!
//! Every rank holds a FULL parameter replica and FULL optimizer state;
//! per step each rank computes gradients on its own microbatch, the
//! gradients are tree-all-reduced (then averaged), and each rank applies
//! the identical update. Because the reduction order is fixed and the
//! optimizers are seeded identically, replicas stay **bitwise equal** —
//! which [`run_ddp`] verifies before returning.
//!
//! Contrast with [`super::FsdpCluster`]: DDP trades w× optimizer-state
//! replication for one all-reduce per layer; FSDP shards the state and
//! pays (reduce-)scatter/gather traffic instead.

use super::comm::Comm;
use super::{MemoryReport, OptimizerSpec};
use crate::tensor::Matrix;

/// Run `steps` of synchronous data-parallel training.
///
/// `grad_fn(rank, step, params)` returns rank-local microbatch gradients in
/// parameter order (full shapes). Returns the final parameters (identical
/// on every rank; rank 0's copy) and per-rank memory/traffic reports.
pub fn run_ddp<F>(
    world: usize,
    init: &[Matrix],
    spec: &OptimizerSpec,
    seed: u64,
    steps: u64,
    lr: f32,
    grad_fn: F,
) -> (Vec<Matrix>, Vec<MemoryReport>)
where
    F: Fn(usize, u64, &[Matrix]) -> Vec<Matrix> + Sync,
{
    assert!(world >= 1);
    let comms = Comm::create_world(world);
    let grad_fn = &grad_fn;
    let mut results: Vec<(Vec<Matrix>, MemoryReport)> = std::thread::scope(|s| {
        let handles: Vec<_> = comms
            .into_iter()
            .map(|comm| {
                s.spawn(move || {
                    let rank = comm.rank();
                    crate::parallel::set_thread_share(world);
                    let mut params: Vec<Matrix> = init.to_vec();
                    // Same seed on every rank: GaLore's local SVD refreshes
                    // draw identical streams, keeping replicas in lockstep.
                    let mut opt = spec.build(seed, false);
                    let scale = 1.0 / world as f32;
                    let mut peak_transient = 0usize;
                    for t in 0..steps {
                        let grads = grad_fn(rank, t, &params);
                        assert_eq!(grads.len(), params.len());
                        opt.as_opt().begin_step(t);
                        for (idx, g) in grads.into_iter().enumerate() {
                            let (r, c) = params[idx].shape();
                            assert_eq!(g.shape(), (r, c), "grad {idx} shape");
                            peak_transient = peak_transient.max(2 * g.data.len() * 4);
                            let mut avg = comm.all_reduce_sum(g.data);
                            for x in avg.iter_mut() {
                                *x *= scale;
                            }
                            let g = Matrix::from_vec(r, c, avg);
                            // Per-layer fused update: the reduced gradient
                            // is consumed and dropped before the next layer.
                            opt.as_opt().step_param(idx, &mut params[idx], &g, lr);
                        }
                    }
                    let report = MemoryReport {
                        rank,
                        param_shard_bytes: params.iter().map(|p| p.numel() * 4).sum(),
                        optimizer_bytes: opt.state_bytes(),
                        peak_transient_bytes: peak_transient,
                        traffic_elems: comm.traffic_elems(),
                    };
                    (params, report)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    // Replicas must have stayed bitwise identical — a divergence here means
    // a non-deterministic reduction or optimizer, which would silently
    // corrupt any real DDP run.
    for r in 1..results.len() {
        for (idx, (a, b)) in results[0].0.iter().zip(&results[r].0).enumerate() {
            assert_eq!(
                a.data, b.data,
                "DDP replicas diverged on param {idx} (rank 0 vs {r})"
            );
        }
    }
    let reports: Vec<MemoryReport> = results.iter().map(|r| r.1).collect();
    let params = results.remove(0).0;
    (params, reports)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{AdamCfg, GaLoreCfg};
    use crate::util::rng::Pcg64;

    fn target_and_init(m: usize, n: usize) -> (Matrix, Vec<Matrix>) {
        let mut rng = Pcg64::new(5, 0);
        (Matrix::randn(m, n, 1.0, &mut rng), vec![Matrix::zeros(m, n)])
    }

    #[test]
    fn ddp_adamw_converges_and_replicas_agree() {
        let (target, init) = target_and_init(10, 14);
        let (params, reports) = run_ddp(
            4,
            &init,
            &OptimizerSpec::AdamW(AdamCfg::default()),
            3,
            300,
            0.05,
            |rank, t, params| {
                // Quadratic with per-rank microbatch noise.
                let mut g = params[0].sub(&target);
                let noise = Matrix::randn(10, 14, 0.02, &mut Pcg64::new(t, rank as u64));
                g.add_assign(&noise);
                vec![g]
            },
        );
        let rel = params[0].sub(&target).frobenius_norm() / target.frobenius_norm();
        assert!(rel < 0.05, "DDP AdamW did not converge: rel {rel}");
        assert_eq!(reports.len(), 4);
        // Replicated state: every rank holds the FULL optimizer moments.
        for r in &reports {
            assert_eq!(r.optimizer_bytes, 2 * 10 * 14 * 4);
            assert!(r.traffic_elems > 0);
        }
    }

    #[test]
    fn ddp_galore_stays_in_lockstep() {
        // GaLore's randomized refresh is the dangerous part: identical
        // seeding must keep replica SVDs identical (run_ddp asserts
        // replica equality internally before returning).
        let (target, init) = target_and_init(12, 20);
        let spec = OptimizerSpec::GaLore {
            galore: GaLoreCfg {
                rank: 4,
                update_freq: 10,
                alpha: 1.0,
                ..GaLoreCfg::default()
            },
            adam: AdamCfg::default(),
        };
        let (params, _) = run_ddp(3, &init, &spec, 9, 60, 0.05, |rank, t, params| {
            let mut g = params[0].sub(&target);
            let noise = Matrix::randn(12, 20, 0.01, &mut Pcg64::new(t, rank as u64));
            g.add_assign(&noise);
            vec![g]
        });
        assert!(params[0].max_abs() > 0.0, "no update applied");
    }

    #[test]
    fn ddp_world1_equals_serial_training() {
        let (target, init) = target_and_init(8, 8);
        let grad = |_: usize, _: u64, params: &[Matrix]| vec![params[0].sub(&target)];
        let (ddp, _) = run_ddp(
            1,
            &init,
            &OptimizerSpec::AdamW(AdamCfg::default()),
            1,
            20,
            0.1,
            grad,
        );
        // Serial reference.
        let mut params = init.clone();
        let mut opt = crate::optim::AdamW::new(AdamCfg::default());
        for t in 0..20 {
            let g = params[0].sub(&target);
            crate::optim::step_all(&mut opt, t, &mut params, &[g], 0.1);
        }
        assert_eq!(ddp[0].data, params[0].data, "world-1 DDP != serial");
    }
}
