//! Threaded FSDP/DDP distributed runtime (§4.3 of the paper).
//!
//! GaLore 2's integration with parallel training maps onto three pieces:
//!
//! * [`Comm`] — in-process collectives (all-reduce / reduce-scatter /
//!   all-gather / broadcast) with fixed-tree reductions, so results are
//!   bitwise identical regardless of thread scheduling, plus per-rank
//!   byte-traffic accounting for the Table 1 reproduction.
//! * [`Cluster`]`<W: `[`Worker`]`>` — the generic worker-protocol runtime:
//!   persistent threads behind channels, shared Cmd/Reply protocol,
//!   coordinator-side validation, panic-aware barrier-safe shutdown, and
//!   per-worker core-budget splitting. Protocol fixes land once and apply
//!   to every mode.
//! * The two instantiations: [`FsdpCluster`] (= `Cluster<FsdpWorker>`) —
//!   each rank owns parameter / gradient / optimizer-state *shards*, with
//!   the per-layer fused update of Fig. 2 and leader-computed subspaces —
//!   and [`DdpCluster`] (= `Cluster<DdpWorker>`) — the replicated-state
//!   baseline Table 1 compares against ([`run_ddp`] remains as the
//!   closure-driven harness the tests use).
//!
//! Worker threads construct their optimizers from
//! [`crate::optim::OptimizerSpec`] (re-exported here), the `Send`-able
//! recipe that is the codebase's single optimizer-construction path.
//!
//! Checkpointing: `Cluster::export_frames` captures each rank's raw state
//! frame; `checkpoint::canonical` gathers those into the world-agnostic
//! canonical form (and re-slices it for any target world on resume).

mod cluster;
mod comm;
mod ddp;
mod fsdp;

pub use cluster::{Cluster, MemoryReport, ParamMeta, Worker};
pub use comm::Comm;
pub use ddp::{run_ddp, DdpCluster, DdpWorker};
pub use fsdp::{FsdpCluster, FsdpWorker};

pub(crate) use cluster::{shard_axis, shard_bounds, ShardAxis};

pub use crate::optim::spec::{BuildTarget, OptimizerSpec, PjrtResources, WorkerOpt};
