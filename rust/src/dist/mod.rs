//! Threaded FSDP/DDP distributed runtime (§4.3 of the paper).
//!
//! GaLore 2's integration with parallel training maps onto three pieces:
//!
//! * [`Comm`] — in-process collectives (all-reduce / reduce-scatter /
//!   all-gather / broadcast) with fixed-tree reductions, so results are
//!   bitwise identical regardless of thread scheduling, plus per-rank
//!   byte-traffic accounting for the Table 1 reproduction.
//! * [`FsdpCluster`] — one OS thread per worker ("GPU"), each owning its
//!   parameter / gradient / optimizer-state *shards*. Per layer, gradients
//!   are reduced and the optimizer steps immediately so the full-size
//!   gradient buffer can be dropped (the per-layer fused update of Fig. 2).
//!   In GaLore mode the leader computes the randomized SVD on the gathered
//!   full gradient and broadcasts P (`GaLoreCfg::external_subspace`).
//! * [`DdpCluster`] — the replicated-state data-parallel baseline Table 1
//!   compares against, now a first-class trainer mode (`--parallel ddp`);
//!   [`run_ddp`] remains as the closure-driven harness the tests use.
//!
//! Worker threads construct their optimizers from
//! [`crate::optim::OptimizerSpec`] (re-exported here), the `Send`-able
//! recipe that is the codebase's single optimizer-construction path.

mod cluster;
mod comm;
mod ddp;

pub use cluster::{FsdpCluster, MemoryReport, ParamMeta};
pub use comm::Comm;
pub use ddp::{run_ddp, DdpCluster};

pub use crate::optim::spec::{BuildTarget, OptimizerSpec, PjrtResources, WorkerOpt};
