//! Threaded FSDP/DDP distributed runtime (§4.3 of the paper).
//!
//! GaLore 2's integration with parallel training maps onto three pieces:
//!
//! * [`Comm`] — in-process collectives (all-reduce / reduce-scatter /
//!   all-gather / broadcast) with fixed-tree reductions, so results are
//!   bitwise identical regardless of thread scheduling, plus per-rank
//!   byte-traffic accounting for the Table 1 reproduction.
//! * [`FsdpCluster`] — one OS thread per worker ("GPU"), each owning its
//!   parameter / gradient / optimizer-state *shards*. Per layer, gradients
//!   are reduced and the optimizer steps immediately so the full-size
//!   gradient buffer can be dropped (the per-layer fused update of Fig. 2).
//!   In GaLore mode the leader computes the randomized SVD on the gathered
//!   full gradient and broadcasts P (`GaLoreCfg::external_subspace`).
//! * [`run_ddp`] — the replicated-state data-parallel baseline Table 1
//!   compares against.
//!
//! [`OptimizerSpec`] is the Send-able recipe from which each worker thread
//! constructs its own (deliberately non-`Send`) optimizer instance.

mod cluster;
mod comm;
mod ddp;

pub use cluster::{FsdpCluster, MemoryReport, ParamMeta};
pub use comm::Comm;
pub use ddp::run_ddp;

use crate::optim::{
    Adafactor, Adam8bit, AdamCfg, AdamW, GaLore, GaLoreCfg, Optimizer, ProjectionKind, SgdM,
};

/// Recipe for a worker-local optimizer (constructed *inside* each worker
/// thread — the `Optimizer` trait is intentionally not `Send`).
#[derive(Clone, Debug)]
pub enum OptimizerSpec {
    AdamW(AdamCfg),
    Adam8bit(AdamCfg),
    Adafactor { eps: f32 },
    SgdM { momentum: f32 },
    GaLore { galore: GaLoreCfg, adam: AdamCfg },
}

impl OptimizerSpec {
    pub fn name(&self) -> &'static str {
        match self {
            OptimizerSpec::AdamW(_) => "adamw",
            OptimizerSpec::Adam8bit(_) => "adam8bit",
            OptimizerSpec::Adafactor { .. } => "adafactor",
            OptimizerSpec::SgdM { .. } => "sgdm",
            // A quantized projector is the Q-GaLore configuration — keep
            // the distinction visible in logs and Table 1 rows.
            OptimizerSpec::GaLore { galore, .. } => match galore.projection {
                ProjectionKind::Quant8 | ProjectionKind::Quant4 => "qgalore",
                _ => "galore",
            },
        }
    }

    /// The GaLore config, if this spec is a GaLore variant.
    pub fn galore_cfg(&self) -> Option<GaLoreCfg> {
        match self {
            OptimizerSpec::GaLore { galore, .. } => Some(*galore),
            _ => None,
        }
    }

    /// Build the worker-local optimizer. `external_subspace` selects the
    /// FSDP contract (the engine owns subspace refreshes and installs P via
    /// [`GaLore::preset_projector`]); DDP workers refresh locally instead,
    /// seeded identically so replicas stay in lockstep.
    pub(crate) fn build(&self, seed: u64, external_subspace: bool) -> WorkerOpt {
        match self {
            OptimizerSpec::AdamW(cfg) => WorkerOpt::Boxed(Box::new(AdamW::new(*cfg))),
            OptimizerSpec::Adam8bit(cfg) => WorkerOpt::Boxed(Box::new(Adam8bit::new(*cfg))),
            OptimizerSpec::Adafactor { eps } => {
                WorkerOpt::Boxed(Box::new(Adafactor::new(*eps)))
            }
            OptimizerSpec::SgdM { momentum } => {
                WorkerOpt::Boxed(Box::new(SgdM::new(*momentum)))
            }
            OptimizerSpec::GaLore { galore, adam } => {
                let mut g = *galore;
                g.external_subspace = external_subspace;
                WorkerOpt::GaLore(GaLore::new(g, *adam, seed))
            }
        }
    }
}

/// Worker-local optimizer: GaLore is held concretely so the engine can
/// drive its external subspace; everything else is a trait object.
pub(crate) enum WorkerOpt {
    GaLore(GaLore),
    Boxed(Box<dyn Optimizer>),
}

impl WorkerOpt {
    pub(crate) fn as_opt(&mut self) -> &mut dyn Optimizer {
        match self {
            WorkerOpt::GaLore(g) => g,
            WorkerOpt::Boxed(b) => b.as_mut(),
        }
    }

    pub(crate) fn state_bytes(&self) -> usize {
        match self {
            WorkerOpt::GaLore(g) => g.state_bytes(),
            WorkerOpt::Boxed(b) => b.state_bytes(),
        }
    }

    pub(crate) fn export_state(&self) -> Vec<u8> {
        match self {
            WorkerOpt::GaLore(g) => g.export_state(),
            WorkerOpt::Boxed(b) => b.export_state(),
        }
    }

    pub(crate) fn galore_mut(&mut self) -> Option<&mut GaLore> {
        match self {
            WorkerOpt::GaLore(g) => Some(g),
            _ => None,
        }
    }

    pub(crate) fn has_projector(&self, idx: usize) -> bool {
        match self {
            WorkerOpt::GaLore(g) => g.has_projector(idx),
            _ => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_names_match_config_strings() {
        let specs = [
            OptimizerSpec::AdamW(AdamCfg::default()),
            OptimizerSpec::Adam8bit(AdamCfg::default()),
            OptimizerSpec::Adafactor { eps: 1e-30 },
            OptimizerSpec::SgdM { momentum: 0.9 },
            OptimizerSpec::GaLore {
                galore: GaLoreCfg::default(),
                adam: AdamCfg::default(),
            },
        ];
        let names: Vec<&str> = specs.iter().map(|s| s.name()).collect();
        assert_eq!(names, ["adamw", "adam8bit", "adafactor", "sgdm", "galore"]);
        // Quantized projector ⇒ the spec self-identifies as Q-GaLore.
        let q = OptimizerSpec::GaLore {
            galore: GaLoreCfg {
                projection: ProjectionKind::Quant8,
                ..GaLoreCfg::default()
            },
            adam: AdamCfg::default(),
        };
        assert_eq!(q.name(), "qgalore");
    }

    #[test]
    fn build_honours_external_subspace_flag() {
        let spec = OptimizerSpec::GaLore {
            galore: GaLoreCfg::default(),
            adam: AdamCfg::default(),
        };
        let mut fsdp = spec.build(1, true);
        let g = fsdp.galore_mut().expect("galore spec builds galore");
        assert!(g.cfg.external_subspace);
        let mut ddp = spec.build(1, false);
        assert!(!ddp.galore_mut().unwrap().cfg.external_subspace);
    }

    #[test]
    fn projection_predicate_matches_shapes() {
        // The coordinator and the optimizer share GaLoreCfg::projects, so
        // the FSDP install decision can never drift from step_param's.
        let cfg = GaLoreCfg {
            rank: 16,
            min_dim: 2,
            ..GaLoreCfg::default()
        };
        assert!(cfg.projects(64, 128));
        assert!(cfg.projects(16, 128)); // rank == min dim
        assert!(!cfg.projects(8, 128)); // rank > min dim
        assert!(!cfg.projects(1, 128)); // bias-like
    }
}
