//! FSDP/DDP distributed runtime (§4.3 of the paper), over selectable
//! transports.
//!
//! GaLore 2's integration with parallel training maps onto four pieces:
//!
//! * [`Comm`] — collectives (all-reduce / reduce-scatter / all-gather /
//!   broadcast) with fixed-tree reductions, generic over a [`Transport`]:
//!   results are bitwise identical regardless of scheduling *and* of the
//!   fabric that moved the bytes, plus per-rank byte-traffic accounting
//!   for the Table 1 reproduction.
//! * [`Transport`] implementations: [`ThreadTransport`] (in-process shared
//!   slots + barrier — the default) and the Unix-socket process transport
//!   (`dist/process.rs`, workers self-exec'd as `galore2 worker`),
//!   selected per cluster via [`TransportKind`] (`[dist] transport` /
//!   `--transport threads|process`).
//! * [`Cluster`]`<W: `[`Worker`]`>` — the generic worker-protocol runtime:
//!   persistent workers behind one framed Cmd/Reply protocol,
//!   coordinator-side validation, panic/exit-aware shutdown for both
//!   worker kinds, and per-worker core-budget splitting. Protocol fixes
//!   land once and apply to every mode and transport.
//! * The two instantiations: [`FsdpCluster`] (= `Cluster<FsdpWorker>`) —
//!   each rank owns parameter / gradient / optimizer-state *shards*, with
//!   the per-layer fused update of Fig. 2 and leader-computed subspaces —
//!   and [`DdpCluster`] (= `Cluster<DdpWorker>`) — the replicated-state
//!   baseline Table 1 compares against ([`run_ddp`] remains as the
//!   closure-driven harness the tests use).
//!
//! Worker threads/processes construct their optimizers from
//! [`crate::optim::OptimizerSpec`] (re-exported here), the `Send`-able
//! recipe that is the codebase's single optimizer-construction path; the
//! process transport ships it over the wire (`dist/wire.rs`).
//!
//! Checkpointing: `Cluster::export_frames` captures each rank's raw state
//! frame; `checkpoint::canonical` gathers those into the world-agnostic
//! canonical form (and re-slices it for any target world on resume) —
//! transport-independent by construction.

pub(crate) mod cluster;
mod comm;
mod ddp;
mod fsdp;
mod pipeline;
mod process;
mod shm;
pub(crate) mod wire;

pub use cluster::{
    Cluster, MemoryReport, ParamMeta, StepTiming, StepTraffic, TransportKind, Worker, WorkerLoss,
};
pub use comm::{Comm, ThreadTransport, Transport};
pub use ddp::{run_ddp, DdpCluster, DdpWorker};
pub use fsdp::{FsdpCluster, FsdpWorker};
pub use pipeline::set_overlap_enabled;
pub use process::{
    run_worker, set_shm_enabled, set_spawn_retries, set_test_crash_hooks, set_test_shm_fail,
    set_worker_binary, WORKER_BIN_ENV,
};

pub(crate) use cluster::{shard_axis, shard_bounds, ShardAxis};

pub use crate::optim::spec::{BuildTarget, OptimizerSpec, PjrtResources, WorkerOpt};
