//! The threaded FSDP cluster: persistent worker threads owning shards.
//!
//! Topology: the coordinator (caller) holds one command channel per worker
//! and drives lockstep steps; workers rendezvous with each other through
//! [`Comm`] collectives. Every parameter is sharded along its *longer*
//! dimension — which is exactly the dimension the GaLore projector does
//! NOT span, so a leader-computed P applies unchanged to every shard:
//!
//!   wide  W (m ≤ n): P is m×r (left), shard columns → R = Pᵀ·G_shard
//!   tall  W (m > n): P is n×r (right), shard rows   → R = G_shard·P
//!
//! Per-layer fused update (Fig. 2): each layer's gradient is reduced and
//! consumed immediately, so at most one full-size gradient buffer is live
//! per worker at a time (tracked in `peak_transient_bytes`).
//!
//! Subspace refreshes (§4.3): on refresh steps the full averaged gradient
//! is materialized on every rank (all-reduce), the leader computes the
//! randomized SVD once, and P is broadcast and installed via
//! [`GaLore::preset_projector`] — workers never SVD their own shards,
//! whose spectra would be wrong.

use super::comm::Comm;
use super::{BuildTarget, OptimizerSpec, WorkerOpt};
use crate::optim::{Projector, ProjectorSide};
use crate::tensor::Matrix;
use crate::util::rng::Pcg64;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

/// Shape metadata for one trainable parameter (from the manifest).
#[derive(Clone, Debug)]
pub struct ParamMeta {
    pub name: String,
    pub rows: usize,
    pub cols: usize,
}

/// Per-rank ("per-GPU") byte counters — the live validation of the Table 1
/// memory model.
#[derive(Clone, Copy, Debug, Default)]
pub struct MemoryReport {
    pub rank: usize,
    /// Bytes of parameter shards resident on this rank.
    pub param_shard_bytes: usize,
    /// Bytes of optimizer state (sharded moments + replicated projectors).
    pub optimizer_bytes: usize,
    /// Peak bytes of transient buffers (reduced gradients, broadcast P)
    /// live at once — bounded by ~one full layer gradient, not the model.
    pub peak_transient_bytes: usize,
    /// f32 elements moved through collectives by this rank.
    pub traffic_elems: u64,
}

/// Which dimension a parameter is sharded along.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum ShardAxis {
    Rows,
    Cols,
}

fn shard_axis(rows: usize, cols: usize) -> ShardAxis {
    if rows > cols {
        ShardAxis::Rows
    } else {
        ShardAxis::Cols
    }
}

/// Balanced contiguous split of `len` across `world`: rank r owns
/// [r·len/world, (r+1)·len/world).
fn shard_bounds(len: usize, world: usize, rank: usize) -> (usize, usize) {
    (rank * len / world, (rank + 1) * len / world)
}

/// Extract a shard (row range or column range) from a full matrix.
fn slice_shard(full: &Matrix, axis: ShardAxis, lo: usize, hi: usize) -> Matrix {
    match axis {
        ShardAxis::Rows => Matrix::from_vec(
            hi - lo,
            full.cols,
            full.data[lo * full.cols..hi * full.cols].to_vec(),
        ),
        ShardAxis::Cols => {
            let mut out = Matrix::zeros(full.rows, hi - lo);
            for r in 0..full.rows {
                out.row_mut(r).copy_from_slice(&full.row(r)[lo..hi]);
            }
            out
        }
    }
}

enum Cmd {
    /// Install the initial full parameters; each worker keeps its shards.
    Init(Vec<Matrix>),
    /// One training step: this worker's microbatch gradients (full shapes).
    Step { t: u64, lr: f32, grads: Vec<Matrix> },
    Gather,
    ExportOpt,
    ImportOpt(Vec<u8>),
    Report,
    Shutdown,
}

enum Reply {
    StepDone,
    Shards(Vec<Matrix>),
    OptState(Vec<u8>),
    ImportDone(Result<(), String>),
    Report(MemoryReport),
}

/// A world of persistent worker threads with sharded optimizer state.
pub struct FsdpCluster {
    world: usize,
    metas: Vec<ParamMeta>,
    cmd_tx: Vec<Sender<Cmd>>,
    reply_rx: Vec<Receiver<Reply>>,
    handles: Vec<JoinHandle<()>>,
    spec_name: &'static str,
}

impl FsdpCluster {
    pub fn new(world: usize, metas: Vec<ParamMeta>, spec: OptimizerSpec, seed: u64) -> FsdpCluster {
        assert!(world >= 1, "world size must be >= 1");
        assert!(
            spec.distributed_ok(),
            "{} cannot run on distributed workers",
            spec.name()
        );
        let spec_name = spec.name();
        let comms = Comm::create_world(world);
        let mut cmd_tx = Vec::with_capacity(world);
        let mut reply_rx = Vec::with_capacity(world);
        let mut handles = Vec::with_capacity(world);
        for (rank, comm) in comms.into_iter().enumerate() {
            let (ctx, crx) = channel::<Cmd>();
            let (rtx, rrx) = channel::<Reply>();
            let metas = metas.clone();
            let spec = spec.clone();
            let handle = std::thread::Builder::new()
                .name(format!("fsdp-worker-{rank}"))
                .spawn(move || {
                    let mut w = Worker::new(rank, world, comm, metas, spec, seed);
                    w.serve(crx, rtx);
                })
                .expect("spawning FSDP worker thread");
            cmd_tx.push(ctx);
            reply_rx.push(rrx);
            handles.push(handle);
        }
        FsdpCluster {
            world,
            metas,
            cmd_tx,
            reply_rx,
            handles,
            spec_name,
        }
    }

    pub fn world(&self) -> usize {
        self.world
    }

    pub fn optimizer_name(&self) -> &'static str {
        self.spec_name
    }

    /// Distribute initial full parameters; each worker keeps only its
    /// shards (channel ordering serializes this before any later step).
    /// Shapes are validated HERE — a worker panicking later would strand
    /// its peers in a collective.
    pub fn init_params(&self, full: &[Matrix]) {
        assert_eq!(full.len(), self.metas.len(), "param count != meta count");
        for (p, meta) in full.iter().zip(&self.metas) {
            assert_eq!(
                p.shape(),
                (meta.rows, meta.cols),
                "{}: param/meta shape mismatch",
                meta.name
            );
        }
        for tx in &self.cmd_tx {
            tx.send(Cmd::Init(full.to_vec())).expect("worker alive");
        }
    }

    /// One synchronous training step. `per_rank[r]` holds rank r's
    /// microbatch gradients in full (unsharded) shapes; the reduction to
    /// shards happens inside the workers. Blocks until all ranks finish.
    pub fn step(&mut self, t: u64, per_rank: Vec<Vec<Matrix>>, lr: f32) {
        assert_eq!(per_rank.len(), self.world, "need one gradient set per rank");
        // Validate shapes HERE, not in the workers: a worker panicking
        // between barrier waves would strand its peers in the collective.
        for (rank, grads) in per_rank.iter().enumerate() {
            assert_eq!(grads.len(), self.metas.len(), "rank {rank}: grad count");
            for (g, meta) in grads.iter().zip(&self.metas) {
                assert_eq!(
                    g.shape(),
                    (meta.rows, meta.cols),
                    "rank {rank}, {}: bad gradient shape",
                    meta.name
                );
            }
        }
        for (tx, grads) in self.cmd_tx.iter().zip(per_rank) {
            tx.send(Cmd::Step { t, lr, grads }).expect("worker alive");
        }
        for rx in &self.reply_rx {
            match rx.recv().expect("worker alive") {
                Reply::StepDone => {}
                _ => unreachable!("protocol error: expected StepDone"),
            }
        }
    }

    /// Assemble the full parameter set from every rank's shards.
    pub fn gather_params(&self) -> Vec<Matrix> {
        for tx in &self.cmd_tx {
            tx.send(Cmd::Gather).expect("worker alive");
        }
        let per_rank: Vec<Vec<Matrix>> = self
            .reply_rx
            .iter()
            .map(|rx| match rx.recv().expect("worker alive") {
                Reply::Shards(s) => s,
                _ => unreachable!("protocol error: expected Shards"),
            })
            .collect();
        self.metas
            .iter()
            .enumerate()
            .map(|(idx, meta)| {
                let shards: Vec<&Matrix> = per_rank.iter().map(|r| &r[idx]).collect();
                assemble(meta, &shards)
            })
            .collect()
    }

    /// Serialized optimizer state of rank 0 (shard-local; diagnostic use —
    /// checkpoints go through [`FsdpCluster::export_optimizers`]).
    pub fn export_rank0_optimizer(&self) -> Vec<u8> {
        self.cmd_tx[0].send(Cmd::ExportOpt).expect("worker alive");
        match self.reply_rx[0].recv().expect("worker alive") {
            Reply::OptState(bytes) => bytes,
            _ => unreachable!("protocol error: expected OptState"),
        }
    }

    /// Serialize EVERY rank's shard-local state (optimizer moments + the
    /// worker's SVD-stream position) into one framed blob:
    /// `[world u64] ([len u64][bytes])×world`. Round-trips through
    /// [`FsdpCluster::import_optimizers`] so FSDP resume restores each
    /// rank's moments instead of only rank 0's, and the next subspace
    /// refresh continues the uninterrupted run's sketch stream.
    pub fn export_optimizers(&self) -> Vec<u8> {
        for tx in &self.cmd_tx {
            tx.send(Cmd::ExportOpt).expect("worker alive");
        }
        let blobs: Vec<Vec<u8>> = self
            .reply_rx
            .iter()
            .map(|rx| match rx.recv().expect("worker alive") {
                Reply::OptState(bytes) => bytes,
                _ => unreachable!("protocol error: expected OptState"),
            })
            .collect();
        let mut out = Vec::new();
        out.extend_from_slice(&(self.world as u64).to_le_bytes());
        for b in &blobs {
            out.extend_from_slice(&(b.len() as u64).to_le_bytes());
            out.extend_from_slice(b);
        }
        out
    }

    /// Restore per-rank optimizer state from an [`export_optimizers`] blob.
    /// Fails (without touching worker state) when the blob was written at a
    /// different world size — shard-local moments do not re-shard.
    ///
    /// [`export_optimizers`]: FsdpCluster::export_optimizers
    pub fn import_optimizers(&self, bytes: &[u8]) -> Result<(), String> {
        let mut r = crate::optim::ser::Reader::new(bytes);
        let world = r.u64()? as usize;
        if world != self.world {
            return Err(format!(
                "optimizer state was saved at world={world}, cluster has world={}",
                self.world
            ));
        }
        let mut blobs = Vec::with_capacity(world);
        for _ in 0..world {
            let len = r.u64()? as usize;
            blobs.push(r.bytes(len)?.to_vec());
        }
        for (tx, blob) in self.cmd_tx.iter().zip(blobs) {
            tx.send(Cmd::ImportOpt(blob)).expect("worker alive");
        }
        let mut result = Ok(());
        for rx in &self.reply_rx {
            match rx.recv().expect("worker alive") {
                Reply::ImportDone(r) => {
                    if result.is_ok() {
                        result = r;
                    }
                }
                _ => unreachable!("protocol error: expected ImportDone"),
            }
        }
        result
    }

    /// Live per-rank byte counters, in rank order.
    pub fn memory_reports(&self) -> Vec<MemoryReport> {
        for tx in &self.cmd_tx {
            tx.send(Cmd::Report).expect("worker alive");
        }
        self.reply_rx
            .iter()
            .map(|rx| match rx.recv().expect("worker alive") {
                Reply::Report(r) => r,
                _ => unreachable!("protocol error: expected Report"),
            })
            .collect()
    }
}

impl Drop for FsdpCluster {
    fn drop(&mut self) {
        for tx in &self.cmd_tx {
            let _ = tx.send(Cmd::Shutdown);
        }
        if std::thread::panicking() {
            // A dead worker strands its peers inside a Barrier (std
            // barriers don't poison); joining them here would turn the
            // panic into a permanent hang. Leak the threads and let the
            // panic surface as a diagnostic instead.
            return;
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Reassemble a full parameter from per-rank shards.
fn assemble(meta: &ParamMeta, shards: &[&Matrix]) -> Matrix {
    let (m, n) = (meta.rows, meta.cols);
    match shard_axis(m, n) {
        ShardAxis::Rows => {
            let mut data = Vec::with_capacity(m * n);
            for s in shards {
                assert_eq!(s.cols, n, "{}: shard col mismatch", meta.name);
                data.extend_from_slice(&s.data);
            }
            Matrix::from_vec(m, n, data)
        }
        ShardAxis::Cols => {
            let mut out = Matrix::zeros(m, n);
            let mut c0 = 0;
            for s in shards {
                assert_eq!(s.rows, m, "{}: shard row mismatch", meta.name);
                for r in 0..m {
                    out.row_mut(r)[c0..c0 + s.cols].copy_from_slice(s.row(r));
                }
                c0 += s.cols;
            }
            assert_eq!(c0, n, "{}: shards do not cover all columns", meta.name);
            out
        }
    }
}

/// One worker thread's state: its rank's shards + optimizer + comm handle.
struct Worker {
    rank: usize,
    world: usize,
    comm: Comm,
    metas: Vec<ParamMeta>,
    galore: Option<crate::optim::GaLoreCfg>,
    opt: WorkerOpt,
    shards: Vec<Matrix>,
    /// Leader-only RNG stream for subspace SVDs (deterministic: refresh
    /// order is fixed by the step/param loop).
    svd_rng: Pcg64,
    peak_transient: usize,
}

impl Worker {
    fn new(
        rank: usize,
        world: usize,
        comm: Comm,
        metas: Vec<ParamMeta>,
        spec: OptimizerSpec,
        seed: u64,
    ) -> Worker {
        // This thread is one of `world` concurrent compute workers: nested
        // GEMM/SVD kernels split the core budget instead of each resolving
        // the full machine (world-fold oversubscription otherwise).
        crate::parallel::set_thread_share(world);
        let galore = spec.galore_cfg();
        // Per-rank optimizer seed (only hygiene — in external-subspace mode
        // workers never draw from their optimizer RNG).
        let opt = spec
            .build(
                seed ^ (rank as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15),
                BuildTarget::Worker {
                    external_subspace: true,
                },
            )
            .expect("spec validated in FsdpCluster::new");
        Worker {
            rank,
            world,
            comm,
            metas,
            galore,
            opt,
            // Same stream constant as the single-process GaLore optimizer:
            // the leader's refresh SVDs then draw the identical sketch
            // sequence, making FSDP(world=1) trajectories match Single mode
            // bitwise (tests/engine_parity.rs pins this).
            svd_rng: Pcg64::new(seed, 0x6a10),
            peak_transient: 0,
        }
    }

    fn serve(&mut self, rx: Receiver<Cmd>, tx: Sender<Reply>) {
        loop {
            match rx.recv() {
                Ok(Cmd::Init(full)) => self.init(full),
                Ok(Cmd::Step { t, lr, grads }) => {
                    self.step(t, lr, grads);
                    let _ = tx.send(Reply::StepDone);
                }
                Ok(Cmd::Gather) => {
                    let _ = tx.send(Reply::Shards(self.shards.clone()));
                }
                Ok(Cmd::ExportOpt) => {
                    let _ = tx.send(Reply::OptState(self.export_opt_state()));
                }
                Ok(Cmd::ImportOpt(bytes)) => {
                    let r = self.import_opt_state(&bytes);
                    let _ = tx.send(Reply::ImportDone(r));
                }
                Ok(Cmd::Report) => {
                    let _ = tx.send(Reply::Report(self.report()));
                }
                Ok(Cmd::Shutdown) | Err(_) => break,
            }
        }
    }

    /// Worker state blob: `[svd_rng position][optimizer blob]`. The SVD
    /// stream position rides along so a resumed run's next leader refresh
    /// draws the sketches the uninterrupted run would have.
    fn export_opt_state(&self) -> Vec<u8> {
        let mut out = Vec::new();
        self.svd_rng.write_state(&mut out);
        out.extend_from_slice(&self.opt.export_state());
        out
    }

    fn import_opt_state(&mut self, bytes: &[u8]) -> Result<(), String> {
        self.svd_rng = Pcg64::read_state(bytes)?;
        self.opt
            .as_opt()
            .import_state(&bytes[Pcg64::STATE_BYTES..])
    }

    fn init(&mut self, full: Vec<Matrix>) {
        assert_eq!(full.len(), self.metas.len());
        self.shards = full
            .iter()
            .zip(&self.metas)
            .map(|(p, meta)| {
                assert_eq!(
                    p.shape(),
                    (meta.rows, meta.cols),
                    "{}: param/meta shape mismatch",
                    meta.name
                );
                let axis = shard_axis(meta.rows, meta.cols);
                let len = match axis {
                    ShardAxis::Rows => meta.rows,
                    ShardAxis::Cols => meta.cols,
                };
                let (lo, hi) = shard_bounds(len, self.world, self.rank);
                slice_shard(p, axis, lo, hi)
            })
            .collect();
    }

    fn step(&mut self, t: u64, lr: f32, grads: Vec<Matrix>) {
        assert_eq!(grads.len(), self.shards.len(), "init_params before step");
        self.opt.as_opt().begin_step(t);
        let scale = 1.0 / self.world as f32;
        for (idx, grad) in grads.into_iter().enumerate() {
            let (m, n) = (self.metas[idx].rows, self.metas[idx].cols);
            assert_eq!(grad.shape(), (m, n), "{}: bad grad shape", self.metas[idx].name);
            let axis = shard_axis(m, n);
            let len = match axis {
                ShardAxis::Rows => m,
                ShardAxis::Cols => n,
            };
            let (lo, hi) = shard_bounds(len, self.world, self.rank);

            let projects = self.galore.map_or(false, |g| g.projects(m, n));
            let refresh = projects
                && (t % self.galore.unwrap().update_freq == 0
                    || !self.opt.has_projector(idx));

            let mut transient;
            let shard_grad = if refresh {
                // Refresh step: materialize the full averaged gradient on
                // every rank, leader computes the SVD, P is broadcast.
                let mut full =
                    Matrix::from_vec(m, n, self.comm.all_reduce_sum(grad.data));
                full.scale(scale);
                transient = full.numel() * 4;
                let g = self.galore.unwrap();
                let r = g.rank.min(m.min(n));
                let (side, d) = if m <= n {
                    (ProjectorSide::Left, m)
                } else {
                    (ProjectorSide::Right, n)
                };
                let p = if self.rank == 0 {
                    let proj =
                        Projector::from_gradient(&full, g.rank, g.projection, &mut self.svd_rng);
                    let p = proj.export_p();
                    debug_assert_eq!(p.shape(), (d, r));
                    self.comm.broadcast(0, Some(p.data.clone()));
                    p
                } else {
                    Matrix::from_vec(d, r, self.comm.broadcast(0, None))
                };
                transient += p.numel() * 4;
                if let Some(gal) = self.opt.galore_mut() {
                    gal.preset_projector(idx, Projector::from_parts(p, side, g.projection));
                }
                slice_shard(&full, axis, lo, hi)
            } else {
                match axis {
                    ShardAxis::Rows => {
                        // Row shards are contiguous in row-major order —
                        // a true reduce-scatter, no full buffer needed.
                        let offsets: Vec<usize> = (0..=self.world)
                            .map(|r| (r * m / self.world) * n)
                            .collect();
                        let mut sh = self.comm.reduce_scatter_sum(grad.data, &offsets);
                        for x in sh.iter_mut() {
                            *x *= scale;
                        }
                        transient = sh.len() * 4;
                        Matrix::from_vec(hi - lo, n, sh)
                    }
                    ShardAxis::Cols => {
                        // Column shards interleave in memory; reduce the
                        // full gradient and slice (dropped right after).
                        let mut full =
                            Matrix::from_vec(m, n, self.comm.all_reduce_sum(grad.data));
                        full.scale(scale);
                        transient = full.numel() * 4;
                        slice_shard(&full, axis, lo, hi)
                    }
                }
            };
            self.peak_transient = self.peak_transient.max(transient + shard_grad.numel() * 4);
            // Per-layer fused update: step now, drop the gradient buffers.
            self.opt
                .as_opt()
                .step_param(idx, &mut self.shards[idx], &shard_grad, lr);
        }
    }

    fn report(&self) -> MemoryReport {
        MemoryReport {
            rank: self.rank,
            param_shard_bytes: self.shards.iter().map(|s| s.numel() * 4).sum(),
            optimizer_bytes: self.opt.state_bytes(),
            peak_transient_bytes: self.peak_transient,
            traffic_elems: self.comm.traffic_elems(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{step_all, AdamCfg, AdamW, GaLoreCfg, ProjectionKind};

    fn metas(shapes: &[(usize, usize)]) -> Vec<ParamMeta> {
        shapes
            .iter()
            .enumerate()
            .map(|(i, &(r, c))| ParamMeta {
                name: format!("p{i}"),
                rows: r,
                cols: c,
            })
            .collect()
    }

    fn init_set(shapes: &[(usize, usize)], seed: u64) -> Vec<Matrix> {
        let mut rng = Pcg64::new(seed, 0);
        shapes
            .iter()
            .map(|&(r, c)| Matrix::randn(r, c, 0.5, &mut rng))
            .collect()
    }

    /// Identical gradients on every rank make the averaged gradient equal
    /// to the single-rank gradient *bitwise* (sum of w equal values is an
    /// exact power-of-two multiple for w ∈ {1,2,4}, then ·1/w is exact),
    /// so runs become comparable across world sizes.
    fn grad_set(shapes: &[(usize, usize)], seed: u64) -> Vec<Matrix> {
        let mut rng = Pcg64::new(seed, 1);
        shapes
            .iter()
            .map(|&(r, c)| Matrix::randn(r, c, 0.1, &mut rng))
            .collect()
    }

    const SHAPES: &[(usize, usize)] = &[(12, 24), (24, 12), (16, 16), (1, 16)];

    fn run_cluster(world: usize, spec: OptimizerSpec, steps: u64) -> Vec<Matrix> {
        let mut cluster = FsdpCluster::new(world, metas(SHAPES), spec, 42);
        cluster.init_params(&init_set(SHAPES, 7));
        for t in 0..steps {
            let grads = grad_set(SHAPES, 100 + t);
            let per_rank = vec![grads; world];
            cluster.step(t, per_rank, 0.05);
        }
        cluster.gather_params()
    }

    #[test]
    fn world1_adamw_matches_single_process_step_all() {
        let got = run_cluster(1, OptimizerSpec::AdamW(AdamCfg::default()), 5);
        let mut params = init_set(SHAPES, 7);
        let mut opt = AdamW::new(AdamCfg::default());
        for t in 0..5 {
            let grads = grad_set(SHAPES, 100 + t);
            step_all(&mut opt, t, &mut params, &grads, 0.05);
        }
        for (a, b) in got.iter().zip(&params) {
            assert_eq!(a.data, b.data, "world-1 cluster diverged from step_all");
        }
    }

    #[test]
    fn adamw_bitwise_invariant_across_world_sizes() {
        let w1 = run_cluster(1, OptimizerSpec::AdamW(AdamCfg::default()), 4);
        let w2 = run_cluster(2, OptimizerSpec::AdamW(AdamCfg::default()), 4);
        let w4 = run_cluster(4, OptimizerSpec::AdamW(AdamCfg::default()), 4);
        for ((a, b), c) in w1.iter().zip(&w2).zip(&w4) {
            assert_eq!(a.data, b.data, "world 1 vs 2 diverged");
            assert_eq!(a.data, c.data, "world 1 vs 4 diverged");
        }
    }

    fn galore_spec() -> OptimizerSpec {
        OptimizerSpec::GaLore {
            galore: GaLoreCfg {
                rank: 4,
                update_freq: 3,
                alpha: 1.0,
                projection: ProjectionKind::RandSvd,
                ..GaLoreCfg::default()
            },
            adam: AdamCfg::default(),
        }
    }

    #[test]
    fn galore_bitwise_invariant_across_world_sizes() {
        // Elementwise inner Adam + shard-compatible projector application
        // (P spans the un-sharded dimension) make the whole GaLore step
        // world-size invariant given identical per-rank microbatches.
        let w1 = run_cluster(1, galore_spec(), 7);
        let w2 = run_cluster(2, galore_spec(), 7);
        let w4 = run_cluster(4, galore_spec(), 7);
        for (idx, ((a, b), c)) in w1.iter().zip(&w2).zip(&w4).enumerate() {
            assert_eq!(a.data, b.data, "param {idx}: world 1 vs 2 diverged");
            assert_eq!(a.data, c.data, "param {idx}: world 1 vs 4 diverged");
        }
    }

    #[test]
    fn galore_learns_low_rank_target_under_fsdp() {
        // Convex quadratic with a low-rank offset: grads differ per rank
        // (each rank sees a noisy microbatch), loss must still fall.
        let shapes = &[(16, 32)];
        let mut rng = Pcg64::new(3, 0);
        let u = Matrix::randn(16, 3, 1.0, &mut rng);
        let v = Matrix::randn(3, 32, 1.0, &mut rng);
        let target = u.matmul(&v);
        let world = 2;
        let mut cluster = FsdpCluster::new(
            world,
            metas(shapes),
            OptimizerSpec::GaLore {
                galore: GaLoreCfg {
                    rank: 3,
                    update_freq: 25,
                    alpha: 1.0,
                    ..GaLoreCfg::default()
                },
                adam: AdamCfg::default(),
            },
            11,
        );
        let mut w = vec![Matrix::zeros(16, 32)];
        cluster.init_params(&w);
        for t in 0..200 {
            let mut per_rank = Vec::new();
            for r in 0..world {
                let mut g = w[0].sub(&target);
                // microbatch noise, different per rank
                let noise = Matrix::randn(16, 32, 0.01, &mut Pcg64::new(t, r as u64));
                g.add_assign(&noise);
                per_rank.push(vec![g]);
            }
            cluster.step(t, per_rank, 0.05);
            w = cluster.gather_params();
        }
        let rel = w[0].sub(&target).frobenius_norm() / target.frobenius_norm();
        assert!(rel < 0.1, "FSDP GaLore did not converge: rel {rel}");
    }

    #[test]
    fn memory_reports_cover_all_params_and_traffic() {
        let world = 4;
        let mut cluster = FsdpCluster::new(world, metas(SHAPES), galore_spec(), 5);
        cluster.init_params(&init_set(SHAPES, 7));
        cluster.step(0, vec![grad_set(SHAPES, 9); world], 0.01);
        let reports = cluster.memory_reports();
        assert_eq!(reports.len(), world);
        let total_param: usize = reports.iter().map(|r| r.param_shard_bytes).sum();
        let expect: usize = SHAPES.iter().map(|&(r, c)| r * c * 4).sum();
        assert_eq!(total_param, expect, "shards must partition the params");
        for r in &reports {
            assert!(r.optimizer_bytes > 0);
            assert!(r.traffic_elems > 0);
            assert!(r.peak_transient_bytes > 0);
        }
        // Sharded GaLore moments: each rank's optimizer state is well below
        // full-model AdamW state (2·4 bytes/elem).
        let full_adam: usize = SHAPES.iter().map(|&(r, c)| 2 * r * c * 4).sum();
        assert!(reports[0].optimizer_bytes < full_adam);
    }

    #[test]
    fn optimizer_state_roundtrips_across_all_ranks() {
        // FSDP resume contract: export_optimizers captures every rank's
        // shard-local moments; a fresh cluster restored from the blob (plus
        // re-scattered params) continues bitwise identically.
        let world = 2;
        let mut cluster = FsdpCluster::new(
            world,
            metas(SHAPES),
            OptimizerSpec::AdamW(AdamCfg::default()),
            1,
        );
        cluster.init_params(&init_set(SHAPES, 7));
        cluster.step(0, vec![grad_set(SHAPES, 3); world], 0.01);
        let blob = cluster.export_optimizers();
        let mut restored = FsdpCluster::new(
            world,
            metas(SHAPES),
            OptimizerSpec::AdamW(AdamCfg::default()),
            99,
        );
        restored.init_params(&cluster.gather_params());
        restored.import_optimizers(&blob).unwrap();
        cluster.step(1, vec![grad_set(SHAPES, 4); world], 0.01);
        restored.step(1, vec![grad_set(SHAPES, 4); world], 0.01);
        let a = cluster.gather_params();
        let b = restored.gather_params();
        for (idx, (x, y)) in a.iter().zip(&b).enumerate() {
            assert_eq!(x.data, y.data, "param {idx}: restored cluster diverged");
        }
        // A different world size must be rejected (shards don't re-shard).
        let other_world = FsdpCluster::new(
            4,
            metas(SHAPES),
            OptimizerSpec::AdamW(AdamCfg::default()),
            1,
        );
        assert!(other_world.import_optimizers(&blob).is_err());
    }

    #[test]
    fn rank0_optimizer_state_exports() {
        let world = 2;
        let mut cluster =
            FsdpCluster::new(world, metas(SHAPES), OptimizerSpec::AdamW(AdamCfg::default()), 1);
        cluster.init_params(&init_set(SHAPES, 7));
        cluster.step(0, vec![grad_set(SHAPES, 3); world], 0.01);
        let state = cluster.export_rank0_optimizer();
        assert!(!state.is_empty(), "AdamW state must serialize");
    }

    #[test]
    fn gather_roundtrips_init_params_before_any_step() {
        let world = 3;
        let cluster =
            FsdpCluster::new(world, metas(SHAPES), OptimizerSpec::AdamW(AdamCfg::default()), 1);
        let init = init_set(SHAPES, 7);
        cluster.init_params(&init);
        let got = cluster.gather_params();
        for (a, b) in got.iter().zip(&init) {
            assert_eq!(a.data, b.data, "shard/assemble roundtrip lost data");
        }
    }
}
