//! The generic worker cluster: persistent workers behind one shared
//! command protocol, over a selectable transport.
//!
//! Both distributed modes — FSDP (sharded state, `dist/fsdp.rs`) and DDP
//! (replicated state, `dist/ddp.rs`) — are worlds of persistent workers
//! driven in lockstep by the coordinator. Everything mode-*independent*
//! lives here, written once:
//!
//! * the [`Cmd`]/[`Reply`] protocol and the single [`handle_cmd`] dispatch
//!   both serve loops (thread channels, worker-process sockets) call into,
//! * the transport-agnostic spawn path ([`TransportKind::Threads`]: worker
//!   threads with per-rank [`Comm`] handles and the
//!   [`crate::parallel::set_thread_share`] core-budget split;
//!   [`TransportKind::Process`]: self-exec'd worker OS processes over
//!   Unix-domain sockets — see `dist/process.rs`),
//! * coordinator-side shape validation (a worker dying mid-collective
//!   would strand its peers inside the rendezvous, so bad inputs are
//!   rejected *before* any `Cmd` is sent),
//! * the panic/exit-aware [`Drop`] for both worker kinds.
//!
//! A mode is one [`Worker`] implementation: what a rank stores (shards vs
//! a replica), how a step consumes gradients, and what its state blob
//! contains. `Cluster<FsdpWorker>` and `Cluster<DdpWorker>` are the two
//! instantiations; protocol fixes land here and cannot drift between
//! modes — or between transports.

use super::comm::Comm;
use super::{process, wire, OptimizerSpec};
use crate::tensor::Matrix;
use std::marker::PhantomData;
use std::os::unix::net::UnixStream;
use std::path::PathBuf;
use std::process::Child;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// A worker rank died mid-run — the caught form of what used to be an
/// unconditional coordinator panic. [`Cluster::try_step`] and friends
/// return this so a supervisor (`train/supervisor.rs`) can tear the
/// cluster down and recover; the panicking wrappers ([`Cluster::step`])
/// keep the old prompt-failure behavior for everyone else.
#[derive(Clone, Debug)]
pub struct WorkerLoss {
    /// The rank that failed FIRST (attributed via the shared failure
    /// cell, the relay, or child exit statuses — not merely the rank
    /// whose link the coordinator happened to read first).
    pub rank: usize,
    /// Human-readable cause (panic payload, exit status, or io error).
    pub cause: String,
}

impl std::fmt::Display for WorkerLoss {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "worker rank {} lost: {}", self.rank, self.cause)
    }
}

/// First-failure-wins record shared by the coordinator, the thread
/// workers' panic handlers, and the process transport's relay: whoever
/// observes a death first writes `(rank, cause)`; later writers are
/// ignored. This is what lets the coordinator blame the rank that
/// actually died rather than the first VICTIM it happens to poll.
pub(crate) type FailureCell = Arc<Mutex<Option<(usize, String)>>>;

pub(crate) fn record_failure(cell: &FailureCell, rank: usize, cause: String) {
    let mut slot = cell.lock().unwrap_or_else(|e| e.into_inner());
    if slot.is_none() {
        *slot = Some((rank, cause));
    }
}

/// Render a caught panic payload for failure attribution.
pub(crate) fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "panicked (non-string payload)".to_string()
    }
}

/// Which fabric connects the ranks of a cluster (`[dist] transport` /
/// `--transport`). Both transports produce **bitwise identical**
/// trajectories — the collective math is transport-independent
/// (`dist/comm.rs`); pinned by `tests/transport.rs`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportKind {
    /// In-process worker threads over shared-memory slots (default).
    Threads,
    /// Worker OS processes (self-exec `galore2 worker …`) over
    /// length-framed Unix-domain sockets.
    Process,
}

impl TransportKind {
    /// Shared by TOML and CLI parsing so the two can never drift.
    pub fn parse(s: &str) -> Result<TransportKind, String> {
        match s {
            "threads" => Ok(TransportKind::Threads),
            "process" => Ok(TransportKind::Process),
            other => Err(format!("unknown transport {other:?} (threads|process)")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            TransportKind::Threads => "threads",
            TransportKind::Process => "process",
        }
    }
}

/// Shape metadata for one trainable parameter (from the manifest).
#[derive(Clone, Debug)]
pub struct ParamMeta {
    pub name: String,
    pub rows: usize,
    pub cols: usize,
}

/// Per-rank ("per-GPU") byte counters — the live validation of the Table 1
/// memory model.
#[derive(Clone, Copy, Debug, Default)]
pub struct MemoryReport {
    pub rank: usize,
    /// Bytes of parameter shards resident on this rank.
    pub param_shard_bytes: usize,
    /// Bytes of optimizer state (sharded moments + replicated projectors).
    pub optimizer_bytes: usize,
    /// Peak bytes of transient buffers (reduced gradients, broadcast P,
    /// one in-flight shm generation) live at once — bounded by ~one full
    /// layer gradient, not the model.
    pub peak_transient_bytes: usize,
    /// f32 elements moved through collectives by this rank.
    pub traffic_elems: u64,
    /// Actual payload bytes this rank moved over comm sockets (process
    /// transport, shm off; 0 under threads). Pins the shm plane's
    /// zero-socket-payload contract.
    pub socket_bytes: u64,
    /// Actual payload bytes this rank moved through the shm slot table
    /// (deposits + peer reads; process transport, shm on).
    pub shm_bytes: u64,
}

/// Per-step timing one rank measured while serving a `Step` command —
/// the payload of `StepEvent::StepTimed` and the overlap benches.
/// `comm_ns` is *worker-blocked* communication time (the comm cost the
/// pipeline failed to hide; under the serial schedule, full collective
/// latency); `compute_ns` is the rest of the step wall time.
/// Observability only — never feeds back into the trajectory.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepTiming {
    pub comm_ns: u64,
    pub compute_ns: u64,
}

/// Per-step traffic one rank measured while serving a `Step` command —
/// the payload of `StepEvent::StepTraffic` and the data-plane benches.
/// Byte counters are per-step deltas of the process-wide transport
/// counters (zero under the thread transport, which moves no bytes).
/// Observability only — never feeds back into the trajectory.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepTraffic {
    /// f32 payload bytes this step moved over comm sockets.
    pub socket_bytes: u64,
    /// Payload bytes this step moved through the shm slot table.
    pub shm_bytes: u64,
    /// Peak transient-buffer bytes live at once on this rank (includes
    /// the in-flight shm generation under the overlap pipeline).
    pub peak_transient_bytes: u64,
}

/// Which dimension a parameter is sharded along (always the *longer* one —
/// exactly the dimension the GaLore projector does not span, so a
/// leader-computed P applies unchanged to every shard).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum ShardAxis {
    Rows,
    Cols,
}

pub(crate) fn shard_axis(rows: usize, cols: usize) -> ShardAxis {
    if rows > cols {
        ShardAxis::Rows
    } else {
        ShardAxis::Cols
    }
}

/// Balanced contiguous split of `len` across `world`: rank r owns
/// [r·len/world, (r+1)·len/world). Ranks may own empty ranges when
/// `len < world` (layers narrower than the world size).
pub(crate) fn shard_bounds(len: usize, world: usize, rank: usize) -> (usize, usize) {
    (rank * len / world, (rank + 1) * len / world)
}

/// Extract a shard (row range or column range) from a full matrix.
pub(crate) fn slice_shard(full: &Matrix, axis: ShardAxis, lo: usize, hi: usize) -> Matrix {
    match axis {
        ShardAxis::Rows => Matrix::from_vec(
            hi - lo,
            full.cols,
            full.data[lo * full.cols..hi * full.cols].to_vec(),
        ),
        ShardAxis::Cols => {
            let mut out = Matrix::zeros(full.rows, hi - lo);
            for r in 0..full.rows {
                out.row_mut(r).copy_from_slice(&full.row(r)[lo..hi]);
            }
            out
        }
    }
}

/// Reassemble a full parameter from per-rank shards (in rank order).
pub(crate) fn assemble(meta: &ParamMeta, shards: &[&Matrix]) -> Matrix {
    let (m, n) = (meta.rows, meta.cols);
    match shard_axis(m, n) {
        ShardAxis::Rows => {
            let mut data = Vec::with_capacity(m * n);
            for s in shards {
                assert_eq!(s.cols, n, "{}: shard col mismatch", meta.name);
                data.extend_from_slice(&s.data);
            }
            Matrix::from_vec(m, n, data)
        }
        ShardAxis::Cols => {
            let mut out = Matrix::zeros(m, n);
            let mut c0 = 0;
            for s in shards {
                assert_eq!(s.rows, m, "{}: shard row mismatch", meta.name);
                for r in 0..m {
                    out.row_mut(r)[c0..c0 + s.cols].copy_from_slice(s.row(r));
                }
                c0 += s.cols;
            }
            assert_eq!(c0, n, "{}: shards do not cover all columns", meta.name);
            out
        }
    }
}

/// One rank's behavior: what it stores and how it consumes a step. The
/// generic [`Cluster`] owns everything else (protocol, spawn, shutdown).
///
/// Not `Send`-bounded on purpose: workers are CONSTRUCTED inside their
/// own thread/process from the `Send`-able spec (built optimizers hold
/// deliberately non-`Send` state) and never cross threads afterwards.
pub trait Worker: 'static {
    /// Mode tag ("fsdp" | "ddp") — thread names, the `galore2 worker
    /// --mode` flag, and diagnostics.
    const MODE: &'static str;

    /// Construct this rank's state. Runs *inside* the worker
    /// thread/process; the optimizer is built locally from the `Send`-able
    /// spec.
    fn new(
        rank: usize,
        world: usize,
        comm: Comm,
        metas: Vec<ParamMeta>,
        spec: OptimizerSpec,
        seed: u64,
    ) -> Self;

    /// Install initial full parameters (keep shards or the whole replica).
    fn install(&mut self, full: Vec<Matrix>);

    /// One training step given this rank's microbatch gradients (full,
    /// unsharded shapes); collectives rendezvous with peer ranks inside.
    fn step(&mut self, t: u64, lr: f32, grads: Vec<Matrix>);

    /// This rank's parameter view (its shards under FSDP, the full replica
    /// under DDP).
    fn params(&self) -> Vec<Matrix>;

    /// This rank's serialized optimizer-state frame (mode-private format).
    fn export_state(&self) -> Vec<u8>;

    /// Restore this rank's state from an `export_state` frame.
    fn import_state(&mut self, bytes: &[u8]) -> Result<(), String>;

    fn report(&self) -> MemoryReport;

    /// Timing of this rank's most recent step (default: all zeros, for
    /// workers that do not measure).
    fn last_step_timing(&self) -> StepTiming {
        StepTiming::default()
    }

    /// Traffic of this rank's most recent step (default: all zeros, for
    /// workers that do not measure).
    fn last_step_traffic(&self) -> StepTraffic {
        StepTraffic::default()
    }
}

pub(crate) enum Cmd {
    /// Install the initial full parameters.
    Init(Vec<Matrix>),
    /// One training step: this worker's microbatch gradients (full shapes).
    Step { t: u64, lr: f32, grads: Vec<Matrix> },
    Params,
    ExportOpt,
    ImportOpt(Vec<u8>),
    Report,
    Shutdown,
}

pub(crate) enum Reply {
    StepDone {
        comm_ns: u64,
        compute_ns: u64,
        socket_bytes: u64,
        shm_bytes: u64,
        peak_transient: u64,
    },
    Params(Vec<Matrix>),
    OptState(Vec<u8>),
    ImportDone(Result<(), String>),
    Report(MemoryReport),
}

/// What serving one command produced.
pub(crate) enum Served {
    Reply(Reply),
    NoReply,
    Shutdown,
}

/// THE protocol dispatch: both serve loops — thread workers reading a
/// channel, process workers reading socket frames — route every command
/// through here, so transports cannot drift in what a command does.
pub(crate) fn handle_cmd<W: Worker>(w: &mut W, cmd: Cmd) -> Served {
    match cmd {
        Cmd::Init(full) => {
            w.install(full);
            Served::NoReply
        }
        Cmd::Step { t, lr, grads } => {
            w.step(t, lr, grads);
            let timing = w.last_step_timing();
            let traffic = w.last_step_traffic();
            Served::Reply(Reply::StepDone {
                comm_ns: timing.comm_ns,
                compute_ns: timing.compute_ns,
                socket_bytes: traffic.socket_bytes,
                shm_bytes: traffic.shm_bytes,
                peak_transient: traffic.peak_transient_bytes,
            })
        }
        Cmd::Params => Served::Reply(Reply::Params(w.params())),
        Cmd::ExportOpt => Served::Reply(Reply::OptState(w.export_state())),
        Cmd::ImportOpt(bytes) => Served::Reply(Reply::ImportDone(w.import_state(&bytes))),
        Cmd::Report => Served::Reply(Reply::Report(w.report())),
        Cmd::Shutdown => Served::Shutdown,
    }
}

/// `crash_at`: thread-transport fault injection (the counterpart of the
/// process transport's `GALORE2_TEST_CRASH_STEP_RANK` exit) — panic when
/// serving a `Step` with `t >= crash_at`. Borrows the channel endpoints
/// so a panic unwinding out of here does NOT drop them: the worker
/// closure records the failure cause first, then drops the channels —
/// the coordinator can only observe the death after it is attributable.
fn serve<W: Worker>(w: &mut W, rx: &Receiver<Cmd>, tx: &Sender<Reply>, crash_at: Option<u64>) {
    loop {
        let cmd = match rx.recv() {
            Ok(cmd) => cmd,
            Err(_) => break,
        };
        if let Cmd::Step { t, .. } = &cmd {
            if crash_at.is_some_and(|n| *t >= n) {
                // lint: allow(no-panic-dist): test-only injected death — flows through the worker closure's catch_unwind into FailureCell by design
                panic!("injected test crash (step {t})");
            }
        }
        match handle_cmd(w, cmd) {
            Served::Reply(reply) => {
                let _ = tx.send(reply);
            }
            Served::NoReply => {}
            Served::Shutdown => break,
        }
    }
}

/// The coordinator's handle onto one rank: a channel pair into a worker
/// thread, or a framed control socket into a worker process. `send`/`recv`
/// panic with an attributable message when the worker is gone — the
/// protocol guarantees a worker only disappears on a real failure, and a
/// prompt panic beats a silent hang (pinned by the crash cases in
/// `tests/transport.rs`).
enum Link {
    Thread {
        tx: Sender<Cmd>,
        rx: Receiver<Reply>,
        handle: Option<JoinHandle<()>>,
    },
    Process {
        control: UnixStream,
        child: Child,
        rank: usize,
        mode: &'static str,
        /// Per-connection receive scratch: the control plane reads one
        /// reply per command and must not allocate per message. RefCell,
        /// not Mutex: links live on the coordinator thread only.
        scratch: std::cell::RefCell<Vec<u8>>,
    },
}

impl Link {
    /// Fallible send: `Err` (io-level cause) when the worker is gone.
    fn try_send(&self, cmd: Cmd) -> Result<(), String> {
        match self {
            Link::Thread { tx, .. } => tx
                .send(cmd)
                .map_err(|_| "command channel closed (worker thread died)".to_string()),
            Link::Process {
                control,
                rank,
                mode,
                ..
            } => {
                let frame = wire::encode_cmd(&cmd);
                wire::write_frame(&mut &*control, &frame).map_err(|e| {
                    format!(
                        "{mode} worker process rank {rank} is gone ({e}) — \
                         check its stderr for the original failure"
                    )
                })
            }
        }
    }

    /// Fallible receive: `Err` (io-level cause) when the worker died
    /// mid-command or sent a malformed reply.
    fn try_recv(&self) -> Result<Reply, String> {
        match self {
            Link::Thread { rx, .. } => rx
                .recv()
                .map_err(|_| "reply channel closed (worker thread died)".to_string()),
            Link::Process {
                control,
                rank,
                mode,
                scratch,
                ..
            } => {
                let mut frame = scratch.borrow_mut();
                wire::read_frame_into(&mut &*control, &mut frame).map_err(|e| {
                    format!(
                        "{mode} worker process rank {rank} died mid-command ({e}) — \
                         check its stderr for the original failure"
                    )
                })?;
                wire::decode_reply(&frame).map_err(|e| {
                    format!("{mode} worker process rank {rank} sent a malformed reply: {e}")
                })
            }
        }
    }

    fn send(&self, cmd: Cmd) {
        self.try_send(cmd)
            .unwrap_or_else(|e| panic!("worker link send failed: {e}"));
    }

    fn recv(&self) -> Reply {
        self.try_recv()
            .unwrap_or_else(|e| panic!("worker link recv failed: {e}"))
    }

    /// Best-effort shutdown notice (Drop path — the worker may be gone).
    fn send_shutdown_quietly(&self) {
        match self {
            Link::Thread { tx, .. } => {
                let _ = tx.send(Cmd::Shutdown);
            }
            Link::Process { control, .. } => {
                let _ = wire::write_frame(&mut &*control, &wire::encode_cmd(&Cmd::Shutdown));
            }
        }
    }
}

/// A world of persistent workers, one per rank, driven in lockstep. `W`
/// decides what each rank stores (see [`Worker`]); [`TransportKind`]
/// decides whether ranks are threads or OS processes.
pub struct Cluster<W: Worker> {
    world: usize,
    metas: Vec<ParamMeta>,
    links: Vec<Link>,
    transport: TransportKind,
    /// Process transport only: the collective relay thread and the
    /// rendezvous socket path (for Drop-time cleanup).
    relay: Option<JoinHandle<()>>,
    socket_path: Option<PathBuf>,
    spec_name: &'static str,
    /// First-failure-wins (rank, cause) record written by whichever party
    /// observes a worker death first (thread panic handler, process relay).
    failure: FailureCell,
    /// Rank-max timing of the most recent successful step (None before
    /// the first step).
    last_timing: Option<StepTiming>,
    /// Data-plane traffic of the most recent successful step (None before
    /// the first step).
    last_traffic: Option<StepTraffic>,
    _mode: PhantomData<fn() -> W>,
}

impl<W: Worker> Cluster<W> {
    /// Spawn an in-process (threaded) cluster — the default transport.
    /// Infallible like thread spawning itself; the process transport's
    /// fallible spawn path is [`Cluster::with_transport`].
    pub fn new(world: usize, metas: Vec<ParamMeta>, spec: OptimizerSpec, seed: u64) -> Cluster<W> {
        Self::with_transport(world, metas, spec, seed, TransportKind::Threads)
            .unwrap_or_else(|e| panic!("spawning {} thread cluster: {e}", W::MODE))
    }

    /// Spawn a cluster over the given transport. The process transport can
    /// fail to come up (missing worker binary, a worker dying during the
    /// handshake) — those are errors, not panics, so the coordinator can
    /// report them.
    pub fn with_transport(
        world: usize,
        metas: Vec<ParamMeta>,
        spec: OptimizerSpec,
        seed: u64,
        transport: TransportKind,
    ) -> Result<Cluster<W>, String> {
        assert!(world >= 1, "world size must be >= 1");
        assert!(
            spec.distributed_ok(),
            "{} cannot run on distributed workers",
            spec.name()
        );
        let spec_name = spec.name();
        let failure: FailureCell = Arc::new(Mutex::new(None));
        let (links, relay, socket_path) = match transport {
            TransportKind::Threads => (
                spawn_threads::<W>(world, &metas, &spec, seed, &failure),
                None,
                None,
            ),
            TransportKind::Process => {
                let spawned =
                    process::spawn_world(W::MODE, world, &metas, &spec, seed, failure.clone())?;
                let links = spawned
                    .controls
                    .into_iter()
                    .zip(spawned.children)
                    .enumerate()
                    .map(|(rank, (control, child))| Link::Process {
                        control,
                        child,
                        rank,
                        mode: W::MODE,
                        scratch: std::cell::RefCell::new(Vec::new()),
                    })
                    .collect();
                (links, Some(spawned.relay), Some(spawned.socket_path))
            }
        };
        Ok(Cluster {
            world,
            metas,
            links,
            transport,
            relay,
            socket_path,
            spec_name,
            failure,
            last_timing: None,
            last_traffic: None,
            _mode: PhantomData,
        })
    }

    pub fn world(&self) -> usize {
        self.world
    }

    pub fn transport(&self) -> TransportKind {
        self.transport
    }

    pub fn optimizer_name(&self) -> &'static str {
        self.spec_name
    }

    /// Full parameter shapes, in parameter order.
    pub fn metas(&self) -> &[ParamMeta] {
        &self.metas
    }

    /// Rendezvous socket path (process transport; `None` for threads).
    /// Exposed so the transport suite can assert Drop-time cleanup.
    pub fn socket_path(&self) -> Option<&std::path::Path> {
        self.socket_path.as_deref()
    }

    /// Distribute initial full parameters to every worker (protocol
    /// ordering serializes this before any later step). Shapes are
    /// validated HERE — a worker dying later would strand its peers in a
    /// collective.
    pub fn init_params(&self, full: &[Matrix]) {
        assert_eq!(full.len(), self.metas.len(), "param count != meta count");
        for (p, meta) in full.iter().zip(&self.metas) {
            assert_eq!(
                p.shape(),
                (meta.rows, meta.cols),
                "{}: param/meta shape mismatch",
                meta.name
            );
        }
        for link in &self.links {
            link.send(Cmd::Init(full.to_vec()));
        }
    }

    /// One synchronous training step. `per_rank[r]` holds rank r's
    /// microbatch gradients in full (unsharded) shapes. Blocks until all
    /// ranks finish. Panics on worker death (the PR 4 prompt-failure
    /// contract); [`Cluster::try_step`] is the caught form.
    pub fn step(&mut self, t: u64, per_rank: Vec<Vec<Matrix>>, lr: f32) {
        self.try_step(t, per_rank, lr)
            .unwrap_or_else(|loss| panic!("{loss}"));
    }

    /// [`Cluster::step`], but a worker death comes back as
    /// `Err(WorkerLoss)` naming the rank that failed FIRST — the hook the
    /// recovery supervisor catches. Coordinator-side shape validation
    /// still panics: bad inputs are coordinator bugs, not worker deaths.
    pub fn try_step(
        &mut self,
        t: u64,
        per_rank: Vec<Vec<Matrix>>,
        lr: f32,
    ) -> Result<(), WorkerLoss> {
        assert_eq!(per_rank.len(), self.world, "need one gradient set per rank");
        // Validate shapes HERE, not in the workers: a worker dying between
        // rendezvous waves would strand its peers in the collective.
        for (rank, grads) in per_rank.iter().enumerate() {
            assert_eq!(grads.len(), self.metas.len(), "rank {rank}: grad count");
            for (g, meta) in grads.iter().zip(&self.metas) {
                assert_eq!(
                    g.shape(),
                    (meta.rows, meta.cols),
                    "rank {rank}, {}: bad gradient shape",
                    meta.name
                );
            }
        }
        let mut first_err: Option<(usize, String)> = None;
        for (rank, grads) in per_rank.into_iter().enumerate() {
            if let Err(e) = self.links[rank].try_send(Cmd::Step { t, lr, grads }) {
                first_err.get_or_insert((rank, e));
            }
        }
        // Drain EVERY reply even after a failure: victims die promptly
        // (barrier poison / relay socket drop), so their links close
        // rather than hang, and skipping them would desynchronize the
        // protocol for any rank that did survive.
        let mut timing = StepTiming::default();
        let mut traffic = StepTraffic::default();
        for (rank, link) in self.links.iter().enumerate() {
            match link.try_recv() {
                Ok(Reply::StepDone {
                    comm_ns,
                    compute_ns,
                    socket_bytes,
                    shm_bytes,
                    peak_transient,
                }) => {
                    // Rank-max of each component: the step is lockstep, so
                    // the slowest rank's stall is the step's stall.
                    timing.comm_ns = timing.comm_ns.max(comm_ns);
                    timing.compute_ns = timing.compute_ns.max(compute_ns);
                    // Bytes sum across ranks (total data-plane volume);
                    // transient footprint is a rank-max, like timing.
                    traffic.socket_bytes += socket_bytes;
                    traffic.shm_bytes += shm_bytes;
                    traffic.peak_transient_bytes =
                        traffic.peak_transient_bytes.max(peak_transient);
                }
                Ok(_) => unreachable!("protocol error: expected StepDone"),
                Err(e) => {
                    first_err.get_or_insert((rank, e));
                }
            }
        }
        match first_err {
            None => {
                self.last_timing = Some(timing);
                self.last_traffic = Some(traffic);
                Ok(())
            }
            Some((rank, cause)) => Err(self.classify(rank, cause)),
        }
    }

    /// Timing of the most recent successful [`Cluster::step`] /
    /// [`Cluster::try_step`] (rank-max per component); `None` before the
    /// first step.
    pub fn last_step_timing(&self) -> Option<StepTiming> {
        self.last_timing
    }

    /// Traffic of the most recent successful [`Cluster::step`] /
    /// [`Cluster::try_step`] (bytes summed across ranks, transient
    /// footprint rank-max); `None` before the first step.
    pub fn last_step_traffic(&self) -> Option<StepTraffic> {
        self.last_traffic
    }

    /// Attribute a link-level failure to the rank that actually died:
    /// the shared failure cell (thread panics, relay observations) wins,
    /// then a non-success child exit status, then the io-errored link.
    fn classify(&mut self, io_rank: usize, io_cause: String) -> WorkerLoss {
        if let Some((rank, cause)) = self
            .failure
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .clone()
        {
            return WorkerLoss { rank, cause };
        }
        for link in &mut self.links {
            if let Link::Process { child, rank, .. } = link {
                if let Ok(Some(status)) = child.try_wait() {
                    if !status.success() {
                        return WorkerLoss {
                            rank: *rank,
                            cause: format!("worker process exited: {status}"),
                        };
                    }
                }
            }
        }
        WorkerLoss {
            rank: io_rank,
            cause: io_cause,
        }
    }

    /// Every rank's parameter view, in rank order (shards under FSDP, full
    /// replicas under DDP).
    pub fn params_per_rank(&self) -> Vec<Vec<Matrix>> {
        for link in &self.links {
            link.send(Cmd::Params);
        }
        self.links
            .iter()
            .map(|link| match link.recv() {
                Reply::Params(p) => p,
                _ => unreachable!("protocol error: expected Params"),
            })
            .collect()
    }

    /// One rank's parameter view.
    pub fn rank_params(&self, rank: usize) -> Vec<Matrix> {
        self.links[rank].send(Cmd::Params);
        match self.links[rank].recv() {
            Reply::Params(p) => p,
            _ => unreachable!("protocol error: expected Params"),
        }
    }

    /// [`Cluster::params_per_rank`] with worker death caught and
    /// attributed, for the recovery path.
    pub fn try_params_per_rank(&mut self) -> Result<Vec<Vec<Matrix>>, WorkerLoss> {
        let mut first_err: Option<(usize, String)> = None;
        for (rank, link) in self.links.iter().enumerate() {
            if let Err(e) = link.try_send(Cmd::Params) {
                first_err.get_or_insert((rank, e));
            }
        }
        let mut out = Vec::with_capacity(self.world);
        for (rank, link) in self.links.iter().enumerate() {
            match link.try_recv() {
                Ok(Reply::Params(p)) => out.push(p),
                Ok(_) => unreachable!("protocol error: expected Params"),
                Err(e) => {
                    first_err.get_or_insert((rank, e));
                }
            }
        }
        match first_err {
            None => Ok(out),
            Some((rank, cause)) => Err(self.classify(rank, cause)),
        }
    }

    /// [`Cluster::rank_params`] with worker death caught and attributed.
    pub fn try_rank_params(&mut self, rank: usize) -> Result<Vec<Matrix>, WorkerLoss> {
        let sent = self.links[rank].try_send(Cmd::Params);
        let got = sent.and_then(|()| self.links[rank].try_recv());
        match got {
            Ok(Reply::Params(p)) => Ok(p),
            Ok(_) => unreachable!("protocol error: expected Params"),
            Err(e) => Err(self.classify(rank, e)),
        }
    }

    /// Every rank's raw optimizer-state frame, in rank order. The frame
    /// format is worker-private; see `checkpoint::canonical` for the
    /// world-agnostic form checkpoints store.
    pub fn export_frames(&self) -> Vec<Vec<u8>> {
        for link in &self.links {
            link.send(Cmd::ExportOpt);
        }
        self.links
            .iter()
            .map(|link| match link.recv() {
                Reply::OptState(bytes) => bytes,
                _ => unreachable!("protocol error: expected OptState"),
            })
            .collect()
    }

    /// One rank's raw optimizer-state frame.
    pub fn export_rank_frame(&self, rank: usize) -> Vec<u8> {
        self.links[rank].send(Cmd::ExportOpt);
        match self.links[rank].recv() {
            Reply::OptState(bytes) => bytes,
            _ => unreachable!("protocol error: expected OptState"),
        }
    }

    /// Restore every rank's optimizer state from per-rank frames (one per
    /// rank, in rank order). The first rank's error is reported when
    /// several fail.
    pub fn import_frames(&self, frames: Vec<Vec<u8>>) -> Result<(), String> {
        if frames.len() != self.world {
            return Err(format!(
                "need one optimizer-state frame per rank: got {}, world={}",
                frames.len(),
                self.world
            ));
        }
        for (link, frame) in self.links.iter().zip(frames) {
            link.send(Cmd::ImportOpt(frame));
        }
        let mut result = Ok(());
        for link in &self.links {
            match link.recv() {
                Reply::ImportDone(r) => {
                    if result.is_ok() {
                        result = r;
                    }
                }
                _ => unreachable!("protocol error: expected ImportDone"),
            }
        }
        result
    }

    /// Live per-rank byte counters, in rank order.
    pub fn memory_reports(&self) -> Vec<MemoryReport> {
        for link in &self.links {
            link.send(Cmd::Report);
        }
        self.links
            .iter()
            .map(|link| match link.recv() {
                Reply::Report(r) => r,
                _ => unreachable!("protocol error: expected Report"),
            })
            .collect()
    }
}

fn spawn_threads<W: Worker>(
    world: usize,
    metas: &[ParamMeta],
    spec: &OptimizerSpec,
    seed: u64,
    failure: &FailureCell,
) -> Vec<Link> {
    // Consume the step-crash plan ONCE per world spawn: the world spawned
    // after a recovery must not re-inject the same crash.
    let step_crash = process::take_step_crash();
    let comms = Comm::create_world(world);
    comms
        .into_iter()
        .enumerate()
        .map(|(rank, comm)| {
            let (ctx, crx) = channel::<Cmd>();
            let (rtx, rrx) = channel::<Reply>();
            let metas = metas.to_vec();
            let spec = spec.clone();
            let failure = failure.clone();
            let crash_at = step_crash.and_then(|(r, at)| (r == rank).then_some(at));
            let handle = std::thread::Builder::new()
                .name(format!("{}-worker-{rank}", W::MODE))
                .spawn(move || {
                    // This thread is one of `world` concurrent compute
                    // workers: nested GEMM/SVD kernels split the core
                    // budget instead of each resolving the full machine.
                    // The persistent pool is process-wide, so `world`
                    // ranks submitting width-(budget/world) regions keep
                    // total pool demand at ~one machine's worth.
                    crate::parallel::set_thread_share(world);
                    let mut w = W::new(rank, world, comm, metas, spec, seed);
                    // Ordering on the death path matters: record the cause
                    // FIRST, then drop `w` (poisoning the barrier wakes the
                    // victims), then let the channels close (what the
                    // coordinator blocks on). Every observer of the death
                    // finds the culprit already attributed.
                    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        serve(&mut w, &crx, &rtx, crash_at)
                    }));
                    if let Err(payload) = r {
                        record_failure(&failure, rank, panic_message(payload.as_ref()));
                    }
                    drop(w);
                })
                .unwrap_or_else(|e| panic!("spawning {} worker thread: {e}", W::MODE));
            Link::Thread {
                tx: ctx,
                rx: rrx,
                handle: Some(handle),
            }
        })
        .collect()
}

impl<W: Worker> Drop for Cluster<W> {
    fn drop(&mut self) {
        for link in &self.links {
            link.send_shutdown_quietly();
        }
        let panicking = std::thread::panicking();
        for link in &mut self.links {
            match link {
                Link::Thread { handle, .. } => {
                    // ALWAYS join, even when a worker died: the transport's
                    // barrier poisons on worker drop (`dist/comm.rs`), so a
                    // dead rank's peers panic out of their collective
                    // instead of blocking forever — joining cannot hang,
                    // and reaping here is what keeps repeated
                    // kill→recover cycles leak-free (PR 4 used to leak
                    // these threads on the panic path).
                    if let Some(h) = handle.take() {
                        let _ = h.join();
                    }
                }
                Link::Process { child, .. } => {
                    // Unlike threads, worker PROCESSES can always be
                    // reclaimed: on a coordinator panic, kill outright
                    // (their peers unblock when the relay drops the
                    // sockets), then reap the zombie either way.
                    if panicking {
                        let _ = child.kill();
                    }
                    let _ = child.wait();
                }
            }
        }
        // The relay exits once every worker's comm socket has closed —
        // which the shutdowns (or kills) above guarantee.
        if let Some(h) = self.relay.take() {
            let _ = h.join();
        }
        if let Some(path) = self.socket_path.take() {
            process::cleanup_socket(&path);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_bounds_partition_any_length_and_world() {
        for world in 1..=6 {
            for len in 0..=9 {
                let mut covered = 0;
                for rank in 0..world {
                    let (lo, hi) = shard_bounds(len, world, rank);
                    assert!(lo <= hi, "len={len} world={world} rank={rank}");
                    assert_eq!(lo, covered, "gap at len={len} world={world} rank={rank}");
                    covered = hi;
                }
                assert_eq!(covered, len, "len={len} world={world} not covered");
            }
        }
    }

    #[test]
    fn slice_and_assemble_roundtrip_including_empty_shards() {
        use crate::util::rng::Pcg64;
        let mut rng = Pcg64::new(9, 0);
        // (1, 3) at world 4 gives rank 0 an empty shard; (5, 2) shards rows.
        for (rows, cols) in [(1usize, 3usize), (5, 2), (4, 4), (3, 7)] {
            let meta = ParamMeta {
                name: format!("p{rows}x{cols}"),
                rows,
                cols,
            };
            let full = Matrix::randn(rows, cols, 1.0, &mut rng);
            for world in [1usize, 2, 3, 4, 5] {
                let axis = shard_axis(rows, cols);
                let len = match axis {
                    ShardAxis::Rows => rows,
                    ShardAxis::Cols => cols,
                };
                let shards: Vec<Matrix> = (0..world)
                    .map(|r| {
                        let (lo, hi) = shard_bounds(len, world, r);
                        slice_shard(&full, axis, lo, hi)
                    })
                    .collect();
                let views: Vec<&Matrix> = shards.iter().collect();
                let back = assemble(&meta, &views);
                assert_eq!(
                    back.data, full.data,
                    "{rows}x{cols} world={world}: slice/assemble lost data"
                );
            }
        }
    }

    #[test]
    fn transport_kind_parses_and_rejects() {
        assert_eq!(
            TransportKind::parse("threads").unwrap(),
            TransportKind::Threads
        );
        assert_eq!(
            TransportKind::parse("process").unwrap(),
            TransportKind::Process
        );
        assert_eq!(TransportKind::Threads.name(), "threads");
        assert_eq!(TransportKind::Process.name(), "process");
        let err = TransportKind::parse("tcp").unwrap_err();
        assert!(err.contains("threads|process"), "unhelpful error: {err}");
    }
}
