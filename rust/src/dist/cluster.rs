//! The generic worker cluster: persistent threads behind one shared
//! command protocol.
//!
//! Both distributed modes — FSDP (sharded state, `dist/fsdp.rs`) and DDP
//! (replicated state, `dist/ddp.rs`) — are worlds of persistent OS threads
//! driven in lockstep by the coordinator. Everything mode-*independent*
//! lives here, written once:
//!
//! * the [`Cmd`]/[`Reply`] channel protocol and the serve loop,
//! * the spawn path (per-rank [`Comm`] handles, thread naming, the
//!   [`crate::parallel::set_thread_share`] core-budget split),
//! * coordinator-side shape validation (a worker panicking mid-collective
//!   would strand its peers inside a barrier, so bad inputs are rejected
//!   *before* any `Cmd` is sent),
//! * the panic-aware, barrier-safe [`Drop`].
//!
//! A mode is one [`Worker`] implementation: what a rank stores (shards vs
//! a replica), how a step consumes gradients, and what its state blob
//! contains. `Cluster<FsdpWorker>` and `Cluster<DdpWorker>` are the two
//! instantiations; protocol fixes land here and cannot drift between them.

use super::comm::Comm;
use super::OptimizerSpec;
use crate::tensor::Matrix;
use std::marker::PhantomData;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

/// Shape metadata for one trainable parameter (from the manifest).
#[derive(Clone, Debug)]
pub struct ParamMeta {
    pub name: String,
    pub rows: usize,
    pub cols: usize,
}

/// Per-rank ("per-GPU") byte counters — the live validation of the Table 1
/// memory model.
#[derive(Clone, Copy, Debug, Default)]
pub struct MemoryReport {
    pub rank: usize,
    /// Bytes of parameter shards resident on this rank.
    pub param_shard_bytes: usize,
    /// Bytes of optimizer state (sharded moments + replicated projectors).
    pub optimizer_bytes: usize,
    /// Peak bytes of transient buffers (reduced gradients, broadcast P)
    /// live at once — bounded by ~one full layer gradient, not the model.
    pub peak_transient_bytes: usize,
    /// f32 elements moved through collectives by this rank.
    pub traffic_elems: u64,
}

/// Which dimension a parameter is sharded along (always the *longer* one —
/// exactly the dimension the GaLore projector does not span, so a
/// leader-computed P applies unchanged to every shard).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum ShardAxis {
    Rows,
    Cols,
}

pub(crate) fn shard_axis(rows: usize, cols: usize) -> ShardAxis {
    if rows > cols {
        ShardAxis::Rows
    } else {
        ShardAxis::Cols
    }
}

/// Balanced contiguous split of `len` across `world`: rank r owns
/// [r·len/world, (r+1)·len/world). Ranks may own empty ranges when
/// `len < world` (layers narrower than the world size).
pub(crate) fn shard_bounds(len: usize, world: usize, rank: usize) -> (usize, usize) {
    (rank * len / world, (rank + 1) * len / world)
}

/// Extract a shard (row range or column range) from a full matrix.
pub(crate) fn slice_shard(full: &Matrix, axis: ShardAxis, lo: usize, hi: usize) -> Matrix {
    match axis {
        ShardAxis::Rows => Matrix::from_vec(
            hi - lo,
            full.cols,
            full.data[lo * full.cols..hi * full.cols].to_vec(),
        ),
        ShardAxis::Cols => {
            let mut out = Matrix::zeros(full.rows, hi - lo);
            for r in 0..full.rows {
                out.row_mut(r).copy_from_slice(&full.row(r)[lo..hi]);
            }
            out
        }
    }
}

/// Reassemble a full parameter from per-rank shards (in rank order).
pub(crate) fn assemble(meta: &ParamMeta, shards: &[&Matrix]) -> Matrix {
    let (m, n) = (meta.rows, meta.cols);
    match shard_axis(m, n) {
        ShardAxis::Rows => {
            let mut data = Vec::with_capacity(m * n);
            for s in shards {
                assert_eq!(s.cols, n, "{}: shard col mismatch", meta.name);
                data.extend_from_slice(&s.data);
            }
            Matrix::from_vec(m, n, data)
        }
        ShardAxis::Cols => {
            let mut out = Matrix::zeros(m, n);
            let mut c0 = 0;
            for s in shards {
                assert_eq!(s.rows, m, "{}: shard row mismatch", meta.name);
                for r in 0..m {
                    out.row_mut(r)[c0..c0 + s.cols].copy_from_slice(s.row(r));
                }
                c0 += s.cols;
            }
            assert_eq!(c0, n, "{}: shards do not cover all columns", meta.name);
            out
        }
    }
}

/// One rank's behavior: what it stores and how it consumes a step. The
/// generic [`Cluster`] owns everything else (protocol, spawn, shutdown).
///
/// Not `Send`-bounded on purpose: workers are CONSTRUCTED inside their
/// own thread from the `Send`-able spec (built optimizers hold
/// deliberately non-`Send` state) and never cross threads afterwards.
pub trait Worker: 'static {
    /// Mode tag ("fsdp" | "ddp") — thread names and diagnostics.
    const MODE: &'static str;

    /// Construct this rank's state. Runs *inside* the worker thread; the
    /// optimizer is built locally from the `Send`-able spec.
    fn new(
        rank: usize,
        world: usize,
        comm: Comm,
        metas: Vec<ParamMeta>,
        spec: OptimizerSpec,
        seed: u64,
    ) -> Self;

    /// Install initial full parameters (keep shards or the whole replica).
    fn install(&mut self, full: Vec<Matrix>);

    /// One training step given this rank's microbatch gradients (full,
    /// unsharded shapes); collectives rendezvous with peer ranks inside.
    fn step(&mut self, t: u64, lr: f32, grads: Vec<Matrix>);

    /// This rank's parameter view (its shards under FSDP, the full replica
    /// under DDP).
    fn params(&self) -> Vec<Matrix>;

    /// This rank's serialized optimizer-state frame (mode-private format).
    fn export_state(&self) -> Vec<u8>;

    /// Restore this rank's state from an `export_state` frame.
    fn import_state(&mut self, bytes: &[u8]) -> Result<(), String>;

    fn report(&self) -> MemoryReport;
}

enum Cmd {
    /// Install the initial full parameters.
    Init(Vec<Matrix>),
    /// One training step: this worker's microbatch gradients (full shapes).
    Step { t: u64, lr: f32, grads: Vec<Matrix> },
    Params,
    ExportOpt,
    ImportOpt(Vec<u8>),
    Report,
    Shutdown,
}

enum Reply {
    StepDone,
    Params(Vec<Matrix>),
    OptState(Vec<u8>),
    ImportDone(Result<(), String>),
    Report(MemoryReport),
}

fn serve<W: Worker>(w: &mut W, rx: Receiver<Cmd>, tx: Sender<Reply>) {
    loop {
        match rx.recv() {
            Ok(Cmd::Init(full)) => w.install(full),
            Ok(Cmd::Step { t, lr, grads }) => {
                w.step(t, lr, grads);
                let _ = tx.send(Reply::StepDone);
            }
            Ok(Cmd::Params) => {
                let _ = tx.send(Reply::Params(w.params()));
            }
            Ok(Cmd::ExportOpt) => {
                let _ = tx.send(Reply::OptState(w.export_state()));
            }
            Ok(Cmd::ImportOpt(bytes)) => {
                let r = w.import_state(&bytes);
                let _ = tx.send(Reply::ImportDone(r));
            }
            Ok(Cmd::Report) => {
                let _ = tx.send(Reply::Report(w.report()));
            }
            Ok(Cmd::Shutdown) | Err(_) => break,
        }
    }
}

/// A world of persistent worker threads, one per rank, driven in lockstep
/// through channels. `W` decides what each rank stores (see [`Worker`]).
pub struct Cluster<W: Worker> {
    world: usize,
    metas: Vec<ParamMeta>,
    cmd_tx: Vec<Sender<Cmd>>,
    reply_rx: Vec<Receiver<Reply>>,
    handles: Vec<JoinHandle<()>>,
    spec_name: &'static str,
    _mode: PhantomData<fn() -> W>,
}

impl<W: Worker> Cluster<W> {
    pub fn new(world: usize, metas: Vec<ParamMeta>, spec: OptimizerSpec, seed: u64) -> Cluster<W> {
        assert!(world >= 1, "world size must be >= 1");
        assert!(
            spec.distributed_ok(),
            "{} cannot run on distributed workers",
            spec.name()
        );
        let spec_name = spec.name();
        let comms = Comm::create_world(world);
        let mut cmd_tx = Vec::with_capacity(world);
        let mut reply_rx = Vec::with_capacity(world);
        let mut handles = Vec::with_capacity(world);
        for (rank, comm) in comms.into_iter().enumerate() {
            let (ctx, crx) = channel::<Cmd>();
            let (rtx, rrx) = channel::<Reply>();
            let metas = metas.clone();
            let spec = spec.clone();
            let handle = std::thread::Builder::new()
                .name(format!("{}-worker-{rank}", W::MODE))
                .spawn(move || {
                    // This thread is one of `world` concurrent compute
                    // workers: nested GEMM/SVD kernels split the core
                    // budget instead of each resolving the full machine.
                    crate::parallel::set_thread_share(world);
                    let mut w = W::new(rank, world, comm, metas, spec, seed);
                    serve(&mut w, crx, rtx);
                })
                .unwrap_or_else(|e| panic!("spawning {} worker thread: {e}", W::MODE));
            cmd_tx.push(ctx);
            reply_rx.push(rrx);
            handles.push(handle);
        }
        Cluster {
            world,
            metas,
            cmd_tx,
            reply_rx,
            handles,
            spec_name,
            _mode: PhantomData,
        }
    }

    pub fn world(&self) -> usize {
        self.world
    }

    pub fn optimizer_name(&self) -> &'static str {
        self.spec_name
    }

    /// Full parameter shapes, in parameter order.
    pub fn metas(&self) -> &[ParamMeta] {
        &self.metas
    }

    /// Distribute initial full parameters to every worker (channel ordering
    /// serializes this before any later step). Shapes are validated HERE —
    /// a worker panicking later would strand its peers in a collective.
    pub fn init_params(&self, full: &[Matrix]) {
        assert_eq!(full.len(), self.metas.len(), "param count != meta count");
        for (p, meta) in full.iter().zip(&self.metas) {
            assert_eq!(
                p.shape(),
                (meta.rows, meta.cols),
                "{}: param/meta shape mismatch",
                meta.name
            );
        }
        for tx in &self.cmd_tx {
            tx.send(Cmd::Init(full.to_vec())).expect("worker alive");
        }
    }

    /// One synchronous training step. `per_rank[r]` holds rank r's
    /// microbatch gradients in full (unsharded) shapes. Blocks until all
    /// ranks finish.
    pub fn step(&mut self, t: u64, per_rank: Vec<Vec<Matrix>>, lr: f32) {
        assert_eq!(per_rank.len(), self.world, "need one gradient set per rank");
        // Validate shapes HERE, not in the workers: a worker panicking
        // between barrier waves would strand its peers in the collective.
        for (rank, grads) in per_rank.iter().enumerate() {
            assert_eq!(grads.len(), self.metas.len(), "rank {rank}: grad count");
            for (g, meta) in grads.iter().zip(&self.metas) {
                assert_eq!(
                    g.shape(),
                    (meta.rows, meta.cols),
                    "rank {rank}, {}: bad gradient shape",
                    meta.name
                );
            }
        }
        for (tx, grads) in self.cmd_tx.iter().zip(per_rank) {
            tx.send(Cmd::Step { t, lr, grads }).expect("worker alive");
        }
        for rx in &self.reply_rx {
            match rx.recv().expect("worker alive") {
                Reply::StepDone => {}
                _ => unreachable!("protocol error: expected StepDone"),
            }
        }
    }

    /// Every rank's parameter view, in rank order (shards under FSDP, full
    /// replicas under DDP).
    pub fn params_per_rank(&self) -> Vec<Vec<Matrix>> {
        for tx in &self.cmd_tx {
            tx.send(Cmd::Params).expect("worker alive");
        }
        self.reply_rx
            .iter()
            .map(|rx| match rx.recv().expect("worker alive") {
                Reply::Params(p) => p,
                _ => unreachable!("protocol error: expected Params"),
            })
            .collect()
    }

    /// One rank's parameter view.
    pub fn rank_params(&self, rank: usize) -> Vec<Matrix> {
        self.cmd_tx[rank].send(Cmd::Params).expect("worker alive");
        match self.reply_rx[rank].recv().expect("worker alive") {
            Reply::Params(p) => p,
            _ => unreachable!("protocol error: expected Params"),
        }
    }

    /// Every rank's raw optimizer-state frame, in rank order. The frame
    /// format is worker-private; see `checkpoint::canonical` for the
    /// world-agnostic form checkpoints store.
    pub fn export_frames(&self) -> Vec<Vec<u8>> {
        for tx in &self.cmd_tx {
            tx.send(Cmd::ExportOpt).expect("worker alive");
        }
        self.reply_rx
            .iter()
            .map(|rx| match rx.recv().expect("worker alive") {
                Reply::OptState(bytes) => bytes,
                _ => unreachable!("protocol error: expected OptState"),
            })
            .collect()
    }

    /// One rank's raw optimizer-state frame.
    pub fn export_rank_frame(&self, rank: usize) -> Vec<u8> {
        self.cmd_tx[rank].send(Cmd::ExportOpt).expect("worker alive");
        match self.reply_rx[rank].recv().expect("worker alive") {
            Reply::OptState(bytes) => bytes,
            _ => unreachable!("protocol error: expected OptState"),
        }
    }

    /// Restore every rank's optimizer state from per-rank frames (one per
    /// rank, in rank order). The first rank's error is reported when
    /// several fail.
    pub fn import_frames(&self, frames: Vec<Vec<u8>>) -> Result<(), String> {
        if frames.len() != self.world {
            return Err(format!(
                "need one optimizer-state frame per rank: got {}, world={}",
                frames.len(),
                self.world
            ));
        }
        for (tx, frame) in self.cmd_tx.iter().zip(frames) {
            tx.send(Cmd::ImportOpt(frame)).expect("worker alive");
        }
        let mut result = Ok(());
        for rx in &self.reply_rx {
            match rx.recv().expect("worker alive") {
                Reply::ImportDone(r) => {
                    if result.is_ok() {
                        result = r;
                    }
                }
                _ => unreachable!("protocol error: expected ImportDone"),
            }
        }
        result
    }

    /// Live per-rank byte counters, in rank order.
    pub fn memory_reports(&self) -> Vec<MemoryReport> {
        for tx in &self.cmd_tx {
            tx.send(Cmd::Report).expect("worker alive");
        }
        self.reply_rx
            .iter()
            .map(|rx| match rx.recv().expect("worker alive") {
                Reply::Report(r) => r,
                _ => unreachable!("protocol error: expected Report"),
            })
            .collect()
    }
}

impl<W: Worker> Drop for Cluster<W> {
    fn drop(&mut self) {
        for tx in &self.cmd_tx {
            let _ = tx.send(Cmd::Shutdown);
        }
        if std::thread::panicking() {
            // A dead worker strands its peers inside a Barrier (std
            // barriers don't poison); joining them here would turn the
            // panic into a permanent hang. Leak the threads and let the
            // panic surface as a diagnostic instead.
            return;
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_bounds_partition_any_length_and_world() {
        for world in 1..=6 {
            for len in 0..=9 {
                let mut covered = 0;
                for rank in 0..world {
                    let (lo, hi) = shard_bounds(len, world, rank);
                    assert!(lo <= hi, "len={len} world={world} rank={rank}");
                    assert_eq!(lo, covered, "gap at len={len} world={world} rank={rank}");
                    covered = hi;
                }
                assert_eq!(covered, len, "len={len} world={world} not covered");
            }
        }
    }

    #[test]
    fn slice_and_assemble_roundtrip_including_empty_shards() {
        use crate::util::rng::Pcg64;
        let mut rng = Pcg64::new(9, 0);
        // (1, 3) at world 4 gives rank 0 an empty shard; (5, 2) shards rows.
        for (rows, cols) in [(1usize, 3usize), (5, 2), (4, 4), (3, 7)] {
            let meta = ParamMeta {
                name: format!("p{rows}x{cols}"),
                rows,
                cols,
            };
            let full = Matrix::randn(rows, cols, 1.0, &mut rng);
            for world in [1usize, 2, 3, 4, 5] {
                let axis = shard_axis(rows, cols);
                let len = match axis {
                    ShardAxis::Rows => rows,
                    ShardAxis::Cols => cols,
                };
                let shards: Vec<Matrix> = (0..world)
                    .map(|r| {
                        let (lo, hi) = shard_bounds(len, world, r);
                        slice_shard(&full, axis, lo, hi)
                    })
                    .collect();
                let views: Vec<&Matrix> = shards.iter().collect();
                let back = assemble(&meta, &views);
                assert_eq!(
                    back.data, full.data,
                    "{rows}x{cols} world={world}: slice/assemble lost data"
                );
            }
        }
    }
}
