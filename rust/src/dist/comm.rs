//! Collectives for the FSDP/DDP runtime, generic over a [`Transport`].
//!
//! One [`Comm`] handle per worker (thread or OS process). The collective
//! *math* lives here and is transport-independent: every collective is one
//! [`Transport::exchange`] rendezvous in which each rank deposits its
//! contribution and then computes its result from the full slot table
//! (every rank's contribution, in rank order).
//!
//! Reductions combine rank contributions in a **fixed binary-tree order**
//! ((r0+r1)+(r2+r3))+…, so the result is bitwise identical on every rank
//! and independent of scheduling — the determinism contract stated in
//! `util/rng.rs`. Because the tree runs over the same slot table on every
//! transport, a process-transport run is bitwise identical to a threaded
//! one by construction (pinned in `tests/transport.rs`).
//!
//! Transports:
//! * [`ThreadTransport`] — in-process shared slots + a reusable barrier
//!   (two barrier waves per exchange: deposit, read, release).
//! * `ProcessTransport` (`dist/process.rs`) — length-framed messages over
//!   Unix-domain sockets, relayed through the coordinator process.
//!
//! Per-rank traffic counters model ring-collective costs (all-reduce
//! 2·(w−1)/w·n, reduce-scatter/all-gather (w−1)/w·n) for the Table 1 byte
//! accounting; they count the modeled wire cost, not the bytes a
//! particular transport happens to move.

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};

/// A rendezvous fabric connecting the ranks of one world.
///
/// `exchange` is the single collective primitive: deposit this rank's
/// contribution, wait for every peer's, and run `reduce` over the full
/// slot table (index = rank). All ranks must call the same sequence of
/// exchanges with compatible payloads — exactly the discipline the
/// lockstep worker protocol (`dist/cluster.rs`) already enforces.
pub trait Transport: Send {
    fn rank(&self) -> usize;

    fn world(&self) -> usize;

    /// Collective rendezvous. `reduce` sees every rank's contribution in
    /// rank order; its return value becomes this rank's result. The slot
    /// table may be reused afterwards — `reduce` must copy what it keeps.
    ///
    /// `need` is a per-rank *delivery hint*: `Some((lo, hi))` promises
    /// that this rank's `reduce` only reads elements `[lo, hi)` of every
    /// contribution, so the transport may deliver just that subrange —
    /// the slices handed to `reduce` are then the `[lo, hi)` windows
    /// (length `hi − lo`), re-indexed from 0. `None` delivers the full
    /// contributions. Purely an optimization: the *elements* any reduce
    /// reads, and the order it combines them in, are identical either
    /// way, so results stay bitwise independent of the hint. The process
    /// transport uses it to ship reduce-scatter replies at the ring-model
    /// byte cost instead of the full w·n slot table.
    fn exchange(
        &mut self,
        data: Vec<f32>,
        need: Option<(usize, usize)>,
        reduce: &mut dyn FnMut(&[&[f32]]) -> Vec<f32>,
    ) -> Vec<f32>;

    /// Pure synchronization point: returns once every rank has entered.
    fn barrier(&mut self);
}

/// A reusable barrier that — unlike `std::sync::Barrier` — can be
/// **poisoned** by a departing rank. A worker that panics mid-collective
/// drops its [`ThreadTransport`], which poisons the barrier and wakes
/// every peer parked inside `wait`; they see `Err(departed_rank)` instead
/// of blocking forever. This is the primitive that turns a thread-mode
/// worker death from a permanent hang into a prompt, attributable
/// failure (`dist/cluster.rs` records it; `train/supervisor.rs` recovers
/// from it).
struct PoisonBarrier {
    state: Mutex<BarrierState>,
    cvar: Condvar,
}

struct BarrierState {
    /// Ranks parked in the current generation.
    waiting: usize,
    /// Incremented each time a full generation releases.
    generation: u64,
    /// First rank that departed (dropped its transport); sticky.
    departed: Option<usize>,
}

impl PoisonBarrier {
    fn new() -> PoisonBarrier {
        PoisonBarrier {
            state: Mutex::new(BarrierState {
                waiting: 0,
                generation: 0,
                departed: None,
            }),
            cvar: Condvar::new(),
        }
    }

    /// Park until all `world` ranks arrive. `Err(rank)` if any rank
    /// departed (before or during the wait) — the barrier can never
    /// complete again once poisoned.
    fn wait(&self, world: usize) -> Result<(), usize> {
        // Poison-tolerant locking (here and below): a worker that panics
        // while holding the state mutex poisons it, but BarrierState is
        // always internally consistent (single-field mutations), and the
        // departing rank separately poisons the *barrier* via Drop. An
        // `unwrap()` here would escalate a recoverable peer death into
        // this rank's own panic.
        let mut s = self.state.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(r) = s.departed {
            return Err(r);
        }
        s.waiting += 1;
        if s.waiting == world {
            s.waiting = 0;
            s.generation += 1;
            self.cvar.notify_all();
            return Ok(());
        }
        let gen = s.generation;
        while s.generation == gen && s.departed.is_none() {
            s = self.cvar.wait(s).unwrap_or_else(|e| e.into_inner());
        }
        match s.departed {
            // Departure wins even on a race with a release: a poisoned
            // group is tearing down either way.
            Some(r) if s.generation == gen => Err(r),
            _ => Ok(()),
        }
    }

    /// Mark `rank` as departed (first departure wins) and wake all
    /// waiters. Called from [`ThreadTransport`]'s `Drop` — on clean
    /// shutdown nobody is waiting and this is a no-op in effect.
    fn poison(&self, rank: usize) {
        // Runs from Drop, possibly DURING a panic unwind: recovering a
        // poisoned mutex here is mandatory — an `unwrap()` panic inside
        // Drop-under-unwind would abort the whole process.
        let mut s = self.state.lock().unwrap_or_else(|e| e.into_inner());
        if s.departed.is_none() {
            s.departed = Some(rank);
        }
        self.cvar.notify_all();
    }
}

struct Shared {
    world: usize,
    /// RwLock, not Mutex: the barrier waves already separate the write
    /// phase (each rank deposits its own slot) from the read phase, so
    /// ranks compute their reductions concurrently under read locks.
    slots: RwLock<Vec<Vec<f32>>>,
    barrier: PoisonBarrier,
}

/// In-process transport: all handles of a world share a slot table + a
/// reusable barrier via `Arc`. Each exchange is two barrier waves:
///
///   1. each rank deposits its contribution into its own slot,
///   2. (barrier) every rank computes its result from the slot table,
///   3. (barrier) slots may be overwritten by the next exchange.
pub struct ThreadTransport {
    rank: usize,
    shared: Arc<Shared>,
}

impl ThreadTransport {
    /// Create a world of `world` connected transports, one per rank.
    pub fn create_world(world: usize) -> Vec<ThreadTransport> {
        assert!(world >= 1, "world size must be >= 1");
        let shared = Arc::new(Shared {
            world,
            slots: RwLock::new(vec![Vec::new(); world]),
            barrier: PoisonBarrier::new(),
        });
        (0..world)
            .map(|rank| ThreadTransport {
                rank,
                shared: shared.clone(),
            })
            .collect()
    }

    /// Barrier wave that converts a peer's departure into a prompt,
    /// attributable panic (which exits this worker thread) instead of a
    /// permanent hang.
    fn wait_or_die(&self) {
        if let Err(dead) = self.shared.barrier.wait(self.shared.world) {
            // lint: allow(no-panic-dist): this panic IS the thread-mode death signal — serve()'s catch_unwind records it into FailureCell
            panic!(
                "rank {}: peer rank {dead} died mid-collective",
                self.rank
            );
        }
    }
}

impl Drop for ThreadTransport {
    fn drop(&mut self) {
        // A departing rank (panic unwind or clean shutdown) poisons the
        // barrier so peers parked in a collective wake and fail instead
        // of hanging — the lockstep protocol guarantees nobody is waiting
        // when a CLEAN shutdown drops its transport.
        self.shared.barrier.poison(self.rank);
    }
}

impl Transport for ThreadTransport {
    fn rank(&self) -> usize {
        self.rank
    }

    fn world(&self) -> usize {
        self.shared.world
    }

    fn exchange(
        &mut self,
        data: Vec<f32>,
        need: Option<(usize, usize)>,
        reduce: &mut dyn FnMut(&[&[f32]]) -> Vec<f32>,
    ) -> Vec<f32> {
        // Poison-tolerant for the same reason as PoisonBarrier: slot
        // writes are rank-disjoint, so a peer's panic never leaves OUR
        // slot half-written, and the barrier (not the lock) carries the
        // departure signal.
        // lint: allow(no-panic-dist): rank < world is asserted at construction; slots is sized to world
        self.shared.slots.write().unwrap_or_else(|e| e.into_inner())[self.rank] = data;
        self.wait_or_die();
        let result = {
            let slots = self.shared.slots.read().unwrap_or_else(|e| e.into_inner());
            let views: Vec<&[f32]> = match need {
                // lint: allow(no-panic-dist): ranged exchanges are issued in lockstep with equal-length deposits — Comm asserts offsets cover the vector before issuing
                Some((lo, hi)) => slots.iter().map(|s| &s[lo..hi]).collect(),
                None => slots.iter().map(|s| s.as_slice()).collect(),
            };
            reduce(&views)
        };
        // Second barrier wave: after this, slots may be overwritten.
        self.wait_or_die();
        result
    }

    fn barrier(&mut self) {
        self.wait_or_die();
    }
}

/// One reified collective request — the unit `dist/pipeline.rs` queues so
/// a dedicated comm thread can run layer k+1's exchange while the worker
/// consumes layer k's result. Running a `Collective` through [`Comm::run`]
/// performs exactly the call the matching `Comm` method would, so queuing
/// changes WHEN a collective executes, never WHAT it computes.
pub(crate) enum Collective {
    AllReduceSum(Vec<f32>),
    ReduceScatterSum(Vec<f32>, Vec<usize>),
    AllGather(Vec<f32>),
    Broadcast(usize, Option<Vec<f32>>),
}

/// A worker's handle onto the collective group. Cheap to move into its
/// owning thread/process; the collective algorithms (fixed-tree sums,
/// rank-order concatenation) are identical across transports.
pub struct Comm {
    rank: usize,
    world: usize,
    /// Interior mutability keeps the collectives `&self` (the worker step
    /// loop borrows its shards mutably alongside the comm handle); a Comm
    /// is owned by exactly one worker and never shared by reference.
    transport: RefCell<Box<dyn Transport>>,
    /// Elements moved per rank (ring-collective cost model). Shared
    /// (`Arc`) so a worker can keep reading its counters after handing
    /// the Comm itself to a pipeline comm thread ([`Comm::traffic_probe`]).
    traffic: Arc<AtomicU64>,
}

impl Comm {
    /// Create an in-process (threaded) world of `world` connected handles,
    /// one per rank.
    pub fn create_world(world: usize) -> Vec<Comm> {
        ThreadTransport::create_world(world)
            .into_iter()
            .map(|t| Comm::from_transport(Box::new(t)))
            .collect()
    }

    /// Wrap an already-connected transport endpoint (the process-transport
    /// worker path).
    pub fn from_transport(transport: Box<dyn Transport>) -> Comm {
        let (rank, world) = (transport.rank(), transport.world());
        assert!(world >= 1, "world size must be >= 1");
        assert!(rank < world, "rank {rank} outside world {world}");
        Comm {
            rank,
            world,
            transport: RefCell::new(transport),
            traffic: Arc::new(AtomicU64::new(0)),
        }
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn world(&self) -> usize {
        self.world
    }

    /// Elements this rank has moved through collectives so far.
    pub fn traffic_elems(&self) -> u64 {
        self.traffic.load(Ordering::Relaxed)
    }

    /// A handle onto the traffic counter that stays readable after the
    /// Comm moves into a pipeline comm thread. Reads are synchronized by
    /// the pipeline's result handoff (a worker only reports counters
    /// between steps, with the pipeline drained).
    pub(crate) fn traffic_probe(&self) -> Arc<AtomicU64> {
        Arc::clone(&self.traffic)
    }

    fn add_traffic(&self, elems: u64) {
        self.traffic.fetch_add(elems, Ordering::Relaxed);
    }

    /// Execute one reified collective request (the pipeline comm thread's
    /// single entry point). Dispatches to the exact method a serial caller
    /// would have invoked — traffic accounting and reduction order
    /// included.
    pub(crate) fn run(&self, c: Collective) -> Vec<f32> {
        match c {
            Collective::AllReduceSum(data) => self.all_reduce_sum(data),
            Collective::ReduceScatterSum(data, offsets) => {
                self.reduce_scatter_sum(data, &offsets)
            }
            Collective::AllGather(data) => self.all_gather(data),
            Collective::Broadcast(root, data) => self.broadcast(root, data),
        }
    }

    fn exchange(
        &self,
        data: Vec<f32>,
        need: Option<(usize, usize)>,
        reduce: &mut dyn FnMut(&[&[f32]]) -> Vec<f32>,
    ) -> Vec<f32> {
        self.transport.borrow_mut().exchange(data, need, reduce)
    }

    /// Elementwise sum of every rank's `data` in fixed tree order; all
    /// ranks receive the identical full-length result.
    pub fn all_reduce_sum(&self, data: Vec<f32>) -> Vec<f32> {
        let n = data.len();
        let w = self.world;
        let mut reduce = |slots: &[&[f32]]| {
            debug_assert!(slots.iter().all(|s| s.len() == n), "ragged all_reduce");
            tree_sum(slots)
        };
        let result = self.exchange(data, None, &mut reduce);
        self.add_traffic((2 * (w - 1) * n / w.max(1)) as u64);
        result
    }

    /// Sum across ranks, then return only this rank's shard. `offsets` has
    /// world+1 entries (element boundaries); rank r receives
    /// `[offsets[r], offsets[r+1])` of the reduced vector.
    ///
    /// Issued as a *ranged* exchange: the transport only has to deliver
    /// `[lo, hi)` of each contribution, so the tree sum runs directly over
    /// this rank's windows — same elements, same fixed combination order,
    /// bitwise identical to summing full vectors and slicing after.
    pub fn reduce_scatter_sum(&self, data: Vec<f32>, offsets: &[usize]) -> Vec<f32> {
        let n = data.len();
        let w = self.world;
        assert_eq!(offsets.len(), w + 1, "offsets must have world+1 entries");
        assert_eq!(offsets[w], n, "offsets must cover the full vector");
        let (lo, hi) = (offsets[self.rank], offsets[self.rank + 1]);
        assert!(lo <= hi && hi <= n, "offsets must be monotone within the vector");
        let mut reduce = |slots: &[&[f32]]| tree_sum(slots);
        let result = self.exchange(data, Some((lo, hi)), &mut reduce);
        self.add_traffic(((w - 1) * n / w.max(1)) as u64);
        result
    }

    /// Concatenate every rank's shard in rank order; all ranks receive the
    /// identical concatenation. Shards may have different lengths.
    pub fn all_gather(&self, shard: Vec<f32>) -> Vec<f32> {
        let own = shard.len();
        let mut concat = |slots: &[&[f32]]| {
            let total: usize = slots.iter().map(|s| s.len()).sum();
            let mut out = Vec::with_capacity(total);
            for s in slots.iter() {
                out.extend_from_slice(s);
            }
            out
        };
        let result = self.exchange(shard, None, &mut concat);
        self.add_traffic((result.len() - own) as u64);
        result
    }

    /// Replicate `root`'s vector to every rank. Exactly the root must pass
    /// `Some(data)`; every rank (including the root) receives a copy.
    pub fn broadcast(&self, root: usize, data: Option<Vec<f32>>) -> Vec<f32> {
        assert!(root < self.world);
        assert_eq!(
            data.is_some(),
            self.rank == root,
            "broadcast: exactly the root provides data"
        );
        let mut pick = |slots: &[&[f32]]| slots[root].to_vec();
        let result = self.exchange(data.unwrap_or_default(), None, &mut pick);
        if self.rank != root {
            self.add_traffic(result.len() as u64);
        }
        result
    }

    /// Pure synchronization point (used between training phases).
    pub fn barrier(&self) {
        self.transport.borrow_mut().barrier();
    }
}

/// Sum `slots[r]` over ranks r with a fixed stride-doubling tree:
/// pass 1 combines (0,1), (2,3), …; pass 2 combines (0,2), (4,6), …; and
/// so on. Every caller runs the identical FP operation sequence, so the
/// reduction is associativity-safe: bitwise reproducible regardless of
/// which rank computes first — and regardless of the transport that
/// delivered the slots.
fn tree_sum(slots: &[&[f32]]) -> Vec<f32> {
    let mut bufs: Vec<Vec<f32>> = slots.iter().map(|s| s.to_vec()).collect();
    let mut stride = 1;
    while stride < bufs.len() {
        let mut i = 0;
        while i + stride < bufs.len() {
            let (head, tail) = bufs.split_at_mut(i + stride);
            let dst = &mut head[i];
            let src = &tail[0];
            for (x, y) in dst.iter_mut().zip(src.iter()) {
                *x += *y;
            }
            i += stride * 2;
        }
        stride *= 2;
    }
    bufs.swap_remove(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Run `f(comm)` on every rank of a fresh world, collecting results in
    /// rank order.
    fn run_world<T: Send>(world: usize, f: impl Fn(Comm) -> T + Sync) -> Vec<T> {
        let comms = Comm::create_world(world);
        let f = &f;
        std::thread::scope(|s| {
            let handles: Vec<_> = comms
                .into_iter()
                .map(|c| s.spawn(move || f(c)))
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        })
    }

    #[test]
    fn all_reduce_sums_across_ranks() {
        let out = run_world(4, |c| {
            let data = vec![(c.rank() + 1) as f32; 8];
            c.all_reduce_sum(data)
        });
        // 1+2+3+4 = 10 on every rank.
        for r in &out {
            assert_eq!(r, &vec![10.0f32; 8]);
        }
    }

    #[test]
    fn all_reduce_repeatable_and_rank_identical() {
        // Irregular magnitudes so a different summation order would show.
        let gen = |rank: usize, i: usize| {
            ((rank * 37 + i) as f32).sin() * 1e3f32.powi((rank % 3) as i32 - 1)
        };
        let run = || {
            run_world(4, |c| {
                let data: Vec<f32> = (0..64).map(|i| gen(c.rank(), i)).collect();
                c.all_reduce_sum(data)
            })
        };
        let a = run();
        let b = run();
        for r in 1..4 {
            assert_eq!(a[0], a[r], "ranks disagree");
        }
        assert_eq!(a[0], b[0], "not reproducible across runs");
    }

    #[test]
    fn reduce_scatter_returns_own_summed_shard() {
        let out = run_world(4, |c| {
            let data: Vec<f32> = (0..8).map(|i| (i + c.rank() * 8) as f32).collect();
            let offsets: Vec<usize> = (0..=4).map(|i| i * 2).collect();
            c.reduce_scatter_sum(data, &offsets)
        });
        // Column sums: sum_r (i + 8r) = 4i + 48 for element i.
        for (rank, shard) in out.iter().enumerate() {
            let expect: Vec<f32> = (rank * 2..rank * 2 + 2).map(|i| (4 * i + 48) as f32).collect();
            assert_eq!(shard, &expect);
        }
    }

    #[test]
    fn all_gather_concatenates_in_rank_order() {
        let out = run_world(3, |c| {
            // Ragged shards: rank r contributes r+1 copies of r.
            let shard = vec![c.rank() as f32; c.rank() + 1];
            c.all_gather(shard)
        });
        let expect = vec![0.0, 1.0, 1.0, 2.0, 2.0, 2.0];
        for r in &out {
            assert_eq!(r, &expect);
        }
    }

    #[test]
    fn broadcast_replicates_root() {
        let out = run_world(4, |c| {
            let data = if c.rank() == 2 {
                Some(vec![7.0, 8.0, 9.0])
            } else {
                None
            };
            c.broadcast(2, data)
        });
        for r in &out {
            assert_eq!(r, &vec![7.0, 8.0, 9.0]);
        }
    }

    #[test]
    fn collectives_compose_over_multiple_rounds() {
        // Reuse the same world for a sequence of collectives (barrier
        // generations must line up).
        let out = run_world(2, |c| {
            let a = c.all_reduce_sum(vec![1.0; 4]);
            let g = c.all_gather(vec![c.rank() as f32]);
            let b = c.broadcast(0, if c.rank() == 0 { Some(a.clone()) } else { None });
            (a, g, b)
        });
        for (a, g, b) in &out {
            assert_eq!(a, &vec![2.0; 4]);
            assert_eq!(g, &vec![0.0, 1.0]);
            assert_eq!(b, &vec![2.0; 4]);
        }
    }

    #[test]
    fn traffic_counters_follow_ring_model() {
        let out = run_world(4, |c| {
            let _ = c.all_reduce_sum(vec![0.0; 100]);
            c.traffic_elems()
        });
        // 2·(4−1)/4·100 = 150 elements per rank.
        for t in out {
            assert_eq!(t, 150);
        }
    }

    #[test]
    fn non_power_of_two_worlds_reduce_exactly() {
        // Worlds 3 and 5 exercise the uneven tail of the stride-doubling
        // tree ((r0+r1)+(r2+r3))+r4. Integer-valued contributions make the
        // expected sums exact, so any dropped or double-counted rank shows.
        for world in [3usize, 5] {
            let out = run_world(world, |c| {
                let data: Vec<f32> = (0..6).map(|i| ((c.rank() + 1) * (i + 1)) as f32).collect();
                c.all_reduce_sum(data)
            });
            // sum_r (r+1)·(i+1) = (i+1)·world·(world+1)/2
            let s = (world * (world + 1) / 2) as f32;
            for r in &out {
                let expect: Vec<f32> = (0..6).map(|i| (i + 1) as f32 * s).collect();
                assert_eq!(r, &expect, "world {world} all_reduce wrong");
            }
        }
    }

    #[test]
    fn reduce_scatter_handles_empty_ranges() {
        // A layer narrower than the world: some ranks own zero elements.
        // offsets [0,0,1,2] at world 3 gives rank 0 an empty shard.
        let out = run_world(3, |c| {
            let data = vec![(c.rank() + 1) as f32; 2];
            c.reduce_scatter_sum(data, &[0, 0, 1, 2])
        });
        assert_eq!(out[0], Vec::<f32>::new(), "rank 0 shard must be empty");
        assert_eq!(out[1], vec![6.0]);
        assert_eq!(out[2], vec![6.0]);
    }

    #[test]
    fn zero_length_collectives_are_noops() {
        // Zero-length reduce inputs (empty layers / empty shards) must
        // round-trip without panicking and without counting traffic.
        let out = run_world(4, |c| {
            let a = c.all_reduce_sum(Vec::new());
            let s = c.reduce_scatter_sum(Vec::new(), &[0, 0, 0, 0, 0]);
            let g = c.all_gather(Vec::new());
            let b = c.broadcast(1, if c.rank() == 1 { Some(Vec::new()) } else { None });
            (a, s, g, b, c.traffic_elems())
        });
        for (a, s, g, b, traffic) in &out {
            assert!(a.is_empty() && s.is_empty() && g.is_empty() && b.is_empty());
            assert_eq!(*traffic, 0, "empty collectives must not count traffic");
        }
    }

    #[test]
    fn ragged_gather_with_empty_ranks() {
        // all_gather where some ranks contribute nothing (empty shards).
        let out = run_world(4, |c| {
            let shard = if c.rank() % 2 == 0 {
                Vec::new()
            } else {
                vec![c.rank() as f32]
            };
            c.all_gather(shard)
        });
        for r in &out {
            assert_eq!(r, &vec![1.0, 3.0]);
        }
    }

    #[test]
    fn world_of_one_is_identity() {
        let out = run_world(1, |c| {
            let a = c.all_reduce_sum(vec![3.0, 4.0]);
            let s = c.reduce_scatter_sum(vec![5.0, 6.0], &[0, 2]);
            let g = c.all_gather(vec![7.0]);
            (a, s, g)
        });
        assert_eq!(out[0].0, vec![3.0, 4.0]);
        assert_eq!(out[0].1, vec![5.0, 6.0]);
        assert_eq!(out[0].2, vec![7.0]);
    }
}
