//! Depth-2 per-layer collective pipeline: hide gradient traffic behind
//! compute.
//!
//! The serial FSDP/DDP step interleaves one collective and one optimizer
//! update per layer, so every rank idles for the full (w−1)/w·n transfer
//! of every layer. Layers are independent tensors, which makes the fix
//! purely a *scheduling* change: give each rank a dedicated comm thread
//! (the condvar park/unpark pattern of `parallel/pool.rs`) draining a
//! bounded FIFO of [`Collective`] requests, and let the worker issue
//! layer k+1's reduce while it consumes layer k's shard in `step_param`.
//!
//! ## Determinism
//!
//! The pipeline moves WHEN a collective executes, never WHAT it computes.
//! Requests run strictly FIFO on one thread per rank, each through the
//! exact `Comm` collective the serial schedule would have run, with the
//! fixed-tree reduction order within each layer untouched — so results
//! are bitwise identical to the serial schedule for every optimizer,
//! world size, and transport (tests/determinism.rs pins this end to end).
//! Queue depth [`DEPTH`] = 2 bounds the extra live gradient to one layer
//! (charged in `peak_transient` by the workers).
//!
//! ## Failure model
//!
//! A peer death surfaces inside the comm thread (poisoned barrier on the
//! thread transport, socket EOF on the process transport). The serve loop
//! catches it, parks the message in the shared state, and wakes the
//! worker, whose next `issue`/`wait` re-raises it — the same prompt named
//! death signal the serial path produces, never a hang. Dropping the
//! pipeline joins the comm thread: any in-flight exchange either
//! completes (healthy peers run the same deterministic issue schedule, so
//! they match every request a dead rank managed to issue) or dies
//! promptly once the peer's transport poisons/closes.

use super::cluster::panic_message;
use super::comm::{Collective, Comm};
use std::cell::{Cell, RefCell};
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::AtomicU64;
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock, RwLock};
use std::thread::JoinHandle;
// lint: allow(determinism): Instant is confined to monotonic_ns below — timing is observability-only, never control flow
use std::time::Instant;

/// Maximum collectives issued but not yet consumed. Two means layer k+1's
/// reduce is in flight while layer k's shard is consumed — more depth
/// buys nothing (the wire is already saturated) and costs a gradient
/// buffer per slot.
const DEPTH: usize = 2;

/// Nanoseconds since an arbitrary process-local origin. All step timing
/// (worker-blocked comm time, step wall time) reads this one clock, so
/// every `Instant` in the distributed runtime lives on these two lines.
pub(crate) fn monotonic_ns() -> u64 {
    // lint: allow(determinism): monotonic origin for observability-only step timing
    static START: OnceLock<Instant> = OnceLock::new();
    // lint: allow(determinism): timing feeds StepTimed events and benches, never control flow
    START.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

/// Process-wide overlap knob (`[dist] overlap` / `--overlap`, default
/// on). Thread-safe like `process::set_spawn_retries` — no `env::set_var`
/// involved; the process transport forwards it to worker processes via a
/// spawn-time environment variable instead.
pub fn set_overlap_enabled(enabled: bool) {
    *overlap_cell().write().unwrap() = enabled;
}

pub(crate) fn overlap_enabled() -> bool {
    *overlap_cell().read().unwrap()
}

fn overlap_cell() -> &'static RwLock<bool> {
    static OVERLAP: RwLock<bool> = RwLock::new(true);
    &OVERLAP
}

struct PipeState {
    requests: VecDeque<Collective>,
    results: VecDeque<Vec<f32>>,
    /// Issued but not yet consumed by [`CommPipeline::wait`] (counts both
    /// queued requests and finished-but-unclaimed results).
    in_flight: usize,
    shutdown: bool,
    /// First comm-thread death, re-raised on the worker thread.
    failed: Option<String>,
}

struct PipeShared {
    m: Mutex<PipeState>,
    /// Comm thread parks here for requests or shutdown.
    work: Condvar,
    /// Worker parks here for results, free depth, or failure.
    done: Condvar,
}

/// Poison-tolerant lock: a panic while holding the pipe mutex leaves the
/// queues consistent (every transition is a single push/pop).
fn lock(m: &Mutex<PipeState>) -> MutexGuard<'_, PipeState> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// One rank's comm thread plus the queue feeding it. The `Comm` moves
/// into the thread; the worker keeps only this handle.
struct CommPipeline {
    shared: Arc<PipeShared>,
    handle: Option<JoinHandle<()>>,
    rank: usize,
}

impl CommPipeline {
    fn spawn(comm: Comm) -> CommPipeline {
        let rank = comm.rank();
        let shared = Arc::new(PipeShared {
            m: Mutex::new(PipeState {
                requests: VecDeque::new(),
                results: VecDeque::new(),
                in_flight: 0,
                shutdown: false,
                failed: None,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let shared2 = Arc::clone(&shared);
        let handle = std::thread::Builder::new()
            .name(format!("g2-comm-{rank}"))
            .spawn(move || serve(comm, shared2))
            // Thread exhaustion at worker construction — before any
            // collective is in flight — is an ordinary fatal resource
            // error, reported through the same death-signal path.
            .unwrap_or_else(|e| panic!("rank {rank}: spawning comm thread failed: {e}"));
        CommPipeline {
            shared,
            handle: Some(handle),
            rank,
        }
    }

    /// Enqueue a collective; blocks while [`DEPTH`] requests are already
    /// outstanding (bounding extra live gradients to one layer).
    fn issue(&self, c: Collective) {
        let mut st = lock(&self.shared.m);
        loop {
            if let Some(msg) = &st.failed {
                let (msg, rank) = (msg.clone(), self.rank);
                drop(st);
                // lint: allow(no-panic-dist): re-raising the comm thread's death IS the death signal — cluster::serve catches it and records the rank into the FailureCell
                panic!("rank {rank}: comm pipeline failed: {msg}");
            }
            if st.in_flight < DEPTH {
                break;
            }
            st = self.shared.done.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        st.in_flight += 1;
        st.requests.push_back(c);
        drop(st);
        self.shared.work.notify_one();
    }

    /// Claim the oldest finished collective's result (strict FIFO with
    /// [`CommPipeline::issue`]); blocks until it lands or the comm thread
    /// reports a death.
    fn wait(&self) -> Vec<f32> {
        let mut st = lock(&self.shared.m);
        loop {
            if let Some(r) = st.results.pop_front() {
                st.in_flight -= 1;
                drop(st);
                // A depth slot freed: an issue blocked on DEPTH may go.
                self.shared.done.notify_all();
                return r;
            }
            if let Some(msg) = &st.failed {
                let (msg, rank) = (msg.clone(), self.rank);
                drop(st);
                // lint: allow(no-panic-dist): re-raising the comm thread's death IS the death signal — cluster::serve catches it and records the rank into the FailureCell
                panic!("rank {rank}: comm pipeline failed: {msg}");
            }
            st = self.shared.done.wait(st).unwrap_or_else(|e| e.into_inner());
        }
    }
}

impl Drop for CommPipeline {
    /// Joins the comm thread. In-flight requests finish first (peers run
    /// the same deterministic issue schedule, so every issued exchange
    /// gets matched — or dies promptly when a dead peer's transport
    /// poisons/closes); queued-but-unstarted requests are abandoned.
    fn drop(&mut self) {
        {
            let mut st = lock(&self.shared.m);
            st.shutdown = true;
        }
        self.shared.work.notify_all();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// The comm thread's whole life: pop a request, run it OUTSIDE the lock,
/// publish the result; on a caught collective panic (peer death), park
/// the message for the worker and exit. Dropping `comm` on exit releases
/// the transport (poisoning the thread-transport barrier / closing the
/// process-transport socket), which is what unblocks any peers still
/// inside a collective.
fn serve(comm: Comm, shared: Arc<PipeShared>) {
    loop {
        let req = {
            let mut st = lock(&shared.m);
            loop {
                if st.shutdown {
                    return;
                }
                if let Some(r) = st.requests.pop_front() {
                    break r;
                }
                st = shared.work.wait(st).unwrap_or_else(|e| e.into_inner());
            }
        };
        let result = catch_unwind(AssertUnwindSafe(|| comm.run(req)));
        let mut st = lock(&shared.m);
        match result {
            Ok(v) => {
                st.results.push_back(v);
                drop(st);
                shared.done.notify_all();
            }
            Err(payload) => {
                st.failed.get_or_insert(panic_message(payload.as_ref()));
                drop(st);
                shared.done.notify_all();
                return;
            }
        }
    }
}

/// The worker-facing issue/await surface over a `Comm`, in one of two
/// modes sharing one API so the FSDP/DDP step loops have a single
/// issue-ahead/consume-in-order shape:
///
/// * **Serial** (`--overlap false`, and the bitwise reference in tests):
///   `issue` runs the collective inline and buffers the result; `wait`
///   pops it. Exactly the pre-pipeline schedule.
/// * **Overlapped** (default): requests go to the rank's comm thread;
///   `issue` returns as soon as a depth slot is free.
///
/// Also accumulates *worker-blocked* communication time: serial mode
/// counts full collective latency, overlapped mode counts only the time
/// the worker actually stalled in `issue`/`wait` — i.e. the comm cost the
/// pipeline failed to hide, which is exactly the number the overlap
/// benches and `StepTimed` events want.
pub(crate) struct CommDriver {
    kind: DriverKind,
    comm_ns: Cell<u64>,
}

enum DriverKind {
    Serial {
        comm: Comm,
        ready: RefCell<VecDeque<Vec<f32>>>,
    },
    Overlapped {
        pipe: CommPipeline,
        rank: usize,
        world: usize,
        traffic: Arc<AtomicU64>,
    },
}

impl CommDriver {
    pub(crate) fn new(comm: Comm, overlap: bool) -> CommDriver {
        let kind = if overlap && comm.world() > 1 {
            DriverKind::Overlapped {
                rank: comm.rank(),
                world: comm.world(),
                traffic: comm.traffic_probe(),
                pipe: CommPipeline::spawn(comm),
            }
        } else {
            DriverKind::Serial {
                comm,
                ready: RefCell::new(VecDeque::new()),
            }
        };
        CommDriver {
            kind,
            comm_ns: Cell::new(0),
        }
    }

    pub(crate) fn rank(&self) -> usize {
        match &self.kind {
            DriverKind::Serial { comm, .. } => comm.rank(),
            DriverKind::Overlapped { rank, .. } => *rank,
        }
    }

    pub(crate) fn world(&self) -> usize {
        match &self.kind {
            DriverKind::Serial { comm, .. } => comm.world(),
            DriverKind::Overlapped { world, .. } => *world,
        }
    }

    /// Elements moved through collectives so far (the modeled,
    /// transport-uniform counter — identical in both modes).
    pub(crate) fn traffic_elems(&self) -> u64 {
        match &self.kind {
            DriverKind::Serial { comm, .. } => comm.traffic_elems(),
            DriverKind::Overlapped { traffic, .. } => {
                traffic.load(std::sync::atomic::Ordering::Relaxed)
            }
        }
    }

    /// Submit the next collective of this rank's fixed per-step schedule.
    pub(crate) fn issue(&self, c: Collective) {
        let t0 = monotonic_ns();
        match &self.kind {
            DriverKind::Serial { comm, ready } => ready.borrow_mut().push_back(comm.run(c)),
            DriverKind::Overlapped { pipe, .. } => pipe.issue(c),
        }
        self.comm_ns.set(self.comm_ns.get() + (monotonic_ns() - t0));
    }

    /// Consume the oldest issued collective's result (strict FIFO).
    pub(crate) fn wait(&self) -> Vec<f32> {
        let t0 = monotonic_ns();
        let r = match &self.kind {
            DriverKind::Serial { ready, .. } => ready
                .borrow_mut()
                .pop_front()
                // lint: allow(no-panic-dist): wait-without-issue is a schedule bug on THIS rank, caught in tests — not a peer-death path
                .expect("CommDriver::wait called with nothing issued"),
            DriverKind::Overlapped { pipe, .. } => pipe.wait(),
        };
        self.comm_ns.set(self.comm_ns.get() + (monotonic_ns() - t0));
        r
    }

    /// Issue-and-wait in one call, for collectives that are ordering
    /// barriers in the step schedule anyway (the SVD-refresh subspace
    /// broadcast). Callers guarantee the queue is drained at this point,
    /// keeping the FIFO trivially aligned.
    pub(crate) fn run(&self, c: Collective) -> Vec<f32> {
        self.issue(c);
        self.wait()
    }

    /// Worker-blocked communication nanoseconds since the last call
    /// (read-and-reset; the workers call this once per step).
    pub(crate) fn take_comm_ns(&self) -> u64 {
        self.comm_ns.replace(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drivers(world: usize, overlap: bool) -> Vec<CommDriver> {
        Comm::create_world(world)
            .into_iter()
            .map(|c| CommDriver::new(c, overlap))
            .collect()
    }

    /// The pipeline is a scheduling change only: the workers' issue-one-
    /// ahead/consume-in-order loop gives bitwise the results of the serial
    /// inline schedule. (Issuing MORE than [`DEPTH`] ahead of the waits
    /// would block by design — the depth bound is what caps the extra
    /// live gradient at one layer.)
    #[test]
    fn pipelined_collectives_match_serial() {
        let layers: Vec<Vec<f32>> = (0..5)
            .map(|l| (0..8).map(|i| (l * 8 + i) as f32 * 0.37 + 0.1).collect())
            .collect();
        let run = |overlap: bool| -> Vec<Vec<Vec<f32>>> {
            let world = 2;
            let layers = layers.clone();
            std::thread::scope(|s| {
                let handles: Vec<_> = drivers(world, overlap)
                    .into_iter()
                    .map(|d| {
                        let layers = layers.clone();
                        s.spawn(move || {
                            let mk = |l: usize| {
                                let data: Vec<f32> =
                                    layers[l].iter().map(|x| x + d.rank() as f32).collect();
                                if l % 2 == 0 {
                                    Collective::AllReduceSum(data)
                                } else {
                                    Collective::ReduceScatterSum(data, vec![0, 3, 8])
                                }
                            };
                            // The production shape: layer l+1's reduce is
                            // issued before layer l's result is consumed.
                            d.issue(mk(0));
                            let mut out = Vec::with_capacity(layers.len());
                            for l in 0..layers.len() {
                                if l + 1 < layers.len() {
                                    d.issue(mk(l + 1));
                                }
                                out.push(d.wait());
                            }
                            out
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            })
        };
        let overlapped = run(true);
        let serial = run(false);
        for (rank, (o, s)) in overlapped.iter().zip(&serial).enumerate() {
            for (l, (a, b)) in o.iter().zip(s).enumerate() {
                let (a, b): (Vec<u32>, Vec<u32>) = (
                    a.iter().map(|x| x.to_bits()).collect(),
                    b.iter().map(|x| x.to_bits()).collect(),
                );
                assert_eq!(a, b, "rank {rank} layer {l}: overlap changed bits");
            }
        }
    }

    /// A peer dying mid-pipeline turns into a prompt named panic on the
    /// survivor's next wait — never a hang — and dropping the survivor's
    /// driver joins its comm thread cleanly.
    #[test]
    fn failed_peer_turns_into_prompt_error() {
        let mut ds = drivers(2, true);
        let survivor = ds.remove(0);
        let dead = ds.remove(0);
        // The peer issues nothing and dies: its Drop joins an idle comm
        // thread, and the released transport poisons the shared barrier.
        drop(dead);
        let err = std::panic::catch_unwind(AssertUnwindSafe(|| {
            survivor.issue(Collective::AllReduceSum(vec![1.0, 2.0]));
            survivor.wait()
        }))
        .expect_err("survivor must not succeed after peer death");
        let msg = panic_message(err.as_ref());
        assert!(
            msg.contains("comm pipeline failed"),
            "unattributed death: {msg}"
        );
        drop(survivor);
    }
}
