//! Process transport: worker ranks as OS processes over Unix-domain
//! sockets.
//!
//! Topology — a star with the coordinator at the hub. Each worker is a
//! self-exec of this binary (`galore2 worker --mode M --rank R --world W
//! --endpoint PATH`) holding two connections to the coordinator's
//! rendezvous socket:
//!
//! * a **control** connection carrying the framed [`Cmd`]/[`Reply`]
//!   cluster protocol (`dist/wire.rs`), driven by the coordinator, and
//! * a **comm** connection carrying collective payloads, serviced by a
//!   dedicated relay thread in the coordinator process: per exchange it
//!   reads one frame from every rank and writes the full slot table back
//!   to every rank. The worker-side [`ProcessTransport`] then runs the
//!   same fixed-tree reduction the threaded transport runs, so results
//!   are **bitwise identical** to `--transport threads`.
//!
//! Spawn handshake (deadline-bounded, child-exit aware — a worker that
//! dies or never connects is an error, not a hang):
//!
//!   1. coordinator binds PATH, spawns `world` workers;
//!   2. each worker connects twice, prefacing each connection with a
//!      9-byte hello `[kind u8][rank u64]`;
//!   3. coordinator sends each worker its setup frame (parameter metas +
//!      [`OptimizerSpec`] + seed) on the control connection;
//!   4. each worker builds its [`Worker`] state and answers `Ready`;
//!   5. the socket file is unlinked and the relay thread takes over the
//!      comm connections.
//!
//! Failure model: a worker that dies mid-run closes both its sockets. The
//! relay sees EOF and drops *every* comm stream, which unblocks any peers
//! waiting inside a collective (they exit with an error); the coordinator
//! sees EOF on a control read and panics with an attributable message
//! instead of hanging (`dist/cluster.rs::Link`). On a coordinator panic,
//! `Cluster::drop` kills and reaps the children.

use super::cluster::{handle_cmd, Cmd, ParamMeta, Served, Worker};
use super::comm::{Comm, Transport};
use super::{wire, OptimizerSpec};
use std::io::{Read, Write};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::RwLock;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Hello tags: which of a worker's two connections this is.
const CONN_CONTROL: u8 = 0;
const CONN_COMM: u8 = 1;

/// Single-byte `Ready` frame a worker sends once its state is built.
const READY: &[u8] = &[0x52]; // 'R'

/// Spawn/handshake deadline. Generous: release-built workers connect in
/// milliseconds; the deadline only bounds pathological failures.
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(30);

/// Env override for the worker binary (defaults to `current_exe`) — for
/// embedders launching through a non-galore2 coordinator binary. Read
/// only (getenv is thread-safe); IN-PROCESS callers such as test
/// harnesses must use [`set_worker_binary`] instead, because calling
/// `std::env::set_var` while other threads read the environment is a
/// data race.
pub const WORKER_BIN_ENV: &str = "GALORE2_WORKER_BIN";

/// Programmatic worker-binary override; takes precedence over
/// [`WORKER_BIN_ENV`]. Thread-safe (unlike `std::env::set_var`) — test
/// suites point this at `env!("CARGO_BIN_EXE_galore2")`, since the test
/// harness binary they run in has no `worker` subcommand.
pub fn set_worker_binary(path: impl Into<PathBuf>) {
    *worker_bin_override().write().unwrap() = Some(path.into());
}

fn worker_bin_override() -> &'static RwLock<Option<PathBuf>> {
    static OVERRIDE: RwLock<Option<PathBuf>> = RwLock::new(None);
    &OVERRIDE
}

/// Test-only fault injection: a worker whose rank matches the value exits
/// before answering `Ready` (handshake failure path) …
const CRASH_SETUP_ENV: &str = "GALORE2_TEST_CRASH_SETUP_RANK";
/// … or exits on its first `Step` command (mid-run failure path).
const CRASH_STEP_ENV: &str = "GALORE2_TEST_CRASH_STEP_RANK";

/// Test-only fault injection (see tests/transport.rs): ranks that should
/// die during setup / on their first Step. The values are injected into
/// the worker environments at spawn time via `Command::env`, so setting
/// them is thread-safe — no `std::env::set_var` in the coordinator.
#[doc(hidden)]
pub fn set_test_crash_hooks(setup_rank: Option<usize>, step_rank: Option<usize>) {
    *test_crash_hooks().write().unwrap() = (setup_rank, step_rank);
}

fn test_crash_hooks() -> &'static RwLock<(Option<usize>, Option<usize>)> {
    static HOOKS: RwLock<(Option<usize>, Option<usize>)> = RwLock::new((None, None));
    &HOOKS
}

/// Worker-process side of the hooks: reads its OWN environment (set at
/// exec, no concurrent mutation).
fn crash_hook(var: &str, rank: usize) -> bool {
    std::env::var(var)
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        == Some(rank)
}

/// Socket filename inside the per-cluster private directory.
const SOCKET_NAME: &str = "w.sock";

/// A fresh mode-0700 directory for the rendezvous socket. Sockets in a
/// shared temp dir under a predictable name would be squattable by other
/// local users (bind denial, or worse a fake coordinator feeding workers
/// an attacker-controlled setup frame); a private directory we must
/// CREATE (never adopt — `create` fails on an existing path) closes that.
fn fresh_socket_dir() -> Result<PathBuf, String> {
    use std::os::unix::fs::DirBuilderExt;
    static COUNTER: AtomicU64 = AtomicU64::new(0);
    let mut last_err = String::new();
    // A handful of attempts skips over stale/squatted names (pid reuse).
    for _ in 0..16 {
        let n = COUNTER.fetch_add(1, Ordering::Relaxed);
        // Short name: Unix socket paths are capped around 108 bytes.
        let dir = std::env::temp_dir().join(format!("g2w-{}-{n}", std::process::id()));
        let mut builder = std::fs::DirBuilder::new();
        builder.mode(0o700);
        match builder.create(&dir) {
            Ok(()) => return Ok(dir),
            Err(e) => last_err = format!("creating socket dir {}: {e}", dir.display()),
        }
    }
    Err(last_err)
}

/// Best-effort removal of the socket file and its private directory.
pub(crate) fn cleanup_socket(path: &std::path::Path) {
    let _ = std::fs::remove_file(path);
    if let Some(dir) = path.parent() {
        let _ = std::fs::remove_dir(dir);
    }
}

fn worker_binary() -> PathBuf {
    if let Some(p) = worker_bin_override().read().unwrap().as_ref() {
        return p.clone();
    }
    match std::env::var_os(WORKER_BIN_ENV) {
        Some(p) => PathBuf::from(p),
        None => std::env::current_exe().unwrap_or_else(|_| PathBuf::from("galore2")),
    }
}

/// A spawned-and-handshaken world, ready to be wrapped into cluster links.
pub(crate) struct SpawnedWorld {
    /// Control connections, in rank order.
    pub(crate) controls: Vec<UnixStream>,
    /// Worker processes, in rank order.
    pub(crate) children: Vec<Child>,
    /// The collective relay servicing the comm connections.
    pub(crate) relay: JoinHandle<()>,
    /// Rendezvous socket path inside its private 0700 directory (already
    /// unlinked; kept for Drop hygiene).
    pub(crate) socket_path: PathBuf,
}

/// Spawn `world` worker processes for `mode` and run the full handshake.
/// On any error every already-spawned child is killed and reaped and the
/// socket file removed — no orphans, no leftover sockets.
pub(crate) fn spawn_world(
    mode: &'static str,
    world: usize,
    metas: &[ParamMeta],
    spec: &OptimizerSpec,
    seed: u64,
) -> Result<SpawnedWorld, String> {
    let path = fresh_socket_dir()?.join(SOCKET_NAME);
    let listener = UnixListener::bind(&path)
        .map_err(|e| format!("binding worker rendezvous socket {}: {e}", path.display()))?;
    let mut children: Vec<Child> = Vec::with_capacity(world);
    match establish(mode, world, metas, spec, seed, &listener, &path, &mut children) {
        Ok((controls, comm_streams)) => {
            // All connections are up: the filesystem name is no longer
            // needed (established sockets outlive the unlink).
            drop(listener);
            cleanup_socket(&path);
            let relay = std::thread::Builder::new()
                .name(format!("{mode}-relay"))
                .spawn(move || relay_loop(comm_streams))
                .map_err(|e| {
                    for c in &mut children {
                        let _ = c.kill();
                        let _ = c.wait();
                    }
                    format!("spawning {mode} collective relay thread: {e}")
                })?;
            Ok(SpawnedWorld {
                controls,
                children,
                relay,
                socket_path: path,
            })
        }
        Err(e) => {
            for c in &mut children {
                let _ = c.kill();
                let _ = c.wait();
            }
            drop(listener);
            cleanup_socket(&path);
            Err(e)
        }
    }
}

/// Spawn + accept + hello + setup + ready. Children are pushed into
/// `children` as they spawn so the caller can clean up on error.
#[allow(clippy::too_many_arguments)]
fn establish(
    mode: &str,
    world: usize,
    metas: &[ParamMeta],
    spec: &OptimizerSpec,
    seed: u64,
    listener: &UnixListener,
    path: &std::path::Path,
    children: &mut Vec<Child>,
) -> Result<(Vec<UnixStream>, Vec<UnixStream>), String> {
    // Refuse un-shippable specs BEFORE spawning anything.
    let setup = wire::encode_setup(metas, spec, seed)?;

    let bin = worker_binary();
    let (crash_setup, crash_step) = *test_crash_hooks().read().unwrap();
    for rank in 0..world {
        let mut cmd = Command::new(&bin);
        cmd.arg("worker")
            .arg("--mode")
            .arg(mode)
            .arg("--rank")
            .arg(rank.to_string())
            .arg("--world")
            .arg(world.to_string())
            .arg("--endpoint")
            .arg(path)
            // Keep worker compute budgets identical to the thread
            // transport: each worker divides the coordinator's resolved
            // pool default by the world size (`set_thread_share`).
            .env("GALORE2_THREADS", crate::parallel::default_threads().to_string())
            .stdin(Stdio::null());
        if let Some(r) = crash_setup {
            cmd.env(CRASH_SETUP_ENV, r.to_string());
        }
        if let Some(r) = crash_step {
            cmd.env(CRASH_STEP_ENV, r.to_string());
        }
        let child = cmd.spawn().map_err(|e| {
            format!(
                "spawning {mode} worker rank {rank} via {:?}: {e} — when the \
                 coordinator is not the galore2 binary itself, point at the \
                 built one ({WORKER_BIN_ENV} in the environment, or \
                 dist::set_worker_binary from in-process harnesses)",
                bin
            )
        })?;
        children.push(child);
    }

    // Accept 2·world connections (control + comm per rank), watching the
    // children: a worker that exits before connecting is an error now, not
    // a 30-second timeout later.
    listener
        .set_nonblocking(true)
        .map_err(|e| format!("configuring rendezvous listener: {e}"))?;
    let deadline = Instant::now() + HANDSHAKE_TIMEOUT;
    let mut controls: Vec<Option<UnixStream>> = (0..world).map(|_| None).collect();
    let mut comms: Vec<Option<UnixStream>> = (0..world).map(|_| None).collect();
    let mut connected = 0usize;
    while connected < 2 * world {
        match listener.accept() {
            Ok((mut stream, _)) => {
                stream
                    .set_nonblocking(false)
                    .map_err(|e| format!("configuring worker connection: {e}"))?;
                // Bound the hello read so a rogue connector can't stall us.
                let _ = stream.set_read_timeout(Some(Duration::from_secs(5)));
                let (kind, rank) = read_hello(&mut stream)
                    .map_err(|e| format!("reading worker hello: {e}"))?;
                let _ = stream.set_read_timeout(None);
                if rank >= world {
                    return Err(format!("worker hello claims rank {rank} in world {world}"));
                }
                let slot = match kind {
                    CONN_CONTROL => &mut controls[rank],
                    CONN_COMM => &mut comms[rank],
                    other => return Err(format!("worker hello with unknown kind {other}")),
                };
                if slot.is_some() {
                    return Err(format!("rank {rank} connected twice with the same kind"));
                }
                *slot = Some(stream);
                connected += 1;
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() > deadline {
                    return Err(format!(
                        "{mode} worker handshake timed out after {HANDSHAKE_TIMEOUT:?} \
                         ({connected}/{} connections)",
                        2 * world
                    ));
                }
                for (rank, child) in children.iter_mut().enumerate() {
                    if let Ok(Some(status)) = child.try_wait() {
                        return Err(format!(
                            "{mode} worker rank {rank} exited during the handshake \
                             ({status}) — check its stderr"
                        ));
                    }
                }
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(e) => return Err(format!("accepting worker connection: {e}")),
        }
    }
    let mut controls: Vec<UnixStream> = controls.into_iter().map(|s| s.unwrap()).collect();
    let comms: Vec<UnixStream> = comms.into_iter().map(|s| s.unwrap()).collect();

    // Ship the setup and wait for every rank's Ready. Timeout-bounded: a
    // worker that dies building its state must error out, not hang.
    for (rank, control) in controls.iter_mut().enumerate() {
        wire::write_frame(control, &setup)
            .map_err(|e| format!("sending setup to {mode} worker rank {rank}: {e}"))?;
    }
    for (rank, control) in controls.iter_mut().enumerate() {
        let _ = control.set_read_timeout(Some(HANDSHAKE_TIMEOUT));
        let frame = wire::read_frame(control).map_err(|e| {
            format!(
                "{mode} worker rank {rank} failed during setup ({e}) — \
                 check its stderr"
            )
        })?;
        let _ = control.set_read_timeout(None);
        if frame != READY {
            return Err(format!(
                "{mode} worker rank {rank} sent a malformed ready frame"
            ));
        }
    }
    Ok((controls, comms))
}

/// The coordinator-side collective hub: one round per exchange — read one
/// frame from every rank (rank order; sockets buffer early senders), then
/// write the full slot table to every rank. Exits on the first socket
/// error/EOF, DROPPING every stream: that is what unblocks surviving
/// workers when one rank dies (their reads fail instead of waiting
/// forever).
fn relay_loop(mut streams: Vec<UnixStream>) {
    loop {
        let mut frames: Vec<Vec<u8>> = Vec::with_capacity(streams.len());
        for s in &mut streams {
            match wire::read_frame(s) {
                Ok(f) => frames.push(f),
                Err(_) => return,
            }
        }
        for s in &mut streams {
            for f in &frames {
                if wire::write_frame(s, f).is_err() {
                    return;
                }
            }
        }
    }
}

fn send_hello(stream: &mut UnixStream, kind: u8, rank: usize) -> Result<(), String> {
    let mut hello = [0u8; 9];
    hello[0] = kind;
    hello[1..9].copy_from_slice(&(rank as u64).to_le_bytes());
    stream
        .write_all(&hello)
        .map_err(|e| format!("sending hello: {e}"))
}

fn read_hello(stream: &mut UnixStream) -> std::io::Result<(u8, usize)> {
    let mut hello = [0u8; 9];
    stream.read_exact(&mut hello)?;
    let rank = u64::from_le_bytes(hello[1..9].try_into().unwrap()) as usize;
    Ok((hello[0], rank))
}

/// The worker half of an exchange: ship this rank's contribution to the
/// relay, read back the full slot table, reduce locally. Socket failures
/// panic — in a worker process that exits the process with a diagnostic,
/// which is exactly the EOF signal the coordinator and relay react to.
struct ProcessTransport {
    rank: usize,
    world: usize,
    stream: UnixStream,
}

impl Transport for ProcessTransport {
    fn rank(&self) -> usize {
        self.rank
    }

    fn world(&self) -> usize {
        self.world
    }

    fn exchange(
        &mut self,
        data: Vec<f32>,
        reduce: &mut dyn FnMut(&[Vec<f32>]) -> Vec<f32>,
    ) -> Vec<f32> {
        wire::write_frame(&mut self.stream, &wire::f32s_to_bytes(&data)).unwrap_or_else(|e| {
            panic!(
                "rank {}: collective send failed ({e}) — coordinator or a peer died",
                self.rank
            )
        });
        drop(data);
        let mut slots: Vec<Vec<f32>> = Vec::with_capacity(self.world);
        for _ in 0..self.world {
            let frame = wire::read_frame(&mut self.stream).unwrap_or_else(|e| {
                panic!(
                    "rank {}: collective receive failed ({e}) — coordinator or a peer died",
                    self.rank
                )
            });
            slots.push(wire::bytes_to_f32s(&frame).unwrap_or_else(|e| {
                panic!("rank {}: corrupt collective frame: {e}", self.rank)
            }));
        }
        reduce(&slots)
    }

    fn barrier(&mut self) {
        let mut noop = |_: &[Vec<f32>]| Vec::new();
        let _ = self.exchange(Vec::new(), &mut noop);
    }
}

/// Entry point for the `galore2 worker` subcommand: dispatch on the mode
/// tag to the matching [`Worker`] implementation.
pub fn run_worker(mode: &str, rank: usize, world: usize, endpoint: &str) -> Result<(), String> {
    if world == 0 || rank >= world {
        return Err(format!("invalid rank {rank} for world {world}"));
    }
    match mode {
        "fsdp" => serve_worker::<super::FsdpWorker>(rank, world, endpoint),
        "ddp" => serve_worker::<super::DdpWorker>(rank, world, endpoint),
        other => Err(format!("unknown worker mode {other:?} (fsdp|ddp)")),
    }
}

/// A worker process's whole life: connect, receive setup, build state,
/// answer Ready, then serve framed commands until Shutdown.
fn serve_worker<W: Worker>(rank: usize, world: usize, endpoint: &str) -> Result<(), String> {
    let mut control = UnixStream::connect(endpoint)
        .map_err(|e| format!("rank {rank}: connecting control to {endpoint}: {e}"))?;
    send_hello(&mut control, CONN_CONTROL, rank)?;
    let mut comm_stream = UnixStream::connect(endpoint)
        .map_err(|e| format!("rank {rank}: connecting comm to {endpoint}: {e}"))?;
    send_hello(&mut comm_stream, CONN_COMM, rank)?;

    let setup = wire::read_frame(&mut control)
        .map_err(|e| format!("rank {rank}: reading setup frame: {e}"))?;
    let (metas, spec, seed) = wire::decode_setup(&setup)?;

    if crash_hook(CRASH_SETUP_ENV, rank) {
        // Test hook: die before Ready so the coordinator exercises its
        // handshake-failure path.
        std::process::exit(61);
    }

    // Same core-budget split as a worker thread in a world of this size.
    crate::parallel::set_thread_share(world);
    let comm = Comm::from_transport(Box::new(ProcessTransport {
        rank,
        world,
        stream: comm_stream,
    }));
    let mut worker = W::new(rank, world, comm, metas, spec, seed);
    wire::write_frame(&mut control, READY)
        .map_err(|e| format!("rank {rank}: sending ready: {e}"))?;

    loop {
        let frame = wire::read_frame(&mut control).map_err(|e| {
            // EOF without a Shutdown command means the coordinator died.
            format!("rank {rank}: control connection lost ({e})")
        })?;
        let cmd = wire::decode_cmd(&frame)?;
        if matches!(cmd, Cmd::Step { .. }) && crash_hook(CRASH_STEP_ENV, rank) {
            // Test hook: die mid-run so the coordinator and the relay
            // exercise their no-hang failure paths.
            std::process::exit(62);
        }
        match handle_cmd(&mut worker, cmd) {
            Served::Reply(reply) => {
                wire::write_frame(&mut control, &wire::encode_reply(&reply))
                    .map_err(|e| format!("rank {rank}: sending reply: {e}"))?;
            }
            Served::NoReply => {}
            Served::Shutdown => return Ok(()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn socket_dirs_are_private_unique_and_short() {
        let a = fresh_socket_dir().unwrap();
        let b = fresh_socket_dir().unwrap();
        assert_ne!(a, b, "socket dirs must be unique per cluster");
        // sun_path is ~108 bytes on Linux; leave generous headroom.
        let sock = a.join(SOCKET_NAME);
        assert!(
            sock.as_os_str().len() < 100,
            "socket path too long for sun_path: {}",
            sock.display()
        );
        // Private: no other local user may squat or connect early.
        use std::os::unix::fs::PermissionsExt;
        let mode = std::fs::metadata(&a).unwrap().permissions().mode();
        assert_eq!(mode & 0o777, 0o700, "socket dir must be mode 0700");
        // cleanup_socket removes the file (if any) and the directory.
        cleanup_socket(&sock);
        assert!(!a.exists(), "cleanup must remove the private dir");
        std::fs::remove_dir_all(&b).ok();
    }

    #[test]
    fn run_worker_rejects_bad_arguments() {
        assert!(run_worker("fsdp", 2, 2, "/nonexistent").is_err());
        assert!(run_worker("fsdp", 0, 0, "/nonexistent").is_err());
        let err = run_worker("mesh", 0, 1, "/nonexistent").unwrap_err();
        assert!(err.contains("fsdp|ddp"), "unhelpful error: {err}");
        // A valid mode with a dead endpoint fails at connect, not by
        // hanging.
        let err = run_worker("ddp", 0, 1, "/nonexistent/g2.sock").unwrap_err();
        assert!(err.contains("connecting"), "unhelpful error: {err}");
    }

    /// In-process smoke of the relay contract: every rank's frame comes
    /// back to every rank, in rank order, round after round. (Full
    /// process-spawn coverage lives in tests/transport.rs, which has the
    /// galore2 binary path.)
    #[test]
    fn relay_round_trips_slot_tables() {
        let world = 3;
        let path = fresh_socket_dir().unwrap().join(SOCKET_NAME);
        let listener = UnixListener::bind(&path).unwrap();
        let clients: Vec<UnixStream> = (0..world)
            .map(|_| UnixStream::connect(&path).unwrap())
            .collect();
        let serves: Vec<UnixStream> = (0..world).map(|_| listener.accept().unwrap().0).collect();
        cleanup_socket(&path);
        let relay = std::thread::spawn(move || relay_loop(serves));
        let workers: Vec<std::thread::JoinHandle<Vec<Vec<f32>>>> = clients
            .into_iter()
            .enumerate()
            .map(|(rank, stream)| {
                std::thread::spawn(move || {
                    let mut t = ProcessTransport {
                        rank,
                        world,
                        stream,
                    };
                    let mut out = Vec::new();
                    for round in 0..4 {
                        let data = vec![(rank * 10 + round) as f32; 2 + round];
                        let mut collect = |slots: &[Vec<f32>]| -> Vec<f32> {
                            slots.iter().map(|s| s[0]).collect()
                        };
                        out.push(t.exchange(data, &mut collect));
                    }
                    t.barrier();
                    out
                })
            })
            .collect();
        let results: Vec<Vec<Vec<f32>>> =
            workers.into_iter().map(|h| h.join().unwrap()).collect();
        for (rank, rounds) in results.iter().enumerate() {
            for (round, firsts) in rounds.iter().enumerate() {
                let expect: Vec<f32> = (0..world).map(|r| (r * 10 + round) as f32).collect();
                assert_eq!(
                    firsts, &expect,
                    "rank {rank} round {round}: relay delivered wrong slot table"
                );
            }
        }
        // Workers hung up: the relay must exit on EOF, not spin.
        relay.join().unwrap();
    }
}
