//! Process transport: worker ranks as OS processes over Unix-domain
//! sockets.
//!
//! Topology — a star with the coordinator at the hub. Each worker is a
//! self-exec of this binary (`galore2 worker --mode M --rank R --world W
//! --endpoint PATH`) holding two connections to the coordinator's
//! rendezvous socket:
//!
//! * a **control** connection carrying the framed [`Cmd`]/[`Reply`]
//!   cluster protocol (`dist/wire.rs`), driven by the coordinator, and
//! * a **comm** connection synchronizing collectives. Two data planes:
//!
//!   **shm (default, `[dist] shm` / `--shm`)** — gradient payloads move
//!   through a shared slot table (`dist/shm.rs`) the coordinator creates
//!   in the private rendezvous directory and names in the setup frame.
//!   Per exchange a rank deposits its payload into its own slot
//!   (`pwrite`), sends a 33-byte control frame (`[kind][lo][hi][gen]
//!   [elems]`), waits for the relay's release frame, then `pread`s every
//!   peer's window straight out of the table — **zero f32 payload bytes
//!   cross the socket** for all four collectives, and the relay is a pure
//!   synchronizer. Lanes double-buffer generations so the overlap
//!   pipeline's depth-2 FIFO never overwrites a slot a peer still reads.
//!
//!   **sockets (fallback)** — per exchange the relay reads one headered
//!   frame from every rank, then writes each sender's contribution back
//!   to every rank — sliced down to the receiver's requested element
//!   window for ranged exchanges (reduce-scatter asks only for its own
//!   slot range, cutting reply bytes from w·n to n), or whole for full
//!   exchanges.
//!
//!   On both planes the worker-side [`ProcessTransport`] runs the same
//!   fixed-tree reduction the threaded transport runs, over the peers'
//!   windows in rank order, so results are **bitwise identical** to
//!   `--transport threads` — and shm-on to shm-off.
//!
//! Spawn handshake (deadline-bounded, child-exit aware — a worker that
//! dies or never connects is an error, not a hang):
//!
//!   1. coordinator binds PATH, spawns `world` workers;
//!   2. each worker connects twice, prefacing each connection with a
//!      9-byte hello `[kind u8][rank u64]`;
//!   3. coordinator sends each worker its setup frame (parameter metas +
//!      [`OptimizerSpec`] + seed) on the control connection;
//!   4. each worker builds its [`Worker`] state and answers `Ready`;
//!   5. the socket file is unlinked and the relay thread takes over the
//!      comm connections.
//!
//! Failure model: a worker that dies mid-run closes both its sockets. The
//! relay sees EOF and drops *every* comm stream, which unblocks any peers
//! waiting inside a collective (they exit with an error); the coordinator
//! sees EOF on a control read and panics with an attributable message
//! instead of hanging (`dist/cluster.rs::Link`). On a coordinator panic,
//! `Cluster::drop` kills and reaps the children.

use super::cluster::{handle_cmd, record_failure, Cmd, FailureCell, ParamMeta, Served, Worker};
use super::comm::{Comm, Transport};
use super::{shm, wire, OptimizerSpec};
use std::io::{Read, Write};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::RwLock;
use std::thread::JoinHandle;
// lint: allow(determinism): Instant only bounds the spawn/handshake deadline — never on the collective step path
use std::time::{Duration, Instant};

/// Hello tags: which of a worker's two connections this is.
const CONN_CONTROL: u8 = 0;
const CONN_COMM: u8 = 1;

/// Single-byte `Ready` frame a worker sends once its state is built.
const READY: &[u8] = &[0x52]; // 'R'

/// Spawn/handshake deadline. Generous: release-built workers connect in
/// milliseconds; the deadline only bounds pathological failures.
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(30);

/// Env override for the worker binary (defaults to `current_exe`) — for
/// embedders launching through a non-galore2 coordinator binary. Read
/// only (getenv is thread-safe); IN-PROCESS callers such as test
/// harnesses must use [`set_worker_binary`] instead, because calling
/// `std::env::set_var` while other threads read the environment is a
/// data race.
pub const WORKER_BIN_ENV: &str = "GALORE2_WORKER_BIN";

/// Programmatic worker-binary override; takes precedence over
/// [`WORKER_BIN_ENV`]. Thread-safe (unlike `std::env::set_var`) — test
/// suites point this at `env!("CARGO_BIN_EXE_galore2")`, since the test
/// harness binary they run in has no `worker` subcommand.
pub fn set_worker_binary(path: impl Into<PathBuf>) {
    *worker_bin_override().write().unwrap() = Some(path.into());
}

fn worker_bin_override() -> &'static RwLock<Option<PathBuf>> {
    static OVERRIDE: RwLock<Option<PathBuf>> = RwLock::new(None);
    &OVERRIDE
}

/// Propagates the coordinator's overlap knob (`[dist] overlap` /
/// `--overlap`) into worker processes: set via `Command::env` at spawn,
/// read exactly once by `serve_worker` before any comm thread exists.
const OVERLAP_ENV: &str = "GALORE2_OVERLAP";

/// Same propagation for the shm data-plane knob (`[dist] shm` / `--shm`).
/// The setup frame is the authoritative carrier (it names the slot-table
/// file); the env keeps the worker's process-wide cell consistent.
const SHM_ENV: &str = "GALORE2_SHM";

/// Enable/disable the shared-memory data plane for process-transport
/// clusters (`[dist] shm` / `--shm`, default on). With it off — or when
/// slot-table creation fails at spawn — collective payloads ride the
/// comm socket as before.
pub fn set_shm_enabled(enabled: bool) {
    *shm_cell().write().unwrap() = enabled;
}

pub(crate) fn shm_enabled() -> bool {
    *shm_cell().read().unwrap()
}

fn shm_cell() -> &'static RwLock<bool> {
    static CELL: RwLock<bool> = RwLock::new(true);
    &CELL
}

/// Cumulative f32 payload bytes this process moved over comm sockets
/// (deposits + replies; control/release headers excluded) and through the
/// shm slot table (deposits + peer reads). A worker process owns exactly
/// one transport, so these are exact per-rank figures; under the thread
/// transport both stay zero.
static SOCKET_PAYLOAD_BYTES: AtomicU64 = AtomicU64::new(0);
static SHM_BYTES: AtomicU64 = AtomicU64::new(0);
/// One slot's byte size when this worker runs the shm plane (else 0) —
/// the in-flight-generation footprint charged into `peak_transient`.
static SHM_SLOT_BYTES: AtomicU64 = AtomicU64::new(0);

/// `(socket_payload_bytes, shm_bytes)` moved by this process so far.
pub(crate) fn wire_traffic() -> (u64, u64) {
    (
        SOCKET_PAYLOAD_BYTES.load(Ordering::Relaxed),
        SHM_BYTES.load(Ordering::Relaxed),
    )
}

/// Bytes one in-flight pipelined generation keeps live in this worker's
/// slot (0 off the shm plane) — workers add it to `peak_transient`.
pub(crate) fn shm_inflight_bytes() -> usize {
    SHM_SLOT_BYTES.load(Ordering::Relaxed) as usize
}

/// Test-only fault injection: a worker whose rank matches the value exits
/// before answering `Ready` (handshake failure path) …
const CRASH_SETUP_ENV: &str = "GALORE2_TEST_CRASH_SETUP_RANK";
/// … or exits when serving `Step` (mid-run failure path). The value is
/// either a plain rank `R` (crash on the first step) or `R@N` (crash when
/// serving a step with `t >= N`).
const CRASH_STEP_ENV: &str = "GALORE2_TEST_CRASH_STEP_RANK";
/// Test-only: a worker whose rank matches refuses to open the shm slot
/// table during setup (the shm handshake itself fails — the coordinator
/// must surface a named error, never hang).
const SHM_FAIL_ENV: &str = "GALORE2_TEST_SHM_FAIL_RANK";

/// The coordinator-side fault-injection plan (see tests/transport.rs and
/// tests/fault_tolerance.rs). Both transports consume it: process spawns
/// inject it into worker environments via `Command::env`; thread spawns
/// read the step plan directly (`take_step_crash`). Setting it is
/// thread-safe — no `std::env::set_var` in the coordinator.
struct CrashPlan {
    /// Crash rank R during setup, up to CREDITS times: each spawn of that
    /// rank burns one credit, so `(r, 1)` is a transient failure the spawn
    /// retry loop should absorb and `(r, u32::MAX)` a persistent one.
    setup: Option<(usize, u32)>,
    /// Crash rank R when it serves a `Step` with `t >= N`. Consumed by the
    /// FIRST world spawned after it is set — a world rebuilt during
    /// recovery must not re-inject the same crash.
    step: Option<(usize, u64)>,
    /// Fail rank R's shm slot-table open during setup, up to CREDITS
    /// spawns of it — the shm-handshake-failure injection.
    shm_fail: Option<(usize, u32)>,
}

/// Schedule test crashes: `setup = (rank, credits)` kills that rank during
/// the spawn handshake for the next CREDITS spawns of it; `step = (rank,
/// at_step)` kills it when serving a step with `t >= at_step` (first
/// spawned world only). Thread transport honors `step` via an injected
/// panic; `setup` is process-transport-only (thread spawning has no
/// fallible handshake to exercise).
#[doc(hidden)]
pub fn set_test_crash_hooks(setup: Option<(usize, u32)>, step: Option<(usize, u64)>) {
    let mut plan = crash_plan().write().unwrap();
    plan.setup = setup;
    plan.step = step;
}

/// Schedule an shm-handshake failure: rank R's slot-table open fails for
/// the next CREDITS spawns of it (`(r, u32::MAX)` = persistent).
#[doc(hidden)]
pub fn set_test_shm_fail(fail: Option<(usize, u32)>) {
    crash_plan().write().unwrap().shm_fail = fail;
}

fn crash_plan() -> &'static RwLock<CrashPlan> {
    static PLAN: RwLock<CrashPlan> = RwLock::new(CrashPlan {
        setup: None,
        step: None,
        shm_fail: None,
    });
    &PLAN
}

/// Burn one setup-crash credit for this spawn of `rank`. Called once per
/// `Command` built, so retries of a transiently-failing rank see the
/// credit pool shrink.
fn consume_setup_crash(rank: usize) -> bool {
    let mut plan = crash_plan().write().unwrap();
    match &mut plan.setup {
        Some((r, credits)) if *r == rank && *credits > 0 => {
            *credits -= 1;
            true
        }
        _ => false,
    }
}

/// Take the step-crash plan for the world being spawned (both transports
/// call this exactly once per world spawn).
pub(crate) fn take_step_crash() -> Option<(usize, u64)> {
    crash_plan().write().unwrap().step.take()
}

/// Burn one shm-failure credit for this spawn of `rank`.
fn consume_shm_fail(rank: usize) -> bool {
    let mut plan = crash_plan().write().unwrap();
    match &mut plan.shm_fail {
        Some((r, credits)) if *r == rank && *credits > 0 => {
            *credits -= 1;
            true
        }
        _ => false,
    }
}

/// Worker-process side of the setup hook: reads its OWN environment (set
/// at exec, no concurrent mutation).
fn crash_hook(var: &str, rank: usize) -> bool {
    std::env::var(var)
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        == Some(rank)
}

/// Worker-process side of the step hook: `R@N` crashes rank R serving a
/// step with `t >= N`; a bare `R` means `R@0`.
fn step_crash_hit(rank: usize, t: u64) -> bool {
    let Ok(v) = std::env::var(CRASH_STEP_ENV) else {
        return false;
    };
    let v = v.trim();
    let (r, at) = match v.split_once('@') {
        Some((r, at)) => (r.trim().parse::<usize>().ok(), at.trim().parse::<u64>().ok()),
        None => (v.parse::<usize>().ok(), Some(0)),
    };
    r == Some(rank) && at.is_some_and(|n| t >= n)
}

/// Bounded retry budget for a failed process spawn/handshake, per rank
/// (`[dist] spawn_retries` / `--spawn-retries`): a rank may be respawned
/// up to this many times (with capped backoff) before the whole spawn
/// fails naming the rank and attempt count.
pub fn set_spawn_retries(n: usize) {
    *spawn_retries_cell().write().unwrap() = n;
}

fn spawn_retries() -> usize {
    *spawn_retries_cell().read().unwrap()
}

fn spawn_retries_cell() -> &'static RwLock<usize> {
    static RETRIES: RwLock<usize> = RwLock::new(2);
    &RETRIES
}

/// Capped exponential backoff before respawning a failed rank.
fn spawn_backoff(attempt: usize) -> Duration {
    Duration::from_millis((50u64 << attempt.min(4)).min(1000))
}

/// Socket filename inside the per-cluster private directory.
const SOCKET_NAME: &str = "w.sock";

/// A fresh mode-0700 directory for the rendezvous socket. Sockets in a
/// shared temp dir under a predictable name would be squattable by other
/// local users (bind denial, or worse a fake coordinator feeding workers
/// an attacker-controlled setup frame); a private directory we must
/// CREATE (never adopt — `create` fails on an existing path) closes that.
fn fresh_socket_dir() -> Result<PathBuf, String> {
    use std::os::unix::fs::DirBuilderExt;
    static COUNTER: AtomicU64 = AtomicU64::new(0);
    let mut last_err = String::new();
    // A handful of attempts skips over stale/squatted names (pid reuse).
    for _ in 0..16 {
        let n = COUNTER.fetch_add(1, Ordering::Relaxed);
        // Short name: Unix socket paths are capped around 108 bytes.
        let dir = std::env::temp_dir().join(format!("g2w-{}-{n}", std::process::id()));
        let mut builder = std::fs::DirBuilder::new();
        builder.mode(0o700);
        match builder.create(&dir) {
            Ok(()) => return Ok(dir),
            Err(e) => last_err = format!("creating socket dir {}: {e}", dir.display()),
        }
    }
    Err(last_err)
}

/// Best-effort removal of the socket file, the shm slot-table file, and
/// their private directory. Safe to call while workers run: established
/// sockets and open slot-table fds outlive the unlink (the kernel
/// reclaims the table when the last fd closes — even if a worker is
/// killed mid-collective, its fds close at exit).
pub(crate) fn cleanup_socket(path: &std::path::Path) {
    let _ = std::fs::remove_file(path);
    if let Some(dir) = path.parent() {
        let _ = std::fs::remove_file(dir.join(shm::FILE_NAME));
        let _ = std::fs::remove_dir(dir);
    }
}

fn worker_binary() -> PathBuf {
    if let Some(p) = worker_bin_override().read().unwrap().as_ref() {
        return p.clone();
    }
    match std::env::var_os(WORKER_BIN_ENV) {
        Some(p) => PathBuf::from(p),
        None => std::env::current_exe().unwrap_or_else(|_| PathBuf::from("galore2")),
    }
}

/// A spawned-and-handshaken world, ready to be wrapped into cluster links.
pub(crate) struct SpawnedWorld {
    /// Control connections, in rank order.
    pub(crate) controls: Vec<UnixStream>,
    /// Worker processes, in rank order.
    pub(crate) children: Vec<Child>,
    /// The collective relay servicing the comm connections.
    pub(crate) relay: JoinHandle<()>,
    /// Rendezvous socket path inside its private 0700 directory (already
    /// unlinked; kept for Drop hygiene).
    pub(crate) socket_path: PathBuf,
}

/// Spawn `world` worker processes for `mode` and run the full handshake.
/// On any error every already-spawned child is killed and reaped and the
/// socket file removed — no orphans, no leftover sockets.
pub(crate) fn spawn_world(
    mode: &'static str,
    world: usize,
    metas: &[ParamMeta],
    spec: &OptimizerSpec,
    seed: u64,
    failure: FailureCell,
) -> Result<SpawnedWorld, String> {
    let dir = fresh_socket_dir()?;
    let path = dir.join(SOCKET_NAME);
    let listener = UnixListener::bind(&path)
        .map_err(|e| format!("binding worker rendezvous socket {}: {e}", path.display()))?;
    // Shared-memory data plane: create the slot table next to the socket
    // and carry its name + geometry in the setup frame. Creation failure
    // falls back LOUDLY to the socket plane — a silent fallback would let
    // a perf regression masquerade as noise.
    let shm_setup: Option<wire::ShmSetup> = if shm_enabled() {
        let slot_elems = shm::slot_elems_for(metas) as u64;
        match shm::SlotTable::create(&dir, world, slot_elems) {
            Ok((table, table_path)) => {
                // Workers open their own handles; the coordinator keeps
                // no fd (the relay only synchronizes, it never touches
                // payload data).
                drop(table);
                Some(wire::ShmSetup {
                    path: table_path.display().to_string(),
                    slot_elems,
                })
            }
            Err(e) => {
                eprintln!(
                    "galore2: shm slot table unavailable ({e}); falling back to the \
                     socket data plane for this cluster"
                );
                None
            }
        }
    } else {
        None
    };
    let relay_slot_elems = shm_setup.as_ref().map(|s| s.slot_elems);
    let mut children: Vec<Child> = Vec::with_capacity(world);
    match establish(
        mode,
        world,
        metas,
        spec,
        seed,
        &listener,
        &path,
        &mut children,
        shm_setup.as_ref(),
    ) {
        Ok((controls, comm_streams)) => {
            // All connections are up: the filesystem names are no longer
            // needed (established sockets and open slot-table fds outlive
            // the unlink — from here the table behaves like a memfd).
            drop(listener);
            cleanup_socket(&path);
            let relay = std::thread::Builder::new()
                .name(format!("{mode}-relay"))
                .spawn(move || relay_loop(comm_streams, failure, relay_slot_elems))
                .map_err(|e| {
                    for c in &mut children {
                        let _ = c.kill();
                        let _ = c.wait();
                    }
                    format!("spawning {mode} collective relay thread: {e}")
                })?;
            Ok(SpawnedWorld {
                controls,
                children,
                relay,
                socket_path: path,
            })
        }
        Err(e) => {
            for c in &mut children {
                let _ = c.kill();
                let _ = c.wait();
            }
            drop(listener);
            cleanup_socket(&path);
            Err(e)
        }
    }
}

/// Spawn one worker process for `rank`, injecting any test crash plan.
#[allow(clippy::too_many_arguments)]
fn spawn_rank(
    mode: &str,
    bin: &PathBuf,
    path: &std::path::Path,
    world: usize,
    rank: usize,
    step_crash: Option<(usize, u64)>,
) -> Result<Child, String> {
    let mut cmd = Command::new(bin);
    cmd.arg("worker")
        .arg("--mode")
        .arg(mode)
        .arg("--rank")
        .arg(rank.to_string())
        .arg("--world")
        .arg(world.to_string())
        .arg("--endpoint")
        .arg(path)
        // Keep worker compute budgets identical to the thread
        // transport: each worker divides the coordinator's resolved
        // pool default by the world size (`set_thread_share`). Set via
        // `Command::env` at spawn — the child resolves it exactly once
        // into `parallel`'s OnceLock, so there is no getenv after
        // threads exist on either side.
        .env(
            "GALORE2_THREADS",
            crate::parallel::default_threads().to_string(),
        )
        // Workers must run the same schedule (pipelined or serial) as a
        // thread-transport world would — the knob rides the environment.
        .env(
            OVERLAP_ENV,
            if super::pipeline::overlap_enabled() {
                "1"
            } else {
                "0"
            },
        )
        // Data-plane knob, same propagation (authoritative carrier is the
        // setup frame; the env keeps the worker's cell consistent).
        .env(SHM_ENV, if shm_enabled() { "1" } else { "0" })
        .stdin(Stdio::null());
    if consume_setup_crash(rank) {
        cmd.env(CRASH_SETUP_ENV, rank.to_string());
    }
    if consume_shm_fail(rank) {
        cmd.env(SHM_FAIL_ENV, rank.to_string());
    }
    if let Some((r, at)) = step_crash {
        if r == rank {
            cmd.env(CRASH_STEP_ENV, format!("{r}@{at}"));
        }
    }
    cmd.spawn().map_err(|e| {
        format!(
            "spawning {mode} worker rank {rank} via {:?}: {e} — when the \
             coordinator is not the galore2 binary itself, point at the \
             built one ({WORKER_BIN_ENV} in the environment, or \
             dist::set_worker_binary from in-process harnesses)",
            bin
        )
    })
}

/// Kill/reap a failed rank, drop its stale connections, back off, and
/// spawn its replacement. The caller has already checked the retry budget.
#[allow(clippy::too_many_arguments)]
fn respawn_rank(
    mode: &str,
    bin: &PathBuf,
    path: &std::path::Path,
    world: usize,
    rank: usize,
    step_crash: Option<(usize, u64)>,
    children: &mut [Child],
    controls: &mut [Option<UnixStream>],
    comms: &mut [Option<UnixStream>],
    attempts: &mut [usize],
) -> Result<(), String> {
    let _ = children[rank].kill();
    let _ = children[rank].wait();
    controls[rank] = None;
    comms[rank] = None;
    std::thread::sleep(spawn_backoff(attempts[rank]));
    children[rank] = spawn_rank(mode, bin, path, world, rank, step_crash)?;
    attempts[rank] += 1;
    Ok(())
}

/// Spawn + accept + hello + setup + ready, retrying a failed rank up to
/// `spawn_retries` times (capped backoff) before surfacing the error with
/// the rank and attempt count. Children live in `children` (rank-indexed)
/// so the caller can clean up on error.
#[allow(clippy::too_many_arguments)]
fn establish(
    mode: &str,
    world: usize,
    metas: &[ParamMeta],
    spec: &OptimizerSpec,
    seed: u64,
    listener: &UnixListener,
    path: &std::path::Path,
    children: &mut Vec<Child>,
    shm_setup: Option<&wire::ShmSetup>,
) -> Result<(Vec<UnixStream>, Vec<UnixStream>), String> {
    // Refuse un-shippable specs BEFORE spawning anything.
    let setup = wire::encode_setup(metas, spec, seed, shm_setup)?;

    let bin = worker_binary();
    let retries = spawn_retries();
    // Consumed ONCE per world: a world respawned during recovery must not
    // re-inject the same step crash.
    let step_crash = take_step_crash();
    for rank in 0..world {
        children.push(spawn_rank(mode, &bin, path, world, rank, step_crash)?);
    }
    let mut attempts: Vec<usize> = vec![1; world];

    listener
        .set_nonblocking(true)
        .map_err(|e| format!("configuring rendezvous listener: {e}"))?;
    // lint: allow(determinism): wall-clock handshake deadline, pre-training-loop only
    let deadline = Instant::now() + HANDSHAKE_TIMEOUT;
    let mut controls: Vec<Option<UnixStream>> = (0..world).map(|_| None).collect();
    let mut comms: Vec<Option<UnixStream>> = (0..world).map(|_| None).collect();
    let mut ready: Vec<bool> = vec![false; world];

    'handshake: loop {
        // Accept phase: fill every missing connection slot (control + comm
        // per rank), watching the children — a worker that exits before
        // connecting is retried (or an error) now, not a 30-second timeout
        // later.
        while !(0..world).all(|r| controls[r].is_some() && comms[r].is_some()) {
            match listener.accept() {
                Ok((mut stream, _)) => {
                    stream
                        .set_nonblocking(false)
                        .map_err(|e| format!("configuring worker connection: {e}"))?;
                    // Bound the hello read so a rogue connector can't stall us.
                    let _ = stream.set_read_timeout(Some(Duration::from_secs(5)));
                    let (kind, rank) = read_hello(&mut stream)
                        .map_err(|e| format!("reading worker hello: {e}"))?;
                    let _ = stream.set_read_timeout(None);
                    if rank >= world {
                        return Err(format!("worker hello claims rank {rank} in world {world}"));
                    }
                    let slot = match kind {
                        CONN_CONTROL => &mut controls[rank],
                        CONN_COMM => &mut comms[rank],
                        other => return Err(format!("worker hello with unknown kind {other}")),
                    };
                    if slot.is_some() {
                        return Err(format!("rank {rank} connected twice with the same kind"));
                    }
                    *slot = Some(stream);
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    // lint: allow(determinism): wall-clock handshake deadline, pre-training-loop only
                    if Instant::now() > deadline {
                        let connected = (0..world)
                            .map(|r| controls[r].is_some() as usize + comms[r].is_some() as usize)
                            .sum::<usize>();
                        return Err(format!(
                            "{mode} worker handshake timed out after {HANDSHAKE_TIMEOUT:?} \
                             ({connected}/{} connections)",
                            2 * world
                        ));
                    }
                    for rank in 0..world {
                        if let Ok(Some(status)) = children[rank].try_wait() {
                            if attempts[rank] > retries {
                                return Err(format!(
                                    "{mode} worker rank {rank} exited during the handshake \
                                     ({status}) — check its stderr; gave up after {} attempts \
                                     ([dist] spawn_retries = {retries})",
                                    attempts[rank]
                                ));
                            }
                            respawn_rank(
                                mode,
                                &bin,
                                path,
                                world,
                                rank,
                                step_crash,
                                children,
                                &mut controls,
                                &mut comms,
                                &mut attempts,
                            )?;
                        }
                    }
                    std::thread::sleep(Duration::from_millis(2));
                }
                Err(e) => return Err(format!("accepting worker connection: {e}")),
            }
        }

        // Setup/ready phase: ship the setup and wait for each remaining
        // rank's Ready. Timeout-bounded; a rank that dies building its
        // state loops back through the accept phase as a respawn.
        for rank in 0..world {
            if ready[rank] {
                continue;
            }
            let control = controls[rank].as_mut().unwrap();
            let result = (|| -> Result<(), String> {
                wire::write_frame(control, &setup).map_err(|e| format!("sending setup: {e}"))?;
                let _ = control.set_read_timeout(Some(HANDSHAKE_TIMEOUT));
                let frame = wire::read_frame(control)
                    .map_err(|e| format!("failed during setup ({e}) — check its stderr"))?;
                let _ = control.set_read_timeout(None);
                if frame != READY {
                    return Err("sent a malformed ready frame".to_string());
                }
                Ok(())
            })();
            match result {
                Ok(()) => ready[rank] = true,
                Err(cause) => {
                    if attempts[rank] > retries {
                        return Err(format!(
                            "{mode} worker rank {rank}: {cause}; gave up after {} attempts \
                             ([dist] spawn_retries = {retries})",
                            attempts[rank]
                        ));
                    }
                    respawn_rank(
                        mode,
                        &bin,
                        path,
                        world,
                        rank,
                        step_crash,
                        children,
                        &mut controls,
                        &mut comms,
                        &mut attempts,
                    )?;
                    continue 'handshake;
                }
            }
        }
        break;
    }

    let controls: Vec<UnixStream> = controls.into_iter().map(|s| s.unwrap()).collect();
    let comms: Vec<UnixStream> = comms.into_iter().map(|s| s.unwrap()).collect();
    Ok((controls, comms))
}

/// The coordinator-side collective hub, one round per exchange. Reads one
/// frame from every rank (rank order; sockets buffer early senders), then:
///
/// **Socket plane** (`shm_slot_elems = None`) — writes each sender's
/// contribution back to every rank, sliced down to that receiver's
/// requested element window (ranged exchanges carry `[lo, hi)` in their
/// header; full exchanges get the whole body). Slicing happens hub-side,
/// so a reduce-scatter reply costs n elements instead of w·n.
///
/// **Shm plane** (`Some(slot_elems)`) — the frames are 33-byte control
/// messages; the relay is a pure synchronizer. It validates that every
/// rank is at the same generation, that deposits fit the slots, and that
/// requested windows fit every peer's deposit, then releases the round
/// with one small go frame per rank (`[gen][elems × world]`). Payloads
/// never pass through the hub: workers read each peer's slot directly and
/// run the reduction themselves — in rank order, so the fixed-tree
/// summation order (and therefore bitwise parity with sockets, threads,
/// and single) is untouched.
///
/// Exits on the first socket error/EOF/desync, DROPPING every stream:
/// that is what unblocks surviving workers when one rank dies (their
/// reads fail instead of waiting forever). The errored rank is recorded
/// into the shared failure cell FIRST, so the coordinator blames the rank
/// that actually died rather than the first victim whose control link it
/// happens to poll.
fn relay_loop(mut streams: Vec<UnixStream>, failure: FailureCell, shm_slot_elems: Option<u64>) {
    let world = streams.len();
    // Per-rank receive buffers, reused across rounds: a long run reads
    // millions of frames and must not allocate per message.
    let mut frames: Vec<Vec<u8>> = vec![Vec::new(); world];
    let mut gen: u64 = 0;
    loop {
        for (rank, (s, buf)) in streams.iter_mut().zip(frames.iter_mut()).enumerate() {
            if let Err(e) = wire::read_frame_into(s, buf) {
                record_failure(
                    &failure,
                    rank,
                    format!("comm socket lost mid-collective ({e}) — check its stderr"),
                );
                return;
            }
        }
        if let Some(slot_elems) = shm_slot_elems {
            // Synchronizer round: validate every rank's control frame,
            // then release. The go frame is control metadata (per-peer
            // deposit lengths), not payload.
            let mut elems: Vec<u64> = Vec::with_capacity(world);
            let mut emin = u64::MAX;
            let mut ranged_hi: Option<(usize, u64)> = None;
            for (rank, f) in frames.iter().enumerate() {
                let ctrl = match shm::header::decode_ctrl(f) {
                    Ok(c) => c,
                    Err(e) => {
                        record_failure(
                            &failure,
                            rank,
                            format!("malformed shm control frame ({e}) — check its stderr"),
                        );
                        return;
                    }
                };
                if ctrl.gen != gen {
                    record_failure(
                        &failure,
                        rank,
                        format!(
                            "shm generation desync (rank at {}, relay at {gen}) — \
                             ranks issued different collective schedules",
                            ctrl.gen
                        ),
                    );
                    return;
                }
                if ctrl.elems > slot_elems {
                    record_failure(
                        &failure,
                        rank,
                        format!(
                            "shm deposit of {} elements exceeds the {slot_elems}-element slot",
                            ctrl.elems
                        ),
                    );
                    return;
                }
                if let Some((_, hi)) = ctrl.need {
                    let hi = hi as u64;
                    match ranged_hi {
                        Some((_, h)) if hi <= h => {}
                        _ => ranged_hi = Some((rank, hi)),
                    }
                }
                emin = emin.min(ctrl.elems);
                elems.push(ctrl.elems);
            }
            if let Some((rank, hi)) = ranged_hi {
                if hi > emin {
                    record_failure(
                        &failure,
                        rank,
                        format!(
                            "shm window reaching element {hi} exceeds a peer's \
                             {emin}-element deposit — ranks desynced"
                        ),
                    );
                    return;
                }
            }
            let go = shm::header::encode_go(gen, &elems);
            for (rank, s) in streams.iter_mut().enumerate() {
                if let Err(e) = wire::write_frame(s, &go) {
                    record_failure(
                        &failure,
                        rank,
                        format!("comm socket lost mid-collective ({e}) — check its stderr"),
                    );
                    return;
                }
            }
            gen += 1;
            continue;
        }
        let mut needs: Vec<Option<(usize, usize)>> = Vec::with_capacity(world);
        for (rank, f) in frames.iter().enumerate() {
            match wire::decode_comm_header(f) {
                Ok((need, _)) => needs.push(need),
                Err(e) => {
                    record_failure(
                        &failure,
                        rank,
                        format!("malformed collective frame ({e}) — check its stderr"),
                    );
                    return;
                }
            }
        }
        for (rank, (s, need)) in streams.iter_mut().zip(&needs).enumerate() {
            for f in &frames {
                // Receiver windows index into peer bodies; ranks issue
                // collectives in lockstep with equal-length payloads, so a
                // miss means a corrupt/desynced peer — a named error.
                let (a, b) = match need {
                    Some((lo, hi)) => (wire::COMM_HDR_LEN + lo * 4, wire::COMM_HDR_LEN + hi * 4),
                    None => (wire::COMM_HDR_LEN, f.len()),
                };
                let Some(reply) = f.get(a..b) else {
                    record_failure(
                        &failure,
                        rank,
                        format!(
                            "collective window [{a}, {b}) exceeds a peer's {}-byte frame — \
                             ranks desynced",
                            f.len()
                        ),
                    );
                    return;
                };
                if let Err(e) = wire::write_frame(s, reply) {
                    record_failure(
                        &failure,
                        rank,
                        format!("comm socket lost mid-collective ({e}) — check its stderr"),
                    );
                    return;
                }
            }
        }
    }
}

fn send_hello(stream: &mut UnixStream, kind: u8, rank: usize) -> Result<(), String> {
    stream
        .write_all(&wire::encode_hello(kind, rank))
        .map_err(|e| format!("sending hello: {e}"))
}

fn read_hello(stream: &mut UnixStream) -> std::io::Result<(u8, usize)> {
    let mut hello = [0u8; wire::HELLO_LEN];
    stream.read_exact(&mut hello)?;
    Ok(wire::decode_hello(&hello))
}

/// The worker half of an exchange. Socket plane: ship this rank's
/// headered contribution to the relay, read back each peer's (possibly
/// range-sliced) window, reduce locally. Shm plane: deposit into this
/// rank's slot, send a 33-byte control frame, wait for the relay's go,
/// then read every peer's window straight out of the slot table — zero
/// f32 payload bytes touch the socket. Either way the reduce closure sees
/// per-rank views in rank order, so the fixed-tree summation is identical
/// across planes. Failures panic — in a worker process that exits the
/// process with a diagnostic, which is exactly the EOF signal the
/// coordinator and relay react to.
struct ProcessTransport {
    rank: usize,
    world: usize,
    stream: UnixStream,
    /// Actual reply bytes read off the comm socket — pins the hub-side
    /// scatter-range slicing (a ranged exchange costs w·(hi−lo)·4, not
    /// w·n·4) and, with shm on, pins the socket payload at exactly zero.
    /// Distinct from `Comm`'s modeled traffic counters, which stay
    /// transport-uniform.
    reply_bytes: u64,
    /// Shared-memory data plane, when the setup handshake carried a slot
    /// table. `None` falls back to framed socket payloads.
    shm: Option<WorkerShm>,
}

/// Per-worker shared-memory state: this rank's handle onto the cluster's
/// slot table, the local generation counter (must stay in lockstep with
/// the relay's), and reusable scratch so the steady-state step path stops
/// allocating per collective.
struct WorkerShm {
    table: shm::SlotTable,
    gen: u64,
    /// Byte staging for pread/pwrite ↔ f32 conversion.
    bytes: Vec<u8>,
    /// Per-peer decoded windows, reused across rounds.
    slots: Vec<Vec<f32>>,
}

impl Transport for ProcessTransport {
    fn rank(&self) -> usize {
        self.rank
    }

    fn world(&self) -> usize {
        self.world
    }

    fn exchange(
        &mut self,
        data: Vec<f32>,
        need: Option<(usize, usize)>,
        reduce: &mut dyn FnMut(&[&[f32]]) -> Vec<f32>,
    ) -> Vec<f32> {
        if self.shm.is_some() {
            return self.exchange_shm(data, need, reduce);
        }
        SOCKET_PAYLOAD_BYTES.fetch_add((data.len() * 4) as u64, Ordering::Relaxed);
        wire::write_frame(&mut self.stream, &wire::encode_comm_frame(need, &data))
            .unwrap_or_else(|e| {
                // lint: allow(no-panic-dist): worker-process exit IS the death signal — the relay sees EOF and records the rank into the coordinator's FailureCell
                panic!(
                    "rank {}: collective send failed ({e}) — coordinator or a peer died",
                    self.rank
                )
            });
        drop(data);
        let mut slots: Vec<Vec<f32>> = Vec::with_capacity(self.world);
        for _ in 0..self.world {
            let frame = wire::read_frame(&mut self.stream).unwrap_or_else(|e| {
                // lint: allow(no-panic-dist): worker-process exit IS the death signal — the relay sees EOF and records the rank into the coordinator's FailureCell
                panic!(
                    "rank {}: collective receive failed ({e}) — coordinator or a peer died",
                    self.rank
                )
            });
            self.reply_bytes += frame.len() as u64;
            SOCKET_PAYLOAD_BYTES.fetch_add(frame.len() as u64, Ordering::Relaxed);
            slots.push(wire::bytes_to_f32s(&frame).unwrap_or_else(|e| {
                // lint: allow(no-panic-dist): worker-process exit IS the death signal (relay EOF → FailureCell); corrupt frame has no recovery inside a collective
                panic!("rank {}: corrupt collective frame: {e}", self.rank)
            }));
        }
        let views: Vec<&[f32]> = slots.iter().map(|s| s.as_slice()).collect();
        reduce(&views)
    }

    fn barrier(&mut self) {
        let mut noop = |_: &[&[f32]]| Vec::new();
        let _ = self.exchange(Vec::new(), None, &mut noop);
    }
}

impl ProcessTransport {
    /// The shared-memory collective: pwrite this rank's payload into its
    /// `gen % LANES` slot, send a 33-byte control frame, block on the
    /// relay's go frame, then pread every peer's window and reduce in rank
    /// order. Two lanes make distance-2 slot reuse safe under the overlap
    /// pipeline's depth-2 FIFO: depositing generation g+2 (same lane as g)
    /// requires the relay to have released g+1, which it only does after
    /// every rank deposited g+1 — i.e. after every rank finished reading g.
    fn exchange_shm(
        &mut self,
        data: Vec<f32>,
        need: Option<(usize, usize)>,
        reduce: &mut dyn FnMut(&[&[f32]]) -> Vec<f32>,
    ) -> Vec<f32> {
        // Disjoint field borrows: the slot-table state and the socket are
        // used simultaneously below.
        let ProcessTransport {
            rank,
            world,
            stream,
            shm,
            ..
        } = self;
        let rank = *rank;
        let world = *world;
        let w = match shm.as_mut() {
            Some(w) => w,
            // Unreachable: exchange() dispatches here only when shm is Some.
            None => panic!("rank {rank}: exchange_shm without a slot table"),
        };
        let lane = w.gen % shm::LANES;
        if let Err(e) = w.table.write_slot(rank, lane, &data, &mut w.bytes) {
            panic!("rank {rank}: shm deposit failed ({e})");
        }
        SHM_BYTES.fetch_add((data.len() * 4) as u64, Ordering::Relaxed);
        let ctrl = shm::header::encode_ctrl(&shm::Ctrl {
            need,
            gen: w.gen,
            elems: data.len() as u64,
        });
        drop(data);
        if let Err(e) = wire::write_frame(stream, &ctrl) {
            panic!("rank {rank}: collective send failed ({e}) — coordinator or a peer died");
        }
        let go = match wire::read_frame(stream) {
            Ok(f) => f,
            Err(e) => {
                panic!("rank {rank}: collective receive failed ({e}) — coordinator or a peer died")
            }
        };
        let (gen, elems) = match shm::header::decode_go(&go, world) {
            Ok(v) => v,
            Err(e) => panic!("rank {rank}: corrupt shm go frame: {e}"),
        };
        if gen != w.gen {
            panic!(
                "rank {rank}: shm generation desync (relay at {gen}, rank at {}) — \
                 ranks issued different collective schedules",
                w.gen
            );
        }
        if w.slots.len() < world {
            w.slots.resize_with(world, Vec::new);
        }
        for (r, (e, out)) in elems.iter().zip(w.slots.iter_mut()).enumerate() {
            let (lo, hi) = match need {
                Some((lo, hi)) => (lo, hi),
                None => (0, *e as usize),
            };
            // The relay already validated windows against the minimum
            // deposit, so a miss here means relay/worker disagreement.
            if hi as u64 > *e {
                panic!(
                    "rank {rank}: shm window reaching element {hi} exceeds rank {r}'s \
                     {e}-element deposit — ranks desynced"
                );
            }
            if let Err(err) = w.table.read_slot(r, lane, lo, hi, &mut w.bytes, out) {
                panic!("rank {rank}: shm read of rank {r}'s slot failed ({err})");
            }
            SHM_BYTES.fetch_add(((hi - lo) * 4) as u64, Ordering::Relaxed);
        }
        w.gen += 1;
        let views: Vec<&[f32]> = w.slots[..world].iter().map(|s| s.as_slice()).collect();
        reduce(&views)
    }
}

/// Entry point for the `galore2 worker` subcommand: dispatch on the mode
/// tag to the matching [`Worker`] implementation.
pub fn run_worker(mode: &str, rank: usize, world: usize, endpoint: &str) -> Result<(), String> {
    if world == 0 || rank >= world {
        return Err(format!("invalid rank {rank} for world {world}"));
    }
    match mode {
        "fsdp" => serve_worker::<super::FsdpWorker>(rank, world, endpoint),
        "ddp" => serve_worker::<super::DdpWorker>(rank, world, endpoint),
        other => Err(format!("unknown worker mode {other:?} (fsdp|ddp)")),
    }
}

/// A worker process's whole life: connect, receive setup, build state,
/// answer Ready, then serve framed commands until Shutdown.
fn serve_worker<W: Worker>(rank: usize, world: usize, endpoint: &str) -> Result<(), String> {
    let mut control = UnixStream::connect(endpoint)
        .map_err(|e| format!("rank {rank}: connecting control to {endpoint}: {e}"))?;
    send_hello(&mut control, CONN_CONTROL, rank)?;
    let mut comm_stream = UnixStream::connect(endpoint)
        .map_err(|e| format!("rank {rank}: connecting comm to {endpoint}: {e}"))?;
    send_hello(&mut comm_stream, CONN_COMM, rank)?;

    let setup = wire::read_frame(&mut control)
        .map_err(|e| format!("rank {rank}: reading setup frame: {e}"))?;
    let (metas, spec, seed, shm_setup) = wire::decode_setup(&setup)?;

    if crash_hook(CRASH_SETUP_ENV, rank) {
        // Test hook: die before Ready so the coordinator exercises its
        // handshake-failure path.
        std::process::exit(61);
    }

    // Same core-budget split as a worker thread in a world of this size.
    crate::parallel::set_thread_share(world);
    // Adopt the coordinator's overlap/shm settings (set at exec; read
    // once, before any comm thread exists — no getenv on the step path).
    if let Ok(v) = std::env::var(OVERLAP_ENV) {
        super::pipeline::set_overlap_enabled(v.trim() != "0");
    }
    if let Ok(v) = std::env::var(SHM_ENV) {
        set_shm_enabled(v.trim() != "0");
    }
    // Map the slot table the setup frame declared. Failing here — before
    // Ready — makes the coordinator's handshake respawn/fail path name
    // this rank instead of hanging a collective later.
    let shm_state = match &shm_setup {
        Some(s) => {
            if crash_hook(SHM_FAIL_ENV, rank) {
                return Err(format!(
                    "rank {rank}: shm slot table: injected open failure (test hook)"
                ));
            }
            let table = shm::SlotTable::open(std::path::Path::new(&s.path), world, s.slot_elems)
                .map_err(|e| format!("rank {rank}: shm slot table: {e}"))?;
            SHM_SLOT_BYTES.store(table.slot_bytes(), Ordering::Relaxed);
            Some(WorkerShm {
                table,
                gen: 0,
                bytes: Vec::new(),
                slots: Vec::new(),
            })
        }
        None => None,
    };
    let comm = Comm::from_transport(Box::new(ProcessTransport {
        rank,
        world,
        stream: comm_stream,
        reply_bytes: 0,
        shm: shm_state,
    }));
    let mut worker = W::new(rank, world, comm, metas, spec, seed);
    wire::write_frame(&mut control, READY)
        .map_err(|e| format!("rank {rank}: sending ready: {e}"))?;

    // Per-connection scratch: the control loop reads one frame per step
    // command and must not allocate per message.
    let mut frame = Vec::new();
    loop {
        wire::read_frame_into(&mut control, &mut frame).map_err(|e| {
            // EOF without a Shutdown command means the coordinator died.
            format!("rank {rank}: control connection lost ({e})")
        })?;
        let cmd = wire::decode_cmd(&frame)?;
        if let Cmd::Step { t, .. } = &cmd {
            if step_crash_hit(rank, *t) {
                // Test hook: die mid-run so the coordinator and the relay
                // exercise their no-hang failure paths.
                std::process::exit(62);
            }
        }
        match handle_cmd(&mut worker, cmd) {
            Served::Reply(reply) => {
                wire::write_frame(&mut control, &wire::encode_reply(&reply))
                    .map_err(|e| format!("rank {rank}: sending reply: {e}"))?;
            }
            Served::NoReply => {}
            Served::Shutdown => return Ok(()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn socket_dirs_are_private_unique_and_short() {
        let a = fresh_socket_dir().unwrap();
        let b = fresh_socket_dir().unwrap();
        assert_ne!(a, b, "socket dirs must be unique per cluster");
        // sun_path is ~108 bytes on Linux; leave generous headroom.
        let sock = a.join(SOCKET_NAME);
        assert!(
            sock.as_os_str().len() < 100,
            "socket path too long for sun_path: {}",
            sock.display()
        );
        // Private: no other local user may squat or connect early.
        use std::os::unix::fs::PermissionsExt;
        let mode = std::fs::metadata(&a).unwrap().permissions().mode();
        assert_eq!(mode & 0o777, 0o700, "socket dir must be mode 0700");
        // cleanup_socket removes the file (if any) and the directory.
        cleanup_socket(&sock);
        assert!(!a.exists(), "cleanup must remove the private dir");
        std::fs::remove_dir_all(&b).ok();
    }

    #[test]
    fn run_worker_rejects_bad_arguments() {
        assert!(run_worker("fsdp", 2, 2, "/nonexistent").is_err());
        assert!(run_worker("fsdp", 0, 0, "/nonexistent").is_err());
        let err = run_worker("mesh", 0, 1, "/nonexistent").unwrap_err();
        assert!(err.contains("fsdp|ddp"), "unhelpful error: {err}");
        // A valid mode with a dead endpoint fails at connect, not by
        // hanging.
        let err = run_worker("ddp", 0, 1, "/nonexistent/g2.sock").unwrap_err();
        assert!(err.contains("connecting"), "unhelpful error: {err}");
    }

    /// In-process smoke of the relay contract: every rank's frame comes
    /// back to every rank, in rank order, round after round. (Full
    /// process-spawn coverage lives in tests/transport.rs, which has the
    /// galore2 binary path.)
    #[test]
    fn relay_round_trips_slot_tables() {
        let world = 3;
        let path = fresh_socket_dir().unwrap().join(SOCKET_NAME);
        let listener = UnixListener::bind(&path).unwrap();
        let clients: Vec<UnixStream> = (0..world)
            .map(|_| UnixStream::connect(&path).unwrap())
            .collect();
        let serves: Vec<UnixStream> = (0..world).map(|_| listener.accept().unwrap().0).collect();
        cleanup_socket(&path);
        let cell: FailureCell = std::sync::Arc::new(std::sync::Mutex::new(None));
        let relay = std::thread::spawn(move || relay_loop(serves, cell, None));
        let workers: Vec<std::thread::JoinHandle<Vec<Vec<f32>>>> = clients
            .into_iter()
            .enumerate()
            .map(|(rank, stream)| {
                std::thread::spawn(move || {
                    let mut t = ProcessTransport {
                        rank,
                        world,
                        stream,
                        reply_bytes: 0,
                        shm: None,
                    };
                    let mut out = Vec::new();
                    for round in 0..4 {
                        let data = vec![(rank * 10 + round) as f32; 2 + round];
                        let mut collect = |slots: &[&[f32]]| -> Vec<f32> {
                            slots.iter().map(|s| s[0]).collect()
                        };
                        out.push(t.exchange(data, None, &mut collect));
                    }
                    t.barrier();
                    out
                })
            })
            .collect();
        let results: Vec<Vec<Vec<f32>>> =
            workers.into_iter().map(|h| h.join().unwrap()).collect();
        for (rank, rounds) in results.iter().enumerate() {
            for (round, firsts) in rounds.iter().enumerate() {
                let expect: Vec<f32> = (0..world).map(|r| (r * 10 + round) as f32).collect();
                assert_eq!(
                    firsts, &expect,
                    "rank {rank} round {round}: relay delivered wrong slot table"
                );
            }
        }
        // Workers hung up: the relay must exit on EOF, not spin.
        relay.join().unwrap();
    }

    /// The satellite pin for hub-side scatter slicing: a ranged exchange's
    /// replies cost exactly w·(hi−lo)·4 bytes on the wire (not w·n·4), a
    /// full exchange exactly w·n·4, and the delivered windows preserve
    /// rank order and element values.
    #[test]
    fn relay_ships_only_requested_ranges() {
        let world = 3usize;
        let n = 6usize;
        let path = fresh_socket_dir().unwrap().join(SOCKET_NAME);
        let listener = UnixListener::bind(&path).unwrap();
        let clients: Vec<UnixStream> = (0..world)
            .map(|_| UnixStream::connect(&path).unwrap())
            .collect();
        let serves: Vec<UnixStream> = (0..world).map(|_| listener.accept().unwrap().0).collect();
        cleanup_socket(&path);
        let cell: FailureCell = std::sync::Arc::new(std::sync::Mutex::new(None));
        let relay = std::thread::spawn(move || relay_loop(serves, cell, None));
        let handles: Vec<std::thread::JoinHandle<()>> = clients
            .into_iter()
            .enumerate()
            .map(|(rank, stream)| {
                std::thread::spawn(move || {
                    let mut t = ProcessTransport {
                        rank,
                        world,
                        stream,
                        reply_bytes: 0,
                        shm: None,
                    };
                    // Rank r contributes [r*100, r*100+1, …]; every rank
                    // asks only for its own 2-element slot window.
                    let data: Vec<f32> = (0..n).map(|i| (rank * 100 + i) as f32).collect();
                    let (lo, hi) = (rank * 2, rank * 2 + 2);
                    let mut collect = |slots: &[&[f32]]| -> Vec<f32> {
                        // Each delivered window is exactly [lo, hi) of one
                        // peer, in rank order.
                        assert_eq!(slots.len(), world);
                        for (r, s) in slots.iter().enumerate() {
                            let expect: Vec<f32> =
                                (lo..hi).map(|i| (r * 100 + i) as f32).collect();
                            assert_eq!(s, &expect.as_slice(), "wrong window from rank {r}");
                        }
                        slots.iter().map(|s| s[0]).collect()
                    };
                    let _ = t.exchange(data.clone(), Some((lo, hi)), &mut collect);
                    assert_eq!(
                        t.reply_bytes,
                        (world * (hi - lo) * 4) as u64,
                        "ranged replies must ship only the requested window"
                    );
                    // A full exchange still ships whole bodies.
                    let before = t.reply_bytes;
                    let mut noop = |_: &[&[f32]]| Vec::new();
                    let _ = t.exchange(data, None, &mut noop);
                    assert_eq!(t.reply_bytes - before, (world * n * 4) as u64);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        relay.join().unwrap();
    }

    /// The shm-plane contract, in process: payloads move through the slot
    /// table only, the relay releases rounds in lockstep generations,
    /// ranged windows come back correct and in rank order — and the comm
    /// socket carries ZERO payload bytes (`reply_bytes == 0`, the
    /// transport-level half of the tentpole's zero-copy pin; the
    /// process-spawn half lives in tests/transport.rs).
    #[test]
    fn shm_relay_synchronizes_without_payload_bytes() {
        let world = 3usize;
        let n = 6usize;
        let dir = fresh_socket_dir().unwrap();
        let slot_elems = (n + shm::SLOT_HEADROOM) as u64;
        let (coord_table, table_path) = shm::SlotTable::create(&dir, world, slot_elems).unwrap();
        // The coordinator holds no mapping: workers open their own handles.
        drop(coord_table);
        let sock = dir.join(SOCKET_NAME);
        let listener = UnixListener::bind(&sock).unwrap();
        let clients: Vec<UnixStream> = (0..world)
            .map(|_| UnixStream::connect(&sock).unwrap())
            .collect();
        let serves: Vec<UnixStream> = (0..world).map(|_| listener.accept().unwrap().0).collect();
        let cell: FailureCell = std::sync::Arc::new(std::sync::Mutex::new(None));
        let relay = std::thread::spawn(move || relay_loop(serves, cell, Some(slot_elems)));
        let handles: Vec<std::thread::JoinHandle<()>> = clients
            .into_iter()
            .enumerate()
            .map(|(rank, stream)| {
                let table_path = table_path.clone();
                std::thread::spawn(move || {
                    let table =
                        shm::SlotTable::open(&table_path, world, slot_elems).unwrap();
                    let mut t = ProcessTransport {
                        rank,
                        world,
                        stream,
                        reply_bytes: 0,
                        shm: Some(WorkerShm {
                            table,
                            gen: 0,
                            bytes: Vec::new(),
                            slots: Vec::new(),
                        }),
                    };
                    // Round 1: full exchange — every peer body, rank order.
                    let data: Vec<f32> = (0..n).map(|i| (rank * 100 + i) as f32).collect();
                    let mut check_full = |slots: &[&[f32]]| -> Vec<f32> {
                        assert_eq!(slots.len(), world);
                        for (r, s) in slots.iter().enumerate() {
                            let expect: Vec<f32> =
                                (0..n).map(|i| (r * 100 + i) as f32).collect();
                            assert_eq!(s, &expect.as_slice(), "wrong body from rank {r}");
                        }
                        slots.iter().map(|s| s[0]).collect()
                    };
                    let _ = t.exchange(data.clone(), None, &mut check_full);
                    // Round 2: ranged exchange — each rank reads only its
                    // own 2-element window of every peer.
                    let (lo, hi) = (rank * 2, rank * 2 + 2);
                    let mut check_ranged = |slots: &[&[f32]]| -> Vec<f32> {
                        for (r, s) in slots.iter().enumerate() {
                            let expect: Vec<f32> =
                                (lo..hi).map(|i| (r * 100 + i) as f32).collect();
                            assert_eq!(s, &expect.as_slice(), "wrong window from rank {r}");
                        }
                        Vec::new()
                    };
                    let _ = t.exchange(data, Some((lo, hi)), &mut check_ranged);
                    // Round 3: barrier (empty payload) still synchronizes.
                    t.barrier();
                    assert_eq!(
                        t.reply_bytes, 0,
                        "shm plane must put zero payload bytes on the socket"
                    );
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        relay.join().unwrap();
        cleanup_socket(&sock);
    }
}
