//! FSDP mode: worker threads owning parameter / optimizer-state *shards*.
//!
//! Every parameter is sharded along its *longer* dimension — which is
//! exactly the dimension the GaLore projector does NOT span, so a
//! leader-computed P applies unchanged to every shard:
//!
//!   wide  W (m ≤ n): P is m×r (left), shard columns → R = Pᵀ·G_shard
//!   tall  W (m > n): P is n×r (right), shard rows   → R = G_shard·P
//!
//! Per-layer fused update (Fig. 2), pipelined: the step loop issues layer
//! k+1's reduce to the rank's comm thread (`dist/pipeline.rs`) before
//! consuming layer k's shard in `step_param`, hiding collective latency
//! behind optimizer compute. Consumption stays strictly in issue order and
//! the fixed-tree order within each layer is untouched, so the schedule
//! change is bitwise invisible; at most TWO full-size gradient buffers are
//! live per worker (the consumed layer plus the in-flight one — the extra
//! buffer is charged in `peak_transient_bytes` identically in serial and
//! overlapped mode). Refresh layers gate the lookahead: their subspace
//! broadcast must be the next collective in FIFO order, so the following
//! layer is issued only after the broadcast completes.
//!
//! Subspace refreshes (§4.3): on refresh steps the full averaged gradient
//! is materialized on every rank (all-reduce), the leader computes the
//! randomized SVD once, and P is broadcast and installed via
//! [`GaLore::preset_projector`] — workers never SVD their own shards,
//! whose spectra would be wrong.
//!
//! The protocol/spawn/shutdown scaffolding is the generic
//! [`Cluster`](super::Cluster); this file only defines what an FSDP rank
//! stores and the shard-specific cluster surface (gather, per-rank
//! optimizer frames).
//!
//! [`GaLore::preset_projector`]: crate::optim::GaLore::preset_projector

use super::cluster::{
    assemble, shard_axis, shard_bounds, slice_shard, Cluster, MemoryReport, ParamMeta, ShardAxis,
    StepTiming, StepTraffic, Worker,
};
use super::comm::{Collective, Comm};
use super::pipeline::{monotonic_ns, overlap_enabled, CommDriver};
use super::{BuildTarget, OptimizerSpec, WorkerOpt};
use crate::optim::{Projector, ProjectorSide};
use crate::tensor::Matrix;
use crate::util::rng::Pcg64;
use std::collections::VecDeque;

/// A world of persistent workers (threads or processes, per
/// [`super::TransportKind`]) with sharded optimizer state.
pub type FsdpCluster = Cluster<FsdpWorker>;

/// One FSDP rank: its shards + optimizer + comm handle.
pub struct FsdpWorker {
    rank: usize,
    world: usize,
    comm: CommDriver,
    metas: Vec<ParamMeta>,
    galore: Option<crate::optim::GaLoreCfg>,
    opt: WorkerOpt,
    shards: Vec<Matrix>,
    /// Leader-only RNG stream for subspace SVDs (deterministic: refresh
    /// order is fixed by the step/param loop).
    svd_rng: Pcg64,
    peak_transient: usize,
    /// Timing of the most recent step (worker-blocked comm vs the rest),
    /// surfaced through `Worker::last_step_timing`.
    last_timing: StepTiming,
    /// Data-plane traffic of the most recent step (per-step deltas of the
    /// process-wide transport counters), surfaced through
    /// `Worker::last_step_traffic`.
    last_traffic: StepTraffic,
}

impl Worker for FsdpWorker {
    const MODE: &'static str = "fsdp";

    fn new(
        rank: usize,
        world: usize,
        comm: Comm,
        metas: Vec<ParamMeta>,
        spec: OptimizerSpec,
        seed: u64,
    ) -> FsdpWorker {
        let galore = spec.galore_cfg();
        // Per-rank optimizer seed (only hygiene — in external-subspace mode
        // workers never draw from their optimizer RNG).
        let opt = spec
            .build(
                seed ^ (rank as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15),
                BuildTarget::Worker {
                    external_subspace: true,
                },
            )
            .expect("spec validated in Cluster::new");
        FsdpWorker {
            rank,
            world,
            comm: CommDriver::new(comm, overlap_enabled()),
            metas,
            galore,
            opt,
            // Same stream constant as the single-process GaLore optimizer:
            // the leader's refresh SVDs then draw the identical sketch
            // sequence, making FSDP(world=1) trajectories match Single mode
            // bitwise (tests/engine_parity.rs pins this).
            svd_rng: Pcg64::new(seed, 0x6a10),
            peak_transient: 0,
            last_timing: StepTiming::default(),
            last_traffic: StepTraffic::default(),
        }
    }

    fn install(&mut self, full: Vec<Matrix>) {
        assert_eq!(full.len(), self.metas.len());
        self.shards = full
            .iter()
            .zip(&self.metas)
            .map(|(p, meta)| {
                assert_eq!(
                    p.shape(),
                    (meta.rows, meta.cols),
                    "{}: param/meta shape mismatch",
                    meta.name
                );
                let axis = shard_axis(meta.rows, meta.cols);
                let len = match axis {
                    ShardAxis::Rows => meta.rows,
                    ShardAxis::Cols => meta.cols,
                };
                let (lo, hi) = shard_bounds(len, self.world, self.rank);
                slice_shard(p, axis, lo, hi)
            })
            .collect();
    }

    fn step(&mut self, t: u64, lr: f32, grads: Vec<Matrix>) {
        assert_eq!(grads.len(), self.shards.len(), "init_params before step");
        let wall0 = monotonic_ns();
        let (sock0, shm0) = super::process::wire_traffic();
        self.opt.as_opt().begin_step(t);
        let scale = 1.0 / self.world as f32;

        // The whole step's refresh schedule, decided up front (needed to
        // gate the lookahead below). Valid to precompute: layer idx's
        // `preset_projector` only ever changes `has_projector(idx)` for
        // idx itself, and the serial schedule checks before installing.
        let refresh: Vec<bool> = (0..grads.len())
            .map(|idx| {
                let (m, n) = (self.metas[idx].rows, self.metas[idx].cols);
                let projects = self.galore.map_or(false, |g| g.projects(m, n));
                projects
                    && (t % self.galore.unwrap().update_freq == 0
                        || !self.opt.has_projector(idx))
            })
            .collect();

        // Issue-ahead + consume-in-order: layer k+1's reduce is in flight
        // while layer k's shard feeds `step_param`. Identical issue order
        // on every rank (the refresh flags are deterministic and
        // lockstep), so pipelined collectives pair up rank-for-rank.
        let mut queue: VecDeque<(usize, Matrix)> = grads.into_iter().enumerate().collect();
        let mut issued: VecDeque<Pending> = VecDeque::new();
        if let Some((idx, grad)) = queue.pop_front() {
            issued.push_back(self.issue_layer(idx, grad, refresh[idx]));
        }
        while let Some(p) = issued.pop_front() {
            // A refresh layer's subspace broadcast must be the next
            // collective in FIFO order — defer the lookahead until after
            // the broadcast has run (inside consume_layer).
            if !p.refresh {
                if let Some((idx, grad)) = queue.pop_front() {
                    issued.push_back(self.issue_layer(idx, grad, refresh[idx]));
                }
            }
            // The in-flight layer's gradient is buffered in the pipeline
            // while this layer is consumed — charge it. `issued` holds at
            // most one entry here (queue depth 2), and the charge is
            // schedule-determined, so serial mode reports identical peaks.
            let extra: usize = issued.iter().map(|q| q.bytes).sum();
            self.consume_layer(&p, extra, scale, lr);
            if p.refresh {
                if let Some((idx, grad)) = queue.pop_front() {
                    issued.push_back(self.issue_layer(idx, grad, refresh[idx]));
                }
            }
        }

        let comm_ns = self.comm.take_comm_ns();
        let wall = monotonic_ns() - wall0;
        self.last_timing = StepTiming {
            comm_ns,
            compute_ns: wall.saturating_sub(comm_ns),
        };
        let (sock, shm) = super::process::wire_traffic();
        self.last_traffic = StepTraffic {
            socket_bytes: sock - sock0,
            shm_bytes: shm - shm0,
            peak_transient_bytes: (self.peak_transient + super::process::shm_inflight_bytes())
                as u64,
        };
    }

    fn params(&self) -> Vec<Matrix> {
        self.shards.clone()
    }

    /// Worker frame: `[svd_rng position][optimizer blob]`. The SVD stream
    /// position rides along so a resumed run's next leader refresh draws
    /// the sketches the uninterrupted run would have.
    fn export_state(&self) -> Vec<u8> {
        let mut out = Vec::new();
        self.svd_rng.write_state(&mut out);
        out.extend_from_slice(&self.opt.export_state());
        out
    }

    fn import_state(&mut self, bytes: &[u8]) -> Result<(), String> {
        self.svd_rng = Pcg64::read_state(bytes)?;
        self.opt
            .as_opt()
            .import_state(&bytes[Pcg64::STATE_BYTES..])
    }

    fn report(&self) -> MemoryReport {
        let (socket_bytes, shm_bytes) = super::process::wire_traffic();
        MemoryReport {
            rank: self.rank,
            param_shard_bytes: self.shards.iter().map(|s| s.numel() * 4).sum(),
            optimizer_bytes: self.opt.state_bytes(),
            // The shm plane keeps one in-flight generation live in this
            // rank's slot under the overlap pipeline — charge it like the
            // pipeline's extra gradient buffer.
            peak_transient_bytes: self.peak_transient + super::process::shm_inflight_bytes(),
            traffic_elems: self.comm.traffic_elems(),
            socket_bytes,
            shm_bytes,
        }
    }

    fn last_step_timing(&self) -> StepTiming {
        self.last_timing
    }

    fn last_step_traffic(&self) -> StepTraffic {
        self.last_traffic
    }
}

/// One issued-but-not-yet-consumed layer: everything `consume_layer` needs
/// to interpret the comm thread's eventual reply.
struct Pending {
    idx: usize,
    refresh: bool,
    axis: ShardAxis,
    m: usize,
    n: usize,
    lo: usize,
    hi: usize,
    /// Full-gradient footprint held by the pipeline while the reduce is in
    /// flight, charged to the consuming layer's transient peak.
    bytes: usize,
}

impl FsdpWorker {
    /// Issue layer `idx`'s reduce to the comm pipeline and record what the
    /// eventual reply means. The collective CHOICE here is exactly the
    /// serial schedule's; only the await moves to `consume_layer`.
    fn issue_layer(&self, idx: usize, grad: Matrix, refresh: bool) -> Pending {
        let (m, n) = (self.metas[idx].rows, self.metas[idx].cols);
        assert_eq!(grad.shape(), (m, n), "{}: bad grad shape", self.metas[idx].name);
        let axis = shard_axis(m, n);
        let len = match axis {
            ShardAxis::Rows => m,
            ShardAxis::Cols => n,
        };
        let (lo, hi) = shard_bounds(len, self.world, self.rank);
        let bytes = m * n * 4;
        if refresh {
            // Refresh step: materialize the full averaged gradient on every
            // rank (the leader SVDs it and broadcasts P in consume_layer).
            self.comm.issue(Collective::AllReduceSum(grad.data));
        } else {
            match axis {
                ShardAxis::Rows => {
                    // Row shards are contiguous in row-major order — a true
                    // reduce-scatter, no full buffer needed.
                    let offsets: Vec<usize> = (0..=self.world)
                        .map(|r| (r * m / self.world) * n)
                        .collect();
                    self.comm
                        .issue(Collective::ReduceScatterSum(grad.data, offsets));
                }
                ShardAxis::Cols => {
                    // Column shards interleave in row-major memory, but the
                    // TRANSPOSED gradient makes them contiguous rows — so a
                    // true reduce-scatter applies here too, cutting this
                    // path from the all-reduce's 2·(w−1)/w·n traffic to
                    // (w−1)/w·n like the row path. Bitwise-safe: the
                    // fixed-tree sum is elementwise across ranks, so
                    // transposing first only permutes element POSITIONS,
                    // never any element's cross-rank summation order.
                    let gt = grad.transpose();
                    drop(grad);
                    let offsets: Vec<usize> = (0..=self.world)
                        .map(|r| (r * n / self.world) * m)
                        .collect();
                    self.comm
                        .issue(Collective::ReduceScatterSum(gt.data, offsets));
                }
            }
        }
        Pending {
            idx,
            refresh,
            axis,
            m,
            n,
            lo,
            hi,
            bytes,
        }
    }

    /// Await layer `p`'s reduced result, finish the local math, and run the
    /// fused optimizer update. `extra` charges the in-flight lookahead
    /// layer's gradient buffer to this layer's transient peak.
    fn consume_layer(&mut self, p: &Pending, extra: usize, scale: f32, lr: f32) {
        let (m, n) = (p.m, p.n);
        let mut transient;
        let shard_grad = if p.refresh {
            let mut full = Matrix::from_vec(m, n, self.comm.wait());
            full.scale(scale);
            transient = full.numel() * 4;
            let g = self.galore.unwrap();
            let side = if m <= n {
                ProjectorSide::Left
            } else {
                ProjectorSide::Right
            };
            // The wire carries the projector's exact stored
            // representation (codes + block scales for quantized
            // kinds) so every rank installs the leader's P
            // bit-for-bit — re-quantizing dequantized values would
            // let replicas drift from a single-process run. The
            // pipeline queue is drained here (refresh layers defer
            // the lookahead), so `run` issues the broadcast as the
            // next collective in FIFO order on every rank.
            let proj = if self.rank == 0 {
                let proj =
                    Projector::from_gradient(&full, g.rank, g.projection, &mut self.svd_rng);
                self.comm
                    .run(Collective::Broadcast(0, Some(proj.encode_wire())));
                proj
            } else {
                let words = self.comm.run(Collective::Broadcast(0, None));
                Projector::decode_wire(&words, side, g.projection)
            };
            transient += proj.nbytes();
            if let Some(gal) = self.opt.galore_mut() {
                gal.preset_projector(p.idx, proj);
            }
            slice_shard(&full, p.axis, p.lo, p.hi)
        } else {
            match p.axis {
                ShardAxis::Rows => {
                    let mut sh = self.comm.wait();
                    for x in sh.iter_mut() {
                        *x *= scale;
                    }
                    transient = sh.len() * 4;
                    Matrix::from_vec(p.hi - p.lo, n, sh)
                }
                ShardAxis::Cols => {
                    let mut sh = self.comm.wait();
                    for x in sh.iter_mut() {
                        *x *= scale;
                    }
                    // The full-size transpose copy made at issue time is
                    // still the peak buffer on this path (traffic shrank;
                    // memory didn't).
                    transient = m * n * 4;
                    Matrix::from_vec(p.hi - p.lo, m, sh).transpose()
                }
            }
        };
        self.peak_transient = self
            .peak_transient
            .max(transient + shard_grad.numel() * 4 + extra);
        // Per-layer fused update: step now, drop the gradient buffers.
        self.opt
            .as_opt()
            .step_param(p.idx, &mut self.shards[p.idx], &shard_grad, lr);
    }
}

impl Cluster<FsdpWorker> {
    /// Assemble the full parameter set from every rank's shards.
    pub fn gather_params(&self) -> Vec<Matrix> {
        let per_rank = self.params_per_rank();
        self.metas()
            .iter()
            .enumerate()
            .map(|(idx, meta)| {
                let shards: Vec<&Matrix> = per_rank.iter().map(|r| &r[idx]).collect();
                assemble(meta, &shards)
            })
            .collect()
    }

    /// [`gather_params`](Cluster::gather_params) with worker death caught
    /// and attributed, for the recovery supervisor.
    pub fn try_gather_params(&mut self) -> Result<Vec<Matrix>, super::WorkerLoss> {
        let per_rank = self.try_params_per_rank()?;
        Ok(self
            .metas()
            .iter()
            .enumerate()
            .map(|(idx, meta)| {
                let shards: Vec<&Matrix> = per_rank.iter().map(|r| &r[idx]).collect();
                assemble(meta, &shards)
            })
            .collect())
    }

    /// Serialized optimizer state of rank 0 (shard-local; diagnostic use —
    /// checkpoints go through the canonical form in
    /// `checkpoint::canonical`).
    pub fn export_rank0_optimizer(&self) -> Vec<u8> {
        self.export_rank_frame(0)
    }

    /// Serialize EVERY rank's shard-local state (optimizer moments + the
    /// worker's SVD-stream position) into one *world-locked* framed blob:
    /// `[world u64] ([len u64][bytes])×world`. This is the legacy (v2)
    /// checkpoint payload; v3 checkpoints store the world-agnostic
    /// canonical form instead (`checkpoint::canonical`).
    pub fn export_optimizers(&self) -> Vec<u8> {
        let frames = self.export_frames();
        let mut out = Vec::new();
        crate::optim::ser::push_u64(&mut out, self.world() as u64);
        for b in &frames {
            crate::optim::ser::push_u64(&mut out, b.len() as u64);
            out.extend_from_slice(b);
        }
        out
    }

    /// Restore per-rank optimizer state from an [`export_optimizers`] blob.
    /// Fails (without touching worker state) when the blob was written at a
    /// different world size — legacy per-rank frames are world-locked; to
    /// move across worlds, resume at the original world and re-save, which
    /// writes the re-shardable canonical (v3) form.
    ///
    /// [`export_optimizers`]: Cluster::export_optimizers
    pub fn import_optimizers(&self, bytes: &[u8]) -> Result<(), String> {
        let mut r = crate::optim::ser::Reader::new(bytes);
        let world = r.u64()? as usize;
        if world != self.world() {
            return Err(format!(
                "optimizer state was saved at world={world}, cluster has world={}; \
                 legacy per-rank (v2) state is world-locked — resume with --parallel \
                 fsdp --world {world} and re-save to migrate to the re-shardable v3 \
                 checkpoint form",
                self.world()
            ));
        }
        let mut frames = Vec::with_capacity(world);
        for _ in 0..world {
            let len = r.u64()? as usize;
            frames.push(r.bytes(len)?.to_vec());
        }
        self.import_frames(frames)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{step_all, AdamCfg, AdamW, GaLoreCfg, ProjectionKind};

    fn metas(shapes: &[(usize, usize)]) -> Vec<ParamMeta> {
        shapes
            .iter()
            .enumerate()
            .map(|(i, &(r, c))| ParamMeta {
                name: format!("p{i}"),
                rows: r,
                cols: c,
            })
            .collect()
    }

    fn init_set(shapes: &[(usize, usize)], seed: u64) -> Vec<Matrix> {
        let mut rng = Pcg64::new(seed, 0);
        shapes
            .iter()
            .map(|&(r, c)| Matrix::randn(r, c, 0.5, &mut rng))
            .collect()
    }

    /// Identical gradients on every rank make the averaged gradient equal
    /// to the single-rank gradient *bitwise* (sum of w equal values is an
    /// exact power-of-two multiple for w ∈ {1,2,4}, then ·1/w is exact),
    /// so runs become comparable across world sizes.
    fn grad_set(shapes: &[(usize, usize)], seed: u64) -> Vec<Matrix> {
        let mut rng = Pcg64::new(seed, 1);
        shapes
            .iter()
            .map(|&(r, c)| Matrix::randn(r, c, 0.1, &mut rng))
            .collect()
    }

    const SHAPES: &[(usize, usize)] = &[(12, 24), (24, 12), (16, 16), (1, 16)];

    fn run_cluster(world: usize, spec: OptimizerSpec, steps: u64) -> Vec<Matrix> {
        let mut cluster = FsdpCluster::new(world, metas(SHAPES), spec, 42);
        cluster.init_params(&init_set(SHAPES, 7));
        for t in 0..steps {
            let grads = grad_set(SHAPES, 100 + t);
            let per_rank = vec![grads; world];
            cluster.step(t, per_rank, 0.05);
        }
        cluster.gather_params()
    }

    #[test]
    fn world1_adamw_matches_single_process_step_all() {
        let got = run_cluster(1, OptimizerSpec::AdamW(AdamCfg::default()), 5);
        let mut params = init_set(SHAPES, 7);
        let mut opt = AdamW::new(AdamCfg::default());
        for t in 0..5 {
            let grads = grad_set(SHAPES, 100 + t);
            step_all(&mut opt, t, &mut params, &grads, 0.05);
        }
        for (a, b) in got.iter().zip(&params) {
            assert_eq!(a.data, b.data, "world-1 cluster diverged from step_all");
        }
    }

    #[test]
    fn adamw_bitwise_invariant_across_world_sizes() {
        let w1 = run_cluster(1, OptimizerSpec::AdamW(AdamCfg::default()), 4);
        let w2 = run_cluster(2, OptimizerSpec::AdamW(AdamCfg::default()), 4);
        let w4 = run_cluster(4, OptimizerSpec::AdamW(AdamCfg::default()), 4);
        for ((a, b), c) in w1.iter().zip(&w2).zip(&w4) {
            assert_eq!(a.data, b.data, "world 1 vs 2 diverged");
            assert_eq!(a.data, c.data, "world 1 vs 4 diverged");
        }
    }

    fn galore_spec() -> OptimizerSpec {
        OptimizerSpec::GaLore {
            galore: GaLoreCfg {
                rank: 4,
                update_freq: 3,
                alpha: 1.0,
                projection: ProjectionKind::RandSvd,
                ..GaLoreCfg::default()
            },
            adam: AdamCfg::default(),
        }
    }

    #[test]
    fn galore_bitwise_invariant_across_world_sizes() {
        // Elementwise inner Adam + shard-compatible projector application
        // (P spans the un-sharded dimension) make the whole GaLore step
        // world-size invariant given identical per-rank microbatches.
        let w1 = run_cluster(1, galore_spec(), 7);
        let w2 = run_cluster(2, galore_spec(), 7);
        let w4 = run_cluster(4, galore_spec(), 7);
        for (idx, ((a, b), c)) in w1.iter().zip(&w2).zip(&w4).enumerate() {
            assert_eq!(a.data, b.data, "param {idx}: world 1 vs 2 diverged");
            assert_eq!(a.data, c.data, "param {idx}: world 1 vs 4 diverged");
        }
    }

    #[test]
    fn odd_worlds_run_and_partition_state() {
        // Non-power-of-two worlds (3, 5): not bitwise-comparable to world 1
        // (averaging by 3 or 5 rounds), but every step must run, shards
        // must partition the params — including the (1, 16) bias, which
        // leaves some ranks with empty shards at world 5 — and repeated
        // runs must be deterministic.
        for world in [3usize, 5] {
            let a = run_cluster(world, galore_spec(), 6);
            let b = run_cluster(world, galore_spec(), 6);
            for (idx, (x, y)) in a.iter().zip(&b).enumerate() {
                assert_eq!(x.data, y.data, "world {world} param {idx} not deterministic");
                assert!(
                    x.data.iter().all(|v| v.is_finite()),
                    "world {world} param {idx} non-finite"
                );
            }
        }
    }

    #[test]
    fn galore_learns_low_rank_target_under_fsdp() {
        // Convex quadratic with a low-rank offset: grads differ per rank
        // (each rank sees a noisy microbatch), loss must still fall.
        let shapes = &[(16, 32)];
        let mut rng = Pcg64::new(3, 0);
        let u = Matrix::randn(16, 3, 1.0, &mut rng);
        let v = Matrix::randn(3, 32, 1.0, &mut rng);
        let target = u.matmul(&v);
        let world = 2;
        let mut cluster = FsdpCluster::new(
            world,
            metas(shapes),
            OptimizerSpec::GaLore {
                galore: GaLoreCfg {
                    rank: 3,
                    update_freq: 25,
                    alpha: 1.0,
                    ..GaLoreCfg::default()
                },
                adam: AdamCfg::default(),
            },
            11,
        );
        let mut w = vec![Matrix::zeros(16, 32)];
        cluster.init_params(&w);
        for t in 0..200 {
            let mut per_rank = Vec::new();
            for r in 0..world {
                let mut g = w[0].sub(&target);
                // microbatch noise, different per rank
                let noise = Matrix::randn(16, 32, 0.01, &mut Pcg64::new(t, r as u64));
                g.add_assign(&noise);
                per_rank.push(vec![g]);
            }
            cluster.step(t, per_rank, 0.05);
            w = cluster.gather_params();
        }
        let rel = w[0].sub(&target).frobenius_norm() / target.frobenius_norm();
        assert!(rel < 0.1, "FSDP GaLore did not converge: rel {rel}");
    }

    #[test]
    fn memory_reports_cover_all_params_and_traffic() {
        let world = 4;
        let mut cluster = FsdpCluster::new(world, metas(SHAPES), galore_spec(), 5);
        cluster.init_params(&init_set(SHAPES, 7));
        cluster.step(0, vec![grad_set(SHAPES, 9); world], 0.01);
        let reports = cluster.memory_reports();
        assert_eq!(reports.len(), world);
        let total_param: usize = reports.iter().map(|r| r.param_shard_bytes).sum();
        let expect: usize = SHAPES.iter().map(|&(r, c)| r * c * 4).sum();
        assert_eq!(total_param, expect, "shards must partition the params");
        for r in &reports {
            assert!(r.optimizer_bytes > 0);
            assert!(r.traffic_elems > 0);
            assert!(r.peak_transient_bytes > 0);
        }
        // Sharded GaLore moments: each rank's optimizer state is well below
        // full-model AdamW state (2·4 bytes/elem).
        let full_adam: usize = SHAPES.iter().map(|&(r, c)| 2 * r * c * 4).sum();
        assert!(reports[0].optimizer_bytes < full_adam);
    }

    #[test]
    fn wide_layers_pay_reduce_scatter_not_all_reduce_traffic() {
        // ROADMAP follow-up (PR 1): column-sharded (wide) layers used to
        // all-reduce their full gradient (2·(w−1)/w·n elems per rank); the
        // transpose-aware reduce-scatter must charge (w−1)/w·n — the same
        // ring cost as the row-sharded path. Exact equality on the Comm
        // traffic counters, so a regression to all-reduce (or any hidden
        // extra collective) fails loudly.
        let world = 4;
        for &shape in &[(8usize, 32usize), (32, 8)] {
            let shapes = &[shape];
            let mut cluster = FsdpCluster::new(
                world,
                metas(shapes),
                OptimizerSpec::AdamW(AdamCfg::default()),
                3,
            );
            cluster.init_params(&init_set(shapes, 7));
            let steps = 3u64;
            for t in 0..steps {
                cluster.step(t, vec![grad_set(shapes, 50 + t); world], 0.01);
            }
            let n = (shape.0 * shape.1) as u64;
            let expect = steps * ((world as u64 - 1) * n / world as u64);
            for r in cluster.memory_reports() {
                assert_eq!(
                    r.traffic_elems, expect,
                    "rank {} of {shape:?}: sharded-grad traffic must follow \
                     the reduce-scatter model",
                    r.rank
                );
            }
        }
    }

    #[test]
    fn optimizer_state_roundtrips_across_all_ranks() {
        // FSDP resume contract: export_optimizers captures every rank's
        // shard-local moments; a fresh cluster restored from the blob (plus
        // re-scattered params) continues bitwise identically.
        let world = 2;
        let mut cluster = FsdpCluster::new(
            world,
            metas(SHAPES),
            OptimizerSpec::AdamW(AdamCfg::default()),
            1,
        );
        cluster.init_params(&init_set(SHAPES, 7));
        cluster.step(0, vec![grad_set(SHAPES, 3); world], 0.01);
        let blob = cluster.export_optimizers();
        let mut restored = FsdpCluster::new(
            world,
            metas(SHAPES),
            OptimizerSpec::AdamW(AdamCfg::default()),
            99,
        );
        restored.init_params(&cluster.gather_params());
        restored.import_optimizers(&blob).unwrap();
        cluster.step(1, vec![grad_set(SHAPES, 4); world], 0.01);
        restored.step(1, vec![grad_set(SHAPES, 4); world], 0.01);
        let a = cluster.gather_params();
        let b = restored.gather_params();
        for (idx, (x, y)) in a.iter().zip(&b).enumerate() {
            assert_eq!(x.data, y.data, "param {idx}: restored cluster diverged");
        }
        // A different world size must be rejected (legacy per-rank frames
        // are world-locked) with an actionable message.
        let other_world = FsdpCluster::new(
            4,
            metas(SHAPES),
            OptimizerSpec::AdamW(AdamCfg::default()),
            1,
        );
        let err = other_world.import_optimizers(&blob).unwrap_err();
        assert!(err.contains("world=2"), "unhelpful error: {err}");
    }

    #[test]
    fn rank0_optimizer_state_exports() {
        let world = 2;
        let mut cluster =
            FsdpCluster::new(world, metas(SHAPES), OptimizerSpec::AdamW(AdamCfg::default()), 1);
        cluster.init_params(&init_set(SHAPES, 7));
        cluster.step(0, vec![grad_set(SHAPES, 3); world], 0.01);
        let state = cluster.export_rank0_optimizer();
        assert!(!state.is_empty(), "AdamW state must serialize");
    }

    #[test]
    fn gather_roundtrips_init_params_before_any_step() {
        let world = 3;
        let cluster =
            FsdpCluster::new(world, metas(SHAPES), OptimizerSpec::AdamW(AdamCfg::default()), 1);
        let init = init_set(SHAPES, 7);
        cluster.init_params(&init);
        let got = cluster.gather_params();
        for (a, b) in got.iter().zip(&init) {
            assert_eq!(a.data, b.data, "shard/assemble roundtrip lost data");
        }
    }
}
