//! Shared-memory data plane for the process transport: the slot table.
//!
//! The coordinator allocates one tmpfs-backed slot-table file per cluster
//! inside the private rendezvous directory (the same 0700 directory the
//! Unix socket lives in), sized from the largest parameter. Workers open
//! it by path during the setup handshake, and from then on gradient
//! payloads move through the table instead of the socket byte stream: a
//! rank `pwrite`s its contribution into its own slot, the relay
//! synchronizes the round with header-only control frames, and every rank
//! `pread`s its peers' windows and runs the same fixed-tree reduction it
//! always ran — zero f32 payload bytes cross the socket.
//!
//! Design note: the ideal shape of this plane is `memfd_create` + `mmap`
//! with the fd passed over the socket via `SCM_RIGHTS`. All three need
//! raw syscalls the crate's no-new-dependencies rule keeps out (no
//! `libc`), so the implementation uses the closest pure-std equivalent: a
//! file in the already-private rendezvous dir (tmpfs on every target we
//! run on), positioned reads/writes (`FileExt::{read_at, write_at}` —
//! pread/pwrite, no shared cursor), and path-based open during the
//! handshake. The file is unlinked as soon as every rank is ready, so —
//! exactly like a memfd — it has no filesystem presence during the run
//! and the kernel reclaims it when the last fd closes, even if a worker
//! is killed mid-collective.
//!
//! Geometry: `world × LANES × slot_elems` f32 regions. `slot_elems` is
//! the largest payload any collective can carry (max layer numel, plus
//! headroom for the projector wire encoding's header words). `LANES = 2`
//! double-buffers generations: with the overlap pipeline's depth-2 FIFO,
//! rank A may deposit generation g+1 while rank B is still reading
//! generation g, so each rank alternates lanes (`lane = gen % 2`). A
//! third generation cannot be in flight: depositing g+2 requires having
//! finished round g+1, which the relay only completes after every rank
//! finished (and therefore fully read) round g.

use std::fs::{File, OpenOptions};
use std::io;
use std::os::unix::fs::FileExt;
use std::path::{Path, PathBuf};

/// Generations double-buffered per rank (see module docs).
pub(crate) const LANES: u64 = 2;

/// Elements of headroom beyond the largest layer: the projector broadcast
/// ships `StoredTensor` bytes packed into words, whose header/scale
/// overhead rides on top of a payload already bounded by the layer size.
pub(crate) const SLOT_HEADROOM: usize = 64;

/// Hard cap on the whole table (16 GiB) — mirrors the wire frame cap, so
/// a corrupt setup frame can never size an absurd segment.
pub(crate) const MAX_TABLE_BYTES: u64 = 1 << 34;

/// File name inside the rendezvous directory.
pub(crate) const FILE_NAME: &str = "slots.shm";

/// Slot size covering every payload the collectives of `metas` can carry.
pub(crate) fn slot_elems_for(metas: &[super::cluster::ParamMeta]) -> usize {
    let largest = metas
        .iter()
        .map(|m| m.rows.saturating_mul(m.cols))
        .max()
        .unwrap_or(0);
    largest.saturating_add(SLOT_HEADROOM)
}

/// Total table size in bytes, with every multiplication checked and the
/// result bounded — this is the guard between a setup-declared geometry
/// and any allocation or file mapping derived from it.
pub(crate) fn table_bytes(world: usize, slot_elems: u64) -> Result<u64, String> {
    let total = (world as u64)
        .checked_mul(LANES)
        .and_then(|x| x.checked_mul(slot_elems))
        .and_then(|x| x.checked_mul(4))
        .ok_or_else(|| {
            format!("slot-table geometry overflows: {world} ranks x {LANES} lanes x {slot_elems} elems")
        })?;
    if total > MAX_TABLE_BYTES {
        return Err(format!(
            "slot table of {total} bytes exceeds the {MAX_TABLE_BYTES}-byte cap"
        ));
    }
    Ok(total)
}

/// One mapped slot table: a file handle plus its validated geometry.
pub(crate) struct SlotTable {
    file: File,
    world: usize,
    slot_elems: u64,
}

impl SlotTable {
    /// Coordinator side: create the table file inside the (private)
    /// rendezvous directory and size it. The returned handle can be
    /// dropped immediately — workers open their own.
    pub(crate) fn create(dir: &Path, world: usize, slot_elems: u64) -> io::Result<(SlotTable, PathBuf)> {
        let total = table_bytes(world, slot_elems)
            .map_err(|e| io::Error::new(io::ErrorKind::InvalidInput, e))?;
        let path = dir.join(FILE_NAME);
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create_new(true)
            .open(&path)?;
        file.set_len(total)?;
        Ok((
            SlotTable {
                file,
                world,
                slot_elems,
            },
            path,
        ))
    }

    /// Worker side: open the table the setup frame named and verify the
    /// file is exactly the size the declared geometry implies — the
    /// length is bounded (checked math + cap) *before* any region of it
    /// is read or written.
    pub(crate) fn open(path: &Path, world: usize, slot_elems: u64) -> Result<SlotTable, String> {
        let declared = table_bytes(world, slot_elems)?;
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .open(path)
            .map_err(|e| format!("open slot table {}: {e}", path.display()))?;
        let got = file
            .metadata()
            .map_err(|e| format!("stat slot table {}: {e}", path.display()))?
            .len();
        if got != declared {
            return Err(format!(
                "slot table {} is {got} bytes but the setup-declared geometry needs {declared}",
                path.display()
            ));
        }
        Ok(SlotTable {
            file,
            world,
            slot_elems,
        })
    }

    /// Byte size of one slot — the per-rank in-flight footprint one
    /// pipelined generation keeps live (charged into `peak_transient`).
    pub(crate) fn slot_bytes(&self) -> u64 {
        self.slot_elems * 4
    }

    fn offset(&self, rank: usize, lane: u64) -> Result<u64, String> {
        if rank >= self.world || lane >= LANES {
            return Err(format!(
                "slot ({rank}, lane {lane}) outside {}x{LANES} table",
                self.world
            ));
        }
        // In-bounds by construction: table_bytes validated the product.
        Ok(((rank as u64) * LANES + lane) * self.slot_elems * 4)
    }

    /// Deposit a payload into `(rank, lane)`. `scratch` is the reusable
    /// byte conversion buffer.
    pub(crate) fn write_slot(
        &self,
        rank: usize,
        lane: u64,
        data: &[f32],
        scratch: &mut Vec<u8>,
    ) -> Result<(), String> {
        if data.len() as u64 > self.slot_elems {
            return Err(format!(
                "payload of {} elements exceeds the {}-element slot",
                data.len(),
                self.slot_elems
            ));
        }
        super::wire::f32s_into_bytes(data, scratch);
        let off = self.offset(rank, lane)?;
        self.file
            .write_all_at(scratch, off)
            .map_err(|e| format!("slot ({rank}, lane {lane}) write: {e}"))
    }

    /// Read elements `[lo, hi)` of the payload in `(rank, lane)` into
    /// `out` (cleared first); `scratch` is the reusable byte buffer.
    pub(crate) fn read_slot(
        &self,
        rank: usize,
        lane: u64,
        lo: usize,
        hi: usize,
        scratch: &mut Vec<u8>,
        out: &mut Vec<f32>,
    ) -> Result<(), String> {
        if lo > hi || hi as u64 > self.slot_elems {
            return Err(format!(
                "slot window [{lo}, {hi}) outside the {}-element slot",
                self.slot_elems
            ));
        }
        let nbytes = (hi - lo) * 4;
        scratch.clear();
        scratch.resize(nbytes, 0);
        let off = self.offset(rank, lane)? + (lo as u64) * 4;
        self.file
            .read_exact_at(scratch, off)
            .map_err(|e| format!("slot ({rank}, lane {lane}) read [{lo}, {hi}): {e}"))?;
        super::wire::bytes_into_f32s(scratch, out)
    }
}

/// A worker's per-round control message: replaces the f32 payload on the
/// socket when the shm data plane is active.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) struct Ctrl {
    /// Scatter window this rank wants of every peer (`None` = full).
    pub need: Option<(usize, usize)>,
    /// Round counter; the relay verifies all ranks agree before releasing
    /// the round, so a desynced worker dies loudly instead of reading a
    /// stale lane.
    pub gen: u64,
    /// Elements this rank deposited in its slot this round.
    pub elems: u64,
}

/// Byte layout of the shm control plane. This `mod header` region is the
/// one sanctioned raw-`le_bytes` island outside `dist/wire.rs` /
/// `optim::ser` / `quant/` — the single-parser lint rule allowlists
/// exactly this block.
pub(crate) mod header {
    use super::Ctrl;

    /// `[kind u8][lo u64][hi u64][gen u64][elems u64]` — fixed size, no
    /// payload bytes ever follow.
    pub(crate) const CTRL_LEN: usize = 33;
    /// Full exchange through the slot table (lo/hi unused, zero).
    pub(crate) const KIND_SHM_FULL: u8 = 2;
    /// Ranged exchange: each rank reads only `[lo, hi)` of every peer.
    pub(crate) const KIND_SHM_RANGED: u8 = 3;

    pub(crate) fn encode_ctrl(c: &Ctrl) -> [u8; CTRL_LEN] {
        let mut out = [0u8; CTRL_LEN];
        match c.need {
            Some((lo, hi)) => {
                out[0] = KIND_SHM_RANGED;
                out[1..9].copy_from_slice(&(lo as u64).to_le_bytes());
                out[9..17].copy_from_slice(&(hi as u64).to_le_bytes());
            }
            None => out[0] = KIND_SHM_FULL,
        }
        out[17..25].copy_from_slice(&c.gen.to_le_bytes());
        out[25..33].copy_from_slice(&c.elems.to_le_bytes());
        out
    }

    pub(crate) fn decode_ctrl(frame: &[u8]) -> Result<Ctrl, String> {
        if frame.len() != CTRL_LEN {
            return Err(format!(
                "shm control frame is {} bytes, expected exactly {CTRL_LEN}",
                frame.len()
            ));
        }
        let u64_at = |at: usize| {
            let mut b = [0u8; 8];
            b.copy_from_slice(&frame[at..at + 8]);
            u64::from_le_bytes(b)
        };
        let gen = u64_at(17);
        let elems = u64_at(25);
        let need = match frame[0] {
            KIND_SHM_FULL => None,
            KIND_SHM_RANGED => {
                let (lo, hi) = (u64_at(1), u64_at(9));
                if lo > hi || hi > elems {
                    return Err(format!(
                        "shm window [{lo}, {hi}) out of bounds for a {elems}-element deposit"
                    ));
                }
                Some((lo as usize, hi as usize))
            }
            other => return Err(format!("unknown shm control kind {other}")),
        };
        Ok(Ctrl { need, gen, elems })
    }

    /// The relay's release frame: `[gen u64][elems u64 × world]` — every
    /// rank learns each peer's deposit length, then reads the table
    /// directly. Control metadata only; carries no f32 payload.
    pub(crate) fn encode_go(gen: u64, elems: &[u64]) -> Vec<u8> {
        let mut out = Vec::with_capacity(8 + elems.len() * 8);
        out.extend_from_slice(&gen.to_le_bytes());
        for &e in elems {
            out.extend_from_slice(&e.to_le_bytes());
        }
        out
    }

    pub(crate) fn decode_go(frame: &[u8], world: usize) -> Result<(u64, Vec<u64>), String> {
        let want = world
            .checked_mul(8)
            .and_then(|x| x.checked_add(8))
            .ok_or_else(|| format!("go-frame size overflows for world {world}"))?;
        if frame.len() != want {
            return Err(format!(
                "shm go frame is {} bytes, expected {want} for world {world}",
                frame.len()
            ));
        }
        let u64_at = |at: usize| {
            let mut b = [0u8; 8];
            b.copy_from_slice(&frame[at..at + 8]);
            u64::from_le_bytes(b)
        };
        let gen = u64_at(0);
        let mut elems = Vec::with_capacity(world);
        for r in 0..world {
            elems.push(u64_at(8 + r * 8));
        }
        Ok((gen, elems))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn scratch_dir() -> PathBuf {
        static N: AtomicUsize = AtomicUsize::new(0);
        let dir = std::env::temp_dir().join(format!(
            "g2shm-test-{}-{}",
            std::process::id(),
            N.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn geometry_is_checked_and_capped() {
        assert_eq!(table_bytes(2, 10).unwrap(), 2 * 2 * 10 * 4);
        assert!(table_bytes(4, u64::MAX / 2).is_err(), "overflow accepted");
        assert!(
            table_bytes(4, MAX_TABLE_BYTES).is_err(),
            "table over the cap accepted"
        );
    }

    #[test]
    fn slot_elems_covers_the_largest_layer_plus_headroom() {
        let metas = vec![
            super::super::cluster::ParamMeta {
                name: "a".into(),
                rows: 12,
                cols: 24,
            },
            super::super::cluster::ParamMeta {
                name: "b".into(),
                rows: 1,
                cols: 16,
            },
        ];
        assert_eq!(slot_elems_for(&metas), 12 * 24 + SLOT_HEADROOM);
        assert_eq!(slot_elems_for(&[]), SLOT_HEADROOM);
    }

    #[test]
    fn slots_roundtrip_bit_exactly_and_windows_slice() {
        let dir = scratch_dir();
        let (table, path) = SlotTable::create(&dir, 2, 8).unwrap();
        let payload = vec![1.0f32, -0.0, f32::NAN, 2.5, -3.0, 0.125];
        let mut scratch = Vec::new();
        table.write_slot(1, 1, &payload, &mut scratch).unwrap();
        let mut out = Vec::new();
        table.read_slot(1, 1, 0, 6, &mut scratch, &mut out).unwrap();
        assert_eq!(out.len(), 6);
        for (a, b) in payload.iter().zip(&out) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // A window reads only its elements, re-indexed from the window.
        table.read_slot(1, 1, 2, 5, &mut scratch, &mut out).unwrap();
        assert_eq!(out.len(), 3);
        assert_eq!(out[0].to_bits(), f32::NAN.to_bits());
        assert_eq!(out[1], 2.5);
        // Other slots are untouched (zero-initialized by set_len).
        table.read_slot(0, 0, 0, 8, &mut scratch, &mut out).unwrap();
        assert!(out.iter().all(|x| x.to_bits() == 0));
        std::fs::remove_file(&path).unwrap();
        std::fs::remove_dir(&dir).unwrap();
    }

    #[test]
    fn out_of_bounds_slots_and_windows_error() {
        let dir = scratch_dir();
        let (table, path) = SlotTable::create(&dir, 2, 4).unwrap();
        let mut scratch = Vec::new();
        let mut out = Vec::new();
        assert!(table.write_slot(2, 0, &[1.0], &mut scratch).is_err());
        assert!(table.write_slot(0, 2, &[1.0], &mut scratch).is_err());
        assert!(table.write_slot(0, 0, &[0.0; 5], &mut scratch).is_err());
        assert!(table.read_slot(0, 0, 3, 5, &mut scratch, &mut out).is_err());
        assert!(table.read_slot(0, 0, 3, 2, &mut scratch, &mut out).is_err());
        std::fs::remove_file(&path).unwrap();
        std::fs::remove_dir(&dir).unwrap();
    }

    #[test]
    fn open_validates_size_against_declared_geometry() {
        let dir = scratch_dir();
        let (table, path) = SlotTable::create(&dir, 2, 8).unwrap();
        drop(table);
        assert!(SlotTable::open(&path, 2, 8).is_ok());
        // Wrong geometry (a lying setup frame) is refused before any IO.
        let err = SlotTable::open(&path, 4, 8).unwrap_err();
        assert!(err.contains("bytes"), "unhelpful error: {err}");
        assert!(SlotTable::open(&path, 2, 9).is_err());
        // Oversized declared geometry is refused by the cap, not mapped.
        assert!(SlotTable::open(&path, 2, MAX_TABLE_BYTES).is_err());
        std::fs::remove_file(&path).unwrap();
        std::fs::remove_dir(&dir).unwrap();
        // A vanished file is a named error, not a hang.
        assert!(SlotTable::open(&path, 2, 8).is_err());
    }

    #[test]
    fn ctrl_frames_roundtrip_and_reject_bad_input() {
        use header::*;
        for c in [
            Ctrl {
                need: None,
                gen: 0,
                elems: 0,
            },
            Ctrl {
                need: None,
                gen: 7,
                elems: 123,
            },
            Ctrl {
                need: Some((2, 9)),
                gen: u64::MAX,
                elems: 12,
            },
        ] {
            assert_eq!(decode_ctrl(&encode_ctrl(&c)).unwrap(), c);
        }
        assert!(decode_ctrl(&[]).is_err());
        assert!(decode_ctrl(&[0u8; CTRL_LEN - 1]).is_err());
        assert!(decode_ctrl(&[0u8; CTRL_LEN + 1]).is_err());
        let mut bad_kind = encode_ctrl(&Ctrl {
            need: None,
            gen: 1,
            elems: 2,
        });
        bad_kind[0] = 0; // socket kind on the shm plane
        assert!(decode_ctrl(&bad_kind).is_err());
        // Window past the deposit length.
        let oob = encode_ctrl(&Ctrl {
            need: Some((1, 50)),
            gen: 1,
            elems: 10,
        });
        assert!(decode_ctrl(&oob).is_err());
    }

    #[test]
    fn go_frames_roundtrip_and_validate_length() {
        use header::*;
        let (gen, elems) = decode_go(&encode_go(9, &[3, 0, 77]), 3).unwrap();
        assert_eq!((gen, elems), (9, vec![3, 0, 77]));
        assert!(decode_go(&encode_go(9, &[3, 0, 77]), 2).is_err());
        assert!(decode_go(&[], 1).is_err());
    }
}
