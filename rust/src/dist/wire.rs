//! Wire format for the process transport: length-framed binary messages.
//!
//! Everything that crosses a worker-process boundary is serialized here,
//! in one place, so the coordinator and the self-exec'd worker can never
//! drift: the [`Cmd`]/[`Reply`] cluster protocol, the setup payload
//! (parameter metas + [`OptimizerSpec`] + seed), and raw f32 collective
//! payloads.
//!
//! Framing: `[len u64 LE][payload]`. f32 values travel as their exact
//! little-endian bit patterns (`to_le_bytes`/`from_le_bytes`), so a
//! process-transport run is bit-for-bit the threaded run — the wire never
//! rounds.
//!
//! The decoders parse *trusted* peers (our own spawned workers), but still
//! fail with errors rather than panics on malformed input: a worker that
//! died mid-write leaves a truncated frame, and the coordinator must
//! report that, not abort.

use super::cluster::{Cmd, MemoryReport, ParamMeta, Reply};
use super::OptimizerSpec;
use crate::optim::ser::{push_f32s, push_u64, Reader};
use crate::optim::{AdamCfg, GaLoreCfg, MomentHandling, ProjectionKind};
use crate::tensor::Matrix;
use std::io::{Read, Write};

/// Upper bound on a single frame (16 GiB) — guards the length prefix of a
/// torn frame from turning into an absurd allocation.
const MAX_FRAME: u64 = 1 << 34;

pub(crate) fn write_frame(w: &mut impl Write, payload: &[u8]) -> std::io::Result<()> {
    w.write_all(&(payload.len() as u64).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()
}

pub(crate) fn read_frame(r: &mut impl Read) -> std::io::Result<Vec<u8>> {
    let mut buf = Vec::new();
    read_frame_into(r, &mut buf)?;
    Ok(buf)
}

/// Read one frame into a caller-owned scratch buffer. The buffer is
/// cleared first and keeps its capacity across calls, so a long-lived
/// connection (the cluster control plane, the relay) pays the payload
/// allocation once instead of per message.
pub(crate) fn read_frame_into(r: &mut impl Read, buf: &mut Vec<u8>) -> std::io::Result<()> {
    let mut len_b = [0u8; 8];
    r.read_exact(&mut len_b)?;
    let len = u64::from_le_bytes(len_b);
    if len > MAX_FRAME {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("frame length {len} exceeds the {MAX_FRAME}-byte cap"),
        ));
    }
    // Allocation tracks bytes actually received (`take` + `read_to_end`)
    // instead of trusting the prefix up front: a torn length under the
    // cap costs at most the real bytes on the socket, and EOF mid-frame
    // surfaces as the short-read error below.
    buf.clear();
    let got = r.by_ref().take(len).read_to_end(buf)?;
    if got as u64 != len {
        return Err(std::io::Error::new(
            std::io::ErrorKind::UnexpectedEof,
            format!("torn frame: length prefix {len}, got {got} bytes"),
        ));
    }
    Ok(())
}

pub(crate) fn f32s_to_bytes(xs: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 4);
    f32s_into_bytes(xs, &mut out);
    out
}

/// Scratch-reusing byte encoding of an f32 payload (clears `out` first) —
/// the shm data plane converts one slot per collective and must not
/// allocate per round.
pub(crate) fn f32s_into_bytes(xs: &[f32], out: &mut Vec<u8>) {
    out.clear();
    out.reserve(xs.len() * 4);
    for &x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

pub(crate) fn bytes_to_f32s(b: &[u8]) -> Result<Vec<f32>, String> {
    let mut out = Vec::new();
    bytes_into_f32s(b, &mut out)?;
    Ok(out)
}

/// Scratch-reusing decode of an f32 payload (clears `out` first).
pub(crate) fn bytes_into_f32s(b: &[u8], out: &mut Vec<f32>) -> Result<(), String> {
    if b.len() % 4 != 0 {
        return Err(format!("f32 payload length {} not a multiple of 4", b.len()));
    }
    out.clear();
    out.reserve(b.len() / 4);
    for c in b.chunks_exact(4) {
        out.push(f32::from_le_bytes(c.try_into().unwrap()));
    }
    Ok(())
}

/// Header prepended to every collective payload a worker sends the relay:
/// `[kind u8][lo u64 LE][hi u64 LE]`. Kind 0 = full exchange (every rank
/// needs every peer's whole vector; lo/hi are zero), kind 1 = ranged
/// exchange (each rank only needs `[lo, hi)` of every peer's vector — the
/// relay slices replies down to each receiver's requested window, cutting
/// reduce-scatter reply traffic from w·n to n elements per step).
pub(crate) const COMM_HDR_LEN: usize = 17;

pub(crate) fn encode_comm_frame(need: Option<(usize, usize)>, data: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(COMM_HDR_LEN + data.len() * 4);
    match need {
        Some((lo, hi)) => {
            out.push(1);
            out.extend_from_slice(&(lo as u64).to_le_bytes());
            out.extend_from_slice(&(hi as u64).to_le_bytes());
        }
        None => out.extend_from_slice(&[0u8; COMM_HDR_LEN]),
    }
    for &x in data {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// Parse a collective frame's header; returns the requested range (if
/// ranged) and the byte offset where the f32 body starts. Validates
/// without allocating: a malformed header from a dying worker must turn
/// into a relay-side named error, never a panic or a bogus slice.
pub(crate) fn decode_comm_header(frame: &[u8]) -> Result<(Option<(usize, usize)>, usize), String> {
    if frame.len() < COMM_HDR_LEN {
        return Err(format!(
            "collective frame of {} bytes is shorter than its {COMM_HDR_LEN}-byte header",
            frame.len()
        ));
    }
    if (frame.len() - COMM_HDR_LEN) % 4 != 0 {
        return Err(format!(
            "collective body length {} not a multiple of 4",
            frame.len() - COMM_HDR_LEN
        ));
    }
    let kind = frame[0];
    let mut b = [0u8; 8];
    b.copy_from_slice(&frame[1..9]);
    let lo = u64::from_le_bytes(b) as usize;
    b.copy_from_slice(&frame[9..17]);
    let hi = u64::from_le_bytes(b) as usize;
    match kind {
        0 => Ok((None, COMM_HDR_LEN)),
        1 => {
            let n = (frame.len() - COMM_HDR_LEN) / 4;
            if lo > hi || hi > n {
                return Err(format!(
                    "collective range [{lo}, {hi}) out of bounds for {n}-element body"
                ));
            }
            // Byte offsets must not overflow when the relay slices replies.
            lo.checked_mul(4)
                .and_then(|l| hi.checked_mul(4).map(|h| (l, h)))
                .ok_or_else(|| format!("collective range [{lo}, {hi}) overflows byte offsets"))?;
            Ok((Some((lo, hi)), COMM_HDR_LEN))
        }
        other => Err(format!("unknown collective frame kind {other}")),
    }
}

/// Connection preamble a worker sends on each of its two sockets:
/// `[kind u8][rank u64 LE]`. Encoded/decoded here (not in process.rs) so
/// the byte layout lives with every other wire layout.
pub(crate) const HELLO_LEN: usize = 9;

pub(crate) fn encode_hello(kind: u8, rank: usize) -> [u8; HELLO_LEN] {
    let mut hello = [0u8; HELLO_LEN];
    hello[0] = kind;
    hello[1..9].copy_from_slice(&(rank as u64).to_le_bytes());
    hello
}

pub(crate) fn decode_hello(hello: &[u8; HELLO_LEN]) -> (u8, usize) {
    let mut rank = [0u8; 8];
    rank.copy_from_slice(&hello[1..9]);
    (hello[0], u64::from_le_bytes(rank) as usize)
}

fn push_u8(out: &mut Vec<u8>, x: u8) {
    out.push(x);
}

fn push_f32(out: &mut Vec<u8>, x: f32) {
    out.extend_from_slice(&x.to_le_bytes());
}

fn push_str(out: &mut Vec<u8>, s: &str) {
    push_u64(out, s.len() as u64);
    out.extend_from_slice(s.as_bytes());
}

fn read_u8(r: &mut Reader) -> Result<u8, String> {
    Ok(r.bytes(1)?[0])
}

fn read_f32(r: &mut Reader) -> Result<f32, String> {
    let b = r.bytes(4)?;
    Ok(f32::from_le_bytes(b.try_into().unwrap()))
}

fn read_usize(r: &mut Reader) -> Result<usize, String> {
    Ok(r.u64()? as usize)
}

fn read_str(r: &mut Reader) -> Result<String, String> {
    let n = read_usize(r)?;
    if n > r.remaining() {
        return Err("truncated string".into());
    }
    String::from_utf8(r.bytes(n)?.to_vec()).map_err(|_| "non-utf8 string".into())
}

fn push_matrix(out: &mut Vec<u8>, m: &Matrix) {
    push_u64(out, m.rows as u64);
    push_u64(out, m.cols as u64);
    // The [len u64][f32 LE…] vector layout is optim::ser's — one codec,
    // one (hardened) parser for it crate-wide.
    push_f32s(out, &m.data);
}

fn read_matrix(r: &mut Reader) -> Result<Matrix, String> {
    let rows = read_usize(r)?;
    let cols = read_usize(r)?;
    let data = r.f32s()?;
    // Checked: corrupt dimensions must error here, not overflow-panic (or
    // wrap past the equality check in release) before Matrix::from_vec.
    let expect = rows
        .checked_mul(cols)
        .ok_or_else(|| format!("matrix shape {rows}x{cols} overflows"))?;
    if data.len() != expect {
        return Err(format!(
            "matrix payload has {} elements for shape {rows}x{cols}",
            data.len()
        ));
    }
    Ok(Matrix::from_vec(rows, cols, data))
}

fn push_matrices(out: &mut Vec<u8>, ms: &[Matrix]) {
    push_u64(out, ms.len() as u64);
    for m in ms {
        push_matrix(out, m);
    }
}

fn read_matrices(r: &mut Reader) -> Result<Vec<Matrix>, String> {
    let n = read_usize(r)?;
    let mut out = Vec::new();
    for _ in 0..n {
        out.push(read_matrix(r)?);
    }
    Ok(out)
}

fn push_bytes(out: &mut Vec<u8>, b: &[u8]) {
    push_u64(out, b.len() as u64);
    out.extend_from_slice(b);
}

fn read_bytes(r: &mut Reader) -> Result<Vec<u8>, String> {
    let n = read_usize(r)?;
    if n > r.remaining() {
        return Err("truncated byte blob".into());
    }
    Ok(r.bytes(n)?.to_vec())
}

// ---------------------------------------------------------------- configs

fn push_adam(out: &mut Vec<u8>, c: &AdamCfg) {
    push_f32(out, c.beta1);
    push_f32(out, c.beta2);
    push_f32(out, c.eps);
    push_f32(out, c.weight_decay);
}

fn read_adam(r: &mut Reader) -> Result<AdamCfg, String> {
    Ok(AdamCfg {
        beta1: read_f32(r)?,
        beta2: read_f32(r)?,
        eps: read_f32(r)?,
        weight_decay: read_f32(r)?,
    })
}

fn projection_tag(k: ProjectionKind) -> u8 {
    match k {
        ProjectionKind::FullSvd => 0,
        ProjectionKind::RandSvd => 1,
        ProjectionKind::Quant8 => 2,
        ProjectionKind::Quant4 => 3,
        ProjectionKind::Random => 4,
    }
}

fn projection_from_tag(t: u8) -> Result<ProjectionKind, String> {
    Ok(match t {
        0 => ProjectionKind::FullSvd,
        1 => ProjectionKind::RandSvd,
        2 => ProjectionKind::Quant8,
        3 => ProjectionKind::Quant4,
        4 => ProjectionKind::Random,
        other => return Err(format!("unknown projection tag {other}")),
    })
}

fn push_galore(out: &mut Vec<u8>, g: &GaLoreCfg) {
    push_u64(out, g.rank as u64);
    push_u64(out, g.update_freq);
    push_f32(out, g.alpha);
    push_u8(out, projection_tag(g.projection));
    push_u8(
        out,
        match g.moments {
            MomentHandling::Keep => 0,
            MomentHandling::Reset => 1,
            MomentHandling::Project => 2,
        },
    );
    push_u64(out, g.min_dim as u64);
    push_u8(out, g.external_subspace as u8);
}

fn read_galore(r: &mut Reader) -> Result<GaLoreCfg, String> {
    Ok(GaLoreCfg {
        rank: read_usize(r)?,
        update_freq: r.u64()?,
        alpha: read_f32(r)?,
        projection: projection_from_tag(read_u8(r)?)?,
        moments: match read_u8(r)? {
            0 => MomentHandling::Keep,
            1 => MomentHandling::Reset,
            2 => MomentHandling::Project,
            other => return Err(format!("unknown moment-handling tag {other}")),
        },
        min_dim: read_usize(r)?,
        external_subspace: read_u8(r)? != 0,
    })
}

/// Serialize an [`OptimizerSpec`] — every variant a worker process can
/// build. `PjrtGaLore` is refused: it holds non-`Send` device handles and
/// is single-process by contract (`OptimizerSpec::distributed_ok`).
pub(crate) fn encode_spec(out: &mut Vec<u8>, spec: &OptimizerSpec) -> Result<(), String> {
    match spec {
        OptimizerSpec::AdamW(c) => {
            push_u8(out, 0);
            push_adam(out, c);
        }
        OptimizerSpec::Adam8bit(c) => {
            push_u8(out, 1);
            push_adam(out, c);
        }
        OptimizerSpec::Adafactor { eps } => {
            push_u8(out, 2);
            push_f32(out, *eps);
        }
        OptimizerSpec::SgdM { momentum } => {
            push_u8(out, 3);
            push_f32(out, *momentum);
        }
        OptimizerSpec::GaLore { galore, adam } => {
            push_u8(out, 4);
            push_galore(out, galore);
            push_adam(out, adam);
        }
        OptimizerSpec::QGaLore {
            galore,
            adam,
            similarity_threshold,
        } => {
            push_u8(out, 5);
            push_galore(out, galore);
            push_adam(out, adam);
            push_f32(out, *similarity_threshold);
        }
        OptimizerSpec::PjrtGaLore { .. } => {
            return Err("pjrt galore cannot run on process-transport workers".into());
        }
    }
    Ok(())
}

pub(crate) fn decode_spec(r: &mut Reader) -> Result<OptimizerSpec, String> {
    Ok(match read_u8(r)? {
        0 => OptimizerSpec::AdamW(read_adam(r)?),
        1 => OptimizerSpec::Adam8bit(read_adam(r)?),
        2 => OptimizerSpec::Adafactor { eps: read_f32(r)? },
        3 => OptimizerSpec::SgdM {
            momentum: read_f32(r)?,
        },
        4 => OptimizerSpec::GaLore {
            galore: read_galore(r)?,
            adam: read_adam(r)?,
        },
        5 => OptimizerSpec::QGaLore {
            galore: read_galore(r)?,
            adam: read_adam(r)?,
            similarity_threshold: read_f32(r)?,
        },
        other => return Err(format!("unknown optimizer-spec tag {other}")),
    })
}

// ------------------------------------------------------------------ setup

/// Shared-memory data-plane parameters carried in the setup frame: where
/// the coordinator created the slot table and how it is shaped. Absent
/// (`None`) when the cluster runs on the socket data plane.
#[derive(Clone, Debug, PartialEq, Eq)]
pub(crate) struct ShmSetup {
    /// Filesystem path of the slot-table file (inside the private
    /// rendezvous directory; unlinked once every rank is ready).
    pub path: String,
    /// Elements per slot — workers re-derive and bound the full table size
    /// from this before touching the segment.
    pub slot_elems: u64,
}

/// The first frame on a worker's control connection: everything
/// `Worker::new` needs beyond what the command line carries.
pub(crate) fn encode_setup(
    metas: &[ParamMeta],
    spec: &OptimizerSpec,
    seed: u64,
    shm: Option<&ShmSetup>,
) -> Result<Vec<u8>, String> {
    let mut out = Vec::new();
    push_u64(&mut out, metas.len() as u64);
    for m in metas {
        push_str(&mut out, &m.name);
        push_u64(&mut out, m.rows as u64);
        push_u64(&mut out, m.cols as u64);
    }
    encode_spec(&mut out, spec)?;
    push_u64(&mut out, seed);
    match shm {
        Some(s) => {
            push_u8(&mut out, 1);
            push_str(&mut out, &s.path);
            push_u64(&mut out, s.slot_elems);
        }
        None => push_u8(&mut out, 0),
    }
    Ok(out)
}

#[allow(clippy::type_complexity)]
pub(crate) fn decode_setup(
    bytes: &[u8],
) -> Result<(Vec<ParamMeta>, OptimizerSpec, u64, Option<ShmSetup>), String> {
    let mut r = Reader::new(bytes);
    let n = read_usize(&mut r)?;
    let mut metas = Vec::new();
    for _ in 0..n {
        metas.push(ParamMeta {
            name: read_str(&mut r)?,
            rows: read_usize(&mut r)?,
            cols: read_usize(&mut r)?,
        });
    }
    let spec = decode_spec(&mut r)?;
    let seed = r.u64()?;
    let shm = match read_u8(&mut r)? {
        0 => None,
        1 => Some(ShmSetup {
            path: read_str(&mut r)?,
            slot_elems: r.u64()?,
        }),
        other => return Err(format!("unknown shm-setup tag {other}")),
    };
    Ok((metas, spec, seed, shm))
}

// ------------------------------------------------------------- cmd/reply

pub(crate) fn encode_cmd(cmd: &Cmd) -> Vec<u8> {
    let mut out = Vec::new();
    match cmd {
        Cmd::Init(full) => {
            push_u8(&mut out, 0);
            push_matrices(&mut out, full);
        }
        Cmd::Step { t, lr, grads } => {
            push_u8(&mut out, 1);
            push_u64(&mut out, *t);
            push_f32(&mut out, *lr);
            push_matrices(&mut out, grads);
        }
        Cmd::Params => push_u8(&mut out, 2),
        Cmd::ExportOpt => push_u8(&mut out, 3),
        Cmd::ImportOpt(bytes) => {
            push_u8(&mut out, 4);
            push_bytes(&mut out, bytes);
        }
        Cmd::Report => push_u8(&mut out, 5),
        Cmd::Shutdown => push_u8(&mut out, 6),
    }
    out
}

pub(crate) fn decode_cmd(bytes: &[u8]) -> Result<Cmd, String> {
    let mut r = Reader::new(bytes);
    Ok(match read_u8(&mut r)? {
        0 => Cmd::Init(read_matrices(&mut r)?),
        1 => Cmd::Step {
            t: r.u64()?,
            lr: read_f32(&mut r)?,
            grads: read_matrices(&mut r)?,
        },
        2 => Cmd::Params,
        3 => Cmd::ExportOpt,
        4 => Cmd::ImportOpt(read_bytes(&mut r)?),
        5 => Cmd::Report,
        6 => Cmd::Shutdown,
        other => return Err(format!("unknown command tag {other}")),
    })
}

pub(crate) fn encode_reply(reply: &Reply) -> Vec<u8> {
    let mut out = Vec::new();
    match reply {
        Reply::StepDone {
            comm_ns,
            compute_ns,
            socket_bytes,
            shm_bytes,
            peak_transient,
        } => {
            push_u8(&mut out, 0);
            push_u64(&mut out, *comm_ns);
            push_u64(&mut out, *compute_ns);
            push_u64(&mut out, *socket_bytes);
            push_u64(&mut out, *shm_bytes);
            push_u64(&mut out, *peak_transient);
        }
        Reply::Params(ms) => {
            push_u8(&mut out, 1);
            push_matrices(&mut out, ms);
        }
        Reply::OptState(bytes) => {
            push_u8(&mut out, 2);
            push_bytes(&mut out, bytes);
        }
        Reply::ImportDone(result) => {
            push_u8(&mut out, 3);
            match result {
                Ok(()) => push_u8(&mut out, 1),
                Err(e) => {
                    push_u8(&mut out, 0);
                    push_str(&mut out, e);
                }
            }
        }
        Reply::Report(rep) => {
            push_u8(&mut out, 4);
            push_u64(&mut out, rep.rank as u64);
            push_u64(&mut out, rep.param_shard_bytes as u64);
            push_u64(&mut out, rep.optimizer_bytes as u64);
            push_u64(&mut out, rep.peak_transient_bytes as u64);
            push_u64(&mut out, rep.traffic_elems);
            push_u64(&mut out, rep.socket_bytes);
            push_u64(&mut out, rep.shm_bytes);
        }
    }
    out
}

pub(crate) fn decode_reply(bytes: &[u8]) -> Result<Reply, String> {
    let mut r = Reader::new(bytes);
    Ok(match read_u8(&mut r)? {
        0 => Reply::StepDone {
            comm_ns: r.u64()?,
            compute_ns: r.u64()?,
            socket_bytes: r.u64()?,
            shm_bytes: r.u64()?,
            peak_transient: r.u64()?,
        },
        1 => Reply::Params(read_matrices(&mut r)?),
        2 => Reply::OptState(read_bytes(&mut r)?),
        3 => {
            if read_u8(&mut r)? != 0 {
                Reply::ImportDone(Ok(()))
            } else {
                Reply::ImportDone(Err(read_str(&mut r)?))
            }
        }
        4 => Reply::Report(MemoryReport {
            rank: read_usize(&mut r)?,
            param_shard_bytes: read_usize(&mut r)?,
            optimizer_bytes: read_usize(&mut r)?,
            peak_transient_bytes: read_usize(&mut r)?,
            traffic_elems: r.u64()?,
            socket_bytes: r.u64()?,
            shm_bytes: r.u64()?,
        }),
        other => return Err(format!("unknown reply tag {other}")),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn frames_roundtrip_through_a_buffer() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        write_frame(&mut buf, b"").unwrap();
        write_frame(&mut buf, &[7u8; 1000]).unwrap();
        let mut cursor = std::io::Cursor::new(buf);
        assert_eq!(read_frame(&mut cursor).unwrap(), b"hello");
        assert_eq!(read_frame(&mut cursor).unwrap(), b"");
        assert_eq!(read_frame(&mut cursor).unwrap(), vec![7u8; 1000]);
        // EOF mid-frame is an error, not a hang or a short read.
        assert!(read_frame(&mut cursor).is_err());
    }

    #[test]
    fn torn_frame_length_is_rejected() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&u64::MAX.to_le_bytes());
        let mut cursor = std::io::Cursor::new(buf);
        let err = read_frame(&mut cursor).unwrap_err();
        assert!(err.to_string().contains("cap"), "unhelpful error: {err}");
    }

    #[test]
    fn comm_frames_roundtrip_and_reject_bad_headers() {
        // Full exchange: no range, body starts right after the header.
        let full = encode_comm_frame(None, &[1.0, -2.5]);
        let (need, off) = decode_comm_header(&full).unwrap();
        assert_eq!((need, off), (None, COMM_HDR_LEN));
        assert_eq!(bytes_to_f32s(&full[off..]).unwrap(), vec![1.0, -2.5]);
        // Ranged exchange carries its window through the header.
        let ranged = encode_comm_frame(Some((1, 3)), &[0.0, 1.0, 2.0, 3.0]);
        let (need, off) = decode_comm_header(&ranged).unwrap();
        assert_eq!((need, off), (Some((1, 3)), COMM_HDR_LEN));
        assert_eq!(bytes_to_f32s(&ranged[off..]).unwrap().len(), 4);
        // Empty ranged body with an empty window is legal (barriers).
        let empty = encode_comm_frame(Some((0, 0)), &[]);
        assert_eq!(decode_comm_header(&empty).unwrap().0, Some((0, 0)));
        // Malformed headers error instead of panicking.
        assert!(decode_comm_header(&[]).is_err(), "short frame accepted");
        assert!(
            decode_comm_header(&full[..COMM_HDR_LEN - 1]).is_err(),
            "truncated header accepted"
        );
        let mut bad_kind = full.clone();
        bad_kind[0] = 7;
        assert!(decode_comm_header(&bad_kind).is_err(), "bad kind accepted");
        let oob = encode_comm_frame(Some((1, 9)), &[0.0, 1.0]);
        assert!(decode_comm_header(&oob).is_err(), "range past body accepted");
        let inverted = encode_comm_frame(Some((3, 1)), &[0.0; 4]);
        assert!(decode_comm_header(&inverted).is_err(), "lo > hi accepted");
        let mut ragged = full.clone();
        ragged.push(0);
        assert!(decode_comm_header(&ragged).is_err(), "ragged body accepted");
    }

    #[test]
    fn hello_roundtrips() {
        for (kind, rank) in [(0u8, 0usize), (1, 7), (0, usize::MAX >> 1)] {
            let (k, r) = decode_hello(&encode_hello(kind, rank));
            assert_eq!((k, r), (kind, rank));
        }
    }

    #[test]
    fn f32_payloads_are_bit_exact() {
        // NaN payloads and signed zeros must survive the wire untouched.
        let xs = vec![
            0.0f32,
            -0.0,
            f32::NAN,
            f32::from_bits(0x7fc0_dead),
            f32::INFINITY,
            -1.5e-38,
        ];
        let back = bytes_to_f32s(&f32s_to_bytes(&xs)).unwrap();
        assert_eq!(back.len(), xs.len());
        for (a, b) in xs.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert!(bytes_to_f32s(&[1, 2, 3]).is_err());
    }

    #[test]
    fn cmds_roundtrip() {
        let mut rng = Pcg64::new(3, 0);
        let grads = vec![
            Matrix::randn(3, 5, 1.0, &mut rng),
            Matrix::randn(1, 2, 1.0, &mut rng),
        ];
        let cases = vec![
            Cmd::Init(grads.clone()),
            Cmd::Step {
                t: 42,
                lr: 0.125,
                grads: grads.clone(),
            },
            Cmd::Params,
            Cmd::ExportOpt,
            Cmd::ImportOpt(vec![1, 2, 3, 255]),
            Cmd::Report,
            Cmd::Shutdown,
        ];
        for cmd in &cases {
            let back = decode_cmd(&encode_cmd(cmd)).unwrap();
            match (cmd, &back) {
                (Cmd::Init(a), Cmd::Init(b)) => {
                    assert_eq!(a.len(), b.len());
                    for (x, y) in a.iter().zip(b) {
                        assert_eq!(x.data, y.data);
                        assert_eq!(x.shape(), y.shape());
                    }
                }
                (
                    Cmd::Step { t, lr, grads },
                    Cmd::Step {
                        t: t2,
                        lr: lr2,
                        grads: g2,
                    },
                ) => {
                    assert_eq!(t, t2);
                    assert_eq!(lr.to_bits(), lr2.to_bits());
                    assert_eq!(grads.len(), g2.len());
                    for (x, y) in grads.iter().zip(g2) {
                        assert_eq!(x.data, y.data);
                    }
                }
                (Cmd::Params, Cmd::Params) => {}
                (Cmd::ExportOpt, Cmd::ExportOpt) => {}
                (Cmd::ImportOpt(a), Cmd::ImportOpt(b)) => assert_eq!(a, b),
                (Cmd::Report, Cmd::Report) => {}
                (Cmd::Shutdown, Cmd::Shutdown) => {}
                _ => panic!("command changed variant over the wire"),
            }
        }
    }

    #[test]
    fn replies_roundtrip() {
        let mut rng = Pcg64::new(4, 0);
        let report = MemoryReport {
            rank: 3,
            param_shard_bytes: 1024,
            optimizer_bytes: 2048,
            peak_transient_bytes: 4096,
            traffic_elems: 123_456,
            socket_bytes: 777,
            shm_bytes: 8_888_888,
        };
        let cases = vec![
            Reply::StepDone {
                comm_ns: 17_000_000,
                compute_ns: 42_000_001,
                socket_bytes: 4096,
                shm_bytes: 65_536,
                peak_transient: 131_072,
            },
            Reply::Params(vec![Matrix::randn(2, 4, 1.0, &mut rng)]),
            Reply::OptState(vec![9; 33]),
            Reply::ImportDone(Ok(())),
            Reply::ImportDone(Err("shard mismatch".into())),
            Reply::Report(report),
        ];
        for reply in &cases {
            let back = decode_reply(&encode_reply(reply)).unwrap();
            match (reply, &back) {
                (
                    Reply::StepDone {
                        comm_ns,
                        compute_ns,
                        socket_bytes,
                        shm_bytes,
                        peak_transient,
                    },
                    Reply::StepDone {
                        comm_ns: c2,
                        compute_ns: p2,
                        socket_bytes: s2,
                        shm_bytes: h2,
                        peak_transient: t2,
                    },
                ) => {
                    assert_eq!(comm_ns, c2);
                    assert_eq!(compute_ns, p2);
                    assert_eq!(socket_bytes, s2);
                    assert_eq!(shm_bytes, h2);
                    assert_eq!(peak_transient, t2);
                }
                (Reply::Params(a), Reply::Params(b)) => {
                    assert_eq!(a[0].data, b[0].data);
                }
                (Reply::OptState(a), Reply::OptState(b)) => assert_eq!(a, b),
                (Reply::ImportDone(Ok(())), Reply::ImportDone(Ok(()))) => {}
                (Reply::ImportDone(Err(a)), Reply::ImportDone(Err(b))) => assert_eq!(a, b),
                (Reply::Report(a), Reply::Report(b)) => {
                    assert_eq!(a.rank, b.rank);
                    assert_eq!(a.param_shard_bytes, b.param_shard_bytes);
                    assert_eq!(a.optimizer_bytes, b.optimizer_bytes);
                    assert_eq!(a.peak_transient_bytes, b.peak_transient_bytes);
                    assert_eq!(a.traffic_elems, b.traffic_elems);
                    assert_eq!(a.socket_bytes, b.socket_bytes);
                    assert_eq!(a.shm_bytes, b.shm_bytes);
                }
                _ => panic!("reply changed variant over the wire"),
            }
        }
    }

    #[test]
    fn setup_roundtrips_every_shippable_spec() {
        let metas = vec![
            ParamMeta {
                name: "blocks.0.wq".into(),
                rows: 64,
                cols: 16,
            },
            ParamMeta {
                name: "embed".into(),
                rows: 1,
                cols: 128,
            },
        ];
        let galore = GaLoreCfg {
            rank: 7,
            update_freq: 11,
            alpha: 0.375,
            projection: ProjectionKind::Quant4,
            moments: MomentHandling::Project,
            min_dim: 3,
            external_subspace: true,
        };
        let specs = vec![
            OptimizerSpec::AdamW(AdamCfg {
                weight_decay: 0.25,
                ..AdamCfg::default()
            }),
            OptimizerSpec::Adam8bit(AdamCfg::default()),
            OptimizerSpec::Adafactor { eps: 1e-21 },
            OptimizerSpec::SgdM { momentum: 0.85 },
            OptimizerSpec::GaLore {
                galore,
                adam: AdamCfg::default(),
            },
            OptimizerSpec::QGaLore {
                galore,
                adam: AdamCfg::default(),
                similarity_threshold: 0.65,
            },
        ];
        for spec in &specs {
            let frame = encode_setup(&metas, spec, 0xdead_beef, None).unwrap();
            let (m2, s2, seed, shm) = decode_setup(&frame).unwrap();
            assert_eq!(seed, 0xdead_beef);
            assert_eq!(shm, None);
            assert_eq!(m2.len(), 2);
            assert_eq!(m2[0].name, "blocks.0.wq");
            assert_eq!((m2[1].rows, m2[1].cols), (1, 128));
            assert_eq!(s2.name(), spec.name());
            // Spot-check the lossiest fields.
            if let (
                OptimizerSpec::QGaLore {
                    galore: g1,
                    similarity_threshold: t1,
                    ..
                },
                OptimizerSpec::QGaLore {
                    galore: g2,
                    similarity_threshold: t2,
                    ..
                },
            ) = (spec, &s2)
            {
                assert_eq!(g1.rank, g2.rank);
                assert_eq!(g1.update_freq, g2.update_freq);
                assert_eq!(g1.alpha.to_bits(), g2.alpha.to_bits());
                assert_eq!(g1.projection, g2.projection);
                assert_eq!(g1.min_dim, g2.min_dim);
                assert_eq!(g1.external_subspace, g2.external_subspace);
                assert_eq!(t1.to_bits(), t2.to_bits());
            }
        }
        // The PJRT variant must refuse to cross a process boundary.
        let pjrt = OptimizerSpec::PjrtGaLore {
            galore,
            adam: AdamCfg::default(),
        };
        assert!(encode_setup(&metas, &pjrt, 1, None).is_err());
    }

    #[test]
    fn setup_carries_the_shm_slot_table() {
        let metas = vec![ParamMeta {
            name: "w".into(),
            rows: 4,
            cols: 8,
        }];
        let shm = ShmSetup {
            path: "/tmp/g2w-1/slots.shm".into(),
            slot_elems: 96,
        };
        let frame = encode_setup(
            &metas,
            &OptimizerSpec::AdamW(AdamCfg::default()),
            7,
            Some(&shm),
        )
        .unwrap();
        let (_, _, _, back) = decode_setup(&frame).unwrap();
        assert_eq!(back, Some(shm));
        // A corrupt shm tag errors instead of silently running socket-mode
        // against an shm-mode coordinator. Layout from the tail: the tag
        // byte precedes [len u64][path bytes][slot_elems u64].
        let tag_idx = frame.len() - 8 - "/tmp/g2w-1/slots.shm".len() - 8 - 1;
        assert_eq!(frame[tag_idx], 1, "shm tag not where the layout says");
        let mut bad = frame.clone();
        bad[tag_idx] = 9;
        assert!(decode_setup(&bad).is_err());
    }

    #[test]
    fn truncated_payloads_error_out() {
        let frame = encode_setup(
            &[ParamMeta {
                name: "p".into(),
                rows: 2,
                cols: 2,
            }],
            &OptimizerSpec::AdamW(AdamCfg::default()),
            9,
            None,
        )
        .unwrap();
        for cut in [0, 1, frame.len() / 2, frame.len() - 1] {
            assert!(
                decode_setup(&frame[..cut]).is_err(),
                "setup truncated at {cut} decoded silently"
            );
        }
        let cmd = encode_cmd(&Cmd::Step {
            t: 1,
            lr: 0.5,
            grads: vec![Matrix::zeros(2, 3)],
        });
        for cut in [0, 1, cmd.len() / 2, cmd.len() - 1] {
            assert!(
                decode_cmd(&cmd[..cut]).is_err(),
                "cmd truncated at {cut} decoded silently"
            );
        }
    }
}
