//! Training metrics: loss curves, throughput, CSV/JSON emission.

use crate::util::json::Json;
use std::io::Write;
use std::path::Path;

/// One logged training/validation point.
#[derive(Clone, Debug)]
pub struct MetricPoint {
    pub step: u64,
    pub tokens: u64,
    pub loss: f64,
    pub lr: f64,
    pub wall_secs: f64,
    pub tag: String,
}

/// Accumulates metric points; writes CSV and JSON-lines.
#[derive(Default)]
pub struct Metrics {
    pub points: Vec<MetricPoint>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn log(&mut self, tag: &str, step: u64, tokens: u64, loss: f64, lr: f64, wall: f64) {
        self.points.push(MetricPoint {
            step,
            tokens,
            loss,
            lr,
            wall_secs: wall,
            tag: tag.to_string(),
        });
    }

    pub fn of_tag<'a>(&'a self, tag: &'a str) -> impl Iterator<Item = &'a MetricPoint> {
        self.points.iter().filter(move |p| p.tag == tag)
    }

    pub fn last_loss(&self, tag: &str) -> Option<f64> {
        self.of_tag(tag).last().map(|p| p.loss)
    }

    /// Mean loss of the final `k` points of a tag (noise-robust endpoint
    /// for the Fig. 3 comparison).
    pub fn tail_mean_loss(&self, tag: &str, k: usize) -> Option<f64> {
        let pts: Vec<f64> = self.of_tag(tag).map(|p| p.loss).collect();
        if pts.is_empty() {
            return None;
        }
        let tail = &pts[pts.len().saturating_sub(k)..];
        Some(tail.iter().sum::<f64>() / tail.len() as f64)
    }

    /// Exponential moving average of a tag's losses.
    pub fn ema(&self, tag: &str, beta: f64) -> Vec<(u64, f64)> {
        let mut out = Vec::new();
        let mut acc: Option<f64> = None;
        for p in self.of_tag(tag) {
            acc = Some(match acc {
                None => p.loss,
                Some(a) => beta * a + (1.0 - beta) * p.loss,
            });
            out.push((p.step, acc.unwrap()));
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::from("tag,step,tokens,loss,lr,wall_secs\n");
        for p in &self.points {
            s.push_str(&format!(
                "{},{},{},{},{},{}\n",
                p.tag, p.step, p.tokens, p.loss, p.lr, p.wall_secs
            ));
        }
        s
    }

    pub fn write_csv(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_csv().as_bytes())
    }

    pub fn to_json(&self) -> Json {
        Json::arr(
            self.points
                .iter()
                .map(|p| {
                    let mut j = Json::obj();
                    j.set("tag", Json::str(p.tag.clone()))
                        .set("step", Json::num(p.step as f64))
                        .set("tokens", Json::num(p.tokens as f64))
                        .set("loss", Json::num(p.loss))
                        .set("lr", Json::num(p.lr))
                        .set("wall_secs", Json::num(p.wall_secs));
                    j
                })
                .collect(),
        )
    }

    /// Perplexity of the latest validation loss.
    pub fn last_ppl(&self, tag: &str) -> Option<f64> {
        self.last_loss(tag).map(f64::exp)
    }
}

/// Metrics subscribes to the trainer's event stream like any other
/// observer — the "train"/"val" curves are a projection of [`StepEvent`]s,
/// not a side channel into trainer internals.
///
/// [`StepEvent`]: crate::train::StepEvent
impl crate::train::StepObserver for Metrics {
    fn on_event(&mut self, event: &crate::train::StepEvent) {
        use crate::train::StepEvent;
        match event {
            StepEvent::Train {
                step,
                loss,
                lr,
                tokens_seen,
                wall_secs,
            } => self.log("train", *step, *tokens_seen, *loss, *lr, *wall_secs),
            StepEvent::Val {
                step,
                loss,
                lr,
                tokens_seen,
                wall_secs,
            } => self.log("val", *step, *tokens_seen, *loss, *lr, *wall_secs),
            // Lifecycle events (checkpoints, worker loss/recovery) and the
            // per-step timing/traffic firehoses carry no loss point; the
            // console observer narrates the former, benches consume the
            // latter.
            StepEvent::StepTimed { .. }
            | StepEvent::StepTraffic { .. }
            | StepEvent::Checkpoint { .. }
            | StepEvent::WorkerLost { .. }
            | StepEvent::RecoveryStarted { .. }
            | StepEvent::RecoveryComplete { .. } => {}
        }
    }
}

/// Render an ASCII loss-curve chart (for terminal reports / EXPERIMENTS.md).
pub fn ascii_chart(series: &[(&str, Vec<(u64, f64)>)], width: usize, height: usize) -> String {
    let all: Vec<(u64, f64)> = series
        .iter()
        .flat_map(|(_, pts)| pts.iter().copied())
        .collect();
    if all.is_empty() {
        return String::new();
    }
    let (x_min, x_max) = all
        .iter()
        .fold((u64::MAX, 0u64), |(lo, hi), &(x, _)| (lo.min(x), hi.max(x)));
    let (y_min, y_max) = all.iter().fold((f64::MAX, f64::MIN), |(lo, hi), &(_, y)| {
        (lo.min(y), hi.max(y))
    });
    let y_span = (y_max - y_min).max(1e-9);
    let x_span = (x_max - x_min).max(1) as f64;
    let mut grid = vec![vec![' '; width]; height];
    let marks = ['*', '+', 'o', 'x', '#'];
    for (si, (_, pts)) in series.iter().enumerate() {
        for &(x, y) in pts {
            let col = (((x - x_min) as f64 / x_span) * (width - 1) as f64) as usize;
            let row = (((y_max - y) / y_span) * (height - 1) as f64) as usize;
            grid[row][col] = marks[si % marks.len()];
        }
    }
    let mut out = String::new();
    out.push_str(&format!("{y_max:>8.4} ┐\n"));
    for row in grid {
        out.push_str("         │");
        out.extend(row);
        out.push('\n');
    }
    out.push_str(&format!("{y_min:>8.4} └{}\n", "─".repeat(width)));
    out.push_str(&format!(
        "          {:<10} … {:>10}   legend: {}\n",
        x_min,
        x_max,
        series
            .iter()
            .enumerate()
            .map(|(i, (name, _))| format!("{}={}", marks[i % marks.len()], name))
            .collect::<Vec<_>>()
            .join("  ")
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Metrics {
        let mut m = Metrics::new();
        for t in 0..10 {
            m.log("train", t, t * 100, 5.0 - 0.3 * t as f64, 0.01, 0.1);
        }
        m.log("val", 9, 900, 3.0, 0.01, 0.5);
        m
    }

    #[test]
    fn csv_has_all_rows() {
        let m = sample();
        let csv = m.to_csv();
        assert_eq!(csv.lines().count(), 1 + 11);
        assert!(csv.lines().nth(1).unwrap().starts_with("train,0,0,5,"));
    }

    #[test]
    fn tag_filters() {
        let m = sample();
        assert_eq!(m.of_tag("train").count(), 10);
        assert_eq!(m.last_loss("val"), Some(3.0));
        assert!((m.last_ppl("val").unwrap() - 3f64.exp()).abs() < 1e-9);
    }

    #[test]
    fn tail_mean() {
        let m = sample();
        let tail = m.tail_mean_loss("train", 2).unwrap();
        let expect = (5.0 - 0.3 * 8.0 + 5.0 - 0.3 * 9.0) / 2.0;
        assert!((tail - expect).abs() < 1e-9);
    }

    #[test]
    fn ema_smooths_monotonically_decreasing() {
        let m = sample();
        let ema = m.ema("train", 0.9);
        assert_eq!(ema.len(), 10);
        assert!(ema.windows(2).all(|w| w[1].1 <= w[0].1));
        // EMA lags the raw series.
        assert!(ema.last().unwrap().1 > m.last_loss("train").unwrap());
    }

    #[test]
    fn json_round_trips() {
        let m = sample();
        let j = m.to_json();
        let parsed = crate::util::json::Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.as_arr().unwrap().len(), 11);
    }

    #[test]
    fn chart_renders() {
        let m = sample();
        let pts: Vec<(u64, f64)> = m.of_tag("train").map(|p| (p.step, p.loss)).collect();
        let chart = ascii_chart(&[("train", pts)], 40, 8);
        assert!(chart.contains('*'));
        assert!(chart.lines().count() >= 8);
    }
}
