//! AdamW — the full-rank, full-precision baseline.
//!
//! This is the optimizer GaLore's memory equation in §3 is written against:
//! 2·mn fp32 state per m×n parameter (first + second moments).

use super::{ser, Optimizer};
use crate::tensor::Matrix;
use std::collections::BTreeMap;

#[derive(Clone, Copy, Debug)]
pub struct AdamCfg {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    /// Decoupled weight decay (AdamW); 0 disables.
    pub weight_decay: f32,
}

impl Default for AdamCfg {
    fn default() -> Self {
        AdamCfg {
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.0,
        }
    }
}

struct State {
    m: Vec<f32>,
    v: Vec<f32>,
}

pub struct AdamW {
    cfg: AdamCfg,
    states: BTreeMap<usize, State>,
    t: u64,
}

impl AdamW {
    pub fn new(cfg: AdamCfg) -> AdamW {
        AdamW {
            cfg,
            states: BTreeMap::new(),
            t: 0,
        }
    }

    /// The normalized update direction N = M̂/(√V̂ + ε) *without* applying it
    /// — GaLore reuses Adam as its inner optimizer on projected gradients.
    pub(crate) fn update_direction(
        cfg: &AdamCfg,
        m: &mut [f32],
        v: &mut [f32],
        grad: &[f32],
        t: u64,
    ) -> Vec<f32> {
        debug_assert_eq!(m.len(), grad.len());
        let b1 = cfg.beta1;
        let b2 = cfg.beta2;
        // Bias correction uses the 1-based step count.
        let bc1 = 1.0 - b1.powi(t as i32 + 1);
        let bc2 = 1.0 - b2.powi(t as i32 + 1);
        let mut out = vec![0f32; grad.len()];
        for i in 0..grad.len() {
            m[i] = b1 * m[i] + (1.0 - b1) * grad[i];
            v[i] = b2 * v[i] + (1.0 - b2) * grad[i] * grad[i];
            let m_hat = m[i] / bc1;
            let v_hat = v[i] / bc2;
            out[i] = m_hat / (v_hat.sqrt() + cfg.eps);
        }
        out
    }
}

impl Optimizer for AdamW {
    fn begin_step(&mut self, t: u64) {
        self.t = t;
    }

    fn step_param(&mut self, idx: usize, param: &mut Matrix, grad: &Matrix, lr: f32) {
        assert_eq!(param.shape(), grad.shape());
        let n = param.numel();
        let st = self.states.entry(idx).or_insert_with(|| State {
            m: vec![0.0; n],
            v: vec![0.0; n],
        });
        assert_eq!(st.m.len(), n, "param {idx} changed shape");
        let dir = Self::update_direction(&self.cfg, &mut st.m, &mut st.v, &grad.data, self.t);
        let wd = self.cfg.weight_decay;
        for i in 0..n {
            if wd > 0.0 {
                param.data[i] -= lr * wd * param.data[i];
            }
            param.data[i] -= lr * dir[i];
        }
    }

    fn state_bytes(&self) -> usize {
        self.states.values().map(|s| (s.m.len() + s.v.len()) * 4).sum()
    }

    fn name(&self) -> &'static str {
        "adamw"
    }

    fn export_state(&self) -> Vec<u8> {
        let mut out = Vec::new();
        ser::push_u64(&mut out, self.t);
        ser::push_u64(&mut out, self.states.len() as u64);
        for (&idx, st) in &self.states {
            ser::push_u64(&mut out, idx as u64);
            ser::push_f32s(&mut out, &st.m);
            ser::push_f32s(&mut out, &st.v);
        }
        out
    }

    fn import_state(&mut self, bytes: &[u8]) -> Result<(), String> {
        let mut r = ser::Reader::new(bytes);
        self.t = r.u64()?;
        let n = r.u64()? as usize;
        self.states.clear();
        for _ in 0..n {
            let idx = r.u64()? as usize;
            let m = r.f32s()?;
            let v = r.f32s()?;
            self.states.insert(idx, State { m, v });
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_step_is_signed_unit_direction() {
        // At t=0 with zero state, m̂ = g, v̂ = g² ⇒ update = sign(g) (ε aside).
        let mut opt = AdamW::new(AdamCfg::default());
        let mut p = Matrix::zeros(1, 3);
        let g = Matrix::from_vec(1, 3, vec![0.5, -2.0, 0.0]);
        opt.begin_step(0);
        opt.step_param(0, &mut p, &g, 0.1);
        assert!((p.data[0] + 0.1).abs() < 1e-3);
        assert!((p.data[1] - 0.1).abs() < 1e-3);
        assert_eq!(p.data[2], 0.0);
    }

    #[test]
    fn weight_decay_shrinks_params() {
        let cfg = AdamCfg {
            weight_decay: 0.1,
            ..AdamCfg::default()
        };
        let mut opt = AdamW::new(cfg);
        let mut p = Matrix::from_vec(1, 2, vec![1.0, -1.0]);
        let g = Matrix::zeros(1, 2);
        opt.begin_step(0);
        opt.step_param(0, &mut p, &g, 0.5);
        assert!((p.data[0] - 0.95).abs() < 1e-6);
        assert!((p.data[1] + 0.95).abs() < 1e-6);
    }

    #[test]
    fn state_bytes_counts_two_moments() {
        let mut opt = AdamW::new(AdamCfg::default());
        let mut p = Matrix::zeros(8, 4);
        let g = Matrix::from_vec(8, 4, vec![1.0; 32]);
        opt.begin_step(0);
        opt.step_param(0, &mut p, &g, 0.1);
        assert_eq!(opt.state_bytes(), 2 * 32 * 4);
    }

    #[test]
    fn export_import_roundtrip_preserves_trajectory() {
        let mut a = AdamW::new(AdamCfg::default());
        let mut pa = Matrix::zeros(4, 4);
        let g = Matrix::from_vec(4, 4, (0..16).map(|x| x as f32 / 8.0).collect());
        for t in 0..5 {
            a.begin_step(t);
            a.step_param(0, &mut pa, &g, 0.1);
        }
        let blob = a.export_state();
        let mut b = AdamW::new(AdamCfg::default());
        b.import_state(&blob).unwrap();
        let mut pb = pa.clone();
        a.begin_step(5);
        a.step_param(0, &mut pa, &g, 0.1);
        b.begin_step(5);
        b.step_param(0, &mut pb, &g, 0.1);
        assert_eq!(pa.data, pb.data);
    }
}
