//! [`OptimizerSpec`] — the single source of truth for optimizer
//! construction.
//!
//! Every execution mode (single-process, FSDP, DDP, benches, tests) builds
//! its optimizer through [`OptimizerSpec::build`]; there is deliberately no
//! other construction matrix in the codebase. Adding an optimizer variant
//! (Q-GaLore and Natural-GaLore-style drop-ins) means adding one enum arm
//! here plus a mapping line in `TrainConfig::optimizer_spec` — not a
//! three-file hunt.
//!
//! The spec is `Send` + `Clone` while the built [`Optimizer`] is
//! intentionally neither: distributed engines ship the *recipe* to worker
//! threads, which construct their own instances ([`BuildTarget::Worker`]).
//! The PJRT-kernel GaLore variant additionally needs runtime handles
//! ([`PjrtResources`]) and is therefore single-process only.

use super::{
    Adafactor, Adam8bit, AdamCfg, AdamW, GaLore, GaLoreCfg, Optimizer, ProjectionKind, QGaLore,
    QGaLoreCfg, SgdM,
};
use crate::runtime::{Manifest, Runtime};
use crate::train::PjrtGaLore;
use std::path::PathBuf;
use std::sync::Arc;

/// Runtime resources needed to build the PJRT-kernel GaLore variant
/// (loads `galore_update_*.hlo` artifacts through the PJRT runtime).
pub struct PjrtResources {
    pub rt: Arc<Runtime>,
    pub artifacts_dir: PathBuf,
    pub manifest: Manifest,
}

/// Where the optimizer instance being built will run.
#[derive(Clone, Copy)]
pub enum BuildTarget<'a> {
    /// The in-process trainer loop. Carries PJRT runtime resources when the
    /// config selected `engine = "pjrt"`.
    Single { pjrt: Option<&'a PjrtResources> },
    /// A distributed worker thread. `external_subspace` selects the FSDP
    /// contract (§4.3: the leader computes subspaces and installs P via
    /// [`GaLore::preset_projector`]); DDP workers refresh locally and rely
    /// on identical seeding across ranks to stay in lockstep.
    Worker { external_subspace: bool },
}

/// Recipe for an optimizer: `Send`-able, buildable on any execution path.
#[derive(Clone, Debug)]
pub enum OptimizerSpec {
    AdamW(AdamCfg),
    Adam8bit(AdamCfg),
    Adafactor { eps: f32 },
    SgdM { momentum: f32 },
    GaLore { galore: GaLoreCfg, adam: AdamCfg },
    /// Q-GaLore (§4.2): quantized projector storage plus the lazy,
    /// similarity-gated subspace refresh. Under FSDP the gate is inert
    /// (the coordinator owns refreshes) but the quantized projector — the
    /// memory-relevant part — is kept.
    QGaLore {
        galore: GaLoreCfg,
        adam: AdamCfg,
        /// Cosine-similarity threshold above which a scheduled refresh is
        /// skipped (1.0 disables laziness).
        similarity_threshold: f32,
    },
    /// GaLore whose fused per-step update runs the Pallas kernel artifacts
    /// over PJRT. Single-process only (holds non-`Send` device handles).
    PjrtGaLore { galore: GaLoreCfg, adam: AdamCfg },
}

/// Force a quantized projector kind (Q-GaLore's invariant) while keeping an
/// explicit Quant4 choice.
fn quantized(mut g: GaLoreCfg) -> GaLoreCfg {
    if !matches!(
        g.projection,
        ProjectionKind::Quant8 | ProjectionKind::Quant4
    ) {
        g.projection = ProjectionKind::Quant8;
    }
    g
}

impl OptimizerSpec {
    /// Name the built optimizer will report — used for logs, Table 1 rows,
    /// and run names. A quantized projector self-identifies as Q-GaLore.
    pub fn name(&self) -> &'static str {
        match self {
            OptimizerSpec::AdamW(_) => "adamw",
            OptimizerSpec::Adam8bit(_) => "adam8bit",
            OptimizerSpec::Adafactor { .. } => "adafactor",
            OptimizerSpec::SgdM { .. } => "sgdm",
            OptimizerSpec::QGaLore { .. } => "qgalore",
            OptimizerSpec::PjrtGaLore { .. } => "galore-pjrt",
            OptimizerSpec::GaLore { galore, .. } => match galore.projection {
                ProjectionKind::Quant8 | ProjectionKind::Quant4 => "qgalore",
                _ => "galore",
            },
        }
    }

    /// The GaLore config, if this spec is a GaLore variant. For Q-GaLore
    /// the returned config carries the (normalized) quantized projection
    /// kind, matching what [`OptimizerSpec::build`] constructs.
    pub fn galore_cfg(&self) -> Option<GaLoreCfg> {
        match self {
            OptimizerSpec::GaLore { galore, .. }
            | OptimizerSpec::PjrtGaLore { galore, .. } => Some(*galore),
            OptimizerSpec::QGaLore { galore, .. } => Some(quantized(*galore)),
            _ => None,
        }
    }

    /// Whether distributed worker threads can build this spec (everything
    /// except the PJRT variant, which holds non-`Send` device handles).
    pub fn distributed_ok(&self) -> bool {
        !matches!(self, OptimizerSpec::PjrtGaLore { .. })
    }

    /// Serialization layout of the state blob the built optimizer exports
    /// ("galore" | "qgalore" | the optimizer name). This can differ from
    /// [`OptimizerSpec::name`]: a quantized-projector `GaLore` spec
    /// *reports* "qgalore" but serializes the raw GaLore layout, and the
    /// FSDP (external-subspace) build of `QGaLore` is a concrete `GaLore`
    /// too. `checkpoint::canonical` uses this to convert blobs between
    /// the two layouts at the canonical boundary, so a checkpoint written
    /// by any build of the family resumes under any other. The "adam8bit"
    /// and "adafactor" codec names additionally tell the canonical layer
    /// to parse those blobs into the structured `Quantized` payload
    /// (stored-representation moments / factored accumulators) instead of
    /// carrying them opaquely.
    pub fn state_codec(&self, external_subspace: bool) -> &'static str {
        match self {
            OptimizerSpec::QGaLore { .. } if !external_subspace => "qgalore",
            OptimizerSpec::QGaLore { .. } | OptimizerSpec::GaLore { .. } => "galore",
            _ => self.name(),
        }
    }

    /// Build the optimizer for a given execution target. This is the ONE
    /// optimizer construction path in the codebase.
    pub fn build(&self, seed: u64, target: BuildTarget) -> Result<WorkerOpt, String> {
        let external = matches!(
            target,
            BuildTarget::Worker {
                external_subspace: true
            }
        );
        Ok(match self {
            OptimizerSpec::AdamW(cfg) => WorkerOpt::Boxed(Box::new(AdamW::new(*cfg))),
            OptimizerSpec::Adam8bit(cfg) => {
                WorkerOpt::Boxed(Box::new(Adam8bit::new(*cfg)))
            }
            OptimizerSpec::Adafactor { eps } => {
                WorkerOpt::Boxed(Box::new(Adafactor::new(*eps)))
            }
            OptimizerSpec::SgdM { momentum } => {
                WorkerOpt::Boxed(Box::new(SgdM::new(*momentum)))
            }
            OptimizerSpec::GaLore { galore, adam } => {
                let mut g = *galore;
                g.external_subspace = external;
                WorkerOpt::GaLore(GaLore::new(g, *adam, seed))
            }
            OptimizerSpec::QGaLore {
                galore,
                adam,
                similarity_threshold,
            } => {
                let mut g = quantized(*galore);
                g.external_subspace = external;
                if external {
                    // FSDP: the coordinator owns every refresh, so the lazy
                    // gate never fires — a plain GaLore with the quantized
                    // projector is the same optimizer, and the engine can
                    // drive its subspace through `preset_projector`.
                    WorkerOpt::GaLore(GaLore::new(g, *adam, seed))
                } else {
                    WorkerOpt::Boxed(Box::new(QGaLore::new(
                        QGaLoreCfg {
                            galore: g,
                            similarity_threshold: *similarity_threshold,
                        },
                        *adam,
                        seed,
                    )))
                }
            }
            OptimizerSpec::PjrtGaLore { galore, adam } => match target {
                BuildTarget::Single { pjrt: Some(res) } => {
                    WorkerOpt::Boxed(Box::new(PjrtGaLore::new(
                        *galore,
                        *adam,
                        res.rt.clone(),
                        res.artifacts_dir.clone(),
                        res.manifest.clone(),
                        seed,
                    )))
                }
                BuildTarget::Single { pjrt: None } => {
                    return Err(
                        "pjrt galore needs PjrtResources (runtime + artifacts)".into()
                    )
                }
                BuildTarget::Worker { .. } => {
                    return Err(
                        "engine=pjrt is single-process only (use --parallel single)"
                            .into(),
                    )
                }
            },
        })
    }
}

/// A built optimizer: GaLore is held concretely so distributed engines can
/// drive its external subspace; everything else is a trait object.
pub enum WorkerOpt {
    GaLore(GaLore),
    Boxed(Box<dyn Optimizer>),
}

impl WorkerOpt {
    pub fn as_opt(&mut self) -> &mut dyn Optimizer {
        match self {
            WorkerOpt::GaLore(g) => g,
            WorkerOpt::Boxed(b) => b.as_mut(),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            WorkerOpt::GaLore(g) => g.name(),
            WorkerOpt::Boxed(b) => b.name(),
        }
    }

    pub fn state_bytes(&self) -> usize {
        match self {
            WorkerOpt::GaLore(g) => g.state_bytes(),
            WorkerOpt::Boxed(b) => b.state_bytes(),
        }
    }

    pub fn export_state(&self) -> Vec<u8> {
        match self {
            WorkerOpt::GaLore(g) => g.export_state(),
            WorkerOpt::Boxed(b) => b.export_state(),
        }
    }

    pub(crate) fn galore_mut(&mut self) -> Option<&mut GaLore> {
        match self {
            WorkerOpt::GaLore(g) => Some(g),
            _ => None,
        }
    }

    pub(crate) fn has_projector(&self, idx: usize) -> bool {
        match self {
            WorkerOpt::GaLore(g) => g.has_projector(idx),
            _ => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_worker_specs() -> Vec<OptimizerSpec> {
        vec![
            OptimizerSpec::AdamW(AdamCfg::default()),
            OptimizerSpec::Adam8bit(AdamCfg::default()),
            OptimizerSpec::Adafactor { eps: 1e-30 },
            OptimizerSpec::SgdM { momentum: 0.9 },
            OptimizerSpec::GaLore {
                galore: GaLoreCfg::default(),
                adam: AdamCfg::default(),
            },
            OptimizerSpec::QGaLore {
                galore: GaLoreCfg::default(),
                adam: AdamCfg::default(),
                similarity_threshold: 0.9,
            },
        ]
    }

    #[test]
    fn spec_names_match_config_strings() {
        let names: Vec<&str> = all_worker_specs().iter().map(|s| s.name()).collect();
        assert_eq!(
            names,
            ["adamw", "adam8bit", "adafactor", "sgdm", "galore", "qgalore"]
        );
        // Quantized projector ⇒ the spec self-identifies as Q-GaLore.
        let q = OptimizerSpec::GaLore {
            galore: GaLoreCfg {
                projection: ProjectionKind::Quant8,
                ..GaLoreCfg::default()
            },
            adam: AdamCfg::default(),
        };
        assert_eq!(q.name(), "qgalore");
    }

    #[test]
    fn every_spec_builds_same_name_on_every_path() {
        // The spec-roundtrip contract: single, FSDP-worker and DDP-worker
        // paths build an optimizer reporting the identical name.
        for spec in all_worker_specs() {
            let single = spec
                .build(1, BuildTarget::Single { pjrt: None })
                .expect("single build");
            let fsdp = spec
                .build(
                    1,
                    BuildTarget::Worker {
                        external_subspace: true,
                    },
                )
                .expect("fsdp build");
            let ddp = spec
                .build(
                    1,
                    BuildTarget::Worker {
                        external_subspace: false,
                    },
                )
                .expect("ddp build");
            assert_eq!(single.name(), spec.name(), "single path name drift");
            assert_eq!(fsdp.name(), spec.name(), "fsdp path name drift");
            assert_eq!(ddp.name(), spec.name(), "ddp path name drift");
        }
    }

    #[test]
    fn state_codec_tracks_blob_layout_not_display_name() {
        // The "qgalore" display name covers two state layouts: the true
        // QGaLore optimizer (framed blob + lazy-gate state, single/DDP
        // builds) and the concrete GaLore it degenerates to (raw layout:
        // FSDP builds, and the quantized-projector GaLore spec).
        let qspec = OptimizerSpec::QGaLore {
            galore: GaLoreCfg::default(),
            adam: AdamCfg::default(),
            similarity_threshold: 0.9,
        };
        assert_eq!(qspec.name(), "qgalore");
        assert_eq!(qspec.state_codec(false), "qgalore");
        assert_eq!(qspec.state_codec(true), "galore");
        let alias = OptimizerSpec::GaLore {
            galore: GaLoreCfg {
                projection: ProjectionKind::Quant8,
                ..GaLoreCfg::default()
            },
            adam: AdamCfg::default(),
        };
        assert_eq!(alias.name(), "qgalore");
        assert_eq!(alias.state_codec(false), "galore");
        assert_eq!(alias.state_codec(true), "galore");
        let plain = OptimizerSpec::AdamW(AdamCfg::default());
        assert_eq!(plain.state_codec(false), "adamw");
    }

    #[test]
    fn build_honours_external_subspace_flag() {
        let spec = OptimizerSpec::GaLore {
            galore: GaLoreCfg::default(),
            adam: AdamCfg::default(),
        };
        let mut fsdp = spec
            .build(
                1,
                BuildTarget::Worker {
                    external_subspace: true,
                },
            )
            .unwrap();
        let g = fsdp.galore_mut().expect("galore spec builds galore");
        assert!(g.cfg.external_subspace);
        let mut ddp = spec
            .build(
                1,
                BuildTarget::Worker {
                    external_subspace: false,
                },
            )
            .unwrap();
        assert!(!ddp.galore_mut().unwrap().cfg.external_subspace);
    }

    #[test]
    fn qgalore_spec_normalizes_projection_and_keeps_gate_off_fsdp() {
        // An fp32 projection kind is normalized to Quant8 (Q-GaLore's
        // invariant) on every path, including the galore_cfg() view the
        // FSDP coordinator uses for its install decisions.
        let spec = OptimizerSpec::QGaLore {
            galore: GaLoreCfg {
                projection: ProjectionKind::RandSvd,
                ..GaLoreCfg::default()
            },
            adam: AdamCfg::default(),
            similarity_threshold: 0.5,
        };
        assert_eq!(
            spec.galore_cfg().unwrap().projection,
            ProjectionKind::Quant8
        );
        let mut fsdp = spec
            .build(
                3,
                BuildTarget::Worker {
                    external_subspace: true,
                },
            )
            .unwrap();
        let g = fsdp.galore_mut().expect("fsdp qgalore is driveable galore");
        assert_eq!(g.cfg.projection, ProjectionKind::Quant8);
        assert_eq!(g.name(), "qgalore");
        let ddp = spec
            .build(
                3,
                BuildTarget::Worker {
                    external_subspace: false,
                },
            )
            .unwrap();
        assert_eq!(ddp.name(), "qgalore");
    }

    #[test]
    fn pjrt_spec_is_single_process_only() {
        let spec = OptimizerSpec::PjrtGaLore {
            galore: GaLoreCfg::default(),
            adam: AdamCfg::default(),
        };
        assert_eq!(spec.name(), "galore-pjrt");
        assert!(!spec.distributed_ok());
        assert!(spec
            .build(
                1,
                BuildTarget::Worker {
                    external_subspace: true
                }
            )
            .is_err());
        assert!(spec.build(1, BuildTarget::Single { pjrt: None }).is_err());
    }

    #[test]
    fn projection_predicate_matches_shapes() {
        // The coordinator and the optimizer share GaLoreCfg::projects, so
        // the FSDP install decision can never drift from step_param's.
        let cfg = GaLoreCfg {
            rank: 16,
            min_dim: 2,
            ..GaLoreCfg::default()
        };
        assert!(cfg.projects(64, 128));
        assert!(cfg.projects(16, 128)); // rank == min dim
        assert!(!cfg.projects(8, 128)); // rank > min dim
        assert!(!cfg.projects(1, 128)); // bias-like
    }
}
