//! GaLore: Gradient Low-Rank Projection (§3, Algorithm 1).
//!
//! For each 2-d parameter W (m×n), the gradient G is projected to a rank-r
//! subspace R = PᵀG (or GP for tall W), the inner Adam runs entirely on R
//! (moments M, V are r×n instead of m×n), and the normalized update N is
//! projected back and applied with scale α:
//!
//! ```text
//! W ← W − η · α · P N
//! ```
//!
//! The projector P refreshes every `update_freq` steps from the current
//! gradient's spectrum (§4.1); GaLore 2 uses fast randomized SVD for the
//! refresh. Non-matrix parameters (biases, norms) and matrices whose rank
//! would not shrink fall back to full-rank Adam, matching the reference
//! implementation's `galore_params` split.

use super::adamw::AdamW;
use super::projector::{ProjectionKind, Projector};
use super::{ser, AdamCfg, Optimizer};
use crate::tensor::Matrix;
use crate::util::rng::Pcg64;
use std::collections::BTreeMap;

/// What happens to the low-rank Adam moments when the subspace changes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MomentHandling {
    /// Keep moments as-is (original GaLore; moments silently reinterpret in
    /// the new basis — works because consecutive subspaces overlap heavily).
    Keep,
    /// Zero the moments at each refresh (conservative).
    Reset,
    /// Rotate the first moment into the new basis: M ← (P_newᵀ P_old) M
    /// (the LDAdam-style calibration the paper cites; V is kept).
    Project,
}

#[derive(Clone, Copy, Debug)]
pub struct GaLoreCfg {
    pub rank: usize,
    /// Subspace refresh period T (paper uses 200–500).
    pub update_freq: u64,
    /// Scale factor α applied to the back-projected update (paper: 0.125
    /// at 7B scale; acts as a fractional learning rate).
    pub alpha: f32,
    pub projection: ProjectionKind,
    pub moments: MomentHandling,
    /// Parameters smaller than this on either side skip projection.
    pub min_dim: usize,
    /// FSDP mode (§4.3): the subspace is owned by the coordinator — the
    /// leader computes the SVD on the *full* (un-sharded) gradient and
    /// replicates P to workers via [`GaLore::preset_projector`]. When set,
    /// `step_param` never computes an SVD itself (gradients it sees are
    /// shards, whose spectrum would be wrong).
    pub external_subspace: bool,
}

impl Default for GaLoreCfg {
    fn default() -> Self {
        GaLoreCfg {
            rank: 128,
            update_freq: 200,
            alpha: 0.25,
            projection: ProjectionKind::RandSvd,
            moments: MomentHandling::Keep,
            min_dim: 2,
            external_subspace: false,
        }
    }
}

impl GaLoreCfg {
    /// Whether an (m, n) parameter is projected under this config. The
    /// single source of truth for the optimizer AND the FSDP coordinator
    /// (which must decide on the *full* shape before sharding).
    pub fn projects(&self, m: usize, n: usize) -> bool {
        m >= self.min_dim && n >= self.min_dim && self.rank <= m.min(n)
    }
}

/// Rotate a first moment into a new basis (MomentHandling::Project):
/// C = P_newᵀ·P_old (r×r), then Left: M ← C·M, Right: M ← M·Cᵀ. Shared by
/// the single-process refresh and the FSDP preset path so the two can
/// never drift. No-op when the moment is lazily unsized or shapes
/// disagree (rank changed between refreshes).
fn rotate_moment(
    m: &mut [f32],
    p_old: &Matrix,
    p_new: &Matrix,
    side: super::ProjectorSide,
    lm: usize,
    ln: usize,
) {
    if m.is_empty() || lm * ln != m.len() || p_old.shape() != p_new.shape() {
        return;
    }
    let c = p_new.matmul_at_b(p_old); // r×r
    let m_mat = Matrix::from_vec(lm, ln, m.to_vec());
    let rotated = match side {
        super::ProjectorSide::Left => c.matmul(&m_mat),
        super::ProjectorSide::Right => m_mat.matmul_a_bt(&c),
    };
    m.copy_from_slice(&rotated.data);
}

enum ParamState {
    /// Low-rank path: projector + low-rank Adam moments.
    LowRank {
        projector: Projector,
        m: Vec<f32>,
        v: Vec<f32>,
        /// Step at which P was last refreshed (drives `t % T == 0`).
        last_refresh: u64,
    },
    /// Full-rank fallback (1-d / small params).
    Full { m: Vec<f32>, v: Vec<f32> },
}

pub struct GaLore {
    pub cfg: GaLoreCfg,
    adam: AdamCfg,
    states: BTreeMap<usize, ParamState>,
    rng: Pcg64,
    t: u64,
    /// Count of SVD/refresh operations (exposed for the E6/E7 benches).
    refreshes: u64,
}

impl GaLore {
    pub fn new(cfg: GaLoreCfg, adam: AdamCfg, seed: u64) -> GaLore {
        GaLore {
            cfg,
            adam,
            states: BTreeMap::new(),
            rng: Pcg64::new(seed, 0x6a10),
            t: 0,
            refreshes: 0,
        }
    }

    fn uses_projection(&self, shape: (usize, usize)) -> bool {
        self.cfg.projects(shape.0, shape.1)
    }

    pub fn refresh_count(&self) -> u64 {
        self.refreshes
    }

    /// Export the projector of a parameter (leader-side SVD replication).
    pub fn export_projector(&self, idx: usize) -> Option<Matrix> {
        match self.states.get(&idx) {
            Some(ParamState::LowRank { projector, .. }) => Some(projector.export_p()),
            _ => None,
        }
    }

    /// Install a replicated projector (worker-side; §4.3).
    pub fn install_projector(&mut self, idx: usize, p: Matrix) {
        if let Some(ParamState::LowRank {
            projector,
            last_refresh,
            ..
        }) = self.states.get_mut(&idx)
        {
            projector.install_p(p);
            *last_refresh = self.t;
        }
    }

    /// Whether step `t` is a subspace-refresh step.
    pub fn is_refresh_step(&self, t: u64) -> bool {
        t % self.cfg.update_freq == 0
    }

    /// Install a complete projector for a parameter (FSDP external-subspace
    /// mode). `side` must be derived from the FULL parameter shape; moments
    /// are (re)created lazily at the next `step_param` to match the local
    /// shard. Existing moments follow `cfg.moments`, mirroring the
    /// single-process refresh: Keep leaves them, Reset zeroes them, Project
    /// rotates M into the new basis via C = P_newᵀ P_old.
    pub fn preset_projector(&mut self, idx: usize, projector: Projector) {
        match self.states.get_mut(&idx) {
            Some(ParamState::LowRank {
                projector: p,
                m,
                v,
                last_refresh,
            }) => {
                match self.cfg.moments {
                    MomentHandling::Keep => {}
                    MomentHandling::Reset => {
                        m.iter_mut().for_each(|x| *x = 0.0);
                        v.iter_mut().for_each(|x| *x = 0.0);
                    }
                    MomentHandling::Project => {
                        // Recover the moment's low-rank shape from its
                        // length + the projector geometry (the shard's full
                        // shape is unknown here); lazily-unsized moments
                        // and rank changes are skipped inside the helper.
                        let r = projector.rank;
                        if r > 0 && m.len() % r == 0 {
                            let (lm, ln) = match projector.side {
                                super::ProjectorSide::Left => (r, m.len() / r),
                                super::ProjectorSide::Right => (m.len() / r, r),
                            };
                            rotate_moment(
                                m,
                                &p.export_p(),
                                &projector.export_p(),
                                projector.side,
                                lm,
                                ln,
                            );
                        }
                    }
                }
                *p = projector;
                *last_refresh = self.t;
            }
            _ => {
                self.states.insert(
                    idx,
                    ParamState::LowRank {
                        projector,
                        m: Vec::new(), // sized on first gradient
                        v: Vec::new(),
                        last_refresh: self.t,
                    },
                );
            }
        }
        self.refreshes += 1;
    }

    /// Whether parameter `idx` currently has a low-rank state.
    pub fn has_projector(&self, idx: usize) -> bool {
        matches!(self.states.get(&idx), Some(ParamState::LowRank { .. }))
    }
}

impl Optimizer for GaLore {
    fn begin_step(&mut self, t: u64) {
        self.t = t;
    }

    fn step_param(&mut self, idx: usize, param: &mut Matrix, grad: &Matrix, lr: f32) {
        assert_eq!(param.shape(), grad.shape());
        let (pm, pn) = param.shape();
        let project = self.uses_projection((pm, pn));

        if self.cfg.external_subspace && project && !self.states.contains_key(&idx) {
            panic!(
                "GaLore external-subspace mode: parameter {idx} has no projector; \
                 the FSDP coordinator must preset_projector() before the first step"
            );
        }
        let state = self.states.entry(idx).or_insert_with(|| {
            if project {
                let projector = Projector::from_gradient(
                    grad,
                    self.cfg.rank,
                    self.cfg.projection,
                    &mut self.rng,
                );
                self.refreshes += 1;
                let (lm, ln) = projector.low_rank_shape(pm, pn);
                ParamState::LowRank {
                    projector,
                    m: vec![0.0; lm * ln],
                    v: vec![0.0; lm * ln],
                    last_refresh: self.t,
                }
            } else {
                ParamState::Full {
                    m: vec![0.0; pm * pn],
                    v: vec![0.0; pm * pn],
                }
            }
        });

        match state {
            ParamState::Full { m, v } => {
                let dir = AdamW::update_direction(&self.adam, m, v, &grad.data, self.t);
                for i in 0..param.numel() {
                    param.data[i] -= lr * dir[i];
                }
            }
            ParamState::LowRank {
                projector,
                m,
                v,
                last_refresh,
            } => {
                // Subspace refresh every T steps (Alg. 1's `t mod T == 0`).
                // In external-subspace (FSDP) mode the coordinator drives
                // refreshes through preset_projector instead.
                if !self.cfg.external_subspace
                    && self.t % self.cfg.update_freq == 0
                    && self.t != *last_refresh
                {
                    match self.cfg.moments {
                        MomentHandling::Keep => projector.refresh(grad, &mut self.rng),
                        MomentHandling::Reset => {
                            projector.refresh(grad, &mut self.rng);
                            m.iter_mut().for_each(|x| *x = 0.0);
                            v.iter_mut().for_each(|x| *x = 0.0);
                        }
                        MomentHandling::Project => {
                            let p_old = projector.export_p();
                            projector.refresh(grad, &mut self.rng);
                            let (lm, ln) = projector.low_rank_shape(pm, pn);
                            rotate_moment(
                                m,
                                &p_old,
                                &projector.export_p(),
                                projector.side,
                                lm,
                                ln,
                            );
                        }
                    }
                    *last_refresh = self.t;
                    self.refreshes += 1;
                }

                // Lazy moment sizing: after preset_projector the local
                // shard's shape is unknown until the first gradient arrives.
                if m.is_empty() {
                    let (lm, ln) = projector.low_rank_shape(pm, pn);
                    *m = vec![0.0; lm * ln];
                    *v = vec![0.0; lm * ln];
                }
                // R = project(G); Adam in low-rank space; N back-projected.
                let r = projector.project(grad);
                let dir = AdamW::update_direction(&self.adam, m, v, &r.data, self.t);
                let n_mat = Matrix::from_vec(r.rows, r.cols, dir);
                let full = projector.project_back(&n_mat);
                let alpha = self.cfg.alpha;
                if self.adam.weight_decay > 0.0 {
                    let wd = self.adam.weight_decay;
                    for i in 0..param.numel() {
                        param.data[i] -= lr * wd * param.data[i];
                    }
                }
                for i in 0..param.numel() {
                    param.data[i] -= lr * alpha * full.data[i];
                }
            }
        }
    }

    fn state_bytes(&self) -> usize {
        self.states
            .values()
            .map(|s| match s {
                ParamState::Full { m, v } => (m.len() + v.len()) * 4,
                ParamState::LowRank {
                    projector, m, v, ..
                } => projector.nbytes() + (m.len() + v.len()) * 4,
            })
            .sum()
    }

    fn name(&self) -> &'static str {
        // A quantized projector is the Q-GaLore configuration — keep the
        // distinction visible in logs and Table 1 rows regardless of which
        // execution path built this instance.
        match self.cfg.projection {
            ProjectionKind::Quant8 | ProjectionKind::Quant4 => "qgalore",
            _ => "galore",
        }
    }

    fn export_state(&self) -> Vec<u8> {
        // Serializes moments + P + the SVD-sketch RNG position, so a
        // resumed run's next subspace refresh draws the same sketches the
        // uninterrupted run would have (refresh *schedule* state is
        // reconstructed from the step counter).
        //
        // Format v2 (gated by `ser::STATE_MAGIC2`): P is serialized as its
        // exact STORED representation (`Projector::stored_tensor`, the
        // shared `quant` codec) — codes + block scales for quantized
        // kinds. This is what lifts Q-GaLore's old refresh-alignment
        // resume caveat: re-quantizing a dequantized P (the v1 layout)
        // could wobble a block's absmax scale by 1 ulp, so only
        // checkpoints taken ON a refresh step used to resume bit-exactly.
        let mut out = Vec::new();
        ser::push_u64(&mut out, ser::STATE_MAGIC2);
        ser::push_u64(&mut out, self.t);
        ser::push_u64(&mut out, self.refreshes);
        self.rng.write_state(&mut out);
        ser::push_u64(&mut out, self.states.len() as u64);
        for (&idx, st) in &self.states {
            ser::push_u64(&mut out, idx as u64);
            match st {
                ParamState::Full { m, v } => {
                    ser::push_u64(&mut out, 0);
                    ser::push_f32s(&mut out, m);
                    ser::push_f32s(&mut out, v);
                }
                ParamState::LowRank {
                    projector,
                    m,
                    v,
                    last_refresh,
                } => {
                    ser::push_u64(&mut out, 1);
                    ser::push_u64(&mut out, *last_refresh);
                    ser::push_u64(
                        &mut out,
                        match projector.side {
                            super::ProjectorSide::Left => 0,
                            super::ProjectorSide::Right => 1,
                        },
                    );
                    projector.stored_tensor().encode(&mut out);
                    ser::push_f32s(&mut out, m);
                    ser::push_f32s(&mut out, v);
                }
            }
        }
        out
    }

    fn import_state(&mut self, bytes: &[u8]) -> Result<(), String> {
        let mut r = ser::Reader::new(bytes);
        let first = r.u64()?;
        let v2 = first == ser::STATE_MAGIC2;
        // Legacy (v1) blobs lead directly with the step counter.
        self.t = if v2 { r.u64()? } else { first };
        self.refreshes = r.u64()?;
        self.rng = Pcg64::read_state(r.bytes(Pcg64::STATE_BYTES)?)?;
        let n = r.u64()? as usize;
        // Every state is at least [idx][tag]: reject corrupt counts
        // before allocating.
        if n > r.remaining() / 16 {
            return Err(format!("galore state count {n} exceeds blob size"));
        }
        // Projector kind comes from cfg; P and its side are stored.
        self.states.clear();
        for _ in 0..n {
            let idx = r.u64()? as usize;
            let tag = r.u64()?;
            if tag == 0 {
                let m = r.f32s()?;
                let v = r.f32s()?;
                self.states.insert(idx, ParamState::Full { m, v });
            } else {
                let last_refresh = r.u64()?;
                let side = match r.u64()? {
                    0 => super::ProjectorSide::Left,
                    _ => super::ProjectorSide::Right,
                };
                let projector = if v2 {
                    // Exact stored representation → bitwise restore for
                    // every projection kind, aligned to a refresh or not.
                    let st = crate::quant::StoredTensor::decode(&mut r)?;
                    Projector::from_stored(st, side, self.cfg.projection)
                } else {
                    // v1: dequantized P; quantized kinds re-quantize on
                    // install (the historical near-bitwise behavior).
                    let st = crate::quant::StoredTensor::decode_legacy_f32(&mut r)?;
                    let p = Matrix::from_vec(st.rows(), st.cols(), st.materialize());
                    Projector::from_parts(p, side, self.cfg.projection)
                };
                let m = r.f32s()?;
                let v = r.f32s()?;
                self.states.insert(
                    idx,
                    ParamState::LowRank {
                        projector,
                        m,
                        v,
                        last_refresh,
                    },
                );
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop;

    fn decaying_gradient(m: usize, n: usize, rng: &mut Pcg64) -> Matrix {
        let mut acc = Matrix::zeros(m, n);
        for k in 0..m.min(n) {
            let u = Matrix::randn(m, 1, 1.0, rng);
            let v = Matrix::randn(1, n, 1.0, rng);
            let mut outer = u.matmul(&v);
            outer.scale(0.5f32.powi(k as i32));
            acc.add_assign(&outer);
        }
        acc
    }

    #[test]
    fn identity_projector_galore_is_exactly_adam() {
        // With P = I (full rank, identity basis), α = 1, no refresh, the
        // GaLore update degenerates to plain Adam step-for-step. (Note a
        // *rotated* full-rank basis does NOT reproduce Adam exactly — Adam
        // is elementwise and not rotation-equivariant; this is why the
        // paper treats α as a fractional learning rate rather than claiming
        // equivalence.)
        let mut rng = Pcg64::new(1, 0);
        let target = Matrix::randn(8, 16, 1.0, &mut rng);
        let cfg = GaLoreCfg {
            rank: 8,
            update_freq: 10_000,
            alpha: 1.0,
            projection: ProjectionKind::FullSvd,
            ..GaLoreCfg::default()
        };
        let mut galore = GaLore::new(cfg, AdamCfg::default(), 3);
        let mut adam = crate::optim::AdamW::new(AdamCfg::default());
        let mut wg = Matrix::zeros(8, 16);
        let mut wa = Matrix::zeros(8, 16);
        // Step 0 with a zero gradient creates the state (moments stay 0,
        // params unmoved), then force P = I.
        let zero = Matrix::zeros(8, 16);
        galore.begin_step(0);
        galore.step_param(0, &mut wg, &zero, 0.05);
        galore.install_projector(0, Matrix::eye(8));
        adam.begin_step(0);
        adam.step_param(0, &mut wa, &zero, 0.05);
        for t in 1..50 {
            let gg = wg.sub(&target);
            let ga = wa.sub(&target);
            galore.begin_step(t);
            galore.step_param(0, &mut wg, &gg, 0.05);
            adam.begin_step(t);
            adam.step_param(0, &mut wa, &ga, 0.05);
        }
        let drift = wg.sub(&wa).frobenius_norm() / target.frobenius_norm();
        assert!(drift < 1e-5, "identity-P GaLore drifted {drift} from Adam");
    }

    #[test]
    fn memory_saving_matches_paper_equation() {
        // §3: GaLore state = mr (projector) + 2nr (moments) for m ≤ n,
        // vs Adam's 2mn.
        let (m, n, r) = (64, 256, 16);
        let mut rng = Pcg64::new(2, 0);
        let g = decaying_gradient(m, n, &mut rng);
        let cfg = GaLoreCfg {
            rank: r,
            ..GaLoreCfg::default()
        };
        let mut opt = GaLore::new(cfg, AdamCfg::default(), 5);
        let mut p = Matrix::zeros(m, n);
        opt.begin_step(0);
        opt.step_param(0, &mut p, &g, 0.01);
        let expect = (m * r + 2 * n * r) * 4;
        assert_eq!(opt.state_bytes(), expect);
        let adam_bytes = 2 * m * n * 4;
        assert!(opt.state_bytes() * 3 < adam_bytes);
    }

    #[test]
    fn subspace_refresh_happens_on_schedule() {
        let mut rng = Pcg64::new(3, 0);
        let cfg = GaLoreCfg {
            rank: 4,
            update_freq: 10,
            ..GaLoreCfg::default()
        };
        let mut opt = GaLore::new(cfg, AdamCfg::default(), 9);
        let mut p = Matrix::zeros(8, 24);
        for t in 0..35 {
            let g = decaying_gradient(8, 24, &mut rng);
            opt.begin_step(t);
            opt.step_param(0, &mut p, &g, 0.01);
        }
        // refreshes: initial (t=0) + t=10,20,30 ⇒ 4
        assert_eq!(opt.refresh_count(), 4);
    }

    #[test]
    fn small_params_fall_back_to_full_adam() {
        let cfg = GaLoreCfg {
            rank: 4,
            min_dim: 2,
            ..GaLoreCfg::default()
        };
        let mut opt = GaLore::new(cfg, AdamCfg::default(), 1);
        // 1×n bias-like parameter
        let mut p = Matrix::zeros(1, 16);
        let g = Matrix::from_vec(1, 16, vec![1.0; 16]);
        opt.begin_step(0);
        opt.step_param(0, &mut p, &g, 0.1);
        // full-rank state: 2 * 16 floats
        assert_eq!(opt.state_bytes(), 2 * 16 * 4);
        assert!(p.max_abs() > 0.0);
    }

    #[test]
    fn alpha_scales_update() {
        let mut rng = Pcg64::new(4, 0);
        let g = decaying_gradient(8, 24, &mut rng);
        let mut run = |alpha: f32| {
            let cfg = GaLoreCfg {
                rank: 4,
                alpha,
                projection: ProjectionKind::FullSvd,
                ..GaLoreCfg::default()
            };
            let mut opt = GaLore::new(cfg, AdamCfg::default(), 7);
            let mut p = Matrix::zeros(8, 24);
            opt.begin_step(0);
            opt.step_param(0, &mut p, &g, 0.1);
            p
        };
        let p1 = run(1.0);
        let p2 = run(0.5);
        for (a, b) in p1.data.iter().zip(&p2.data) {
            assert!((a - 2.0 * b).abs() < 1e-5, "{a} vs 2*{b}");
        }
    }

    #[test]
    fn converges_on_low_rank_quadratic() {
        // Target offset is low-rank ⇒ GaLore with matching rank converges.
        let mut rng = Pcg64::new(5, 0);
        let u = Matrix::randn(16, 3, 1.0, &mut rng);
        let v = Matrix::randn(3, 32, 1.0, &mut rng);
        let target = u.matmul(&v);
        let cfg = GaLoreCfg {
            rank: 3,
            update_freq: 25,
            alpha: 1.0,
            ..GaLoreCfg::default()
        };
        let mut opt = GaLore::new(cfg, AdamCfg::default(), 2);
        let mut w = Matrix::zeros(16, 32);
        for t in 0..300 {
            let g = w.sub(&target);
            opt.begin_step(t);
            opt.step_param(0, &mut w, &g, 0.05);
        }
        let rel = w.sub(&target).frobenius_norm() / target.frobenius_norm();
        assert!(rel < 0.05, "rel residual {rel}");
    }

    #[test]
    fn moment_handling_variants_all_converge() {
        let mut rng = Pcg64::new(6, 0);
        let target = decaying_gradient(12, 24, &mut rng);
        for moments in [
            MomentHandling::Keep,
            MomentHandling::Reset,
            MomentHandling::Project,
        ] {
            let cfg = GaLoreCfg {
                rank: 6,
                update_freq: 20,
                alpha: 1.0,
                moments,
                ..GaLoreCfg::default()
            };
            let mut opt = GaLore::new(cfg, AdamCfg::default(), 8);
            let mut w = Matrix::zeros(12, 24);
            for t in 0..250 {
                let g = w.sub(&target);
                opt.begin_step(t);
                opt.step_param(0, &mut w, &g, 0.05);
            }
            let rel = w.sub(&target).frobenius_norm() / target.frobenius_norm();
            assert!(rel < 0.25, "{moments:?} rel {rel}");
        }
    }

    #[test]
    fn preset_projector_honours_moment_handling() {
        // FSDP refresh path: preset_projector must apply cfg.moments like
        // the single-process refresh does (regression: it always kept).
        let mut rng = Pcg64::new(8, 1);
        let g = decaying_gradient(8, 24, &mut rng);
        for moments in [MomentHandling::Keep, MomentHandling::Reset] {
            let cfg = GaLoreCfg {
                rank: 4,
                update_freq: 1000,
                moments,
                external_subspace: true,
                ..GaLoreCfg::default()
            };
            let mut opt = GaLore::new(cfg, AdamCfg::default(), 3);
            opt.begin_step(0);
            let p0 = Projector::from_gradient(&g, 4, ProjectionKind::RandSvd, &mut rng);
            opt.preset_projector(0, p0);
            let mut w = Matrix::zeros(8, 24);
            opt.step_param(0, &mut w, &g, 0.05);
            let bytes_before = opt.export_state();
            let p1 = Projector::from_gradient(&g, 4, ProjectionKind::RandSvd, &mut rng);
            opt.begin_step(1);
            opt.preset_projector(0, p1);
            let bytes_after = opt.export_state();
            let kept = bytes_before.len() == bytes_after.len();
            assert!(kept, "state layout must be stable across refreshes");
            match moments {
                MomentHandling::Reset => {
                    // After reset, a fresh step behaves like step-0 Adam.
                    let mut w2 = Matrix::zeros(8, 24);
                    opt.step_param(0, &mut w2, &Matrix::zeros(8, 24), 0.05);
                    assert!(w2.max_abs() < 1e-6, "moments not reset");
                }
                _ => {}
            }
        }
    }

    #[test]
    fn quantized_projector_resumes_bitwise_off_refresh_boundary() {
        // The v2 state layout serializes P's exact stored representation
        // (codes + block scales), so a quantized-projector checkpoint
        // taken MID refresh-cycle resumes bit-for-bit — the alignment
        // caveat the dequantized v1 layout imposed is gone.
        let mut rng = Pcg64::new(13, 0);
        let target = decaying_gradient(8, 24, &mut rng);
        let cfg = GaLoreCfg {
            rank: 4,
            update_freq: 6,
            alpha: 1.0,
            projection: ProjectionKind::Quant8,
            ..GaLoreCfg::default()
        };
        let mut a = GaLore::new(cfg, AdamCfg::default(), 4);
        let mut wa = Matrix::zeros(8, 24);
        // Boundary at t=8: last refresh was t=6, next is t=12 — the
        // checkpoint crosses neither.
        for t in 0..8 {
            let g = wa.sub(&target);
            a.begin_step(t);
            a.step_param(0, &mut wa, &g, 0.05);
        }
        let blob = a.export_state();
        let mut b = GaLore::new(cfg, AdamCfg::default(), 77); // other seed
        b.import_state(&blob).unwrap();
        assert_eq!(b.export_state(), blob, "import→export must be identity");
        let mut wb = wa.clone();
        for t in 8..15 {
            let ga = wa.sub(&target);
            a.begin_step(t);
            a.step_param(0, &mut wa, &ga, 0.05);
            let gb = wb.sub(&target);
            b.begin_step(t);
            b.step_param(0, &mut wb, &gb, 0.05);
        }
        assert_eq!(wa.data, wb.data, "quantized-projector resume drifted");
    }

    #[test]
    fn legacy_v1_state_blob_still_imports() {
        // Pre-v5 galore blobs lead with the step counter and carry P as
        // dequantized f32s; the format gate must route them through the
        // legacy branch, and the re-export must be the current layout.
        let mut rng = Pcg64::new(3, 0);
        let p = Matrix::randn(8, 4, 0.3, &mut rng);
        let mut blob = Vec::new();
        ser::push_u64(&mut blob, 5); // t (v1 blobs lead with it)
        ser::push_u64(&mut blob, 2); // refreshes
        Pcg64::new(3, 0x6a10).write_state(&mut blob);
        ser::push_u64(&mut blob, 1); // one state
        ser::push_u64(&mut blob, 0); // idx
        ser::push_u64(&mut blob, 1); // low-rank tag
        ser::push_u64(&mut blob, 0); // last_refresh
        ser::push_u64(&mut blob, 0); // side: Left
        ser::push_u64(&mut blob, 8); // p rows
        ser::push_u64(&mut blob, 4); // p cols
        ser::push_f32s(&mut blob, &p.data);
        ser::push_f32s(&mut blob, &vec![0.01; 64]);
        ser::push_f32s(&mut blob, &vec![0.02; 64]);
        let cfg = GaLoreCfg {
            rank: 4,
            update_freq: 100,
            alpha: 1.0,
            ..GaLoreCfg::default()
        };
        let mut opt = GaLore::new(cfg, AdamCfg::default(), 9);
        opt.import_state(&blob).unwrap();
        let mut w = Matrix::zeros(8, 16);
        let g = Matrix::randn(8, 16, 0.1, &mut rng);
        opt.begin_step(5);
        opt.step_param(0, &mut w, &g, 0.05);
        assert!(w.data.iter().all(|x| x.is_finite()));
        assert!(w.max_abs() > 0.0, "legacy state did not drive an update");
        let out = opt.export_state();
        assert!(
            ser::sniff_magic2(&out),
            "re-export must migrate to the v2 layout"
        );
        // Corrupt state counts error before allocating.
        let mut corrupt = Vec::new();
        ser::push_u64(&mut corrupt, ser::STATE_MAGIC2);
        ser::push_u64(&mut corrupt, 0); // t
        ser::push_u64(&mut corrupt, 0); // refreshes
        Pcg64::new(0, 0).write_state(&mut corrupt);
        ser::push_u64(&mut corrupt, u64::MAX);
        let mut fresh = GaLore::new(cfg, AdamCfg::default(), 1);
        assert!(fresh.import_state(&corrupt).is_err());
    }

    #[test]
    fn export_import_resumes_identically() {
        let mut rng = Pcg64::new(7, 0);
        let target = decaying_gradient(8, 20, &mut rng);
        let cfg = GaLoreCfg {
            rank: 4,
            // Refreshes at t=0 (creation), 6, and — inside the post-resume
            // window — t=12: the serialized RNG position must make the
            // resumed optimizer draw the SAME randomized-SVD sketch there.
            update_freq: 6,
            ..GaLoreCfg::default()
        };
        let mut a = GaLore::new(cfg, AdamCfg::default(), 11);
        let mut wa = Matrix::zeros(8, 20);
        for t in 0..10 {
            let g = wa.sub(&target);
            a.begin_step(t);
            a.step_param(0, &mut wa, &g, 0.05);
        }
        let blob = a.export_state();
        let mut b = GaLore::new(cfg, AdamCfg::default(), 99); // different seed
        b.import_state(&blob).unwrap();
        let mut wb = wa.clone();
        for t in 10..15 {
            let ga = wa.sub(&target);
            a.begin_step(t);
            a.step_param(0, &mut wa, &ga, 0.05);
            let gb = wb.sub(&target);
            b.begin_step(t);
            b.step_param(0, &mut wb, &gb, 0.05);
        }
        prop::assert_close(&wa.data, &wb.data, 1e-6, 1e-5).unwrap();
    }
}
