//! SGD with momentum — sanity baseline and the cheapest point on the
//! memory/quality trade-off curve (mn state).

use super::{ser, Optimizer};
use crate::tensor::Matrix;
use std::collections::BTreeMap;

pub struct SgdM {
    momentum: f32,
    velocity: BTreeMap<usize, Vec<f32>>,
    t: u64,
}

impl SgdM {
    pub fn new(momentum: f32) -> SgdM {
        SgdM {
            momentum,
            velocity: BTreeMap::new(),
            t: 0,
        }
    }
}

impl Optimizer for SgdM {
    fn begin_step(&mut self, t: u64) {
        self.t = t;
    }

    fn step_param(&mut self, idx: usize, param: &mut Matrix, grad: &Matrix, lr: f32) {
        assert_eq!(param.shape(), grad.shape());
        let n = param.numel();
        let v = self.velocity.entry(idx).or_insert_with(|| vec![0.0; n]);
        for i in 0..n {
            v[i] = self.momentum * v[i] + grad.data[i];
            param.data[i] -= lr * v[i];
        }
    }

    fn state_bytes(&self) -> usize {
        self.velocity.values().map(|v| v.len() * 4).sum()
    }

    fn name(&self) -> &'static str {
        "sgdm"
    }

    fn export_state(&self) -> Vec<u8> {
        let mut out = Vec::new();
        ser::push_u64(&mut out, self.t);
        ser::push_u64(&mut out, self.velocity.len() as u64);
        for (&idx, v) in &self.velocity {
            ser::push_u64(&mut out, idx as u64);
            ser::push_f32s(&mut out, v);
        }
        out
    }

    fn import_state(&mut self, bytes: &[u8]) -> Result<(), String> {
        let mut r = ser::Reader::new(bytes);
        self.t = r.u64()?;
        let n = r.u64()? as usize;
        self.velocity.clear();
        for _ in 0..n {
            let idx = r.u64()? as usize;
            self.velocity.insert(idx, r.f32s()?);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_momentum_is_plain_sgd() {
        let mut opt = SgdM::new(0.0);
        let mut p = Matrix::from_vec(1, 2, vec![1.0, 2.0]);
        let g = Matrix::from_vec(1, 2, vec![0.5, -0.5]);
        opt.begin_step(0);
        opt.step_param(0, &mut p, &g, 0.1);
        assert!((p.data[0] - 0.95).abs() < 1e-7);
        assert!((p.data[1] - 2.05).abs() < 1e-7);
    }

    #[test]
    fn momentum_accumulates() {
        let mut opt = SgdM::new(0.9);
        let mut p = Matrix::zeros(1, 1);
        let g = Matrix::from_vec(1, 1, vec![1.0]);
        opt.begin_step(0);
        opt.step_param(0, &mut p, &g, 1.0);
        let first = -p.data[0]; // = 1
        opt.begin_step(1);
        opt.step_param(0, &mut p, &g, 1.0);
        let second = -p.data[0] - first; // = 1.9
        assert!((first - 1.0).abs() < 1e-6);
        assert!((second - 1.9).abs() < 1e-6);
    }
}
