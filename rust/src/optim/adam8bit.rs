//! 8-bit Adam (Dettmers et al. 2022) — the paper's 500B-token baseline.
//!
//! Moment tensors are stored in the block-wise dynamic 8-bit code from
//! `crate::quant`; each step dequantizes a block, applies the Adam
//! recurrence in fp32, and re-quantizes. This quarters optimizer memory
//! versus fp32 Adam while tracking it closely — exactly the trade the
//! paper's baseline makes (state: 2·mn bytes instead of 8·mn).

use super::{ser, AdamCfg, Optimizer};
use crate::quant::Quantized8;
use crate::tensor::Matrix;
use std::collections::BTreeMap;

struct State {
    m: Quantized8,
    v: Quantized8,
}

pub struct Adam8bit {
    cfg: AdamCfg,
    states: BTreeMap<usize, State>,
    t: u64,
}

impl Adam8bit {
    pub fn new(cfg: AdamCfg) -> Adam8bit {
        Adam8bit {
            cfg,
            states: BTreeMap::new(),
            t: 0,
        }
    }
}

impl Optimizer for Adam8bit {
    fn begin_step(&mut self, t: u64) {
        self.t = t;
    }

    fn step_param(&mut self, idx: usize, param: &mut Matrix, grad: &Matrix, lr: f32) {
        assert_eq!(param.shape(), grad.shape());
        let n = param.numel();
        let st = self.states.entry(idx).or_insert_with(|| State {
            m: Quantized8::quantize(&vec![0.0; n]),
            v: Quantized8::quantize(&vec![0.0; n]),
        });
        // Dequantize → fp32 Adam recurrence → requantize.
        let mut m = st.m.dequantize();
        let mut v = st.v.dequantize();
        // v is stored via its sqrt-friendly positive values; recurrences as usual.
        let b1 = self.cfg.beta1;
        let b2 = self.cfg.beta2;
        let bc1 = 1.0 - b1.powi(self.t as i32 + 1);
        let bc2 = 1.0 - b2.powi(self.t as i32 + 1);
        let wd = self.cfg.weight_decay;
        for i in 0..n {
            let g = grad.data[i];
            m[i] = b1 * m[i] + (1.0 - b1) * g;
            v[i] = (b2 * v[i] + (1.0 - b2) * g * g).max(0.0);
            let m_hat = m[i] / bc1;
            let v_hat = v[i] / bc2;
            if wd > 0.0 {
                param.data[i] -= lr * wd * param.data[i];
            }
            param.data[i] -= lr * m_hat / (v_hat.sqrt() + self.cfg.eps);
        }
        st.m = Quantized8::quantize(&m);
        st.v = Quantized8::quantize(&v);
    }

    fn state_bytes(&self) -> usize {
        self.states
            .values()
            .map(|s| s.m.nbytes() + s.v.nbytes())
            .sum()
    }

    fn name(&self) -> &'static str {
        "adam8bit"
    }

    fn export_state(&self) -> Vec<u8> {
        // Serialize dequantized moments: simple and checkpoint-compatible
        // across quantizer versions (state re-quantizes on import).
        let mut out = Vec::new();
        ser::push_u64(&mut out, self.t);
        ser::push_u64(&mut out, self.states.len() as u64);
        for (&idx, st) in &self.states {
            ser::push_u64(&mut out, idx as u64);
            ser::push_f32s(&mut out, &st.m.dequantize());
            ser::push_f32s(&mut out, &st.v.dequantize());
        }
        out
    }

    fn import_state(&mut self, bytes: &[u8]) -> Result<(), String> {
        let mut r = ser::Reader::new(bytes);
        self.t = r.u64()?;
        let n = r.u64()? as usize;
        self.states.clear();
        for _ in 0..n {
            let idx = r.u64()? as usize;
            let m = r.f32s()?;
            let v = r.f32s()?;
            self.states.insert(
                idx,
                State {
                    m: Quantized8::quantize(&m),
                    v: Quantized8::quantize(&v),
                },
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::AdamW;
    use crate::util::rng::Pcg64;

    #[test]
    fn tracks_fp32_adam_closely() {
        // On a smooth trajectory the 8-bit state should stay within a few
        // percent of fp32 Adam (the design point of Dettmers et al.).
        let mut rng = Pcg64::new(1, 0);
        let target = Matrix::randn(8, 32, 1.0, &mut rng);
        let mut w8 = Matrix::zeros(8, 32);
        let mut w32 = Matrix::zeros(8, 32);
        let mut o8 = Adam8bit::new(AdamCfg::default());
        let mut o32 = AdamW::new(AdamCfg::default());
        for t in 0..150 {
            let g8 = w8.sub(&target);
            let g32 = w32.sub(&target);
            o8.begin_step(t);
            o8.step_param(0, &mut w8, &g8, 0.05);
            o32.begin_step(t);
            o32.step_param(0, &mut w32, &g32, 0.05);
        }
        let drift = w8.sub(&w32).frobenius_norm() / target.frobenius_norm();
        assert!(drift < 0.05, "8-bit drifted {drift} from fp32 Adam");
    }

    #[test]
    fn state_is_quarter_of_fp32() {
        let mut o8 = Adam8bit::new(AdamCfg::default());
        let mut o32 = AdamW::new(AdamCfg::default());
        let mut p = Matrix::zeros(32, 32); // multiple of block size
        let g = Matrix::from_vec(32, 32, vec![0.1; 1024]);
        o8.begin_step(0);
        o8.step_param(0, &mut p.clone(), &g, 0.1);
        o32.begin_step(0);
        o32.step_param(0, &mut p, &g, 0.1);
        let ratio = o32.state_bytes() as f64 / o8.state_bytes() as f64;
        assert!(ratio > 3.5 && ratio < 4.1, "ratio {ratio}");
    }

    #[test]
    fn second_moment_never_negative() {
        let mut opt = Adam8bit::new(AdamCfg::default());
        let mut p = Matrix::zeros(4, 64);
        let mut rng = Pcg64::new(2, 0);
        for t in 0..50 {
            let g = Matrix::randn(4, 64, 1.0, &mut rng);
            opt.begin_step(t);
            opt.step_param(0, &mut p, &g, 0.01);
        }
        let v = opt.states[&0].v.dequantize();
        assert!(v.iter().all(|&x| x >= 0.0));
        assert!(p.data.iter().all(|x| x.is_finite()));
    }
}
