//! 8-bit Adam (Dettmers et al. 2022) — the paper's 500B-token baseline.
//!
//! Moment tensors are stored in the block-wise dynamic 8-bit code from
//! `crate::quant`; each step dequantizes a block, applies the Adam
//! recurrence in fp32, and re-quantizes. This quarters optimizer memory
//! versus fp32 Adam while tracking it closely — exactly the trade the
//! paper's baseline makes (state: 2·mn bytes instead of 8·mn).

use super::{ser, AdamCfg, Optimizer};
use crate::quant::Quantized8;
use crate::tensor::Matrix;
use std::collections::BTreeMap;

struct State {
    m: Quantized8,
    v: Quantized8,
}

pub struct Adam8bit {
    cfg: AdamCfg,
    states: BTreeMap<usize, State>,
    t: u64,
}

impl Adam8bit {
    pub fn new(cfg: AdamCfg) -> Adam8bit {
        Adam8bit {
            cfg,
            states: BTreeMap::new(),
            t: 0,
        }
    }
}

impl Optimizer for Adam8bit {
    fn begin_step(&mut self, t: u64) {
        self.t = t;
    }

    fn step_param(&mut self, idx: usize, param: &mut Matrix, grad: &Matrix, lr: f32) {
        assert_eq!(param.shape(), grad.shape());
        let n = param.numel();
        let st = self.states.entry(idx).or_insert_with(|| State {
            m: Quantized8::quantize(&vec![0.0; n]),
            v: Quantized8::quantize(&vec![0.0; n]),
        });
        // Dequantize → fp32 Adam recurrence → requantize.
        let mut m = st.m.dequantize();
        let mut v = st.v.dequantize();
        // v is stored via its sqrt-friendly positive values; recurrences as usual.
        let b1 = self.cfg.beta1;
        let b2 = self.cfg.beta2;
        let bc1 = 1.0 - b1.powi(self.t as i32 + 1);
        let bc2 = 1.0 - b2.powi(self.t as i32 + 1);
        let wd = self.cfg.weight_decay;
        for i in 0..n {
            let g = grad.data[i];
            m[i] = b1 * m[i] + (1.0 - b1) * g;
            v[i] = (b2 * v[i] + (1.0 - b2) * g * g).max(0.0);
            let m_hat = m[i] / bc1;
            let v_hat = v[i] / bc2;
            if wd > 0.0 {
                param.data[i] -= lr * wd * param.data[i];
            }
            param.data[i] -= lr * m_hat / (v_hat.sqrt() + self.cfg.eps);
        }
        st.m = Quantized8::quantize(&m);
        st.v = Quantized8::quantize(&v);
    }

    fn state_bytes(&self) -> usize {
        self.states
            .values()
            .map(|s| s.m.nbytes() + s.v.nbytes())
            .sum()
    }

    fn name(&self) -> &'static str {
        "adam8bit"
    }

    fn export_state(&self) -> Vec<u8> {
        // Serialize the exact stored representation (codes + block scales
        // via the shared `quant` codec): the stored INT8 state *is* the
        // optimizer state (Q-GaLore's observation), so a resumed run
        // continues from the identical quantization — a dequantized f32
        // export would re-block on import and could move absmax scales.
        // Layout gate: the blob leads with `STATE_MAGIC2`; legacy blobs
        // (dequantized f32 moments) lead with their small step counter.
        let mut out = Vec::new();
        ser::push_u64(&mut out, ser::STATE_MAGIC2);
        ser::push_u64(&mut out, self.t);
        ser::push_u64(&mut out, self.states.len() as u64);
        for (&idx, st) in &self.states {
            ser::push_u64(&mut out, idx as u64);
            st.m.encode(&mut out);
            st.v.encode(&mut out);
        }
        out
    }

    fn import_state(&mut self, bytes: &[u8]) -> Result<(), String> {
        let mut r = ser::Reader::new(bytes);
        let first = r.u64()?;
        self.states.clear();
        if first == ser::STATE_MAGIC2 {
            // Current layout: exact codes + scales, bitwise resume.
            self.t = r.u64()?;
            let n = r.u64()? as usize;
            // Every state is at least [idx] + two block headers: reject
            // corrupt counts before allocating.
            if n > r.remaining() / (8 * 3) {
                return Err(format!("adam8bit state count {n} exceeds blob size"));
            }
            for _ in 0..n {
                let idx = r.u64()? as usize;
                let m = Quantized8::decode(&mut r)?;
                let v = Quantized8::decode(&mut r)?;
                self.states.insert(idx, State { m, v });
            }
        } else {
            // Legacy layout (pre-v5 checkpoints): dequantized f32 moments;
            // re-quantizing on import reproduces the historical behavior.
            self.t = first;
            let n = r.u64()? as usize;
            if n > r.remaining() / (8 * 3) {
                return Err(format!("adam8bit state count {n} exceeds blob size"));
            }
            for _ in 0..n {
                let idx = r.u64()? as usize;
                let m = r.f32s()?;
                let v = r.f32s()?;
                self.states.insert(
                    idx,
                    State {
                        m: Quantized8::quantize(&m),
                        v: Quantized8::quantize(&v),
                    },
                );
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::AdamW;
    use crate::util::rng::Pcg64;

    #[test]
    fn tracks_fp32_adam_closely() {
        // On a smooth trajectory the 8-bit state should stay within a few
        // percent of fp32 Adam (the design point of Dettmers et al.).
        let mut rng = Pcg64::new(1, 0);
        let target = Matrix::randn(8, 32, 1.0, &mut rng);
        let mut w8 = Matrix::zeros(8, 32);
        let mut w32 = Matrix::zeros(8, 32);
        let mut o8 = Adam8bit::new(AdamCfg::default());
        let mut o32 = AdamW::new(AdamCfg::default());
        for t in 0..150 {
            let g8 = w8.sub(&target);
            let g32 = w32.sub(&target);
            o8.begin_step(t);
            o8.step_param(0, &mut w8, &g8, 0.05);
            o32.begin_step(t);
            o32.step_param(0, &mut w32, &g32, 0.05);
        }
        let drift = w8.sub(&w32).frobenius_norm() / target.frobenius_norm();
        assert!(drift < 0.05, "8-bit drifted {drift} from fp32 Adam");
    }

    #[test]
    fn state_is_quarter_of_fp32() {
        let mut o8 = Adam8bit::new(AdamCfg::default());
        let mut o32 = AdamW::new(AdamCfg::default());
        let mut p = Matrix::zeros(32, 32); // multiple of block size
        let g = Matrix::from_vec(32, 32, vec![0.1; 1024]);
        o8.begin_step(0);
        o8.step_param(0, &mut p.clone(), &g, 0.1);
        o32.begin_step(0);
        o32.step_param(0, &mut p, &g, 0.1);
        let ratio = o32.state_bytes() as f64 / o8.state_bytes() as f64;
        assert!(ratio > 3.5 && ratio < 4.1, "ratio {ratio}");
    }

    #[test]
    fn export_carries_stored_representation_and_resumes_bitwise() {
        // The state blob leads with the format gate and round-trips the
        // exact codes + scales: a resumed optimizer continues bit-for-bit
        // on the uninterrupted trajectory (the old dequantized export only
        // did so up to re-quantization).
        let mut rng = Pcg64::new(5, 0);
        let target = Matrix::randn(4, 96, 1.0, &mut rng);
        let mut a = Adam8bit::new(AdamCfg::default());
        let mut wa = Matrix::zeros(4, 96);
        for t in 0..9 {
            let g = wa.sub(&target);
            a.begin_step(t);
            a.step_param(0, &mut wa, &g, 0.05);
        }
        let blob = a.export_state();
        assert!(
            crate::optim::ser::sniff_magic2(&blob),
            "stored-representation blob must lead with the format gate"
        );
        let mut b = Adam8bit::new(AdamCfg::default());
        b.import_state(&blob).unwrap();
        assert_eq!(b.export_state(), blob, "import→export must be identity");
        let mut wb = wa.clone();
        for t in 9..14 {
            let ga = wa.sub(&target);
            a.begin_step(t);
            a.step_param(0, &mut wa, &ga, 0.05);
            let gb = wb.sub(&target);
            b.begin_step(t);
            b.step_param(0, &mut wb, &gb, 0.05);
        }
        assert_eq!(wa.data, wb.data, "adam8bit resume diverged");
    }

    #[test]
    fn legacy_f32_state_still_imports() {
        // Pre-v5 blobs carry dequantized f32 moments behind a small step
        // counter; the gate must route them through the re-quantizing
        // legacy branch, and corrupt counts must error, not abort.
        use crate::optim::ser;
        let mut legacy = Vec::new();
        ser::push_u64(&mut legacy, 3); // t (legacy blobs lead with it)
        ser::push_u64(&mut legacy, 1); // one state
        ser::push_u64(&mut legacy, 0); // idx
        ser::push_f32s(&mut legacy, &[0.25; 16]);
        ser::push_f32s(&mut legacy, &[0.5; 16]);
        let mut opt = Adam8bit::new(AdamCfg::default());
        opt.import_state(&legacy).unwrap();
        let back = opt.states[&0].m.dequantize();
        assert!((back[0] - 0.25).abs() < 0.02, "legacy moments lost: {back:?}");

        let mut corrupt = Vec::new();
        ser::push_u64(&mut corrupt, ser::STATE_MAGIC2);
        ser::push_u64(&mut corrupt, 0); // t
        ser::push_u64(&mut corrupt, u64::MAX); // insane state count
        assert!(Adam8bit::new(AdamCfg::default()).import_state(&corrupt).is_err());
    }

    #[test]
    fn second_moment_never_negative() {
        let mut opt = Adam8bit::new(AdamCfg::default());
        let mut p = Matrix::zeros(4, 64);
        let mut rng = Pcg64::new(2, 0);
        for t in 0..50 {
            let g = Matrix::randn(4, 64, 1.0, &mut rng);
            opt.begin_step(t);
            opt.step_param(0, &mut p, &g, 0.01);
        }
        let v = opt.states[&0].v.dequantize();
        assert!(v.iter().all(|&x| x >= 0.0));
        assert!(p.data.iter().all(|x| x.is_finite()));
    }
}
