//! Optimizers.
//!
//! The paper's contribution ([`GaLore`]) plus every optimizer it is compared
//! against or composed with:
//!   * [`AdamW`] — full-rank baseline (Table 1's "AdamW + FSDP"),
//!   * [`Adam8bit`] — block-wise quantized Adam (Dettmers et al. 2022), the
//!     baseline of the 500B-token run (Fig. 3),
//!   * [`Adafactor`] — sublinear-memory baseline from related work,
//!   * [`SgdM`] — sanity baseline,
//!   * [`GaLore`] — gradient low-rank projection wrapper (§3, Alg. 1),
//!   * [`QGaLore`] — quantized projector + lazy subspace updates (§4.2),
//!   * Tensor-GaLore mode-k projection for ≥3-d parameters (§4.2).
//!
//! All optimizers implement [`Optimizer`], a per-parameter interface so the
//! FSDP engine can run *per-layer fused updates*: as soon as a layer's
//! gradient is reduce-scattered, `step_param` is called and the gradient
//! buffer is dropped (Fig. 2 integration).

mod adafactor;
mod adam8bit;
mod adamw;
mod galore;
pub mod lr;
mod projector;
mod qgalore;
mod sgdm;
pub mod spec;
mod tensor_galore;

pub use adafactor::Adafactor;
pub use adam8bit::Adam8bit;
pub use adamw::{AdamCfg, AdamW};
pub use galore::{GaLore, GaLoreCfg, MomentHandling};
pub use projector::{ProjectionKind, Projector, ProjectorSide};
pub use qgalore::{QGaLore, QGaLoreCfg};
pub use sgdm::SgdM;
pub use spec::{BuildTarget, OptimizerSpec, PjrtResources, WorkerOpt};
pub use tensor_galore::TensorGaLore;

use crate::tensor::Matrix;

/// Per-parameter optimizer interface.
///
/// State is keyed by a caller-assigned stable parameter index; shapes must
/// be consistent across calls for a given index. `begin_step` advances the
/// global step counter (bias correction, subspace schedule); callers must
/// invoke it exactly once per training step before any `step_param`.
/// (Not `Send`: distributed engines construct optimizers inside worker
/// threads from [`OptimizerSpec`], and the PJRT-backed engine holds
/// non-Send device handles.)
pub trait Optimizer {
    /// Advance to training step `t` (0-based).
    fn begin_step(&mut self, t: u64);

    /// Apply the update for one parameter given its gradient.
    /// `lr` is the (already scheduled) learning rate for this step.
    fn step_param(&mut self, idx: usize, param: &mut Matrix, grad: &Matrix, lr: f32);

    /// Bytes of optimizer state currently held (for the memory model and
    /// Table 1 telemetry).
    fn state_bytes(&self) -> usize;

    fn name(&self) -> &'static str;

    /// Serialize optimizer state (checkpointing). Format is
    /// optimizer-private; round-trips through `import_state`.
    fn export_state(&self) -> Vec<u8> {
        Vec::new()
    }

    fn import_state(&mut self, _bytes: &[u8]) -> Result<(), String> {
        Ok(())
    }
}

/// Convenience: run one full step over a parameter list.
pub fn step_all(
    opt: &mut dyn Optimizer,
    t: u64,
    params: &mut [Matrix],
    grads: &[Matrix],
    lr: f32,
) {
    assert_eq!(params.len(), grads.len());
    opt.begin_step(t);
    for (idx, (p, g)) in params.iter_mut().zip(grads).enumerate() {
        opt.step_param(idx, p, g, lr);
    }
}

/// Serialization helpers shared by optimizer `export_state` impls.
pub(crate) mod ser {
    /// Format gate for optimizer state blobs that switched to serializing
    /// the exact *stored* representation (codes + block scales) instead of
    /// dequantized f32 values: a bumped blob starts with this u64, while
    /// every legacy blob starts with a small little-endian step counter —
    /// so `first == STATE_MAGIC2` distinguishes the layouts unambiguously
    /// and old checkpoints keep loading through the legacy branch.
    pub const STATE_MAGIC2: u64 = u64::from_le_bytes(*b"GALSTAT\x02");

    /// True when `bytes` begins with [`STATE_MAGIC2`]. The one sanctioned
    /// way to sniff the format gate — callers must not reimplement the
    /// byte-layout comparison (single-parser invariant).
    pub fn sniff_magic2(bytes: &[u8]) -> bool {
        match bytes.get(..8) {
            Some(head) => {
                let mut b = [0u8; 8];
                b.copy_from_slice(head);
                u64::from_le_bytes(b) == STATE_MAGIC2
            }
            None => false,
        }
    }

    pub fn push_u64(out: &mut Vec<u8>, x: u64) {
        out.extend_from_slice(&x.to_le_bytes());
    }
    pub fn push_u32(out: &mut Vec<u8>, x: u32) {
        out.extend_from_slice(&x.to_le_bytes());
    }
    pub fn push_f32s(out: &mut Vec<u8>, xs: &[f32]) {
        push_u64(out, xs.len() as u64);
        push_f32s_raw(out, xs);
    }
    /// f32 payload with NO length prefix — for formats whose element
    /// count lives in already-written header fields (checkpoint params).
    pub fn push_f32s_raw(out: &mut Vec<u8>, xs: &[f32]) {
        for &x in xs {
            out.extend_from_slice(&x.to_le_bytes());
        }
    }
    pub struct Reader<'a> {
        buf: &'a [u8],
        pos: usize,
    }
    impl<'a> Reader<'a> {
        pub fn new(buf: &'a [u8]) -> Self {
            Reader { buf, pos: 0 }
        }
        pub fn u64(&mut self) -> Result<u64, String> {
            let end = self.pos + 8;
            let bytes = self.buf.get(self.pos..end).ok_or("truncated state")?;
            self.pos = end;
            Ok(u64::from_le_bytes(bytes.try_into().unwrap()))
        }
        pub fn u32(&mut self) -> Result<u32, String> {
            let end = self.pos + 4;
            let bytes = self.buf.get(self.pos..end).ok_or("truncated state")?;
            self.pos = end;
            Ok(u32::from_le_bytes(bytes.try_into().unwrap()))
        }
        pub fn f32s(&mut self) -> Result<Vec<f32>, String> {
            let n = self.u64()? as usize;
            self.f32s_exact(n)
        }
        /// Exactly `n` f32 values, no length prefix (counterpart of
        /// `push_f32s_raw`; `n` comes from validated header fields).
        pub fn f32s_exact(&mut self, n: usize) -> Result<Vec<f32>, String> {
            // Checked: a corrupt length must error, not overflow (debug)
            // or wrap (release) before the range check catches it.
            let nbytes = n.checked_mul(4).ok_or("truncated state")?;
            if nbytes > self.remaining() {
                return Err("truncated state".into());
            }
            Ok(self
                .bytes(nbytes)?
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect())
        }
        /// Bytes left to read — lets parsers sanity-check untrusted
        /// counts BEFORE allocating (`Vec::with_capacity` on a corrupt
        /// u64 would abort instead of returning an error).
        pub fn remaining(&self) -> usize {
            self.buf.len() - self.pos
        }
        /// Raw byte slice of length `n` (nested optimizer blobs).
        pub fn bytes(&mut self, n: usize) -> Result<&'a [u8], String> {
            let end = self.pos.checked_add(n).ok_or("truncated state")?;
            let bytes = self.buf.get(self.pos..end).ok_or("truncated state")?;
            self.pos = end;
            Ok(bytes)
        }
        #[allow(dead_code)] // used by tests; kept for state-format debugging
        pub fn done(&self) -> bool {
            self.pos == self.buf.len()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    /// Shared harness: optimize a convex quadratic f(W) = ½‖W − T‖² whose
    /// gradient is (W − T); every reasonable optimizer must converge.
    pub(crate) fn converges_on_quadratic(opt: &mut dyn Optimizer, lr: f32, steps: u64) -> f32 {
        let mut rng = Pcg64::new(42, 0);
        let target = Matrix::randn(16, 24, 1.0, &mut rng);
        let mut w = Matrix::zeros(16, 24);
        for t in 0..steps {
            let grad = w.sub(&target);
            opt.begin_step(t);
            opt.step_param(0, &mut w, &grad, lr);
        }
        w.sub(&target).frobenius_norm() / target.frobenius_norm()
    }

    #[test]
    fn every_optimizer_converges_on_quadratic() {
        // (optimizer, lr, steps, tolerance). Adafactor's RMS-clipped update
        // plateaus at ~lr, so it runs with a small lr and a looser bound.
        let cases: Vec<(Box<dyn Optimizer>, f32, u64, f32)> = vec![
            (Box::new(AdamW::new(AdamCfg::default())), 0.05, 400, 0.05),
            (Box::new(Adam8bit::new(AdamCfg::default())), 0.05, 400, 0.05),
            (Box::new(Adafactor::new(1e-3)), 0.02, 800, 0.10),
            (Box::new(SgdM::new(0.9)), 0.3, 400, 0.05),
            (
                Box::new(GaLore::new(
                    GaLoreCfg {
                        rank: 16, // full rank for the 16x24 test matrix
                        update_freq: 50,
                        alpha: 1.0,
                        ..GaLoreCfg::default()
                    },
                    AdamCfg::default(),
                    7,
                )),
                0.05,
                400,
                0.05,
            ),
        ];
        for (mut opt, lr, steps, tol) in cases {
            let rel = converges_on_quadratic(opt.as_mut(), lr, steps);
            assert!(
                rel < tol,
                "{} did not converge: rel residual {rel} (tol {tol})",
                opt.name()
            );
        }
    }

    #[test]
    fn step_all_updates_every_param() {
        let mut opt = AdamW::new(AdamCfg::default());
        let mut params = vec![Matrix::zeros(4, 4), Matrix::zeros(2, 8)];
        let grads = vec![
            Matrix::from_vec(4, 4, vec![1.0; 16]),
            Matrix::from_vec(2, 8, vec![1.0; 16]),
        ];
        step_all(&mut opt, 0, &mut params, &grads, 0.1);
        for p in &params {
            assert!(p.max_abs() > 0.0);
        }
    }

    #[test]
    fn ser_roundtrip() {
        use super::ser::*;
        let mut buf = Vec::new();
        push_u64(&mut buf, 7);
        push_f32s(&mut buf, &[1.5, -2.5]);
        let mut r = Reader::new(&buf);
        assert_eq!(r.u64().unwrap(), 7);
        assert_eq!(r.f32s().unwrap(), vec![1.5, -2.5]);
        assert!(r.done());
    }
}
