//! Tensor-GaLore (George et al. 2024; incorporated in §4.2).
//!
//! Extends gradient low-rank projection to parameters with ≥3 modes (e.g.
//! Fourier-operator weights, conv kernels): the gradient tensor is unfolded
//! along its largest mode, projected with a rank-r subspace of that mode's
//! unfolding (a Tucker-1 projection), and the inner Adam runs on the
//! projected core. This keeps the projector small (n_k × r) while the state
//! shrinks by n_k/r along the projected mode.

use super::adamw::AdamW;
use super::projector::{ProjectionKind, Projector};
use super::{AdamCfg, Optimizer};
use crate::tensor::{Matrix, Tensor};
use crate::util::rng::Pcg64;
use std::collections::BTreeMap;

pub struct TensorGaLore {
    pub rank: usize,
    pub update_freq: u64,
    pub alpha: f32,
    pub projection: ProjectionKind,
    adam: AdamCfg,
    states: BTreeMap<usize, State>,
    rng: Pcg64,
    t: u64,
}

struct State {
    /// Projector over the unfolded mode.
    projector: Projector,
    mode: usize,
    shape: Vec<usize>,
    m: Vec<f32>,
    v: Vec<f32>,
    last_refresh: u64,
}

impl TensorGaLore {
    pub fn new(
        rank: usize,
        update_freq: u64,
        alpha: f32,
        projection: ProjectionKind,
        adam: AdamCfg,
        seed: u64,
    ) -> TensorGaLore {
        TensorGaLore {
            rank,
            update_freq,
            alpha,
            projection,
            adam,
            states: BTreeMap::new(),
            rng: Pcg64::new(seed, 0x760a),
            t: 0,
        }
    }

    /// One optimizer step on an N-d parameter. (The [`Optimizer`] trait is
    /// matrix-shaped; tensors enter through this dedicated entry point and
    /// the trait impl handles the 2-d case by delegation.)
    pub fn step_tensor(&mut self, idx: usize, param: &mut Tensor, grad: &Tensor, lr: f32) {
        assert_eq!(param.shape, grad.shape);
        // Project along the largest mode — the biggest memory win.
        let mode = grad
            .shape
            .iter()
            .enumerate()
            .max_by_key(|(_, &s)| s)
            .map(|(i, _)| i)
            .unwrap();
        let unfolded = grad.unfold(mode);
        let t_now = self.t;
        let (rank, projection, update_freq) = (self.rank, self.projection, self.update_freq);
        let state = self.states.entry(idx).or_insert_with(|| {
            let projector =
                Projector::from_gradient(&unfolded, rank, projection, &mut self.rng);
            let (lm, ln) = projector.low_rank_shape(unfolded.rows, unfolded.cols);
            State {
                projector,
                mode,
                shape: grad.shape.clone(),
                m: vec![0.0; lm * ln],
                v: vec![0.0; lm * ln],
                last_refresh: t_now,
            }
        });
        assert_eq!(state.shape, grad.shape, "param {idx} changed shape");
        assert_eq!(state.mode, mode);

        if t_now % update_freq == 0 && t_now != state.last_refresh {
            state.projector.refresh(&unfolded, &mut self.rng);
            state.last_refresh = t_now;
        }

        let r = state.projector.project(&unfolded);
        let dir = AdamW::update_direction(&self.adam, &mut state.m, &mut state.v, &r.data, t_now);
        let n_mat = Matrix::from_vec(r.rows, r.cols, dir);
        let full_unfolded = state.projector.project_back(&n_mat);
        let full = Tensor::fold(&full_unfolded, mode, &grad.shape);
        for i in 0..param.numel() {
            param.data[i] -= lr * self.alpha * full.data[i];
        }
    }
}

impl Optimizer for TensorGaLore {
    fn begin_step(&mut self, t: u64) {
        self.t = t;
    }

    fn step_param(&mut self, idx: usize, param: &mut Matrix, grad: &Matrix, lr: f32) {
        // 2-d parameters are rank-1 tensors of the same machinery.
        let shape = [param.rows, param.cols];
        let mut pt = Tensor::from_vec(&shape, param.data.clone());
        let gt = Tensor::from_vec(&shape, grad.data.clone());
        self.step_tensor(idx, &mut pt, &gt, lr);
        param.data.copy_from_slice(&pt.data);
    }

    fn state_bytes(&self) -> usize {
        self.states
            .values()
            .map(|s| s.projector.nbytes() + (s.m.len() + s.v.len()) * 4)
            .sum()
    }

    fn name(&self) -> &'static str {
        "tensor_galore"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn low_rank_tensor(shape: &[usize], rank: usize, rng: &mut Pcg64) -> Tensor {
        // Build a tensor whose largest-mode unfolding has rank ≤ `rank`.
        let mode = shape
            .iter()
            .enumerate()
            .max_by_key(|(_, &s)| s)
            .map(|(i, _)| i)
            .unwrap();
        let n_k = shape[mode];
        let other: usize = shape.iter().product::<usize>() / n_k;
        let a = Matrix::randn(n_k, rank, 1.0, rng);
        let b = Matrix::randn(rank, other, 1.0, rng);
        Tensor::fold(&a.matmul(&b), mode, shape)
    }

    #[test]
    fn converges_on_3d_quadratic() {
        let mut rng = Pcg64::new(1, 0);
        let shape = [6, 20, 8];
        let target = low_rank_tensor(&shape, 3, &mut rng);
        let mut opt = TensorGaLore::new(
            3,
            50,
            1.0,
            ProjectionKind::RandSvd,
            AdamCfg::default(),
            5,
        );
        let mut w = Tensor::zeros(&shape);
        for t in 0..300 {
            let grad = Tensor::from_vec(
                &shape,
                w.data.iter().zip(&target.data).map(|(a, b)| a - b).collect(),
            );
            opt.begin_step(t);
            opt.step_tensor(0, &mut w, &grad, 0.05);
        }
        let num: f32 = w
            .data
            .iter()
            .zip(&target.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f32>()
            .sqrt();
        let den: f32 = target.data.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!(num / den < 0.08, "rel {}", num / den);
    }

    #[test]
    fn state_smaller_than_full_adam() {
        let mut rng = Pcg64::new(2, 0);
        let shape = [8, 64, 8];
        let target = low_rank_tensor(&shape, 4, &mut rng);
        let mut opt =
            TensorGaLore::new(4, 100, 1.0, ProjectionKind::RandSvd, AdamCfg::default(), 6);
        let mut w = Tensor::zeros(&shape);
        let grad = Tensor::from_vec(
            &shape,
            w.data.iter().zip(&target.data).map(|(a, b)| a - b).collect(),
        );
        opt.begin_step(0);
        opt.step_tensor(0, &mut w, &grad, 0.01);
        let full_adam = 2 * shape.iter().product::<usize>() * 4;
        assert!(
            opt.state_bytes() * 2 < full_adam,
            "{} vs {}",
            opt.state_bytes(),
            full_adam
        );
    }

    #[test]
    fn matrix_trait_path_works() {
        let mut opt =
            TensorGaLore::new(2, 100, 1.0, ProjectionKind::RandSvd, AdamCfg::default(), 7);
        let mut rng = Pcg64::new(3, 0);
        let a = Matrix::randn(8, 2, 1.0, &mut rng);
        let b = Matrix::randn(2, 16, 1.0, &mut rng);
        let target = a.matmul(&b);
        let mut w = Matrix::zeros(8, 16);
        for t in 0..200 {
            let g = w.sub(&target);
            opt.begin_step(t);
            opt.step_param(0, &mut w, &g, 0.05);
        }
        let rel = w.sub(&target).frobenius_norm() / target.frobenius_norm();
        assert!(rel < 0.1, "rel {rel}");
    }
}
