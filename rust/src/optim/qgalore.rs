//! Q-GaLore (Zhang et al. 2024; incorporated in §4.2).
//!
//! Two additions over plain GaLore:
//!   1. the projection matrix is stored in a low-bit linear code (int8 by
//!      default, int4 optionally) — delegated to [`ProjectionKind::Quant8`]
//!      / `Quant4` in the shared projector;
//!   2. *layer-adaptive lazy subspace updates*: at each scheduled refresh,
//!      the candidate projector is compared with the current one (cosine
//!      similarity of dominant directions); if the subspace has barely
//!      rotated, the refresh is skipped and the SVD cost saved. Layers
//!      whose gradients stabilize stop paying for subspace updates.

use super::galore::{GaLore, GaLoreCfg};
use super::projector::ProjectionKind;
use super::{ser, AdamCfg, Optimizer};
use crate::tensor::Matrix;

#[derive(Clone, Copy, Debug)]
pub struct QGaLoreCfg {
    pub galore: GaLoreCfg,
    /// Cosine-similarity threshold above which a refresh is skipped.
    /// (Q-GaLore's paper uses ~0.4 on quantized projectors; 1.0 disables
    /// laziness, 0.0 skips every refresh after the first.)
    pub similarity_threshold: f32,
}

impl Default for QGaLoreCfg {
    fn default() -> Self {
        QGaLoreCfg {
            galore: GaLoreCfg {
                projection: ProjectionKind::Quant8,
                ..GaLoreCfg::default()
            },
            similarity_threshold: 0.9,
        }
    }
}

pub struct QGaLore {
    inner: GaLore,
    threshold: f32,
    /// Per-parameter dominant direction at last refresh (first column of P).
    last_dir: std::collections::BTreeMap<usize, Vec<f32>>,
    skipped: u64,
    taken: u64,
    t: u64,
}

impl QGaLore {
    pub fn new(cfg: QGaLoreCfg, adam: AdamCfg, seed: u64) -> QGaLore {
        assert!(
            matches!(
                cfg.galore.projection,
                ProjectionKind::Quant8 | ProjectionKind::Quant4
            ),
            "Q-GaLore requires a quantized projection kind"
        );
        QGaLore {
            inner: GaLore::new(cfg.galore, adam, seed),
            threshold: cfg.similarity_threshold,
            last_dir: std::collections::BTreeMap::new(),
            skipped: 0,
            taken: 0,
            t: 0,
        }
    }

    pub fn lazy_stats(&self) -> (u64, u64) {
        (self.taken, self.skipped)
    }

    fn cosine(a: &[f32], b: &[f32]) -> f32 {
        let dot: f32 = a.iter().zip(b).map(|(x, y)| x * y).sum();
        let na: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
        let nb: f32 = b.iter().map(|x| x * x).sum::<f32>().sqrt();
        if na == 0.0 || nb == 0.0 {
            0.0
        } else {
            (dot / (na * nb)).abs() // sign-invariant (§4.1.3)
        }
    }
}

impl Optimizer for QGaLore {
    fn begin_step(&mut self, t: u64) {
        self.t = t;
        self.inner.begin_step(t);
    }

    fn step_param(&mut self, idx: usize, param: &mut Matrix, grad: &Matrix, lr: f32) {
        // Lazy-refresh gate: on refresh steps, peek at whether the subspace
        // actually rotated. We approximate the Q-GaLore similarity test by
        // comparing the gradient's current dominant direction (one power
        // iteration — cheap) against the stored one.
        let is_refresh = self.t % self.inner.cfg.update_freq == 0 && self.t > 0;
        if is_refresh {
            if let Some(prev) = self.last_dir.get(&idx) {
                // Cheap subspace-rotation probe: G applied to a fixed probe
                // vector tracks the dominant row-space direction without an
                // SVD.
                let ggt_col = {
                    let probe = vec![1.0f32; grad.cols];
                    let mut dir = vec![0f32; grad.rows];
                    for r in 0..grad.rows {
                        dir[r] = crate::tensor::dot(grad.row(r), &probe);
                    }
                    dir
                };
                let sim = Self::cosine(prev, &ggt_col);
                if sim > self.threshold {
                    // Subspace stable: temporarily push the refresh horizon
                    // past this step by telling the inner optimizer the last
                    // refresh "just happened". Easiest correct mechanism:
                    // reinstall the existing projector (counts as refresh,
                    // but skips the SVD).
                    if let Some(p) = self.inner.export_projector(idx) {
                        self.inner.install_projector(idx, p);
                        self.skipped += 1;
                    }
                } else {
                    self.taken += 1;
                    self.last_dir.insert(idx, ggt_col);
                }
            }
        }
        self.inner.step_param(idx, param, grad, lr);
        // Record the initial direction after the first step creates state.
        self.last_dir.entry(idx).or_insert_with(|| {
            let probe = vec![1.0f32; grad.cols];
            let mut dir = vec![0f32; grad.rows];
            for r in 0..grad.rows {
                dir[r] = crate::tensor::dot(grad.row(r), &probe);
            }
            dir
        });
    }

    fn state_bytes(&self) -> usize {
        self.inner.state_bytes() + self.last_dir.values().map(|v| v.len() * 4).sum::<usize>()
    }

    fn name(&self) -> &'static str {
        "qgalore"
    }

    fn export_state(&self) -> Vec<u8> {
        // Inner GaLore blob (length-framed) + the lazy-gate state: without
        // `last_dir`, a resumed run's similarity gate would re-seed from a
        // post-resume gradient and take/skip different refreshes than the
        // uninterrupted run.
        let mut out = Vec::new();
        let inner = self.inner.export_state();
        ser::push_u64(&mut out, inner.len() as u64);
        out.extend_from_slice(&inner);
        ser::push_u64(&mut out, self.skipped);
        ser::push_u64(&mut out, self.taken);
        ser::push_u64(&mut out, self.last_dir.len() as u64);
        for (&idx, dir) in &self.last_dir {
            ser::push_u64(&mut out, idx as u64);
            ser::push_f32s(&mut out, dir);
        }
        out
    }

    fn import_state(&mut self, bytes: &[u8]) -> Result<(), String> {
        let mut r = ser::Reader::new(bytes);
        let inner_len = r.u64()? as usize;
        let inner = r.bytes(inner_len)?.to_vec();
        self.inner.import_state(&inner)?;
        self.skipped = r.u64()?;
        self.taken = r.u64()?;
        let n = r.u64()? as usize;
        self.last_dir.clear();
        for _ in 0..n {
            let idx = r.u64()? as usize;
            let dir = r.f32s()?;
            self.last_dir.insert(idx, dir);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn converges_with_quantized_projector() {
        let mut rng = Pcg64::new(1, 0);
        let u = Matrix::randn(16, 4, 1.0, &mut rng);
        let v = Matrix::randn(4, 32, 1.0, &mut rng);
        let target = u.matmul(&v);
        let cfg = QGaLoreCfg {
            galore: GaLoreCfg {
                rank: 4,
                update_freq: 30,
                alpha: 1.0,
                projection: ProjectionKind::Quant8,
                ..GaLoreCfg::default()
            },
            similarity_threshold: 0.95,
        };
        let mut opt = QGaLore::new(cfg, AdamCfg::default(), 3);
        let mut w = Matrix::zeros(16, 32);
        for t in 0..300 {
            let g = w.sub(&target);
            opt.begin_step(t);
            opt.step_param(0, &mut w, &g, 0.05);
        }
        let rel = w.sub(&target).frobenius_norm() / target.frobenius_norm();
        assert!(rel < 0.1, "rel {rel}");
    }

    #[test]
    fn lazy_gate_skips_on_stationary_gradients() {
        // Constant gradient direction ⇒ every scheduled refresh after the
        // first should be skipped.
        let mut rng = Pcg64::new(2, 0);
        let grad = Matrix::randn(8, 24, 1.0, &mut rng);
        let cfg = QGaLoreCfg {
            galore: GaLoreCfg {
                rank: 4,
                update_freq: 5,
                alpha: 1.0,
                projection: ProjectionKind::Quant8,
                ..GaLoreCfg::default()
            },
            similarity_threshold: 0.5,
        };
        let mut opt = QGaLore::new(cfg, AdamCfg::default(), 4);
        let mut w = Matrix::zeros(8, 24);
        for t in 0..26 {
            opt.begin_step(t);
            opt.step_param(0, &mut w, &grad, 1e-6); // tiny lr: grad ~constant
        }
        let (taken, skipped) = opt.lazy_stats();
        assert!(skipped >= 4, "skipped={skipped} taken={taken}");
        assert_eq!(taken, 0);
    }

    #[test]
    fn export_import_resumes_gate_and_trajectory() {
        // The lazy gate's last_dir and counters ride along in the state
        // blob: a resumed instance must take/skip the same refreshes and
        // stay bitwise on the uninterrupted trajectory.
        let mut rng = Pcg64::new(6, 0);
        let grad = Matrix::randn(8, 24, 1.0, &mut rng);
        let cfg = QGaLoreCfg {
            galore: GaLoreCfg {
                rank: 4,
                update_freq: 5,
                alpha: 1.0,
                projection: ProjectionKind::Quant8,
                ..GaLoreCfg::default()
            },
            similarity_threshold: 0.5,
        };
        let mut a = QGaLore::new(cfg, AdamCfg::default(), 4);
        let mut wa = Matrix::zeros(8, 24);
        for t in 0..12 {
            a.begin_step(t);
            a.step_param(0, &mut wa, &grad, 1e-6); // tiny lr: grad ~constant
        }
        let blob = a.export_state();
        let mut b = QGaLore::new(cfg, AdamCfg::default(), 77); // other seed
        b.import_state(&blob).unwrap();
        let mut wb = wa.clone();
        for t in 12..26 {
            a.begin_step(t);
            a.step_param(0, &mut wa, &grad, 1e-6);
            b.begin_step(t);
            b.step_param(0, &mut wb, &grad, 1e-6);
        }
        assert_eq!(wa.data, wb.data, "qgalore resume diverged");
        assert_eq!(a.lazy_stats(), b.lazy_stats(), "gate counters diverged");
    }

    #[test]
    fn rejects_fp32_projection_kind() {
        let cfg = QGaLoreCfg {
            galore: GaLoreCfg {
                projection: ProjectionKind::RandSvd,
                ..GaLoreCfg::default()
            },
            ..QGaLoreCfg::default()
        };
        let result = std::panic::catch_unwind(|| QGaLore::new(cfg, AdamCfg::default(), 1));
        assert!(result.is_err());
    }
}
