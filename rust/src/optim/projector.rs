//! Gradient subspace projectors (§3, §4.1).
//!
//! A projector holds P ∈ ℝ^{d×r} with orthonormal columns and maps gradients
//! between full and low-rank space:
//!   * wide parameters (m ≤ n): P spans the top row-space directions, taken
//!     from the left singular vectors U of G — R = Pᵀ G ∈ ℝ^{r×n};
//!   * tall parameters (m > n): P comes from the right singular vectors V —
//!     R = G P ∈ ℝ^{m×r}.
//!
//! [`ProjectionKind`] enumerates the refresh strategies compared in Fig. 1:
//! exact SVD, fast randomized SVD (§4.1.2, the GaLore 2 default), 8/4-bit
//! quantized storage of the SVD projector (Q-GaLore), and a random
//! orthonormal projector (the degradation case).

use crate::linalg::{qr_q_only, randomized_svd, svd, RandSvdOpts};
use crate::quant::{self, LinearQ4, LinearQ8, StoredTensor};
use crate::tensor::Matrix;
use crate::util::rng::Pcg64;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProjectionKind {
    /// Exact truncated SVD of the gradient (original GaLore).
    FullSvd,
    /// Halko randomized SVD (GaLore 2 default).
    RandSvd,
    /// Randomized SVD, then store P in linear 8-bit blocks (Q-GaLore).
    Quant8,
    /// Randomized SVD, then store P in linear 4-bit blocks (Q-GaLore-int4).
    Quant4,
    /// Random orthonormal basis, never spectrum-matched (ablation; Fig. 1
    /// shows this degrades significantly).
    Random,
}

impl ProjectionKind {
    pub fn parse(s: &str) -> Option<ProjectionKind> {
        Some(match s {
            "svd" | "full_svd" => ProjectionKind::FullSvd,
            "rand_svd" | "randomized" => ProjectionKind::RandSvd,
            "q8" | "quant8" => ProjectionKind::Quant8,
            "q4" | "quant4" => ProjectionKind::Quant4,
            "random" => ProjectionKind::Random,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            ProjectionKind::FullSvd => "svd",
            ProjectionKind::RandSvd => "rand_svd",
            ProjectionKind::Quant8 => "q8",
            ProjectionKind::Quant4 => "q4",
            ProjectionKind::Random => "random",
        }
    }
}

/// Which side of the gradient the projector multiplies (Alg. 1's m ≤ n
/// branch selects Left).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProjectorSide {
    /// P from left singular vectors; R = Pᵀ G (r×n). For m ≤ n.
    Left,
    /// P from right singular vectors; R = G P (m×r). For m > n.
    Right,
}

/// Storage for P — fp32 or quantized (Q-GaLore).
#[derive(Clone, Debug)]
enum Stored {
    F32(Matrix),
    Q8 { q: LinearQ8, rows: usize, cols: usize },
    Q4 { q: LinearQ4, rows: usize, cols: usize },
}

impl Stored {
    fn materialize(&self) -> Matrix {
        match self {
            Stored::F32(m) => m.clone(),
            Stored::Q8 { q, rows, cols } => Matrix::from_vec(*rows, *cols, q.dequantize()),
            Stored::Q4 { q, rows, cols } => Matrix::from_vec(*rows, *cols, q.dequantize()),
        }
    }

    fn nbytes(&self) -> usize {
        match self {
            Stored::F32(m) => m.numel() * 4,
            Stored::Q8 { q, .. } => q.nbytes(),
            Stored::Q4 { q, .. } => q.nbytes(),
        }
    }
}

/// A gradient subspace projector for one parameter.
pub struct Projector {
    pub kind: ProjectionKind,
    pub side: ProjectorSide,
    pub rank: usize,
    stored: Stored,
    /// Dequantized cache of P (dropped + rebuilt on refresh). Quantized
    /// kinds pay the storage win in `stored`; the cache models Q-GaLore's
    /// on-the-fly dequantization into the matmul.
    cache: Option<Matrix>,
    refresh_count: u64,
}

impl Projector {
    /// Build a projector for a parameter of shape (m, n) from its current
    /// gradient. Side selection follows Alg. 1: left if m ≤ n else right.
    pub fn from_gradient(
        grad: &Matrix,
        rank: usize,
        kind: ProjectionKind,
        rng: &mut Pcg64,
    ) -> Projector {
        let (m, n) = grad.shape();
        let side = if m <= n {
            ProjectorSide::Left
        } else {
            ProjectorSide::Right
        };
        let mut p = Projector {
            kind,
            side,
            rank: rank.min(m.min(n)),
            stored: Stored::F32(Matrix::zeros(0, 0)),
            cache: None,
            refresh_count: 0,
        };
        p.refresh(grad, rng);
        p
    }

    /// Build a projector from an explicit P and side — used by the FSDP
    /// engine when installing a leader-computed, replicated subspace on a
    /// worker whose local *shard* has a different aspect ratio than the
    /// full parameter (side must come from the full shape).
    pub fn from_parts(p: Matrix, side: ProjectorSide, kind: ProjectionKind) -> Projector {
        let rank = p.cols;
        let mut out = Projector {
            kind,
            side,
            rank,
            stored: Stored::F32(Matrix::zeros(0, 0)),
            cache: None,
            refresh_count: 0,
        };
        out.install_p(p);
        out
    }

    /// Recompute P to match the current gradient spectrum (every T steps).
    pub fn refresh(&mut self, grad: &Matrix, rng: &mut Pcg64) {
        let (m, n) = grad.shape();
        let d = match self.side {
            ProjectorSide::Left => m,
            ProjectorSide::Right => n,
        };
        let r = self.rank.min(m.min(n));
        let p: Matrix = match self.kind {
            ProjectionKind::Random => {
                // Orthonormalized Gaussian — matches the "random projection"
                // ablation: a valid isometry with no spectrum knowledge.
                let g = Matrix::randn(d, r, 1.0, rng);
                qr_q_only(&g)
            }
            ProjectionKind::FullSvd => {
                let s = svd(grad);
                match self.side {
                    ProjectorSide::Left => s.u.first_cols(r),
                    ProjectorSide::Right => s.vt.transpose().first_cols(r),
                }
            }
            ProjectionKind::RandSvd | ProjectionKind::Quant8 | ProjectionKind::Quant4 => {
                let s = randomized_svd(grad, r, RandSvdOpts::default(), rng);
                match self.side {
                    ProjectorSide::Left => s.u.first_cols(r),
                    ProjectorSide::Right => s.vt.transpose().first_cols(r),
                }
            }
        };
        self.stored = match self.kind {
            ProjectionKind::Quant8 => Stored::Q8 {
                q: LinearQ8::quantize(&p.data),
                rows: p.rows,
                cols: p.cols,
            },
            ProjectionKind::Quant4 => Stored::Q4 {
                q: LinearQ4::quantize(&p.data),
                rows: p.rows,
                cols: p.cols,
            },
            _ => Stored::F32(p),
        };
        self.cache = None;
        self.refresh_count += 1;
    }

    fn p(&mut self) -> &Matrix {
        if self.cache.is_none() {
            self.cache = Some(self.stored.materialize());
        }
        self.cache.as_ref().unwrap()
    }

    /// Project a full gradient into the low-rank space:
    /// Left: R = Pᵀ G (r×n);  Right: R = G P (m×r).
    pub fn project(&mut self, grad: &Matrix) -> Matrix {
        let side = self.side;
        let p = self.p();
        match side {
            ProjectorSide::Left => p.matmul_at_b(grad),
            ProjectorSide::Right => grad.matmul(p),
        }
    }

    /// Map a low-rank update back to full space:
    /// Left: G̃ = P N;  Right: G̃ = N Pᵀ.
    pub fn project_back(&mut self, low: &Matrix) -> Matrix {
        let side = self.side;
        let p = self.p();
        match side {
            ProjectorSide::Left => p.matmul(low),
            ProjectorSide::Right => low.matmul_a_bt(p),
        }
    }

    /// Shape of the low-rank gradient for a (m, n) parameter.
    pub fn low_rank_shape(&self, m: usize, n: usize) -> (usize, usize) {
        match self.side {
            ProjectorSide::Left => (self.rank.min(m), n),
            ProjectorSide::Right => (m, self.rank.min(n)),
        }
    }

    /// Bytes used to *store* P (the memory model's mr term; quantized kinds
    /// shrink it).
    pub fn nbytes(&self) -> usize {
        self.stored.nbytes()
    }

    pub fn refresh_count(&self) -> u64 {
        self.refresh_count
    }

    /// Export P for SVD-replication across FSDP workers (§4.3: the leader
    /// computes the SVD once and broadcasts the result).
    pub fn export_p(&self) -> Matrix {
        self.stored.materialize()
    }

    /// The exact *stored* representation of P — codes + block scales for
    /// quantized kinds, the f32 matrix otherwise — as the crate-wide
    /// [`StoredTensor`] codec type. This is what checkpoints persist and
    /// the FSDP broadcast ships: never dequantized values, whose
    /// re-quantization could wobble a block's absmax scale by 1 ulp and
    /// drift replicas off the leader's trajectory.
    pub fn stored_tensor(&self) -> StoredTensor {
        match &self.stored {
            Stored::F32(m) => StoredTensor::F32 {
                rows: m.rows,
                cols: m.cols,
                data: m.data.clone(),
            },
            Stored::Q8 { q, rows, cols } => StoredTensor::Q8 {
                rows: *rows,
                cols: *cols,
                q: q.clone(),
            },
            Stored::Q4 { q, rows, cols } => StoredTensor::Q4 {
                rows: *rows,
                cols: *cols,
                q: q.clone(),
            },
        }
    }

    /// Rebuild a projector from a [`StoredTensor`] — the exact inverse of
    /// [`Projector::stored_tensor`] when the tensor's storage kind matches
    /// `kind`. On a mismatch (a checkpoint taken under a different
    /// `[galore] projection` setting) the values are materialized and
    /// re-quantized for the configured kind, mirroring
    /// [`Projector::install_p`] — lossy, but shape-correct. `side` must
    /// come from the FULL parameter shape, as with `decode_wire`.
    pub fn from_stored(st: StoredTensor, side: ProjectorSide, kind: ProjectionKind) -> Projector {
        let (rows, cols) = (st.rows(), st.cols());
        let stored = match (&st, kind) {
            (StoredTensor::Q8 { q, .. }, ProjectionKind::Quant8) => Stored::Q8 {
                q: q.clone(),
                rows,
                cols,
            },
            (StoredTensor::Q4 { q, .. }, ProjectionKind::Quant4) => Stored::Q4 {
                q: q.clone(),
                rows,
                cols,
            },
            (
                StoredTensor::F32 { data, .. },
                ProjectionKind::FullSvd
                | ProjectionKind::RandSvd
                | ProjectionKind::Random,
            ) => Stored::F32(Matrix::from_vec(rows, cols, data.clone())),
            _ => {
                // Storage kind changed between save and resume (e.g. the
                // `[galore] projection` setting was edited): fall back to
                // the install path (materialize + re-encode for `kind`).
                // LOUD, never silent — this is the one lossy projector
                // conversion, and it only persists until the next
                // scheduled refresh re-derives the subspace.
                let stored_as = match &st {
                    StoredTensor::F32 { .. } => "f32",
                    StoredTensor::Q8 { .. } => "q8",
                    StoredTensor::Q4 { .. } => "q4",
                };
                eprintln!(
                    "[resume] projector stored as {stored_as} but the config \
                     selects {kind:?}: re-encoding (lossy until the next \
                     subspace refresh)"
                );
                let mut p = Projector {
                    kind,
                    side,
                    rank: cols,
                    stored: Stored::F32(Matrix::zeros(0, 0)),
                    cache: None,
                    refresh_count: 0,
                };
                p.install_p(Matrix::from_vec(rows, cols, st.materialize()));
                p.refresh_count = 0;
                return p;
            }
        };
        Projector {
            kind,
            side,
            rank: cols,
            stored,
            cache: None,
            refresh_count: 0,
        }
    }

    /// Encode the stored representation as f32 words for collective
    /// transport: the [`StoredTensor`] byte codec — the same one
    /// checkpoints use — packed into exact-integer words
    /// (`quant::bytes_to_words`), so there is exactly ONE quantized
    /// serialization layout crate-wide. Round-trips through
    /// [`Projector::decode_wire`] bit-exactly.
    pub fn encode_wire(&self) -> Vec<f32> {
        let mut bytes = Vec::new();
        self.stored_tensor().encode(&mut bytes);
        quant::bytes_to_words(&bytes)
    }

    /// Rebuild a projector from [`Projector::encode_wire`] words. `side`
    /// must come from the FULL parameter shape (the decoder may live on a
    /// worker whose local shard has a different aspect ratio); `kind` is
    /// the config's projection kind and must agree with the encoded tag.
    /// Panics on malformed words: the wire connects our own ranks, so
    /// corruption is an internal invariant violation, not user input.
    pub fn decode_wire(words: &[f32], side: ProjectorSide, kind: ProjectionKind) -> Projector {
        let bytes = quant::words_to_bytes(words)
            .unwrap_or_else(|e| panic!("corrupt projector wire encoding: {e}"));
        let mut r = crate::optim::ser::Reader::new(&bytes);
        let st = StoredTensor::decode(&mut r)
            .unwrap_or_else(|e| panic!("corrupt projector wire encoding: {e}"));
        // The broadcast connects ranks sharing one config: a storage-kind
        // mismatch here is an internal invariant violation (from_stored
        // would re-quantize and silently drift replicas), not a user's
        // config edit.
        debug_assert!(
            matches!(
                (&st, kind),
                (StoredTensor::Q8 { .. }, ProjectionKind::Quant8)
                    | (StoredTensor::Q4 { .. }, ProjectionKind::Quant4)
                    | (
                        StoredTensor::F32 { .. },
                        ProjectionKind::FullSvd
                            | ProjectionKind::RandSvd
                            | ProjectionKind::Random
                    )
            ),
            "wire tag does not match kind {kind:?}"
        );
        Projector::from_stored(st, side, kind)
    }

    /// Install a replicated P (on non-leader workers).
    pub fn install_p(&mut self, p: Matrix) {
        self.stored = match self.kind {
            ProjectionKind::Quant8 => Stored::Q8 {
                q: LinearQ8::quantize(&p.data),
                rows: p.rows,
                cols: p.cols,
            },
            ProjectionKind::Quant4 => Stored::Q4 {
                q: LinearQ4::quantize(&p.data),
                rows: p.rows,
                cols: p.cols,
            },
            _ => Stored::F32(p),
        };
        self.cache = None;
        self.refresh_count += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop;

    fn low_rank_grad(m: usize, n: usize, r: usize, rng: &mut Pcg64) -> Matrix {
        let a = Matrix::randn(m, r, 1.0, rng);
        let b = Matrix::randn(r, n, 1.0, rng);
        a.matmul(&b)
    }

    #[test]
    fn side_selection_follows_shape() {
        let mut rng = Pcg64::new(1, 0);
        let wide = Matrix::randn(8, 20, 1.0, &mut rng);
        let tall = Matrix::randn(20, 8, 1.0, &mut rng);
        let p1 = Projector::from_gradient(&wide, 4, ProjectionKind::RandSvd, &mut rng);
        let p2 = Projector::from_gradient(&tall, 4, ProjectionKind::RandSvd, &mut rng);
        assert_eq!(p1.side, ProjectorSide::Left);
        assert_eq!(p2.side, ProjectorSide::Right);
    }

    #[test]
    fn project_shapes() {
        let mut rng = Pcg64::new(2, 0);
        let g = Matrix::randn(8, 20, 1.0, &mut rng);
        let mut p = Projector::from_gradient(&g, 4, ProjectionKind::RandSvd, &mut rng);
        let r = p.project(&g);
        assert_eq!(r.shape(), (4, 20));
        let back = p.project_back(&r);
        assert_eq!(back.shape(), (8, 20));
    }

    #[test]
    fn svd_projector_preserves_low_rank_gradient() {
        // If rank(G) ≤ r, projection then back-projection must be lossless.
        prop::check("P Pᵀ G == G for low-rank G", 15, |g| {
            let m = g.usize_in(4, 16);
            let n = g.usize_in(4, 16);
            let r = g.usize_in(1, m.min(n) / 2 + 1);
            let mut rng = Pcg64::new(77, 3);
            let grad = low_rank_grad(m, n, r, &mut rng);
            for kind in [ProjectionKind::FullSvd, ProjectionKind::RandSvd] {
                let mut p = Projector::from_gradient(&grad, r, kind, &mut rng);
                let rec = {
                    let low = p.project(&grad);
                    p.project_back(&low)
                };
                let rel = grad.sub(&rec).frobenius_norm() / grad.frobenius_norm().max(1e-9);
                if rel > 2e-2 {
                    return Err(format!(
                        "{} lossy on rank-{r} {m}x{n} grad: rel {rel}",
                        kind.name()
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn random_projector_is_worse_than_svd() {
        // The Fig. 1 premise: spectrum-matched projection captures more
        // gradient energy than a random isometry.
        let mut rng = Pcg64::new(3, 0);
        // Gradient with decaying spectrum (realistic per the paper).
        let g = {
            let mut acc = Matrix::zeros(16, 48);
            for k in 0..16 {
                let u = Matrix::randn(16, 1, 1.0, &mut rng);
                let v = Matrix::randn(1, 48, 1.0, &mut rng);
                let mut outer = u.matmul(&v);
                outer.scale(0.6f32.powi(k));
                acc.add_assign(&outer);
            }
            acc
        };
        let capture = |p: &mut Projector| {
            let low = p.project(&g);
            let rec = p.project_back(&low);
            1.0 - g.sub(&rec).frobenius_norm() / g.frobenius_norm()
        };
        let mut svd_p = Projector::from_gradient(&g, 4, ProjectionKind::FullSvd, &mut rng);
        let mut rnd_p = Projector::from_gradient(&g, 4, ProjectionKind::Random, &mut rng);
        let c_svd = capture(&mut svd_p);
        let c_rnd = capture(&mut rnd_p);
        assert!(
            c_svd > c_rnd + 0.1,
            "svd capture {c_svd} vs random {c_rnd}"
        );
    }

    #[test]
    fn quantized_projector_close_to_fp32() {
        let mut rng = Pcg64::new(4, 0);
        let g = low_rank_grad(12, 30, 4, &mut rng);
        let mut fp = Projector::from_gradient(&g, 4, ProjectionKind::RandSvd, &mut rng);
        let mut q8 = Projector::from_gradient(&g, 4, ProjectionKind::Quant8, &mut rng);
        let r_fp = fp.project(&g);
        let r_q8 = q8.project(&g);
        let rel = r_fp.sub(&r_q8).frobenius_norm() / r_fp.frobenius_norm();
        assert!(rel < 0.05, "q8 projection rel err {rel}");
        // and q8 stores P in ~1/4 the bytes
        assert!(q8.nbytes() * 3 < fp.nbytes());
    }

    #[test]
    fn memory_accounting_per_kind() {
        let mut rng = Pcg64::new(5, 0);
        let g = Matrix::randn(256, 512, 1.0, &mut rng);
        let fp = Projector::from_gradient(&g, 64, ProjectionKind::RandSvd, &mut rng);
        assert_eq!(fp.nbytes(), 256 * 64 * 4); // d×r fp32
        let q4 = Projector::from_gradient(&g, 64, ProjectionKind::Quant4, &mut rng);
        assert!(q4.nbytes() < 256 * 64 / 2 + 1024);
    }

    #[test]
    fn replication_roundtrip() {
        let mut rng = Pcg64::new(6, 0);
        let g = Matrix::randn(10, 24, 1.0, &mut rng);
        let mut leader = Projector::from_gradient(&g, 4, ProjectionKind::RandSvd, &mut rng);
        let mut worker = Projector::from_gradient(&g, 4, ProjectionKind::Random, &mut rng);
        worker.install_p(leader.export_p());
        let a = leader.project(&g);
        let b = worker.project(&g);
        prop::assert_close(&a.data, &b.data, 1e-6, 1e-5).unwrap();
    }

    #[test]
    fn wire_encoding_transports_stored_repr_bit_exactly() {
        // The FSDP broadcast contract: a decoded projector projects
        // IDENTICALLY to the leader's — including quantized kinds, where
        // re-quantizing dequantized values could wobble block scales by
        // 1 ulp. (This is why the wire carries codes+scales, not floats.)
        let mut rng = Pcg64::new(17, 0);
        let g = Matrix::randn(20, 36, 1.0, &mut rng);
        for kind in [
            ProjectionKind::RandSvd,
            ProjectionKind::Quant8,
            ProjectionKind::Quant4,
        ] {
            let mut leader = Projector::from_gradient(&g, 6, kind, &mut rng);
            let words = leader.encode_wire();
            let mut worker = Projector::decode_wire(&words, leader.side, kind);
            assert_eq!(worker.rank, leader.rank, "{kind:?} rank");
            assert_eq!(
                worker.export_p().data,
                leader.export_p().data,
                "{kind:?}: dequantized P differs after wire transport"
            );
            let a = leader.project(&g);
            let b = worker.project(&g);
            assert_eq!(a.data, b.data, "{kind:?}: projection differs");
            // And a second encode round-trips to the same words.
            assert_eq!(worker.encode_wire(), words, "{kind:?}: unstable encoding");
        }
    }

    #[test]
    fn stored_tensor_roundtrip_preserves_exact_projection() {
        // stored_tensor → from_stored is the identity on the stored
        // representation for matching kinds; a kind mismatch falls back to
        // materialize + re-encode (shape-correct, possibly lossy).
        let mut rng = Pcg64::new(21, 0);
        let g = Matrix::randn(16, 28, 1.0, &mut rng);
        for kind in [
            ProjectionKind::RandSvd,
            ProjectionKind::Quant8,
            ProjectionKind::Quant4,
        ] {
            let mut a = Projector::from_gradient(&g, 5, kind, &mut rng);
            let mut b = Projector::from_stored(a.stored_tensor(), a.side, kind);
            assert_eq!(b.rank, a.rank, "{kind:?} rank");
            assert_eq!(a.project(&g).data, b.project(&g).data, "{kind:?}");
            assert_eq!(a.stored_tensor(), b.stored_tensor(), "{kind:?} stored");
        }
        // Mismatch: a q8 checkpoint resumed under an fp32 config still
        // yields a usable projector of the right geometry.
        let q8 = Projector::from_gradient(&g, 5, ProjectionKind::Quant8, &mut rng);
        let mut back =
            Projector::from_stored(q8.stored_tensor(), q8.side, ProjectionKind::RandSvd);
        assert_eq!(back.rank, 5);
        assert_eq!(back.project(&g).shape(), (5, 28));
    }

    #[test]
    fn projector_columns_orthonormal_all_kinds() {
        let mut rng = Pcg64::new(7, 0);
        let g = Matrix::randn(20, 40, 1.0, &mut rng);
        for kind in [
            ProjectionKind::FullSvd,
            ProjectionKind::RandSvd,
            ProjectionKind::Random,
        ] {
            let p = Projector::from_gradient(&g, 8, kind, &mut rng);
            let defect = p.export_p().orthonormality_defect();
            assert!(defect < 1e-3, "{} defect {defect}", kind.name());
        }
    }
}
