//! Adafactor (Shazeer & Stern 2018) — sublinear-memory related-work
//! baseline (§2): the second moment of an m×n parameter is factored into a
//! row vector (m) and a column vector (n) instead of the full mn matrix.
//!
//! This implementation uses the fixed-decay, no-first-moment variant with
//! update clipping (d=1.0), which is the memory-relevant comparison point.

use super::{ser, Optimizer};
use crate::tensor::Matrix;
use std::collections::BTreeMap;

struct State {
    row: Vec<f32>, // R_t: per-row mean of squared grads (EMA)
    col: Vec<f32>, // C_t: per-column mean of squared grads (EMA)
}

pub struct Adafactor {
    eps: f32,
    /// Decay exponent for the running averages: β₂(t) = 1 − t^(−0.8).
    decay_pow: f32,
    clip_d: f32,
    states: BTreeMap<usize, State>,
    t: u64,
}

impl Adafactor {
    pub fn new(eps: f32) -> Adafactor {
        Adafactor {
            eps,
            decay_pow: 0.8,
            clip_d: 1.0,
            states: BTreeMap::new(),
            t: 0,
        }
    }
}

impl Optimizer for Adafactor {
    fn begin_step(&mut self, t: u64) {
        self.t = t;
    }

    fn step_param(&mut self, idx: usize, param: &mut Matrix, grad: &Matrix, lr: f32) {
        assert_eq!(param.shape(), grad.shape());
        let (rows, cols) = grad.shape();
        let st = self.states.entry(idx).or_insert_with(|| State {
            row: vec![0.0; rows],
            col: vec![0.0; cols],
        });
        let beta2 = 1.0 - ((self.t + 1) as f32).powf(-self.decay_pow);

        // Row/column EMA of squared gradients (+eps regularizer as in paper).
        for r in 0..rows {
            let mut s = 0f32;
            for c in 0..cols {
                let g = grad.at(r, c);
                s += g * g + self.eps;
            }
            st.row[r] = beta2 * st.row[r] + (1.0 - beta2) * (s / cols as f32);
        }
        for c in 0..cols {
            let mut s = 0f32;
            for r in 0..rows {
                let g = grad.at(r, c);
                s += g * g + self.eps;
            }
            st.col[c] = beta2 * st.col[c] + (1.0 - beta2) * (s / rows as f32);
        }
        let row_mean: f32 =
            st.row.iter().sum::<f32>() / rows as f32;

        // U_t = G / sqrt(R Cᵀ / mean(R)); then clip by RMS and apply.
        let mut rms_acc = 0f64;
        let mut update = vec![0f32; rows * cols];
        for r in 0..rows {
            for c in 0..cols {
                let denom = (st.row[r] * st.col[c] / row_mean.max(1e-30)).sqrt() + 1e-30;
                let u = grad.at(r, c) / denom;
                update[r * cols + c] = u;
                rms_acc += (u as f64) * (u as f64);
            }
        }
        let rms = (rms_acc / (rows * cols) as f64).sqrt() as f32;
        let scale = 1.0 / (rms / self.clip_d).max(1.0);
        for i in 0..rows * cols {
            param.data[i] -= lr * scale * update[i];
        }
    }

    fn state_bytes(&self) -> usize {
        self.states
            .values()
            .map(|s| (s.row.len() + s.col.len()) * 4)
            .sum()
    }

    fn name(&self) -> &'static str {
        "adafactor"
    }

    fn export_state(&self) -> Vec<u8> {
        let mut out = Vec::new();
        ser::push_u64(&mut out, self.t);
        ser::push_u64(&mut out, self.states.len() as u64);
        for (&idx, st) in &self.states {
            ser::push_u64(&mut out, idx as u64);
            ser::push_f32s(&mut out, &st.row);
            ser::push_f32s(&mut out, &st.col);
        }
        out
    }

    fn import_state(&mut self, bytes: &[u8]) -> Result<(), String> {
        let mut r = ser::Reader::new(bytes);
        self.t = r.u64()?;
        let n = r.u64()? as usize;
        self.states.clear();
        for _ in 0..n {
            let idx = r.u64()? as usize;
            let row = r.f32s()?;
            let col = r.f32s()?;
            self.states.insert(idx, State { row, col });
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn state_is_sublinear() {
        let mut opt = Adafactor::new(1e-30);
        let mut p = Matrix::zeros(64, 128);
        let g = Matrix::from_vec(64, 128, vec![0.1; 64 * 128]);
        opt.begin_step(0);
        opt.step_param(0, &mut p, &g, 0.01);
        // (64 + 128) * 4 bytes, vs full Adam's 2*64*128*4.
        assert_eq!(opt.state_bytes(), (64 + 128) * 4);
        assert!(opt.state_bytes() * 80 < 2 * 64 * 128 * 4);
    }

    #[test]
    fn update_is_clipped() {
        // Huge gradient; RMS clipping must bound the applied step by ~lr·d.
        let mut opt = Adafactor::new(1e-30);
        let mut p = Matrix::zeros(4, 4);
        let g = Matrix::from_vec(4, 4, vec![1e6; 16]);
        opt.begin_step(0);
        opt.step_param(0, &mut p, &g, 0.1);
        assert!(p.max_abs() <= 0.1 * 1.0 + 1e-6, "max {}", p.max_abs());
    }

    #[test]
    fn descends_quadratic() {
        // RMS clipping means the step magnitude is ~lr once the factored
        // denominator stabilizes, so the residual plateaus at O(lr).
        let rel = crate::optim::tests::converges_on_quadratic(
            &mut Adafactor::new(1e-3),
            0.02,
            800,
        );
        assert!(rel < 0.10, "rel={rel}");
    }
}
