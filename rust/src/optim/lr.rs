//! Learning-rate schedules.
//!
//! §5: "learning rate warmup over the initial 10% of training steps and
//! cosine annealing ... reducing it to 10% of its initial value."

/// A learning-rate schedule mapping step → lr.
#[derive(Clone, Copy, Debug)]
pub enum Schedule {
    Constant {
        lr: f32,
    },
    /// Linear warmup to `peak` over `warmup` steps, then cosine decay to
    /// `peak * floor_frac` at `total` steps (the paper's schedule with
    /// warmup = 0.1·total, floor_frac = 0.1).
    WarmupCosine {
        peak: f32,
        warmup: u64,
        total: u64,
        floor_frac: f32,
    },
    /// Linear warmup then inverse-sqrt decay (Adafactor-style comparator).
    WarmupInvSqrt {
        peak: f32,
        warmup: u64,
    },
}

impl Schedule {
    /// The paper's schedule for a run of `total` steps at `peak` lr.
    pub fn paper_default(peak: f32, total: u64) -> Schedule {
        Schedule::WarmupCosine {
            peak,
            warmup: (total / 10).max(1),
            total,
            floor_frac: 0.1,
        }
    }

    pub fn lr(&self, step: u64) -> f32 {
        match *self {
            Schedule::Constant { lr } => lr,
            Schedule::WarmupCosine {
                peak,
                warmup,
                total,
                floor_frac,
            } => {
                if step < warmup {
                    peak * (step + 1) as f32 / warmup as f32
                } else {
                    let floor = peak * floor_frac;
                    let progress = (step - warmup) as f32
                        / (total.saturating_sub(warmup)).max(1) as f32;
                    let progress = progress.min(1.0);
                    let cos = 0.5 * (1.0 + (std::f32::consts::PI * progress).cos());
                    floor + (peak - floor) * cos
                }
            }
            Schedule::WarmupInvSqrt { peak, warmup } => {
                if step < warmup {
                    peak * (step + 1) as f32 / warmup as f32
                } else {
                    peak * ((warmup.max(1) as f32) / (step + 1) as f32).sqrt()
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_ramps_linearly() {
        let s = Schedule::WarmupCosine {
            peak: 1.0,
            warmup: 10,
            total: 100,
            floor_frac: 0.1,
        };
        assert!((s.lr(0) - 0.1).abs() < 1e-6);
        assert!((s.lr(4) - 0.5).abs() < 1e-6);
        assert!((s.lr(9) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn cosine_decays_to_floor() {
        let s = Schedule::paper_default(0.01, 1000);
        assert!((s.lr(999) - 0.001).abs() < 1e-4, "end lr {}", s.lr(999));
        // Monotone decreasing after warmup.
        let mut prev = s.lr(100);
        for t in (100..1000).step_by(50) {
            let cur = s.lr(t);
            assert!(cur <= prev + 1e-7);
            prev = cur;
        }
    }

    #[test]
    fn beyond_total_clamps_at_floor() {
        let s = Schedule::paper_default(0.01, 100);
        assert!((s.lr(5000) - 0.001).abs() < 1e-5);
    }

    #[test]
    fn midpoint_is_mean_of_peak_and_floor() {
        let s = Schedule::WarmupCosine {
            peak: 1.0,
            warmup: 0,
            total: 100,
            floor_frac: 0.0,
        };
        assert!((s.lr(50) - 0.5).abs() < 0.02, "mid lr {}", s.lr(50));
    }

    #[test]
    fn inv_sqrt_decays() {
        let s = Schedule::WarmupInvSqrt {
            peak: 1.0,
            warmup: 100,
        };
        assert!((s.lr(99) - 1.0).abs() < 1e-6);
        assert!((s.lr(399) - 0.5).abs() < 1e-2);
    }

    #[test]
    fn constant_is_constant() {
        let s = Schedule::Constant { lr: 0.123 };
        assert_eq!(s.lr(0), 0.123);
        assert_eq!(s.lr(1_000_000), 0.123);
    }
}
