//! Checkpointing: parameters + optimizer state + step, one binary file.
//!
//! Format (little-endian):
//!   magic "GAL2CKPT" | version u32 | step u64 |
//!   v4+: has_tokens u8, tokens_seen u64 |
//!   n_params u64 |
//!   per param: name_len u64, name bytes, rows u64, cols u64, f32 data |
//!   opt_blob_len u64 | optimizer state blob
//!
//! Since v3 the optimizer blob is the **canonical, world-agnostic form**
//! ([`canonical::CanonicalOptState`]): a checkpoint written by any
//! execution mode (`--parallel single|fsdp|ddp`) at any world size resumes
//! under any other — the elastic-restart contract pinned by
//! `tests/resharding.rs`. Legacy v2 files (mode-specific blobs: raw
//! single-process state, or FSDP per-rank frames that hard-require the
//! same world) still load; engines detect them by the missing canonical
//! header and fail loudly on any world mismatch instead of silently
//! resetting moments. Loading a v2 checkpoint at its original
//! mode/world and re-saving migrates it to the current version.
//!
//! v4 adds the exact `tokens_seen` counter: an ELASTIC resume (different
//! world) previously had to reconstruct the token axis from the NEW
//! world's tokens-per-step, rescaling the metrics axis. v2/v3 files load
//! with `tokens_seen: None` and keep that documented approximation
//! (`Trainer::resume`).
//!
//! v5 carries QUANTIZED canonical state: optimizer blobs serialize their
//! exact stored representation (Adam8bit codes + block scales, Q-GaLore
//! projector codes via `Projector::stored_tensor`), and the canonical
//! payload gains the typed `Quantized` flavor — extending elastic resume
//! to adam8bit/adafactor (bitwise where re-slicing is exact, loud
//! `--resume-requantize` opt-in otherwise) and lifting qgalore's old
//! refresh-alignment resume caveat. v2–v4 files still load behind the
//! existing legacy gates (mode-specific blobs, dequantized state layouts).
//!
//! Resume fidelity is tested end to end: a resumed run reproduces the
//! exact next-step losses of the uninterrupted run.

pub mod canonical;

use crate::optim::ser;
use crate::tensor::Matrix;
use anyhow::{bail, Context, Result};
use std::path::Path;

const MAGIC: &[u8; 8] = b"GAL2CKPT";
/// v5: quantized canonical state (stored-representation optimizer blobs +
/// the `Quantized` canonical flavor). v4: exact `tokens_seen` counter.
/// v3: canonical (re-shardable) optimizer state. v2: mode-specific blobs
/// — readable, but FSDP state is world-locked. v1 blobs would misparse,
/// so the version gate rejects them.
pub const VERSION: u32 = 5;
/// Oldest version [`Checkpoint::load`] still accepts.
pub const LEGACY_VERSION: u32 = 2;
/// First version carrying the `tokens_seen` field.
const TOKENS_SEEN_VERSION: u32 = 4;

pub struct Checkpoint {
    pub step: u64,
    /// Exact tokens consumed when this checkpoint was written (v4 field).
    /// `None` for pre-v4 files and non-trainer writers — resume then falls
    /// back to reconstructing from the resuming world's tokens-per-step.
    pub tokens_seen: Option<u64>,
    pub names: Vec<String>,
    pub params: Vec<Matrix>,
    pub opt_state: Vec<u8>,
}

impl Checkpoint {
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        self.save_with_version(path, VERSION)
    }

    /// Write with an explicit version number. Exists for migration tooling
    /// and the negative/migration tests — regular checkpoints always go
    /// through [`Checkpoint::save`], which writes the current [`VERSION`].
    pub fn save_with_version(&self, path: impl AsRef<Path>, version: u32) -> Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        // Byte layout lives in optim::ser's push helpers (single-parser
        // invariant); the resulting bytes are identical to what the old
        // direct `to_le_bytes` writes produced, so committed v3/v4
        // fixtures and the migration smoke are unaffected.
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        ser::push_u32(&mut out, version);
        ser::push_u64(&mut out, self.step);
        if version >= TOKENS_SEEN_VERSION {
            out.push(self.tokens_seen.is_some() as u8);
            ser::push_u64(&mut out, self.tokens_seen.unwrap_or(0));
        }
        ser::push_u64(&mut out, self.params.len() as u64);
        for (name, p) in self.names.iter().zip(&self.params) {
            ser::push_u64(&mut out, name.len() as u64);
            out.extend_from_slice(name.as_bytes());
            ser::push_u64(&mut out, p.rows as u64);
            ser::push_u64(&mut out, p.cols as u64);
            ser::push_f32s_raw(&mut out, &p.data);
        }
        ser::push_u64(&mut out, self.opt_state.len() as u64);
        out.extend_from_slice(&self.opt_state);
        std::fs::write(path.as_ref(), &out)
            .with_context(|| format!("writing checkpoint {:?}", path.as_ref()))
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint> {
        let bytes = std::fs::read(path.as_ref())
            .with_context(|| format!("opening checkpoint {:?}", path.as_ref()))?;
        if bytes.get(..MAGIC.len()) != Some(MAGIC.as_slice()) {
            bail!("not a galore2 checkpoint");
        }
        // Whole file in memory, parsed through the hardened ser::Reader:
        // every length field is range-checked against the REAL remaining
        // bytes before any allocation, so a corrupt header costs an error,
        // not a multi-GiB allocation (pinned by tests/invariants.rs).
        let mut r = ser::Reader::new(&bytes[MAGIC.len()..]);
        let trunc = |e: String| anyhow::anyhow!("truncated checkpoint: {e}");
        let version = r.u32().map_err(trunc)?;
        if !(LEGACY_VERSION..=VERSION).contains(&version) {
            bail!(
                "unsupported checkpoint version {version} (this build reads \
                 v{LEGACY_VERSION}–v{VERSION} checkpoints)"
            );
        }
        let step = r.u64().map_err(trunc)?;
        let tokens_seen = if version >= TOKENS_SEEN_VERSION {
            let has = r.bytes(1).map_err(trunc)?[0];
            let tokens = r.u64().map_err(trunc)?;
            (has != 0).then_some(tokens)
        } else {
            None
        };
        let n = r.u64().map_err(trunc)? as usize;
        // Each param costs at least 24 header bytes; a count claiming more
        // params than the file could possibly hold is corruption, caught
        // BEFORE the with_capacity allocations below.
        if n > r.remaining() / 24 {
            bail!("corrupt checkpoint: claims {n} params in {} bytes", r.remaining());
        }
        let mut names = Vec::with_capacity(n);
        let mut params = Vec::with_capacity(n);
        for _ in 0..n {
            let name_len = r.u64().map_err(trunc)? as usize;
            let name = r.bytes(name_len).map_err(trunc)?.to_vec();
            names.push(String::from_utf8(name).context("bad name")?);
            let rows = r.u64().map_err(trunc)? as usize;
            let cols = r.u64().map_err(trunc)? as usize;
            let count = rows
                .checked_mul(cols)
                .ok_or_else(|| anyhow::anyhow!("corrupt checkpoint: shape {rows}x{cols}"))?;
            let data = r.f32s_exact(count).map_err(trunc)?;
            params.push(Matrix::from_vec(rows, cols, data));
        }
        let blob_len = r.u64().map_err(trunc)? as usize;
        let opt_state = r
            .bytes(blob_len)
            .map_err(|_| {
                anyhow::anyhow!(
                    "truncated checkpoint: optimizer state shorter than its header claims"
                )
            })?
            .to_vec();
        Ok(Checkpoint {
            step,
            tokens_seen,
            names,
            params,
            opt_state,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("galore2_test_{name}_{}", std::process::id()))
    }

    #[test]
    fn roundtrip() {
        let mut rng = Pcg64::new(1, 0);
        let ckpt = Checkpoint {
            step: 42,
            tokens_seen: Some(987_654_321),
            names: vec!["a".into(), "b.weight".into()],
            params: vec![
                Matrix::randn(3, 5, 1.0, &mut rng),
                Matrix::randn(7, 2, 1.0, &mut rng),
            ],
            opt_state: vec![1, 2, 3, 255],
        };
        let path = tmp("roundtrip");
        ckpt.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.step, 42);
        assert_eq!(back.tokens_seen, Some(987_654_321));
        assert_eq!(back.names, ckpt.names);
        assert_eq!(back.params[0].data, ckpt.params[0].data);
        assert_eq!(back.params[1].shape(), (7, 2));
        assert_eq!(back.opt_state, vec![1, 2, 3, 255]);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_garbage() {
        let path = tmp("garbage");
        std::fs::write(&path, b"definitely not a checkpoint").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn accepts_legacy_v2_v3_v4_rejects_unknown_versions() {
        let ckpt = Checkpoint {
            step: 3,
            tokens_seen: Some(999),
            names: vec!["w".into()],
            params: vec![Matrix::zeros(2, 2)],
            opt_state: vec![7; 12],
        };
        let path = tmp("versions");
        for legacy in [2u32, 3] {
            ckpt.save_with_version(&path, legacy).unwrap();
            let back = Checkpoint::load(&path).unwrap();
            assert_eq!(
                back.opt_state,
                vec![7; 12],
                "v{legacy} payload must pass through"
            );
            assert_eq!(
                back.tokens_seen, None,
                "pre-v4 files carry no token counter"
            );
        }
        ckpt.save_with_version(&path, 4).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.opt_state, vec![7; 12], "v4 payload must pass through");
        assert_eq!(back.tokens_seen, Some(999), "v4 carries the token counter");
        for bad in [1u32, 6, 99] {
            ckpt.save_with_version(&path, bad).unwrap();
            let err = Checkpoint::load(&path).unwrap_err().to_string();
            assert!(
                err.contains(&format!("version {bad}")),
                "unhelpful error for v{bad}: {err}"
            );
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn absent_token_counter_survives_v4_roundtrip() {
        // Non-trainer writers (migration tools, tests) may not know the
        // counter; None must NOT come back as Some(0).
        let ckpt = Checkpoint {
            step: 1,
            tokens_seen: None,
            names: vec!["w".into()],
            params: vec![Matrix::zeros(1, 2)],
            opt_state: Vec::new(),
        };
        let path = tmp("no_tokens");
        ckpt.save(&path).unwrap();
        assert_eq!(Checkpoint::load(&path).unwrap().tokens_seen, None);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn truncated_file_fails_loudly() {
        let ckpt = Checkpoint {
            step: 3,
            tokens_seen: None,
            names: vec!["w".into()],
            params: vec![Matrix::zeros(4, 4)],
            opt_state: vec![9; 100],
        };
        let path = tmp("truncated");
        ckpt.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        // Chop into the framed optimizer blob: the declared length no
        // longer matches, which must be an error — never a silent
        // moment reset.
        std::fs::write(&path, &bytes[..bytes.len() - 40]).unwrap();
        let err = Checkpoint::load(&path).unwrap_err().to_string();
        assert!(err.contains("truncated"), "unhelpful error: {err}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn optimizer_state_resume_equivalence() {
        use crate::optim::{AdamCfg, AdamW, Optimizer};
        let mut rng = Pcg64::new(2, 0);
        let target = Matrix::randn(6, 9, 1.0, &mut rng);
        let mut opt = AdamW::new(AdamCfg::default());
        let mut w = Matrix::zeros(6, 9);
        for t in 0..7 {
            let g = w.sub(&target);
            opt.begin_step(t);
            opt.step_param(0, &mut w, &g, 0.05);
        }
        let ckpt = Checkpoint {
            step: 7,
            tokens_seen: None,
            names: vec!["w".into()],
            params: vec![w.clone()],
            opt_state: opt.export_state(),
        };
        let path = tmp("resume");
        ckpt.save(&path).unwrap();

        // Continue original.
        let mut w_orig = w.clone();
        for t in 7..12 {
            let g = w_orig.sub(&target);
            opt.begin_step(t);
            opt.step_param(0, &mut w_orig, &g, 0.05);
        }
        // Resume from disk.
        let back = Checkpoint::load(&path).unwrap();
        let mut opt2 = AdamW::new(AdamCfg::default());
        opt2.import_state(&back.opt_state).unwrap();
        let mut w_res = back.params[0].clone();
        for t in back.step..12 {
            let g = w_res.sub(&target);
            opt2.begin_step(t);
            opt2.step_param(0, &mut w_res, &g, 0.05);
        }
        assert_eq!(w_orig.data, w_res.data);
        std::fs::remove_file(path).ok();
    }
}
